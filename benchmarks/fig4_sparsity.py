"""Paper Fig. 4/5: (a) codebook-entry usage ratio by the true top-100 per
subspace — the sparsity JUNO exploits; (b) CDF of top-100 coverage from
closest to farthest entries — the spatial locality. The paper reports
~25-30% average usage and >90% coverage within the closest 50% of entries."""
from __future__ import annotations

import numpy as np

from repro.core.ivf import filter_clusters
from repro.core.pq import split_subspaces
from .common import emit, get_bench_index


def run(dataset="deep"):
    pts, queries, index, gt, cfg = get_bench_index(dataset)
    codes = index.codes                                   # (N, S)
    s_dim = codes.shape[1]
    e = cfg.n_entries

    gt_codes = codes[gt[:, :100]].astype(np.int32)        # (Q, 100, S)
    used = np.zeros((s_dim,))
    for s in range(s_dim):
        for qi in range(gt_codes.shape[0]):
            used[s] += len(np.unique(np.asarray(gt_codes[qi, :, s])))
    used_ratio = used / gt_codes.shape[0] / e

    # coverage CDF: entries ranked by distance to the query projection
    _, c1 = filter_clusters(queries, index.ivf, nprobe=1,
                            metric=cfg.metric)
    qres = queries - index.ivf.centroids[c1[:, 0]]
    qsub = np.asarray(split_subspaces(qres, cfg.sub_dim))  # (Q, S, M)
    entries = np.asarray(index.codebook.entries)           # (S, E, M)
    fracs = [0.125, 0.25, 0.5, 0.75]
    cover = np.zeros((len(fracs),))
    nq = qsub.shape[0]
    for qi in range(nq):
        d = np.sum((entries - qsub[qi][:, None]) ** 2, -1)     # (S, E)
        order = np.argsort(d, axis=1)
        rank_of = np.argsort(order, axis=1)                    # entry → rank
        gt_rank = np.take_along_axis(
            rank_of, np.asarray(gt_codes[qi]).T, axis=1)       # (S, 100)
        for fi, f in enumerate(fracs):
            cover[fi] += np.mean(gt_rank < f * e)
    cover /= nq

    emit(f"fig4_sparsity_{dataset}", 0.0,
         f"avg_used%={used_ratio.mean() * 100:.1f};"
         f"max_used%={used_ratio.max() * 100:.1f};"
         + ";".join(f"cdf@{int(f * 100)}%={c * 100:.1f}"
                    for f, c in zip(fracs, cover)))

"""Diagnostic: lower one cell and print the top collectives by scaled link
traffic, with their HLO metadata op_name (which model op produced them).

    PYTHONPATH=src python -m benchmarks.collective_diag --arch X --shape Y
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")
import argparse
import re

from repro.launch import hlo_analysis as ha


def diagnose(hlo: str, top: int = 15):
    comps = ha.split_computations(hlo)
    mult = ha.computation_multipliers(comps)
    rows = []
    for name, lines in comps.items():
        m = max(mult.get(name, 1.0), 1.0)
        for line in lines:
            cm = ha._COLL_RE.search(line)
            if not cm:
                continue
            op = ha.CollectiveOp(kind=cm.group(1),
                                 result_bytes=ha._shape_bytes(line),
                                 group_size=ha._group_size(line),
                                 multiplier=m)
            meta = re.search(r'op_name="([^"]*)"', line)
            shape = re.search(r"=\s*(\(?[a-z0-9]+\[[^\]]*\])", line)
            rows.append((op.per_chip_link_bytes, op.kind,
                         shape.group(1) if shape else "?", op.group_size, m,
                         (meta.group(1)[-110:] if meta else "?")))
    rows.sort(key=lambda r: -r[0])
    total = sum(r[0] for r in rows)
    print(f"total link bytes/chip: {total / 1e9:.1f} GB")
    for r in rows[:top]:
        print(f"{r[0] / 1e9:8.2f}GB {r[1]:18s} {r[2]:28s} grp={r[3]:<4d} "
              f"x{r[4]:<6.0f} {r[5]}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--sp", action="store_true",
                    help="sequence-parallel activation constraints")
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.dist import sharding as act_sharding
    from repro.launch.dryrun import (_lower_decode, _lower_prefill,
                                     _lower_train)
    from repro.launch.mesh import batch_axes, make_production_mesh
    from repro.launch.shapes import SHAPES
    from repro.models import get_model

    cfg = get_config(args.arch)
    model = get_model(cfg)
    mesh = make_production_mesh(multi_pod=args.multi)
    act_sharding.enable(batch_axes(mesh), sp=args.sp, mesh=mesh)
    shape = SHAPES[args.shape]
    with mesh:
        if shape.kind == "train":
            lowered, _ = _lower_train(model, shape, mesh)
        elif shape.kind == "prefill":
            lowered, _ = _lower_prefill(model, shape, mesh)
        else:
            lowered, _ = _lower_decode(model, shape, mesh)
        hlo = lowered.compile().as_text()
    diagnose(hlo, args.top)


if __name__ == "__main__":
    main()

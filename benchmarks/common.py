"""Shared benchmark infrastructure: datasets, index cache, timing."""
from __future__ import annotations

import functools
import time

import jax

from repro.core import JunoConfig, build, exact_topk
from repro.data import DEEP_LIKE, SIFT_LIKE, TTI_LIKE, make_dataset

# CPU-scaled defaults (flags in run.py scale up, --smoke scales down).
# Module globals resolved at CALL time so run.py can adjust them after import.
N_POINTS = 30_000
N_QUERIES = 64
N_CLUSTERS = 128
N_ENTRIES = 128


def set_smoke_sizes():
    """Shrink the shared benchmark problem to CI-smoke scale (~seconds per
    figure module). Call before the first get_bench_index()."""
    global N_POINTS, N_QUERIES, N_CLUSTERS, N_ENTRIES
    N_POINTS, N_QUERIES, N_CLUSTERS, N_ENTRIES = 4_000, 16, 32, 32
    get_bench_index.cache_clear()


@functools.lru_cache(maxsize=4)
def get_bench_index(dataset: str = "deep", n_points: int | None = None,
                    n_queries: int | None = None):
    n_points = N_POINTS if n_points is None else n_points
    n_queries = N_QUERIES if n_queries is None else n_queries
    spec = {"deep": DEEP_LIKE, "sift": SIFT_LIKE, "tti": TTI_LIKE}[dataset]
    pts, queries = make_dataset(spec, n_points, n_queries,
                                key=jax.random.PRNGKey(11))
    cfg = JunoConfig(n_clusters=N_CLUSTERS, n_entries=N_ENTRIES,
                     metric=spec.metric, calib_queries=48, kmeans_iters=8)
    index = build(pts, cfg)
    _, gt = exact_topk(queries, pts, k=100, metric=spec.metric)
    return pts, queries, index, gt, cfg


def time_fn(fn, *args, warmup: int = 1, iters: int = 3, **kw) -> float:
    """Median wall time in seconds (jit-warm)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)

"""Paper Fig. 14(a): algorithm-only gain — JUNO's selective algorithm run
WITHOUT the hardware-mapped kernels (impl="ref", the A100-without-RT-core
analogue) against the IVFPQ baseline. The paper reports the selection
algorithm alone is worth up to 2.6×; here the derived column carries the
work reduction that produces that gain (f32 accumulate ops per query)."""
from __future__ import annotations

from repro.core import recall_1_at_k, search
from .common import emit, get_bench_index, time_fn


def run():
    pts, queries, index, gt, cfg = get_bench_index("deep")
    gt1 = gt[:, 0]
    p_cap = index.ivf.capacity
    s = 48
    for nprobe in [8, 16]:
        rows = {}
        for name, kw in [("baseline_fullLUT", dict(mode="H",
                                                   thres_scale=1e6)),
                         ("juno_algo_only_H2", dict(mode="H2"))]:
            def fn():
                return search(index, queries, nprobe=nprobe, k=100,
                              impl="ref", **kw)
            t = time_fn(fn, iters=3)
            _, ids = fn()
            r1 = float(recall_1_at_k(ids, gt1))
            f32_ops = (nprobe * p_cap * s if "baseline" in name
                       else 400 * s)
            rows[name] = (t, r1, f32_ops)
            emit(f"fig14_{name}_np{nprobe}", t / queries.shape[0] * 1e6,
                 f"R1@100={r1:.3f};f32_accum_ops/q={f32_ops}")
        speed = rows["baseline_fullLUT"][0] / rows["juno_algo_only_H2"][0]
        work = rows["baseline_fullLUT"][2] / rows["juno_algo_only_H2"][2]
        emit(f"fig14_speedup_np{nprobe}", 0.0,
             f"wallclock_x={speed:.2f};f32_work_reduction_x={work:.1f}")

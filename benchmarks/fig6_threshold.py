"""Paper Fig. 6 + 7(b): remaining search points needing distance
accumulation vs threshold scale (linear-ish decrease), and the power-law
top-100 retention when the threshold shrinks (×0.5 keeps ≈90%)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import density as density_lib
from repro.core import lut as lut_lib
from repro.core.ivf import filter_clusters
from repro.core import scan as scan_lib
from .common import emit, get_bench_index


def run():
    pts, queries, index, gt, cfg = get_bench_index("deep")
    nprobe = 16
    m = cfg.sub_dim
    q = queries.astype(jnp.float32)
    base, cids = filter_clusters(q, index.ivf, nprobe=nprobe)
    res = q[:, None, :] - index.ivf.centroids[cids]
    qsub = res.reshape(q.shape[0], nprobe, -1, m)
    codes = index.cluster_codes[cids]
    valid = index.ivf.valid[cids]
    ids = index.ivf.point_ids[cids]

    for scale in [0.1, 0.25, 0.5, 1.0]:
        tau = density_lib.predict_threshold(index.density, qsub, scale)
        _, mask = lut_lib.build_lut(qsub, index.codebook, tau)
        # work metrics: entries kept in the LUT (stage-B savings) and
        # (point, subspace) lookups skipped (stage-C savings, the paper's
        # inverted-index skip, Alg. 2)
        entries_kept = float(jnp.mean(mask))
        kept = jax.vmap(jax.vmap(scan_lib.hit_count_scan))(
            mask.astype(jnp.int8), codes, valid)
        s_dim = codes.shape[-1]
        lookups_kept = float(jnp.sum(jnp.where(valid, kept, 0))) / \
            (float(jnp.sum(valid)) * s_dim)
        # a point "remains" if hit in ≥1 subspace (inverted-index semantics)
        remains = (kept > 0) & valid
        frac = float(jnp.sum(remains)) / float(jnp.sum(valid))

        # top-100 retention: fraction of true top-100 still fully covered
        gt100 = np.asarray(gt[:, :100])
        idn = np.asarray(ids).reshape(ids.shape[0], -1)
        remn = np.asarray(remains).reshape(ids.shape[0], -1)
        ret = 0.0
        for qi in range(idn.shape[0]):
            keep_ids = set(idn[qi][remn[qi]])
            ret += np.mean([g in keep_ids for g in gt100[qi]])
        ret /= idn.shape[0]
        emit(f"fig6_threshold_scale{scale}", 0.0,
             f"remaining%={frac * 100:.1f};"
             f"entries_kept%={entries_kept * 100:.1f};"
             f"lookups_kept%={lookups_kept * 100:.1f};"
             f"top100_retained%={ret * 100:.1f}")

"""Benchmark harness entry point — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--only fig12] [--dataset deep]
"""
from __future__ import annotations

import argparse
import os
import sys
import time
import traceback

# support both `python -m benchmarks.run` and `python benchmarks/run.py`
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

MODULES = [
    ("fig3_breakdown", "stage time breakdown (paper Fig. 3a)"),
    ("fig4_sparsity", "entry sparsity + locality CDF (Fig. 4/5)"),
    ("fig6_threshold", "remaining points & retention vs threshold (Fig. 6/7b)"),
    ("fig7_density", "density<->threshold regression (Fig. 7a)"),
    ("fig11_hitcount", "hit-count <-> distance correlation (Fig. 11b)"),
    ("fig12_qps_recall", "QPS vs recall Pareto (Fig. 12)"),
    ("fig13_ablation", "optimization ablations (Fig. 13)"),
    ("fig14_algo_only", "algorithm-only gain (Fig. 14a)"),
]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--dataset", default="deep",
                    choices=["deep", "sift", "tti"])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-N CI mode: every module runs in seconds; "
                         "exit code is the number of failing modules")
    args = ap.parse_args()

    if args.smoke:
        from benchmarks import common
        common.set_smoke_sizes()

    print("name,us_per_call,derived")
    failures = 0
    for mod_name, desc in MODULES:
        if args.only and args.only not in mod_name:
            continue
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            import inspect
            sig = inspect.signature(mod.run)
            if "dataset" in sig.parameters:
                mod.run(dataset=args.dataset)
            else:
                mod.run()
            print(f"# {mod_name} done in {time.time() - t0:.0f}s "
                  f"({desc})", file=sys.stderr)
        except Exception:
            failures += 1
            print(f"# {mod_name} FAILED:\n{traceback.format_exc()}",
                  file=sys.stderr)
    return failures


if __name__ == "__main__":
    raise SystemExit(main())

"""Paper Fig. 7(a): correlation between the threshold needed to contain the
top-100 and local point density (negative), and the polynomial regressor's
fit quality — the dynamic-threshold machinery's calibration report."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import density as density_lib
from repro.core.ivf import filter_clusters
from repro.core.pq import split_subspaces
from .common import emit, get_bench_index


def run():
    pts, queries, index, gt, cfg = get_bench_index("deep")
    _, c1 = filter_clusters(queries, index.ivf, nprobe=1)
    qres = queries - index.ivf.centroids[c1[:, 0]]
    qsub = split_subspaces(qres, cfg.sub_dim)              # (Q, S, M)

    # needed threshold per (query, subspace) from ground truth
    gt_codes = index.codes[gt[:, :100]].astype(jnp.int32)  # (Q, 100, S)
    ent = index.codebook.entries
    s_idx = jnp.arange(ent.shape[0])[None, None, :]
    gt_entries = ent[s_idx, gt_codes]
    diff = gt_entries - qsub[:, None]
    tau_needed = jnp.sqrt(jnp.max(jnp.sum(diff * diff, -1), axis=1))

    dens = density_lib.lookup_density(index.density, qsub)
    x = np.asarray(dens).ravel()
    y = np.asarray(tau_needed).ravel()
    corr = float(np.corrcoef(x, y)[0, 1])

    pred = np.asarray(density_lib.predict_threshold(index.density, qsub))
    resid = np.abs(pred.ravel() - y) / np.maximum(y, 1e-6)
    # fraction of subspaces where predicted tau covers the needed tau
    coverage = float(np.mean(pred.ravel() >= y * 0.999))
    emit("fig7_density_threshold", 0.0,
         f"pearson={corr:.3f};median_rel_err={np.median(resid):.3f};"
         f"tau_covers_needed%={coverage * 100:.1f}")

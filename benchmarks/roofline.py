"""Roofline report generator: reads experiments/dryrun/*.json and emits the
per-(arch × shape × mesh) table for EXPERIMENTS.md §Roofline.

    PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    for unit, scale in [("s", 1.0), ("ms", 1e-3), ("us", 1e-6), ("ns", 1e-9)]:
        if x >= scale:
            return f"{x / scale:.2f}{unit}"
    return f"{x:.1e}s"


def load(dirname: str) -> list:
    rows = []
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        d = json.load(open(f))
        rows.append(d)
    return rows


def bottleneck_sentence(r: dict) -> str:
    dom = r["roofline"]["dominant"]
    if dom == "collective":
        kinds = {k: v for k, v in r["collectives"].items()
                 if isinstance(v, dict)}
        top = max(kinds.items(),
                  key=lambda kv: kv[1]["link_bytes_per_chip"])[0] \
            if kinds else "?"
        return (f"{top} traffic dominates — reshard/overlap it")
    if dom == "memory":
        return "HBM streaming dominates — fuse/cast to cut passes"
    return "MXU-bound — increase arithmetic intensity only via algorithm"


def markdown_table(rows: list, mesh: str = "single") -> str:
    hdr = ("| arch | shape | compute | memory | collective | dominant | "
           "roofline-frac | 6ND/analytic | note |\n"
           "|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        if r.get("mesh") != mesh:
            continue
        if r["status"] == "skip":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | skip | "
                         f"— | — | {r['reason'][:60]} |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | ERROR |"
                         f" — | — | {r['error'][:60]} |")
            continue
        t = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(t['compute_s'])} | "
            f"{fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} | "
            f"{t['dominant']} | {t['roofline_fraction']:.3f} | "
            f"{r['useful_flop_ratio']:.2f} | {bottleneck_sentence(r)} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    args = ap.parse_args()
    rows = load(args.dir)
    print(markdown_table(rows, args.mesh))
    ok = [r for r in rows if r["status"] == "ok" and r["mesh"] == args.mesh]
    worst = sorted(ok, key=lambda r: r["roofline"]["roofline_fraction"])[:5]
    print("\nworst roofline fractions:")
    for r in worst:
        print(f"  {r['arch']} {r['shape']}: "
              f"{r['roofline']['roofline_fraction']:.4f} "
              f"({r['roofline']['dominant']})")
    coll = sorted(ok, key=lambda r: -r["roofline"]["collective_s"])[:5]
    print("most collective-bound (abs seconds):")
    for r in coll:
        print(f"  {r['arch']} {r['shape']}: "
              f"coll={fmt_s(r['roofline']['collective_s'])} vs "
              f"comp={fmt_s(r['roofline']['compute_s'])}")


if __name__ == "__main__":
    main()

"""Build-pipeline benchmark: streaming throughput, store round-trip,
rebuild + hot-swap serve parity.

Exercises the full `repro.build` lifecycle at ~200k synthetic points:

1. **stream-build** the set through `build_streaming` (chunked source,
   bounded training sample) and report throughput in pts/s;
2. **round-trip** the index through the versioned artifact store
   (save → verify → load, checksummed);
3. **load spills** into a serving engine (overfill the tightest cluster
   so the side buffer carries real weight), **rebuild + swap**
   (`AnnServeEngine.swap_index`), report the rebuild wall time, and
   assert the swap preserved search results;
4. time **side-buffer-laden vs post-rebuild serve QPS** with interleaved
   passes (this box's load drifts on the seconds scale — back-to-back
   blocks would hand one engine a quiet machine, docs/benchmarks.md).

``--check``/``--smoke`` gate: post-rebuild QPS >= side-laden QPS (the
side gather is pure extra work, so a rebuild that does not win means the
swap broke something) and artifact integrity. ``--json`` records the
numbers (committed as BENCH_build.json).

    PYTHONPATH=src python benchmarks/build_bench.py [--smoke] [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

import numpy as np

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

import jax  # noqa: E402

from benchmarks import common  # noqa: E402
from repro.build import (ArtifactStore, BuildProbe, array_source,  # noqa: E402
                         build_streaming)
from repro.core import JunoConfig, MutableJunoIndex  # noqa: E402
from repro.data import DEEP_LIKE, make_dataset  # noqa: E402
from repro.serve.ann import AnnServeEngine  # noqa: E402

# single-query H2-tier requests: the online-serving shape where the side
# buffer's per-search (Q, B) gather weighs the most relative to useful work
REQUESTS = [(1, 10, 0.85), (1, 10, 0.88), (1, 10, 0.82)]


def _spill_and_tombstone(mid: MutableJunoIndex, rng, n_spill: int,
                         n_points: int) -> int:
    """Overfill the tightest clusters until >= n_spill side entries exist,
    then tombstone one original member per spill in the same clusters.

    The mixed insert+delete shape of a real serving workload: the side
    buffer is laden AND freed slots exist, so the rebuild drains every
    spill WITHOUT growing the padded capacity — post-swap searches reuse
    the warm jit signatures (docs/building.md)."""
    import collections

    n_clusters = mid.data.ivf.point_ids.shape[0]
    free = [mid.free_slots(c) for c in range(n_clusters)]
    order = np.argsort(free)
    d = mid.data.ivf.centroids.shape[1]
    for c in order:
        if mid.side_fill >= n_spill:
            break
        c = int(c)
        cent = np.asarray(mid.data.ivf.centroids[c])
        need = mid.free_slots(c) + min(n_spill - mid.side_fill,
                                       mid.side.capacity - mid.side_fill)
        pts = (cent[None] + 0.01 * rng.standard_normal((need, d))
               ).astype(np.float32)
        mid.insert(pts)
    side_mask = np.asarray(mid.side.valid)
    per_c = collections.Counter(
        np.asarray(mid.side.cluster)[side_mask].tolist())
    victims = []
    for c, cnt in per_c.items():
        row = np.asarray(mid.data.ivf.point_ids[c])
        val = np.asarray(mid.data.ivf.valid[c])
        orig = [int(p) for p in row[val] if p < n_points]
        victims += orig[:cnt]
    mid.delete(victims)
    return mid.side_fill


def _make_trace(queries: np.ndarray, n_requests: int):
    trace, pos = [], 0
    for r in range(n_requests):
        nq, k, target = REQUESTS[r % len(REQUESTS)]
        rows = np.take(queries, range(pos, pos + nq), axis=0, mode="wrap")
        trace.append((rows, k, target))
        pos += nq
    return trace


def run(n_points: int = 200_000, n_requests: int = 96,
        n_spill: int = 256) -> dict:
    pts, queries = make_dataset(DEEP_LIKE, n_points, 64,
                                key=jax.random.PRNGKey(11))
    pts, queries = np.asarray(pts), np.asarray(queries)
    cfg = JunoConfig(n_clusters=128, n_entries=64, metric="l2",
                     calib_queries=32, kmeans_iters=8,
                     max_train_points=50_000, capacity_mult=1.05)

    # --- 1. streaming build ----------------------------------------------
    probe = BuildProbe()
    t0 = time.perf_counter()
    data = build_streaming(array_source(pts, 32768), cfg, probe=probe)
    t_build = time.perf_counter() - t0
    build_pps = n_points / t_build
    common.emit("build_bench.stream_build", t_build * 1e6,
                f"pts_per_s={build_pps:.0f};chunks={probe.chunks};"
                f"passes={probe.passes};train_rows={probe.train_rows}")

    # --- 2. artifact store round-trip ------------------------------------
    with tempfile.TemporaryDirectory() as tmp:
        store = ArtifactStore(tmp)
        t0 = time.perf_counter()
        store.put("bench", data, cfg)
        t_save = time.perf_counter() - t0
        t0 = time.perf_counter()
        loaded = store.get("bench", expect_config=cfg)   # verifying load
        t_load = time.perf_counter() - t0
    data = loaded.data
    common.emit("build_bench.store_roundtrip", (t_save + t_load) * 1e6,
                f"save_s={t_save:.2f};load_verify_s={t_load:.2f}")

    # --- 3. spill + tombstone, then rebuild + swap on a second engine ----
    engines = {}
    for name in ("laden", "rebuilt"):
        eng = AnnServeEngine(MutableJunoIndex(data, side_capacity=4096),
                             metric=cfg.metric, batch_buckets=(8, 16, 32))
        spilled = _spill_and_tombstone(eng.index, np.random.default_rng(0),
                                       n_spill, n_points)
        assert spilled >= min(n_spill, 64), f"spill failed: {spilled}"
        engines[name] = eng
    check = np.take(queries, range(32), axis=0, mode="wrap")
    r_pre = engines["rebuilt"].submit(check, k=10, mode="H2")
    engines["rebuilt"].run()
    t0 = time.perf_counter()
    engines["rebuilt"].swap_index()
    t_rebuild = time.perf_counter() - t0
    assert engines["rebuilt"].index.side_fill == 0
    r_post = engines["rebuilt"].submit(check, k=10, mode="H2")
    engines["rebuilt"].run()
    np.testing.assert_array_equal(r_pre.scores, r_post.scores)
    common.emit("build_bench.rebuild_swap", t_rebuild * 1e6,
                f"rebuild_s={t_rebuild:.2f};"
                f"side_drained={engines['laden'].index.side_fill}")

    # --- 4. side-laden vs post-rebuild serve QPS (interleaved) -----------
    trace = _make_trace(queries, n_requests)
    total_q = sum(t[0].shape[0] for t in trace)
    times = {name: [] for name in engines}
    for eng in engines.values():     # warm every signature + bucket
        for (q, k, t) in trace:
            eng.submit(q, k=k, recall_target=t)
        eng.run()
    for _ in range(3):               # interleave the timed passes
        for name, eng in engines.items():
            t0 = time.perf_counter()
            for (q, k, t) in trace:
                eng.submit(q, k=k, recall_target=t)
            eng.run()
            times[name].append(time.perf_counter() - t0)
    qps = {name: total_q / sorted(ts)[1] for name, ts in times.items()}
    speedup = qps["rebuilt"] / qps["laden"]
    common.emit("build_bench.serve_qps", 0.0,
                f"laden_qps={qps['laden']:.0f};"
                f"rebuilt_qps={qps['rebuilt']:.0f};speedup={speedup:.2f}x")
    return {"n_points": n_points, "build_pts_per_s": build_pps,
            "build_s": t_build, "store_save_s": t_save,
            "store_load_verify_s": t_load, "rebuild_s": t_rebuild,
            "laden_qps": qps["laden"], "rebuilt_qps": qps["rebuilt"],
            "speedup": speedup}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-points", type=int, default=200_000)
    ap.add_argument("--n-requests", type=int, default=96)
    ap.add_argument("--n-spill", type=int, default=256,
                    help="side-buffer entries to load before the QPS A/B")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode; implies --check (same ~200k build — the "
                         "streaming pipeline IS the thing under test)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless post-rebuild QPS >= side-laden QPS")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write throughput/rebuild/QPS numbers here")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    res = run(n_points=args.n_points, n_requests=args.n_requests,
              n_spill=args.n_spill)
    ok = res["rebuilt_qps"] >= res["laden_qps"]
    print(f"# post-rebuild {res['rebuilt_qps']:.0f} QPS vs side-laden "
          f"{res['laden_qps']:.0f} QPS -> {'OK' if ok else 'REGRESSION'}",
          file=sys.stderr)
    if args.json:
        with open(args.json, "w") as fh:
            json.dump({"smoke": args.smoke, "backend": "cpu-hostpath",
                       **res}, fh, indent=2, sort_keys=True)
            fh.write("\n")
    if (args.check or args.smoke) and not ok:
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Paper Fig. 3(a): execution-time breakdown of the three online stages
(filtering / L2-LUT construction / distance calculation) across nprobe.
Reproduces the paper's finding: LUT construction + distance calculation
dominate (90%+) and scale with nprobe; filtering is nprobe-independent."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import lut as lut_lib
from repro.core import scan as scan_lib
from repro.core import density as density_lib
from repro.core.ivf import filter_clusters
from .common import emit, get_bench_index, time_fn


def run():
    pts, queries, index, gt, cfg = get_bench_index("deep")
    m = cfg.sub_dim

    for nprobe in [4, 8, 16, 32]:
        q = queries.astype(jnp.float32)

        filt = jax.jit(lambda qq: filter_clusters(qq, index.ivf,
                                                  nprobe=nprobe))
        t_filter = time_fn(filt, q)
        base, cids = filt(q)

        def lut_stage(qq, cids):
            res = qq[:, None, :] - index.ivf.centroids[cids]
            qsub = res.reshape(qq.shape[0], nprobe, -1, m)
            tau = density_lib.predict_threshold(index.density, qsub, 1.0)
            lutv, mask = lut_lib.build_lut(qsub, index.codebook, tau)
            return lut_lib.masked_lut(lutv, mask, tau)

        lut_j = jax.jit(lut_stage)
        t_lut = time_fn(lut_j, q, cids)
        mlut = lut_j(q, cids)

        def dist_stage(mlut, cids):
            codes = index.cluster_codes[cids]
            valid = index.ivf.valid[cids]
            scan = jax.vmap(jax.vmap(scan_lib.adc_scan))
            return scan(mlut, codes, valid)

        dist_j = jax.jit(dist_stage)
        t_dist = time_fn(dist_j, mlut, cids)

        total = t_filter + t_lut + t_dist
        nq = q.shape[0]
        emit(f"fig3_breakdown_nprobe{nprobe}", total / nq * 1e6,
             f"filter%={t_filter / total * 100:.1f};"
             f"lut%={t_lut / total * 100:.1f};"
             f"dist%={t_dist / total * 100:.1f}")

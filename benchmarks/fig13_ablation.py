"""Paper Fig. 13: optimization ablations.
(a) without hit-count selection (JUNO-H only) and without kernel fusion
    (impl="ref" vs impl="pallas" — the TPU analogue of removing the
    RT-core/Tensor-core pipelining, cf. DESIGN.md §2);
(b) dynamic vs static thresholds: small-static / large-static / dynamic,
    reporting recall and the selected-entry budget (the throughput driver).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.core import recall_1_at_k, search
from repro.core import density as density_lib
from repro.core import lut as lut_lib
from repro.core.ivf import filter_clusters
from .common import emit, get_bench_index, time_fn


def run():
    pts, queries, index, gt, cfg = get_bench_index("deep")
    gt1 = gt[:, 0]
    nprobe = 16

    # (a) component ablations. NOTE: impl="pallas" on CPU runs the kernels
    # in interpret mode (Python per block) — correctness-equivalence is
    # asserted, wall time is NOT comparable and therefore not measured here;
    # the fused kernels' perf claim lives in the TPU roofline (§Perf).
    for name, kw in [
            ("full_H2", dict(mode="H2")),
            ("no_hitcount_H", dict(mode="H")),
            ("no_fusion_ref_H2", dict(mode="H2", impl="ref"))]:
        def fn():
            return search(index, queries, nprobe=nprobe, k=100, **kw)
        t = time_fn(fn, iters=3)
        _, ids = fn()
        emit(f"fig13a_{name}", t / queries.shape[0] * 1e6,
             f"R1@100={float(recall_1_at_k(ids, gt1)):.3f}")
    _, ids_p = search(index, queries, nprobe=nprobe, k=100, mode="H2",
                      impl="pallas")
    _, ids_r = search(index, queries, nprobe=nprobe, k=100, mode="H2",
                      impl="ref")
    agree = float(jnp.mean((ids_p == ids_r).astype(jnp.float32)))
    emit("fig13a_fusion_pallas_H2", 0.0,
         f"id_agreement_vs_ref={agree:.3f};timing=TPU-only(interpret on CPU)")

    # (b) threshold strategies: static uses the dynamic model's min/max
    q = queries.astype(jnp.float32)
    _, cids = filter_clusters(q, index.ivf, nprobe=nprobe)
    res = q[:, None, :] - index.ivf.centroids[cids]
    qsub = res.reshape(q.shape[0], nprobe, -1, cfg.sub_dim)
    tau_dyn = density_lib.predict_threshold(index.density, qsub, 1.0)
    lo, hi = float(index.density.tau_min), float(index.density.tau_max)

    for name, tau in [("static_small", jnp.full_like(tau_dyn, lo)),
                      ("static_large", jnp.full_like(tau_dyn, hi)),
                      ("dynamic", tau_dyn)]:
        _, mask = lut_lib.build_lut(qsub, index.codebook, tau)
        kept = float(jnp.mean(mask))      # selected-entry budget ∝ 1/QPS
        _, ids = _static_search(index, queries, nprobe, tau)
        emit(f"fig13b_{name}", 0.0,
             f"entries_kept%={kept * 100:.1f};"
             f"R1@100={float(recall_1_at_k(ids, gt1)):.3f}")


def _static_search(index, queries, nprobe, tau):
    """JUNO-H with a fixed threshold tensor (bypasses the density model)."""
    from repro.core import scan as scan_lib
    q = queries.astype(jnp.float32)
    _, cids = filter_clusters(q, index.ivf, nprobe=nprobe)
    res = q[:, None, :] - index.ivf.centroids[cids]
    qsub = res.reshape(q.shape[0], nprobe, -1, 2)
    lutv, mask = lut_lib.build_lut(qsub, index.codebook, tau)
    mlut = lut_lib.masked_lut(lutv, mask, tau)
    codes = index.cluster_codes[cids]
    valid = index.ivf.valid[cids]
    ids = index.ivf.point_ids[cids]
    scores = jax.vmap(jax.vmap(scan_lib.adc_scan))(mlut, codes, valid)
    flat_s = scores.reshape(q.shape[0], -1)
    flat_i = ids.reshape(q.shape[0], -1)
    s, sel = jax.lax.top_k(-flat_s, 100)
    return -s, jnp.take_along_axis(flat_i, sel, axis=1)

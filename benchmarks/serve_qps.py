"""QPS/latency of the online ANN serving engine under a mixed workload.

Replays a request trace (many small queries with mixed k/mode/recall-target
knobs) through ``repro.serve.ann.AnnServeEngine`` with insert batches
interleaved between waves — the online-serving shape — and compares against
the seed baseline: one single-shot ``core.search()`` call per request, no
batching, query-only. The engine must win on throughput (dynamic batching
amortizes dispatch and fills the batch dimension) while also absorbing the
inserts; ``--check``/``--smoke`` turn that into a hard gate.

A second section compares FUSED vs UNFUSED serving of the high-recall
tiers: the same H2-tier (and H+H2-tier) request trace served by an engine
with ``fused=True`` — both stages of the two-stage search in one fused
scan, H folded onto the H2 signature — against a default engine. Gated
(fused H2-tier QPS >= unfused) under ``--check``/``--smoke``; ``--json``
records the numbers (committed as BENCH_fused.json).

A third section compares RT-PREFILTER vs DENSE-SCAN serving of the H2
tier on the MIPS ("tti") workload: an engine with ``prefilter="rt"``
routes each request's probe budget through the sphere-intersection
filter's survivor ranks (``repro.rt``), so geometrically prunable
queries run at nprobe 4/8 instead of 16 — smaller jitted scans, not just
masked lanes. Gated (rt H2-tier QPS >= dense-scan) under
``--check``/``--smoke``; ``--json-rt`` records the numbers (committed as
BENCH_rt.json) including both engines' recall@10 — rt pruning also
IMPROVES ip-workload H2 recall by keeping junk clusters out of stage 1.

A fourth section drives an ``AnnServeFleet`` (2 replicas × 2 shards on 8
emulated host devices) with OPEN-LOOP mixed query+insert traffic —
steady Poisson and bursty arrival profiles at ~4× the fleet's measured
closed-loop capacity — and gates TAIL LATENCY: with bounded admission
queues (``policy="shed"``) the p99 over served requests must not exceed
the unbounded-queue fleet's p99 under the identical trace, and shedding
must actually fire. Open-loop latency counts schedule slip (measured
from the intended arrival time), so an unbounded queue honestly shows
the backlog blow-up that bounded admission exists to cap. ``--json-
fleet`` records the numbers (committed as BENCH_fleet.json); see
docs/fleet.md for the methodology.

A fifth section exercises the PAGED (out-of-core) tier: the bench index
is committed to a throwaway ``ArtifactStore`` generation and served back
through ``repro.serve.paged.PagedAnnServeEngine`` — memory-mapped PQ code
shards behind an LRU hot-cluster cache sized to 1/4 of the shard bytes,
so the dataset is structurally >= 4x the cache and eviction pressure is
real. Gates, under ``--check``/``--smoke``: the paged engine returns
bit-identical ids to a resident engine over the full mixed-tier trace,
the cache actually evicts, and paged QPS stays above a floor (>= 0.25x
resident — paging trades throughput for footprint, bounded). ``--json-
paged`` records the numbers (committed as BENCH_paged.json), including
the exact-rerank tier's recall@10 uplift from the raw-vector file.

A sixth section soaks the LSM FRESHNESS tiers: a tier-enabled engine
(``max_minors > 0``) serves a fixed-signature query stream while insert
batches aimed at a full cluster spill into the exact-scored L0 delta,
promote into PQ-encoded minor generations, and fold incrementally back
into the base — >= 8 full merge cycles driven entirely by the
between-ticks ``MergeScheduler``, no stop-the-world rebuild. Gates,
under ``--check``/``--smoke``: every cycle completes (the minor
generation counter advances per cycle), per-cycle p99 stays <= 2x the
steady-state p99 (merge work must amortize, not stall the serving
path), and the end-state search matches a from-scratch
``rebuild_index`` bit-identically (scores equal; ids equal up to
exact-tie permutation). ``--json-freshness`` records the numbers
(committed as BENCH_freshness.json).

A seventh section gates the OBSERVABILITY layer (``repro.obs``): the
H2-tier trace is replayed through a plain engine and an instrumented one
(metrics registry + span tracer + sampled online-recall probe), and
under ``--check``/``--smoke`` the instrumented engine must return
bit-identical ids AND scores, hold >= 0.95x the plain engine's QPS, and
report an online recall@10 gauge within 0.05 of the offline ground-truth
recall. A fleet, a paged engine, a merge-tier engine and an
``ArtifactStore`` run alongside so the merged registry covers every
``juno_<subsystem>_*`` metric family; the merged dump must pass
``repro.obs.validate_events``. ``--emit-metrics PATH`` writes the JSONL
event dump plus a Prometheus-text sibling snapshot; ``--json-obs``
records the numbers (committed as BENCH_obs.json).

    PYTHONPATH=src python benchmarks/serve_qps.py [--smoke] [--json PATH]
        [--json-rt PATH] [--json-fleet PATH] [--json-paged PATH]
        [--json-freshness PATH] [--json-obs PATH] [--emit-metrics PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
import tempfile
import time

import numpy as np

# 8 emulated host devices so the 2 replicas x 2 shards fleet topology is
# real (must be set before anything imports jax; run.py never imports this
# module, so the flag stays scoped to serve_qps runs)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

from benchmarks import common  # noqa: E402
from repro.build.rebuild import rebuild_index  # noqa: E402
from repro.build.store import ArtifactStore  # noqa: E402
from repro.core import search  # noqa: E402
from repro.obs import (  # noqa: E402
    MetricsRegistry, Observability, RecallProbe, to_events, validate_events,
    write_jsonl)
from repro.serve.ann import AnnServeEngine  # noqa: E402
from repro.serve.fleet import AnnServeFleet  # noqa: E402
from repro.serve.paged import PagedAnnServeEngine, PagedIndexData  # noqa: E402

# request trace knobs: (n_queries, k, mode, recall_target) cycled over
REQUEST_MIX = [
    (4, 10, "auto", 0.95),   # → H
    (2, 10, "auto", 0.85),   # → H2
    (8, 10, "auto", 0.55),   # → M
    (16, 10, "auto", 0.30),  # → L
    (1, 10, "H", 0.0),
    (4, 10, "H", 0.0),
]


def _make_trace(queries: np.ndarray, n_requests: int):
    trace, pos = [], 0
    for r in range(n_requests):
        nq, k, mode, target = REQUEST_MIX[r % len(REQUEST_MIX)]
        rows = np.take(queries, range(pos, pos + nq), axis=0, mode="wrap")
        trace.append((rows, k, mode, target))
        pos += nq
    return trace


def run(dataset: str = "deep", n_requests: int = 96, insert_every: int = 12,
        insert_batch: int = 8) -> dict:
    pts, queries, index, gt, cfg = common.get_bench_index(dataset)
    queries = np.asarray(queries)
    trace = _make_trace(queries, n_requests)
    rng = np.random.default_rng(0)
    d = queries.shape[1]
    new_points = (np.asarray(pts)[:insert_batch].mean(0)[None] +
                  rng.standard_normal(
                      (n_requests // insert_every * insert_batch, d))
                  ).astype(np.float32)

    # CPU-sized buckets: on this backend per-query cost grows with batch, so
    # right-sizing beats maximal batching (on TPU the default (8,32,128)
    # buckets fill the batch dim instead)
    engine = AnnServeEngine(index, metric=cfg.metric, side_capacity=512,
                            batch_buckets=(8, 16, 32))

    # resolve each request exactly as the engine will, so the baseline runs
    # the same kernels with the same knobs — minus batching and mutability
    resolved = [engine.route(engine.submit(q, k=k, mode=m, recall_target=t))
                for q, k, m, t in trace]
    engine.queue.clear()
    engine.completed.clear()

    # --- warm every jit signature both paths will hit (compile time out):
    # one full untimed replay for the engine (all batch buckets + the
    # side≠None trace), one pass over the request mix for the baselines
    for q, (k, mode, nprobe) in zip([t[0] for t in trace[:len(REQUEST_MIX)]],
                                    resolved[:len(REQUEST_MIX)]):
        search(index, q, nprobe=nprobe, k=k, mode=mode, metric=cfg.metric)
        search(index, q, nprobe=nprobe, k=k, mode=mode, metric=cfg.metric,
               batch=q.shape[0])
    for start in range(0, n_requests, insert_every):
        for (q, k, m, t) in trace[start:start + insert_every]:
            engine.submit(q, k=k, mode=m, recall_target=t)
        engine.run()
    engine.insert(new_points[:insert_batch])
    # FORCE a spill, then replay one full wave: the engine elides an empty
    # side buffer, so side≠None programs are distinct traces — if the first
    # spill happened mid-measurement, every active signature would recompile
    # inside the timed region and could flip the --smoke gate spuriously
    mid = engine.index
    n_clusters = mid.data.ivf.point_ids.shape[0]
    c = int(np.argmin([mid.free_slots(cc) for cc in range(n_clusters)]))
    cent = np.asarray(mid.data.ivf.centroids[c])
    spillers = (cent[None] + 0.01 * rng.standard_normal(
        (mid.free_slots(c) + 1, d))).astype(np.float32)
    engine.insert(spillers)
    assert mid.side_fill >= 1, "warmup spill failed"
    for (q, k, m, t) in trace[:insert_every]:
        engine.submit(q, k=k, mode=m, recall_target=t)
    engine.run()
    engine.completed.clear()
    n_warm_q = engine.stats["queries"]
    total_q = sum(t[0].shape[0] for t in trace)

    # --- baseline A (the acceptance comparator): seed single-shot search()
    # exactly as a seed-repo client would call it per request — default
    # batch (64) padding and all
    t0 = time.perf_counter()
    for (q, _, _, _), (k, mode, nprobe) in zip(trace, resolved):
        search(index, q, nprobe=nprobe, k=k, mode=mode, metric=cfg.metric)
    t_base = time.perf_counter() - t0
    base_qps = total_q / t_base

    # --- baseline B (informational): single-shot with exact-size batches --
    t0 = time.perf_counter()
    for (q, _, _, _), (k, mode, nprobe) in zip(trace, resolved):
        search(index, q, nprobe=nprobe, k=k, mode=mode, metric=cfg.metric,
               batch=q.shape[0])
    exact_qps = total_q / (time.perf_counter() - t0)

    # --- engine: dynamic batching + interleaved inserts -------------------
    t0 = time.perf_counter()
    ins_pos = insert_batch  # first batch consumed by warmup
    for start in range(0, n_requests, insert_every):
        for (q, k, m, t) in trace[start:start + insert_every]:
            engine.submit(q, k=k, mode=m, recall_target=t)
        engine.run()
        if ins_pos < len(new_points):
            engine.insert(new_points[ins_pos:ins_pos + insert_batch])
            ins_pos += insert_batch
    t_eng = time.perf_counter() - t0
    eng_qps = total_q / t_eng
    lat = engine.latency_stats()

    common.emit("serve_qps.baseline_single_shot", t_base / n_requests * 1e6,
                f"qps={base_qps:.0f}")
    common.emit("serve_qps.baseline_exact_batch", 0.0,
                f"qps={exact_qps:.0f}")
    common.emit("serve_qps.engine_mixed", t_eng / n_requests * 1e6,
                f"qps={eng_qps:.0f};speedup={eng_qps / base_qps:.2f}x;"
                f"p50_ms={lat['p50'] * 1e3:.1f};p95_ms={lat['p95'] * 1e3:.1f};"
                f"inserted={engine.stats['inserts']};"
                f"side_fill={engine.index.side_fill}")
    common.emit("serve_qps.batching",
                engine.stats["queries"] - n_warm_q,
                f"ticks={engine.stats['ticks']};"
                f"signatures={len(engine.stats['signatures'])};"
                f"padded_rows={engine.stats['padded_rows']}")
    fused = run_fused_tiers(index, queries, cfg)
    return {"base_qps": base_qps, "eng_qps": eng_qps, "lat": lat,
            "fused": fused}


# high-recall request mix: (n_queries, k, recall_target); >= 0.9 routes to
# the H tier, [0.8, 0.9) to H2 — exactly the tiers a fused engine serves
# through the fused two-stage kernel path
HIGH_RECALL_MIX = [(4, 10, 0.95), (2, 10, 0.85), (1, 10, 0.92),
                   (4, 10, 0.85), (8, 10, 0.88), (2, 10, 0.97)]


def run_fused_tiers(index, queries: np.ndarray, cfg,
                    n_requests: int = 48) -> dict:
    """Fused vs unfused serving of the high-recall tiers (query-only).

    Two traces: the H2 tier alone (the acceptance gate: fused must be at
    least as fast), and the combined H+H2 tier (where the fused engine
    additionally coalesces both tiers onto one jit signature)."""
    out = {}
    for tag, lo, hi in [("h2_tier", 0.8, 0.9), ("h_h2_tier", 0.8, 1.1)]:
        mix = [m for m in HIGH_RECALL_MIX if lo <= m[2] < hi]
        trace, pos = [], 0
        for r in range(n_requests):
            nq, k, target = mix[r % len(mix)]
            rows = np.take(queries, range(pos, pos + nq), axis=0, mode="wrap")
            trace.append((rows, k, target))
            pos += nq
        total_q = sum(t[0].shape[0] for t in trace)

        qps = {}
        for name, fused in [("unfused", False), ("fused", True)]:
            eng = AnnServeEngine(index, metric=cfg.metric, fused=fused,
                                 batch_buckets=(8, 16, 32))
            for _ in range(2):  # warm every signature+bucket, then time
                for (q, k, t) in trace:
                    eng.submit(q, k=k, recall_target=t)
                eng.run()
            t0 = time.perf_counter()
            for (q, k, t) in trace:
                eng.submit(q, k=k, recall_target=t)
            eng.run()
            dt = time.perf_counter() - t0
            qps[name] = total_q / dt
        speedup = qps["fused"] / qps["unfused"]
        common.emit(f"serve_qps.{tag}", 0.0,
                    f"fused_qps={qps['fused']:.0f};"
                    f"unfused_qps={qps['unfused']:.0f};"
                    f"speedup={speedup:.2f}x")
        out[tag] = {"fused_qps": qps["fused"], "unfused_qps": qps["unfused"],
                    "speedup": speedup}
    return out


# rt-prefilter request mix: H2-tier recall targets, SINGLE-query requests —
# the router shrinks a request to the max survivor rank over its queries,
# so the online-serving shape (point lookups) is where the shrink fires;
# the dynamic batcher still coalesces same-signature requests into buckets
RT_MIX = [(1, 10, 0.85), (1, 10, 0.88), (1, 10, 0.82), (1, 10, 0.85)]


def run_rt_prefilter(n_requests: int = 96) -> dict:
    """RT-prefilter vs dense-scan serving of the H2 tier (query-only).

    Runs on the "tti" (MIPS) index — the workload whose ray-plane
    geometry the sphere test prunes well (DEEP-like l2 clusters overlap
    in the projection, so there the router rarely shrinks; that neutral
    result is the documented trade-off, docs/benchmarks.md). Timing is
    the median of 3 replay passes per engine; recall@10 of both engines
    is recorded alongside (rt must not trade recall for its throughput
    — on this workload it gains both).
    """
    pts, queries, index, gt, cfg = common.get_bench_index("tti")
    queries = np.asarray(queries)
    gt10 = np.asarray(gt)[:, :10]
    trace, pos = [], 0
    for r in range(n_requests):
        nq, k, target = RT_MIX[r % len(RT_MIX)]
        rows = np.take(queries, range(pos, pos + nq), axis=0, mode="wrap")
        trace.append((rows, k, target))
        pos += nq
    total_q = sum(t[0].shape[0] for t in trace)

    engines, times = {}, {}
    for name, kw in [("scan", {}), ("rt", dict(prefilter="rt"))]:
        eng = AnnServeEngine(index, metric=cfg.metric,
                             batch_buckets=(8, 16, 32), **kw)
        for _ in range(2):   # warm every signature+bucket the trace hits
            for (q, k, t) in trace:
                eng.submit(q, k=k, recall_target=t)
            eng.run()
        engines[name], times[name] = eng, []
    # interleave the timed passes: this box's load drifts on the second
    # scale, so back-to-back blocks would hand one engine a quiet machine
    for _ in range(3):
        for name, eng in engines.items():
            t0 = time.perf_counter()
            for (q, k, t) in trace:
                eng.submit(q, k=k, recall_target=t)
            eng.run()
            times[name].append(time.perf_counter() - t0)
    out = {}
    for name, eng in engines.items():
        qps = total_q / sorted(times[name])[1]
        req = eng.submit(queries, k=10, mode="H2")
        eng.run()
        hits = (req.ids[:, :, None] == gt10[:, None, :]).any(-1)
        shrunk = sum(n for (sk, sm, sn, sb), n
                     in eng.stats["signatures"].items() if sn < 16)
        out[name] = {"qps": qps, "recall10": float(hits.mean()),
                     "shrunk_calls": int(shrunk)}
    speedup = out["rt"]["qps"] / out["scan"]["qps"]
    common.emit("serve_qps.rt_h2_tier", 0.0,
                f"rt_qps={out['rt']['qps']:.0f};"
                f"scan_qps={out['scan']['qps']:.0f};"
                f"speedup={speedup:.2f}x;"
                f"rt_recall10={out['rt']['recall10']:.3f};"
                f"scan_recall10={out['scan']['recall10']:.3f};"
                f"shrunk_calls={out['rt']['shrunk_calls']}")
    return {"dataset": "tti", "speedup": speedup, **out}


def run_fused3(n_requests: int = 96) -> dict:
    """Single-residency three-stage serving vs its composition baselines.

    Replays the rt-prefilter H2 trace (same "tti" workload — the geometry
    the sphere test prunes) through four engines:

    * ``fused3``   — ``fused=True, prefilter="rt"``: the three-stage
      RT→hit-count→ADC kernel path, probe-budget shrinking intact.
    * ``composed`` — same engine with ``fused3=False``: the rt mask
      applied OUTSIDE the fused scan (the exact path fused3 replaces).
    * ``fused``    — fused two-stage only, dense probe scan.
    * ``rt``       — rt prefilter only, composed (unfused) two-stage.

    Gates: (1) the three-stage engine's ids AND scores are bit-equal to
    the composed engine's on the full query batch — folding the sphere
    walk into the kernel is a scheduling change, never a semantics
    change; (2) three-stage H2 QPS >= max(fused-only, rt-only) — the
    single residency must compound both prior speedups, not trade one
    for the other. Timing is the median of 3 interleaved replay passes.
    """
    pts, queries, index, gt, cfg = common.get_bench_index("tti")
    queries = np.asarray(queries)
    gt10 = np.asarray(gt)[:, :10]
    trace, pos = [], 0
    for r in range(n_requests):
        nq, k, target = RT_MIX[r % len(RT_MIX)]
        rows = np.take(queries, range(pos, pos + nq), axis=0, mode="wrap")
        trace.append((rows, k, target))
        pos += nq
    total_q = sum(t[0].shape[0] for t in trace)

    variants = [
        ("fused3", dict(fused=True, prefilter="rt")),
        ("composed", dict(fused=True, prefilter="rt", fused3=False)),
        ("fused", dict(fused=True)),
        ("rt", dict(prefilter="rt")),
    ]
    engines, times = {}, {}
    for name, kw in variants:
        eng = AnnServeEngine(index, metric=cfg.metric,
                             batch_buckets=(8, 16, 32), **kw)
        for _ in range(2):   # warm every signature+bucket the trace hits
            for (q, k, t) in trace:
                eng.submit(q, k=k, recall_target=t)
            eng.run()
        engines[name], times[name] = eng, []
    # interleaved timed passes (box-load drift; see run_rt_prefilter)
    for _ in range(3):
        for name, eng in engines.items():
            t0 = time.perf_counter()
            for (q, k, t) in trace:
                eng.submit(q, k=k, recall_target=t)
            eng.run()
            times[name].append(time.perf_counter() - t0)

    out = {}
    reqs = {}
    for name, eng in engines.items():
        qps = total_q / sorted(times[name])[1]
        req = eng.submit(queries, k=10, mode="H2")
        eng.run()
        reqs[name] = req
        hits = (req.ids[:, :, None] == gt10[:, None, :]).any(-1)
        out[name] = {"qps": qps, "recall10": float(hits.mean())}
    ids_equal = bool(np.array_equal(reqs["fused3"].ids,
                                    reqs["composed"].ids))
    scores_equal = bool(np.array_equal(reqs["fused3"].scores,
                                       reqs["composed"].scores))
    baseline = max(out["fused"]["qps"], out["rt"]["qps"])
    qps_ok = out["fused3"]["qps"] >= baseline
    gate_ok = ids_equal and scores_equal and qps_ok
    common.emit("serve_qps.fused3_h2_tier", 0.0,
                f"fused3_qps={out['fused3']['qps']:.0f};"
                f"composed_qps={out['composed']['qps']:.0f};"
                f"fused_qps={out['fused']['qps']:.0f};"
                f"rt_qps={out['rt']['qps']:.0f};"
                f"speedup_vs_best={out['fused3']['qps'] / baseline:.2f}x;"
                f"ids_equal={ids_equal};scores_equal={scores_equal};"
                f"gate={'OK' if gate_ok else 'FAIL'}")
    return {"dataset": "tti", "ids_equal": ids_equal,
            "scores_equal": scores_equal,
            "speedup_vs_best": out["fused3"]["qps"] / baseline,
            "gate_ok": gate_ok, **out}


def run_paged(n_requests: int = 96, exact_rerank: int = 40) -> dict:
    """Paged (out-of-core) vs resident serving of the mixed-tier trace.

    Commits the bench index to a throwaway ``ArtifactStore`` generation,
    reopens it memory-mapped with a hot-cluster cache of 1/4 the PQ
    shard bytes (dataset >= 4x cache by construction), and replays the
    same mixed-mode trace through a ``PagedAnnServeEngine`` and a
    resident ``AnnServeEngine``. The paged engine must return the
    resident engine's ids bit-for-bit (the scoring tail is shared code,
    so this is an equality — not a tolerance — gate), must actually
    evict (otherwise the 4x pressure claim is vacuous), and must hold
    >= 0.25x resident QPS. Timing is the median of 3 interleaved
    passes. The exact-rerank tier's recall@10 against the raw-vector
    file is recorded alongside (informational — it trades extra reads
    for exact final ordering).
    """
    pts, queries, index, gt, cfg = common.get_bench_index("deep")
    queries = np.asarray(queries)
    gt10 = np.asarray(gt)[:, :10]
    trace = _make_trace(queries, n_requests)
    total_q = sum(t[0].shape[0] for t in trace)

    tmp = tempfile.mkdtemp(prefix="bench_paged_")
    try:
        store = ArtifactStore(tmp)
        version = store.put("bench", index, cfg)
        vec_path = os.path.join(tmp, "vectors.npy")
        np.save(vec_path, np.asarray(pts, np.float32))
        cluster_bytes = int(np.asarray(index.cluster_codes).nbytes)
        cache_bytes = max(1, cluster_bytes // 4)      # dataset >= 4x cache
        paged = PagedIndexData(store.path("bench", version),
                               cache_bytes=cache_bytes, expect_config=cfg,
                               vectors=vec_path)

        engines = {
            "resident": AnnServeEngine(index, metric=cfg.metric,
                                       batch_buckets=(8, 16, 32)),
            "paged": PagedAnnServeEngine(paged, metric=cfg.metric,
                                         batch_buckets=(8, 16, 32)),
        }
        # warm every signature+bucket AND check id parity request-by-request
        reqs = {}
        for name, eng in engines.items():
            for _ in range(2):
                for (q, k, m, t) in trace:
                    eng.submit(q, k=k, mode=m, recall_target=t)
                eng.run()
            reqs[name] = [eng.submit(q, k=k, mode=m, recall_target=t)
                          for (q, k, m, t) in trace]
            eng.run()
        ids_equal = all(np.array_equal(rp.ids, rr.ids) for rp, rr
                        in zip(reqs["paged"], reqs["resident"]))

        times = {name: [] for name in engines}
        # interleave the timed passes (same rationale as run_rt_prefilter)
        for _ in range(3):
            for name, eng in engines.items():
                t0 = time.perf_counter()
                for (q, k, m, t) in trace:
                    eng.submit(q, k=k, mode=m, recall_target=t)
                eng.run()
                times[name].append(time.perf_counter() - t0)
        qps = {name: total_q / sorted(ts)[1] for name, ts in times.items()}
        ratio = qps["paged"] / qps["resident"]
        cache = engines["paged"].cache_stats()

        # exact-rerank tier: same paged generation, final top-C re-scored
        # against the memory-mapped raw vectors (recall uplift on record)
        recall = {}
        for name, eng in [
                ("paged", engines["paged"]),
                ("rerank", PagedAnnServeEngine(paged, metric=cfg.metric,
                                               exact_rerank=exact_rerank,
                                               batch_buckets=(8, 16, 32)))]:
            req = eng.submit(queries, k=10, mode="H2")
            eng.run()
            hits = (req.ids[:, :, None] == gt10[:, None, :]).any(-1)
            recall[name] = float(hits.mean())

        gate_ok = (ids_equal and cache["evictions"] > 0 and ratio >= 0.25)
        common.emit("serve_qps.paged_tier", 0.0,
                    f"paged_qps={qps['paged']:.0f};"
                    f"resident_qps={qps['resident']:.0f};ratio={ratio:.2f}x;"
                    f"ids_equal={ids_equal};evictions={cache['evictions']};"
                    f"hit_rate={cache['hits'] / max(1, cache['hits'] + cache['misses']):.2f};"
                    f"recall10={recall['paged']:.3f};"
                    f"rerank_recall10={recall['rerank']:.3f};"
                    f"gate={'OK' if gate_ok else 'FAIL'}")
        return {"paged_qps": qps["paged"], "resident_qps": qps["resident"],
                "qps_ratio": ratio, "qps_floor": 0.25,
                "ids_equal": ids_equal, "cluster_bytes": cluster_bytes,
                "cache_bytes": cache_bytes,
                "dataset_over_cache": cluster_bytes / cache_bytes,
                "cache": cache, "exact_rerank": exact_rerank,
                "recall10": recall, "gate_ok": gate_ok}
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


# freshness soak: request sizes of one query wave, all on ONE jit
# signature (k=10, mode "M", nprobe 8) so per-cycle p99 measures merge
# interference — not mode mix
FRESH_WAVE = (1, 2, 4, 1)


def run_freshness(n_cycles: int = 8, waves_per_cycle: int = 8) -> dict:
    """Sustained mixed insert+query load across >= ``n_cycles`` merge cycles.

    One cycle: fill the L0 delta with inserts aimed at a structurally
    full cluster (every point spills), let the between-ticks scheduler
    promote the full L0 into a PQ-encoded minor generation, retire an
    equal batch of that cluster's base points, and let subsequent ticks
    fold the minor back into the freed slots — the index returns to
    quiescence with the same occupancy, ready for the next cycle. The
    query stream never stops; per-cycle latency is measured over it.

    Gates (all recorded in the returned dict): the minor-generation
    counter advances at least once per cycle (merges really ran), each
    cycle's p99 <= 2x the steady-state p99 (same jitted program, delta
    tier warm but quiescent), and the end state searches bit-identically
    to ``rebuild_index`` (scores equal; ids equal up to permutation
    within exactly-tied scores).
    """
    pts, queries, index, gt, cfg = common.get_bench_index("deep")
    queries = np.asarray(queries)
    rng = np.random.default_rng(3)
    d = queries.shape[1]

    eng = AnnServeEngine(index, metric=cfg.metric, batch_buckets=(8,),
                         side_capacity=16, max_minors=3,
                         merge_clusters_per_step=8)
    mid = eng.index
    n_clusters = mid.data.ivf.point_ids.shape[0]
    c = int(np.argmin([mid.free_slots(cc) for cc in range(n_clusters)]))
    cent = np.asarray(mid.data.ivf.centroids[c])

    def near_c(n: int) -> np.ndarray:
        return (cent[None] + 0.01 * rng.standard_normal(
            (n, d))).astype(np.float32)

    def wave() -> None:
        pos = rng.integers(0, queries.shape[0])
        for nq in FRESH_WAVE:
            rows = np.take(queries, range(pos, pos + nq), axis=0,
                           mode="wrap")
            eng.submit(rows, k=10, mode="M", nprobe=8)
            pos += nq
        eng.run()

    # fill the target cluster so every cycle's inserts spill into L0
    if mid.free_slots(c):
        eng.insert(near_c(mid.free_slots(c)))
    assert mid.free_slots(c) == 0, "freshness target cluster not full"
    # retirement pool: base-resident ids of the target cluster; cycles
    # retire from the head and append their own (folded) inserts
    row_ids = np.asarray(mid.data.ivf.point_ids[c])
    row_valid = np.asarray(mid.data.ivf.valid[c])
    pool = [int(p) for p in row_ids[row_valid]]

    def retire(n: int) -> list[int]:
        victims, keep = [], []
        while pool and len(victims) < n:
            pid = pool.pop(0)
            # delta-resident ids free no base slots yet; recycle them
            (victims if mid._loc.get(pid, (-9, 0))[0] >= 0
             else keep).append(pid)
        pool.extend(keep)
        assert len(victims) == n, "retirement pool exhausted"
        return victims

    # --- warm every program + merge path untimed: one full cycle ---------
    wave()                                   # empty-delta program
    warm_ids = eng.insert(near_c(mid.side.capacity))   # L0 fills
    for _ in range(2):
        wave()                               # ticks promote L0 -> minor
    eng.delete(retire(len(warm_ids)))        # open fold targets
    for _ in range(4):
        wave()                               # ticks fold minor -> base
    pool.extend(warm_ids)
    assert mid._minor_gen >= 1, "warmup never promoted"

    # --- steady state: quiescent delta (2 pinned L0 points keep the same
    # combined-view program hot without crossing the promote threshold) --
    pool.extend(eng.insert(near_c(2)))
    eng.completed.clear()
    for _ in range(2 * waves_per_cycle):
        wave()
    steady = eng.latency_stats()
    steady_p99 = steady["p99"]

    # --- the soak: n_cycles full spill -> promote -> fold cycles ---------
    gen0, folded0 = mid._minor_gen, eng.scheduler.stats["folded"]
    cycles = []
    for _ in range(n_cycles):
        eng.completed.clear()
        need = mid.side.capacity - mid.side_fill
        new_ids = eng.insert(near_c(need))
        half = waves_per_cycle // 2
        for _ in range(half):
            wave()                           # promotion fires between ticks
        # retire exactly one full L0 of base points: the promoted minor is
        # always full, so every fold is the single jit-warmed full-capacity
        # scatter shape and the cluster's occupancy is cycle-invariant
        eng.delete(retire(int(mid.side.capacity)))
        for _ in range(waves_per_cycle - half):
            wave()                           # folds drain between ticks
        lat = eng.latency_stats()
        cycles.append({"p99": lat["p99"], "p50": lat["p50"],
                       "minor_gen": mid._minor_gen,
                       "delta_fill": mid.delta_fill})
        pool.extend(new_ids)

    cycles_promoted = mid._minor_gen - gen0
    merges_ok = cycles_promoted >= n_cycles
    tail_ratio = max(cy["p99"] for cy in cycles) / steady_p99
    tail_ok = tail_ratio <= 2.0

    # --- end-state parity vs a from-scratch stop-the-world rebuild -------
    qq = np.concatenate([queries[:16], near_c(4)], axis=0)
    s0, i0 = mid.search(qq, nprobe=min(16, n_clusters), k=10, mode="H")
    rebuilt = rebuild_index(mid)
    s1, i1 = search(rebuilt, qq, nprobe=min(16, n_clusters), k=10,
                    mode="H", metric=cfg.metric, batch=qq.shape[0])
    s0, i0, s1, i1 = (np.asarray(x) for x in (s0, i0, s1, i1))
    scores_equal = np.array_equal(s0, s1)
    ids_strict = np.array_equal(i0, i1)
    ties_ok = scores_equal
    if scores_equal and not ids_strict:
        # lax.top_k may permute EXACTLY tied scores; ids must still agree
        # as sets within every non-boundary score level
        for r in range(s0.shape[0]):
            boundary = s0[r, -1]
            for v in np.unique(s0[r][s0[r] != boundary]):
                if set(i0[r][s0[r] == v]) != set(i1[r][s1[r] == v]):
                    ties_ok = False
    parity_ok = scores_equal and ties_ok

    gate_ok = merges_ok and tail_ok and parity_ok
    common.emit("serve_qps.freshness_soak", 0.0,
                f"cycles={cycles_promoted}/{n_cycles};"
                f"steady_p99_ms={steady_p99 * 1e3:.1f};"
                f"worst_cycle_p99_ms={max(cy['p99'] for cy in cycles) * 1e3:.1f};"
                f"tail_ratio={tail_ratio:.2f};"
                f"folded={eng.scheduler.stats['folded'] - folded0};"
                f"parity={'bit' if ids_strict else 'tie' if parity_ok else 'FAIL'};"
                f"gate={'OK' if gate_ok else 'FAIL'}")
    return {"n_cycles": n_cycles, "cycles_promoted": cycles_promoted,
            "waves_per_cycle": waves_per_cycle,
            "side_capacity": int(mid.side.capacity),
            "steady_p99_ms": steady_p99 * 1e3,
            "tail_ratio": tail_ratio, "tail_bound": 2.0,
            "cycles": [{"p99_ms": cy["p99"] * 1e3, "p50_ms": cy["p50"] * 1e3,
                        "minor_gen": cy["minor_gen"],
                        "delta_fill": cy["delta_fill"]} for cy in cycles],
            "scheduler": dict(eng.scheduler.stats),
            "scores_equal": scores_equal, "ids_strict": ids_strict,
            "parity_ok": parity_ok, "merges_ok": merges_ok,
            "tail_ok": tail_ok, "gate_ok": gate_ok}


def run_obs(n_requests: int = 63, emit: str | None = None) -> dict:
    """Instrumented vs plain serving of the H2 tier, plus metric coverage.

    The cost side: the H2-tier trace replayed through a plain engine and
    one carrying a full observability bundle (registry + tracer + recall
    probe sampling every 8th H2 request). Instrumentation is host-side
    bookkeeping only, so the gates are strict: ids AND scores bit-equal,
    instrumented QPS >= 0.95x plain (best of 9 interleaved passes), and
    the online recall@10 gauge within 0.05 of the offline recall
    against the committed ground truth.

    The coverage side: a 2-replica fleet (``obs=True``), a paged engine
    over a throwaway ``ArtifactStore`` generation, and a merge-tier
    engine driven through an L0 spill all run briefly so the merged
    registry contains every ``juno_<subsystem>_*`` family; the combined
    event dump must validate clean. ``emit`` writes the JSONL dump and a
    ``.txt`` Prometheus-text snapshot next to it.
    """
    pts, queries, index, gt, cfg = common.get_bench_index("deep")
    pts = np.asarray(pts, np.float32)
    queries = np.asarray(queries)
    gt10 = np.asarray(gt)[:, :10]
    mix = [m for m in HIGH_RECALL_MIX if 0.8 <= m[2] < 0.9]
    trace, pos = [], 0
    for r in range(n_requests):
        nq, k, target = mix[r % len(mix)]
        rows = np.take(queries, range(pos, pos + nq), axis=0, mode="wrap")
        trace.append((rows, k, target))
        pos += nq
    total_q = sum(t[0].shape[0] for t in trace)

    # the default n_requests is chosen coprime to the probe cadence: the
    # deterministic round-robin sampler then rotates through DIFFERENT
    # requests on every replay pass instead of aliasing onto the same
    # few (which would bias the online estimate by whatever those
    # particular queries happen to score)
    probe = RecallProbe(pts, k=10, every=8, metric=cfg.metric)
    obs = Observability(recall=probe)
    engines = {
        "plain": AnnServeEngine(index, metric=cfg.metric,
                                batch_buckets=(8, 16, 32)),
        "obs": AnnServeEngine(index, metric=cfg.metric,
                              batch_buckets=(8, 16, 32), obs=obs),
    }
    # warm every signature+bucket, then check parity request-by-request
    reqs = {}
    for name, eng in engines.items():
        for _ in range(2):
            for (q, k, t) in trace:
                eng.submit(q, k=k, recall_target=t)
            eng.run()
        reqs[name] = [eng.submit(q, k=k, recall_target=t)
                      for (q, k, t) in trace]
        eng.run()
    ids_equal = all(np.array_equal(a.ids, b.ids)
                    for a, b in zip(reqs["obs"], reqs["plain"]))
    scores_equal = all(np.array_equal(a.scores, b.scores)
                       for a, b in zip(reqs["obs"], reqs["plain"]))

    times = {name: [] for name in engines}
    # interleaved timed passes (box-load drift; see run_rt_prefilter) —
    # 9 of them, scored BEST-of rather than median: the effect under
    # test is a few-percent overhead bound, far below this box's
    # pass-to-pass load swing, and each engine's best pass is its
    # quiet-machine cost — the number the bound is actually about
    for _ in range(9):
        for name, eng in engines.items():
            t0 = time.perf_counter()
            for (q, k, t) in trace:
                eng.submit(q, k=k, recall_target=t)
            eng.run()
            times[name].append(time.perf_counter() - t0)
    qps = {name: total_q / min(ts) for name, ts in times.items()}
    ratio = qps["obs"] / qps["plain"]

    # online (sampled gauge) vs offline (full ground truth) recall@10
    req = engines["plain"].submit(queries, k=10, mode="H2")
    engines["plain"].run()
    hits = (np.asarray(req.ids)[:, :, None] == gt10[:, None, :]).any(-1)
    offline = float(hits.mean())
    online = probe.estimate("H2")
    recall_delta = abs(online - offline)

    # --- coverage: run every instrumented subsystem at least briefly -----
    fleet = AnnServeFleet(index, n_replicas=2, shards_per_replica=1,
                          metric=cfg.metric, batch_buckets=(8,), obs=True)
    for i in range(8):
        fleet.submit(np.take(queries, range(i * 2, i * 2 + 2), axis=0,
                             mode="wrap"), k=10, mode="M", nprobe=8)
    fleet.run()

    # merge tiers: fill the fullest cluster, spill one full L0, let the
    # between-ticks scheduler promote it (juno_merge_* series)
    rng = np.random.default_rng(5)
    d = queries.shape[1]
    mobs = Observability()
    meng = AnnServeEngine(index, metric=cfg.metric, batch_buckets=(8,),
                          side_capacity=8, max_minors=2, obs=mobs)
    mid = meng.index
    n_clusters = mid.data.ivf.point_ids.shape[0]
    c = int(np.argmin([mid.free_slots(cc) for cc in range(n_clusters)]))
    cent = np.asarray(mid.data.ivf.centroids[c])
    fill = (cent[None] + 0.01 * rng.standard_normal(
        (mid.free_slots(c) + mid.side.capacity, d))).astype(np.float32)
    meng.insert(fill)
    for _ in range(4):
        meng.submit(queries[:2], k=10, mode="M", nprobe=8)
        meng.run()

    # paged serving off a throwaway store generation (juno_store_*,
    # juno_cache_*, juno_paged_* series)
    tmp = tempfile.mkdtemp(prefix="bench_obs_")
    try:
        sreg = MetricsRegistry()
        store = ArtifactStore(tmp, registry=sreg)
        version = store.put("bench", index, cfg)
        store.verify("bench", version)
        cluster_bytes = int(np.asarray(index.cluster_codes).nbytes)
        paged = PagedIndexData(store.path("bench", version),
                               cache_bytes=max(1, cluster_bytes // 4),
                               expect_config=cfg)
        pobs = Observability()
        peng = PagedAnnServeEngine(paged, metric=cfg.metric,
                                   batch_buckets=(8, 16, 32), obs=pobs)
        for (q, k, t) in trace[:8]:
            peng.submit(q, k=k, recall_target=t)
        peng.run()
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    merged = MetricsRegistry()
    for reg in (obs.registry, fleet.merged_registry(), mobs.registry,
                pobs.registry, sreg):
        merged.merge(reg)
    prefixes = ("juno_engine_", "juno_fleet_", "juno_cache_", "juno_paged_",
                "juno_merge_", "juno_store_", "juno_recall_")
    names = {name for name, _, _ in merged.metrics()}
    missing = [p for p in prefixes
               if not any(n.startswith(p) for n in names)]

    events = to_events(merged, obs.tracer,
                       extra_meta={"bench": "serve_qps.run_obs",
                                   "dataset": "deep"})
    for tracer in (fleet.obs.tracer, mobs.tracer, pobs.tracer):
        events.extend(tracer.to_events())
    problems = validate_events(events)
    if emit:
        write_jsonl(emit, events)
        snap = os.path.splitext(emit)[0] + ".txt"
        with open(snap, "w") as fh:
            fh.write(merged.render_text())

    gate_ok = (ratio >= 0.95 and ids_equal and scores_equal
               and recall_delta <= 0.05 and not missing and not problems)
    common.emit("serve_qps.obs_h2_tier", 0.0,
                f"obs_qps={qps['obs']:.0f};plain_qps={qps['plain']:.0f};"
                f"ratio={ratio:.2f}x;ids_equal={ids_equal};"
                f"scores_equal={scores_equal};"
                f"recall10_online={online:.3f};"
                f"recall10_offline={offline:.3f};"
                f"series={len(merged)};events={len(events)};"
                f"problems={len(problems)};"
                f"gate={'OK' if gate_ok else 'FAIL'}")
    return {"obs_qps": qps["obs"], "plain_qps": qps["plain"],
            "qps_ratio": ratio, "qps_floor": 0.95,
            "ids_equal": ids_equal, "scores_equal": scores_equal,
            "recall10_online": online, "recall10_offline": offline,
            "recall_delta": recall_delta, "recall_bound": 0.05,
            "series": len(merged), "n_events": len(events),
            "missing_prefixes": missing, "validate_problems": problems,
            "gate_ok": gate_ok}


# fleet traffic: (n_queries,) request sizes cycled over, all on ONE jit
# signature (k=10, mode "M", nprobe 8) so the tail measures queueing and
# batching — not compile blips or mode mix — under overload
FLEET_MIX = (1, 2, 4, 1)
FLEET_INSERT_EVERY = 24     # an insert batch every this many events
FLEET_INSERT_ROWS = 4


def _fleet_arrivals(n_events: int, rate: float, profile: str,
                    rng: np.random.Generator) -> np.ndarray:
    """Arrival-time offsets (seconds) for an open-loop trace.

    "steady" draws i.i.d. exponential gaps (Poisson arrivals at
    ``rate``). "bursty" alternates bursts of 24 events at 4x rate with
    silences of 18/rate, which preserves the long-run rate while
    concentrating arrivals — the profile bounded admission exists for.
    """
    if profile == "steady":
        gaps = rng.exponential(1.0 / rate, n_events)
    elif profile == "bursty":
        gaps = []
        while len(gaps) < n_events:
            gaps.extend(rng.exponential(1.0 / (4 * rate),
                                        min(24, n_events - len(gaps))))
            gaps[-1] += 18.0 / rate      # inter-burst silence
        gaps = np.asarray(gaps[:n_events])
    else:
        raise ValueError(f"unknown profile {profile!r}")
    return np.cumsum(gaps)


def _fleet_events(queries: np.ndarray, new_points: np.ndarray,
                  n_events: int):
    """Mixed query+insert event payloads (arrival times added per profile)."""
    events, pos, ins = [], 0, 0
    for i in range(n_events):
        if i and i % FLEET_INSERT_EVERY == 0 and ins < len(new_points):
            events.append(("insert",
                           new_points[ins:ins + FLEET_INSERT_ROWS]))
            ins += FLEET_INSERT_ROWS
            continue
        nq = FLEET_MIX[i % len(FLEET_MIX)]
        rows = np.take(queries, range(pos, pos + nq), axis=0, mode="wrap")
        events.append(("query", rows))
        pos += nq
    return events


def _fleet_replay(fleet: AnnServeFleet, events, offsets) -> dict:
    """Open-loop replay: submit each event at its intended time, stepping
    the fleet while waiting; latency is measured from the INTENDED arrival
    (schedule slip counts against the server — no coordinated omission)."""
    fleet.reset_metrics()
    base = time.perf_counter()
    for (kind, payload), t_off in zip(events, offsets):
        target = base + t_off
        while time.perf_counter() < target:
            if fleet.pending:
                fleet.step()
            else:
                time.sleep(min(2e-4, max(0.0, target - time.perf_counter())))
        if kind == "insert":
            fleet.insert(payload)
        else:
            fleet.submit(payload, k=10, mode="M", nprobe=8, t_arrival=target)
    fleet.run()
    return fleet.latency_summary()


def _warm_fleet(fleet: AnnServeFleet, queries: np.ndarray,
                rng: np.random.Generator) -> None:
    """Warm the single fleet signature on every replica, spill included.

    Forces one side-buffer spill first so the side≠None search trace is
    the one timed throughout (the sharded path always passes the side
    buffer, but the unsharded fallback elides an empty one — a first
    spill mid-measurement would recompile inside the timed region).
    """
    eng = fleet.engines[0]
    n_clusters = eng.index.data.ivf.point_ids.shape[0]
    c = int(np.argmin([eng.index.free_slots(cc) for cc in range(n_clusters)]))
    cent = np.asarray(eng.index.data.ivf.centroids[c])
    spillers = (cent[None] + 0.01 * rng.standard_normal(
        (eng.index.free_slots(c) + 1, queries.shape[1]))).astype(np.float32)
    fleet.insert(spillers)
    assert eng.index.side_fill >= 1, "fleet warmup spill failed"
    for _ in range(2):
        for i in range(12):
            fleet.submit(np.take(queries, range(i * 4, i * 4 + 4), axis=0,
                                 mode="wrap"), k=10, mode="M", nprobe=8)
        fleet.run()


def run_fleet(n_events: int = 120) -> dict:
    """Tail latency of bounded vs unbounded admission under overload.

    Topology: 2 replicas × 2 shards when >= 4 devices are visible (the
    CI/default path — the module forces 8 emulated host devices), else
    2 unsharded replicas. Method: measure the fleet's CLOSED-LOOP
    capacity (rows/s with the trace submitted all at once), then replay
    the mixed query+insert trace open-loop at ~4× that rate — a
    structural overload no calibration error can undo — through two
    identically-warmed fleets: bounded admission (``policy="shed"``,
    per-replica queue ≈ 0.15 s of capacity) and unbounded
    (``policy="queue"``). Gate, per arrival profile: bounded p99 <=
    unbounded p99 AND bounded shed > 0. The unbounded fleet serves
    everything but its tail absorbs the whole backlog drain; the bounded
    fleet converts that tail into explicit typed rejections — the SLO
    trade this layer exists to make (docs/fleet.md).
    """
    import jax

    pts, queries, index, gt, cfg = common.get_bench_index("deep")
    queries = np.asarray(queries)
    rng = np.random.default_rng(7)
    d = queries.shape[1]
    new_points = (np.asarray(pts)[:64].mean(0)[None] + rng.standard_normal(
        (n_events // FLEET_INSERT_EVERY * FLEET_INSERT_ROWS + FLEET_INSERT_ROWS,
         d))).astype(np.float32)
    spr = 2 if jax.device_count() >= 4 else 1
    fleet_kw = dict(n_replicas=2, shards_per_replica=spr,
                    metric=cfg.metric, batch_buckets=(8,))

    events = _fleet_events(queries, new_points, n_events)
    query_rows = sum(p.shape[0] for k, p in events if k == "query")

    # closed-loop capacity: same fleet shape, trace submitted all at once
    calib = AnnServeFleet(index, **fleet_kw)
    _warm_fleet(calib, queries, rng)
    t0 = time.perf_counter()
    for kind, payload in events:
        if kind == "query":
            calib.submit(payload, k=10, mode="M", nprobe=8)
    calib.run()
    capacity = query_rows / (time.perf_counter() - t0)          # rows/s
    mean_rows = query_rows / sum(1 for k, _ in events if k == "query")
    rate = 4.0 * capacity / mean_rows                           # events/s
    # per-replica admission bound = 20 ms of fleet capacity: under 4x
    # overload the backlog reaches ~40% of the trace per replica, far past
    # this bound, so shedding fires structurally — while the bound still
    # caps a served request's queue wait at ~tens of ms
    max_queue = max(8, int(0.02 * capacity))                    # rows/replica

    fleets = {
        "bounded": AnnServeFleet(index, policy="shed", max_queue=max_queue,
                                 **fleet_kw),
        "unbounded": AnnServeFleet(index, policy="queue",
                                   max_queue=1 << 30, **fleet_kw),
    }
    for f in fleets.values():
        _warm_fleet(f, queries, rng)

    out = {"devices": jax.device_count(), "n_replicas": 2,
           "shards_per_replica": spr, "capacity_qps": capacity,
           "overload_rate_qps": rate * mean_rows, "max_queue_rows": max_queue,
           "n_events": n_events, "profiles": {}}
    for profile in ("steady", "bursty"):
        offsets = _fleet_arrivals(len(events), rate, profile,
                                  np.random.default_rng(11))
        # interleave two passes per variant and keep each variant's best-
        # p99 pass: this box's load drifts on the second scale, and the
        # structural effect under test (bounded wait vs backlog drain) is
        # 5-10x — far larger than pass-to-pass drift after interleaving
        passes = {name: [] for name in fleets}
        for _ in range(2):
            for name, f in fleets.items():
                passes[name].append(_fleet_replay(f, events, offsets))
        res = {name: min(ps, key=lambda s: s["p99"])
               for name, ps in passes.items()}
        ok = (res["bounded"]["p99"] <= res["unbounded"]["p99"]
              and res["bounded"]["shed"] > 0)
        res["gate_ok"] = ok
        out["profiles"][profile] = res
        common.emit(f"serve_qps.fleet_{profile}", 0.0,
                    f"bounded_p99_ms={res['bounded']['p99'] * 1e3:.1f};"
                    f"unbounded_p99_ms={res['unbounded']['p99'] * 1e3:.1f};"
                    f"shed={res['bounded']['shed']};"
                    f"served={res['bounded']['served']};"
                    f"gate={'OK' if ok else 'FAIL'}")
    out["gate_ok"] = all(p["gate_ok"] for p in out["profiles"].values())
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="deep",
                    choices=["deep", "sift", "tti"])
    ap.add_argument("--n-requests", type=int, default=96)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-N CI mode; implies --check")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless engine QPS >= single-shot QPS")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write fused-vs-unfused + engine QPS numbers here")
    ap.add_argument("--json-rt", default=None, metavar="PATH",
                    help="write rt-prefilter vs dense-scan numbers here")
    ap.add_argument("--json-fused3", default=None, metavar="PATH",
                    help="write three-stage vs composition-baseline "
                         "numbers here")
    ap.add_argument("--json-fleet", default=None, metavar="PATH",
                    help="write fleet tail-latency numbers here")
    ap.add_argument("--json-paged", default=None, metavar="PATH",
                    help="write paged-vs-resident serving numbers here")
    ap.add_argument("--json-freshness", default=None, metavar="PATH",
                    help="write LSM-freshness merge-cycle soak numbers here")
    ap.add_argument("--json-obs", default=None, metavar="PATH",
                    help="write instrumented-vs-plain observability "
                         "numbers here")
    ap.add_argument("--emit-metrics", default=None, metavar="PATH",
                    help="write the merged juno.obs.v1 JSONL event dump "
                         "here (+ a .txt Prometheus-text snapshot)")
    args = ap.parse_args()
    if args.smoke:
        common.set_smoke_sizes()
    print("name,us_per_call,derived")
    res = run(dataset=args.dataset, n_requests=args.n_requests)
    ok = res["eng_qps"] >= res["base_qps"]
    print(f"# engine {res['eng_qps']:.0f} QPS vs single-shot "
          f"{res['base_qps']:.0f} QPS -> {'OK' if ok else 'REGRESSION'}",
          file=sys.stderr)
    f = res["fused"]["h2_tier"]
    fused_ok = f["fused_qps"] >= f["unfused_qps"]
    print(f"# H2 tier fused {f['fused_qps']:.0f} QPS vs unfused "
          f"{f['unfused_qps']:.0f} QPS -> "
          f"{'OK' if fused_ok else 'REGRESSION'}", file=sys.stderr)
    rt_res = run_rt_prefilter()
    rt_ok = rt_res["rt"]["qps"] >= rt_res["scan"]["qps"]
    print(f"# H2 tier rt-prefilter {rt_res['rt']['qps']:.0f} QPS vs "
          f"dense-scan {rt_res['scan']['qps']:.0f} QPS -> "
          f"{'OK' if rt_ok else 'REGRESSION'}", file=sys.stderr)
    fused3_res = run_fused3()
    fused3_ok = fused3_res["gate_ok"]
    print(f"# H2 tier three-stage {fused3_res['fused3']['qps']:.0f} QPS vs "
          f"max(fused {fused3_res['fused']['qps']:.0f}, "
          f"rt {fused3_res['rt']['qps']:.0f}) QPS, "
          f"ids_equal={fused3_res['ids_equal']}, "
          f"scores_equal={fused3_res['scores_equal']} -> "
          f"{'OK' if fused3_ok else 'REGRESSION'}", file=sys.stderr)
    fleet_res = run_fleet()
    fleet_ok = fleet_res["gate_ok"]
    for prof, pres in fleet_res["profiles"].items():
        print(f"# fleet {prof}: bounded p99 "
              f"{pres['bounded']['p99'] * 1e3:.1f} ms vs unbounded "
              f"{pres['unbounded']['p99'] * 1e3:.1f} ms "
              f"(shed {pres['bounded']['shed']}) -> "
              f"{'OK' if pres['gate_ok'] else 'REGRESSION'}",
              file=sys.stderr)
    paged_res = run_paged(n_requests=args.n_requests)
    paged_ok = paged_res["gate_ok"]
    print(f"# paged tier {paged_res['paged_qps']:.0f} QPS vs resident "
          f"{paged_res['resident_qps']:.0f} QPS "
          f"({paged_res['qps_ratio']:.2f}x, ids_equal="
          f"{paged_res['ids_equal']}, evictions="
          f"{paged_res['cache']['evictions']}) -> "
          f"{'OK' if paged_ok else 'REGRESSION'}", file=sys.stderr)
    fresh_res = run_freshness()
    fresh_ok = fresh_res["gate_ok"]
    print(f"# freshness soak: {fresh_res['cycles_promoted']}/"
          f"{fresh_res['n_cycles']} merge cycles, tail ratio "
          f"{fresh_res['tail_ratio']:.2f} (bound 2.0), rebuild parity "
          f"{'bit' if fresh_res['ids_strict'] else 'tie'} -> "
          f"{'OK' if fresh_ok else 'REGRESSION'}", file=sys.stderr)
    obs_res = run_obs(emit=args.emit_metrics)
    obs_ok = obs_res["gate_ok"]
    print(f"# obs H2 tier instrumented {obs_res['obs_qps']:.0f} QPS vs "
          f"plain {obs_res['plain_qps']:.0f} QPS "
          f"({obs_res['qps_ratio']:.2f}x, ids_equal={obs_res['ids_equal']}, "
          f"recall10 online {obs_res['recall10_online']:.3f} vs offline "
          f"{obs_res['recall10_offline']:.3f}, "
          f"{obs_res['series']} series) -> "
          f"{'OK' if obs_ok else 'REGRESSION'}", file=sys.stderr)
    if args.json_obs:
        with open(args.json_obs, "w") as fh:
            json.dump({"smoke": args.smoke, "backend": "cpu-hostpath",
                       "dataset": "deep", **obs_res},
                      fh, indent=2, sort_keys=True)
            fh.write("\n")
    if args.json_freshness:
        with open(args.json_freshness, "w") as fh:
            json.dump({"smoke": args.smoke, "backend": "cpu-hostpath",
                       "dataset": "deep", **fresh_res},
                      fh, indent=2, sort_keys=True)
            fh.write("\n")
    if args.json_paged:
        with open(args.json_paged, "w") as fh:
            json.dump({"smoke": args.smoke, "backend": "cpu-hostpath",
                       "dataset": "deep", **paged_res},
                      fh, indent=2, sort_keys=True)
            fh.write("\n")
    if args.json_fused3:
        with open(args.json_fused3, "w") as fh:
            json.dump({"smoke": args.smoke, "backend": "cpu-hostpath",
                       "h2_tier": fused3_res}, fh, indent=2, sort_keys=True)
            fh.write("\n")
    if args.json_fleet:
        with open(args.json_fleet, "w") as fh:
            json.dump({"smoke": args.smoke, "backend": "cpu-hostpath",
                       **fleet_res}, fh, indent=2, sort_keys=True)
            fh.write("\n")
    if args.json_rt:
        with open(args.json_rt, "w") as fh:
            json.dump({"smoke": args.smoke, "backend": "cpu-hostpath",
                       "h2_tier": rt_res}, fh, indent=2, sort_keys=True)
            fh.write("\n")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump({"dataset": args.dataset, "smoke": args.smoke,
                       "backend": "cpu-hostpath",
                       "engine_vs_single_shot": {
                           "engine_qps": res["eng_qps"],
                           "single_shot_qps": res["base_qps"]},
                       **res["fused"]}, fh, indent=2, sort_keys=True)
            fh.write("\n")
    if (args.check or args.smoke) and not (ok and fused_ok and rt_ok
                                           and fused3_ok and fleet_ok
                                           and paged_ok and fresh_ok
                                           and obs_ok):
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

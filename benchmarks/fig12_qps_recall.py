"""Paper Fig. 12: QPS vs recall Pareto — JUNO-L/M/H/H2 operating points vs
the IVFPQ baseline (full LUT, no selection ≙ FAISS semantics in this stack).

CPU wall time is a proxy for the shape of the trade-off; the TPU throughput
claim is carried by the derived work columns: f32 gather-accumulate ops per
query (what the paper's selection skips) and int8-vs-f32 scan mix."""
from __future__ import annotations

from repro.core import recall_1_at_k, recall_n_at_k, search
from .common import emit, get_bench_index, time_fn


def _work_per_query(cfg, nprobe, p_cap, mode, rerank=400):
    """Derived: f32 LUT gather-adds + int8 adds per query (S = subspaces)."""
    s = 48  # deep-like: 96 / M=2
    n_cand = nprobe * p_cap
    if mode == "baseline" or mode == "H":
        return n_cand * s, 0
    if mode in ("L", "M"):
        return 0, n_cand * s
    if mode == "H2":
        return rerank * s, n_cand * s
    raise ValueError(mode)


def run(dataset="deep"):
    pts, queries, index, gt, cfg = get_bench_index(dataset)
    metric = cfg.metric
    p_cap = index.ivf.capacity
    gt1, gt100 = gt[:, 0], gt[:, :100]

    points = []
    for nprobe in [4, 8, 16]:
        # baseline: IVFPQ with full LUT (threshold → ∞ disables selection);
        # JUNO-H2-fused: the same two-stage operating point served by the
        # fused hit-count→masked-ADC scan (identical ids to JUNO-H2)
        for name, mode, scale, fused in [
                ("baseline", "H", 1e6, False),
                ("JUNO-H", "H", 1.0, False),
                ("JUNO-H2", "H2", 1.0, False),
                ("JUNO-H2-fused", "H2", 1.0, True),
                ("JUNO-M", "M", 1.0, False),
                ("JUNO-L", "L", 1.0, False),
                ("JUNO-L-tight", "L", 0.5, False)]:
            m = "H" if name == "baseline" else mode

            def fn():
                return search(index, queries, nprobe=nprobe, k=100, mode=m,
                              metric=metric, thres_scale=scale, fused=fused)

            t = time_fn(fn, iters=3)
            _, ids = fn()
            r1 = float(recall_1_at_k(ids, gt1))
            r100 = float(recall_n_at_k(ids, gt100))
            qps = queries.shape[0] / t
            f32_ops, i8_ops = _work_per_query(
                cfg, nprobe, p_cap, "baseline" if name == "baseline" else mode)
            emit(f"fig12_{dataset}_{name}_np{nprobe}",
                 t / queries.shape[0] * 1e6,
                 f"qps={qps:.0f};R1@100={r1:.3f};R100@1000={r100:.3f};"
                 f"f32_ops/q={f32_ops};int8_ops/q={i8_ops}")
            points.append((name, nprobe, qps, r1))

        # fused-vs-unfused speedup at this probe budget (same ids by
        # construction, so this isolates the kernel-path cost)
        by_name = {n: q for (n, np_, q, _) in points if np_ == nprobe}
        emit(f"fig12_{dataset}_fused_speedup_np{nprobe}", 0.0,
             f"fused_over_composed="
             f"{by_name['JUNO-H2-fused'] / by_name['JUNO-H2']:.2f}x")

    # Pareto summary: best QPS at each recall band (the paper's grey line)
    for lo, hi, tag in [(0.0, 0.95, "lowQ"), (0.95, 0.97, "midQ"),
                        (0.97, 1.01, "highQ")]:
        cand = [(q, n, np_) for (n, np_, q, r) in points if lo <= r < hi]
        if cand:
            q, n, np_ = max(cand)
            emit(f"fig12_{dataset}_pareto_{tag}", 0.0,
                 f"best={n};nprobe={np_};qps={q:.0f}")

"""Paper Fig. 11(b): correlation between hit count and exact distance.
The reward/penalty counter (inner sphere at r/2, JUNO-M) must correlate
more strongly than the plain counter (JUNO-L) — the paper's justification
for the multi-sphere refinement."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import density as density_lib
from repro.core import lut as lut_lib
from repro.core import scan as scan_lib
from repro.core.ivf import filter_clusters
from .common import emit, get_bench_index


def run():
    pts, queries, index, gt, cfg = get_bench_index("deep")
    nprobe = 16
    m = cfg.sub_dim
    q = queries.astype(jnp.float32)
    _, cids = filter_clusters(q, index.ivf, nprobe=nprobe)
    res = q[:, None, :] - index.ivf.centroids[cids]
    qsub = res.reshape(q.shape[0], nprobe, -1, m)
    tau = density_lib.predict_threshold(index.density, qsub, 1.0)
    lutv, mask = lut_lib.build_lut(qsub, index.codebook, tau)
    mlut = lut_lib.masked_lut(lutv, mask, tau)

    codes = index.cluster_codes[cids]
    valid = index.ivf.valid[cids]
    exact = jax.vmap(jax.vmap(scan_lib.adc_scan))(mlut, codes, valid)

    corrs = {}
    for name, hc_mode in [("plain_L", "count"), ("reward_penalty_M",
                                                 "reward_penalty")]:
        table = lut_lib.hit_tables(lutv, mask, tau, mode=hc_mode)
        counts = jax.vmap(jax.vmap(scan_lib.hit_count_scan))(table, codes,
                                                             valid)
        v = np.asarray(valid).ravel()
        e = np.asarray(exact).ravel()[v]
        c = np.asarray(counts).ravel()[v].astype(np.float64)
        corrs[name] = float(np.corrcoef(-e, c)[0, 1])
    emit("fig11_hitcount_correlation", 0.0,
         f"plain_L={corrs['plain_L']:.3f};"
         f"reward_penalty_M={corrs['reward_penalty_M']:.3f};"
         f"stronger={corrs['reward_penalty_M'] > corrs['plain_L']}")

"""Pure-jnp oracles for every Pallas kernel (the semantics of record).

Each function mirrors one kernel's contract exactly; kernel tests sweep
shapes/dtypes and assert_allclose against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_lut_ref(q0, q1, e0, e1, esq, tau, *, metric="l2"):
    """(B,S),(B,S),(S,E),(S,E),(S,E),(B,S) → lut (B,S,E) f32, hit (B,S,E) i8."""
    dot = q0[:, :, None] * e0[None] + q1[:, :, None] * e1[None]
    tau_sq = (tau * tau)[:, :, None]
    if metric == "l2":
        r_sq = (q0 * q0 + q1 * q1)[:, :, None]
        dist = r_sq - 2.0 * dot + esq[None]
        outer = dist <= tau_sq
        inner = dist <= 0.25 * tau_sq
        lut = jnp.where(outer, dist, tau_sq)
    else:
        t = esq[None] - 2.0 * dot
        outer = t <= tau_sq
        inner = t <= 0.25 * tau_sq
        # shared pruned-entry substitution rule (core/lut.ip_pruned_fill)
        from repro.core.lut import ip_pruned_fill
        lut = ip_pruned_fill(dot, outer)
    hit = inner.astype(jnp.int8) - (~outer).astype(jnp.int8)
    return lut.astype(jnp.float32), hit


def pq_scan_ref(lut, codes, valid, *, metric="l2"):
    """lut (S,E) f32, codes (P,S) uint8, valid (P,) → (P,) f32."""
    s_idx = jnp.arange(lut.shape[0])[None, :]
    vals = lut[s_idx, codes.astype(jnp.int32)]
    total = jnp.sum(vals.astype(jnp.float32), axis=-1)
    bad = jnp.inf if metric == "l2" else -jnp.inf
    return jnp.where(valid, total, bad)


def hit_count_ref(table, codes, valid):
    """table (S,E) int8, codes (P,S) uint8, valid (P,) → (P,) int32."""
    s_idx = jnp.arange(table.shape[0])[None, :]
    vals = table[s_idx, codes.astype(jnp.int32)].astype(jnp.int32)
    total = jnp.sum(vals, axis=-1)
    return jnp.where(valid, total, jnp.int32(-(2 ** 30)))


def fused_two_stage_ref(lut, table, codes, valid, *, cap_c, metric="l2"):
    """Dense oracle for the fused two-stage kernel (semantics of record).

    lut/table (Q, np, S, E), codes (Q, np, P, S) uint8, valid (Q, np, P).
    counts = per-point hit totals (== hit_count_ref per (q, probe));
    θ_q = cap_c-th largest count of query q (over the flat np·P axis);
    dist = ADC totals (== pq_scan_ref) wherever ``valid & (count >= θ_q)``,
    bad_value elsewhere; cand = lax.top_k(counts_flat, cap_c)[1];
    cand_dist = dist at cand.
    """
    q, n_probe, p, s = codes.shape
    w = n_probe * p
    cap_c = max(1, min(cap_c, w))
    bad = jnp.float32(jnp.inf if metric == "l2" else -jnp.inf)
    neg = jnp.int32(-(2 ** 30))

    qi = jnp.arange(q)[:, None, None, None]
    pri = jnp.arange(n_probe)[None, :, None, None]
    si = jnp.arange(s)[None, None, None, :]
    ci = codes.astype(jnp.int32)
    counts = jnp.where(valid, jnp.sum(table[qi, pri, si, ci].astype(jnp.int32),
                                      axis=-1), neg)
    flat = counts.reshape(q, w)
    topv, cand = jax.lax.top_k(flat, cap_c)
    theta = topv[:, -1]

    totals = jnp.sum(lut[qi, pri, si, ci].astype(jnp.float32), axis=-1)
    keep = valid & (counts >= theta[:, None, None])
    dist = jnp.where(keep, totals, bad)
    cand_dist = jnp.take_along_axis(dist.reshape(q, w), cand, axis=1)
    return counts, dist, cand, cand_dist


def fused_three_stage_ref(lut, table, codes, valid, q0, q1, radius,
                          cell_c0, cell_c1, slot_reach, slot_idx, *,
                          cap_c, metric="l2"):
    """Dense oracle for the three-stage RT→hit-count→ADC kernel.

    The two-stage oracle with phase 0 composed in front: the dense sphere
    test (``rt_sphere_hits_ref``) gathered at ``slot_idx`` (Q, np) —
    ``CentroidGrid.slot_of`` at the probed cluster ids — yields
    ``probe_ok``; probe 0 is forced True (the `_rt_probe_mask` backstop:
    the nearest probe is always scanned); ``valid`` is masked by it before
    ``fused_two_stage_ref``. Returns that oracle's 4-tuple + probe_ok.
    """
    hits = rt_sphere_hits_ref(q0, q1, radius, cell_c0, cell_c1, slot_reach)
    probe_ok = jnp.take_along_axis(hits, slot_idx, axis=1) > 0
    probe_ok = probe_ok.at[:, 0].set(True)
    valid = valid & probe_ok[:, :, None]
    counts, dist, cand, cand_dist = fused_two_stage_ref(
        lut, table, codes, valid, cap_c=cap_c, metric=metric)
    return counts, dist, cand, cand_dist, probe_ok


def rt_sphere_hits_ref(q0, q1, radius, c0, c1, slot_reach):
    """Dense oracle for the RT sphere-intersection kernel.

    (Q,),(Q,),(Q,) queries/radii; (n_cells, cap) centroid planes/reaches
    → (Q, n_cells·cap) int8. hit = ``||qp - cp|| <= R + reach`` via the
    signed squared compare (``thr >= 0`` guards the ``-inf`` pad/empty
    sentinels). No cell walk — the kernel's AABB skip is conservative, so
    results must match this bit-for-bit.
    """
    dx = q0[:, None, None] - c0[None]
    dy = q1[:, None, None] - c1[None]
    d2 = dx * dx + dy * dy
    thr = radius[:, None, None] + slot_reach[None]
    hit = (thr >= 0.0) & (d2 <= thr * thr)
    return hit.reshape(q0.shape[0], -1).astype(jnp.int8)


def ivf_filter_ref(queries, centroids, centroid_sq, *, metric="l2"):
    """(Q,D),(C,D),(C,) → (Q,C): csq - 2 q·c (l2, rank-equivalent) or q·c."""
    dots = queries.astype(jnp.float32) @ centroids.astype(jnp.float32).T
    if metric == "l2":
        return centroid_sq[None, :].astype(jnp.float32) - 2.0 * dots
    return dots

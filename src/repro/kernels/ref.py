"""Pure-jnp oracles for every Pallas kernel (the semantics of record).

Each function mirrors one kernel's contract exactly; kernel tests sweep
shapes/dtypes and assert_allclose against these.
"""
from __future__ import annotations

import jax.numpy as jnp


def selective_lut_ref(q0, q1, e0, e1, esq, tau, *, metric="l2"):
    """(B,S),(B,S),(S,E),(S,E),(S,E),(B,S) → lut (B,S,E) f32, hit (B,S,E) i8."""
    dot = q0[:, :, None] * e0[None] + q1[:, :, None] * e1[None]
    tau_sq = (tau * tau)[:, :, None]
    if metric == "l2":
        r_sq = (q0 * q0 + q1 * q1)[:, :, None]
        dist = r_sq - 2.0 * dot + esq[None]
        outer = dist <= tau_sq
        inner = dist <= 0.25 * tau_sq
        lut = jnp.where(outer, dist, tau_sq)
    else:
        t = esq[None] - 2.0 * dot
        outer = t <= tau_sq
        inner = t <= 0.25 * tau_sq
        # shared pruned-entry substitution rule (core/lut.ip_pruned_fill)
        from repro.core.lut import ip_pruned_fill
        lut = ip_pruned_fill(dot, outer)
    hit = inner.astype(jnp.int8) - (~outer).astype(jnp.int8)
    return lut.astype(jnp.float32), hit


def pq_scan_ref(lut, codes, valid, *, metric="l2"):
    """lut (S,E) f32, codes (P,S) uint8, valid (P,) → (P,) f32."""
    s_idx = jnp.arange(lut.shape[0])[None, :]
    vals = lut[s_idx, codes.astype(jnp.int32)]
    total = jnp.sum(vals.astype(jnp.float32), axis=-1)
    bad = jnp.inf if metric == "l2" else -jnp.inf
    return jnp.where(valid, total, bad)


def hit_count_ref(table, codes, valid):
    """table (S,E) int8, codes (P,S) uint8, valid (P,) → (P,) int32."""
    s_idx = jnp.arange(table.shape[0])[None, :]
    vals = table[s_idx, codes.astype(jnp.int32)].astype(jnp.int32)
    total = jnp.sum(vals, axis=-1)
    return jnp.where(valid, total, jnp.int32(-(2 ** 30)))


def ivf_filter_ref(queries, centroids, centroid_sq, *, metric="l2"):
    """(Q,D),(C,D),(C,) → (Q,C): csq - 2 q·c (l2, rank-equivalent) or q·c."""
    dots = queries.astype(jnp.float32) @ centroids.astype(jnp.float32).T
    if metric == "l2":
        return centroid_sq[None, :].astype(jnp.float32) - 2.0 * dots
    return dots

"""Pallas TPU kernels for JUNO's compute hot-spots (paper §4.2/§5.3/§5.4):

    selective_lut — fused pairwise-dist + threshold mask + hit table
                    (the RT-core stage, re-mapped per DESIGN.md §2)
    pq_scan       — masked ADC accumulation as one-hot·LUT MXU contraction
                    (the Tensor-core A×B(=1) trick, TPU-native)
    hit_count     — int8 reward/penalty scan (aggressive approximation)
    ivf_filter    — fused stage-A filtering distances (the cuBLAS
                    x^2-2xq^T+q^2 trick, §5.3, MXU-native)
    fused_two_stage — hit-count prefilter + in-kernel survivor threshold +
                    masked ADC + top-candidate compaction in ONE kernel
                    (the RT→TC pipelining of §5.5, DESIGN.md §3)

The cluster-granularity RT stage (sphere-intersection prefilter over the
centroid grid) lives in ``repro.rt``; its kernel is dispatched from here
(``ops.rt_sphere_hits``). Contracts and grid/VMEM budgets for everything:
docs/kernels.md.

``ops`` holds the jit'd public wrappers (interpret=True off-TPU, except
the serving hot paths which dispatch to host paths — see docs/kernels.md);
``ref`` holds the pure-jnp oracles every kernel is tested against.
"""
from . import ops, ref  # noqa: F401

"""Pallas TPU kernel: fused two-stage scan — int8 hit-count prefilter and
survivor-masked ADC in ONE kernel (paper §5.5; DESIGN.md §3).

The paper's core hardware claim is that the RT-core membership test and the
tensor-core distance accumulation run as a *pipeline*, not two serialized
passes with a host-visible survivor set in between. The TPU analogue built
here: a query-batched grid keeps each (query-block, point-block) tile in one
VMEM residency and runs BOTH stages over it —

  phase 0 (grid t=0) — int8 hit scores for the tile plus a streamed
      per-query top-``cap_c`` (value, flat-index) carried in VMEM scratch:
      after the last point block, scratch row q holds exactly
      ``lax.top_k(counts[q], cap_c)`` (ties resolved index-ascending, like
      ``lax.top_k``), i.e. the stage-1 survivor threshold θ_q = the cap_c-th
      largest hit count — computed in-kernel, never leaving the chip.
  phase 1 (grid t=1) — the masked-LUT ADC for the same tiles, but only
      where ``count >= θ_q``: blocks with zero survivors skip the f32
      contraction entirely (`pl.when`), surviving lanes are accumulated with
      the same SLAB one-hot MXU contraction as ``pq_scan``, and the
      compacted candidate list is folded: each block writes its slice of
      the (cap_c,) candidate distances.

Outputs (Q = queries, W = nprobe·P points, C = cap_c):
  counts (Q, np, P) int32 — stage-1 scores (== ``hit_count`` composed)
  dist   (Q, np, P) f32  — ADC totals at survivors, ``bad_value`` elsewhere
  cand   (Q, C)     int32 — flat top-C-by-count indices into (np·P),
                            bit-identical to ``lax.top_k(counts, C)[1]``
  cand_dist (Q, C)  f32  — ``dist`` gathered at ``cand``

so the downstream two-stage search needs NO wide top-k and NO second scan:
stage 2 consumes the compacted candidates directly.

Grid: (Q/bQ, 2, np·Ppad/bP) with bP the largest divisor of P ≤ 128 when
that divisor is a usable tile (≥ 64), else P is padded per probe to a
multiple of 128 (a P like 8·prime would otherwise collapse bP to 8 and
balloon the grid). Padded slots carry a count sentinel STRICTLY below the
invalid-point `_NEG`, so — with cap_c clamped to the real candidate count —
they can never enter the top-C, and the real entries' (value desc, index
asc) selection order is preserved exactly (the padded flat index is
monotone in the unpadded one); the wrapper remaps candidate indices back
to the unpadded layout. The query axis pads to bQ and is sliced off.
VMEM per program ≈
bQ·S·E·(4+1) [lut+table] + bQ·bP·S [codes] + bQ·bP·SLAB·E·4 [one-hot slab]
+ 2·bQ·C·4 [top-C scratch] ≈ 2.6 MB at (bQ, bP, S, E, C) = (4, 128, 48,
256, 400).

``fused_two_stage_host`` is the schedule-equivalent host path used for
off-TPU serving (see its docstring); the Pallas kernel itself is validated
in interpret mode by tests/test_fused_kernel.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ops import slab_onehot_dot

DEFAULT_BQ = 4     # query rows per program
DEFAULT_BP = 128   # points per program (upper bound; must divide P)
SLAB = 8           # subspaces one-hot-expanded at a time (VMEM control)

_NEG = -(2 ** 30)  # invalid-point count sentinel (matches hit_count kernel)
# point-padding sentinel: STRICTLY below _NEG so a padded slot loses every
# tie against a real (even invalid) point and never enters the top-C
_PAD = -(2 ** 30) - 1
# scratch init must sit STRICTLY below every real/pad count or top_k's
# position-asc tie-break would keep a stale scratch slot in place of a
# genuine sentinel-count point
_INIT = -(2 ** 31)

# hit-count accumulation dtypes the autotuner may pick (kernels.autotune).
# Every option is EXACT for hit counts (|count| <= S <= 48 << 2^8): "f32"
# is the historical default, "bf16" halves the one-hot operand bytes (8
# mantissa bits hold integers to 256), "int8" keeps the table in int8 and
# accumulates on the integer pipeline (int32) — so the knob is
# result-invariant by construction (tests/test_autotune.py pins this).
ACC_DTYPES = ("f32", "bf16", "int8")


def count_dot(codes, table_i8, *, n_entries, acc="f32", slab=SLAB):
    """Stage-1 hit-count contraction with a tunable accumulation dtype.

    codes (..., bP, S) int32, table_i8 (..., S, E) int8 → (..., bP) int32.
    ``acc`` selects the MXU operand/accumulation dtype (see ACC_DTYPES);
    all options produce bit-identical int32 counts.
    """
    if acc == "bf16":
        tab, od = table_i8.astype(jnp.bfloat16), jnp.bfloat16
    elif acc == "int8":
        tab, od = table_i8, jnp.int32
    else:
        tab, od = table_i8.astype(jnp.float32), jnp.float32
    out = slab_onehot_dot(codes, tab, n_entries=n_entries, out_dtype=od,
                          slab=slab)
    return out.astype(jnp.int32)


def _fused_kernel(lut_ref, table_ref, codes_ref, valid_ref,
                  counts_ref, dist_ref, cand_ref, cdist_ref,
                  topv_ref, topi_ref, *, n_entries, cap_c, bp, p_real,
                  p_pad, bad_value, acc):
    t = pl.program_id(1)           # 0 = hit-count pass, 1 = masked-ADC pass
    j = pl.program_id(2)           # flat point-block index over np·Ppad
    codes = codes_ref[...].astype(jnp.int32)          # (bQ, bP, S)
    valid = valid_ref[...]                            # (bQ, bP)
    bq = codes.shape[0]

    # stage 1 (both phases — phase 1 re-derives the survivor mask from it):
    # batched SLAB one-hot contraction; accumulation of {-1,0,1} terms is
    # exact in every ACC_DTYPES option (|count| <= S << 2^8), so counts are
    # bit-identical to the int32-path hit_count kernel regardless of ``acc``.
    cnt = count_dot(codes, table_ref[...][:, 0], n_entries=n_entries,
                    acc=acc)
    bad_count = _NEG
    if p_pad != p_real:            # point axis padded: mark pad slots so
        lane = j * bp + jax.lax.broadcasted_iota(jnp.int32, (bq, bp), 1)
        bad_count = jnp.where(lane % p_pad < p_real, _NEG, _PAD)
    counts = jnp.where(valid, cnt, bad_count)
    counts_ref[...] = counts

    @pl.when(t == 0)
    def _stage1():
        @pl.when(j == 0)
        def _init():
            topv_ref[...] = jnp.full_like(topv_ref, _INIT)
            topi_ref[...] = jnp.zeros_like(topi_ref)
        # streamed top-C merge: previously selected entries sit at the lower
        # concat positions, so lax.top_k's position-ascending tie-break
        # reproduces the global (value desc, index asc) order exactly.
        newi = j * bp + jax.lax.broadcasted_iota(jnp.int32, (bq, bp), 1)
        runv = jnp.concatenate([topv_ref[...], counts], axis=1)
        runi = jnp.concatenate([topi_ref[...], newi], axis=1)
        v, pos = jax.lax.top_k(runv, cap_c)
        topv_ref[...] = v
        topi_ref[...] = jnp.take_along_axis(runi, pos, axis=1)
        cand_ref[...] = topi_ref[...]
        cdist_ref[...] = jnp.full_like(cdist_ref, bad_value)
        dist_ref[...] = jnp.full((bq, codes.shape[1]), bad_value, jnp.float32)

    @pl.when(t == 1)
    def _stage2():
        theta = topv_ref[...][:, cap_c - 1]           # (bQ,) survivor floor
        keep = valid & (counts >= theta[:, None])
        cand_ref[...] = topi_ref[...]
        any_keep = jnp.any(keep)

        @pl.when(any_keep)
        def _adc():
            lut = lut_ref[...][:, 0]                  # (bQ, S, E) f32
            acc = slab_onehot_dot(codes, lut, n_entries=n_entries,
                                  out_dtype=jnp.float32, slab=SLAB)
            dist = jnp.where(keep, acc, bad_value)
            dist_ref[...] = dist
            # compaction fold: this block's slice of the candidate list
            local = topi_ref[...] - j * bp            # (bQ, C)
            inblk = (local >= 0) & (local < bp)
            got = jnp.take_along_axis(dist, jnp.clip(local, 0, bp - 1),
                                      axis=1)
            cdist_ref[...] = jnp.where(inblk, got, cdist_ref[...])

        # zero-survivor block: stage-2 f32 work skipped entirely
        @pl.when(jnp.logical_not(any_keep))
        def _skip():
            dist_ref[...] = jnp.full((bq, codes.shape[1]), bad_value,
                                     jnp.float32)


def _largest_divisor(n: int, cap: int) -> int:
    for d in range(min(cap, n), 0, -1):
        if n % d == 0:
            return d
    return 1


@functools.partial(jax.jit,
                   static_argnames=("cap_c", "metric", "bq", "bp", "acc",
                                    "interpret"))
def fused_two_stage(lut: jnp.ndarray, table: jnp.ndarray, codes: jnp.ndarray,
                    valid: jnp.ndarray, *, cap_c: int, metric: str = "l2",
                    bq: int = DEFAULT_BQ, bp: int | None = None,
                    acc: str = "f32", interpret: bool = False):
    """lut (Q, np, S, E) f32, table (Q, np, S, E) int8,
    codes (Q, np, P, S) uint8, valid (Q, np, P) bool →
    (counts (Q, np, P) i32, dist (Q, np, P) f32,
     cand (Q, C) i32, cand_dist (Q, C) f32). See module docstring.
    ``bq``/``bp``/``acc`` are the autotuner's tile/accumulation knobs
    (``kernels.autotune``) — all result-invariant."""
    q, n_probe, p, s = codes.shape
    e = lut.shape[-1]
    cap_c = max(1, min(cap_c, n_probe * p))
    bp = _largest_divisor(p, bp or DEFAULT_BP)
    if bp < min(64, p):
        # divisor cliff (e.g. P = 8·prime would give bp = 8): pad the point
        # axis per probe to a full tile instead; pad slots are masked in the
        # kernel with the below-_NEG _PAD sentinel, so candidate selection
        # over the REAL entries is unchanged (cap_c <= np·P real entries
        # always outrank every pad slot)
        bp = DEFAULT_BP
        pad_p = (-p) % bp
        codes = jnp.pad(codes, ((0, 0), (0, 0), (0, pad_p), (0, 0)))
        valid = jnp.pad(valid, ((0, 0), (0, 0), (0, pad_p)))
    p_pad = codes.shape[2]
    w = n_probe * p_pad
    bq = min(bq, q)
    pad_q = (-q) % bq
    if pad_q:
        lut = jnp.pad(lut, ((0, pad_q), (0, 0), (0, 0), (0, 0)))
        table = jnp.pad(table, ((0, pad_q), (0, 0), (0, 0), (0, 0)))
        codes = jnp.pad(codes, ((0, pad_q), (0, 0), (0, 0), (0, 0)))
        valid = jnp.pad(valid, ((0, pad_q), (0, 0), (0, 0)))
    qp = q + pad_q
    codes_f = codes.reshape(qp, w, s)
    valid_f = valid.reshape(qp, w)
    npb = p_pad // bp                 # point blocks per probe
    bad = float("inf") if metric == "l2" else float("-inf")

    counts, dist, cand, cdist = pl.pallas_call(
        functools.partial(_fused_kernel, n_entries=e, cap_c=cap_c, bp=bp,
                          p_real=p, p_pad=p_pad, bad_value=bad, acc=acc),
        grid=(qp // bq, 2, n_probe * npb),
        in_specs=[
            pl.BlockSpec((bq, 1, s, e), lambda i, t, j: (i, j // npb, 0, 0)),
            pl.BlockSpec((bq, 1, s, e), lambda i, t, j: (i, j // npb, 0, 0)),
            pl.BlockSpec((bq, bp, s), lambda i, t, j: (i, j, 0)),
            pl.BlockSpec((bq, bp), lambda i, t, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((bq, bp), lambda i, t, j: (i, j)),
            pl.BlockSpec((bq, bp), lambda i, t, j: (i, j)),
            pl.BlockSpec((bq, cap_c), lambda i, t, j: (i, 0)),
            pl.BlockSpec((bq, cap_c), lambda i, t, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qp, w), jnp.int32),
            jax.ShapeDtypeStruct((qp, w), jnp.float32),
            jax.ShapeDtypeStruct((qp, cap_c), jnp.int32),
            jax.ShapeDtypeStruct((qp, cap_c), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bq, cap_c), jnp.int32),
                        pltpu.VMEM((bq, cap_c), jnp.int32)],
        interpret=interpret,
    )(lut, table, codes_f, valid_f)
    counts = counts[:q].reshape(q, n_probe, p_pad)[:, :, :p]
    dist = dist[:q].reshape(q, n_probe, p_pad)[:, :, :p]
    cand, cdist = cand[:q], cdist[:q]
    if p_pad != p:
        # remap candidate indices from the padded to the real flat layout
        # (cand never contains pad slots — see _PAD — and the mapping is
        # monotone, so top-k order is preserved)
        cand = (cand // p_pad) * p + cand % p_pad
    return counts, dist, cand, cdist


@functools.partial(jax.jit, static_argnames=("cap_c", "metric", "topc_impl"))
def fused_two_stage_host(lut: jnp.ndarray, table: jnp.ndarray,
                         codes: jnp.ndarray, valid: jnp.ndarray, *,
                         cap_c: int, metric: str = "l2",
                         topc_impl: str = "sort"):
    """Schedule-equivalent host path for off-TPU serving. Same contract as
    the kernel with two documented deviations, both invisible to the
    two-stage search (which consumes only ``cand``/``cand_dist``/``counts``):

    * ``cand`` holds the identical top-C-by-count SET, but ordered by flat
      index instead of ``lax.top_k``'s (value desc, index asc);
    * ``dist`` carries ADC totals only at ``cand`` positions (``bad_value``
      elsewhere) — count-ties beyond the C-th candidate are not scored.

    The in-kernel streamed threshold becomes an exact θ-selection: a
    values-only sort yields the per-query C-th-largest count θ_q, the
    index-ascending rank among θ-ties falls out of one cumsum, and the
    selected indices are compacted with searchsorted over that cumsum.
    A key-value select (``lax.top_k`` / argsort) costs ~5× a values-only
    sort on CPU at serving widths, and it is exactly the wide top-k that
    dominates the composed two-stage path there — this is the host-side
    payoff of the kernel's "threshold in-kernel, compact per block" design.
    Stage 2 then gathers the masked LUT for exactly the C survivors.

    ``topc_impl`` is the autotuner's θ-selection knob (``kernels.autotune``):
    "sort" (default) derives θ_q from a values-only sort + searchsorted;
    "topk" derives the same θ_q from ``lax.top_k`` values and a count of
    strictly-greater entries. Both feed the identical tie-rank/compaction
    tail, so candidate sets, order and every output are bit-identical —
    only the selection cost differs by backend and problem width.
    """
    q, n_probe, p, s = codes.shape
    w = n_probe * p
    cap_c = max(1, min(cap_c, w))
    bad = jnp.float32(jnp.inf if metric == "l2" else -jnp.inf)
    rows = jnp.arange(q)[:, None]

    # ---- stage 1: hit counts by direct gather (CPU-optimal) -------------
    qi = jnp.arange(q)[:, None, None, None]
    pri = jnp.arange(n_probe)[None, :, None, None]
    si = jnp.arange(s)[None, None, None, :]
    ci = codes.astype(jnp.int32)
    tvals = table[qi, pri, si, ci]                       # (Q, np, P, S) int8
    counts = jnp.where(valid, jnp.sum(tvals.astype(jnp.int32), axis=-1),
                       _NEG)
    flat = counts.reshape(q, w)

    # ---- survivor threshold: exact θ-selection ---------------------------
    if topc_impl == "topk":
        theta = jax.lax.top_k(flat, cap_c)[0][:, -1]     # C-th largest count
        n_gt = jnp.sum((flat > theta[:, None]).astype(jnp.int32), axis=1)
    else:                                                # values-only sort
        srt = jnp.sort(flat, axis=1)
        theta = srt[:, w - cap_c]            # C-th largest count (with ties)
        n_gt = w - jax.vmap(
            lambda sr, th: jnp.searchsorted(sr, th, side="right"))(srt, theta)
    tie = flat == theta[:, None]
    tie_rank = jnp.cumsum(tie.astype(jnp.int32), axis=1) - 1
    take = (flat > theta[:, None]) | (
        tie & (tie_rank < (cap_c - n_gt)[:, None]))      # exactly C True

    # ---- compaction: the C selected flat indices, index-ascending -------
    cum = jnp.cumsum(take.astype(jnp.int32), axis=1)
    ranks = jnp.arange(1, cap_c + 1)
    cand = jax.vmap(
        lambda c: jnp.searchsorted(c, ranks))(cum).astype(jnp.int32)

    # ---- stage 2: masked-LUT ADC for the C survivors only ---------------
    cand_probe = cand // p
    cand_codes = jnp.take_along_axis(
        codes.reshape(q, w, s), cand[..., None], axis=1).astype(jnp.int32)
    s2 = jnp.arange(s)[None, None, :]
    vals = lut[rows[..., None], cand_probe[..., None], s2, cand_codes]
    cand_valid = jnp.take_along_axis(valid.reshape(q, w), cand, axis=1)
    cdist = jnp.where(cand_valid, jnp.sum(vals, axis=-1), bad)

    dist = jnp.full((q, w), bad, jnp.float32).at[rows, cand].set(cdist)
    return counts, dist.reshape(q, n_probe, p), cand, cdist

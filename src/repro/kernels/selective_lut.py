"""Pallas TPU kernel: fused selective LUT construction (paper §4.1/§4.2).

One pass over the codebook produces, per (query-residual, subspace):
  * the masked L2/IP LUT row (pruned entries pre-substituted with tau^2 /
    the IP floor) and
  * the int8 hit table (+1 inner sphere, 0 outer ring, -1 miss — paper §5.4),
so the RT-core's "membership test + free distance from t_hit" collapses into
a single VMEM-resident fused kernel (DESIGN.md §2): codebook coordinates are
read from HBM once per block and never touched again downstream.

Layout: 2-D subspaces (M=2, as in JUNO) are carried as separate (…, S) planes
q0/q1 and (S, E) planes e0/e1 so every operand is lane-aligned on E (=256)
and sublane-aligned on S — no (…, 2) trailing dims anywhere near the VPU.

Grid: (B/bB, S/bS); each program computes a (bB, bS, E) tile of both outputs.
VMEM per program ≈ bB*bS*E*(4+1) + 2*bS*E*4 ≈ 0.7 MB at (8, 8, 256).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BB = 8   # query-residual rows per program
DEFAULT_BS = 8   # subspaces per program


def _kernel_l2(q0_ref, q1_ref, e0_ref, e1_ref, esq_ref, tau_ref,
               lut_ref, hit_ref):
    q0 = q0_ref[...]                       # (bB, bS)
    q1 = q1_ref[...]
    e0 = e0_ref[...]                       # (bS, E)
    e1 = e1_ref[...]
    esq = esq_ref[...]
    tau = tau_ref[...]                     # (bB, bS)

    # |r - e|^2 = |r|^2 - 2 r.e + |e|^2 — rank-1 expansion, fused per tile
    r_sq = q0 * q0 + q1 * q1                                     # (bB, bS)
    dot = (q0[:, :, None] * e0[None, :, :] +
           q1[:, :, None] * e1[None, :, :])                      # (bB, bS, E)
    dist = r_sq[:, :, None] - 2.0 * dot + esq[None, :, :]

    tau_sq = (tau * tau)[:, :, None]
    outer = dist <= tau_sq
    inner = dist <= 0.25 * tau_sq
    # masked LUT: pruned entries substituted with their tau^2 lower bound
    lut_ref[...] = jnp.where(outer, dist, tau_sq)
    hit_ref[...] = (inner.astype(jnp.int8) - (~outer).astype(jnp.int8))


def _kernel_ip(q0_ref, q1_ref, e0_ref, e1_ref, esq_ref, tau_ref,
               lut_ref, hit_ref):
    q0 = q0_ref[...]
    q1 = q1_ref[...]
    e0 = e0_ref[...]
    e1 = e1_ref[...]
    esq = esq_ref[...]
    tau = tau_ref[...]

    dot = (q0[:, :, None] * e0[None, :, :] +
           q1[:, :, None] * e1[None, :, :])                      # (bB, bS, E)
    # transformed-L2 selection geometry (the paper's radius-folding trick):
    t = esq[None, :, :] - 2.0 * dot
    tau_sq = (tau * tau)[:, :, None]
    outer = t <= tau_sq
    inner = t <= 0.25 * tau_sq
    # pruned entries get a -tau^2/2 placeholder: the row-min of kept entries
    # (the reference substitution) needs a reduction over the whole E axis,
    # which this tiled kernel cannot do in one pass. ops.build_selective_lut
    # replaces the placeholder with the exact kept-row min afterwards so the
    # pallas and ref paths rank identically (tests/test_impl_parity.py).
    lut_ref[...] = jnp.where(outer, dot, -0.5 * tau_sq)
    hit_ref[...] = (inner.astype(jnp.int8) - (~outer).astype(jnp.int8))


@functools.partial(jax.jit,
                   static_argnames=("metric", "bb", "bs", "interpret"))
def selective_lut(q0: jnp.ndarray, q1: jnp.ndarray, e0: jnp.ndarray,
                  e1: jnp.ndarray, esq: jnp.ndarray, tau: jnp.ndarray, *,
                  metric: str = "l2", bb: int = DEFAULT_BB,
                  bs: int = DEFAULT_BS, interpret: bool = False):
    """q0/q1 (B, S) f32; e0/e1/esq (S, E) f32; tau (B, S) f32.
    Returns (masked_lut (B, S, E) f32, hit_table (B, S, E) int8)."""
    b, s = q0.shape
    e = e0.shape[1]
    bb = min(bb, b)
    bs = min(bs, s)
    assert b % bb == 0 and s % bs == 0, (b, s, bb, bs)
    grid = (b // bb, s // bs)

    q_spec = pl.BlockSpec((bb, bs), lambda i, j: (i, j))
    e_spec = pl.BlockSpec((bs, e), lambda i, j: (j, 0))
    out_spec = pl.BlockSpec((bb, bs, e), lambda i, j: (i, j, 0))
    kernel = _kernel_l2 if metric == "l2" else _kernel_ip

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[q_spec, q_spec, e_spec, e_spec, e_spec, q_spec],
        out_specs=[out_spec, out_spec],
        out_shape=[jax.ShapeDtypeStruct((b, s, e), jnp.float32),
                   jax.ShapeDtypeStruct((b, s, e), jnp.int8)],
        interpret=interpret,
    )(q0, q1, e0, e1, esq, tau)

"""Pallas TPU kernel: fused IVF filtering distances (paper §5.3).

The paper maps stage-A filtering to Tensor cores as
``|x-q|^2 = x^2 - 2 x.q^T + q^2`` with a cuBLAS GEMM; this kernel is the
MXU-native fusion: one pass computes the (Q, C) distance (or similarity)
matrix from query and centroid blocks with the rank-1 terms folded in —
no separate |x|^2 broadcast materialisation in HBM.

Grid: (Q/bQ, C/bC); operands stream through VMEM in (bQ, D) / (bC, D)
tiles; D is the contraction dim on the MXU (D ≤ 1024 fits one tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BQ = 128
DEFAULT_BC = 256


def _filter_kernel_l2(q_ref, c_ref, csq_ref, out_ref):
    q = q_ref[...]                                   # (bQ, D)
    c = c_ref[...]                                   # (bC, D)
    csq = csq_ref[...]                               # (bC,)
    dots = jax.lax.dot_general(q, c, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    # |q|^2 omitted: constant per row, rank-only (matches ivf.filter_clusters)
    out_ref[...] = csq[None, :] - 2.0 * dots


def _filter_kernel_ip(q_ref, c_ref, csq_ref, out_ref):
    q = q_ref[...]
    c = c_ref[...]
    out_ref[...] = jax.lax.dot_general(
        q, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("metric", "bq", "bc",
                                             "interpret"))
def ivf_filter(queries: jnp.ndarray, centroids: jnp.ndarray,
               centroid_sq: jnp.ndarray, *, metric: str = "l2",
               bq: int = DEFAULT_BQ, bc: int = DEFAULT_BC,
               interpret: bool = False) -> jnp.ndarray:
    """queries (Q, D) f32, centroids (C, D) f32, centroid_sq (C,) f32 →
    scores (Q, C) f32 (lower-better for l2, higher-better for ip)."""
    nq, d = queries.shape
    nc = centroids.shape[0]
    bq = min(bq, nq)
    bc = min(bc, nc)
    pad_q = (-nq) % bq
    pad_c = (-nc) % bc
    if pad_q:
        queries = jnp.pad(queries, ((0, pad_q), (0, 0)))
    if pad_c:
        centroids = jnp.pad(centroids, ((0, pad_c), (0, 0)))
        centroid_sq = jnp.pad(centroid_sq, (0, pad_c))
    grid = ((nq + pad_q) // bq, (nc + pad_c) // bc)
    kernel = _filter_kernel_l2 if metric == "l2" else _filter_kernel_ip

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bc, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bc,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bq, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nq + pad_q, nc + pad_c), jnp.float32),
        interpret=interpret,
    )(queries.astype(jnp.float32), centroids.astype(jnp.float32),
      centroid_sq.astype(jnp.float32))
    return out[:nq, :nc]

"""Pallas TPU kernel: single-residency three-stage search — RT sphere test,
int8 hit-count prefilter, and survivor-masked ADC in ONE kernel (paper §5.5
+ §6; DESIGN.md §2-§3; ROADMAP open item 2).

The paper's full hardware pipeline maps the RT-core sphere test *into* the
tensor-core distance stage: survivors of the BVH traversal stream straight
to the MXU without a host-visible round trip. Before this kernel the repo
paid exactly that round trip — ``rt_sphere_hits`` produced an HBM-resident
(Q, n_cells·cap) table, the host gathered it into a probe mask, and the
mask re-entered ``fused_two_stage`` as a pre-masked ``valid``. Here the
whole thing is one ``pallas_call`` with grid (Q/bQ, 3, J),
J = max(n_cells, np·Ppad/bP):

  phase 0 (grid t=0) — the RT walk of ``rt/intersect.py``, one cell per
      program: AABB pre-test gates the per-slot disc-vs-disc tests behind
      ``pl.when`` (the BVH-subtree skip), and each live cell's verdicts are
      merged *directly into a (bQ, np) probe-ok scratch* via the probed
      clusters' flat slot indices (``CentroidGrid.slot_of[cids]``) — the
      hit table never materializes, in VMEM or anywhere else.
  phase 1 (grid t=1) — the hit-count pass of ``fused_two_stage``, with
      ``valid`` masked in-register by the phase-0 scratch (probe 0 is
      backstopped exactly like ``_rt_probe_mask``), plus the streamed
      per-query top-``cap_c`` threshold carried in VMEM scratch.
  phase 2 (grid t=2) — the survivor-masked ADC + per-block candidate
      compaction, unchanged from the two-stage kernel.

Because the cell axis (phase 0) and the point-block axis (phases 1-2) are
both folded onto grid axis 2 of length J, programs past their own axis
clamp their block index and re-run idempotent work: phase-0 programs with
j ≥ n_cells redo cell n_cells-1's merge (same values → same scratch), and
phase-1 programs with j ≥ np·Ppad/bP rewrite block np·Ppad/bP - 1 but are
fenced out of the streamed top-C merge (``pl.when(j < npmax)``) so no
duplicate entries can enter the running selection.

Outputs are the two-stage kernel's four (bit-identical to composing
``rt_sphere_hits`` → probe-mask gather → ``fused_two_stage``; pinned by
tests/test_fused3_kernel.py) plus ``probe_ok`` (Q, np) bool — the phase-0
verdict per probed cluster, identical to ``core.juno._rt_probe_mask`` —
so the side-buffer/minor-tier path downstream applies the SAME verdict to
out-of-cluster points as the kernel applied to their in-cluster siblings.

VMEM per program adds to the two-stage budget only the cell operands and
the probe scratch: 4·cap·4 [boxes+planes+reach] + bQ·np·4 [probe-ok] +
bQ·np·4 [slot idx] ≈ 18 KB at (cap, np) = (64, 32) — the ≈2.6 MB
(bQ, bP, S, E, C) = (4, 128, 48, 256, 400) two-stage budget dominates.

``fused_three_stage_host`` is the schedule-equivalent host path for
off-TPU serving; ``kernels.ref.fused_three_stage_ref`` is the dense jnp
oracle. Tile/accumulation knobs (``bq``/``bp``/``acc``/``topc_impl``) are
supplied by ``kernels.autotune`` and are result-invariant by construction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .fused_two_stage import (_INIT, _NEG, _PAD, SLAB, _largest_divisor,
                              count_dot, fused_two_stage_host)
from .ops import slab_onehot_dot
from .ref import rt_sphere_hits_ref

DEFAULT_BQ = 4     # query rows per program
DEFAULT_BP = 128   # points per program (upper bound; must divide P)


def _fused3_kernel(q0_ref, q1_ref, r_ref, box_ref, creach_ref,
                   c0_ref, c1_ref, reach_ref, sidx_ref,
                   lut_ref, table_ref, codes_ref, valid_ref,
                   counts_ref, dist_ref, cand_ref, cdist_ref, pok_ref,
                   pok_s, topv_ref, topi_ref, *, n_entries, cap_c, bp,
                   p_real, p_pad, bad_value, npb, n_cells, cap, acc):
    t = pl.program_id(1)           # 0 = RT walk, 1 = hit-count, 2 = ADC
    j = pl.program_id(2)           # cell index (t=0) / point-block (t=1,2)
    n_probe = sidx_ref.shape[1]
    npmax = n_probe * npb          # real point blocks (j clamps above this)

    @pl.when(t == 0)
    def _stage0():
        @pl.when(j == 0)
        def _init():
            pok_s[...] = jnp.zeros_like(pok_s)
        # cell AABB pre-test, verbatim from rt/intersect.py: a cell no
        # query disc touches skips the slot tests AND the scratch merge
        # (missed slots contribute 0, which is what the init left there)
        q0 = q0_ref[...]                              # (bQ,)
        q1 = q1_ref[...]
        r = r_ref[...]
        box = box_ref[...]                            # (1, 4) lo0 lo1 hi0 hi1
        dx = jnp.clip(q0, box[0, 0], box[0, 2]) - q0
        dy = jnp.clip(q1, box[0, 1], box[0, 3]) - q1
        d2_cell = dx * dx + dy * dy
        thr_cell = r + creach_ref[...][0]
        live = (thr_cell >= 0.0) & (d2_cell <= thr_cell * thr_cell)

        @pl.when(jnp.any(live))
        def _slot_tests():
            c0 = c0_ref[...][0]                       # (cap,)
            c1 = c1_ref[...][0]
            reach = reach_ref[...][0]
            sx = q0[:, None] - c0[None, :]
            sy = q1[:, None] - c1[None, :]
            d2 = sx * sx + sy * sy
            thr = r[:, None] + reach[None, :]
            hit = ((thr >= 0.0) & (d2 <= thr * thr)).astype(jnp.int32)
            # merge this cell's verdicts into the probe-ok scratch: probe
            # slots whose flat slot index lives in THIS cell take their
            # verdict from the (bQ, cap) hit tile. Each probe belongs to
            # exactly one cell, so clamped duplicate programs (j >= n_cells
            # re-runs cell n_cells-1) rewrite identical values.
            jc = jnp.minimum(j, n_cells - 1)
            sidx = sidx_ref[...]                      # (bQ, np) flat indices
            in_cell = (sidx // cap) == jc
            got = jnp.take_along_axis(hit, sidx % cap, axis=1)
            pok_s[...] = jnp.where(in_cell, got, pok_s[...])

        # placeholder writes: every output block this program maps to gets
        # a defined value; phases 1-2 overwrite them all with finals
        counts_ref[...] = jnp.zeros(counts_ref.shape, counts_ref.dtype)
        dist_ref[...] = jnp.full(dist_ref.shape, bad_value, jnp.float32)
        cand_ref[...] = jnp.zeros(cand_ref.shape, cand_ref.dtype)
        cdist_ref[...] = jnp.full(cdist_ref.shape, bad_value, jnp.float32)
        pok_ref[...] = jnp.zeros(pok_ref.shape, pok_ref.dtype)

    @pl.when(t != 0)
    def _scan_phases():
        jp = jnp.minimum(j, npmax - 1)
        probe = jp // npb
        # in-register probe mask from the phase-0 scratch; probe 0 is
        # backstopped exactly like _rt_probe_mask's `.at[:, 0].set(True)`
        keep_q = (pok_s[...][:, probe] > 0) | (probe == 0)
        codes = codes_ref[...].astype(jnp.int32)      # (bQ, bP, S)
        valid = valid_ref[...] & keep_q[:, None]
        cnt = count_dot(codes, table_ref[...][:, 0], n_entries=n_entries,
                        acc=acc)
        bad_count = _NEG
        if p_pad != p_real:        # point axis padded: mark pad slots
            lane = jp * bp + jax.lax.broadcasted_iota(
                jnp.int32, (codes.shape[0], bp), 1)
            bad_count = jnp.where(lane % p_pad < p_real, _NEG, _PAD)
        counts = jnp.where(valid, cnt, bad_count)
        counts_ref[...] = counts
        iot = jax.lax.broadcasted_iota(jnp.int32, pok_ref.shape, 1)
        pok_ref[...] = ((pok_s[...] > 0) | (iot == 0)).astype(jnp.int8)

        @pl.when(t == 1)
        def _stage1():
            @pl.when(j == 0)
            def _init():
                topv_ref[...] = jnp.full_like(topv_ref, _INIT)
                topi_ref[...] = jnp.zeros_like(topi_ref)

            # streamed top-C merge, fenced to REAL point blocks: clamped
            # duplicate programs (j >= npmax when the cell axis is longer)
            # must not re-merge block npmax-1 or its entries would repeat
            # in the running selection
            @pl.when(j < npmax)
            def _merge():
                newi = jp * bp + jax.lax.broadcasted_iota(
                    jnp.int32, counts.shape, 1)
                runv = jnp.concatenate([topv_ref[...], counts], axis=1)
                runi = jnp.concatenate([topi_ref[...], newi], axis=1)
                v, pos = jax.lax.top_k(runv, cap_c)
                topv_ref[...] = v
                topi_ref[...] = jnp.take_along_axis(runi, pos, axis=1)
            cand_ref[...] = topi_ref[...]
            cdist_ref[...] = jnp.full_like(cdist_ref, bad_value)
            dist_ref[...] = jnp.full(counts.shape, bad_value, jnp.float32)

        @pl.when(t == 2)
        def _stage2():
            theta = topv_ref[...][:, cap_c - 1]       # (bQ,) survivor floor
            keep = valid & (counts >= theta[:, None])
            cand_ref[...] = topi_ref[...]
            any_keep = jnp.any(keep)

            @pl.when(any_keep)
            def _adc():
                lut = lut_ref[...][:, 0]              # (bQ, S, E) f32
                adc = slab_onehot_dot(codes, lut, n_entries=n_entries,
                                      out_dtype=jnp.float32, slab=SLAB)
                dist = jnp.where(keep, adc, bad_value)
                dist_ref[...] = dist
                # compaction fold: this block's slice of the candidates
                local = topi_ref[...] - jp * bp       # (bQ, C)
                inblk = (local >= 0) & (local < bp)
                got = jnp.take_along_axis(dist, jnp.clip(local, 0, bp - 1),
                                          axis=1)
                cdist_ref[...] = jnp.where(inblk, got, cdist_ref[...])

            @pl.when(jnp.logical_not(any_keep))
            def _skip():
                dist_ref[...] = jnp.full(counts.shape, bad_value,
                                         jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("cap_c", "metric", "bq", "bp", "acc",
                                    "interpret"))
def fused_three_stage(lut: jnp.ndarray, table: jnp.ndarray,
                      codes: jnp.ndarray, valid: jnp.ndarray,
                      q0: jnp.ndarray, q1: jnp.ndarray, radius: jnp.ndarray,
                      boxes: jnp.ndarray, cell_reach: jnp.ndarray,
                      cell_c0: jnp.ndarray, cell_c1: jnp.ndarray,
                      slot_reach: jnp.ndarray, slot_idx: jnp.ndarray, *,
                      cap_c: int, metric: str = "l2", bq: int = DEFAULT_BQ,
                      bp: int | None = None, acc: str = "f32",
                      interpret: bool = False):
    """lut/table (Q, np, S, E) f32/int8, codes (Q, np, P, S) uint8,
    valid (Q, np, P) bool; q0/q1/radius (Q,) f32 ray-plane queries;
    boxes (n_cells, 4), cell_reach (n_cells,), cell_c0/cell_c1/slot_reach
    (n_cells, cap) — the ``CentroidGrid`` layout; slot_idx (Q, np) int32 =
    ``grid.slot_of[probed cluster ids]`` →
    (counts (Q, np, P) i32, dist (Q, np, P) f32, cand (Q, C) i32,
     cand_dist (Q, C) f32, probe_ok (Q, np) bool). See module docstring.
    ``bq``/``bp``/``acc`` are the autotuner's knobs — all
    result-invariant."""
    q, n_probe, p, s = codes.shape
    e = lut.shape[-1]
    n_cells, cap = cell_c0.shape
    cap_c = max(1, min(cap_c, n_probe * p))
    bp = _largest_divisor(p, bp or DEFAULT_BP)
    if bp < min(64, p):
        # divisor cliff: pad the point axis per probe to a full tile; pad
        # slots carry the below-_NEG _PAD sentinel (see fused_two_stage)
        bp = DEFAULT_BP
        pad_p = (-p) % bp
        codes = jnp.pad(codes, ((0, 0), (0, 0), (0, pad_p), (0, 0)))
        valid = jnp.pad(valid, ((0, 0), (0, 0), (0, pad_p)))
    p_pad = codes.shape[2]
    w = n_probe * p_pad
    bq = min(bq, q)
    pad_q = (-q) % bq
    if pad_q:
        lut = jnp.pad(lut, ((0, pad_q), (0, 0), (0, 0), (0, 0)))
        table = jnp.pad(table, ((0, pad_q), (0, 0), (0, 0), (0, 0)))
        codes = jnp.pad(codes, ((0, pad_q), (0, 0), (0, 0), (0, 0)))
        valid = jnp.pad(valid, ((0, pad_q), (0, 0), (0, 0)))
        q0 = jnp.pad(q0, (0, pad_q))
        q1 = jnp.pad(q1, (0, pad_q))
        radius = jnp.pad(radius, (0, pad_q))
        slot_idx = jnp.pad(slot_idx, ((0, pad_q), (0, 0)))
    qp = q + pad_q
    codes_f = codes.reshape(qp, w, s)
    valid_f = valid.reshape(qp, w)
    npb = p_pad // bp                 # point blocks per probe
    npmax = n_probe * npb
    jdim = max(n_cells, npmax)        # shared cell/point-block grid axis
    bad = float("inf") if metric == "l2" else float("-inf")
    jc = lambda j: jnp.minimum(j, n_cells - 1)          # noqa: E731
    jp = lambda j: jnp.minimum(j, npmax - 1)            # noqa: E731

    counts, dist, cand, cdist, pok = pl.pallas_call(
        functools.partial(_fused3_kernel, n_entries=e, cap_c=cap_c, bp=bp,
                          p_real=p, p_pad=p_pad, bad_value=bad, npb=npb,
                          n_cells=n_cells, cap=cap, acc=acc),
        grid=(qp // bq, 3, jdim),
        in_specs=[
            pl.BlockSpec((bq,), lambda i, t, j: (i,)),
            pl.BlockSpec((bq,), lambda i, t, j: (i,)),
            pl.BlockSpec((bq,), lambda i, t, j: (i,)),
            pl.BlockSpec((1, 4), lambda i, t, j: (jc(j), 0)),
            pl.BlockSpec((1,), lambda i, t, j: (jc(j),)),
            pl.BlockSpec((1, cap), lambda i, t, j: (jc(j), 0)),
            pl.BlockSpec((1, cap), lambda i, t, j: (jc(j), 0)),
            pl.BlockSpec((1, cap), lambda i, t, j: (jc(j), 0)),
            pl.BlockSpec((bq, n_probe), lambda i, t, j: (i, 0)),
            pl.BlockSpec((bq, 1, s, e), lambda i, t, j: (i, jp(j) // npb,
                                                         0, 0)),
            pl.BlockSpec((bq, 1, s, e), lambda i, t, j: (i, jp(j) // npb,
                                                         0, 0)),
            pl.BlockSpec((bq, bp, s), lambda i, t, j: (i, jp(j), 0)),
            pl.BlockSpec((bq, bp), lambda i, t, j: (i, jp(j))),
        ],
        out_specs=[
            pl.BlockSpec((bq, bp), lambda i, t, j: (i, jp(j))),
            pl.BlockSpec((bq, bp), lambda i, t, j: (i, jp(j))),
            pl.BlockSpec((bq, cap_c), lambda i, t, j: (i, 0)),
            pl.BlockSpec((bq, cap_c), lambda i, t, j: (i, 0)),
            pl.BlockSpec((bq, n_probe), lambda i, t, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qp, w), jnp.int32),
            jax.ShapeDtypeStruct((qp, w), jnp.float32),
            jax.ShapeDtypeStruct((qp, cap_c), jnp.int32),
            jax.ShapeDtypeStruct((qp, cap_c), jnp.float32),
            jax.ShapeDtypeStruct((qp, n_probe), jnp.int8),
        ],
        scratch_shapes=[pltpu.VMEM((bq, n_probe), jnp.int32),
                        pltpu.VMEM((bq, cap_c), jnp.int32),
                        pltpu.VMEM((bq, cap_c), jnp.int32)],
        interpret=interpret,
    )(q0, q1, radius, boxes, cell_reach, cell_c0, cell_c1, slot_reach,
      slot_idx, lut, table, codes_f, valid_f)
    counts = counts[:q].reshape(q, n_probe, p_pad)[:, :, :p]
    dist = dist[:q].reshape(q, n_probe, p_pad)[:, :, :p]
    cand, cdist = cand[:q], cdist[:q]
    if p_pad != p:
        # remap candidate indices from the padded to the real flat layout
        cand = (cand // p_pad) * p + cand % p_pad
    return counts, dist, cand, cdist, pok[:q].astype(jnp.bool_)


@functools.partial(jax.jit, static_argnames=("cap_c", "metric", "topc_impl"))
def fused_three_stage_host(lut: jnp.ndarray, table: jnp.ndarray,
                           codes: jnp.ndarray, valid: jnp.ndarray,
                           q0: jnp.ndarray, q1: jnp.ndarray,
                           radius: jnp.ndarray, cell_c0: jnp.ndarray,
                           cell_c1: jnp.ndarray, slot_reach: jnp.ndarray,
                           slot_idx: jnp.ndarray, *, cap_c: int,
                           metric: str = "l2", topc_impl: str = "sort"):
    """Schedule-equivalent host path for off-TPU serving: the dense sphere
    test (``rt_sphere_hits_ref`` — no cell skip needed at host scale)
    gathered at ``slot_idx`` plays phase 0, masks ``valid``, and the result
    flows through ``fused_two_stage_host`` (same contract/deviations as
    documented there; ``topc_impl`` is its autotuner θ-selection knob).
    Returns the kernel's 5-tuple."""
    hits = rt_sphere_hits_ref(q0, q1, radius, cell_c0, cell_c1, slot_reach)
    pok = jnp.take_along_axis(hits, slot_idx, axis=1) > 0
    pok = pok.at[:, 0].set(True)
    valid = valid & pok[:, :, None]
    counts, dist, cand, cdist = fused_two_stage_host(
        lut, table, codes, valid, cap_c=cap_c, metric=metric,
        topc_impl=topc_impl)
    return counts, dist, cand, cdist, pok

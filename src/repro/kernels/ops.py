"""Jit'd public wrappers over the Pallas kernels.

On CPU (this container) kernels execute with ``interpret=True`` — the kernel
body runs in Python over real blocks, validating BlockSpec tiling and
semantics. On TPU they compile natively. ``use_pallas()`` picks the backend.

Also home of :func:`slab_onehot_dot`, the SLAB-wise one-hot ``dot_general``
shared by the kernel bodies (hit_count / pq_scan / fused_two_stage).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

DEFAULT_SLAB = 8


def slab_onehot_dot(codes: jnp.ndarray, tab: jnp.ndarray, *, n_entries: int,
                    out_dtype=jnp.float32,
                    slab: int = DEFAULT_SLAB) -> jnp.ndarray:
    """``out[..., p] = sum_s tab[..., s, codes[..., p, s]]`` on the MXU.

    codes (..., bP, S) int, tab (..., S, E) → (..., bP) in ``out_dtype``.

    The per-(point, subspace) LUT gather is expressed as a one-hot
    contraction — ``one_hot(codes_slab) (..., bP, sl·E) · tab_slab
    (..., sl·E, 1)`` — the TPU analogue of the paper's Tensor-core
    "A × B(=ones)" accumulation trick (§5.3): quantized codes choose MXU
    operand rows instead of driving scalar lookups. The one-hot is formed
    ``slab`` subspaces at a time to bound VMEM (≈ prod(lead)·bP·slab·E·
    itemsize per slab). Accumulation dtype is pinned by ``out_dtype`` via
    ``preferred_element_type``: int32 for the int8 hit path, f32 for the ADC
    path (tests/test_kernels.py pins both).

    Shared by the kernel bodies of ``hit_count`` (int32), ``pq_scan`` (f32)
    and ``fused_two_stage`` (f32, batched) — callable both inside Pallas
    kernels and as plain jnp.
    """
    n_sub = codes.shape[-1]
    *lead, bp, _ = codes.shape
    nb = len(lead)
    dnums = (((nb + 1,), (nb,)), (tuple(range(nb)), tuple(range(nb))))
    acc = jnp.zeros((*lead, bp), out_dtype)
    for s0 in range(0, n_sub, slab):
        sl = min(slab, n_sub - s0)
        oh = jax.nn.one_hot(codes[..., s0:s0 + sl], n_entries,
                            dtype=out_dtype)          # (..., bP, sl, E)
        acc = acc + jax.lax.dot_general(
            oh.reshape(*lead, bp, sl * n_entries),
            tab[..., s0:s0 + sl, :].reshape(*lead, sl * n_entries, 1),
            dnums, preferred_element_type=out_dtype)[..., 0]
    return acc


# NOTE: these imports sit BELOW slab_onehot_dot on purpose — the kernel
# modules import it from here at module load, so it must already be bound
# when a kernel module (imported by this block) re-enters the partially
# initialised ``ops``.
from . import fused_three_stage as _fused3  # noqa: E402
from . import fused_two_stage as _fused  # noqa: E402
from . import hit_count as _hit  # noqa: E402
from . import ivf_filter as _filt  # noqa: E402
from . import pq_scan as _scan  # noqa: E402
from . import selective_lut as _lut  # noqa: E402
from repro.rt import intersect as _rt  # noqa: E402


@functools.cache
def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not _on_tpu()


def build_selective_lut(qsub: jnp.ndarray, entries: jnp.ndarray,
                        entry_sq: jnp.ndarray, tau: jnp.ndarray, *,
                        metric: str = "l2"):
    """qsub (..., S, 2) f32, entries (S, E, 2), entry_sq (S, E), tau (..., S).
    Returns (masked_lut (..., S, E) f32, hit_table (..., S, E) int8).
    Leading dims are flattened into the kernel's batch axis."""
    lead = qsub.shape[:-2]
    s = qsub.shape[-2]
    b = 1
    for d in lead:
        b *= d
    q0 = qsub[..., 0].reshape(b, s)
    q1 = qsub[..., 1].reshape(b, s)
    # pad batch to the block size
    bb = _lut.DEFAULT_BB
    pad_b = (-b) % bb
    if pad_b:
        q0 = jnp.pad(q0, ((0, pad_b), (0, 0)))
        q1 = jnp.pad(q1, ((0, pad_b), (0, 0)))
    tau2 = tau.reshape(b, s)
    if pad_b:
        tau2 = jnp.pad(tau2, ((0, pad_b), (0, 0)))
    bs = _lut.DEFAULT_BS
    while s % bs:
        bs //= 2
    lut, hit = _lut.selective_lut(q0, q1, entries[..., 0], entries[..., 1],
                                  entry_sq, tau2, metric=metric, bs=bs,
                                  interpret=_interpret())
    e = entries.shape[1]
    lut = lut[:b].reshape(*lead, s, e)
    hit = hit[:b].reshape(*lead, s, e)
    if metric == "ip":
        # The kernel substitutes pruned entries with -tau^2/2 (the exact
        # floor needs a row reduction over kept entries, which would cost a
        # second kernel pass). Recover the reference semantics here with one
        # cheap vectorized pass so impl="pallas" and impl="ref" rank
        # identically.
        from repro.core.lut import ip_pruned_fill
        lut = ip_pruned_fill(lut, hit >= 0)
    return lut, hit


def masked_adc_scan(lut: jnp.ndarray, codes: jnp.ndarray, valid: jnp.ndarray,
                    *, metric: str = "l2") -> jnp.ndarray:
    """lut (..., S, E), codes (..., P, S), valid (..., P) → (..., P) f32."""
    lead = codes.shape[:-2]
    if not lead:
        return _scan.pq_scan(lut, codes, valid, metric=metric,
                             interpret=_interpret())
    fn = functools.partial(_scan.pq_scan, metric=metric,
                           interpret=_interpret())
    for _ in lead:
        fn = jax.vmap(fn)
    return fn(lut, codes, valid)


def hit_count_scan(table: jnp.ndarray, codes: jnp.ndarray,
                   valid: jnp.ndarray) -> jnp.ndarray:
    """table (..., S, E) int8, codes (..., P, S), valid (..., P) → int32."""
    lead = codes.shape[:-2]
    if not lead:
        return _hit.hit_count(table, codes, valid, interpret=_interpret())
    fn = functools.partial(_hit.hit_count, interpret=_interpret())
    for _ in lead:
        fn = jax.vmap(fn)
    return fn(table, codes, valid)


def fused_two_stage_scan(mlut: jnp.ndarray, table: jnp.ndarray,
                         codes: jnp.ndarray, valid: jnp.ndarray, *,
                         cap_c: int, metric: str = "l2"):
    """Fused two-stage scan: hit-count prefilter → in-kernel survivor
    threshold → masked ADC + top-candidate compaction, in one pass.

    mlut/table (Q, np, S, E), codes (Q, np, P, S), valid (Q, np, P) →
    (counts (Q, np, P) i32, dist (Q, np, P) f32, cand (Q, C) i32,
     cand_dist (Q, C) f32); ``cand`` is the top-cap_c-by-count candidate
    set, ``cand_dist`` their masked-LUT totals — the two-stage search
    consumes these directly, with no wide top-k and no second scan.

    On TPU this is the fused Pallas kernel (one VMEM residency per tile,
    RT→TC-pipeline analogue). Off-TPU it dispatches to the
    schedule-equivalent host path rather than interpret mode: a 2-trip
    grid under the interpreter would serialize the serving hot path, and
    the host path's histogram selection is the same survivor-threshold
    idea expressed CPU-natively. The interpret-mode kernel is validated
    against the composed kernels by tests/test_fused_kernel.py.

    The result-invariant tile/θ-selection knobs come from the process
    active :class:`repro.kernels.autotune.KernelConfig` (read at trace
    time — install tuned configs before the first dispatch).
    """
    from . import autotune
    cfg = autotune.active_config("fused_two_stage")
    if _on_tpu():
        return _fused.fused_two_stage(mlut, table, codes, valid,
                                      cap_c=cap_c, metric=metric,
                                      bq=cfg.bq, bp=cfg.bp,
                                      acc=cfg.acc_dtype)
    return _fused.fused_two_stage_host(mlut, table, codes, valid,
                                       cap_c=cap_c, metric=metric,
                                       topc_impl=cfg.topc_impl)


def fused_three_stage_scan(mlut: jnp.ndarray, table: jnp.ndarray,
                           codes: jnp.ndarray, valid: jnp.ndarray,
                           q0: jnp.ndarray, q1: jnp.ndarray,
                           radius: jnp.ndarray, boxes: jnp.ndarray,
                           cell_reach: jnp.ndarray, cell_c0: jnp.ndarray,
                           cell_c1: jnp.ndarray, slot_reach: jnp.ndarray,
                           slot_idx: jnp.ndarray, *, cap_c: int,
                           metric: str = "l2"):
    """Single-residency three-stage scan: RT sphere test → hit-count
    prefilter → masked ADC + top-candidate compaction, in one pass.

    The :func:`fused_two_stage_scan` contract with the RT probe filter
    folded in as stage 0: ``q0``/``q1``/``radius`` are the ray-plane
    queries, ``boxes``/``cell_reach``/``cell_c0``/``cell_c1``/
    ``slot_reach`` the ``CentroidGrid`` layout, and ``slot_idx`` (Q, np)
    int32 the probed clusters' flat slot indices
    (``grid.slot_of[cids]``). Returns the two-stage 4-tuple plus
    ``probe_ok`` (Q, np) bool — identical to the host-side
    ``_rt_probe_mask`` gather (probe 0 always True), so downstream
    side-buffer scoring applies the same verdict the kernel applied to
    in-cluster points.

    Dispatch/knob rules are those of :func:`fused_two_stage_scan`: the
    Pallas kernel on TPU, the schedule-equivalent host path off-TPU, with
    the active ``autotune`` config (``fused_three_stage`` entry) applied
    at trace time. Bit-identical to composing :func:`rt_sphere_hits` →
    probe-mask gather → :func:`fused_two_stage_scan`
    (tests/test_fused3_kernel.py).
    """
    from . import autotune
    cfg = autotune.active_config("fused_three_stage")
    if _on_tpu():
        return _fused3.fused_three_stage(
            mlut, table, codes, valid, q0, q1, radius, boxes, cell_reach,
            cell_c0, cell_c1, slot_reach, slot_idx, cap_c=cap_c,
            metric=metric, bq=cfg.bq, bp=cfg.bp, acc=cfg.acc_dtype)
    return _fused3.fused_three_stage_host(
        mlut, table, codes, valid, q0, q1, radius, cell_c0, cell_c1,
        slot_reach, slot_idx, cap_c=cap_c, metric=metric,
        topc_impl=cfg.topc_impl)


def rt_sphere_hits(q0: jnp.ndarray, q1: jnp.ndarray, radius: jnp.ndarray,
                   boxes: jnp.ndarray, cell_reach: jnp.ndarray,
                   c0: jnp.ndarray, c1: jnp.ndarray,
                   slot_reach: jnp.ndarray) -> jnp.ndarray:
    """RT-core-style sphere-intersection filter (stage-1 spatial pruning).

    Parameters
    ----------
    q0, q1, radius : jnp.ndarray
        (Q,) f32 ray-plane query coordinates and query-sphere radii.
    boxes : jnp.ndarray
        (n_cells, 4) f32 per-cell AABBs (kernel path's cell-skip input).
    cell_reach : jnp.ndarray
        (n_cells,) f32 per-cell max reach (``-inf`` = empty cell).
    c0, c1, slot_reach : jnp.ndarray
        (n_cells, cap) f32 projected centroid planes and per-slot reaches
        (``-inf`` = pad slot).

    Returns
    -------
    jnp.ndarray
        (Q, n_cells·cap) int8 flat hit table, cell-major.

    Notes
    -----
    On TPU this runs the cell-walk Pallas kernel (``rt.intersect``); the
    AABB pre-test skips a cell's disc tests when no query disc touches
    it. Off-TPU it dispatches to the dense host path rather than
    interpret mode — same dispatch rule (and rationale) as
    :func:`fused_two_stage_scan`; results are identical either way
    because the cell skip is conservative.
    """
    if _on_tpu():
        return _rt.sphere_hits(q0, q1, radius, boxes, cell_reach,
                               c0, c1, slot_reach)
    return _rt.sphere_hits_host(q0, q1, radius, c0, c1, slot_reach)


def filter_scores(queries, centroids, centroid_sq, *, metric="l2"):
    """Fused IVF filtering distance matrix (paper stage A on the MXU)."""
    return _filt.ivf_filter(queries, centroids, centroid_sq, metric=metric,
                            interpret=_interpret())

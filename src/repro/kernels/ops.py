"""Jit'd public wrappers over the Pallas kernels.

On CPU (this container) kernels execute with ``interpret=True`` — the kernel
body runs in Python over real blocks, validating BlockSpec tiling and
semantics. On TPU they compile natively. ``use_pallas()`` picks the backend.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import hit_count as _hit
from . import ivf_filter as _filt
from . import pq_scan as _scan
from . import selective_lut as _lut


@functools.cache
def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not _on_tpu()


def build_selective_lut(qsub: jnp.ndarray, entries: jnp.ndarray,
                        entry_sq: jnp.ndarray, tau: jnp.ndarray, *,
                        metric: str = "l2"):
    """qsub (..., S, 2) f32, entries (S, E, 2), entry_sq (S, E), tau (..., S).
    Returns (masked_lut (..., S, E) f32, hit_table (..., S, E) int8).
    Leading dims are flattened into the kernel's batch axis."""
    lead = qsub.shape[:-2]
    s = qsub.shape[-2]
    b = 1
    for d in lead:
        b *= d
    q0 = qsub[..., 0].reshape(b, s)
    q1 = qsub[..., 1].reshape(b, s)
    # pad batch to the block size
    bb = _lut.DEFAULT_BB
    pad_b = (-b) % bb
    if pad_b:
        q0 = jnp.pad(q0, ((0, pad_b), (0, 0)))
        q1 = jnp.pad(q1, ((0, pad_b), (0, 0)))
    tau2 = tau.reshape(b, s)
    if pad_b:
        tau2 = jnp.pad(tau2, ((0, pad_b), (0, 0)))
    bs = _lut.DEFAULT_BS
    while s % bs:
        bs //= 2
    lut, hit = _lut.selective_lut(q0, q1, entries[..., 0], entries[..., 1],
                                  entry_sq, tau2, metric=metric, bs=bs,
                                  interpret=_interpret())
    e = entries.shape[1]
    lut = lut[:b].reshape(*lead, s, e)
    hit = hit[:b].reshape(*lead, s, e)
    if metric == "ip":
        # The kernel substitutes pruned entries with -tau^2/2 (the exact
        # floor needs a row reduction over kept entries, which would cost a
        # second kernel pass). Recover the reference semantics here with one
        # cheap vectorized pass so impl="pallas" and impl="ref" rank
        # identically.
        from repro.core.lut import ip_pruned_fill
        lut = ip_pruned_fill(lut, hit >= 0)
    return lut, hit


def masked_adc_scan(lut: jnp.ndarray, codes: jnp.ndarray, valid: jnp.ndarray,
                    *, metric: str = "l2") -> jnp.ndarray:
    """lut (..., S, E), codes (..., P, S), valid (..., P) → (..., P) f32."""
    lead = codes.shape[:-2]
    if not lead:
        return _scan.pq_scan(lut, codes, valid, metric=metric,
                             interpret=_interpret())
    fn = functools.partial(_scan.pq_scan, metric=metric,
                           interpret=_interpret())
    for _ in lead:
        fn = jax.vmap(fn)
    return fn(lut, codes, valid)


def hit_count_scan(table: jnp.ndarray, codes: jnp.ndarray,
                   valid: jnp.ndarray) -> jnp.ndarray:
    """table (..., S, E) int8, codes (..., P, S), valid (..., P) → int32."""
    lead = codes.shape[:-2]
    if not lead:
        return _hit.hit_count(table, codes, valid, interpret=_interpret())
    fn = functools.partial(_hit.hit_count, interpret=_interpret())
    for _ in lead:
        fn = jax.vmap(fn)
    return fn(table, codes, valid)


def filter_scores(queries, centroids, centroid_sq, *, metric="l2"):
    """Fused IVF filtering distance matrix (paper stage A on the MXU)."""
    return _filt.ivf_filter(queries, centroids, centroid_sq, metric=metric,
                            interpret=_interpret())

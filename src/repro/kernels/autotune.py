"""Measured autotune pass for the fused Pallas kernels (ROADMAP item 2).

The fused kernels expose result-invariant knobs — tile shapes ``bq``/``bp``
and the hit-count accumulation dtype ``acc`` on the TPU path, the θ-selection
strategy ``topc_impl`` on the host path (see ``fused_two_stage`` /
``fused_three_stage``: every option produces bit-identical outputs, pinned
by tests/test_autotune.py). This module picks between them by measurement:

* ``tune(kernel)`` times each candidate :class:`KernelConfig` on a small
  synthetic problem (median of ``repeats`` wall-clock runs, compiled call
  only) and returns the winner. Candidates are deduplicated down to the
  knobs that are *effective* on the current backend (off-TPU only
  ``topc_impl`` reaches the dispatched host path, on TPU only
  ``bq``/``bp``/``acc_dtype`` do), and ties break deterministically toward
  the earlier candidate in the canonical enumeration order — repeated
  tuning under timing jitter cannot oscillate between equivalent configs.
* ``save_cache``/``load_cache`` persist winners per backend as JSON keyed
  by ``(schema, backend)``. Loading FAILS CLOSED: a corrupt file, a schema
  bump, another backend's cache, or out-of-domain field values all return
  ``None`` (caller retunes) — a stale cache is never silently applied.
* ``set_config``/``active_config`` hold the process-global active configs
  that ``kernels.ops`` dispatchers consult. Configs are read at TRACE
  time: install them (``ensure_tuned`` or ``set_config``) before the first
  search dispatch — changing them later does not retrace already-compiled
  signatures (by the same token, tuning can never widen an engine's jit
  signature lattice; pinned in tests/test_recall_matrix.py).

Every knob is benign under mis-selection — a wrong cache entry could only
ever cost speed, but the fail-closed load refuses even that.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import os
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from .fused_two_stage import ACC_DTYPES

SCHEMA_VERSION = 1

#: kernels this pass knows how to tune (and the ops dispatchers consult)
KERNELS = ("fused_two_stage", "fused_three_stage")

TOPC_IMPLS = ("sort", "topk")

# canonical candidate axes — enumeration order is the deterministic
# tie-break order, so keep these stable across releases
_BQ = (2, 4, 8)
_BP = (64, 128, 256)


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """One point in the tuning space; defaults reproduce the untuned path.

    ``bq``/``bp``/``acc_dtype`` steer the Pallas kernel (TPU), ``topc_impl``
    the host path — all four are result-invariant by construction.
    """

    bq: int = 4
    bp: int | None = None
    topc_impl: str = "sort"
    acc_dtype: str = "f32"

    def validate(self) -> bool:
        """True iff every field is in the domain the kernels accept."""
        return (isinstance(self.bq, int) and not isinstance(self.bq, bool)
                and self.bq >= 1
                and (self.bp is None
                     or (isinstance(self.bp, int)
                         and not isinstance(self.bp, bool) and self.bp >= 1))
                and self.topc_impl in TOPC_IMPLS
                and self.acc_dtype in ACC_DTYPES)


_active: dict[str, KernelConfig] = {}


def active_config(kernel: str) -> KernelConfig:
    """Config the ops dispatchers apply for ``kernel`` (default if unset)."""
    return _active.get(kernel, KernelConfig())


def set_config(kernel: str, config: KernelConfig) -> None:
    """Install ``config`` as the process-global active config for ``kernel``.

    Takes effect for signatures traced AFTER this call (see module
    docstring) — install before the first search dispatch.
    """
    if kernel not in KERNELS:
        raise ValueError(f"unknown kernel {kernel!r}; expected one of "
                         f"{KERNELS}")
    if not config.validate():
        raise ValueError(f"invalid config for {kernel!r}: {config}")
    _active[kernel] = config


def reset() -> None:
    """Drop all active configs (every kernel back to defaults)."""
    _active.clear()


def backend_name() -> str:
    """The backend string cache entries are keyed on."""
    return jax.default_backend()


def _effective_key(config: KernelConfig, backend: str):
    """The knob subset that can reach the dispatched path on ``backend``."""
    if backend == "tpu":
        return (config.bq, config.bp, config.acc_dtype)
    return (config.topc_impl,)


def candidates(backend: str | None = None) -> list[KernelConfig]:
    """Canonically-ordered candidate configs, deduplicated per backend.

    Two configs differing only in knobs the ``backend`` cannot exercise
    would measure identically; only the first (canonical order) survives.
    """
    backend = backend or backend_name()
    out, seen = [], set()
    for bq, bp, topc, acc in itertools.product(_BQ, (None,) + _BP,
                                               TOPC_IMPLS, ACC_DTYPES):
        cfg = KernelConfig(bq=bq, bp=bp, topc_impl=topc, acc_dtype=acc)
        key = _effective_key(cfg, backend)
        if key not in seen:
            seen.add(key)
            out.append(cfg)
    return out


def _two_stage_problem(seed: int = 0):
    """Small synthetic (lut, table, codes, valid, cap_c) tuning workload."""
    rng = np.random.default_rng(seed)
    q, n_probe, p, s, e = 8, 4, 64, 8, 16
    lut = jnp.asarray(rng.normal(size=(q, n_probe, s, e)), jnp.float32)
    table = jnp.asarray(rng.integers(-1, 2, size=(q, n_probe, s, e)),
                        jnp.int8)
    codes = jnp.asarray(rng.integers(0, e, size=(q, n_probe, p, s)),
                        jnp.uint8)
    valid = jnp.asarray(rng.random(size=(q, n_probe, p)) < 0.9)
    return lut, table, codes, valid, 32


def _three_stage_problem(seed: int = 0):
    """The two-stage workload plus a tiny synthetic RT grid."""
    lut, table, codes, valid, cap_c = _two_stage_problem(seed)
    rng = np.random.default_rng(seed + 1)
    q, n_probe = codes.shape[:2]
    n_cells, cap = 9, 8
    q0 = jnp.asarray(rng.normal(size=(q,)), jnp.float32)
    q1 = jnp.asarray(rng.normal(size=(q,)), jnp.float32)
    radius = jnp.asarray(rng.random(size=(q,)), jnp.float32)
    boxes = jnp.asarray(
        np.stack([rng.normal(size=n_cells) - 2.0,
                  rng.normal(size=n_cells) - 2.0,
                  rng.normal(size=n_cells) + 2.0,
                  rng.normal(size=n_cells) + 2.0], axis=1), jnp.float32)
    c0 = jnp.asarray(rng.normal(size=(n_cells, cap)), jnp.float32)
    c1 = jnp.asarray(rng.normal(size=(n_cells, cap)), jnp.float32)
    reach = jnp.asarray(np.abs(rng.normal(size=(n_cells, cap))), jnp.float32)
    cell_reach = reach.max(axis=1)
    slot_idx = jnp.asarray(
        rng.integers(0, n_cells * cap, size=(q, n_probe)), jnp.int32)
    return (lut, table, codes, valid, q0, q1, radius, boxes, cell_reach,
            c0, c1, reach, slot_idx, cap_c)


def _run_fn(kernel: str, config: KernelConfig, problem):
    """A zero-arg callable running ``kernel`` with ``config`` applied."""
    from . import fused_three_stage as _f3
    from . import fused_two_stage as _f2
    on_tpu = backend_name() == "tpu"
    if kernel == "fused_two_stage":
        lut, table, codes, valid, cap_c = problem
        if on_tpu:
            return lambda: _f2.fused_two_stage(
                lut, table, codes, valid, cap_c=cap_c, bq=config.bq,
                bp=config.bp, acc=config.acc_dtype)
        return lambda: _f2.fused_two_stage_host(
            lut, table, codes, valid, cap_c=cap_c,
            topc_impl=config.topc_impl)
    if kernel == "fused_three_stage":
        (lut, table, codes, valid, q0, q1, radius, boxes, cell_reach,
         c0, c1, reach, slot_idx, cap_c) = problem
        if on_tpu:
            return lambda: _f3.fused_three_stage(
                lut, table, codes, valid, q0, q1, radius, boxes, cell_reach,
                c0, c1, reach, slot_idx, cap_c=cap_c, bq=config.bq,
                bp=config.bp, acc=config.acc_dtype)
        return lambda: _f3.fused_three_stage_host(
            lut, table, codes, valid, q0, q1, radius, c0, c1, reach,
            slot_idx, cap_c=cap_c, topc_impl=config.topc_impl)
    raise ValueError(f"unknown kernel {kernel!r}")


def _block(out):
    """Block until every array in a pytree of outputs is ready."""
    for leaf in jax.tree_util.tree_leaves(out):
        leaf.block_until_ready()


def tune(kernel: str, *, repeats: int = 5, problem=None) -> KernelConfig:
    """Measure every effective candidate for ``kernel``; return the winner.

    One warmup call per candidate absorbs compilation, then ``repeats``
    timed runs; the score is the median. Winner = min (median, canonical
    index) — the index tie-break keeps re-tuning deterministic when two
    configs measure identically.
    """
    if kernel not in KERNELS:
        raise ValueError(f"unknown kernel {kernel!r}; expected one of "
                         f"{KERNELS}")
    if problem is None:
        problem = (_two_stage_problem() if kernel == "fused_two_stage"
                   else _three_stage_problem())
    best = None
    for idx, cfg in enumerate(candidates()):
        fn = _run_fn(kernel, cfg, problem)
        _block(fn())                                  # compile + warm
        times = []
        for _ in range(max(1, repeats)):
            t0 = time.perf_counter()
            _block(fn())
            times.append(time.perf_counter() - t0)
        med = sorted(times)[len(times) // 2]
        if best is None or (med, idx) < best[:2]:
            best = (med, idx, cfg)
    return best[2]


def default_cache_path() -> Path:
    """Cache location: ``$REPRO_AUTOTUNE_CACHE`` or a per-user default."""
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return Path(env)
    return Path.home() / ".cache" / "repro" / "autotune.json"


def save_cache(configs: dict[str, KernelConfig], path: Path | str,
               *, backend: str | None = None) -> None:
    """Write ``configs`` as the JSON cache for ``backend`` (atomic-enough:
    deterministic serialization, parents created)."""
    for kernel, cfg in configs.items():
        if kernel not in KERNELS or not cfg.validate():
            raise ValueError(f"refusing to cache invalid entry "
                             f"{kernel!r}: {cfg}")
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    doc = {
        "schema": SCHEMA_VERSION,
        "backend": backend or backend_name(),
        "configs": {k: dataclasses.asdict(v)
                    for k, v in sorted(configs.items())},
    }
    path.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")


def load_cache(path: Path | str,
               *, backend: str | None = None) -> dict[str, KernelConfig] | None:
    """Load a cache written by :func:`save_cache` — FAIL CLOSED.

    Returns the config dict only when the file parses, the schema version
    matches, the backend matches, every kernel name is known, and every
    field validates. Anything else → ``None`` (caller retunes); a stale or
    foreign cache is never silently applied.
    """
    path = Path(path)
    try:
        doc = json.loads(path.read_text())
    except (OSError, ValueError):
        return None
    if not isinstance(doc, dict):
        return None
    if doc.get("schema") != SCHEMA_VERSION:
        return None
    if doc.get("backend") != (backend or backend_name()):
        return None
    raw = doc.get("configs")
    if not isinstance(raw, dict):
        return None
    out = {}
    for kernel, fields in raw.items():
        if kernel not in KERNELS or not isinstance(fields, dict):
            return None
        if set(fields) != {f.name for f in dataclasses.fields(KernelConfig)}:
            return None
        try:
            cfg = KernelConfig(**fields)
        except TypeError:
            return None
        if not cfg.validate():
            return None
        out[kernel] = cfg
    return out


def ensure_tuned(path: Path | str | None = None, *, repeats: int = 3,
                 kernels: tuple[str, ...] = KERNELS) -> dict[str, KernelConfig]:
    """Load cached winners (or tune and cache them) and install as active.

    The one-call orchestrator: cache hit → install, zero measurement; miss
    (absent/corrupt/stale/foreign — :func:`load_cache` fails closed) →
    retune every requested kernel, save, install. Call once at process
    start, BEFORE the first search dispatch (trace-time read, see module
    docstring).
    """
    path = Path(path) if path is not None else default_cache_path()
    configs = load_cache(path)
    if configs is None or any(k not in configs for k in kernels):
        configs = {k: tune(k, repeats=repeats) for k in kernels}
        save_cache(configs, path)
    for kernel in kernels:
        set_config(kernel, configs[kernel])
    return dict(configs)

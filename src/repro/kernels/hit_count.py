"""Pallas TPU kernel: hit-count scan (paper §5.4, JUNO-L/M).

score[p] = sum_s table[s, codes[p, s]]  with table in {+1, 0, -1} int8.

This is the aggressive approximation: the f32 LUT is never touched — an int8
reward/penalty table is contracted against one-hot codes with int32
accumulation (VPU/MXU int8 path), 4× denser than the exact scan. The TPU
stand-in for "count ray hits instead of computing distances".
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ops import slab_onehot_dot

DEFAULT_BP = 128
SLAB = 8

_NEG = -(2 ** 30)  # python int → baked literal (pallas rejects traced consts)


def _hit_kernel(table_ref, codes_ref, valid_ref, out_ref, *, n_sub,
                n_entries):
    codes = codes_ref[...].astype(jnp.int32)          # (bP, S)
    table = table_ref[...].astype(jnp.int32)          # (S, E)
    acc = slab_onehot_dot(codes, table, n_entries=n_entries,
                          out_dtype=jnp.int32, slab=SLAB)
    out_ref[...] = jnp.where(valid_ref[...], acc, _NEG)


@functools.partial(jax.jit, static_argnames=("bp", "interpret"))
def hit_count(table: jnp.ndarray, codes: jnp.ndarray, valid: jnp.ndarray, *,
              bp: int = DEFAULT_BP, interpret: bool = False) -> jnp.ndarray:
    """table (S, E) int8, codes (P, S) uint8, valid (P,) bool → (P,) int32."""
    p, s = codes.shape
    e = table.shape[1]
    bp = min(bp, p)
    pad = (-p) % bp
    if pad:
        codes = jnp.pad(codes, ((0, pad), (0, 0)))
        valid = jnp.pad(valid, (0, pad))

    out = pl.pallas_call(
        functools.partial(_hit_kernel, n_sub=s, n_entries=e),
        grid=((p + pad) // bp,),
        in_specs=[
            pl.BlockSpec((s, e), lambda i: (0, 0)),
            pl.BlockSpec((bp, s), lambda i: (i, 0)),
            pl.BlockSpec((bp,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bp,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((p + pad,), jnp.int32),
        interpret=interpret,
    )(table, codes, valid)
    return out[:p]

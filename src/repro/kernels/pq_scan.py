"""Pallas TPU kernel: masked ADC scan (paper Fig. 1 stage D, JUNO-H).

Accumulates per-point total distance from the masked LUT:
    total[p] = sum_s lut[s, codes[p, s]]

TPU mapping: the per-(point, subspace) gather is expressed as a one-hot
contraction  one_hot(codes) (bP, S, E) · lut (S, E) → (bP,)  which XLA lowers
onto the MXU — the direct TPU analogue of the paper's Tensor-core
"A × B(=ones)" accumulation trick (§5.3): the quantized codes choose MXU
operand rows instead of driving scalar lookups.

Grid: (P/bP,). LUT stays VMEM-resident across all point blocks (constant
index map), codes stream through. VMEM ≈ bP*S*E (one-hot, f32) — the one-hot
is formed per 8-subspace slab to stay within budget at bP=128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ops import slab_onehot_dot

DEFAULT_BP = 128   # points per program
SLAB = 8           # subspaces one-hot-expanded at a time (VMEM control)


def _scan_kernel(lut_ref, codes_ref, valid_ref, out_ref, *, n_sub, n_entries,
                 bad_value):
    codes = codes_ref[...].astype(jnp.int32)          # (bP, S)
    lut = lut_ref[...]                                # (S, E)
    # slab over subspaces: one_hot (bP, SLAB, E) · lut_slab (SLAB, E) on MXU
    acc = slab_onehot_dot(codes, lut, n_entries=n_entries,
                          out_dtype=jnp.float32, slab=SLAB)
    out_ref[...] = jnp.where(valid_ref[...], acc, bad_value)


@functools.partial(jax.jit, static_argnames=("metric", "bp", "interpret"))
def pq_scan(lut: jnp.ndarray, codes: jnp.ndarray, valid: jnp.ndarray, *,
            metric: str = "l2", bp: int = DEFAULT_BP,
            interpret: bool = False) -> jnp.ndarray:
    """lut (S, E) f32 (pre-masked), codes (P, S) uint8, valid (P,) bool.
    Returns (P,) f32 total scores; invalid slots get ±inf."""
    p, s = codes.shape
    e = lut.shape[1]
    bp = min(bp, p)
    pad = (-p) % bp
    if pad:
        codes = jnp.pad(codes, ((0, pad), (0, 0)))
        valid = jnp.pad(valid, (0, pad))
    bad = float("inf") if metric == "l2" else float("-inf")

    out = pl.pallas_call(
        functools.partial(_scan_kernel, n_sub=s, n_entries=e, bad_value=bad),
        grid=((p + pad) // bp,),
        in_specs=[
            pl.BlockSpec((s, e), lambda i: (0, 0)),   # LUT resident
            pl.BlockSpec((bp, s), lambda i: (i, 0)),
            pl.BlockSpec((bp,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bp,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((p + pad,), jnp.float32),
        interpret=interpret,
    )(lut, codes, valid)
    return out[:p]

"""Architecture registry: ``get_config(name)`` / ``get_smoke_config(name)``.

Each ``<arch>.py`` holds FULL (the exact published config from the
assignment) and SMOKE (same family, reduced) ModelConfigs.
"""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "phi4_mini_3_8b",
    "mistral_large_123b",
    "deepseek_coder_33b",
    "h2o_danube_3_4b",
    "whisper_large_v3",
    "hymba_1_5b",
    "deepseek_v2_lite_16b",
    "llama4_scout_17b_a16e",
    "llama_3_2_vision_90b",
    "mamba2_1_3b",
]

ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}


def _module(name: str):
    name = ALIASES.get(name, name)
    if name not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{name}")


def get_config(name: str):
    return _module(name).FULL


def get_smoke_config(name: str):
    return _module(name).SMOKE

"""mamba2-1.3b [ssm] — arXiv:2405.21060 (unverified tier).
48L d_model=2048 (attention-free) d_ff=0 vocab=50280, ssm_state=128 —
SSD (state-space duality). d_inner=4096 (expand 2), 64 heads × head_dim 64.
Blocks are pure mamba mixers (no MLP), matching the published architecture.
"""
from repro.models.config import ModelConfig, SSMConfig

FULL = ModelConfig(
    name="mamba2-1.3b",
    n_layers=48, d_model=2048, n_heads=1, n_kv_heads=1,   # attn unused
    d_ff=0, vocab_size=50280,
    attn_kind="none", mixer_kind="ssm",
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, n_groups=1),
)

SMOKE = ModelConfig(
    name="mamba2-smoke",
    n_layers=2, d_model=64, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab_size=512,
    attn_kind="none", mixer_kind="ssm",
    ssm=SSMConfig(d_state=16, head_dim=16, expand=2, n_groups=1, chunk=16),
)

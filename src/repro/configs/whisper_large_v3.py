"""whisper-large-v3 [audio] — arXiv:2212.04356 (unverified tier).
32L d_model=1280 20H (kv=20, MHA) d_ff=5120 vocab=51866 — enc-dec.

The conv/mel frontend is a STUB per the task spec: input_specs() provides
precomputed frame embeddings (B, 1500, d_model). "32L" is per stack
(32 encoder + 32 decoder). Deviation: RoPE instead of Whisper's
learned/sinusoidal positions (backbone-shape preserving, see DESIGN.md §4).
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="whisper-large-v3",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab_size=51866,
    encoder_decoder=True, n_encoder_layers=32,
    n_context_tokens=1500,          # 30 s of audio at 50 Hz after conv stub
)

SMOKE = ModelConfig(
    name="whisper-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=512,
    encoder_decoder=True, n_encoder_layers=2, n_context_tokens=24,
    attn_chunk=64,
)

"""h2o-danube-3-4b [dense] — arXiv:2401.16818 (unverified tier).
24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000 — llama+mistral mix,
SWA. Window = 4096 (the danube-family sliding window); head_dim = 120."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="h2o-danube-3-4b",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8,
    d_ff=10240, vocab_size=32000,
    sliding_window=4096,
)

SMOKE = ModelConfig(
    name="h2o-danube-smoke",
    n_layers=2, d_model=120, n_heads=4, n_kv_heads=2,
    d_ff=240, vocab_size=512, sliding_window=32, attn_chunk=64,
)

"""phi4-mini-3.8b [dense] — arXiv:2412.08905 (hf-verified tier).

32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064 — RoPE SwiGLU GQA.
Deviation notes: phi-4-mini uses partial rotary + tied embeddings; we apply
full-head RoPE and untied head (backbone-shape preserving).
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="phi4-mini-3.8b",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=8192, vocab_size=200064,
)

SMOKE = ModelConfig(
    name="phi4-mini-smoke",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    d_ff=256, vocab_size=512, attn_chunk=64,
)

"""llama-3.2-vision-90b [vlm] — hf:meta-llama/Llama-3.2-11B-Vision scaled
per assignment (unverified tier). 100L d_model=8192 64H (GQA kv=8)
d_ff=28672 vocab=128256 — cross-attn image layers.

Realised as 80 self-attention + 20 cross-attention blocks (every 5th layer
cross-attends), image frontend stubbed: input_specs() provides patch
embeddings (B, 6400, d_model)."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="llama-3.2-vision-90b",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab_size=128256,
    cross_attn_period=5, n_context_tokens=6400,
)

SMOKE = ModelConfig(
    name="llama-vision-smoke",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=512,
    cross_attn_period=2, n_context_tokens=16, attn_chunk=64,
)

"""hymba-1.5b [hybrid] — arXiv:2411.13676 (hf-verified tier).
32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16 —
parallel attention + mamba heads in every block (head_dim=64).

Deviations (recorded per DESIGN.md §4): meta-tokens omitted; all layers use
SWA (window 1024) — Hymba mixes 3 global layers in, our uniform-scan layout
keeps every block identical (long_500k viability is what SWA provides).
"""
from repro.models.config import ModelConfig, SSMConfig

FULL = ModelConfig(
    name="hymba-1.5b",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab_size=32001,
    mixer_kind="hybrid", sliding_window=1024,
    ssm=SSMConfig(d_state=16, head_dim=64, expand=2, n_groups=1),
)

SMOKE = ModelConfig(
    name="hymba-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=512,
    mixer_kind="hybrid", sliding_window=32,
    ssm=SSMConfig(d_state=8, head_dim=16, expand=2, n_groups=1, chunk=16),
    attn_chunk=64,
)

"""llama4-scout-17b-a16e [moe] — hf:meta-llama/Llama-4-Scout-17B-16E
(unverified tier). 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1 (+1 shared expert, per the released
model). Early-fusion vision frontend is a stub → lowered as a text LM
(DESIGN.md §4). iRoPE nuance (rope-free every 4th layer) not modeled."""
from repro.models.config import ModelConfig, MoEConfig

FULL = ModelConfig(
    name="llama4-scout-17b-a16e",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab_size=202048,
    moe=MoEConfig(n_experts=16, top_k=1, d_ff_expert=8192, n_shared=1),
)

SMOKE = ModelConfig(
    name="llama4-scout-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=512,
    moe=MoEConfig(n_experts=4, top_k=1, d_ff_expert=128, n_shared=1),
    attn_chunk=64,
)

"""deepseek-v2-lite-16b [moe] — arXiv:2405.04434 (hf-verified tier).
27L d_model=2048 16H (kv=16) d_ff=1408 vocab=102400, MLA kv_lora=512,
MoE: 64 routed experts top-6 + 2 shared (d_ff_expert=1408).

Note: the assignment line says "2 shared+160 routed" which contradicts its
own "MoE 64e top-6"; the published model is 64 routed + 2 shared, top-6 —
we implement that. Deviation: layer 0 is MoE like the rest (published model
has one dense first layer) to keep the uniform scanned stack.
"""
from repro.models.config import MLAConfig, ModelConfig, MoEConfig

FULL = ModelConfig(
    name="deepseek-v2-lite-16b",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=102400,
    head_dim=128,
    attn_kind="mla",
    mla=MLAConfig(kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
                  v_head_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2),
)

SMOKE = ModelConfig(
    name="deepseek-v2-lite-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=64, vocab_size=512,
    head_dim=16,
    attn_kind="mla",
    mla=MLAConfig(kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
                  v_head_dim=16),
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64, n_shared=1),
    attn_chunk=64,
)

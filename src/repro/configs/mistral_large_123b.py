"""mistral-large-123b [dense] — hf:mistralai/Mistral-Large-Instruct-2407
(unverified tier). 88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="mistral-large-123b",
    n_layers=88, d_model=12288, n_heads=96, n_kv_heads=8,
    d_ff=28672, vocab_size=32768,
)

SMOKE = ModelConfig(
    name="mistral-large-smoke",
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
    d_ff=256, vocab_size=512, attn_chunk=64,
)

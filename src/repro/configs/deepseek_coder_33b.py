"""deepseek-coder-33b [dense] — arXiv:2401.14196 (hf-verified tier).
62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256 — llama-arch."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="deepseek-coder-33b",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=19200, vocab_size=32256,
)

SMOKE = ModelConfig(
    name="deepseek-coder-smoke",
    n_layers=2, d_model=112, n_heads=4, n_kv_heads=2,
    d_ff=224, vocab_size=512, attn_chunk=64,
)

"""RT-core-style spatial pruning (`repro.rt`) — the paper's stage-1 filter.

JUNO's hardware contribution maps candidate filtering onto ray-tracing
cores as query-vs-centroid-sphere intersection tests, pruning pairwise
distance work before the tensor-core ADC stage (paper §5). This package is
the TPU re-mapping of that stage (docs/kernels.md §RT):

    grid       — build-time spatial index: uniform cell grid over a 2-D
                 orthonormal projection of the IVF centroids, per-cell
                 padded centroid lists, per-cluster projected reaches
    intersect  — the online Pallas kernel: AABB cell walk + disc-vs-disc
                 tests emitting the int8 survivor mask (host path off-TPU)

Consumers: ``core.search(prefilter="rt")`` masks non-intersecting probes
out of the hit-count / masked-ADC scans, ``serve.AnnServeEngine
(prefilter="rt")`` additionally shrinks the probe budget per request from
the survivor counts, and ``dist.make_distributed_search(prefilter="rt")``
applies the same mask per shard. The dense oracle lives in
``kernels.ref.rt_sphere_hits_ref``; dispatch in ``kernels.ops``.
"""
from .grid import (CentroidGrid, build_grid, load_grid,  # noqa: F401
                   probe_budget, query_radius, routing_state, save_grid,
                   survivor_mask, update_radii)
from .intersect import sphere_hits, sphere_hits_host  # noqa: F401

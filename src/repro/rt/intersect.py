"""Pallas TPU kernel: RT-core-style sphere-intersection filter stage.

The paper's stage-1 filter is an RT-core BVH traversal: the query is cast
as a ray origin and the hardware reports which centroid spheres it lands
in, skipping whole BVH subtrees that cannot intersect. The TPU has no
traversal unit, but the grid built by ``repro.rt.grid`` gives the same
two-level structure in a regular layout — and this kernel walks it:

* **grid axis = cells.** One program per (query-block, cell). The cell's
  AABB is tested against the block's query discs first; a cell no disc
  touches writes zeros and **skips the per-centroid work entirely**
  (``pl.when``) — the TPU-shaped analogue of the BVH skipping subtrees.
* **slot test.** For live cells, the (bQ, cap) disc-vs-disc test
  ``||qp - cp|| <= R + reach`` runs on lane-aligned coordinate planes
  (c0/c1 — the selective_lut idiom), emitting int8 hits.

Both tests compare *squared* distances guarded by ``thr >= 0`` so the
``-inf`` pad/empty sentinels from the grid build can never hit. The cell
test is conservative by construction (centroids lie inside their cell's
AABB and ``cell_reach >= reach``, with float monotonicity preserving both
inequalities), so kernel output is bit-identical to the dense oracle
``kernels.ref.rt_sphere_hits_ref`` — the skip changes work, never results.

``sphere_hits_host`` is the dense jnp path used for off-TPU serving
(dispatched by ``kernels.ops.rt_sphere_hits``): at host scale the whole
slot table is a few thousand lanes, so the dense test beats paying
interpret-mode overhead per cell.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BQ = 8   # query rows per program


def _sphere_kernel(q0_ref, q1_ref, r_ref, box_ref, creach_ref,
                   c0_ref, c1_ref, reach_ref, out_ref):
    """One (query-block, cell) program: AABB pre-test, then disc tests."""
    q0 = q0_ref[...]                                  # (bQ,)
    q1 = q1_ref[...]
    r = r_ref[...]
    box = box_ref[...]                                # (1, 4) lo0 lo1 hi0 hi1
    dx = jnp.clip(q0, box[0, 0], box[0, 2]) - q0      # query → AABB offset
    dy = jnp.clip(q1, box[0, 1], box[0, 3]) - q1
    d2_cell = dx * dx + dy * dy
    thr_cell = r + creach_ref[...][0]
    live = (thr_cell >= 0.0) & (d2_cell <= thr_cell * thr_cell)
    out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)

    @pl.when(jnp.any(live))
    def _slot_tests():
        c0 = c0_ref[...][0]                           # (cap,)
        c1 = c1_ref[...][0]
        reach = reach_ref[...][0]
        sx = q0[:, None] - c0[None, :]
        sy = q1[:, None] - c1[None, :]
        d2 = sx * sx + sy * sy
        thr = r[:, None] + reach[None, :]
        hit = (thr >= 0.0) & (d2 <= thr * thr)
        out_ref[...] = hit.astype(jnp.int8)[:, None, :]


@functools.partial(jax.jit, static_argnames=("bq", "interpret"))
def sphere_hits(q0: jnp.ndarray, q1: jnp.ndarray, radius: jnp.ndarray,
                boxes: jnp.ndarray, cell_reach: jnp.ndarray,
                c0: jnp.ndarray, c1: jnp.ndarray, slot_reach: jnp.ndarray,
                *, bq: int = DEFAULT_BQ,
                interpret: bool = False) -> jnp.ndarray:
    """Cell-walk sphere-intersection filter (see module docstring).

    Parameters
    ----------
    q0, q1 : jnp.ndarray
        (Q,) f32 — ray-plane query coordinates.
    radius : jnp.ndarray
        (Q,) f32 — ray-plane query-sphere radii.
    boxes : jnp.ndarray
        (n_cells, 4) f32 — per-cell AABBs ``[lo0, lo1, hi0, hi1]``.
    cell_reach : jnp.ndarray
        (n_cells,) f32 — per-cell max centroid reach (``-inf`` = empty).
    c0, c1 : jnp.ndarray
        (n_cells, cap) f32 — projected centroid coordinate planes.
    slot_reach : jnp.ndarray
        (n_cells, cap) f32 — per-slot reach (``-inf`` = pad slot).
    bq : int
        Query rows per program.
    interpret : bool
        Run the Pallas interpreter (CPU validation) instead of compiling.

    Returns
    -------
    jnp.ndarray
        (Q, n_cells · cap) int8 flat hit table, cell-major — index it with
        ``CentroidGrid.slot_of`` to recover cluster order.
    """
    q = q0.shape[0]
    n_cells, cap = c0.shape
    bq = min(bq, q)
    pad_q = (-q) % bq
    if pad_q:
        q0 = jnp.pad(q0, (0, pad_q))
        q1 = jnp.pad(q1, (0, pad_q))
        radius = jnp.pad(radius, (0, pad_q))
    qp = q + pad_q

    out = pl.pallas_call(
        _sphere_kernel,
        grid=(qp // bq, n_cells),
        in_specs=[
            pl.BlockSpec((bq,), lambda i, c: (i,)),
            pl.BlockSpec((bq,), lambda i, c: (i,)),
            pl.BlockSpec((bq,), lambda i, c: (i,)),
            pl.BlockSpec((1, 4), lambda i, c: (c, 0)),
            pl.BlockSpec((1,), lambda i, c: (c,)),
            pl.BlockSpec((1, cap), lambda i, c: (c, 0)),
            pl.BlockSpec((1, cap), lambda i, c: (c, 0)),
            pl.BlockSpec((1, cap), lambda i, c: (c, 0)),
        ],
        out_specs=pl.BlockSpec((bq, 1, cap), lambda i, c: (i, c, 0)),
        out_shape=jax.ShapeDtypeStruct((qp, n_cells, cap), jnp.int8),
        interpret=interpret,
    )(q0, q1, radius, boxes, cell_reach, c0, c1, slot_reach)
    return out[:q].reshape(q, n_cells * cap)


@jax.jit
def sphere_hits_host(q0: jnp.ndarray, q1: jnp.ndarray, radius: jnp.ndarray,
                     c0: jnp.ndarray, c1: jnp.ndarray,
                     slot_reach: jnp.ndarray) -> jnp.ndarray:
    """Dense jnp sphere-intersection path for off-TPU serving.

    Identical results to the kernel (the cell pre-test is conservative, so
    skipping it changes nothing); at host scale the dense (Q, n_cells·cap)
    test is a handful of fused vector ops and beats per-cell interpreter
    dispatch. The body IS the dense oracle
    (``kernels.ref.rt_sphere_hits_ref``) under one jit — a single source
    of truth, so host path and semantics of record cannot drift.

    Parameters
    ----------
    q0, q1, radius : jnp.ndarray
        (Q,) f32 ray-plane query coordinates and radii.
    c0, c1, slot_reach : jnp.ndarray
        (n_cells, cap) f32 centroid planes and per-slot reaches
        (``-inf`` = pad).

    Returns
    -------
    jnp.ndarray
        (Q, n_cells · cap) int8 flat hit table (cell-major).
    """
    from repro.kernels.ref import rt_sphere_hits_ref
    return rt_sphere_hits_ref(q0, q1, radius, c0, c1, slot_reach)

"""Build-time spatial index over IVF cluster centroids (the "BVH build").

The paper's stage-1 filter runs on RT cores: cluster centroids become
spheres, the query becomes a ray origin, and BVH traversal answers "which
clusters might contain near neighbours" without touching the ones that
cannot. This module is the build-time half of the TPU re-mapping
(docs/kernels.md §RT): a **uniform cell grid** over a 2-D orthonormal
projection of the centroids (the "ray plane") with per-cell centroid lists
padded to static shapes, so the online walk (`repro.rt.intersect`) is a
regular grid-shaped kernel instead of pointer chasing.

Geometry
--------
Every cluster ``c`` carries a *projected reach* ``r_c`` — the radius of the
smallest disc around its projected centroid containing every member point's
projection — computed exactly at build time (projection first, then max).
A query with sphere radius ``R`` intersects cluster ``c`` iff::

    ||P q - P c||_2 <= R + r_c        (P = the (D, 2) orthonormal projection)

which is exactly "query disc touches cluster disc" in the ray plane and a
superset of the members the full-space sphere can contain *in that plane*.
The per-cell bound ``cell_reach = max_c r_c`` lets the online kernel skip
whole cells (the traversal analogue). ``R`` itself comes from the density
model's calibrated per-subspace thresholds — see :func:`query_radius`.

The grid is a plain NamedTuple of arrays: it shards/replicates like any
other index component, serializes alongside the index
(:func:`save_grid`/:func:`load_grid`), and updates in place on online
inserts touching only the affected cells (:func:`update_radii`).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

# analytic fallback (calib_queries=0): a full-space distance R contracts to
# ~R*sqrt(m/D) under an orthonormal (D, m) projection; SIGMA standard
# deviations of the (Rayleigh-ish) projected length keep essentially every
# in-sphere point inside the projected query disc. The calibrated build
# replaces this with a measured quantile (see _radius_calibration).
DEFAULT_SIGMA = 3.0


class CentroidGrid(NamedTuple):
    """Static-shape uniform cell grid over projected cluster centroids.

    Attributes
    ----------
    proj : jnp.ndarray
        (D, 2) f32 — orthonormal projection onto the ray plane.
    lo, hi : jnp.ndarray
        (2,) f32 — grid bounding box in the ray plane.
    boxes : jnp.ndarray
        (n_cells, 4) f32 — per-cell AABB as ``[lo0, lo1, hi0, hi1]``.
    cell_ids : jnp.ndarray
        (n_cells, cap) int32 — padded per-cell cluster-id lists; -1 = pad.
    cell_c0, cell_c1 : jnp.ndarray
        (n_cells, cap) f32 — projected centroid coordinates per slot,
        carried as separate lane-aligned planes (selective_lut idiom).
    slot_reach : jnp.ndarray
        (n_cells, cap) f32 — projected cluster reach per slot; ``-inf`` at
        pad slots, so the signed intersection test can never hit them.
    cell_reach : jnp.ndarray
        (n_cells,) f32 — ``max`` of slot_reach per cell (``-inf`` when the
        cell is empty); the kernel's cell-skip bound.
    slot_of : jnp.ndarray
        (C,) int32 — flat slot index (``cell * cap + slot``) of each
        cluster; inverts the cell layout back to cluster order.
    radius_scale : jnp.ndarray
        () f32 — full-space → ray-plane radius contraction
        (``sqrt(2 / D)``; the analytic fallback folds in DEFAULT_SIGMA).
    radius_bias : jnp.ndarray
        () f32 — calibrated additive radius term (ray-plane units); the
        ``coverage`` quantile of ``needed - contraction * ||τ||`` over
        calibration queries, so ``rt_scale=1`` hits the coverage target
        while the knob stays monotone (larger scale ⇒ more survivors).
    """

    proj: jnp.ndarray
    lo: jnp.ndarray
    hi: jnp.ndarray
    boxes: jnp.ndarray
    cell_ids: jnp.ndarray
    cell_c0: jnp.ndarray
    cell_c1: jnp.ndarray
    slot_reach: jnp.ndarray
    cell_reach: jnp.ndarray
    slot_of: jnp.ndarray
    radius_scale: jnp.ndarray
    radius_bias: jnp.ndarray

    @property
    def n_cells(self) -> int:
        """Number of grid cells (G²)."""
        return self.cell_ids.shape[0]

    @property
    def capacity(self) -> int:
        """Padded per-cell centroid-list capacity."""
        return self.cell_ids.shape[1]

    @property
    def grid_size(self) -> int:
        """Cells per axis G (the grid is square)."""
        return int(round(self.n_cells ** 0.5))


def _projection(dim: int, seed: int) -> np.ndarray:
    """Deterministic (D, 2) orthonormal projection via QR of a Gaussian."""
    g = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (dim, 2)),
                   np.float64)
    q, _ = np.linalg.qr(g)
    return q.astype(np.float32)


def _radius_calibration(data, proj: np.ndarray, reach: np.ndarray, *,
                        metric: str, coverage: float, n_queries: int,
                        k: int = 10, seed: int = 0,
                        points: np.ndarray | None = None) -> float:
    """Measure the τ → ray-plane-radius scale on reconstruction queries.

    Same recipe as ``_calibrate_density``: perturbed database points act
    as calibration queries, their exact top-``k`` give ground truth. For
    each query the smallest radius whose survivor set covers *every*
    owner cluster of its top-k is ``max_owner(||qp - cp|| - reach_c)``;
    subtracting the query's contracted ``sqrt(Σ_s τ_s²)`` (the same
    density-model thresholds search time will have) leaves the additive
    correction the analytic radius misses, and the ``coverage`` quantile
    of those corrections becomes ``radius_bias`` — so at ``rt_scale=1.0``
    roughly a ``coverage`` fraction of queries keep all their
    true-neighbour clusters as survivors.
    """
    from repro.core import density as density_lib
    from repro.core.pq import decode
    from repro.core.ref import exact_topk

    cent = np.asarray(data.ivf.centroids, np.float32)
    labels = np.asarray(data.ivf.labels)
    if points is not None:
        pts = np.asarray(points, np.float32)
    else:
        pts = cent[labels] + np.asarray(decode(data.codes, data.codebook))
    n = pts.shape[0]
    nq = min(n_queries, n)
    rng = np.random.default_rng(seed)
    qidx = rng.choice(n, size=nq, replace=False)
    noise = 0.01 * rng.standard_normal((nq, pts.shape[1])) * pts.std()
    queries = (pts[qidx] + noise).astype(np.float32)

    _, gt = exact_topk(jnp.asarray(queries), jnp.asarray(pts), k=k,
                       metric=metric, chunk=min(65536, n))
    owners = labels[np.asarray(gt)]                            # (nq, k)
    qp = queries @ proj
    cp = cent @ proj
    dproj = np.linalg.norm(qp[:, None, :] - cp[owners], axis=-1)
    needed = (dproj - reach[owners]).max(axis=1)               # (nq,)

    if metric == "l2":   # probe-0 residual geometry, as at search time
        d = np.sum(cent * cent, -1)[None, :] - 2.0 * queries @ cent.T
        res = queries - cent[np.argmin(d, axis=1)]
    else:
        res = queries
    m = data.codebook.sub_dim
    tau = np.asarray(density_lib.predict_threshold(
        data.density, jnp.asarray(res.reshape(nq, -1, m)), 1.0))
    tau_norm = np.sqrt(np.sum(tau * tau, axis=-1))
    contract = (2.0 / cent.shape[1]) ** 0.5
    return float(np.quantile(needed - contract * tau_norm, coverage))


def build_grid(data, *, metric: str = "l2", grid_size: int | None = None,
               proj_seed: int = 0, coverage: float = 0.9,
               calib_queries: int = 64,
               points: np.ndarray | None = None) -> CentroidGrid:
    """Build the centroid cell grid for a built index.

    Parameters
    ----------
    data : JunoIndexData
        A built index (``repro.core.build``); centroids, labels and PQ
        codes are read from it.
    metric : str
        "l2" | "ip" — the metric the index serves (drives calibration).
    grid_size : int, optional
        Cells per axis. Default: ``max(2, round(sqrt(C / 4)))`` — about
        four centroids per cell.
    proj_seed : int
        PRNG seed for the orthonormal ray-plane projection.
    coverage : float
        Radius-calibration target: at ``rt_scale=1.0`` about this
        fraction of calibration queries keep every owner cluster of
        their exact top-10 in the survivor set.
    calib_queries : int
        Calibration sample size; 0 skips calibration and falls back to
        the analytic ``DEFAULT_SIGMA * sqrt(2/D)`` contraction.
    points : np.ndarray, optional
        (N, D) f32 raw database points. When given, per-cluster reaches
        and calibration use exact residuals; otherwise positions are
        reconstructed from the PQ codes (``pq.decode``), which
        under-measures reach by at most the quantization error.

    Returns
    -------
    CentroidGrid
        The static-shape grid, ready for :func:`survivor_mask`.
    """
    cent = np.asarray(data.ivf.centroids, np.float32)          # (C, D)
    labels = np.asarray(data.ivf.labels)
    c, d = cent.shape
    proj = _projection(d, proj_seed)
    cp = cent @ proj                                           # (C, 2)

    if points is not None:
        res = np.asarray(points, np.float32) - cent[labels]
    else:
        from repro.core.pq import decode
        res = np.asarray(decode(data.codes, data.codebook))
    rp = res @ proj                                            # (N, 2)
    rnorm = np.sqrt(np.sum(rp * rp, axis=-1))
    reach = np.zeros((c,), np.float32)
    np.maximum.at(reach, labels, rnorm)

    if calib_queries > 0:
        radius_scale = (2.0 / d) ** 0.5
        radius_bias = _radius_calibration(
            data, proj, reach, metric=metric, coverage=coverage,
            n_queries=calib_queries, seed=proj_seed, points=points)
    else:
        radius_scale = DEFAULT_SIGMA * (2.0 / d) ** 0.5
        radius_bias = 0.0

    g = grid_size or max(2, int(round((c / 4.0) ** 0.5)))
    lo = cp.min(axis=0)
    hi = cp.max(axis=0)
    span = np.maximum(hi - lo, 1e-6)
    ij = np.clip(((cp - lo) / span * g).astype(np.int64), 0, g - 1)
    flat_cell = ij[:, 0] * g + ij[:, 1]

    counts = np.bincount(flat_cell, minlength=g * g)
    cap = max(8, int(-(-counts.max() // 8) * 8))               # pad to 8
    cell_ids = np.full((g * g, cap), -1, np.int32)
    slot_reach = np.full((g * g, cap), -np.inf, np.float32)
    cell_c0 = np.zeros((g * g, cap), np.float32)
    cell_c1 = np.zeros((g * g, cap), np.float32)
    slot_of = np.zeros((c,), np.int32)
    fill = np.zeros((g * g,), np.int64)
    for cid in range(c):
        cell = flat_cell[cid]
        s = fill[cell]
        cell_ids[cell, s] = cid
        cell_c0[cell, s] = cp[cid, 0]
        cell_c1[cell, s] = cp[cid, 1]
        slot_reach[cell, s] = reach[cid]
        slot_of[cid] = cell * cap + s
        fill[cell] += 1

    cell_lo = lo[None, :] + np.stack(
        np.meshgrid(np.arange(g), np.arange(g), indexing="ij"),
        axis=-1).reshape(-1, 2) * (span / g)[None, :]
    boxes = np.concatenate([cell_lo, cell_lo + (span / g)[None, :]],
                           axis=1).astype(np.float32)

    return CentroidGrid(
        proj=jnp.asarray(proj), lo=jnp.asarray(lo.astype(np.float32)),
        hi=jnp.asarray(hi.astype(np.float32)), boxes=jnp.asarray(boxes),
        cell_ids=jnp.asarray(cell_ids), cell_c0=jnp.asarray(cell_c0),
        cell_c1=jnp.asarray(cell_c1), slot_reach=jnp.asarray(slot_reach),
        cell_reach=jnp.asarray(slot_reach.max(axis=1)),
        slot_of=jnp.asarray(slot_of),
        radius_scale=jnp.float32(radius_scale),
        radius_bias=jnp.float32(radius_bias))


def query_radius(grid: CentroidGrid, tau: jnp.ndarray,
                 scale: jnp.ndarray | float = 1.0) -> jnp.ndarray:
    """Ray-plane query-sphere radius from the calibrated thresholds.

    The density model's per-subspace thresholds τ_s are calibrated so the
    top-k's entries fall within τ_s of the query's subspace projection
    (paper §4.1); since full-space distances add over subspaces,
    ``sqrt(Σ_s τ_s²)`` is the matching full-space radius, and
    ``grid.radius_scale`` contracts it into the ray plane.

    Parameters
    ----------
    grid : CentroidGrid
        The built grid (supplies ``radius_scale``).
    tau : jnp.ndarray
        (Q, S) f32 per-subspace thresholds for each query — e.g. the
        probe-0 row of the thresholds ``_search_batch`` already computes.
    scale : float or jnp.ndarray
        User knob (the rt analogue of ``thres_scale``): > 1 trades
        throughput for coverage — the radius is monotone in it — and
        very large values cover every cell (the full-coverage limit the
        parity tests pin).

    Returns
    -------
    jnp.ndarray
        (Q,) f32 ray-plane radii,
        ``scale · radius_scale · sqrt(Σ_s τ_s²) + radius_bias``.
    """
    return (jnp.asarray(scale, jnp.float32) * grid.radius_scale
            * jnp.sqrt(jnp.sum(tau * tau, axis=-1)) + grid.radius_bias)


def survivor_mask(grid: CentroidGrid, queries: jnp.ndarray,
                  radius: jnp.ndarray) -> jnp.ndarray:
    """Per-(query, cluster) sphere-intersection hits, in cluster order.

    Projects the queries onto the ray plane, runs the cell-walk
    intersection stage (``kernels.ops.rt_sphere_hits`` — Pallas on TPU,
    host path off-TPU) and inverts the cell layout back to cluster order.

    Parameters
    ----------
    grid : CentroidGrid
        The built grid.
    queries : jnp.ndarray
        (Q, D) f32 full-space queries.
    radius : jnp.ndarray
        (Q,) f32 ray-plane radii (:func:`query_radius`).

    Returns
    -------
    jnp.ndarray
        (Q, C) int8 — 1 where the query sphere intersects the cluster's
        disc, 0 elsewhere; the stage-1 survivor mask consumed ahead of the
        hit-count / masked-ADC scans.
    """
    from repro.kernels import ops as kops
    qp = queries.astype(jnp.float32) @ grid.proj
    hits = kops.rt_sphere_hits(qp[:, 0], qp[:, 1], radius, grid.boxes,
                               grid.cell_reach, grid.cell_c0, grid.cell_c1,
                               grid.slot_reach)
    return jnp.take(hits, grid.slot_of, axis=1)


def update_radii(grid: CentroidGrid, clusters, reaches) -> CentroidGrid:
    """Grow per-cluster reaches after online inserts (touched cells only).

    Inserts never move centroids, so cell membership is stable — the only
    grid state an insert can invalidate is the reach of the owning
    cluster (a new point may project farther from its centroid than any
    existing member). This recomputes ``slot_reach``/``cell_reach`` for
    exactly the touched slots/cells; deletes are left alone (a stale
    larger reach only over-covers, never drops a survivor).

    Parameters
    ----------
    grid : CentroidGrid
        Current grid.
    clusters : array-like
        (B,) int — owning cluster of each inserted point.
    reaches : array-like
        (B,) f32 — projected residual length of each inserted point
        (``||(p - centroid) @ proj||``).

    Returns
    -------
    CentroidGrid
        Updated grid (shares every untouched array with the input).
    """
    clusters = np.atleast_1d(np.asarray(clusters, np.int64))
    reaches = np.atleast_1d(np.asarray(reaches, np.float32))
    cap = grid.capacity
    slots = np.asarray(grid.slot_of)[clusters]
    slot_reach = np.asarray(grid.slot_reach).copy()
    np.maximum.at(slot_reach.reshape(-1), slots, reaches)
    cells = np.unique(slots // cap)
    cell_reach = np.asarray(grid.cell_reach).copy()
    cell_reach[cells] = slot_reach[cells].max(axis=1)
    return grid._replace(slot_reach=jnp.asarray(slot_reach),
                         cell_reach=jnp.asarray(cell_reach))


def routing_state(grid: CentroidGrid, data) -> dict:
    """Host-side (numpy) snapshot of everything :func:`probe_budget` reads.

    The serving engine routes every request through ``probe_budget``;
    pulling the density grid and centroid planes off-device per request
    would dominate the (microseconds-scale) numpy math, so the engine
    caches this snapshot and refreshes it only when the grid object
    changes (online inserts grow reaches via :func:`update_radii`, which
    builds a new grid).

    Parameters
    ----------
    grid : CentroidGrid
        The built grid.
    data : JunoIndexData
        The served index (centroids + density model).

    Returns
    -------
    dict
        Plain numpy arrays/scalars keyed by name; pass as the ``state``
        argument of :func:`probe_budget`.
    """
    dens = data.density
    return {
        "cent": np.asarray(data.ivf.centroids, np.float32),
        "dens_grid": np.asarray(dens.grid),
        "dens_lo": np.asarray(dens.lo), "dens_hi": np.asarray(dens.hi),
        "coeffs": np.asarray(dens.coeffs),
        "tau_min": float(dens.tau_min), "tau_max": float(dens.tau_max),
        "sub_dim": int(data.codebook.sub_dim),
        "proj": np.asarray(grid.proj),
        "slot_of": np.asarray(grid.slot_of),
        "c0": np.asarray(grid.cell_c0).reshape(-1),
        "c1": np.asarray(grid.cell_c1).reshape(-1),
        "reach": np.asarray(grid.slot_reach).reshape(-1),
        "radius_scale": float(grid.radius_scale),
        "radius_bias": float(grid.radius_bias),
    }


def probe_budget(grid: CentroidGrid, data, queries, *, metric: str = "l2",
                 scale: float = 1.0, thres_scale: float = 1.0,
                 max_probes: int = 16,
                 state: dict | None = None) -> np.ndarray:
    """Host-side (numpy) per-query probe budget — the router's rt input.

    For each query, ranks the ``max_probes`` best clusters by the same
    stage-A score ``filter_clusters`` uses and returns the rank of the
    LAST one surviving the sphere-intersection test. Probing that many
    clusters (plus the probe-0 backstop) reaches every cluster the rt
    mask would keep at the full budget — ranks beyond it are pruned
    probes that contribute only sentinels — so shrinking a request's
    nprobe to the next bucket ≥ this value loses nothing the mask would
    have kept.

    Parameters
    ----------
    grid : CentroidGrid
        The built grid.
    data : JunoIndexData
        The served index (centroids + density model).
    queries : np.ndarray
        (Q, D) f32 queries.
    metric : str
        "l2" | "ip".
    scale : float
        Same radius knob as :func:`query_radius`.
    thres_scale : float
        The search-time selectivity-threshold multiplier — MUST match
        the ``thres_scale`` of the searches being routed, because the
        in-search mask derives its radius from the scaled τ.
    max_probes : int
        The unshrunk probe budget to rank within.
    state : dict, optional
        Cached :func:`routing_state` snapshot (avoids per-call
        device→host copies on the serving hot path).

    Returns
    -------
    np.ndarray
        (Q,) int64 in ``[1, max_probes]``.
    """
    st = state if state is not None else routing_state(grid, data)
    q = np.atleast_2d(np.asarray(queries, np.float32))
    cent = st["cent"]
    max_probes = min(max_probes, cent.shape[0])
    qc = q @ cent.T                                            # (Q, C), once
    if metric == "l2":
        score = np.sum(cent * cent, -1)[None, :] - 2.0 * qc
        res = q - cent[np.argmin(score, axis=1)]
    else:
        score = -qc
        res = q
    order = np.argsort(score, axis=1)[:, :max_probes]          # (Q, np)

    qsub = res.reshape(q.shape[0], -1, st["sub_dim"])
    g = st["dens_grid"]
    gsz = g.shape[-1]
    span = np.maximum(st["dens_hi"] - st["dens_lo"], 1e-6)
    ij = np.clip(((qsub - st["dens_lo"]) / span * gsz).astype(np.int64),
                 0, gsz - 1)
    dval = g[np.arange(g.shape[0])[None, :], ij[..., 0], ij[..., 1]]
    tau = np.clip(np.polyval(st["coeffs"], dval),
                  st["tau_min"], st["tau_max"]) * thres_scale
    radius = (scale * st["radius_scale"]
              * np.sqrt(np.sum(tau * tau, axis=-1)) + st["radius_bias"])

    qp = q @ st["proj"]
    flat = st["slot_of"][order]                                # (Q, np)
    dx = qp[:, 0, None] - st["c0"][flat]
    dy = qp[:, 1, None] - st["c1"][flat]
    thr = radius[:, None] + st["reach"][flat]
    hit = (thr >= 0) & (dx * dx + dy * dy <= thr * thr)
    hit[:, 0] = True                                           # backstop
    return max_probes - np.argmax(hit[:, ::-1], axis=1)


def save_grid(path: str, grid: CentroidGrid) -> None:
    """Serialize a grid to ``path`` (.npz) alongside the index it indexes.

    Parameters
    ----------
    path : str
        Target file path (np.savez format).
    grid : CentroidGrid
        The grid to persist.
    """
    np.savez(path, **{k: np.asarray(v) for k, v in grid._asdict().items()})


def load_grid(path: str) -> CentroidGrid:
    """Load a grid serialized by :func:`save_grid`.

    Parameters
    ----------
    path : str
        File written by :func:`save_grid`.

    Returns
    -------
    CentroidGrid
        The deserialized grid (device arrays).
    """
    with np.load(path) as z:
        return CentroidGrid(**{k: jnp.asarray(z[k])
                               for k in CentroidGrid._fields})

"""JUNO reproduction (sparsity-aware ANN search + RT-core mapping, on JAX).

Subpackages: ``core`` (the paper's algorithm), ``kernels`` (Pallas),
``rt`` (spatial prefilter: the RT-core stage at cluster granularity),
``build`` (out-of-core streaming construction, versioned artifact store,
online rebuild/hot-swap), ``models``/``train``/``serve`` (the
surrounding LM system), ``dist`` (sharding / distributed index /
checkpointing / fault tolerance), ``launch`` (meshes + dry-run),
``configs``, ``data``.
Documentation: docs/index.md.
"""

"""Out-of-core paged ANN serving (`repro.serve.paged`).

JUNO's evaluation tops out where the PQ-coded index stops fitting in
accelerator memory; FusionANNS (PAPERS.md) shows the billion-scale
regime wants a tiered split instead — small hot metadata resident,
bulk data demand-paged. This module maps that split onto the artifact
store (``repro.build.store``):

* **Resident tier** — IVF centroids/point-ids/valid masks, PQ codebooks,
  the density→threshold model and (when saved into the artifact) the
  ``repro.rt`` centroid grid are promoted to device arrays at load time.
  Stage A cluster filtering, rt probe routing and the LUT/threshold
  machinery run entirely over this tier.
* **Paged tier** — the per-cluster PQ code shards (``cluster_codes``,
  the O(N·S) bulk) stay memory-mapped on disk
  (``load_index(mmap_mode="r")``) behind :class:`ClusterCache`, a
  byte-capacity LRU of hot clusters with hit/miss/eviction counters.
  Each cluster row is digest-verified on first touch against the
  manifest's per-row sha256 table — the mmap half of the store's
  fail-closed contract.
* **Exact-rerank tier** (optional) — FusionANNS's CPU/GPU cooperative
  split mapped to host-memory/VMEM: the paged search returns a top-C
  candidate list and the final top-k is re-scored exactly against raw
  vectors fetched (memory-mapped) for only those C candidates.

:class:`PagedJunoIndex` is the :class:`~repro.core.juno.MutableIndexBase`
wiring: inserts route to the side buffer (the paged shards are
read-only), deletes tombstone the resident valid mask, and
``swap_data``/:meth:`PagedAnnServeEngine.swap_index` atomically retarget
the cluster cache to a new artifact generation. Scoring reuses
``repro.core.juno``'s ``_score_probed`` / ``_score_probed_two_stage``
verbatim, so the paged path returns the same ids the resident path does
(``tests/test_paged.py``; gated at scale by ``benchmarks/serve_qps.py``
serving a dataset ≥ 4× the cache).
"""
from __future__ import annotations

import collections
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.build.store import (ArtifactError, _array_digest, load_index)
from repro.core.ivf import filter_clusters
from repro.core.juno import (JunoIndexData, MutableIndexBase, _score_probed,
                             _score_probed_two_stage)
from repro.serve.ann import AnnServeEngine


class ClusterCache:
    """Byte-capacity LRU cache of per-cluster PQ code rows.

    Keys are cluster ids, values are the materialized ``(P, S)`` uint8
    code rows read from the memory-mapped shard. Eviction is
    least-recently-used by bytes: rows are dropped until the new row
    fits ``capacity_bytes``. A row larger than the whole capacity is
    served but never cached (correctness never depends on residency).
    ``hits``/``misses``/``evictions``/``bytes`` make cache pressure
    observable; ``benchmarks/serve_qps.py`` asserts evictions > 0 to
    prove its gate really exercised the paged tier.
    """

    def __init__(self, capacity_bytes: int):
        """Create an empty cache bounded by ``capacity_bytes`` bytes."""
        self.capacity_bytes = int(capacity_bytes)
        self._rows: collections.OrderedDict[int, np.ndarray] = \
            collections.OrderedDict()
        self.bytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self._m = None          # registry metric handles once bound
        self._bound_to = None   # the registry the handles live in

    def bind(self, registry) -> None:
        """Mirror the counters into a ``repro.obs`` registry.

        Registers the documented ``juno_cache_*`` series (the ad-hoc
        int attributes and :meth:`stats` keys stay as the deprecated
        alias) and seeds them with the counts accumulated so far, so
        binding after warm-up loses nothing. Re-binding to the same
        registry is a no-op (generation swaps re-bind the adopted
        cache) — the seed must not double-count.
        """
        if self._bound_to is registry:
            return
        self._bound_to = registry
        m = {"hits": registry.counter("juno_cache_hits_total"),
             "misses": registry.counter("juno_cache_misses_total"),
             "evictions": registry.counter("juno_cache_evictions_total"),
             "evicted_bytes": registry.counter(
                 "juno_cache_evicted_bytes_total"),
             "bytes": registry.gauge("juno_cache_bytes", agg="sum"),
             "rows": registry.gauge("juno_cache_rows", agg="sum")}
        m["hits"].inc(self.hits)
        m["misses"].inc(self.misses)
        m["evictions"].inc(self.evictions)
        m["bytes"].set(self.bytes)
        m["rows"].set(len(self._rows))
        self._m = m

    def get(self, cid: int) -> np.ndarray | None:
        """Return the cached row for ``cid`` (refreshing LRU) or None."""
        row = self._rows.get(cid)
        if row is None:
            self.misses += 1
            if self._m is not None:
                self._m["misses"].inc()
            return None
        self._rows.move_to_end(cid)
        self.hits += 1
        if self._m is not None:
            self._m["hits"].inc()
        return row

    def put(self, cid: int, row: np.ndarray) -> None:
        """Insert ``row`` under ``cid``, evicting LRU rows to fit."""
        nb = row.nbytes
        if nb > self.capacity_bytes:
            return                    # larger than the whole cache: bypass
        while self._rows and self.bytes + nb > self.capacity_bytes:
            _, old = self._rows.popitem(last=False)
            self.bytes -= old.nbytes
            self.evictions += 1
            if self._m is not None:
                self._m["evictions"].inc()
                self._m["evicted_bytes"].inc(old.nbytes)
        self._rows[cid] = row
        self.bytes += nb
        if self._m is not None:
            self._m["bytes"].set(self.bytes)
            self._m["rows"].set(len(self._rows))

    def clear(self) -> None:
        """Drop every cached row (capacity and counters are kept)."""
        self._rows.clear()
        self.bytes = 0

    def __len__(self) -> int:
        """Number of cached cluster rows."""
        return len(self._rows)

    def stats(self) -> dict:
        """``{"capacity_bytes", "bytes", "rows", "hits", "misses",
        "evictions"}`` — deprecated-alias snapshot of the counters.

        These ad-hoc keys predate ``repro.obs``; the documented form is
        the ``juno_cache_*`` registry series a :meth:`bind` call keeps
        in lockstep with the same numbers.
        """
        return {"capacity_bytes": self.capacity_bytes, "bytes": self.bytes,
                "rows": len(self._rows), "hits": self.hits,
                "misses": self.misses, "evictions": self.evictions}


def _to_device(nt):
    """Promote every field of a NamedTuple of arrays to device arrays."""
    return type(nt)(**{f: jnp.asarray(np.asarray(getattr(nt, f)))
                       for f in type(nt)._fields})


class PagedIndexData:
    """One artifact generation served out-of-core.

    Loads an artifact with ``load_index(mmap_mode="r")``: metadata
    (IVF/codebook/density, plus the rt grid when the artifact holds one)
    is promoted to resident device arrays in :attr:`meta` — a real
    :class:`~repro.core.juno.JunoIndexData` whose ``codes`` /
    ``cluster_codes`` / ``points_sq`` are zero-length placeholders — and
    the cluster code shards stay on disk behind a :class:`ClusterCache`.

    Integrity is fail-closed in two stages: the load itself runs the
    store's ``verify="manifest"`` pass (schema, config hash, array
    set/shapes/dtypes), and each cluster row is sha256-verified against
    the manifest's ``sha256_rows`` table the first time it is faulted in
    (:meth:`fetch_cluster` raises :class:`~repro.build.store.ArtifactError`
    on mismatch). Artifacts written before per-row digests existed can
    only be served with ``verify_rows=False`` — an explicit opt-out, not
    a silent downgrade.
    """

    def __init__(self, path: str, *, cache_bytes: int = 64 << 20,
                 expect_config=None, vectors=None,
                 verify_rows: bool = True, verify: str | None = None):
        """Open an artifact directory for paged serving.

        Parameters
        ----------
        path : str
            Artifact directory written by ``repro.build.store.save_index``
            (usually ``ArtifactStore.path(name, version)``).
        cache_bytes : int
            Hot-cluster cache capacity in bytes. Size it to the working
            set: ``C_hot · P · S`` bytes for the clusters the query
            distribution actually probes (docs/serving.md).
        expect_config : JunoConfig, optional
            Forwarded to ``load_index`` (config-hash guard).
        vectors : array-like or str, optional
            Raw ``(N, D)`` vectors for the exact-rerank tier — an
            ``np.memmap``/array, or a path to an ``.npy`` opened with
            ``mmap_mode="r"``. Only the final top-C candidate rows are
            ever read.
        verify_rows : bool
            Verify each cluster row's sha256 on first touch (default).
            Required when the manifest carries ``sha256_rows``-capable
            data; ``False`` is the explicit opt-out for old artifacts.
        verify : str, optional
            Load-time verification level forwarded to ``load_index``
            (default: the mmap default, ``"manifest"``).
        """
        loaded = load_index(path, expect_config=expect_config,
                            mmap_mode="r", verify=verify)
        self.path = path
        self.config = loaded.config
        self.manifest = loaded.manifest
        self.rt_grid = (None if loaded.rt_grid is None
                        else _to_device(loaded.rt_grid))
        self._cluster_codes = loaded.data.cluster_codes   # (C, P, S) memmap
        self._codes = loaded.data.codes                   # (N, S) memmap
        self._points_sq = loaded.data.points_sq           # (N,) memmap
        c, p, s = self._cluster_codes.shape
        ivf = _to_device(loaded.data.ivf)
        self.meta = JunoIndexData(
            ivf=ivf, codebook=_to_device(loaded.data.codebook),
            density=_to_device(loaded.data.density),
            codes=jnp.zeros((0, s), self._codes.dtype),
            cluster_codes=jnp.zeros((0, p, s), self._cluster_codes.dtype),
            points_sq=jnp.zeros((0,), jnp.float32))
        self.cluster_bytes = int(self._cluster_codes.nbytes)
        self._row_digests = loaded.manifest["arrays"]["cluster_codes"].get(
            "sha256_rows")
        if verify_rows and self._row_digests is None:
            raise ArtifactError(
                f"artifact has no per-row digests for cluster_codes; "
                f"re-save it with the current store, or opt out with "
                f"verify_rows=False ({path})")
        if not verify_rows:
            self._row_digests = None
        self._verified = np.zeros(c, bool)
        self.verified_rows = 0
        if isinstance(vectors, str):
            vectors = np.load(vectors, mmap_mode="r")
        self.vectors = vectors
        self.cache = ClusterCache(cache_bytes)
        self._obs = None        # Observability bundle once bound
        pid = np.asarray(loaded.data.ivf.point_ids)
        valid = np.asarray(loaded.data.ivf.valid)
        #: smallest id no committed point uses — seeds the mutable wrapper
        self.first_new_id = int(pid[valid].max(initial=-1)) + 1

    def bind_obs(self, obs) -> None:
        """Attach an ``repro.obs.Observability`` bundle to the fetch plane.

        Binds the cluster cache's counters to ``obs.registry`` and turns
        every cache miss into a ``paged.fault`` span plus
        ``juno_paged_faults_total`` / ``juno_paged_fault_bytes_total``
        counters, with first-touch digest time observed into
        ``juno_paged_verify_seconds``.
        """
        self._obs = obs
        self.cache.bind(obs.registry)

    # ---- paged fetch plane ----------------------------------------------
    def fetch_cluster(self, cid: int) -> np.ndarray:
        """Materialize one cluster's ``(P, S)`` code row, cached.

        Cache hit → the resident copy. Miss → one cluster-sized read
        from the memory-mapped shard, sha256-checked against the
        manifest on the row's first-ever touch (fail-closed: a flipped
        bit raises ``ArtifactError`` instead of serving garbage), then
        inserted into the LRU.
        """
        row = self.cache.get(cid)
        if row is not None:
            return row
        if self._obs is not None:
            with self._obs.tracer.span("paged.fault", cluster=cid):
                row = self._fault_in(cid)
            self._obs.registry.counter("juno_paged_faults_total").inc()
            self._obs.registry.counter(
                "juno_paged_fault_bytes_total").inc(row.nbytes)
        else:
            row = self._fault_in(cid)
        self.cache.put(cid, row)
        return row

    def _fault_in(self, cid: int) -> np.ndarray:
        """Miss path: mmap read + first-touch digest check for one cluster."""
        row = np.ascontiguousarray(self._cluster_codes[cid])
        if self._row_digests is not None and not self._verified[cid]:
            t0 = time.perf_counter()
            if _array_digest(row) != self._row_digests[cid]:
                raise ArtifactError(
                    f"cluster_codes[{cid}]: checksum mismatch on first "
                    f"touch ({self.path})")
            self._verified[cid] = True
            self.verified_rows += 1
            if self._obs is not None:
                self._obs.registry.histogram(
                    "juno_paged_verify_seconds").add(
                        time.perf_counter() - t0)
        return row

    def gather(self, cids) -> np.ndarray:
        """Gather probed clusters' codes: ``(...,) ids → (..., P, S)``.

        The host-side equivalent of the resident path's
        ``index.cluster_codes[cids]`` device gather — every distinct
        cluster is faulted through :meth:`fetch_cluster` exactly once
        per call, so a batch touching U unique clusters costs at most U
        cluster reads (0 when all are cache-hot).
        """
        cids = np.asarray(cids)
        uniq, inv = np.unique(cids, return_inverse=True)
        rows = np.stack([self.fetch_cluster(int(c)) for c in uniq])
        return rows[inv].reshape(cids.shape + rows.shape[1:])

    def fetch_vectors(self, ids) -> np.ndarray:
        """Raw vectors for the exact-rerank tier: ``(Q, C) ids → (Q, C, D)``.

        Reads only the addressed rows from the memory-mapped vector
        file. Negative (sentinel) ids are clamped to row 0 — callers
        mask them out of the rerank by score.
        """
        if self.vectors is None:
            raise RuntimeError("no raw-vector source attached "
                               "(PagedIndexData(vectors=...))")
        ids = np.asarray(ids)
        safe = np.clip(ids, 0, self.vectors.shape[0] - 1)
        return np.asarray(self.vectors[safe], np.float32)

    # ---- generation retargeting ------------------------------------------
    def adopt_cache(self, cache: ClusterCache) -> None:
        """Take over an existing cache for this generation.

        Every cached row is dropped first — rows belong to the
        generation that faulted them in — while the capacity and
        cumulative hit/miss/eviction counters carry over. This is the
        swap-time primitive: ``PagedJunoIndex.swap_data`` calls it so a
        hot-swapped engine keeps one cache whose contents can never
        alias across generations.
        """
        cache.clear()
        self.cache = cache

    def stats(self) -> dict:
        """Cache counters plus paged-tier sizing and verify progress."""
        out = self.cache.stats()
        out.update({"cluster_bytes": self.cluster_bytes,
                    "verified_rows": self.verified_rows,
                    "generation": self.path})
        return out


@functools.partial(jax.jit, static_argnames=("nprobe", "metric"))
def _paged_filter(ivf, q, *, nprobe: int, metric: str):
    """Stage A alone, over the resident IVF metadata (jitted)."""
    return filter_clusters(q, ivf, nprobe=nprobe, metric=metric)


@functools.partial(jax.jit,
                   static_argnames=("k", "mode", "metric", "impl",
                                    "prefilter"))
def _paged_score(index, q, base, cids, codes, *, k, mode, metric,
                 thres_scale, impl, side, prefilter, rt_grid, rt_scale):
    """Stages B+C over host-gathered codes (jitted).

    ``codes`` is the (Q, np, P, S) batch the cluster cache assembled;
    ``valid``/``ids`` are gathered here from the resident IVF arrays so
    tombstones committed after a row was cached still mask correctly.
    """
    valid = index.ivf.valid[cids]
    ids = index.ivf.point_ids[cids]
    return _score_probed(index, q, base, cids, codes, valid, ids, k=k,
                         mode=mode, metric=metric, thres_scale=thres_scale,
                         impl=impl, side=side, prefilter=prefilter,
                         rt_grid=rt_grid, rt_scale=rt_scale)


@functools.partial(jax.jit,
                   static_argnames=("k", "metric", "impl", "rerank",
                                    "fused", "fused3", "prefilter"))
def _paged_score_two_stage(index, q, base, cids, codes, *, k, metric,
                           thres_scale, rerank, impl, fused, side,
                           prefilter, rt_grid, rt_scale, fused3=None):
    """Mode-H2 stages over host-gathered codes (jitted); see
    :func:`_paged_score` for the gather contract."""
    valid = index.ivf.valid[cids]
    ids = index.ivf.point_ids[cids]
    return _score_probed_two_stage(
        index, q, base, cids, codes, valid, ids, k=k, metric=metric,
        thres_scale=thres_scale, rerank=rerank, impl=impl, fused=fused,
        fused3=fused3, side=side, prefilter=prefilter, rt_grid=rt_grid,
        rt_scale=rt_scale)


class PagedJunoIndex(MutableIndexBase):
    """Mutable serving wrapper over a :class:`PagedIndexData` generation.

    The control plane is the shared
    :class:`~repro.core.juno.MutableIndexBase` bookkeeping with one
    paged-tier rule: the on-disk cluster shards are read-only, so
    **every insert routes to the side buffer** (the per-cluster free
    lists are kept empty) and **deleted slots are never reused** —
    tombstones accumulate in the resident valid mask until the next
    offline rebuild lands as a new artifact generation
    (:meth:`swap_data`). ``compact()`` is therefore always a no-op here;
    draining the delta tiers is the offline rebuild's job.

    With the LSM freshness tiers enabled
    (``enable_tiers(max_minors, minor_store=...)``, see
    ``repro.core.freshness``), a full L0 no longer stalls inserts until
    the next rebuild: it is sealed into a minor generation committed
    through the :class:`~repro.build.store.ArtifactStore` and
    demand-paged back on first search touch with the same per-row sha256
    fail-closed verification the base shards get — the paged tier's
    insert headroom grows from B to B·(1 + max_minors) between rebuilds,
    while incremental folds into the sealed base naturally no-op.
    """

    def __init__(self, paged: PagedIndexData, *, side_capacity: int = 256):
        """Wrap one paged generation.

        Parameters
        ----------
        paged : PagedIndexData
            The artifact generation to serve.
        side_capacity : int
            Overflow-buffer capacity — the *only* insert headroom a
            paged index has between rebuilds.
        """
        self.paged = paged
        self.data = paged.meta
        self.rt_grid = paged.rt_grid
        self._init_bookkeeping(
            paged.meta.ivf.valid, paged.meta.ivf.point_ids,
            side_capacity=side_capacity, first_new_id=paged.first_new_id,
            n_subspaces=int(paged.meta.cluster_codes.shape[-1]))
        self._seal_clusters()

    def _seal_clusters(self) -> None:
        # read-only shards: no cluster slot is ever an insert target
        self._free = [[] for _ in self._free]

    def _labels_codes(self, pts):
        from repro.core.juno import _label_encode
        return _label_encode(pts, self.data.ivf.centroids,
                             self.data.codebook)

    def _rt_centroids(self):
        """Centroids for rt-grid reach maintenance (resident tier)."""
        return self.data.ivf.centroids

    def _apply_insert(self, cl, sl, ids, codes):
        raise RuntimeError(
            "paged cluster shards are read-only; inserts must land in the "
            "side buffer (this indicates a bookkeeping bug)")

    def _apply_delete(self, cl, sl):
        ivf = self.data.ivf._replace(
            valid=self.data.ivf.valid.at[jnp.asarray(cl),
                                         jnp.asarray(sl)].set(False))
        self.data = self.data._replace(ivf=ivf)

    def delete(self, ids) -> int:
        """Tombstone points by global id (see ``MutableIndexBase.delete``).

        Paged rule: the freed cluster slots do NOT become insert targets
        — the code shards on disk cannot be rewritten — so they stay
        dead until an offline rebuild. The resident valid mask updates
        immediately; a cached code row needs no invalidation because
        validity is applied at scoring time from the resident tier.
        """
        n = super().delete(ids)
        self._seal_clusters()
        return n

    def ensure_rt_grid(self, *, metric: str = "l2", **kw):
        """Return the artifact's rt grid; paged mode cannot build one.

        ``rt.build_grid`` calibrates against every PQ code — O(N) reads,
        exactly what paging exists to avoid — so the grid must have been
        folded into the artifact at build time
        (``save_index(rt_grid=...)``).
        """
        if self.rt_grid is None:
            raise RuntimeError(
                "paged serving cannot build an rt grid lazily (calibration "
                "decodes every point); save the grid into the artifact: "
                "save_index(path, data, config, rt_grid=build_grid(...))")
        return self.rt_grid

    # ---- hot swap --------------------------------------------------------
    def swap_data(self, new_data, *, side_capacity: int | None = None
                  ) -> None:
        """Atomically retarget serving to a new paged generation.

        ``new_data`` must be a :class:`PagedIndexData` (a rebuilt
        artifact generation, e.g. ``PagedIndexData(store.path(name,
        store.latest(name)))``). The new generation **adopts the current
        cluster cache** — same capacity, cumulative counters — with
        every cached row dropped, so no request served after the swap
        can ever read a stale generation's codes. Bookkeeping is
        rederived from the new resident metadata, the side buffer resets
        (the rebuild drained it), the id counter never goes backwards,
        and the rt grid becomes the new artifact's.
        """
        if not isinstance(new_data, PagedIndexData):
            raise TypeError(
                f"a paged index swaps to a new PagedIndexData generation, "
                f"got {type(new_data).__name__} (build the artifact "
                f"offline and wrap it)")
        new_data.adopt_cache(self.paged.cache)
        first_new = max(self._next_id, new_data.first_new_id)
        self.paged = new_data
        self.data = new_data.meta
        self.rt_grid = new_data.rt_grid
        self._init_bookkeeping(
            new_data.meta.ivf.valid, new_data.meta.ivf.point_ids,
            side_capacity=(self.side.capacity if side_capacity is None
                           else side_capacity),
            first_new_id=first_new,
            n_subspaces=int(new_data.meta.cluster_codes.shape[-1]))
        self._seal_clusters()

    # ---- query -----------------------------------------------------------
    def search(self, queries, *, nprobe: int = 16, k: int = 10,
               mode: str = "H", metric: str = "l2",
               thres_scale: float = 1.0, impl: str = "ref",
               rerank: int = 0, fused: bool = False,
               fused3: bool | None = None,
               prefilter: str = "scan", rt_scale: float = 1.0):
        """One paged search batch: filter → cache gather → shared scoring.

        The single-shot counterpart of
        :meth:`PagedAnnServeEngine._dispatch` (same three phases, no
        batching/bucketing): stage A runs jitted over the resident IVF,
        the probed clusters' codes are gathered on the host through the
        cluster cache, and the jitted scoring tail is the *same
        function* the resident path runs — so returned ids match
        resident serving (tests/test_paged.py pins this).

        Parameters
        ----------
        queries : array-like
            (Q, D) f32 query rows.
        nprobe, k, mode, metric, thres_scale, impl, rerank, fused, fused3
            As :func:`repro.core.juno.search` (``fused`` +
            ``prefilter="rt"`` serves the three-stage kernel over the
            paged codes unless ``fused3=False``).
        prefilter : str
            "scan" | "rt" — "rt" requires the artifact-stored grid.
        rt_scale : float
            Query-sphere radius knob for "rt".

        Returns
        -------
        tuple of np.ndarray
            ``(scores (Q, k), ids (Q, k))``.
        """
        if fused and mode != "H2":
            raise ValueError(f"fused=True requires mode='H2', got {mode!r}")
        q = jnp.asarray(np.atleast_2d(np.asarray(queries, np.float32)))
        nprobe = min(nprobe, self.data.ivf.centroids.shape[0])
        rt_grid = (self.ensure_rt_grid(metric=metric)
                   if prefilter == "rt" else None)
        side = self.delta_view()
        base, cids = _paged_filter(self.data.ivf, q, nprobe=nprobe,
                                   metric=metric)
        codes = jnp.asarray(self.paged.gather(np.asarray(cids)))
        if mode == "H2":
            s, ids = _paged_score_two_stage(
                self.data, q, base, cids, codes, k=k, metric=metric,
                thres_scale=thres_scale, rerank=rerank, impl=impl,
                fused=fused, fused3=fused3, side=side, prefilter=prefilter,
                rt_grid=rt_grid, rt_scale=rt_scale)
        else:
            s, ids = _paged_score(
                self.data, q, base, cids, codes, k=k, mode=mode,
                metric=metric, thres_scale=thres_scale, impl=impl,
                side=side, prefilter=prefilter, rt_grid=rt_grid,
                rt_scale=rt_scale)
        return np.asarray(s), np.asarray(ids)


class PagedAnnServeEngine(AnnServeEngine):
    """An :class:`~repro.serve.ann.AnnServeEngine` over a paged index.

    Inherits the whole request plane — knob quantization, size-bucketed
    batching, recall routing, rt probe-budget shrinking (the routing
    state reads only resident metadata) — and replaces dispatch with the
    three-phase paged pipeline: jitted stage-A filter over the resident
    tier, host gather of the probed clusters through the LRU cache,
    jitted shared scoring tail. With ``exact_rerank=C > 0`` each
    dispatch widens the paged search to C candidates and re-scores them
    exactly against the raw-vector tier before returning top-k
    (FusionANNS's final-stage split; scores become exact squared-l2
    distances / inner products).

    Mutations follow the paged rules (side-buffer inserts, tombstone
    deletes); ``swap_index`` requires an explicit new
    :class:`PagedIndexData` generation and atomically retargets the
    cluster cache to it.
    """

    def __init__(self, index, *, exact_rerank: int = 0,
                 side_capacity: int = 256, minor_store=None,
                 minor_name: str = "minors", **kw):
        """Wrap a paged index (or raw :class:`PagedIndexData`).

        Parameters
        ----------
        index : PagedIndexData or PagedJunoIndex
            The paged generation to serve (a bare ``PagedIndexData`` is
            wrapped in a :class:`PagedJunoIndex`).
        exact_rerank : int
            Candidate budget C for the exact-rerank tier (0 disables).
            Requires the index's ``PagedIndexData(vectors=...)`` source.
        side_capacity : int
            Side-buffer capacity when wrapping a bare ``PagedIndexData``.
        minor_store : repro.build.store.ArtifactStore, optional
            With ``max_minors > 0``, promoted minor generations are
            committed through this store and demand-paged back on first
            search touch (per-row sha256-verified) instead of staying
            resident — the out-of-core freshness tier. Default: minors
            stay resident (they are small: B rows each).
        minor_name : str
            Store name minors are committed under.
        **kw
            Remaining :class:`AnnServeEngine` knobs (``metric``,
            ``impl``, ``batch_buckets``, ``fused``, ``prefilter``,
            ``max_minors``, ...).
        """
        if isinstance(index, PagedIndexData):
            index = PagedJunoIndex(index, side_capacity=side_capacity)
        if not isinstance(index, PagedJunoIndex):
            raise TypeError(f"PagedAnnServeEngine serves a PagedIndexData/"
                            f"PagedJunoIndex, got {type(index).__name__}")
        if exact_rerank and index.paged.vectors is None:
            raise ValueError("exact_rerank needs a raw-vector source: "
                             "PagedIndexData(vectors=...)")
        self.exact_rerank = int(exact_rerank)
        if minor_store is not None:
            index._minor_sink = (minor_store, minor_name)
        super().__init__(index, side_capacity=side_capacity, **kw)
        if self.obs is not None:
            index.paged.bind_obs(self.obs)

    def _dispatch(self, qb, k, mode, nprobe, side):
        """One padded batch: filter jit → cache gather → scoring jit."""
        rt_grid, rt_scale = None, 1.0
        prefilter = "scan"
        if self.prefilter == "rt":
            prefilter = "rt"
            rt_grid = self.index.ensure_rt_grid(metric=self.metric)
            rt_scale = self.rt_scale
        p = self.index.data.ivf.point_ids.shape[1]
        kq = k if not self.exact_rerank else min(max(k, self.exact_rerank),
                                                 nprobe * p)
        with self._span("paged.filter", nprobe=nprobe):
            base, cids = _paged_filter(self.index.data.ivf, qb,
                                       nprobe=nprobe, metric=self.metric)
        with self._span("paged.gather"):
            codes = jnp.asarray(self.index.paged.gather(np.asarray(cids)))
        with self._span("paged.score", mode=mode):
            if mode == "H2":
                s, ids = _paged_score_two_stage(
                    self.index.data, qb, base, cids, codes, k=kq,
                    metric=self.metric, thres_scale=self.thres_scale,
                    rerank=self.FUSED_RERANK_MULT * k if self.fused else 0,
                    impl=self.impl, fused=self.fused, fused3=self.fused3,
                    side=side, prefilter=prefilter, rt_grid=rt_grid,
                    rt_scale=rt_scale)
            else:
                s, ids = _paged_score(
                    self.index.data, qb, base, cids, codes, k=kq, mode=mode,
                    metric=self.metric, thres_scale=self.thres_scale,
                    impl=self.impl, side=side, prefilter=prefilter,
                    rt_grid=rt_grid, rt_scale=rt_scale)
        if self.exact_rerank:
            s, ids = self._rerank_exact(qb, ids, k)
        return s, ids

    def _rerank_exact(self, qb, cand_ids, k):
        """Re-score top-C candidates exactly from the raw-vector tier.

        Fetches only the C candidate rows (memory-mapped), computes the
        exact metric on the host, and returns the stable top-k. Sentinel
        ids (< 0, padded probes) score ±inf and sort last; candidate
        *selection* stays the paged search's, only the final order and
        scores are exact.
        """
        ids_np = np.asarray(cand_ids)
        q_np = np.asarray(qb, np.float32)
        vecs = self.index.paged.fetch_vectors(ids_np)        # (Q, C, D)
        ok = ids_np >= 0
        if self.metric == "l2":
            d = np.sum((vecs - q_np[:, None, :]) ** 2, axis=-1)
            d = np.where(ok, d, np.inf)
            order = np.argsort(d, axis=1, kind="stable")[:, :k]
            out_s = np.take_along_axis(d, order, axis=1)
        else:
            sim = np.einsum("qcd,qd->qc", vecs, q_np)
            sim = np.where(ok, sim, -np.inf)
            order = np.argsort(-sim, axis=1, kind="stable")[:, :k]
            out_s = np.take_along_axis(sim, order, axis=1)
        return out_s, np.take_along_axis(ids_np, order, axis=1)

    def compact(self, *, rebuild: bool | str = "auto") -> int:
        """Schedule merge work; never rebuilds in-process.

        The cluster shards are read-only, so folds into the base are
        always no-ops here, and the in-process rebuild the resident
        engine escalates to would need every PQ code resident.
        ``rebuild=True`` raises to make that contract explicit; build
        the next generation offline and :meth:`swap_index` it instead.
        With the LSM tiers enabled (``max_minors > 0``) this drains the
        merge scheduler, which promotes a stuck L0 into an
        artifact-backed minor generation — the paged tier's only
        in-process way to reclaim side-buffer headroom between rebuilds.
        """
        if rebuild is True:
            raise RuntimeError(
                "paged serving cannot rebuild in-process; build the next "
                "generation offline (ArtifactStore.put) and swap_index() "
                "a new PagedIndexData")
        if self.scheduler is not None:
            return self.scheduler.drain()
        return self.index.compact()

    def swap_index(self, new_data=None) -> int:
        """Swap to a new artifact generation, retargeting the cache.

        Unlike the resident engine there is no in-process rebuild
        default — the PQ codes needed to re-encode live out-of-core —
        so ``new_data`` is required: a :class:`PagedIndexData` over the
        next generation (typically ``PagedIndexData(store.path(name,
        store.latest(name)), ...)``). The swap is atomic on the control
        path: the new generation adopts the existing cluster cache with
        all rows dropped (see :meth:`PagedIndexData.adopt_cache`), so
        post-swap requests can never mix generations. Returns the new
        engine generation number.
        """
        if new_data is None:
            raise RuntimeError(
                "paged serving cannot rebuild in-process; pass a "
                "PagedIndexData over the next artifact generation")
        gen = super().swap_index(new_data)
        if self.obs is not None:
            # the adopted cache keeps its registry handles, but the new
            # generation's fetch plane needs its own obs binding
            self.index.paged.bind_obs(self.obs)
        return gen

    def cache_stats(self) -> dict:
        """Paged-tier observability: cache + verify counters
        (see :meth:`PagedIndexData.stats`)."""
        return self.index.paged.stats()

"""Replica-fleet ANN serving with admission control and tail-latency SLOs.

:class:`AnnServeFleet` is the layer between "one serving engine" and
"heavy traffic": a **replica group × shard group** topology on top of
:class:`repro.serve.ann.AnnServeEngine`.

* **Replicas** — each replica group wraps one engine over its own copy of
  the index (reads route to exactly one replica; writes fan out to every
  replica, and the deterministic slot bookkeeping guarantees all replicas
  assign identical ids, so any replica answers any query identically).
* **Shards** — with ``shards_per_replica > 1`` each replica's engine is a
  :class:`_ShardedAnnServeEngine`: its index is a
  :class:`repro.dist.distributed_index.DistributedMutableIndex` cluster-
  sharded over a **private sub-mesh** of devices, and dispatch runs the
  existing ``make_distributed_search`` exact-merge path.
* **Routing** — least-outstanding-rows: every request goes to the healthy
  replica whose engine reports the smallest ``queued_rows``.
* **Admission control** — per-replica queues are bounded (``max_queue``
  query rows). When the least-loaded replica is full, ``policy="shed"``
  returns a typed :class:`Rejection` on the request (never an exception
  on the data plane) and ``policy="queue"`` parks the request in a fleet
  backlog that drains as capacity frees. Requests may carry a deadline;
  a request whose deadline passes while still queued is dropped *before
  compute* with a ``"deadline"`` rejection.
* **Latency tracing** — every served request's timestamp chain
  (``t_arrival → t_batch → t_compute → t_done``, stamped by the engine
  tick) feeds a streaming log-bucketed :class:`LatencyHistogram`
  (p50/p95/p99 in fixed memory) plus per-segment queue/compute/merge
  accumulators. ``benchmarks/serve_qps.py`` gates the p99 under an
  open-loop mixed query+insert overload (BENCH_fleet.json).

The failure model is routing-level: :meth:`AnnServeFleet.fail_replica`
takes a replica out of rotation and re-admits its queued requests to the
survivors — results are preserved exactly (replicas are identical).
Recovering lost *state* is the artifact store's job
(``repro.build.store`` + ``swap_index``), not this layer's.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import time
from typing import Optional

import numpy as np

from repro.core.juno import JunoIndexData
from repro.obs import Histogram as _ObsHistogram
from repro.serve.ann import AnnRequest, AnnServeEngine


class LatencyHistogram(_ObsHistogram):
    """Streaming log-bucketed latency histogram (back-compat alias).

    This class began here and was relocated to
    :class:`repro.obs.Histogram` as the registry's general histogram
    primitive; it remains as a subclass so existing imports, pickles of
    summaries, and the fleet's resettable warm-up/timed-run accounting
    keep working. Semantics are unchanged: fixed memory (one int64
    count per geometric bucket between ``lo`` and ``hi`` seconds),
    fail-closed ``merge`` comparing bucket *edges*, and ``percentile``
    returning the conservative upper bucket edge (clamped to the exact
    observed max) — an SLO gate on it can over-reject by at most one
    bucket width, never under-reject.
    """


@dataclasses.dataclass(frozen=True)
class Rejection:
    """Typed admission verdict attached to a shed/expired request.

    Returned on the request object — admission control never raises on
    the data plane, so a traffic spike degrades into explicit,
    client-visible rejections instead of exceptions mid-router.
    ``reason`` is one of ``"queue_full"`` (bounded queues all at
    capacity under ``policy="shed"``), ``"deadline"`` (expired while
    queued, dropped before compute), or ``"no_replica"`` (every replica
    marked down).
    """

    reason: str
    detail: str = ""


@dataclasses.dataclass
class FleetRequest:
    """One fleet-level request: routing envelope around an AnnRequest.

    ``status`` walks ``"queued" → "done"`` on the happy path, or
    terminally ``"shed"`` / ``"expired"`` with :attr:`rejection` set.
    ``t_arrival`` defaults to the submit time but open-loop load
    generators pass the *intended* arrival time, so measured latency
    includes schedule slip when the serving side falls behind — the
    honest open-loop convention (no coordinated omission).
    """

    rid: int
    queries: np.ndarray
    k: int = 10
    mode: str = "auto"
    nprobe: int = 0
    recall_target: float = 0.9
    deadline: Optional[float] = None     # absolute perf_counter() time
    t_arrival: float = 0.0
    replica: int = -1
    status: str = "queued"               # queued | done | shed | expired
    rejection: Optional[Rejection] = None
    inner: Optional[AnnRequest] = None

    @property
    def done(self) -> bool:
        """True once the request was served (not shed/expired)."""
        return self.status == "done"

    @property
    def ids(self) -> Optional[np.ndarray]:
        """(q, k) result ids, or None unless served."""
        return self.inner.ids if self.status == "done" else None

    @property
    def scores(self) -> Optional[np.ndarray]:
        """(q, k) result scores, or None unless served."""
        return self.inner.scores if self.status == "done" else None

    def trace(self) -> dict:
        """Per-segment latencies (seconds) of a served request.

        ``queue`` = arrival → batch formation (admission wait,
        coalescing wait, and any open-loop schedule slip), ``compute`` =
        batch formation → jitted search host-synced, ``merge`` = compute
        → results sliced back onto the request, ``total`` = arrival →
        done. Empty dict unless ``status == "done"``.
        """
        if self.status != "done" or self.inner is None:
            return {}
        i = self.inner
        return {"queue": i.t_batch - self.t_arrival,
                "compute": i.t_compute - i.t_batch,
                "merge": i.t_done - i.t_compute,
                "total": i.t_done - self.t_arrival}


class _ShardedAnnServeEngine(AnnServeEngine):
    """An AnnServeEngine whose dispatch is cluster-sharded over a sub-mesh.

    The replica-private data plane of a sharded fleet: the served index
    is a :class:`~repro.dist.distributed_index.DistributedMutableIndex`
    on a mesh built from a *subset* of the host's devices, and every
    signature dispatches through ``make_distributed_search(...,
    with_side=True)`` (exact top-k merge; the request-visible contract —
    routing, batching, timestamps — is inherited unchanged). The probe
    budget splits across shards: a resolved ``nprobe`` runs as
    ``ceil(nprobe / n_shards)`` probes per shard, so the global scanned
    work matches the unsharded engine's budget. ``fused`` / ``rt``
    serving modes are not wired through this path (ValueError).
    """

    def __init__(self, index: JunoIndexData, mesh, *,
                 side_capacity: int = 256, **kw):
        """Build the replica engine over ``mesh`` (a private sub-mesh)."""
        from repro.dist.distributed_index import DistributedMutableIndex
        if kw.get("fused") or kw.get("prefilter", "scan") != "scan":
            raise ValueError("sharded fleet replicas serve the composed "
                             "scan path only (fused/rt not wired)")
        dmi = DistributedMutableIndex(index, mesh,
                                      side_capacity=side_capacity)
        super().__init__(dmi, **kw)
        self.mesh = mesh
        self._dcache: dict = {}

    def _dispatch(self, qb, k, mode, nprobe, side):
        """One padded batch through the cached distributed searcher."""
        from repro.dist.distributed_index import make_distributed_search
        fn = self._dcache.get((k, mode, nprobe))
        if fn is None:
            local_np = max(1, math.ceil(nprobe / self.index.n_shards))
            fn = make_distributed_search(
                self.mesh, local_np, k, mode=mode, metric=self.metric,
                thres_scale=self.thres_scale, impl=self.impl,
                rerank=self.FUSED_RERANK_MULT * k if mode == "H2" else 0,
                with_side=True)
            self._dcache[(k, mode, nprobe)] = fn
        # always pass the (possibly empty) replicated delta view: the
        # sharded path has ONE signature per knob point, no side=None split
        return fn(self.index.data, qb,
                  self.index.delta_view(elide_empty=False))


class AnnServeFleet:
    """Replica-group × shard-group serving fleet over AnnServeEngine.

    See the module docstring for the full semantics. The control surface:

    * :meth:`submit` — route one request (returns a
      :class:`FleetRequest`; possibly already shed, never raises for
      load reasons).
    * :meth:`step` / :meth:`run` — expire deadlined requests, drain the
      backlog, tick every healthy replica once / until idle.
    * :meth:`insert` / :meth:`delete` / :meth:`compact` — fan the
      mutation out to every replica (identical ids asserted).
    * :meth:`fail_replica` / :meth:`restore_replica` — routing-level
      failover; queued work is re-admitted to the survivors.
    * :meth:`latency_summary` — streaming percentiles + segment means +
      admission counters.
    """

    POLICIES = ("queue", "shed")

    def __init__(self, index: JunoIndexData, *, n_replicas: int = 2,
                 shards_per_replica: int = 1, max_queue: int = 1024,
                 policy: str = "queue",
                 default_deadline_s: Optional[float] = None,
                 side_capacity: int = 256, obs=None, **engine_kw):
        """Build the fleet topology over a built index.

        Parameters
        ----------
        index : JunoIndexData or repro.serve.paged.PagedIndexData
            The built index every replica serves (each replica wraps its
            own mutable copy; arrays are shared until first mutation).
            A :class:`~repro.serve.paged.PagedIndexData` builds a fleet
            of :class:`~repro.serve.paged.PagedAnnServeEngine` replicas
            sharing ONE memory-mapped artifact and ONE hot-cluster
            cache (requires ``shards_per_replica == 1``; paged shards
            are a storage split, not a device split).
        n_replicas : int
            Replica-group count (reads route to one, writes to all).
        shards_per_replica : int
            1 → plain single-device engines; > 1 → each replica owns a
            private sub-mesh of ``shards_per_replica`` devices and
            serves through the distributed exact-merge path (requires
            ``n_replicas * shards_per_replica`` visible devices).
        max_queue : int
            Per-replica admission bound, in queued query ROWS.
        policy : str
            ``"shed"`` — reject (typed, not raised) when every healthy
            replica is at ``max_queue``; ``"queue"`` — park overflow in
            a fleet backlog that drains as capacity frees.
        default_deadline_s : float, optional
            Relative deadline attached to every request that does not
            carry its own; expired requests drop before compute.
        side_capacity : int
            Side-buffer capacity per replica.
        obs : repro.obs.Observability or bool, optional
            Fleet-level observability: each replica engine gets its own
            child registry (one shared tracer and recall probe), fleet
            admission/latency metrics land in ``obs.registry`` under the
            ``juno_fleet_*`` names, and :meth:`merged_registry` folds
            everything into one fleet view. ``True`` creates a fresh
            bundle. Default None = off.
        **engine_kw
            Forwarded to every replica's :class:`AnnServeEngine`
            (``metric``, ``batch_buckets``, ``impl``, ...).
        """
        if policy not in self.POLICIES:
            raise ValueError(f"unknown admission policy {policy!r}")
        if n_replicas < 1:
            raise ValueError("need at least one replica")
        self.policy = policy
        self.max_queue = max_queue
        self.default_deadline_s = default_deadline_s
        if obs is True:
            from repro.obs import Observability
            obs = Observability()
        self.obs = obs or None
        if self.obs is not None and self.obs.recall is not None:
            # recall gauges land in the FLEET registry (first bind wins)
            self.obs.recall.bind(self.obs.registry)
        self.engines: list[AnnServeEngine] = []

        def _ekw() -> dict:
            # per-replica engine kwargs: each replica gets its own child
            # registry so merged_registry() can fold them fail-closed
            kw = dict(engine_kw)
            if self.obs is not None:
                kw["obs"] = self.obs.child()
            return kw
        # imported lazily: the paged tier pulls in the artifact store and
        # is only needed when the caller actually serves out-of-core
        from repro.serve.paged import PagedAnnServeEngine, PagedIndexData
        if isinstance(index, PagedIndexData):
            if shards_per_replica > 1:
                raise ValueError(
                    "paged serving does not compose with device sharding "
                    "(shards_per_replica > 1): the paged tier is a storage "
                    "split; scale reads with n_replicas instead")
            for _ in range(n_replicas):
                self.engines.append(PagedAnnServeEngine(
                    index, side_capacity=side_capacity, **_ekw()))
            if self.obs is not None:
                # the replicas share ONE mmap + cluster cache: its series
                # belong to the fleet registry, not any replica's child
                # (each engine ctor bound its own — this rebind wins)
                index.bind_obs(self.obs)
        elif shards_per_replica > 1:
            import jax
            from jax.sharding import Mesh
            devs = jax.devices()
            need = n_replicas * shards_per_replica
            if len(devs) < need:
                raise ValueError(
                    f"{n_replicas}x{shards_per_replica} fleet needs {need} "
                    f"devices, have {len(devs)} (set XLA_FLAGS="
                    f"--xla_force_host_platform_device_count={need})")
            for r in range(n_replicas):
                mesh = Mesh(np.asarray(
                    devs[r * shards_per_replica:(r + 1) * shards_per_replica]
                ), ("data",))
                self.engines.append(_ShardedAnnServeEngine(
                    index, mesh, side_capacity=side_capacity, **_ekw()))
        else:
            for _ in range(n_replicas):
                self.engines.append(AnnServeEngine(
                    index, side_capacity=side_capacity, **_ekw()))
        self.n_replicas = n_replicas
        self.shards_per_replica = shards_per_replica
        self.backlog: collections.deque[FleetRequest] = collections.deque()
        self.down: set[int] = set()
        self._by_inner: dict[int, FleetRequest] = {}
        self._rid = 0
        self.hist = LatencyHistogram()
        self.seg = {"queue": 0.0, "compute": 0.0, "merge": 0.0}
        self.stats = {
            "submitted": 0, "served": 0, "shed": 0, "expired": 0,
            "rerouted": 0, "inserts": 0, "deletes": 0, "ticks": 0,
            "per_replica": [collections.Counter() for _ in range(n_replicas)],
        }

    # ---- request plane ---------------------------------------------------
    def outstanding(self, replica: int) -> int:
        """Queued query rows currently waiting on ``replica``."""
        return self.engines[replica].queued_rows

    def _pick_replica(self) -> Optional[int]:
        """Least-outstanding-rows healthy replica (None if all down)."""
        healthy = [r for r in range(self.n_replicas) if r not in self.down]
        if not healthy:
            return None
        return min(healthy, key=self.outstanding)

    def _place(self, freq: FleetRequest, replica: int) -> None:
        """Hand a request to a replica engine's queue (first or re-route)."""
        eng = self.engines[replica]
        if freq.inner is None:
            freq.inner = eng.submit(
                freq.queries, k=freq.k, mode=freq.mode, nprobe=freq.nprobe,
                recall_target=freq.recall_target)
        else:
            eng.queue.append(freq.inner)
        freq.replica = replica
        freq.status = "queued"
        self._by_inner[id(freq.inner)] = freq
        self.stats["per_replica"][replica]["admitted"] += 1

    def _admit(self, freq: FleetRequest) -> None:
        """Route/shed/backlog one request per the admission policy."""
        replica = self._pick_replica()
        if replica is None:
            freq.status = "shed"
            freq.rejection = Rejection("no_replica", "all replicas down")
            self.stats["shed"] += 1
            self._count_shed("no_replica")
            return
        if self.outstanding(replica) >= self.max_queue:
            if self.policy == "shed":
                freq.status = "shed"
                freq.rejection = Rejection(
                    "queue_full",
                    f"least-loaded replica {replica} at max_queue="
                    f"{self.max_queue} rows")
                self.stats["shed"] += 1
                self._count_shed("queue_full")
            else:
                self.backlog.append(freq)   # stays status "queued"
            return
        self._place(freq, replica)

    def _count_shed(self, reason: str) -> None:
        """Bump the per-reason fleet shed counter when obs is on."""
        if self.obs is not None:
            self.obs.registry.counter("juno_fleet_shed_total",
                                      reason=reason).inc()

    def submit(self, queries, *, k: int = 10, mode: str = "auto",
               nprobe: int = 0, recall_target: float = 0.9,
               deadline_s: Optional[float] = None,
               t_arrival: Optional[float] = None) -> FleetRequest:
        """Route one search request into the fleet.

        Same knobs as :meth:`AnnServeEngine.submit`, plus admission
        fields. NEVER raises for load reasons: an inadmissible request
        comes back with ``status="shed"`` and a typed
        :class:`Rejection`.

        Parameters
        ----------
        queries : array-like
            (q, D) f32 query rows (a single (D,) vector is promoted).
        k, mode, nprobe, recall_target
            Engine knobs, forwarded to the serving replica's router.
        deadline_s : float, optional
            Relative deadline; overrides the fleet default. A request
            still queued past its deadline is dropped before compute.
        t_arrival : float, optional
            Intended arrival time (``perf_counter`` clock) for open-loop
            load generation; defaults to now. Latency is measured from
            this point, so schedule slip counts against the server.

        Returns
        -------
        FleetRequest
            The routed request; poll ``.status`` / ``.ids`` after
            :meth:`run`.
        """
        now = time.perf_counter()
        dl = self.default_deadline_s if deadline_s is None else deadline_s
        freq = FleetRequest(
            rid=self._rid,
            queries=np.atleast_2d(np.asarray(queries, np.float32)),
            k=k, mode=mode, nprobe=nprobe, recall_target=recall_target,
            deadline=None if dl is None else now + dl,
            t_arrival=now if t_arrival is None else t_arrival)
        self._rid += 1
        self.stats["submitted"] += 1
        if self.obs is not None:
            self.obs.registry.counter("juno_fleet_submitted_total").inc()
        self._admit(freq)
        return freq

    # ---- engine ticks ----------------------------------------------------
    def _drop_expired(self, freq: FleetRequest) -> None:
        """Terminal transition for a deadline-expired queued request."""
        freq.status = "expired"
        freq.rejection = Rejection("deadline", "expired before compute")
        if freq.inner is not None:
            self._by_inner.pop(id(freq.inner), None)
        self.stats["expired"] += 1
        if self.obs is not None:
            self.obs.registry.counter("juno_fleet_expired_total").inc()

    def _expire(self, now: float) -> None:
        """Drop queued/backlogged requests whose deadline has passed."""
        for eng in self.engines:
            if not eng.queue:
                continue
            kept: collections.deque[AnnRequest] = collections.deque()
            for inner in eng.queue:
                freq = self._by_inner.get(id(inner))
                if (freq is not None and freq.deadline is not None
                        and now > freq.deadline):
                    self._drop_expired(freq)
                else:
                    kept.append(inner)
            eng.queue = kept
        if self.backlog:
            kept_b: collections.deque[FleetRequest] = collections.deque()
            for freq in self.backlog:
                if freq.deadline is not None and now > freq.deadline:
                    self._drop_expired(freq)
                else:
                    kept_b.append(freq)
            self.backlog = kept_b

    def _drain_backlog(self) -> None:
        """Admit backlogged requests while some replica has capacity."""
        while self.backlog:
            replica = self._pick_replica()
            if replica is None or self.outstanding(replica) >= self.max_queue:
                return
            self._place(self.backlog.popleft(), replica)

    def _collect(self, replica: int) -> None:
        """Fold a replica's completed requests into the fleet metrics."""
        eng = self.engines[replica]
        for inner in eng.completed:
            freq = self._by_inner.pop(id(inner), None)
            if freq is None:
                continue
            freq.status = "done"
            tr = freq.trace()
            self.hist.add(tr["total"])
            for segment in ("queue", "compute", "merge"):
                self.seg[segment] += tr[segment]
            self.stats["served"] += 1
            self.stats["per_replica"][replica]["served"] += 1
            if self.obs is not None:
                self._observe_served(freq, inner, tr, replica)
        eng.completed.clear()

    def _observe_served(self, freq: FleetRequest, inner: AnnRequest,
                        tr: dict, replica: int) -> None:
        """Registry + tracer view of one served request (obs non-None).

        The request's whole lifetime becomes a retro-stamped
        ``fleet.request`` span with queue/compute/merge children (the
        span-level extension of :meth:`FleetRequest.trace`); latency and
        segments feed the cumulative ``juno_fleet_*`` histograms, which
        unlike the legacy resettable ``hist``/``seg`` survive
        :meth:`reset_metrics`.
        """
        reg, tracer = self.obs.registry, self.obs.tracer
        reg.counter("juno_fleet_served_total",
                    replica=str(replica)).inc()
        reg.histogram("juno_fleet_request_seconds").add(tr["total"])
        for segment in ("queue", "compute", "merge"):
            reg.histogram(f"juno_fleet_{segment}_seconds").add(tr[segment])
        tid = f"fleet-{freq.rid}"
        root = tracer.record("fleet.request", freq.t_arrival, inner.t_done,
                             trace_id=tid, replica=replica,
                             mode=freq.mode, rows=freq.queries.shape[0])
        tracer.record("fleet.queue", freq.t_arrival, inner.t_batch,
                      trace_id=tid, parent=root)
        tracer.record("fleet.compute", inner.t_batch, inner.t_compute,
                      trace_id=tid, parent=root)
        tracer.record("fleet.merge", inner.t_compute, inner.t_done,
                      trace_id=tid, parent=root)

    def step(self) -> int:
        """One fleet tick: expire, drain backlog, tick every replica.

        Deadline expiry runs first, so a request that is already dead on
        arrival of the tick is dropped before any compute is spent on
        it. Returns the number of query rows served this tick.
        """
        self._expire(time.perf_counter())
        self._drain_backlog()
        rows = 0
        for r, eng in enumerate(self.engines):
            if r in self.down or not eng.queue:
                continue
            rows += eng.step()
            self._collect(r)
        self.stats["ticks"] += 1
        return rows

    @property
    def pending(self) -> bool:
        """True while any backlog or healthy-replica queue is non-empty."""
        return bool(self.backlog) or any(
            self.engines[r].queue for r in range(self.n_replicas)
            if r not in self.down)

    def run(self, max_ticks: int = 100_000) -> int:
        """Tick until the fleet is idle; returns total rows served."""
        rows = 0
        for _ in range(max_ticks):
            if not self.pending:
                break
            rows += self.step()
        return rows

    # ---- failover --------------------------------------------------------
    def fail_replica(self, replica: int) -> int:
        """Take a replica out of rotation; re-admit its queued work.

        Routing-level failover: the replica's queued requests are
        re-routed through normal admission (so they can land on any
        survivor, or shed if the survivors are saturated under
        ``policy="shed"``). Because replicas hold identical state, the
        re-routed requests return exactly the results the failed replica
        would have produced — pinned in ``tests/test_fleet.py``.

        Returns the number of requests re-admitted.
        """
        if replica in self.down:
            return 0
        self.down.add(replica)
        eng = self.engines[replica]
        moved = list(eng.queue)
        eng.queue.clear()
        n = 0
        for inner in moved:
            freq = self._by_inner.pop(id(inner), None)
            if freq is None:
                continue
            freq.replica = -1
            self._admit(freq)
            n += 1
        self.stats["rerouted"] += n
        if self.obs is not None and n:
            self.obs.registry.counter("juno_fleet_rerouted_total").inc(n)
        return n

    def restore_replica(self, replica: int) -> None:
        """Return a failed replica to the routing rotation."""
        self.down.discard(replica)

    # ---- mutation plane --------------------------------------------------
    def insert(self, points) -> list[int]:
        """Insert a point batch into EVERY replica (identical ids).

        Writes fan out so reads can route anywhere; the deterministic
        plan-then-commit bookkeeping must assign the same ids on every
        replica (asserted — divergence means replica state has forked).
        Down replicas are written too: failover here is a routing state,
        not state loss.
        """
        ids0: Optional[list[int]] = None
        for r, eng in enumerate(self.engines):
            ids = eng.insert(points)
            if ids0 is None:
                ids0 = ids
            elif ids != ids0:
                raise RuntimeError(
                    f"replica {r} id divergence: {ids[:4]} vs {ids0[:4]}")
        self.stats["inserts"] += len(ids0)
        if self.obs is not None:
            self.obs.registry.counter(
                "juno_fleet_inserts_total").inc(len(ids0))
        return ids0

    def delete(self, ids) -> int:
        """Tombstone points by id on every replica; returns the count."""
        n = 0
        for eng in self.engines:
            n = eng.delete(ids)
        self.stats["deletes"] += n
        return n

    def compact(self, **kw) -> int:
        """Run :meth:`AnnServeEngine.compact` on every replica."""
        return sum(eng.compact(**kw) for eng in self.engines)

    # ---- observability ---------------------------------------------------
    def merged_registry(self):
        """One fleet-wide metrics view: fleet + every replica registry.

        Returns a FRESH :class:`repro.obs.MetricsRegistry` built by
        fail-closed merging (``MetricsRegistry.merge``) of the fleet
        bundle's registry and each replica engine's child registry —
        counters sum, sum-aggregated gauges (queue depth) add across
        replicas, histograms fold bucket-by-bucket. The live registries
        are never mutated, so this can be called repeatedly (e.g. per
        scrape). Raises RuntimeError when the fleet was built without
        ``obs=``.
        """
        if self.obs is None:
            raise RuntimeError("fleet was built without obs=; nothing "
                               "to merge")
        from repro.obs import MetricsRegistry
        merged = MetricsRegistry()
        merged.merge(self.obs.registry)
        for eng in self.engines:
            if eng.obs is not None:
                merged.merge(eng.obs.registry)
        return merged

    def latency_summary(self) -> dict:
        """Streaming latency + admission summary of the fleet.

        Returns
        -------
        dict
            Histogram summary (``n/mean/p50/p95/p99/max`` seconds over
            *served* requests, measured arrival → done), per-segment
            means (``queue_mean``/``compute_mean``/``merge_mean``), and
            the admission counters (``served``/``shed``/``expired``/
            ``rerouted``).
        """
        out = self.hist.summary()
        served = max(1, self.stats["served"])
        out.update({f"{k}_mean": v / served for k, v in self.seg.items()})
        for key in ("served", "shed", "expired", "rerouted"):
            out[key] = self.stats[key]
        return out

    def reset_metrics(self) -> None:
        """Zero the latency histogram, segment sums and counters.

        Engine/jit state and index contents are untouched — benchmarks
        call this between the warm-up replay and the timed replay. The
        ``repro.obs`` registries are deliberately NOT reset: registry
        series are cumulative by contract (Prometheus semantics), so a
        scrape delta over them stays meaningful across resets here.
        """
        self.hist = LatencyHistogram()
        self.seg = {k: 0.0 for k in self.seg}
        for key in ("submitted", "served", "shed", "expired", "rerouted",
                    "inserts", "deletes", "ticks"):
            self.stats[key] = 0
        for counter in self.stats["per_replica"]:
            counter.clear()

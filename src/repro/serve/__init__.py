from .ann import AnnRequest, AnnServeEngine  # noqa: F401
from .engine import Request, ServeEngine  # noqa: F401
from .fleet import (AnnServeFleet, FleetRequest,  # noqa: F401
                    LatencyHistogram, Rejection)
from .paged import (ClusterCache, PagedAnnServeEngine,  # noqa: F401
                    PagedIndexData, PagedJunoIndex)

from .engine import Request, ServeEngine  # noqa: F401

"""Online ANN query serving (`repro.serve.ann`).

Mirrors the fixed-slot design of the LM ``ServeEngine``: requests enter an
async queue and every engine tick drains ONE group of compatible requests
into a single jitted search call. Three mechanisms keep the number of
compiled programs small and the batches dense:

* **Knob quantization** — per-request (k, mode, nprobe) are resolved to a
  small lattice of static jit signatures (``K_BUCKETS × modes ×
  NPROBE_BUCKETS``), so arbitrary client knobs never trigger fresh traces
  on the hot path.
* **Size-bucketed dynamic batching** — queued requests with the same
  resolved signature are coalesced into one batch, padded up to the next
  bucket in ``BATCH_BUCKETS`` (pad rows replicate the last real query, so
  they are in-distribution work whose results are sliced off).
* **Recall-target routing** — ``mode="auto"`` requests are routed to
  L/M/H2/H by the declared ``recall_target``, exposing the paper's
  quality/throughput dial as a per-request SLA knob.
* **Fused two-stage serving** (``fused=True``) — the H and H2 recall
  tiers fold onto one fused-H2 signature served by the fused
  hit-count→masked-ADC scan (``kernels.ops.fused_two_stage_scan``),
  coalescing both tiers' requests into shared batches; see ``__init__``.
* **RT-prefilter serving** (``prefilter="rt"``) — every dispatched
  search masks probes through the sphere-intersection filter
  (``repro.rt``), and the router shrinks each request's probe budget to
  the smallest ``RT_NPROBE_BUCKETS`` entry covering its queries'
  last-surviving-probe ranks (``rt.probe_budget``) — the spatial pruning
  shows up as smaller jitted scans, not just masked lanes
  (docs/serving.md).

The engine owns a :class:`repro.core.MutableJunoIndex`: ``insert``/
``delete``/``compact`` are served between ticks with no rebuild and no
change to any jitted search signature (the side buffer rides along as a
fixed-shape argument).
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import time
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.juno import (JunoIndexData, MutableIndexBase,
                             MutableJunoIndex, _search_batch,
                             _search_batch_two_stage)


@dataclasses.dataclass
class AnnRequest:
    """One queued search request (inputs + engine-filled results).

    The engine stamps a timestamp chain onto every served request —
    ``t_submit`` (queued) → ``t_batch`` (picked into a tick's batch) →
    ``t_compute`` (jitted search returned, host-materialized) →
    ``t_done`` (results sliced back onto the request) — so queue wait,
    compute, and merge time are separable per request (the fleet layer's
    latency histogram is fed from exactly these, see
    ``repro.serve.fleet``).
    """

    rid: int
    queries: np.ndarray                 # (q, D) f32
    k: int = 10
    mode: str = "auto"                  # "H" | "M" | "L" | "H2" | "auto"
    nprobe: int = 0                     # 0 → engine default for the mode
    recall_target: float = 0.9          # router input when mode == "auto"
    # filled in by the engine
    rt_probes: int = -1                 # cached rt survivor count (-1 unset)
    rt_epoch: int = -1                  # index rt_mutations the cache is for
    scores: Optional[np.ndarray] = None
    ids: Optional[np.ndarray] = None
    done: bool = False
    t_submit: float = 0.0
    t_batch: float = 0.0                # batch formation (tick picked it)
    t_compute: float = 0.0              # jitted search done (host-synced)
    t_done: float = 0.0

    @property
    def latency(self) -> float:
        """Submit → completion wall time in seconds."""
        return self.t_done - self.t_submit


class AnnServeEngine:
    """Dynamic-batching ANN serving engine over a mutable JUNO index."""

    K_BUCKETS = (10, 100)
    NPROBE_BUCKETS = (4, 8, 16, 32)
    # extended lattice the rt shrink may route DOWN onto: explicit client
    # knobs still quantize to NPROBE_BUCKETS, but a geometrically prunable
    # request deserves the finer low end (an nprobe-2 signature exists only
    # if the workload produces such queries)
    RT_NPROBE_BUCKETS = (2,) + NPROBE_BUCKETS
    BATCH_BUCKETS = (8, 32, 128)
    MODE_NPROBE = {"L": 8, "M": 8, "H2": 16, "H": 16}
    # recall_target lower bound → mode, checked in order (router table)
    ROUTES = ((0.9, "H"), (0.8, "H2"), (0.5, "M"), (0.0, "L"))
    # fused serving: rerank budget C = FUSED_RERANK_MULT · k for the shared
    # H/H2 fused signature — wide enough that the H tier keeps near-H recall
    # (tests/test_recall_matrix.py pins the floors), small enough that
    # stage 2 stays ≪ stage 1
    FUSED_RERANK_MULT = 32

    def __init__(self, index: JunoIndexData | MutableJunoIndex, *,
                 metric: str = "l2", impl: str = "ref",
                 thres_scale: float = 1.0, side_capacity: int = 256,
                 batch_buckets: tuple[int, ...] | None = None,
                 fused: bool = False, fused3: bool | None = None,
                 prefilter: str = "scan",
                 rt_scale: float = 1.0, max_minors: int = 0,
                 merge_clusters_per_step: int = 32,
                 obs=None):
        """Wrap an index (mutable or not) in a serving engine.

        Parameters
        ----------
        index : JunoIndexData or MutableJunoIndex
            The index to serve (a bare ``JunoIndexData`` is wrapped).
        metric : str
            "l2" | "ip".
        impl : str
            "ref" | "pallas" — forwarded to the search kernels.
        thres_scale : float
            Selectivity-threshold multiplier forwarded to search.
        side_capacity : int
            Overflow-buffer capacity when wrapping a bare index.
        batch_buckets : tuple of int, optional
            Dynamic-batching bucket sizes (default ``BATCH_BUCKETS``;
            use small buckets on CPU where per-query cost grows with
            batch size).
        fused : bool
            Serve the H and H2 recall tiers through the fused two-stage
            kernel path on ONE shared jit signature (see class notes).
        fused3 : bool, optional
            Three-stage dispatch override (``core.juno.search``): with
            ``fused=True`` and ``prefilter="rt"`` the engine serves the
            single-residency RT→hit-count→ADC kernel by default;
            ``False`` forces the composed rt-mask + two-stage path
            (bit-identical ids/scores — the parity baseline the
            benchmarks gate against).
        prefilter : str
            "scan" | "rt". With "rt" every dispatched search masks
            non-intersecting probes via the sphere-intersection filter
            (``repro.rt``), AND the router shrinks each request's probe
            budget to the smallest ``RT_NPROBE_BUCKETS`` entry covering
            its queries' last-surviving-probe ranks — fewer clusters
            scanned per tick for queries whose sphere the grid prunes
            well.
        rt_scale : float
            Radius knob for "rt" (monotone; large ⇒ no pruning).
        max_minors : int
            With a value > 0, enable the LSM freshness tiers
            (``repro.core.freshness``): a full L0 side buffer is
            promoted into one of up to ``max_minors`` sealed minor
            generations instead of rejecting inserts, and a
            ``MergeScheduler`` folds generations back into the base
            incrementally between ticks. 0 (default) keeps the legacy
            single-SideBuffer behavior.
        merge_clusters_per_step : int
            Fold budget per between-ticks merge step (clusters).
        obs : repro.obs.Observability or bool, optional
            Observability bundle: metrics land in ``obs.registry`` under
            the ``juno_engine_*`` names, engine ticks open nested spans
            in ``obs.tracer``, and ``obs.recall`` (when set) shadows a
            sample of served requests for online recall@k. ``True``
            creates a fresh bundle. Instrumentation is host-side only:
            no jit argument changes, no new signatures, bit-identical
            results (pinned by tests/test_obs.py). Default None = off.
        """
        # any MutableIndexBase works as the served index: the sharded
        # DistributedMutableIndex flows through here too (the fleet layer's
        # _ShardedAnnServeEngine passes one and overrides _dispatch)
        self.index = (index if isinstance(index, MutableIndexBase)
                      else MutableJunoIndex(index,
                                            side_capacity=side_capacity))
        self.metric = metric
        self.impl = impl
        self.thres_scale = thres_scale
        # observability is opt-in and host-side only (see docstring)
        if obs is True:
            from repro.obs import Observability
            obs = Observability()
        self.obs = obs or None
        #: signatures already traced, keyed (k, mode, nprobe, bucket,
        #: side-is-empty) — drives juno_engine_jit_retraces_total
        self._obs_sigs: set = set()
        if prefilter not in ("scan", "rt"):
            raise ValueError(f"unknown prefilter {prefilter!r}")
        self.prefilter = prefilter
        self.rt_scale = rt_scale
        #: cached (grid, routing_state, rt_mutations) for route(); the
        #: mutation counter invalidates it when inserts grow grid reaches
        self._rt_state = None
        if prefilter == "rt":
            self.index.ensure_rt_grid(metric=metric)
        #: between-ticks merge driver when the LSM tiers are enabled
        self.scheduler = None
        if max_minors:
            from repro.core.freshness import MergeScheduler
            self.index.enable_tiers(max_minors)
            self.scheduler = MergeScheduler(
                self.index, clusters_per_step=merge_clusters_per_step,
                registry=self.obs.registry if self.obs else None)
        #: route the high-recall tiers (H and H2) through the fused
        #: two-stage kernel path: both collapse onto ONE jit signature
        #: (mode "H2", rerank = FUSED_RERANK_MULT·k), so their requests
        #: coalesce into shared batches AND each call replaces the full
        #: masked-ADC scan / wide top-k with the fused hit-count → in-kernel
        #: threshold → compacted-rerank pipeline. H-tier results become
        #: two-stage approximations (recall floors pinned in
        #: tests/test_recall_matrix.py); H2-tier ids are unchanged only in
        #: the candidate-budget sense (C grows from 4k to 32k).
        self.fused = fused
        #: three-stage override forwarded to every H2 dispatch (None =
        #: auto: the three-stage kernel serves fused+rt requests)
        self.fused3 = fused3
        # deployment-tunable: big buckets fill a TPU's batch dim; smaller
        # buckets suit CPU where per-query cost grows with batch size
        self.batch_buckets = tuple(batch_buckets or self.BATCH_BUCKETS)
        self.queue: collections.deque[AnnRequest] = collections.deque()
        self.completed: list[AnnRequest] = []
        self._rid = 0
        #: bumped by every swap_index(); search results served after a
        #: bump come from the new index generation
        self.generation = 0
        self.stats = {"queries": 0, "requests": 0, "ticks": 0,
                      "padded_rows": 0, "inserts": 0, "deletes": 0,
                      "swaps": 0, "signatures": collections.Counter()}

    def _span(self, name: str, trace_id: str = None, **attrs):
        """Tracer span context when obs is on; no-op context otherwise."""
        if self.obs is None:
            return contextlib.nullcontext()
        return self.obs.tracer.span(name, trace_id=trace_id, **attrs)

    # ---- request plane ---------------------------------------------------
    def submit(self, queries, *, k: int = 10, mode: str = "auto",
               nprobe: int = 0, recall_target: float = 0.9) -> AnnRequest:
        """Enqueue a search request; ``step``/``run`` fills its results.

        Parameters
        ----------
        queries : array-like
            (q, D) f32 query rows (a single (D,) vector is promoted).
        k : int
            Results per query (rounded up to a ``K_BUCKETS`` entry).
        mode : str
            "H" | "M" | "L" | "H2", or "auto" to route by
            ``recall_target``.
        nprobe : int
            Explicit probe budget; 0 uses the mode default
            (``MODE_NPROBE``), then rounds onto ``NPROBE_BUCKETS``.
        recall_target : float
            Router input for ``mode="auto"`` (the per-request SLA knob).

        Returns
        -------
        AnnRequest
            The queued request; after serving, ``.scores``/``.ids`` are
            (q, k) arrays and ``.done`` is True.
        """
        req = AnnRequest(rid=self._rid, queries=np.atleast_2d(
            np.asarray(queries, np.float32)), k=k, mode=mode, nprobe=nprobe,
            recall_target=recall_target, t_submit=time.perf_counter())
        self._rid += 1
        self.queue.append(req)
        return req

    @property
    def queued_rows(self) -> int:
        """Total query rows currently waiting in this engine's queue.

        The fleet router's load signal: least-outstanding-rows balancing
        (``repro.serve.fleet``) routes each new request to the replica
        whose engine reports the smallest value here.
        """
        return sum(r.queries.shape[0] for r in self.queue)

    def route(self, req: AnnRequest) -> tuple[int, str, int]:
        """Resolve per-request knobs to one static jit signature.

        With ``fused=True`` the H recall tier folds into the H2 signature
        (see ``__init__``), so H and H2 requests batch together. With
        ``prefilter="rt"`` the probe budget additionally shrinks to the
        smallest bucket covering the request's rt survivor counts — the
        RT filter's throughput win on a batch-oriented backend: clusters
        the sphere test prunes are not merely masked, the whole jitted
        scan runs at a smaller nprobe.

        Parameters
        ----------
        req : AnnRequest
            The request to resolve (its ``rt_probes`` cache is filled on
            first call).

        Returns
        -------
        tuple
            ``(k, mode, nprobe)`` — one point of the static signature
            lattice.
        """
        mode = req.mode
        if mode == "auto":
            mode = next(m for lo, m in self.ROUTES if req.recall_target >= lo)
        if self.fused and mode == "H":
            mode = "H2"
        k = next((b for b in self.K_BUCKETS if b >= req.k), None) or req.k
        nprobe = req.nprobe or self.MODE_NPROBE[mode]
        nprobe = next((b for b in self.NPROBE_BUCKETS if b >= nprobe),
                      self.NPROBE_BUCKETS[-1])
        if self.prefilter == "rt":
            muts = getattr(self.index, "rt_mutations", 0)
            if req.rt_probes < 0 or req.rt_epoch != muts:
                # a request's cached probe budget is only valid for the
                # index mutation state it was computed against: inserts
                # grow grid reaches, so a budget cached before an insert
                # would under-probe the freshly inserted points
                from repro import rt as rt_lib
                # rebuilt lazily after swap_index() dropped it
                grid = self.index.ensure_rt_grid(metric=self.metric)
                if (self._rt_state is None or self._rt_state[0] is not grid
                        or self._rt_state[2] != muts):
                    # inserts replace the grid object (update_radii), so
                    # identity plus the mutation counter keys the cached
                    # host routing state
                    self._rt_state = (grid, rt_lib.routing_state(
                        grid, self.index.data), muts)
                with self._span("engine.rt_probe", trace_id=str(req.rid),
                                rows=req.queries.shape[0]):
                    req.rt_probes = int(rt_lib.probe_budget(
                        grid, self.index.data, req.queries,
                        metric=self.metric, scale=self.rt_scale,
                        thres_scale=self.thres_scale,
                        max_probes=nprobe, state=self._rt_state[1]).max())
                req.rt_epoch = muts
            shrunk = next((b for b in self.RT_NPROBE_BUCKETS
                           if b >= max(req.rt_probes, 1)),
                          self.RT_NPROBE_BUCKETS[-1])
            nprobe = min(nprobe, shrunk)
        nprobe = min(nprobe, self.index.data.ivf.centroids.shape[0])
        return k, mode, nprobe

    # ---- engine ticks ----------------------------------------------------
    def step(self) -> int:
        """Serve one signature group in one jitted call. Returns #queries."""
        if not self.queue:
            return 0
        if self.obs is not None:
            # queue depth sampled at tick entry; agg="sum" so the fleet
            # view adds replicas' backlogs instead of picking one
            self.obs.registry.gauge("juno_engine_queue_rows",
                                    agg="sum").set(self.queued_rows)
        with self._span("engine.tick"):
            return self._step_inner()

    def _step_inner(self) -> int:
        """One tick's pick → dispatch → merge body (inside the tick span)."""
        sig = self.route(self.queue[0])
        max_rows = self.batch_buckets[-1]
        # one linear pass: pick head-signature requests FIFO until the batch
        # budget closes; everything else keeps its order for later ticks
        picked, rest, rows, closed = [], [], 0, False
        for req in self.queue:
            if closed or self.route(req) != sig:
                rest.append(req)
                continue
            if picked and rows + req.queries.shape[0] > max_rows:
                closed = True     # preserve FIFO within the signature
                rest.append(req)
                continue
            picked.append(req)
            rows += req.queries.shape[0]
        self.queue = collections.deque(rest)
        t_batch = time.perf_counter()   # batch formed; queue wait ends here

        k, mode, nprobe = sig
        batch = np.concatenate([r.queries for r in picked], axis=0)
        # an empty delta tier contributes nothing: drop the argument so the
        # jitted program skips side scoring entirely (side=None and side≠None
        # are separate traces; crossing over costs one compile, not a
        # rebuild). With the LSM tiers enabled this is the combined
        # fixed-capacity L0 ⊕ minors view, so merge cycles never retrace.
        side = self.index.delta_view()
        # a single request larger than the top bucket is served in top-bucket
        # chunks, so the jit-signature lattice stays closed for any request
        out_s, out_i = [], []
        for lo in range(0, rows, max_rows):
            chunk = batch[lo:lo + max_rows]
            n = chunk.shape[0]
            bucket = next(b for b in self.batch_buckets if b >= n)
            if n < bucket:  # in-distribution pad rows (see module docstring)
                chunk = np.pad(chunk, ((0, bucket - n), (0, 0)), mode="edge")
            if self.obs is not None:
                self._observe_dispatch(k, mode, nprobe, bucket, n,
                                       side is None)
            with self._span("engine.dispatch", mode=mode, k=k,
                            nprobe=nprobe, bucket=bucket, rows=n):
                s, ids = self._dispatch(jnp.asarray(chunk), k, mode,
                                        nprobe, side)
                out_s.append(np.asarray(s)[:n])
                out_i.append(np.asarray(ids)[:n])
            self.stats["padded_rows"] += bucket - n
            self.stats["signatures"][(k, mode, nprobe, bucket)] += 1
        # np.asarray above forced host materialization, so this bounds the
        # jitted compute (incl. device->host) for every request in the tick
        t_compute = time.perf_counter()
        s, ids = np.concatenate(out_s), np.concatenate(out_i)

        with self._span("engine.merge", requests=len(picked)):
            off, now = 0, time.perf_counter()
            for req in picked:
                q = req.queries.shape[0]
                req.scores = s[off:off + q, :req.k]
                req.ids = ids[off:off + q, :req.k]
                req.t_batch, req.t_compute = t_batch, t_compute
                req.done, req.t_done = True, now
                off += q
                self.completed.append(req)
        self.stats["queries"] += rows
        self.stats["requests"] += len(picked)
        self.stats["ticks"] += 1
        if self.obs is not None:
            self._observe_served(picked, mode, rows)
        if self.scheduler is not None:
            # background merge: one bounded step between ticks (the same
            # control-path hook pattern as swap_index), so promotions and
            # folds amortize across serving instead of stopping the world
            self.scheduler.maybe_step()
        return rows

    def _dispatch(self, qb, k, mode, nprobe, side):
        """Run one padded batch through the jitted search for its mode."""
        rt_kw = {}
        if self.prefilter == "rt":
            rt_kw = dict(prefilter="rt",
                         rt_grid=self.index.ensure_rt_grid(metric=self.metric),
                         rt_scale=self.rt_scale)
        if mode == "H2":
            return _search_batch_two_stage(
                self.index.data, qb, nprobe=nprobe, k=k, metric=self.metric,
                thres_scale=self.thres_scale, impl=self.impl,
                fused=self.fused, fused3=self.fused3,
                rerank=self.FUSED_RERANK_MULT * k if self.fused else 0,
                side=side, **rt_kw)
        return _search_batch(
            self.index.data, qb, nprobe=nprobe, k=k, mode=mode,
            metric=self.metric, thres_scale=self.thres_scale,
            impl=self.impl, side=side, **rt_kw)

    def _observe_dispatch(self, k, mode, nprobe, bucket, n, empty_side):
        """Record per-dispatch registry metrics (obs is known non-None).

        Batch occupancy lands in ``juno_engine_batch_fill_ratio``; the
        first time a (signature, side-emptiness) combination is
        dispatched it counts as a jit retrace
        (``juno_engine_jit_retraces_total``) — side=None and side≠None
        are separate traces, so emptiness is part of the key.
        """
        reg = self.obs.registry
        reg.histogram("juno_engine_batch_fill_ratio", lo=1e-3, hi=1.0,
                      mode=mode).add(n / bucket)
        sig_key = (k, mode, nprobe, bucket, empty_side)
        if sig_key not in self._obs_sigs:
            self._obs_sigs.add(sig_key)
            reg.counter("juno_engine_jit_retraces_total").inc()

    def _observe_served(self, picked, mode, rows):
        """Record per-request metrics + spans for one served tick.

        Feeds the per-tier latency histograms (the documented registry
        form of :meth:`latency_stats`), retro-stamps one
        ``engine.enqueue`` span per request (submit → batch formation,
        i.e. queue wait), and hands a sample of requests to the recall
        probe when the bundle carries one.
        """
        reg, tracer = self.obs.registry, self.obs.tracer
        reg.counter("juno_engine_ticks_total").inc()
        reg.counter("juno_engine_queries_total").inc(rows)
        reg.counter("juno_engine_requests_total", mode=mode).inc(len(picked))
        lat = reg.histogram("juno_engine_request_seconds", mode=mode)
        h_queue = reg.histogram("juno_engine_queue_seconds")
        h_compute = reg.histogram("juno_engine_compute_seconds")
        h_merge = reg.histogram("juno_engine_merge_seconds")
        for req in picked:
            lat.add(req.latency)
            h_queue.add(req.t_batch - req.t_submit)
            h_compute.add(req.t_compute - req.t_batch)
            h_merge.add(req.t_done - req.t_compute)
            tracer.record("engine.enqueue", req.t_submit, req.t_batch,
                          trace_id=str(req.rid),
                          rows=req.queries.shape[0], mode=mode)
            if self.obs.recall is not None:
                self.obs.recall.observe(req, mode)

    def run(self, max_ticks: int = 100_000) -> int:
        """Drain the queue; returns total queries served."""
        total = 0
        for _ in range(max_ticks):
            if not self.queue:
                break
            total += self.step()
        return total

    # ---- mutation plane (control path, between ticks) --------------------
    def insert(self, points) -> list[int]:
        """Insert a (B, D) point batch into the served index.

        Runs between ticks on the control path — no rebuild, no jit
        signature change (see :class:`repro.core.MutableJunoIndex`).
        Returns the assigned global ids.
        """
        ids = self.index.insert(points)
        self.stats["inserts"] += len(ids)
        if self.obs is not None:
            self.obs.registry.counter(
                "juno_engine_inserts_total").inc(len(ids))
        return ids

    def delete(self, ids) -> int:
        """Tombstone points by global id; returns how many were removed.

        All-or-nothing: an unknown or duplicated id raises before any
        state is touched.
        """
        n = self.index.delete(ids)
        self.stats["deletes"] += n
        if self.obs is not None:
            self.obs.registry.counter("juno_engine_deletes_total").inc(n)
        return n

    def compact(self, *, rebuild: bool | str = "auto") -> int:
        """Schedule merge work instead of rebuilding the world.

        With the LSM tiers enabled (``max_minors > 0``) this drains the
        merge scheduler: L0 folds into free base slots, full L0s promote
        into minor generations, and generations fold incrementally into
        the base — a :meth:`swap_index` rebuild happens only when the
        tiers themselves are exhausted (every minor slot taken AND the
        stuck points' clusters full). Without tiers it keeps the legacy
        behavior: fold spills into already-free slots (a search no-op by
        construction), then — with ``rebuild="auto"`` (default) — any
        spills that remain stuck trigger the full rebuild so the side
        buffer always ends empty. ``rebuild=True`` forces the rebuild,
        ``rebuild=False`` never rebuilds.

        Parameters
        ----------
        rebuild : bool or "auto"
            Rebuild policy for stuck spills (see above).

        Returns
        -------
        int
            Total points moved between tiers.
        """
        if self.scheduler is not None:
            moved = self.scheduler.drain()
        else:
            moved = self.index.compact()
        stuck = self.index.side_fill
        if rebuild is True or (rebuild == "auto" and stuck):
            self.swap_index()
            moved += stuck
        return moved

    def swap_index(self, new_data=None) -> int:
        """Atomically install a rebuilt index — zero-downtime hot swap.

        Runs on the control path between ticks: requests completed
        before the call were served by the old generation, anything
        still queued (and everything after) is served by the new one,
        and no request ever observes a half-installed index. With the
        default rebuild, the side buffer is drained into the new index
        (spills re-encoded into proper cluster slots), tombstones are
        dropped, and results are preserved; the rt grid and router
        state are invalidated and rebuilt lazily, and the jitted search
        signatures stay warm whenever the rebuild kept the padded
        capacity unchanged.

        Parameters
        ----------
        new_data : JunoIndexData, optional
            The replacement index. Default: rebuild from the live state
            (``repro.build.rebuild.rebuild_index``), which preserves
            every live point. A caller-supplied index (e.g. loaded from
            a ``repro.build.store`` artifact) REPLACES the serving
            state wholesale: the side buffer and bookkeeping are reset
            to exactly what ``new_data`` contains, so any live
            mutations not already reflected in it are discarded — the
            caller owns that consistency (rebuild into the artifact
            first, or replay the mutation log after the swap).

        Returns
        -------
        int
            The new generation number.
        """
        if new_data is None:
            from repro.build.rebuild import rebuild_index
            new_data = rebuild_index(self.index)
        self.index.swap_data(new_data)
        self._rt_state = None    # routing snapshot belongs to the old grid
        self.generation += 1
        self.stats["swaps"] += 1
        if self.obs is not None:
            self.obs.registry.counter("juno_engine_swaps_total").inc()
        return self.generation

    # ---- observability ---------------------------------------------------
    def latency_stats(self) -> dict:
        """Latency percentiles over completed requests (deprecated alias).

        The ad-hoc key names here predate ``repro.obs``; the documented
        form of the same signal is the registry's per-tier
        ``juno_engine_request_seconds`` histogram (plus the
        queue/compute/merge segment histograms), populated when the
        engine is constructed with ``obs=``. This dict is kept as a
        deprecated back-compat alias for existing callers.

        Returns
        -------
        dict
            ``{"n", "p50", "p95", "p99", "max"}`` in seconds (submit →
            done), or ``{"n": 0}`` when nothing has completed. For
            streaming accounting that survives ``completed`` truncation,
            use the fleet layer's ``LatencyHistogram`` instead.
        """
        lats = sorted(r.latency for r in self.completed)
        if not lats:
            return {"n": 0}
        pick = lambda p: lats[min(len(lats) - 1, int(p * len(lats)))]  # noqa: E731
        return {"n": len(lats), "p50": pick(0.5), "p95": pick(0.95),
                "p99": pick(0.99), "max": lats[-1]}

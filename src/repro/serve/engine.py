"""Batched decode serving: fixed-slot continuous batching engine.

A ServeEngine owns B cache slots with independent per-slot positions.
Every tick runs ONE jitted decode over all slots (prompt tokens are fed
through the same decode path — "prefill-as-decode" continuous batching);
finished requests free their slot for the next queued request. This is the
standard TPU decode-serving shape: static batch, per-slot position vector,
preallocated cache — no paging required when slots own their cache region.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import ModelAPI
from repro.models.params import init_params


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    slot: int = -1
    done: bool = False
    fed: int = 0                    # prompt tokens already consumed


class ServeEngine:
    def __init__(self, model: ModelAPI, params, *, n_slots: int = 4,
                 max_seq: int = 256, key: Optional[jax.Array] = None):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.cache = init_params(model.cache_schema(n_slots, max_seq),
                                 key or jax.random.PRNGKey(0))
        self.pos = np.zeros((n_slots,), np.int32)
        self.slot_req: list = [None] * n_slots
        self.queue: list = []
        self._decode = jax.jit(model.decode, donate_argnums=1)

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for slot in range(self.n_slots):
            if self.slot_req[slot] is None and self.queue:
                req = self.queue.pop(0)
                req.slot, req.fed = slot, 0
                self.pos[slot] = 0
                self.slot_req[slot] = req

    def step(self) -> int:
        """One engine tick: one token for every active slot, in one call."""
        self._admit()
        tokens = np.zeros((self.n_slots, 1), np.int32)
        active = []
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            active.append(slot)
            if req.fed < len(req.prompt):                  # still prefilling
                tokens[slot, 0] = req.prompt[req.fed]
            else:                                          # generating
                tokens[slot, 0] = req.out[-1]
        if not active:
            return 0

        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(self.pos))
        logits = np.asarray(logits)

        for slot in active:
            req = self.slot_req[slot]
            self.pos[slot] += 1
            if req.fed < len(req.prompt):
                req.fed += 1
                if req.fed < len(req.prompt):
                    continue                               # keep prefilling
            req.out.append(int(np.argmax(logits[slot])))
            if len(req.out) >= req.max_new or self.pos[slot] >= self.max_seq - 1:
                req.done = True
                self.slot_req[slot] = None
        return len(active)

    def run(self, max_ticks: int = 10_000):
        ticks = 0
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and ticks < max_ticks:
            self.step()
            ticks += 1
        return ticks

"""Synthetic ANN datasets statistically matched to the paper's benchmarks.

SIFT1M / DEEP1M / TTI1M are not redistributable offline, so we synthesize
anisotropic Gaussian-mixture stand-ins whose two properties JUNO exploits are
present by construction: (i) IVF-cluster imbalance (power-law cluster sizes)
and (ii) PQ-entry sparsity/locality (points concentrate near their cluster
centre, so top-k entries are spatially local in each subspace).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    dim: int
    metric: str          # "l2" | "ip"
    n_modes: int = 256   # latent mixture components
    anisotropy: float = 4.0
    power: float = 1.5   # cluster-size power law exponent


SIFT_LIKE = DatasetSpec("sift-like", 128, "l2")
DEEP_LIKE = DatasetSpec("deep-like", 96, "l2")
TTI_LIKE = DatasetSpec("tti-like", 200, "ip", n_modes=128)


def make_dataset(spec: DatasetSpec, n_points: int, n_queries: int,
                 key: jax.Array | None = None):
    """Returns (points (N, D) f32, queries (Q, D) f32)."""
    if key is None:
        key = jax.random.PRNGKey(42)
    k_mu, k_scale, k_assign, k_pts, k_q, k_rot = jax.random.split(key, 6)
    d, g = spec.dim, spec.n_modes

    mu = jax.random.normal(k_mu, (g, d)) * 4.0
    # anisotropic per-mode scales: a few directions dominate (like real
    # descriptor data after PCA) — drives the entry-locality the paper sees.
    scales = jnp.exp(jax.random.normal(k_scale, (g, d)) *
                     jnp.log(spec.anisotropy) / 2.0)
    # power-law mode weights -> imbalanced IVF clusters
    w = jnp.arange(1, g + 1, dtype=jnp.float32) ** (-spec.power)
    w = w / jnp.sum(w)

    assign = jax.random.choice(k_assign, g, shape=(n_points,), p=w)
    eps = jax.random.normal(k_pts, (n_points, d))
    points = mu[assign] + eps * scales[assign]

    qassign = jax.random.choice(k_q, g, shape=(n_queries,), p=w)
    qeps = jax.random.normal(jax.random.fold_in(k_q, 1), (n_queries, d))
    queries = mu[qassign] + qeps * scales[qassign] * 1.1

    if spec.metric == "ip":  # normalise magnitude spread for MIPS realism
        norm = jnp.linalg.norm(points, axis=-1, keepdims=True)
        points = points / jnp.maximum(norm, 1e-6) * (
            1.0 + 0.3 * jax.random.uniform(k_rot, (n_points, 1)))
        queries = queries / jnp.maximum(
            jnp.linalg.norm(queries, axis=-1, keepdims=True), 1e-6)
    return points.astype(jnp.float32), queries.astype(jnp.float32)

from .synthetic import (DatasetSpec, SIFT_LIKE, DEEP_LIKE, TTI_LIKE,  # noqa: F401
                        make_dataset)

"""Deterministic synthetic token pipeline.

Batches are pure functions of (seed, step, shard): restart-exact replay with
zero pipeline state (the property fault_tolerance.py relies on). The
generator is Zipfian over the vocab with a shifted-window correlation so the
LM loss actually decreases during smoke training.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def make_batch(cfg, *, batch: int, seq: int, step: int, seed: int = 0,
               shard: int = 0, n_shards: int = 1) -> dict:
    key = jax.random.fold_in(jax.random.fold_in(
        jax.random.PRNGKey(seed), step), shard)
    v = cfg.vocab_size
    # zipf-ish marginal via squared uniform
    u = jax.random.uniform(key, (batch, seq + 1))
    toks = jnp.minimum((u * u * v).astype(jnp.int32), v - 1)
    # inject copy structure: every 4th token repeats t-2 (learnable signal)
    idx = jnp.arange(seq + 1)
    toks = jnp.where((idx % 4 == 0) & (idx >= 2),
                     jnp.roll(toks, 2, axis=1), toks)
    batch_d = {"tokens": toks[:, :seq], "targets": toks[:, 1:]}
    if cfg.encoder_decoder:
        kf = jax.random.fold_in(key, 1)
        batch_d["frames"] = jax.random.normal(
            kf, (batch, cfg.n_context_tokens, cfg.d_model), jnp.float32
        ).astype(cfg.dtype)
    elif cfg.cross_attn_period:
        kf = jax.random.fold_in(key, 2)
        batch_d["context"] = jax.random.normal(
            kf, (batch, cfg.n_context_tokens, cfg.d_model), jnp.float32
        ).astype(cfg.dtype)
    return batch_d

"""Online rebuild: drain the side buffer and tombstones (`repro.build.rebuild`).

The PR 2 mutability story left one gap: a :class:`~repro.core.juno.SideBuffer`
spill is scored exactly like an in-cluster point, but it costs an extra
(Q, B) gather on EVERY search, and ``compact()`` can only fold spills back
when deletes happen to free slots in the right clusters. This module closes
the loop: :func:`rebuild_index` re-packs every live point — in-cluster
survivors keep their slot order, side points are re-encoded into proper
slots of their owning cluster, tombstoned ids are dropped — into a fresh
:class:`~repro.core.juno.JunoIndexData`, growing the padded capacity only
when the live fill demands it (an unchanged capacity keeps every jitted
search signature warm across the swap).

Because side points were already scored with the identical masked-LUT /
hit-table gather an in-cluster sibling receives, the rebuilt index returns
the same search results as the pre-rebuild (base ⊕ side ⊖ tombstones)
state — bit-identical scores, ids equal up to ``lax.top_k``'s index-order
tie-break among exactly equal scores (tests/test_build.py pins it).

``AnnServeEngine.swap_index()`` installs the result atomically between
ticks; ``DistributedMutableIndex.rebuild_shard()`` applies the same repack
per cluster shard through the routed row scatter.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.juno import JunoIndexData, MutableIndexBase


def _reconstructed_sq(centroids, codebook, labels, codes) -> np.ndarray:
    """|centroid + decode(code)|^2 for points whose raw vector is gone."""
    from repro.core.pq import decode
    pts = centroids[labels] + np.asarray(
        decode(jnp.asarray(codes), codebook))
    return np.sum(pts * pts, axis=-1).astype(np.float32)


def live_points(mid: MutableIndexBase, point_ids: np.ndarray,
                valid: np.ndarray, cluster_codes: np.ndarray,
                clusters: range | None = None
                ) -> list[list[tuple[int, np.ndarray]]]:
    """Per-cluster live (id, code) lists for a mutable index snapshot.

    In-cluster points come first in slot order, then drained delta-tier
    points — the L0 side buffer followed by each minor generation, in
    position order (``delta_snapshot``) — the deterministic repack order
    both the single-device and per-shard rebuilds share. A rebuild
    therefore folds minor generations into the base exactly like side
    spills: the escalation path can never lose tiered points.

    Parameters
    ----------
    mid : MutableIndexBase
        The live index (supplies the delta tiers).
    point_ids, valid, cluster_codes : np.ndarray
        Host snapshots of the padded storage ((C, P), (C, P), (C, P, S)).
    clusters : range, optional
        Restrict the scan to these cluster ids (a per-shard rebuild only
        repacks its own slice; default: all clusters). Entries outside
        the range stay empty.

    Returns
    -------
    list of list
        ``out[c]`` = ordered ``(global_id, (S,) uint8 code)`` pairs.
    """
    n_clusters = point_ids.shape[0]
    if clusters is None:
        clusters = range(n_clusters)
    out: list[list[tuple[int, np.ndarray]]] = [[] for _ in range(n_clusters)]
    for c in clusters:
        for slot in np.where(valid[c])[0]:
            out[c].append((int(point_ids[c, slot]), cluster_codes[c, slot]))
    d_valid, d_cluster, d_ids, d_codes = mid.delta_snapshot()
    for pos in np.where(d_valid)[0]:
        c = int(d_cluster[pos])
        if clusters.start <= c < clusters.stop:
            out[c].append((int(d_ids[pos]), d_codes[pos]))
    return out


def rebuild_index(mid: MutableIndexBase, *,
                  min_capacity: int | None = None) -> JunoIndexData:
    """Re-pack a mutable index's live state into a fresh immutable index.

    Centroids, PQ codebooks and the density model are carried over
    unchanged (no retraining — inserts were encoded with the existing
    codebooks, so their codes stay valid); only the padded storage is
    rewritten: tombstoned slots vanish, side-buffer points land in real
    slots of their owning cluster, and the flat ``codes``/``labels``/
    ``points_sq`` arrays grow to cover every id ever assigned (rows of
    deleted ids keep their last-known values — stale but unreachable,
    and only ever read by conservative consumers like ``rt.build_grid``
    reach measurement).

    Parameters
    ----------
    mid : MutableIndexBase
        A :class:`~repro.core.juno.MutableJunoIndex` (or the distributed
        variant) whose live state to drain.
    min_capacity : int, optional
        Floor for the new padded capacity P. Default: keep the current
        capacity (preserving every jitted search signature) unless the
        densest cluster no longer fits, in which case P grows to the
        next multiple of 8 plus one insert-headroom row of 8.

    Returns
    -------
    JunoIndexData
        The rebuilt index; global point ids are preserved, so post-swap
        searches return the pre-swap (base ⊕ side ⊖ tombstones) results.
    """
    data = mid.data
    point_ids = np.asarray(data.ivf.point_ids)
    valid = np.asarray(data.ivf.valid)
    cluster_codes = np.asarray(data.cluster_codes)
    centroids = np.asarray(data.ivf.centroids)
    n_clusters, old_cap = point_ids.shape
    n_sub = cluster_codes.shape[-1]

    per_cluster = live_points(mid, point_ids, valid, cluster_codes)
    max_fill = max((len(members) for members in per_cluster), default=0)
    cap = max(old_cap, min_capacity or 0)
    if max_fill > cap:
        cap = ((max_fill + 7) // 8) * 8 + 8

    # flat arrays over every id ever assigned (next_id is the watermark)
    n_old = int(data.codes.shape[0])
    n_ids = max(n_old, int(mid._next_id))
    codes_all = np.zeros((n_ids, n_sub), np.uint8)
    codes_all[:n_old] = np.asarray(data.codes)
    labels_all = np.zeros((n_ids,), np.int32)
    labels_all[:n_old] = np.asarray(data.ivf.labels)
    psq_all = np.zeros((n_ids,), np.float32)
    psq_all[:n_old] = np.asarray(data.points_sq)

    new_ids = np.full((n_clusters, cap), -1, np.int32)
    new_codes = np.zeros((n_clusters, cap, n_sub), np.uint8)
    recon_ids, recon_labels, recon_codes = [], [], []
    for c, members in enumerate(per_cluster):
        for slot, (pid, code) in enumerate(members):
            new_ids[c, slot] = pid
            new_codes[c, slot] = code
            codes_all[pid] = code
            labels_all[pid] = c
            if pid >= n_old:   # inserted id: |p|^2 must be reconstructed
                recon_ids.append(pid)
                recon_labels.append(c)
                recon_codes.append(code)
    if recon_ids:
        psq_all[np.asarray(recon_ids)] = _reconstructed_sq(
            centroids, data.codebook, np.asarray(recon_labels),
            np.stack(recon_codes))

    ids_j = jnp.asarray(new_ids)
    return data._replace(
        ivf=data.ivf._replace(point_ids=ids_j, valid=ids_j >= 0,
                              labels=jnp.asarray(labels_all)),
        codes=jnp.asarray(codes_all),
        cluster_codes=jnp.asarray(new_codes),
        points_sq=jnp.asarray(psq_all))

"""Incremental fold of minor delta generations into the base index.

This is the mechanics half of the LSM freshness engine
(``repro.core.freshness`` holds the tiers and the policy driver).
:func:`fold_step` moves live points of the oldest minor generations into
already-free padded slots of their owning clusters — bounded,
per-cluster work (one row scatter per generation touched), instead of
``rebuild_index``'s stop-the-world escalation. On a sharded index a
``lane`` restricts the fold to one shard's cluster range so each step's
scatter lands on a single shard.

The module also owns the on-disk format for artifact-backed minors:
:func:`commit_minor` writes a generation through the same
tmp-dir → fsync → atomic-rename discipline as
:meth:`~repro.build.store.ArtifactStore.put`, with a per-row
``sha256_rows`` manifest; :func:`minor_codes_loader` gives the matching
verify-on-first-touch fault-in used by the paged tier.
"""
from __future__ import annotations

import errno
import json
import os
import shutil
import uuid
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from .store import ArtifactError, ArtifactStore, _array_digest, _fsync_dir

MINOR_SCHEMA = 1
_MINOR_ARRAYS = "minor.npz"
_MINOR_MANIFEST = "manifest.json"


def fold_step(mid, *, max_clusters: int = 32,
              lane: Optional[tuple[int, int]] = None) -> int:
    """Fold minor-generation points into free base slots of their clusters.

    Walks generations oldest-first; for each, groups live positions by
    owning cluster and moves up to ``len(_free[c])`` of them into that
    cluster's freed padded slots, touching at most ``max_clusters``
    clusters total. Commit ordering matches ``insert``/``compact``:
    plan, validate the plan fail-closed (duplicate free slots raise
    RuntimeError with nothing mutated), apply the device scatter, then
    run the infallible host bookkeeping. Generations left with zero live
    points are dropped. On a read-only base (the paged tier seals every
    free list) this is a cheap no-op.

    Parameters
    ----------
    mid : MutableIndexBase
        Tier-enabled mutable index.
    max_clusters : int
        Budget: number of clusters folded in this call.
    lane : (lo, hi) or None
        Restrict the fold to clusters in ``[lo, hi)`` — one shard's
        range when driven by a per-shard scheduler.

    Returns
    -------
    int
        Number of points moved into the base.
    """
    budget = int(max_clusters)
    moved = 0
    for m in list(getattr(mid, "_minors", [])):
        if budget <= 0:
            break
        pos_all = np.where(m.valid)[0]
        if lane is not None:
            lo, hi = lane
            keep = (m.cluster[pos_all] >= lo) & (m.cluster[pos_all] < hi)
            pos_all = pos_all[keep]
        if pos_all.size == 0:
            continue
        cl: list[int] = []
        sl: list[int] = []
        pos_l: list[int] = []
        plan: list[tuple[int, int]] = []
        for c in np.unique(m.cluster[pos_all]):
            if budget <= 0:
                break
            c = int(c)
            free = mid._free[c]
            if not free:
                continue
            ppos = pos_all[m.cluster[pos_all] == c][:len(free)]
            slots = free[-len(ppos):][::-1]
            cl += [c] * len(ppos)
            sl += [int(s) for s in slots]
            pos_l += [int(p) for p in ppos]
            plan.append((c, len(ppos)))
            budget -= 1
        if not pos_l:
            continue
        if len(set(zip(cl, sl))) != len(sl):
            raise RuntimeError(
                "fold plan references a base slot twice (corrupted free "
                "list / double-free); refusing to fold")
        codes = m.materialize()          # verified fault-in when disk-backed
        pos_j = jnp.asarray(np.asarray(pos_l))
        mid._apply_insert(cl, sl, m.ids[pos_l].astype(np.int32),
                          jnp.asarray(codes)[pos_j])
        # infallible host commit
        for c, take in plan:
            del mid._free[c][-take:]
        for c, slot, pos in zip(cl, sl, pos_l):
            mid._loc[int(m.ids[pos])] = (c, slot)
        m.valid[np.asarray(pos_l)] = False
        moved += len(pos_l)
    if moved:
        mid._minors = [m for m in mid._minors if m.live]
        mid._delta_epoch += 1
    return moved


def save_minor(path: str, codes: np.ndarray, cluster: np.ndarray,
               ids: np.ndarray, valid: np.ndarray, *, gen: int) -> dict:
    """Write one minor generation (arrays + manifest) into ``path``.

    The manifest carries whole-array sha256 digests plus per-row
    ``sha256_rows`` over the PQ codes, mirroring ``save_index`` so the
    demand-paged fault-in can verify rows the same way base shards are
    verified.

    Returns the manifest dict.
    """
    os.makedirs(path, exist_ok=True)
    codes = np.ascontiguousarray(codes, np.uint8)
    cluster = np.ascontiguousarray(cluster, np.int32)
    ids = np.ascontiguousarray(ids, np.int32)
    valid = np.ascontiguousarray(valid, bool)
    arrays = {"codes": codes, "cluster": cluster, "ids": ids, "valid": valid}
    manifest = {
        "minor_schema": MINOR_SCHEMA,
        "gen": int(gen),
        "capacity": int(ids.shape[0]),
        "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype),
                       "sha256": _array_digest(v)}
                   for k, v in arrays.items()},
        "sha256_rows": [_array_digest(row) for row in codes],
    }
    np.savez(os.path.join(path, _MINOR_ARRAYS), **arrays)
    with open(os.path.join(path, _MINOR_MANIFEST), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    return manifest


def commit_minor(store: ArtifactStore, name: str, codes: np.ndarray,
                 cluster: np.ndarray, ids: np.ndarray, valid: np.ndarray,
                 *, gen: int, max_attempts: int = 32) -> str:
    """Atomically commit a minor generation under ``store.root/name``.

    Same crash-safe discipline as :meth:`ArtifactStore.put`: write into a
    unique temp dir, fsync every file and the directory, then rename
    into the next free ``v%04d`` slot (retrying on collision with a
    concurrent writer). A failure at any point leaves no committed
    version behind.

    Returns the committed version directory path.
    """
    base = os.path.join(store.root, name)
    os.makedirs(base, exist_ok=True)
    tmp = os.path.join(base, f".tmp-{os.getpid()}-{uuid.uuid4().hex[:8]}")
    try:
        save_minor(tmp, codes, cluster, ids, valid, gen=gen)
        for fname in os.listdir(tmp):
            with open(os.path.join(tmp, fname), "rb") as fh:
                os.fsync(fh.fileno())
        _fsync_dir(tmp)
        for _ in range(max_attempts):
            version = (store.latest(name) or 0) + 1
            dst = store.path(name, version)
            try:
                os.rename(tmp, dst)
            except OSError as e:
                if e.errno not in (errno.EEXIST, errno.ENOTEMPTY,
                                   errno.ENOTDIR, errno.EISDIR):
                    raise
                continue  # lost the race for this generation number
            _fsync_dir(base)
            return dst
        raise ArtifactError(
            f"could not commit minor generation under {base!r}: "
            f"{max_attempts} version slots taken by concurrent writers")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def load_minor(path: str, *, verify_rows: bool = True):
    """Load a minor generation from disk, verifying digests fail-closed.

    Raises :class:`ArtifactError` on a missing/alien manifest, an array
    set mismatch, or (with ``verify_rows``) any PQ code row whose sha256
    does not match the manifest — corruption surfaces as an error, never
    as garbage candidates.

    Returns ``(codes, cluster, ids, valid, manifest)`` as host arrays.
    """
    mpath = os.path.join(path, _MINOR_MANIFEST)
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise ArtifactError(f"unreadable minor manifest {mpath!r}: {e}")
    if manifest.get("minor_schema") != MINOR_SCHEMA:
        raise ArtifactError(
            f"{mpath!r} is not a minor generation "
            f"(minor_schema={manifest.get('minor_schema')!r})")
    with np.load(os.path.join(path, _MINOR_ARRAYS)) as z:
        if set(z.files) != set(manifest["arrays"]):
            raise ArtifactError(
                f"minor array set mismatch in {path!r}: "
                f"{sorted(z.files)} vs {sorted(manifest['arrays'])}")
        codes = z["codes"]
        cluster = z["cluster"]
        ids = z["ids"]
        valid = z["valid"]
    if verify_rows:
        rows = manifest.get("sha256_rows")
        if rows is None or len(rows) != codes.shape[0]:
            raise ArtifactError(
                f"minor manifest {mpath!r} lacks per-row digests")
        for i, row in enumerate(codes):
            if _array_digest(np.ascontiguousarray(row)) != rows[i]:
                raise ArtifactError(
                    f"sha256 mismatch on minor code row {i} in {path!r}: "
                    f"artifact corrupt")
    return codes, cluster, ids, valid, manifest


def minor_codes_loader(path: str) -> Callable[[], jnp.ndarray]:
    """First-touch fault-in for an artifact-backed minor generation.

    The returned thunk reads the generation's PQ codes from ``path``,
    verifies every row's sha256 against the manifest (raising
    :class:`ArtifactError` on corruption), and returns them as a device
    array — the paged tier's fail-closed contract, applied to minors.
    """
    def load() -> jnp.ndarray:
        codes, _, _, _, _ = load_minor(path, verify_rows=True)
        return jnp.asarray(np.ascontiguousarray(codes))
    return load

"""Streaming, memory-bounded JUNO index construction (`repro.build.pipeline`).

The in-memory ``core.build`` holds the full (N, D) point set plus every
intermediate (residuals, codes) at once — fine at 10^5 points, hopeless at
the paper's 10^7-10^8. This pipeline makes two passes over a re-iterable
chunk source and never materialises more than one chunk of raw points (plus
the bounded training sample):

pass 1  reservoir-sample ``max_train_points`` rows (uniform, deterministic)
        and count N. Train IVF centroids (``kmeans_subsampled``) and the
        residual PQ codebook on the sample; fix the density grid's bounding
        box from the sample's residual projections; draw the calibration
        queries from the sample. When the sample covers the whole set
        (N <= max_train_points) AND no cluster overflows its padded
        capacity, training, box and queries match the in-memory build bit
        for bit; an overflow spill keeps recall parity but not bit
        identity (``core.build`` retrains on post-spill residuals, the
        stream trains pre-spill and patches — see pass 3).

pass 2  per chunk, under one jit: chunked assignment (the ``|x-c|^2``
        MXU expansion), residual PQ encoding, density-histogram
        accumulation, ``|p|^2`` — while a streaming exact top-k merge
        accumulates the calibration queries' ground truth. Only O(N)
        bytes of codes/labels accumulate on the host.

finalize  padded cluster layout (shared ``ivf.padded_layout`` spill pass),
        threshold-regressor fit on the streamed grid
        (``density.calibrate_from_grid``), bit-compatible
        :class:`repro.core.juno.JunoIndexData` out.

Every chunk that enters the pipeline is recorded on a :class:`BuildProbe`,
so tests assert the memory bound structurally (max resident chunk rows)
instead of scraping RSS.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Iterable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import density as density_lib
from repro.core.ivf import IVFIndex, cluster_capacity, padded_layout
from repro.core.juno import (JunoConfig, JunoIndexData, _calib_query_subspaces,
                             _calib_tau_needed)
from repro.core.kmeans import kmeans_subsampled
from repro.core.pq import encode, split_subspaces, train_codebook

#: rows per jitted encode call inside a chunk (bounds the (B, C) distance
#: matrix at ~`_EVAL_ROWS * C * 4` bytes regardless of the chunk budget)
_EVAL_ROWS = 8192


@dataclasses.dataclass
class BuildProbe:
    """Structural memory-bound instrumentation for the streaming build.

    Attributes
    ----------
    passes : int
        Completed passes over the chunk source (2 for a spill-free
        build; 3 when overflow spill forced targeted re-encoding).
    chunks : int
        Total chunks consumed across all passes.
    max_chunk_rows : int
        Largest single chunk seen — the raw-point residency bound: the
        pipeline never holds more than this many (D,)-rows of input at
        once beyond the training sample.
    train_rows : int
        Rows held in the bounded training sample (<= max_train_points).
    n_points : int
        Total rows streamed (N).
    """

    passes: int = 0
    chunks: int = 0
    max_chunk_rows: int = 0
    train_rows: int = 0
    n_points: int = 0

    def note_chunk(self, rows: int) -> None:
        """Record one consumed chunk of ``rows`` points."""
        self.chunks += 1
        self.max_chunk_rows = max(self.max_chunk_rows, rows)


def array_source(points, chunk_points: int = 65536
                 ) -> Callable[[], Iterator[np.ndarray]]:
    """Wrap an in-memory / memory-mapped (N, D) array as a chunk source.

    Parameters
    ----------
    points : array-like
        (N, D) array; ``np.memmap`` works — slices are materialised one
        chunk at a time.
    chunk_points : int
        Rows per yielded chunk.

    Returns
    -------
    callable
        Zero-arg callable returning a fresh chunk iterator (the pipeline
        makes two passes, so the source must be re-iterable).
    """
    def it() -> Iterator[np.ndarray]:
        n = points.shape[0]
        for lo in range(0, n, chunk_points):
            yield np.asarray(points[lo:lo + chunk_points], np.float32)
    return it


def _chunks(source) -> Iterator[np.ndarray]:
    """One pass over a chunk source (callable or re-iterable)."""
    it: Iterable = source() if callable(source) else source
    for chunk in it:
        arr = np.asarray(chunk, np.float32)
        if arr.ndim != 2:
            raise ValueError(f"chunk must be (B, D), got {arr.shape}")
        if arr.shape[0]:
            yield arr


def _reservoir_extend(sample: np.ndarray, fill: int, seen: int,
                      chunk: np.ndarray, rng: np.random.Generator
                      ) -> tuple[int, int]:
    """Vectorised reservoir sampling (algorithm R) over one chunk.

    Mutates ``sample`` in place; returns the new (fill, seen). While the
    reservoir is not yet full, rows are appended in stream order — so for
    N <= capacity the sample IS the stream, and sample-trained stages
    match the in-memory build bit for bit.
    """
    cap = sample.shape[0]
    b = chunk.shape[0]
    take = min(cap - fill, b)
    if take:
        sample[fill:fill + take] = chunk[:take]
        fill += take
    if take < b:
        rest = chunk[take:]
        idx = seen + take + np.arange(rest.shape[0])
        accept = rng.integers(0, idx + 1) < cap
        slots = rng.integers(0, cap, size=int(accept.sum()))
        sample[slots] = rest[accept]
    return fill, seen + b


@jax.jit
def _encode_chunk(pts, centroids, codebook, counts, lo, hi, n_valid):
    """labels, codes, density counts and |p|^2 for one padded chunk.

    One jitted program per (chunk-shape) signature: nearest-centroid
    assignment via the MXU expansion, residual PQ encode, histogram
    accumulation (pad rows weighted out), squared norms.
    """
    c_sq = jnp.sum(centroids * centroids, axis=-1)
    d = c_sq[None, :] - 2.0 * pts @ centroids.T
    labels = jnp.argmin(d, axis=-1).astype(jnp.int32)
    res = pts - centroids[labels]
    codes = encode(res, codebook)
    sub = jnp.swapaxes(split_subspaces(res, codebook.sub_dim), 0, 1)
    w = (jnp.arange(pts.shape[0]) < n_valid).astype(jnp.float32)
    counts = density_lib.accumulate_density_counts(counts, sub, lo, hi, w)
    return labels, codes, counts, jnp.sum(pts * pts, axis=-1)


@functools.partial(jax.jit, static_argnames=("metric",))
def _merge_topk(best_s, best_i, queries, chunk_pts, base, n_valid, *, metric):
    """Fold one chunk into the calibration queries' running exact top-k.

    Same internal score convention as ``core.ref.exact_topk`` (l2 drops
    the |q|^2 rank-only term; internally higher-is-better), so the merged
    ground-truth ids match the oracle's.
    """
    dots = queries @ chunk_pts.T                             # (Q, B)
    if metric == "l2":
        p_sq = jnp.sum(chunk_pts * chunk_pts, axis=-1)
        scores = -(p_sq[None, :] - 2.0 * dots)
    else:
        scores = dots
    b = chunk_pts.shape[0]
    ids = base + jnp.arange(b, dtype=jnp.int32)[None, :]
    scores = jnp.where(jnp.arange(b)[None, :] < n_valid, scores, -jnp.inf)
    cat_s = jnp.concatenate([best_s, scores], axis=1)
    cat_i = jnp.concatenate(
        [best_i, jnp.broadcast_to(ids, (best_s.shape[0], b))], axis=1)
    top_s, sel = jax.lax.top_k(cat_s, best_s.shape[1])
    return top_s, jnp.take_along_axis(cat_i, sel, axis=1)


class _EvalBatcher:
    """Regroup arbitrary chunk sizes into fixed ``_EVAL_ROWS`` jit batches.

    At most two jit signatures exist per build: the full eval batch and
    one final partial flush — chunk-size heterogeneity never retraces.
    """

    def __init__(self, d: int, rows: int = _EVAL_ROWS):
        self.buf = np.empty((rows, d), np.float32)
        self.fill = 0

    def feed(self, chunk: np.ndarray):
        """Yield (batch, n_valid) eval batches as the chunk fills them."""
        pos = 0
        rows = self.buf.shape[0]
        while pos < chunk.shape[0]:
            take = min(rows - self.fill, chunk.shape[0] - pos)
            self.buf[self.fill:self.fill + take] = chunk[pos:pos + take]
            self.fill += take
            pos += take
            if self.fill == rows:
                yield self.buf, rows
                self.fill = 0

    def flush(self):
        """Yield the final partial batch, edge-padded to a static shape."""
        if self.fill:
            self.buf[self.fill:] = self.buf[self.fill - 1]
            yield self.buf, self.fill
            self.fill = 0


def _gather_rows(source, ids: np.ndarray, probe: BuildProbe) -> np.ndarray:
    """Fetch specific rows (sorted global ids) in one extra streaming pass.

    Used to re-encode overflow-spilled points; residency is bounded by one
    chunk plus the (small) requested row set.
    """
    ids = np.asarray(ids)
    out = np.empty((ids.shape[0], 0), np.float32)
    base = 0
    filled = False
    for chunk in _chunks(source):
        probe.note_chunk(chunk.shape[0])
        if not filled:
            out = np.empty((ids.shape[0], chunk.shape[1]), np.float32)
            filled = True
        lo = np.searchsorted(ids, base)
        hi = np.searchsorted(ids, base + chunk.shape[0])
        if hi > lo:
            out[lo:hi] = chunk[ids[lo:hi] - base]
        base += chunk.shape[0]
    probe.passes += 1
    return out


def build_streaming(source, config: JunoConfig, *,
                    key: jax.Array | None = None,
                    probe: BuildProbe | None = None) -> JunoIndexData:
    """Out-of-core offline build: chunked two-pass JUNO construction.

    Produces a :class:`repro.core.juno.JunoIndexData` bit-compatible with
    ``core.build`` (identical array shapes/dtypes; H-tier recall within
    the in-memory build's on the same data — tests/test_build.py pins
    0.01; bit-identical arrays only in the spill-free N <=
    ``max_train_points`` regime, see the module docstring) while the raw
    point set is only ever resident one chunk at a time plus the bounded
    training sample.

    Parameters
    ----------
    source : callable or iterable
        Chunk source yielding (B, D) float arrays. A callable is invoked
        once per pass (the pipeline makes two); a plain iterable must be
        re-iterable (e.g. a list of arrays — NOT a generator).
        :func:`array_source` adapts arrays/memmaps.
    config : JunoConfig
        Build-time knobs; ``max_train_points`` bounds the training
        sample (<= 0 falls back to 200_000 — a streaming build cannot
        train on "all" points).
    key : jax.Array, optional
        PRNG key (default ``PRNGKey(0)``), split exactly as
        ``core.build`` splits it.
    probe : BuildProbe, optional
        Filled with chunk/pass/residency counters for memory-bound
        assertions.

    Returns
    -------
    JunoIndexData
        The built index.
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    probe = probe if probe is not None else BuildProbe()
    k_ivf, k_pq, k_cal = jax.random.split(key, 3)
    t_max = config.max_train_points if config.max_train_points > 0 else 200_000

    # ---- pass 1: reservoir sample + count --------------------------------
    sample = None
    fill = seen = 0
    rng = np.random.default_rng(
        int(np.asarray(jax.random.randint(jax.random.fold_in(k_ivf, 17), (),
                                          0, 2 ** 31 - 1))))
    for chunk in _chunks(source):
        probe.note_chunk(chunk.shape[0])
        if sample is None:
            sample = np.empty((t_max, chunk.shape[1]), np.float32)
        fill, seen = _reservoir_extend(sample, fill, seen, chunk, rng)
    if sample is None:
        raise ValueError("empty point source")
    probe.passes += 1
    n, d = seen, sample.shape[1]
    sample = sample[:fill]
    probe.train_rows = fill
    probe.n_points = n
    s = d // config.sub_dim

    # ---- train on the sample --------------------------------------------
    sample_j = jnp.asarray(sample)
    st = kmeans_subsampled(sample_j, n_clusters=config.n_clusters,
                           n_iters=config.kmeans_iters, key=k_ivf,
                           max_train_points=t_max)
    centroids = st.centroids
    # sample residuals train the PQ codebook and fix the density box
    c_sq = jnp.sum(centroids * centroids, axis=-1)
    s_labels = jnp.argmin(c_sq[None, :] - 2.0 * sample_j @ centroids.T,
                          axis=-1)
    s_res = sample_j - centroids[s_labels]
    codebook = train_codebook(s_res, n_entries=config.n_entries,
                              m=config.sub_dim,
                              n_iters=config.kmeans_iters, key=k_pq)
    s_sub = jnp.swapaxes(split_subspaces(s_res, config.sub_dim), 0, 1)
    dens_lo = jnp.min(s_sub, axis=1)                         # (S, M)
    dens_hi = jnp.max(s_sub, axis=1)

    # calibration queries from the sample (== the full set when it fits)
    nq = min(config.calib_queries, fill)
    k_choice, k_noise = jax.random.split(k_cal)
    qidx = jax.random.choice(k_choice, fill, shape=(nq,), replace=False)
    noise = (0.01 * jax.random.normal(k_noise, (nq, d))
             * jnp.std(sample_j))
    queries = sample_j[qidx] + noise.astype(jnp.float32)

    # ---- pass 2: encode + density + streaming ground truth ---------------
    counts = jnp.zeros((s, config.grid_size, config.grid_size), jnp.float32)
    kcal = min(config.calib_topk, n)
    best_s = jnp.full((nq, kcal), -jnp.inf)
    best_i = jnp.full((nq, kcal), -1, jnp.int32)
    labels_all = np.empty((n,), np.int32)
    codes_all = np.empty((n, s), np.uint8)
    psq_all = np.empty((n,), np.float32)
    batcher = _EvalBatcher(d)
    pos = 0

    def eat(batch: np.ndarray, n_valid: int):
        nonlocal counts, best_s, best_i, pos
        bj = jnp.asarray(batch)
        labels, codes, counts, psq = _encode_chunk(
            bj, centroids, codebook, counts, dens_lo, dens_hi, n_valid)
        best_s, best_i = _merge_topk(best_s, best_i, queries, bj,
                                     pos, n_valid, metric=config.metric)
        labels_all[pos:pos + n_valid] = np.asarray(labels[:n_valid])
        codes_all[pos:pos + n_valid] = np.asarray(codes[:n_valid])
        psq_all[pos:pos + n_valid] = np.asarray(psq[:n_valid])
        pos += n_valid

    for chunk in _chunks(source):
        probe.note_chunk(chunk.shape[0])
        for batch, n_valid in batcher.feed(chunk):
            eat(batch, n_valid)
    for batch, n_valid in batcher.flush():
        eat(batch, n_valid)
    probe.passes += 1
    if pos != n:
        raise ValueError(
            f"source yielded {pos} rows on pass 2 but {n} on pass 1 — "
            "the chunk source must be re-iterable and stable")

    # ---- finalize: layout + density model --------------------------------
    cap = cluster_capacity(n, config.n_clusters, config.capacity_mult)
    labels_pre = labels_all.copy()
    point_ids, labels_all = padded_layout(labels_all, config.n_clusters, cap)
    # overflow spill moved some points to an adoptive cluster: their codes
    # must be residuals w.r.t. the OWNING centroid (the in-memory build
    # encodes after the spill pass). A targeted third pass re-fetches just
    # those rows and patches codes + density counts.
    changed = np.nonzero(labels_pre != labels_all)[0]
    if changed.size:
        rows = _gather_rows(source, changed, probe)
        rows_j = jnp.asarray(rows)
        old_res = rows_j - centroids[labels_pre[changed]]
        new_res = rows_j - centroids[labels_all[changed]]
        codes_all[changed] = np.asarray(encode(new_res, codebook))
        sub_old = jnp.swapaxes(split_subspaces(old_res, config.sub_dim), 0, 1)
        sub_new = jnp.swapaxes(split_subspaces(new_res, config.sub_dim), 0, 1)
        neg = jnp.full((changed.size,), -1.0, jnp.float32)
        counts = density_lib.accumulate_density_counts(
            counts, sub_old, dens_lo, dens_hi, neg)
        counts = density_lib.accumulate_density_counts(
            counts, sub_new, dens_lo, dens_hi, -neg)
    point_ids = jnp.asarray(point_ids)
    ivf = IVFIndex(centroids=centroids, centroid_sq=c_sq,
                   point_ids=point_ids, valid=point_ids >= 0,
                   labels=jnp.asarray(labels_all))
    codes = jnp.asarray(codes_all)
    safe_ids = jnp.maximum(ivf.point_ids, 0)
    cluster_codes = codes[safe_ids]

    grid = density_lib.density_grid_from_counts(counts, dens_lo, dens_hi)
    qsub = _calib_query_subspaces(queries, ivf, config)
    gt_codes = codes[best_i].astype(jnp.int32)               # (nq, K, S)
    tau_needed = _calib_tau_needed(qsub, gt_codes, codebook, config.metric)
    dens_model = density_lib.calibrate_from_grid(
        grid, dens_lo, dens_hi, qsub, tau_needed, degree=config.poly_degree)

    return JunoIndexData(ivf=ivf, codebook=codebook, codes=codes,
                         cluster_codes=cluster_codes, density=dens_model,
                         points_sq=jnp.asarray(psq_all))


def split_shards(data: JunoIndexData, n_shards: int) -> list[JunoIndexData]:
    """Slice a built index into cluster-partitioned per-shard parts.

    Shard ``i`` owns clusters ``[i*C/n .. (i+1)*C/n)`` — exactly the rows
    ``dist.shard_index`` would place on mesh position ``i`` — with the
    codebook, density model, flat codes and GLOBAL labels/ids replicated,
    so each part can be stored and shipped as its own artifact and
    :func:`merge_shards` reassembles the global index losslessly.

    Parameters
    ----------
    data : JunoIndexData
        A built index.
    n_shards : int
        Shard count; must divide ``n_clusters``.

    Returns
    -------
    list of JunoIndexData
        One cluster-sliced part per shard.
    """
    c = data.ivf.centroids.shape[0]
    if c % n_shards:
        raise ValueError(f"clusters ({c}) must divide over {n_shards} shards")
    cl = c // n_shards
    out = []
    for i in range(n_shards):
        sl = slice(i * cl, (i + 1) * cl)
        out.append(data._replace(
            ivf=data.ivf._replace(
                centroids=data.ivf.centroids[sl],
                centroid_sq=data.ivf.centroid_sq[sl],
                point_ids=data.ivf.point_ids[sl],
                valid=data.ivf.valid[sl]),
            cluster_codes=data.cluster_codes[sl]))
    return out


def merge_shards(parts: list[JunoIndexData]) -> JunoIndexData:
    """Reassemble :func:`split_shards` parts into one global index.

    Parameters
    ----------
    parts : list of JunoIndexData
        Cluster-sliced parts in shard order (replicated components are
        taken from part 0).

    Returns
    -------
    JunoIndexData
        The concatenated global index.
    """
    first = parts[0]
    cat = lambda f: jnp.concatenate([getattr(p.ivf, f) for p in parts])  # noqa: E731
    return first._replace(
        ivf=first.ivf._replace(
            centroids=cat("centroids"), centroid_sq=cat("centroid_sq"),
            point_ids=cat("point_ids"), valid=cat("valid")),
        cluster_codes=jnp.concatenate([p.cluster_codes for p in parts]))


def build_streaming_sharded(source, config: JunoConfig, n_shards: int, **kw
                            ) -> list[JunoIndexData]:
    """Streaming build that emits per-shard indices for ``repro.dist``.

    Runs :func:`build_streaming` once, then cluster-partitions the result
    (:func:`split_shards`); each part is ready to be persisted as its own
    artifact (``store.save_index`` with a shard tag in ``extra``) and
    reassembled with :func:`merge_shards` before ``dist.shard_index``.

    Parameters
    ----------
    source : callable or iterable
        Re-iterable chunk source (see :func:`build_streaming`).
    config : JunoConfig
        Build-time knobs; ``n_clusters`` must divide over ``n_shards``.
    n_shards : int
        Number of cluster partitions to emit.
    **kw
        Forwarded to :func:`build_streaming` (``key``, ``probe``).

    Returns
    -------
    list of JunoIndexData
        One part per shard, in shard order.
    """
    return split_shards(build_streaming(source, config, **kw), n_shards)

"""`repro.build` — out-of-core index construction, artifact store, rebuild.

The offline half of the serving story at scale: `pipeline` streams a
memory-bounded build (the (N, D) point set is never resident beyond one
chunk), `store` persists a built index + its rt grid as one versioned,
integrity-checked artifact, and `rebuild` drains the online side buffer
and tombstones into a fresh index that `AnnServeEngine.swap_index()`
installs without taking serving down. See docs/building.md.

Public API:
    build_streaming, build_streaming_sharded   — streaming build (pipeline)
    array_source, BuildProbe                   — chunk plumbing (pipeline)
    split_shards, merge_shards                 — per-shard artifacts (pipeline)
    save_index, load_index, ArtifactStore      — versioned store (store)
    config_hash, verify_artifact, ArtifactError
    rebuild_index                              — side/tombstone drain (rebuild)
"""
from .pipeline import (BuildProbe, array_source, build_streaming,  # noqa: F401
                       build_streaming_sharded, merge_shards, split_shards)
from .rebuild import rebuild_index  # noqa: F401
from .store import (ArtifactError, ArtifactStore, config_hash,  # noqa: F401
                    load_index, save_index, verify_artifact)

"""Versioned on-disk index artifacts (`repro.build.store`).

One artifact = one directory holding ``arrays.npz`` (every
:class:`~repro.core.juno.JunoIndexData` array, flattened with dotted
keys, plus — when attached — the ``repro.rt`` centroid grid under an
``rt_grid.`` prefix, so an index and its calibrated spatial filter travel
together) and ``manifest.json`` (schema version, the full
:class:`~repro.core.juno.JunoConfig`, its canonical hash, metric,
N/C/P/S/E shape summary, and a per-array sha256/shape/dtype table for
integrity verification).

Loads are fail-closed: a schema-version mismatch, a config-hash mismatch
against the caller's expected config, a missing/extra array, or a
checksum mismatch all raise :class:`ArtifactError` before any partially
valid index can reach serving.

:class:`ArtifactStore` layers generation management on top: each ``put``
writes a fresh ``<root>/<name>/v<NNNN>`` directory (written to a temp
path, then atomically renamed), so a serving process can keep reading
``latest`` while the next generation lands — the storage half of the
zero-downtime rebuild story (``repro.build.rebuild``).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core.density import DensityModel
from repro.core.ivf import IVFIndex
from repro.core.juno import JunoConfig, JunoIndexData
from repro.core.pq import PQCodebook

#: bump when the on-disk layout changes incompatibly
SCHEMA_VERSION = 1

_MANIFEST = "manifest.json"
_ARRAYS = "arrays.npz"
_RT_PREFIX = "rt_grid."


class ArtifactError(RuntimeError):
    """A persisted index failed validation (version, config hash, integrity)."""


class LoadedIndex(NamedTuple):
    """What :func:`load_index` returns.

    Attributes
    ----------
    data : JunoIndexData
        The reconstructed index (device arrays).
    config : JunoConfig
        The build config persisted alongside it.
    manifest : dict
        The raw manifest (schema version, hashes, shapes, ``extra``).
    rt_grid : repro.rt.CentroidGrid or None
        The folded-in spatial grid, when one was saved.
    """

    data: JunoIndexData
    config: JunoConfig
    manifest: dict
    rt_grid: object | None


def _flatten_index(data: JunoIndexData) -> dict[str, np.ndarray]:
    out: dict[str, np.ndarray] = {}
    for group, obj in (("ivf", data.ivf), ("codebook", data.codebook),
                       ("density", data.density)):
        for f in type(obj)._fields:
            out[f"{group}.{f}"] = np.asarray(getattr(obj, f))
    for f in ("codes", "cluster_codes", "points_sq"):
        out[f] = np.asarray(getattr(data, f))
    return out


def _unflatten_index(arr: dict[str, np.ndarray]) -> JunoIndexData:
    pick = lambda g, t: t(**{f: jnp.asarray(arr[f"{g}.{f}"])  # noqa: E731
                             for f in t._fields})
    return JunoIndexData(
        ivf=pick("ivf", IVFIndex), codebook=pick("codebook", PQCodebook),
        density=pick("density", DensityModel),
        codes=jnp.asarray(arr["codes"]),
        cluster_codes=jnp.asarray(arr["cluster_codes"]),
        points_sq=jnp.asarray(arr["points_sq"]))


def config_hash(config: JunoConfig) -> str:
    """Canonical hash of a :class:`JunoConfig` (sha256 of sorted JSON).

    Parameters
    ----------
    config : JunoConfig
        The build config to fingerprint.

    Returns
    -------
    str
        Hex digest; equal iff every config field is equal.
    """
    blob = json.dumps(dataclasses.asdict(config), sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()


def _array_digest(a: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(a).tobytes()).hexdigest()


def save_index(path: str, data: JunoIndexData, config: JunoConfig, *,
               rt_grid=None, extra: dict | None = None) -> dict:
    """Persist an index (and optionally its rt grid) as one artifact.

    Parameters
    ----------
    path : str
        Target directory (created; existing files are overwritten).
    data : JunoIndexData
        The built index.
    config : JunoConfig
        The config it was built with (hashed into the manifest;
        :func:`load_index` can verify against an expected config).
    rt_grid : repro.rt.CentroidGrid, optional
        A calibrated spatial grid to fold into the same artifact.
    extra : dict, optional
        Caller metadata recorded verbatim in the manifest (e.g. a shard
        tag from ``pipeline.build_streaming_sharded``).

    Returns
    -------
    dict
        The manifest that was written.
    """
    arrays = _flatten_index(data)
    if rt_grid is not None:
        for f in type(rt_grid)._fields:
            arrays[_RT_PREFIX + f] = np.asarray(getattr(rt_grid, f))
    n, s = data.codes.shape
    c, p = data.ivf.point_ids.shape
    manifest = {
        "schema_version": SCHEMA_VERSION,
        "config": dataclasses.asdict(config),
        "config_hash": config_hash(config),
        "metric": config.metric,
        "shapes": {"n": int(n), "d": int(data.ivf.centroids.shape[1]),
                   "c": int(c), "p": int(p), "s": int(s),
                   "e": int(data.codebook.entries.shape[1])},
        "rt_grid": rt_grid is not None,
        "extra": dict(extra or {}),
        "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype),
                       "sha256": _array_digest(v)}
                   for k, v in arrays.items()},
    }
    os.makedirs(path, exist_ok=True)
    np.savez(os.path.join(path, _ARRAYS), **arrays)
    with open(os.path.join(path, _MANIFEST), "w") as fh:
        json.dump(manifest, fh, indent=2, sort_keys=True)
        fh.write("\n")
    return manifest


def _read_manifest(path: str) -> dict:
    mpath = os.path.join(path, _MANIFEST)
    if not os.path.exists(mpath):
        raise ArtifactError(f"no manifest at {mpath}")
    with open(mpath) as fh:
        manifest = json.load(fh)
    ver = manifest.get("schema_version")
    if ver != SCHEMA_VERSION:
        raise ArtifactError(
            f"schema version mismatch: artifact v{ver}, reader "
            f"v{SCHEMA_VERSION} ({path})")
    return manifest


def _load_arrays(path: str) -> dict[str, np.ndarray]:
    apath = os.path.join(path, _ARRAYS)
    if not os.path.exists(apath):
        raise ArtifactError(f"no array bundle at {apath}")
    with np.load(apath) as z:
        return {k: z[k] for k in z.files}


def _check_arrays(manifest: dict, arrays: dict[str, np.ndarray],
                  path: str) -> None:
    names = set(arrays)
    listed = set(manifest["arrays"])
    if names != listed:
        raise ArtifactError(
            f"array set mismatch: bundle-only {sorted(names - listed)}, "
            f"manifest-only {sorted(listed - names)} ({path})")
    for name, meta in manifest["arrays"].items():
        a = arrays[name]
        if list(a.shape) != meta["shape"] or str(a.dtype) != meta["dtype"]:
            raise ArtifactError(
                f"{name}: stored {a.shape}/{a.dtype} != manifest "
                f"{meta['shape']}/{meta['dtype']} ({path})")
        if _array_digest(a) != meta["sha256"]:
            raise ArtifactError(f"{name}: checksum mismatch ({path})")


def verify_artifact(path: str) -> dict:
    """Validate an artifact's manifest and array integrity on disk.

    Every array listed in the manifest must exist in ``arrays.npz`` with
    the recorded shape, dtype and sha256 (and no unlisted arrays may be
    present).

    Parameters
    ----------
    path : str
        Artifact directory.

    Returns
    -------
    dict
        The validated manifest.

    Raises
    ------
    ArtifactError
        On any missing file, version mismatch, or integrity failure.
    """
    manifest = _read_manifest(path)
    _check_arrays(manifest, _load_arrays(path), path)
    return manifest


def load_index(path: str, *, expect_config: JunoConfig | None = None,
               verify: bool = True) -> LoadedIndex:
    """Load a persisted index artifact, fail-closed.

    Parameters
    ----------
    path : str
        Artifact directory written by :func:`save_index`.
    expect_config : JunoConfig, optional
        When given, the artifact's config hash must match this config's
        (guards a serving process against loading an index built with
        different knobs).
    verify : bool
        Run the full :func:`verify_artifact` integrity pass (default).
        ``False`` skips checksums but still checks schema version and
        config hash.

    Returns
    -------
    LoadedIndex
        ``(data, config, manifest, rt_grid)``.

    Raises
    ------
    ArtifactError
        On version, config-hash, or integrity mismatch.
    """
    manifest = _read_manifest(path)
    config = JunoConfig(**manifest["config"])
    if manifest.get("config_hash") != config_hash(config):
        raise ArtifactError(f"manifest config_hash does not match its own "
                            f"config ({path})")
    if expect_config is not None and \
            config_hash(expect_config) != manifest["config_hash"]:
        raise ArtifactError(
            f"config hash mismatch: expected {config_hash(expect_config)}, "
            f"artifact has {manifest['config_hash']} ({path})")
    arrays = _load_arrays(path)   # single read: verification hashes the
    if verify:                    # same in-memory arrays the index is
        _check_arrays(manifest, arrays, path)  # built from
    rt_grid = None
    if manifest.get("rt_grid"):
        from repro.rt import CentroidGrid
        rt_grid = CentroidGrid(**{
            f: jnp.asarray(arrays.pop(_RT_PREFIX + f))
            for f in CentroidGrid._fields})
    else:
        arrays = {k: v for k, v in arrays.items()
                  if not k.startswith(_RT_PREFIX)}
    return LoadedIndex(data=_unflatten_index(arrays), config=config,
                       manifest=manifest, rt_grid=rt_grid)


class ArtifactStore:
    """Directory of named, versioned index artifacts.

    Layout: ``<root>/<name>/v0001``, ``v0002``, … — one
    :func:`save_index` artifact per generation. Writes land in a temp
    directory and are renamed into place, so readers of
    :meth:`latest`/:meth:`get` never observe a half-written generation.
    """

    def __init__(self, root: str):
        """Open (creating if needed) the store rooted at ``root``.

        Parameters
        ----------
        root : str
            Store root directory.
        """
        self.root = root
        os.makedirs(root, exist_ok=True)

    def path(self, name: str, version: int) -> str:
        """Directory of one generation of ``name``.

        Parameters
        ----------
        name : str
            Artifact name.
        version : int
            Generation number (1-based).

        Returns
        -------
        str
            The artifact directory path (may not exist yet).
        """
        return os.path.join(self.root, name, f"v{version:04d}")

    def versions(self, name: str) -> list[int]:
        """All committed generations of ``name``, ascending.

        Parameters
        ----------
        name : str
            Artifact name.

        Returns
        -------
        list of int
            Generation numbers; empty when the name is unknown.
        """
        d = os.path.join(self.root, name)
        if not os.path.isdir(d):
            return []
        out = []
        for entry in os.listdir(d):
            if entry.startswith("v") and entry[1:].isdigit() and \
                    os.path.exists(os.path.join(d, entry, _MANIFEST)):
                out.append(int(entry[1:]))
        return sorted(out)

    def latest(self, name: str) -> int | None:
        """Newest committed generation of ``name`` (None when absent).

        Parameters
        ----------
        name : str
            Artifact name.

        Returns
        -------
        int or None
            The highest generation number, or None.
        """
        vs = self.versions(name)
        return vs[-1] if vs else None

    def put(self, name: str, data: JunoIndexData, config: JunoConfig, *,
            rt_grid=None, extra: dict | None = None) -> int:
        """Commit a new generation of ``name`` atomically.

        Parameters
        ----------
        name : str
            Artifact name.
        data, config, rt_grid, extra
            Forwarded to :func:`save_index`.

        Returns
        -------
        int
            The committed generation number.
        """
        version = (self.latest(name) or 0) + 1
        final = self.path(name, version)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        save_index(tmp, data, config, rt_grid=rt_grid, extra=extra)
        os.makedirs(os.path.dirname(final), exist_ok=True)
        os.rename(tmp, final)
        return version

    def get(self, name: str, version: int | None = None, **kw) -> LoadedIndex:
        """Load one generation of ``name`` (default: the latest).

        Parameters
        ----------
        name : str
            Artifact name.
        version : int, optional
            Generation to load (default :meth:`latest`).
        **kw
            Forwarded to :func:`load_index` (``expect_config``,
            ``verify``).

        Returns
        -------
        LoadedIndex
            See :func:`load_index`.
        """
        if version is None:
            version = self.latest(name)
            if version is None:
                raise ArtifactError(f"no artifact named {name!r} in "
                                    f"{self.root}")
        return load_index(self.path(name, version), **kw)

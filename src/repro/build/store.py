"""Versioned on-disk index artifacts (`repro.build.store`).

One artifact = one directory holding ``arrays.npz`` (every
:class:`~repro.core.juno.JunoIndexData` array, flattened with dotted
keys, plus — when attached — the ``repro.rt`` centroid grid under an
``rt_grid.`` prefix, so an index and its calibrated spatial filter travel
together) and ``manifest.json`` (schema version, the full
:class:`~repro.core.juno.JunoConfig`, its canonical hash, metric,
N/C/P/S/E shape summary, and a per-array sha256/shape/dtype table for
integrity verification).

Loads are fail-closed at three explicit verification levels
(:func:`load_index`'s ``verify``): ``"full"`` re-digests every array,
``"manifest"`` validates the array set/shapes/dtypes without reading
data (the default for memory-mapped loads, whose per-cluster integrity
is then enforced on first touch by ``repro.serve.paged``), and
``"never"`` checks only schema version and config hash. At every level a
schema-version mismatch or a config-hash mismatch against the caller's
expected config raises :class:`ArtifactError` before any partially valid
index can reach serving.

:class:`ArtifactStore` layers generation management on top: each ``put``
writes a fresh ``<root>/<name>/v<NNNN>`` directory (written to a unique
temp path, fsynced, then atomically renamed with retry when a concurrent
writer claims the same generation), so a serving process can keep
reading ``latest`` while the next generation lands — the storage half of
the zero-downtime rebuild story (``repro.build.rebuild``).
"""
from __future__ import annotations

import dataclasses
import errno
import hashlib
import json
import os
import shutil
import time
import uuid
import zipfile
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core.density import DensityModel
from repro.core.ivf import IVFIndex
from repro.core.juno import JunoConfig, JunoIndexData
from repro.core.pq import PQCodebook

#: bump when the on-disk layout changes incompatibly
SCHEMA_VERSION = 1

_MANIFEST = "manifest.json"
_ARRAYS = "arrays.npz"
_RT_PREFIX = "rt_grid."


class ArtifactError(RuntimeError):
    """A persisted index failed validation (version, config hash, integrity)."""


class LoadedIndex(NamedTuple):
    """What :func:`load_index` returns.

    Attributes
    ----------
    data : JunoIndexData
        The reconstructed index (device arrays).
    config : JunoConfig
        The build config persisted alongside it.
    manifest : dict
        The raw manifest (schema version, hashes, shapes, ``extra``).
    rt_grid : repro.rt.CentroidGrid or None
        The folded-in spatial grid, when one was saved.
    """

    data: JunoIndexData
    config: JunoConfig
    manifest: dict
    rt_grid: object | None


def _flatten_index(data: JunoIndexData) -> dict[str, np.ndarray]:
    out: dict[str, np.ndarray] = {}
    for group, obj in (("ivf", data.ivf), ("codebook", data.codebook),
                       ("density", data.density)):
        for f in type(obj)._fields:
            out[f"{group}.{f}"] = np.asarray(getattr(obj, f))
    for f in ("codes", "cluster_codes", "points_sq"):
        out[f] = np.asarray(getattr(data, f))
    return out


def _unflatten_index(arr: dict[str, np.ndarray],
                     convert=jnp.asarray) -> JunoIndexData:
    pick = lambda g, t: t(**{f: convert(arr[f"{g}.{f}"])  # noqa: E731
                             for f in t._fields})
    return JunoIndexData(
        ivf=pick("ivf", IVFIndex), codebook=pick("codebook", PQCodebook),
        density=pick("density", DensityModel),
        codes=convert(arr["codes"]),
        cluster_codes=convert(arr["cluster_codes"]),
        points_sq=convert(arr["points_sq"]))


def config_hash(config: JunoConfig) -> str:
    """Canonical hash of a :class:`JunoConfig` (sha256 of sorted JSON).

    Parameters
    ----------
    config : JunoConfig
        The build config to fingerprint.

    Returns
    -------
    str
        Hex digest; equal iff every config field is equal.
    """
    blob = json.dumps(dataclasses.asdict(config), sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()


def _array_digest(a: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(a).tobytes()).hexdigest()


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _fsync_artifact(path: str) -> None:
    """Force an artifact's files, then its directory entry, to disk."""
    for fname in (_ARRAYS, _MANIFEST):
        with open(os.path.join(path, fname), "rb") as fh:
            os.fsync(fh.fileno())
    _fsync_dir(path)


def save_index(path: str, data: JunoIndexData, config: JunoConfig, *,
               rt_grid=None, extra: dict | None = None) -> dict:
    """Persist an index (and optionally its rt grid) as one artifact.

    Parameters
    ----------
    path : str
        Target directory (created; existing files are overwritten).
    data : JunoIndexData
        The built index.
    config : JunoConfig
        The config it was built with (hashed into the manifest;
        :func:`load_index` can verify against an expected config).
    rt_grid : repro.rt.CentroidGrid, optional
        A calibrated spatial grid to fold into the same artifact.
    extra : dict, optional
        Caller metadata recorded verbatim in the manifest (e.g. a shard
        tag from ``pipeline.build_streaming_sharded``).

    Returns
    -------
    dict
        The manifest that was written.
    """
    arrays = _flatten_index(data)
    if rt_grid is not None:
        for f in type(rt_grid)._fields:
            arrays[_RT_PREFIX + f] = np.asarray(getattr(rt_grid, f))
    n, s = data.codes.shape
    c, p = data.ivf.point_ids.shape
    manifest = {
        "schema_version": SCHEMA_VERSION,
        "config": dataclasses.asdict(config),
        "config_hash": config_hash(config),
        "metric": config.metric,
        "shapes": {"n": int(n), "d": int(data.ivf.centroids.shape[1]),
                   "c": int(c), "p": int(p), "s": int(s),
                   "e": int(data.codebook.entries.shape[1])},
        "rt_grid": rt_grid is not None,
        "extra": dict(extra or {}),
        "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype),
                       "sha256": _array_digest(v)}
                   for k, v in arrays.items()},
    }
    # Per-cluster digests let the paged backend (repro.serve.paged) verify
    # each cluster_codes row on first touch without reading the whole shard
    # — the mmap-friendly half of the fail-closed contract.
    manifest["arrays"]["cluster_codes"]["sha256_rows"] = [
        _array_digest(row) for row in arrays["cluster_codes"]]
    os.makedirs(path, exist_ok=True)
    np.savez(os.path.join(path, _ARRAYS), **arrays)
    with open(os.path.join(path, _MANIFEST), "w") as fh:
        json.dump(manifest, fh, indent=2, sort_keys=True)
        fh.write("\n")
    return manifest


def _read_manifest(path: str) -> dict:
    mpath = os.path.join(path, _MANIFEST)
    if not os.path.exists(mpath):
        raise ArtifactError(f"no manifest at {mpath}")
    with open(mpath) as fh:
        manifest = json.load(fh)
    ver = manifest.get("schema_version")
    if ver != SCHEMA_VERSION:
        raise ArtifactError(
            f"schema version mismatch: artifact v{ver}, reader "
            f"v{SCHEMA_VERSION} ({path})")
    return manifest


def _load_arrays(path: str) -> dict[str, np.ndarray]:
    apath = os.path.join(path, _ARRAYS)
    if not os.path.exists(apath):
        raise ArtifactError(f"no array bundle at {apath}")
    with np.load(apath) as z:
        return {k: z[k] for k in z.files}


def _mmap_arrays(path: str) -> dict[str, np.ndarray]:
    """Memory-map every member of ``arrays.npz`` without reading data.

    ``np.savez`` stores members uncompressed (ZIP_STORED), so each
    embedded ``.npy`` is a contiguous byte range of the archive: parse
    the zip local file header to find the member's data offset, read the
    npy header for shape/dtype/order, and hand the payload range to
    ``np.memmap``. Raises :class:`ArtifactError` on compressed or
    object-dtype members (neither is produced by :func:`save_index`).
    """
    apath = os.path.join(path, _ARRAYS)
    if not os.path.exists(apath):
        raise ArtifactError(f"no array bundle at {apath}")
    out: dict[str, np.ndarray] = {}
    with zipfile.ZipFile(apath) as zf, open(apath, "rb") as fh:
        for info in zf.infolist():
            if info.compress_type != zipfile.ZIP_STORED:
                raise ArtifactError(
                    f"{info.filename}: compressed member cannot be "
                    f"memory-mapped ({apath})")
            fh.seek(info.header_offset)
            hdr = fh.read(30)  # fixed part of the zip local file header
            n_name = int.from_bytes(hdr[26:28], "little")
            n_extra = int.from_bytes(hdr[28:30], "little")
            fh.seek(info.header_offset + 30 + n_name + n_extra)
            version = np.lib.format.read_magic(fh)
            if version == (1, 0):
                shape, fortran, dtype = np.lib.format.read_array_header_1_0(fh)
            else:
                shape, fortran, dtype = np.lib.format.read_array_header_2_0(fh)
            if dtype.hasobject:
                raise ArtifactError(
                    f"{info.filename}: object dtype cannot be memory-mapped "
                    f"({apath})")
            name = info.filename
            if name.endswith(".npy"):
                name = name[:-4]
            out[name] = np.memmap(apath, dtype=dtype, mode="r",
                                  offset=fh.tell(), shape=shape,
                                  order="F" if fortran else "C")
    return out


def _check_arrays(manifest: dict, arrays: dict[str, np.ndarray],
                  path: str, *, digests: bool = True) -> None:
    names = set(arrays)
    listed = set(manifest["arrays"])
    if names != listed:
        raise ArtifactError(
            f"array set mismatch: bundle-only {sorted(names - listed)}, "
            f"manifest-only {sorted(listed - names)} ({path})")
    for name, meta in manifest["arrays"].items():
        a = arrays[name]
        if list(a.shape) != meta["shape"] or str(a.dtype) != meta["dtype"]:
            raise ArtifactError(
                f"{name}: stored {a.shape}/{a.dtype} != manifest "
                f"{meta['shape']}/{meta['dtype']} ({path})")
        rows = meta.get("sha256_rows")
        if rows is not None and len(rows) != meta["shape"][0]:
            raise ArtifactError(
                f"{name}: {len(rows)} per-row digests for "
                f"{meta['shape'][0]} rows ({path})")
        if digests and _array_digest(a) != meta["sha256"]:
            raise ArtifactError(f"{name}: checksum mismatch ({path})")


def verify_artifact(path: str) -> dict:
    """Validate an artifact's manifest and array integrity on disk.

    Every array listed in the manifest must exist in ``arrays.npz`` with
    the recorded shape, dtype and sha256 (and no unlisted arrays may be
    present).

    Parameters
    ----------
    path : str
        Artifact directory.

    Returns
    -------
    dict
        The validated manifest.

    Raises
    ------
    ArtifactError
        On any missing file, version mismatch, or integrity failure.
    """
    manifest = _read_manifest(path)
    _check_arrays(manifest, _load_arrays(path), path)
    return manifest


def _normalize_verify(verify, mmap_mode) -> str:
    if verify is None:
        return "manifest" if mmap_mode else "full"
    if verify is True:
        return "full"
    if verify is False:
        return "manifest"
    if verify in ("full", "manifest", "never"):
        return verify
    raise ValueError(f"verify must be 'full', 'manifest' or 'never', "
                     f"got {verify!r}")


def load_index(path: str, *, expect_config: JunoConfig | None = None,
               verify: bool | str | None = None,
               mmap_mode: str | None = None) -> LoadedIndex:
    """Load a persisted index artifact, fail-closed.

    Parameters
    ----------
    path : str
        Artifact directory written by :func:`save_index`.
    expect_config : JunoConfig, optional
        When given, the artifact's config hash must match this config's
        (guards a serving process against loading an index built with
        different knobs).
    verify : {"full", "manifest", "never"} or bool, optional
        How much integrity checking to do before the index is handed
        out. ``"full"`` (the default for resident loads) re-digests
        every array against the manifest sha256 table — O(index bytes).
        ``"manifest"`` (the default for ``mmap_mode`` loads, and what
        ``False`` maps to) validates the array set, shapes and dtypes
        without reading array data, leaving per-cluster digests to be
        enforced on first touch by the paged backend
        (``repro.serve.paged``). ``"never"`` checks only schema version
        and config hash. ``True`` maps to ``"full"``. All three levels
        are fail-closed: anything they do check raises
        :class:`ArtifactError` rather than degrading.
    mmap_mode : {"r"}, optional
        When ``"r"``, arrays are returned as read-only ``np.memmap``
        views into ``arrays.npz`` instead of device arrays — no array
        data is read at load time. Callers (the paged serving tier)
        promote the small metadata arrays to device and demand-page the
        rest.

    Returns
    -------
    LoadedIndex
        ``(data, config, manifest, rt_grid)``.

    Raises
    ------
    ArtifactError
        On version, config-hash, or integrity mismatch.
    """
    if mmap_mode not in (None, "r"):
        raise ValueError(f"mmap_mode must be None or 'r', got {mmap_mode!r}")
    mode = _normalize_verify(verify, mmap_mode)
    manifest = _read_manifest(path)
    config = JunoConfig(**manifest["config"])
    if manifest.get("config_hash") != config_hash(config):
        raise ArtifactError(f"manifest config_hash does not match its own "
                            f"config ({path})")
    if expect_config is not None and \
            config_hash(expect_config) != manifest["config_hash"]:
        raise ArtifactError(
            f"config hash mismatch: expected {config_hash(expect_config)}, "
            f"artifact has {manifest['config_hash']} ({path})")
    if mmap_mode == "r":
        arrays = _mmap_arrays(path)     # no data read; "full" would page
        convert = lambda a: a           # noqa: E731 — keep the mmap views
    else:
        arrays = _load_arrays(path)     # single read: verification hashes
        convert = jnp.asarray           # what the index is built from
    if mode != "never":
        _check_arrays(manifest, arrays, path, digests=mode == "full")
    rt_grid = None
    if manifest.get("rt_grid"):
        from repro.rt import CentroidGrid
        rt_grid = CentroidGrid(**{
            f: jnp.asarray(arrays.pop(_RT_PREFIX + f))
            for f in CentroidGrid._fields})
    else:
        arrays = {k: v for k, v in arrays.items()
                  if not k.startswith(_RT_PREFIX)}
    return LoadedIndex(data=_unflatten_index(arrays, convert), config=config,
                       manifest=manifest, rt_grid=rt_grid)


class ArtifactStore:
    """Directory of named, versioned index artifacts.

    Layout: ``<root>/<name>/v0001``, ``v0002``, … — one
    :func:`save_index` artifact per generation. Writes land in a temp
    directory and are renamed into place, so readers of
    :meth:`latest`/:meth:`get` never observe a half-written generation.
    """

    def __init__(self, root: str, *, registry=None):
        """Open (creating if needed) the store rooted at ``root``.

        Parameters
        ----------
        root : str
            Store root directory.
        registry : repro.obs.MetricsRegistry, optional
            Destination for the ``juno_store_*`` series:
            put/load/verify duration histograms plus operation
            counters. None (default) disables instrumentation.
        """
        self.root = root
        self.registry = registry
        os.makedirs(root, exist_ok=True)

    def _observe(self, op: str, dt: float) -> None:
        """Record one timed store operation when a registry is bound."""
        if self.registry is not None:
            self.registry.histogram("juno_store_op_seconds", op=op).add(dt)
            self.registry.counter("juno_store_ops_total", op=op).inc()

    def path(self, name: str, version: int) -> str:
        """Directory of one generation of ``name``.

        Parameters
        ----------
        name : str
            Artifact name.
        version : int
            Generation number (1-based).

        Returns
        -------
        str
            The artifact directory path (may not exist yet).
        """
        return os.path.join(self.root, name, f"v{version:04d}")

    def versions(self, name: str) -> list[int]:
        """All committed generations of ``name``, ascending.

        Parameters
        ----------
        name : str
            Artifact name.

        Returns
        -------
        list of int
            Generation numbers; empty when the name is unknown.
        """
        d = os.path.join(self.root, name)
        if not os.path.isdir(d):
            return []
        out = []
        for entry in os.listdir(d):
            if entry.startswith("v") and entry[1:].isdigit() and \
                    os.path.exists(os.path.join(d, entry, _MANIFEST)):
                out.append(int(entry[1:]))
        return sorted(out)

    def latest(self, name: str) -> int | None:
        """Newest committed generation of ``name`` (None when absent).

        Parameters
        ----------
        name : str
            Artifact name.

        Returns
        -------
        int or None
            The highest generation number, or None.
        """
        vs = self.versions(name)
        return vs[-1] if vs else None

    def put(self, name: str, data: JunoIndexData, config: JunoConfig, *,
            rt_grid=None, extra: dict | None = None,
            max_attempts: int = 32) -> int:
        """Commit a new generation of ``name`` atomically and durably.

        The artifact is written once to a unique temp directory (never
        visible to :meth:`versions`), fsynced file-by-file plus the
        directory entry, then renamed onto the next free generation.
        ``os.rename`` onto an existing committed generation fails
        (exclusive-create semantics), in which case another writer won
        that number and the rename retries with the next one — two
        racing writers commit two distinct generations instead of one
        clobbering the other. The parent directory is fsynced after the
        rename so a crash cannot surface a renamed-but-unsynced
        generation.

        Parameters
        ----------
        name : str
            Artifact name.
        data, config, rt_grid, extra
            Forwarded to :func:`save_index`.
        max_attempts : int
            Rename retries before giving up (each consumed only by a
            concurrent writer committing the contended generation).

        Returns
        -------
        int
            The committed generation number.

        Raises
        ------
        ArtifactError
            When ``max_attempts`` generations were contended.
        """
        t0 = time.perf_counter()
        d = os.path.join(self.root, name)
        os.makedirs(d, exist_ok=True)
        tmp = os.path.join(d, f".tmp-{os.getpid()}-{uuid.uuid4().hex[:8]}")
        try:
            save_index(tmp, data, config, rt_grid=rt_grid, extra=extra)
            _fsync_artifact(tmp)
            for _ in range(max_attempts):
                version = (self.latest(name) or 0) + 1
                final = self.path(name, version)
                try:
                    os.rename(tmp, final)
                except OSError as e:
                    if e.errno not in (errno.EEXIST, errno.ENOTEMPTY,
                                       errno.ENOTDIR, errno.EISDIR):
                        raise
                    continue  # lost the race for this generation number
                _fsync_dir(d)
                self._observe("put", time.perf_counter() - t0)
                return version
            raise ArtifactError(
                f"could not commit a generation of {name!r} after "
                f"{max_attempts} contended attempts")
        finally:
            shutil.rmtree(tmp, ignore_errors=True)

    def get(self, name: str, version: int | None = None, **kw) -> LoadedIndex:
        """Load one generation of ``name`` (default: the latest).

        Parameters
        ----------
        name : str
            Artifact name.
        version : int, optional
            Generation to load (default :meth:`latest`).
        **kw
            Forwarded to :func:`load_index` (``expect_config``,
            ``verify``, ``mmap_mode``).

        Returns
        -------
        LoadedIndex
            See :func:`load_index`.
        """
        if version is None:
            version = self.latest(name)
            if version is None:
                raise ArtifactError(f"no artifact named {name!r} in "
                                    f"{self.root}")
        t0 = time.perf_counter()
        loaded = load_index(self.path(name, version), **kw)
        self._observe("load", time.perf_counter() - t0)
        return loaded

    def verify(self, name: str, version: int | None = None) -> dict:
        """Re-verify one committed generation against its manifest.

        Runs :func:`verify_artifact` (schema, config hash, full array
        digests) over the generation's directory, timing the pass into
        the ``juno_store_op_seconds{op="verify"}`` histogram when a
        registry is bound. Fail-closed: a corrupt artifact raises
        ``ArtifactError`` — the timing is still recorded so slow or
        failing scrubs show up in the metrics.

        Parameters
        ----------
        name : str
            Artifact name.
        version : int, optional
            Generation to verify (default :meth:`latest`).

        Returns
        -------
        dict
            The verified manifest (see :func:`verify_artifact`).
        """
        if version is None:
            version = self.latest(name)
            if version is None:
                raise ArtifactError(f"no artifact named {name!r} in "
                                    f"{self.root}")
        t0 = time.perf_counter()
        try:
            return verify_artifact(self.path(name, version))
        finally:
            self._observe("verify", time.perf_counter() - t0)

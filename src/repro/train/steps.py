"""Training step factory: loss → grads (optionally micro-batched) → AdamW.

Distributed-optimization hooks:
  * gradient compression — ``grad_dtype="bfloat16"`` makes the backward pass
    (and therefore the cross-pod grad all-reduce XLA inserts) run in bf16,
    halving DCI traffic; the optimizer math stays f32 (error feedback is the
    Adam m/v accumulation itself).
  * grad accumulation — microbatch scan; the all-reduce of microbatch i
    overlaps the backward of i+1 under XLA's latency-hiding scheduler.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.models.api import ModelAPI
from .optimizer import AdamWConfig, OptState, adamw_update, init_opt_state


class TrainState(NamedTuple):
    params: Any
    opt: OptState


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    accum_steps: int = 1
    grad_dtype: str = "float32"       # "bfloat16" → compressed grad reduce


def make_train_step(model: ModelAPI, tcfg: TrainConfig,
                    grad_pspecs=None) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    grad_pspecs: optional PartitionSpec tree matching params — constrains
    gradients to the param layout so the cross-data reduction lowers as
    reduce-scatter (each chip only receives ITS shard) instead of the
    partitioner's default all-reduce: half the traffic (§Perf iteration 3).
    """

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def grads_of(params, batch):
        if tcfg.grad_dtype == "bfloat16":
            # cast-through: grads flow (and reduce) in bf16
            p16 = jax.tree.map(lambda x: x.astype(jnp.bfloat16)
                               if x.dtype == jnp.float32 else x, params)
            loss, g16 = jax.value_and_grad(loss_fn)(p16, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), g16)
            return loss, grads
        return jax.value_and_grad(loss_fn)(params, batch)

    def train_step(state: TrainState, batch):
        if tcfg.accum_steps > 1:
            a = tcfg.accum_steps
            micro = jax.tree.map(
                lambda x: x.reshape((a, x.shape[0] // a) + x.shape[1:]),
                batch)

            def acc(carry, mb):
                loss_sum, g_sum = carry
                loss, g = grads_of(state.params, mb)
                g_sum = jax.tree.map(jnp.add, g_sum, g)
                return (loss_sum + loss, g_sum), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (loss, grads), _ = jax.lax.scan(acc, (jnp.float32(0), zero_g),
                                            micro)
            loss = loss / a
            grads = jax.tree.map(lambda g: g / a, grads)
        else:
            loss, grads = grads_of(state.params, batch)
        if grad_pspecs is not None:
            grads = jax.lax.with_sharding_constraint(grads, grad_pspecs)

        new_params, new_opt, metrics = adamw_update(
            tcfg.optimizer, state.params, grads, state.opt)
        metrics["loss"] = loss
        return TrainState(new_params, new_opt), metrics

    return train_step


def init_train_state(model: ModelAPI, key: jax.Array) -> TrainState:
    from repro.models.params import init_params
    params = init_params(model.schema, key)
    return TrainState(params=params, opt=init_opt_state(params))

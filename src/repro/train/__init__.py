from .optimizer import AdamWConfig, OptState, adamw_update, init_opt_state  # noqa: F401
from .steps import TrainConfig, TrainState, make_train_step, init_train_state  # noqa: F401

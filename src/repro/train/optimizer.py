"""AdamW from scratch (no optax offline). Optimizer state mirrors the param
pytree leaf-for-leaf, so FSDP shardings apply to m/v identically — the
ZeRO-style property that makes 123B trainable on 256 chips."""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


class OptState(NamedTuple):
    m: dict
    v: dict
    step: jnp.ndarray


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return OptState(m=zeros, v=jax.tree.map(jnp.zeros_like, params),
                    step=jnp.zeros((), jnp.int32))


def opt_state_schema(schema):
    """Spec tree for the optimizer state (dry-run: abstract, sharded like
    params)."""
    from repro.models.params import Spec, tree_map_specs
    z = tree_map_specs(lambda s: Spec(s.shape, s.pspec, "zeros", s.dtype),
                       schema)
    return OptState(m=z, v=z, step=Spec((), jax.sharding.PartitionSpec(),
                                        "zeros", jnp.int32))


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, params, grads, state: OptState):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    stepf = step.astype(jnp.float32)
    lr = cfg.lr * jnp.minimum(1.0, stepf / max(cfg.warmup_steps, 1))

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    b1c = 1.0 - cfg.b1 ** stepf
    b2c = 1.0 - cfg.b2 ** stepf

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m_new / b1c
        vh = v_new / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), \
            m_new, v_new

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OptState(new_m, new_v, step), \
        {"grad_norm": gnorm, "lr": lr}

"""Analytic per-chip FLOP and HBM-byte models for the roofline terms.

Why analytic: XLA's cost_analysis counts while-loop bodies once (verified in
hlo_analysis.py), so for layer-scanned models the reported FLOPs/bytes are
~L× too small. Collectives are recovered exactly from the HLO call graph;
compute/memory come from this closed-form matmul accounting — the standard
MFU methodology. Every component is listed so the model is auditable.

Conventions:
  * matmul flops = 2·M·N·K; causal attention context = (S+1)/2, window-capped
  * train flops = fwd × (3 + remat) on blocks, fwd × 3 on the LM head
  * bytes: f32 params, bf16 activations; FSDP means each chip reads the
    TP-shard (not the FSDP shard) of every layer's weights each pass —
    the all-gathered copy has to stream through HBM.
"""
from __future__ import annotations

from repro.models.config import ModelConfig
from repro.launch.shapes import ShapeSpec


def _attn_flops_per_tok(cfg: ModelConfig, ctx: float) -> float:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    proj = 2 * d * (h * hd) * 2 + 2 * d * (kv * hd) * 2
    sdpa = 2 * h * hd * ctx * 2
    return proj + sdpa


def _mla_flops_per_tok(cfg: ModelConfig, ctx: float, decode: bool) -> float:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    down = 2 * d * (m.kv_lora_rank + m.qk_rope_dim)
    q = 2 * d * h * (m.qk_nope_dim + m.qk_rope_dim)
    out = 2 * h * m.v_head_dim * d
    if decode:  # absorbed path: scores in latent space
        absorb = 2 * h * m.qk_nope_dim * m.kv_lora_rank \
            + 2 * h * m.kv_lora_rank * m.v_head_dim
        sdpa = 2 * h * (m.kv_lora_rank + m.qk_rope_dim) * ctx \
            + 2 * h * m.kv_lora_rank * ctx
        return q + down + absorb + sdpa + out
    up = 2 * m.kv_lora_rank * h * (m.qk_nope_dim + m.v_head_dim)
    sdpa = 2 * h * (m.qk_nope_dim + m.qk_rope_dim) * ctx \
        + 2 * h * m.v_head_dim * ctx
    return q + down + up + sdpa + out


def _mlp_flops_per_tok(cfg: ModelConfig) -> float:
    if cfg.moe:
        mo = cfg.moe
        return (2 * cfg.d_model * mo.n_experts                 # router
                + 6 * cfg.d_model * mo.d_ff_expert
                * (mo.top_k + mo.n_shared))
    return 6 * cfg.d_model * cfg.d_ff


def _ssm_flops_per_tok(cfg: ModelConfig, decode: bool) -> float:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    nh = d_in // s.head_dim
    gn = s.n_groups * s.d_state
    proj = 2 * d * (2 * d_in + 2 * gn + nh) + 2 * d_in * d
    conv = 2 * s.conv_width * (d_in + 2 * gn)
    n, p, lc = s.d_state, s.head_dim, s.chunk
    if decode:
        ssd = 4 * nh * p * n
    else:
        ssd = 2 * nh * (n * lc + p * lc + 2 * n * p)
    return proj + conv + ssd


def _block_flops_per_tok(cfg: ModelConfig, ctx: float, decode: bool) -> float:
    fl = 0.0
    if cfg.mixer_kind in ("attn", "hybrid"):
        if cfg.attn_kind == "mla":
            fl += _mla_flops_per_tok(cfg, ctx, decode)
        else:
            fl += _attn_flops_per_tok(cfg, ctx)
    if cfg.mixer_kind in ("ssm", "hybrid"):
        fl += _ssm_flops_per_tok(cfg, decode)
    if cfg.mixer_kind != "ssm":
        fl += _mlp_flops_per_tok(cfg)
    return fl


def _ctx(cfg: ModelConfig, shape: ShapeSpec) -> float:
    if shape.kind == "decode":
        c = shape.seq_len
    else:
        c = (shape.seq_len + 1) / 2
    if cfg.sliding_window:
        c = min(c, cfg.sliding_window)
    return float(c)


def fwd_flops_total(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """Whole-job forward flops for one step of this shape."""
    decode = shape.kind == "decode"
    n_tok = shape.global_batch * (1 if decode else shape.seq_len)
    ctx = _ctx(cfg, shape)

    per_tok = _block_flops_per_tok(cfg, ctx, decode)
    total = per_tok * cfg.n_layers * n_tok

    if cfg.cross_attn_period:
        n_cross = cfg.n_layers // cfg.cross_attn_period
        # replace n_cross self blocks' attn with cross-attn over n_ctx
        self_attn = _attn_flops_per_tok(cfg, ctx)
        cross_attn = (2 * cfg.d_model * cfg.n_heads * cfg.head_dim * 2
                      + 2 * cfg.n_heads * cfg.head_dim * cfg.n_context_tokens
                      * 2)
        total += n_cross * n_tok * (cross_attn - self_attn)
        # context K/V projection, once per sequence
        total += (n_cross * shape.global_batch * cfg.n_context_tokens
                  * 2 * cfg.d_model * 2 * cfg.n_kv_heads * cfg.head_dim)

    if cfg.encoder_decoder:
        t_enc = shape.seq_len if shape.kind == "prefill" \
            else cfg.n_context_tokens
        if not decode:
            # encoder pass over frames (bidirectional ctx = T_enc) — decode
            # attends cached cross K/V, the encoder does NOT re-run
            enc_tok = shape.global_batch * t_enc
            enc_per_tok = _attn_flops_per_tok(cfg, t_enc) \
                + _mlp_flops_per_tok(cfg)
            total += enc_per_tok * cfg.n_encoder_layers * enc_tok
            # cross K/V projections once per sequence
            total += (cfg.n_layers * shape.global_batch * t_enc
                      * 2 * cfg.d_model * 2 * cfg.n_kv_heads * cfg.head_dim)
        # decoder cross-attention to T_enc per decoded token
        cross = (2 * cfg.d_model * cfg.n_heads * cfg.head_dim * 2
                 + 2 * cfg.n_heads * cfg.head_dim * t_enc * 2)
        total += cross * cfg.n_layers * n_tok

    total += 2 * cfg.d_model * cfg.vocab_size * (
        shape.global_batch if decode or shape.kind == "prefill"
        else n_tok)                                     # lm head
    return total


def step_flops_per_chip(cfg: ModelConfig, shape: ShapeSpec,
                        n_chips: int) -> float:
    fwd = fwd_flops_total(cfg, shape)
    if shape.kind == "train":
        head = 2 * cfg.d_model * cfg.vocab_size * shape.global_batch \
            * shape.seq_len
        body = fwd - head
        mult = 4.0 if cfg.remat else 3.0
        return (body * mult + head * 3.0) / n_chips
    return fwd / n_chips


# --- HBM bytes -------------------------------------------------------------


def step_bytes_per_chip(cfg: ModelConfig, shape: ShapeSpec, n_chips: int,
                        schema_bytes_total: int, cache_bytes_total: int,
                        tp: int = 16) -> float:
    """Documented HBM-traffic model (per chip, per step):

    train:   weights: 3 passes (fwd, remat-fwd, bwd) over the TP shard of
             every layer (the FSDP-gathered copy streams through HBM) at
             bf16, + 7 f32 passes over the FSDP-local shard for the
             optimizer (read p,m,v,g; write p,m,v)
             activations: 2·L·B_loc·S·D·2B (checkpoint write + bwd read)
             logits: 3·B_loc·S·V/tp·4B
    prefill: weights 1 bf16 pass over TP shard; activations 1 write+read;
             cache write; flash K/V re-reads ≈ (S/2048)·KV_bytes
    decode:  weights 1 bf16 pass over TP shard; full local cache read +
             1-token write (the canonical decode bound)
    """
    d, v = cfg.d_model, cfg.vocab_size
    b_loc = max(shape.global_batch / (n_chips / tp), 1.0)
    w_tp_bf16 = schema_bytes_total / 4 / tp * 2         # f32 count → bf16
    w_local_f32 = schema_bytes_total / n_chips

    if shape.kind == "train":
        weights = 3 * w_tp_bf16 + 7 * w_local_f32
        acts = 2 * cfg.n_layers * b_loc * shape.seq_len * d * 2
        logits = 3 * b_loc * shape.seq_len * v / tp * 4
        return weights + acts + logits

    if shape.kind == "prefill":
        weights = w_tp_bf16
        acts = 2 * cfg.n_layers * b_loc * shape.seq_len * d * 2
        cache = cache_bytes_total / n_chips
        flash_reread = (shape.seq_len / 2048) * cache
        return weights + acts + cache + flash_reread

    cache_local = cache_bytes_total / n_chips
    return w_tp_bf16 + cache_local

"""Production mesh construction. A FUNCTION (not a module-level constant) so
importing this module never touches jax device state."""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def batch_axes(mesh: Mesh) -> tuple:
    """The data-parallel axes of this mesh ("pod" composes with "data")."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def normalize_pspec(spec: P, mesh: Mesh, shape: tuple | None = None) -> P:
    """Adapt a canonical PartitionSpec to a concrete mesh:
    * drop axis names the mesh doesn't have (e.g. "pod" on the single-pod mesh)
    * drop axes whose dim size isn't divisible by the axis size (e.g. a
      batch=1 long-context cell can't shard its batch dim)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for i, entry in enumerate(spec):
        names = entry if isinstance(entry, tuple) else (
            () if entry is None else (entry,))
        names = tuple(n for n in names if n in sizes)
        if shape is not None and names:
            total = 1
            for n in names:
                total *= sizes[n]
            if shape[i] % total != 0:
                # greedily drop trailing axes until divisible
                while names:
                    total = 1
                    for n in names:
                        total *= sizes[n]
                    if shape[i] % total == 0:
                        break
                    names = names[:-1]
        out.append(names if len(names) != 1 else names[0])
        if out[-1] == ():
            out[-1] = None
    return P(*out)


def named_sharding(mesh: Mesh, spec: P, shape: tuple | None = None
                   ) -> NamedSharding:
    return NamedSharding(mesh, normalize_pspec(spec, mesh, shape))

"""Launch tooling: mesh construction, shapes, analytics, dry-run, trainer.

Deliberately empty of imports: ``launch.dryrun`` pins XLA_FLAGS at import
time and must only be imported by entry points that want 512 host devices.
"""

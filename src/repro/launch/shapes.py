"""The assigned input-shape matrix and per-(arch × shape) applicability."""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str           # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k":    ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k":  ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k":   ShapeSpec("long_500k", "decode", 524_288, 1),
}


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Skip rules from the assignment (recorded in DESIGN.md §4):
    long_500k needs sub-quadratic attention — run for SSM/hybrid/SWA archs,
    skip for pure full-attention archs."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("full-attention arch: 500k dense KV decode is "
                       "quadratic-cost; runnable via --juno-attention only "
                       "(DESIGN.md §4)")
    return True, ""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
"""Multi-pod dry-run (task deliverable e).

For every (architecture × input shape × mesh) cell:
  jit(step).lower(*abstract_inputs).compile()
on the production meshes — (16,16) single-pod and (2,16,16) multi-pod —
recording memory_analysis(), cost_analysis() and the collective schedule
parsed from the optimized HLO. No arrays are ever allocated
(ShapeDtypeStruct stand-ins throughout).

Usage:
  python -m repro.launch.dryrun --arch phi4_mini_3_8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--outdir experiments/dryrun]
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.dist import sharding as act_sharding
from repro.launch import analytic, hlo_analysis
from repro.launch.mesh import (batch_axes, make_production_mesh,
                               named_sharding, normalize_pspec)
from repro.launch.shapes import SHAPES, applicable
from repro.models import get_model
from repro.models.params import Spec, tree_map_specs
from repro.train import TrainConfig, TrainState, make_train_step
from repro.train.optimizer import opt_state_schema


def _structs(schema):
    return tree_map_specs(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
                          schema)


def _shardings(schema, mesh):
    return tree_map_specs(
        lambda s: named_sharding(mesh, s.pspec, s.shape), schema)


def _bytes_of(shape, dtype) -> int:
    n = 1
    for d in shape:
        n *= d
    return n * jnp.dtype(dtype).itemsize


def total_bytes(schema) -> int:
    return sum(_bytes_of(s.shape, s.dtype) for s in
               jax.tree.leaves(schema, is_leaf=lambda x: isinstance(x, Spec)))


def analytic_bytes_per_chip(schema, mesh) -> int:
    """Exact per-chip residency of a Spec tree under its shardings."""
    total = 0
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for s in jax.tree.leaves(schema, is_leaf=lambda x: isinstance(x, Spec)):
        spec = normalize_pspec(s.pspec, mesh, s.shape)
        shards = 1
        for entry in spec:
            names = entry if isinstance(entry, tuple) else (
                () if entry is None else (entry,))
            for n in names:
                shards *= sizes[n]
        total += _bytes_of(s.shape, s.dtype) // shards
    return total


def _model_flops(cfg, schema, shape) -> float:
    """6·N·D (train) / 2·N·D (inference) with MoE active-expert scaling."""
    def leaf_count(tree):
        total, active = 0, 0
        for path, s in jax.tree_util.tree_flatten_with_path(
                tree, is_leaf=lambda x: isinstance(x, Spec))[0]:
            n = 1
            for d in s.shape:
                n *= d
            total += n
            keys = [getattr(k, "key", str(k)) for k in path]
            if cfg.moe and any(k in ("w_gate", "w_in", "w_out") for k in keys) \
                    and len(s.shape) >= 3 and s.shape[-3] == cfg.moe.n_experts:
                active += n * cfg.moe.top_k / cfg.moe.n_experts
            else:
                active += n
        return total, active

    total, active = leaf_count(schema)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        return 2.0 * active * shape.global_batch * shape.seq_len
    return 2.0 * active * shape.global_batch          # decode: 1 token


def lower_juno_cell(multi_pod: bool) -> dict:
    """The paper's own system at pod scale: distributed JUNO search over a
    100M-point index (deep-like: D=96, C=65536, E=256, S=48), clusters
    sharded over all chips, JUNO-H2 mode. Abstract index — no allocation."""
    from repro.core.density import DensityModel
    from repro.core.ivf import IVFIndex
    from repro.core.juno import JunoIndexData
    from repro.core.pq import PQCodebook
    from repro.dist.distributed_index import make_distributed_search

    n, d, c, e, s, g = 100_000_000, 96, 65_536, 256, 48, 64
    p_cap = 6144            # 4× mean cluster size, padded layout
    nq, k = 128, 100

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    f32, i32, u8 = jnp.float32, jnp.int32, jnp.uint8
    index_structs = JunoIndexData(
        ivf=IVFIndex(
            centroids=jax.ShapeDtypeStruct((c, d), f32),
            centroid_sq=jax.ShapeDtypeStruct((c,), f32),
            point_ids=jax.ShapeDtypeStruct((c, p_cap), i32),
            valid=jax.ShapeDtypeStruct((c, p_cap), jnp.bool_),
            labels=jax.ShapeDtypeStruct((n,), i32)),
        codebook=PQCodebook(
            entries=jax.ShapeDtypeStruct((s, e, 2), f32),
            entry_sq=jax.ShapeDtypeStruct((s, e), f32)),
        codes=jax.ShapeDtypeStruct((1, s), u8),     # unused at serve time
        cluster_codes=jax.ShapeDtypeStruct((c, p_cap, s), u8),
        density=DensityModel(
            grid=jax.ShapeDtypeStruct((s, g, g), f32),
            lo=jax.ShapeDtypeStruct((s, 2), f32),
            hi=jax.ShapeDtypeStruct((s, 2), f32),
            coeffs=jax.ShapeDtypeStruct((3,), f32),
            tau_min=jax.ShapeDtypeStruct((), f32),
            tau_max=jax.ShapeDtypeStruct((), f32)),
        points_sq=jax.ShapeDtypeStruct((1,), f32))

    result = {"arch": "juno_ann_100m", "shape": "serve_q128",
              "mesh": "multi" if multi_pod else "single",
              "n_chips": n_chips, "status": "ok"}
    t0 = time.time()
    try:
        with mesh:
            dsearch = make_distributed_search(mesh, local_nprobe=2, k=k,
                                              mode="H2", impl="ref")
            lowered = dsearch.lower(
                index_structs, jax.ShapeDtypeStruct((nq, d), f32))
            compiled = lowered.compile()
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
        colls = hlo_analysis.parse_collectives(hlo)
        summary = hlo_analysis.collective_summary(colls)
        # analytic per-chip flops: filtering GEMM + selective LUT + int8
        # hit scan (÷4 MXU density) + f32 rerank, local shard sizes
        c_loc, probes = c / n_chips, 2
        lut_fl = probes * s * e * 8 * nq
        scan_i8 = probes * p_cap * s * 2 * nq / 4
        rerank_fl = 400 * s * 2 * nq
        filt_fl = 2 * c_loc * d * nq
        flops = filt_fl + lut_fl + scan_i8 + rerank_fl
        hbm = (c_loc * p_cap * s            # local codes streamed once (u8)
               + c_loc * d * 4 + nq * d * 4)
        terms = hlo_analysis.roofline_terms(
            flops, hbm, summary["total_link_bytes_per_chip"], n_chips)
        result.update({
            "compile_s": round(time.time() - t0, 1),
            "raw_cost_flops": float(_cost_dict(cost).get("flops", 0.0)),
            "analytic_flops_per_chip": flops,
            "analytic_hbm_bytes_per_chip": hbm,
            "collectives": summary, "roofline": terms,
            "memory_analysis": _mem_dict(mem),
            "useful_flop_ratio": 1.0,
            "model_flops_per_chip": flops,
        })
    except Exception as e:
        result.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]})
    return result


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               juno_attention: bool = False, sp: bool = False) -> dict:
    if arch == "juno_ann":
        return lower_juno_cell(multi_pod)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    if not ok and not juno_attention:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skip", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    model = get_model(cfg)
    # per-arch SP policy: the cross-attention group-scan interacts badly
    # with the SP schedule (measured 3.2x WORSE on vision-90b train —
    # §Perf notes), so SP is auto-disabled for cross-attn architectures.
    sp = sp and cfg.cross_attn_period == 0
    act_sharding.enable(batch_axes(mesh), sp=sp, mesh=mesh)

    t0 = time.time()
    result = {"arch": arch, "shape": shape_name,
              "mesh": "multi" if multi_pod else "single",
              "n_chips": n_chips, "status": "ok", "sp": sp}
    try:
        with mesh:
            if shape.kind == "train":
                lowered, residency = _lower_train(model, shape, mesh)
            elif shape.kind == "prefill":
                lowered, residency = _lower_prefill(model, shape, mesh)
            else:
                lowered, residency = _lower_decode(model, shape, mesh)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()

        colls = hlo_analysis.parse_collectives(hlo)
        summary = hlo_analysis.collective_summary(colls)
        raw_flops = float(_cost_dict(cost).get("flops", 0.0))
        raw_bytes = float(_cost_dict(cost).get("bytes accessed", 0.0))
        loop_corr = hlo_analysis.loop_correction_factor(hlo)

        # analytic compute/memory terms (cost_analysis counts loop bodies
        # once — see hlo_analysis.py); collectives are HLO-exact.
        tp = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
        sch_bytes = total_bytes(model.schema)
        cache_bytes = 0
        if shape.kind != "train":
            cache_bytes = total_bytes(model.cache_schema(
                shape.global_batch, shape.seq_len))
        flops = analytic.step_flops_per_chip(cfg, shape, n_chips)
        hbm = analytic.step_bytes_per_chip(cfg, shape, n_chips, sch_bytes,
                                           cache_bytes, tp=tp)
        terms = hlo_analysis.roofline_terms(
            flops, hbm, summary["total_link_bytes_per_chip"], n_chips)
        model_fl = _model_flops(cfg, model.schema, shape)

        result.update({
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "raw_cost_flops": raw_flops,
            "raw_cost_bytes": raw_bytes,
            "hlo_loop_correction": round(loop_corr, 1),
            "analytic_flops_per_chip": flops,
            "analytic_hbm_bytes_per_chip": hbm,
            "collectives": summary,
            "roofline": terms,
            "model_flops_total": model_fl,
            "model_flops_per_chip": model_fl / n_chips,
            "useful_flop_ratio": (model_fl / n_chips) / flops if flops else 0,
            "analytic_state_bytes_per_chip": residency,
            "memory_analysis": _mem_dict(mem),
        })
    except Exception as e:  # a failing cell is a bug — record it loudly
        result.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]})
    finally:
        act_sharding.disable()
    return result


def _cost_dict(cost) -> dict:
    """Normalize compiled.cost_analysis() across jax versions (dict vs
    one-element list of dicts)."""
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def _mem_dict(mem) -> dict:
    out = {}
    for k in ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "alias_size_in_bytes",
              "temp_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _lower_train(model, shape, mesh):
    grad_pspecs = tree_map_specs(
        lambda s: normalize_pspec(s.pspec, mesh, s.shape), model.schema)
    tstep = make_train_step(model, TrainConfig(), grad_pspecs=grad_pspecs)
    state_schema = TrainState(params=model.schema,
                              opt=opt_state_schema(model.schema))
    per_pod_batch = shape.global_batch
    batch_schema = model.batch_schema(per_pod_batch, shape.seq_len)

    state_sh = _shardings(state_schema, mesh)
    batch_sh = _shardings(batch_schema, mesh)
    fn = jax.jit(tstep, in_shardings=(state_sh, batch_sh),
                 out_shardings=(state_sh, None))
    lowered = fn.lower(_structs(state_schema), _structs(batch_schema))
    residency = analytic_bytes_per_chip(state_schema, mesh)
    return lowered, residency


def _lower_prefill(model, shape, mesh):
    def prefill_step(params, batch, cache):
        return model.prefill(params, batch, cache)

    batch_schema = model.batch_schema(shape.global_batch, shape.seq_len)
    cache_schema = model.cache_schema(shape.global_batch, shape.seq_len)
    if model.cfg.encoder_decoder:
        # prefill_32k stresses the ENCODER: frames length = shape.seq_len
        batch_schema = dict(batch_schema)
        batch_schema["frames"] = Spec(
            (shape.global_batch, shape.seq_len, model.cfg.d_model),
            P(("pod", "data"), None, None), "normal", model.cfg.dtype)
        batch_schema["tokens"] = Spec((shape.global_batch, 64),
                                      P(("pod", "data"), None), "zeros",
                                      jnp.int32)
        del batch_schema["targets"]
        cache_schema = model.cache_schema(shape.global_batch, 4096)
    else:
        batch_schema = {k: v for k, v in batch_schema.items()
                        if k != "targets"}

    p_sh = _shardings(model.schema, mesh)
    b_sh = _shardings(batch_schema, mesh)
    c_sh = _shardings(cache_schema, mesh)
    fn = jax.jit(prefill_step, in_shardings=(p_sh, b_sh, c_sh),
                 out_shardings=(None, c_sh))
    lowered = fn.lower(_structs(model.schema), _structs(batch_schema),
                       _structs(cache_schema))
    residency = (analytic_bytes_per_chip(model.schema, mesh)
                 + analytic_bytes_per_chip(cache_schema, mesh))
    return lowered, residency


def _serving_schema(model, max_tp_resident_gb: float = 6.0):
    """Serving layout (§Perf decode iterations 2-3):
    * params are bf16 (inference checkpoints) — halves gather traffic;
    * FSDP is a TRAINING artifact: if the pure-TP residency (params/16)
      fits comfortably, drop the "data" axis from weight shardings so decode
      performs ZERO per-step weight gathers (weights stay resident).
      Large models (mistral-123b, vision-90b) keep the 2D layout."""
    tp_resident = total_bytes(model.schema) / 4 * 2 / 16   # bf16 over TP=16
    drop_data = tp_resident <= max_tp_resident_gb * 1e9

    def one(s):
        dtype = jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype
        spec = s.pspec
        if drop_data:
            entries = []
            for e in spec:
                if e == "data":
                    entries.append(None)
                elif isinstance(e, tuple):
                    kept = tuple(a for a in e if a != "data")
                    entries.append(kept if kept else None)
                else:
                    entries.append(e)
            spec = P(*entries)
        return Spec(s.shape, spec, s.init, dtype)

    return tree_map_specs(one, model.schema)


def _lower_decode(model, shape, mesh):
    def serve_step(params, cache, token, pos):
        return model.decode(params, cache, token, pos)

    serving_schema = _serving_schema(model)
    cache_schema = model.cache_schema(shape.global_batch, shape.seq_len)
    token_schema = Spec((shape.global_batch, 1), P(("pod", "data"), None),
                        "zeros", jnp.int32)

    p_sh = _shardings(serving_schema, mesh)
    c_sh = _shardings(cache_schema, mesh)
    t_sh = named_sharding(mesh, token_schema.pspec, token_schema.shape)
    pos_sh = named_sharding(mesh, P(("pod", "data")), (shape.global_batch,))
    fn = jax.jit(serve_step, in_shardings=(p_sh, c_sh, t_sh, pos_sh),
                 out_shardings=(None, c_sh))
    lowered = fn.lower(_structs(serving_schema), _structs(cache_schema),
                       jax.ShapeDtypeStruct((shape.global_batch, 1),
                                            jnp.int32),
                       jax.ShapeDtypeStruct((shape.global_batch,),
                                            jnp.int32))
    residency = (analytic_bytes_per_chip(serving_schema, mesh)
                 + analytic_bytes_per_chip(cache_schema, mesh))
    return lowered, residency


def input_specs(arch: str, shape_name: str = "train_4k") -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a cell — the
    public hook the task spec asks for."""
    cfg = get_config(arch)
    model = get_model(cfg)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return _structs(model.batch_schema(shape.global_batch, shape.seq_len))
    if shape.kind == "prefill":
        b = model.batch_schema(shape.global_batch, shape.seq_len)
        return _structs({k: v for k, v in b.items() if k != "targets"})
    return {"token": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--outdir", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--sp", action="store_true",
                    help="sequence-parallel optimized variant (§Perf)")
    args = ap.parse_args()

    os.makedirs(args.outdir, exist_ok=True)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    cells = []
    archs = ARCH_IDS if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    for a in archs:
        for s in shapes:
            for m in meshes:
                cells.append((a, s, m))

    n_bad = 0
    for arch, shape, multi in cells:
        tag = f"{arch}_{shape}_{'multi' if multi else 'single'}"
        path = os.path.join(args.outdir, tag + ".json")
        if os.path.exists(path) and not args.force:
            with open(path) as f:
                prev = json.load(f)
            print(f"[cache] {tag}: {prev['status']}")
            n_bad += prev["status"] == "error"
            continue
        t0 = time.time()
        res = lower_cell(arch, shape, multi, sp=args.sp)
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        n_bad += res["status"] == "error"
        extra = ""
        if res["status"] == "ok":
            r = res["roofline"]
            extra = (f" dominant={r['dominant']}"
                     f" c/m/coll={r['compute_s']:.2e}/{r['memory_s']:.2e}"
                     f"/{r['collective_s']:.2e}s"
                     f" useful={res['useful_flop_ratio']:.2f}")
        elif res["status"] == "error":
            extra = " " + res["error"][:160]
        print(f"[{res['status']}] {tag} ({time.time() - t0:.0f}s){extra}",
              flush=True)
    print(f"done; {n_bad} errors")
    return 1 if n_bad else 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Training driver: end-to-end runnable on this CPU container (smoke
configs) and mesh-shaped for the pod (full configs).

    PYTHONPATH=src python -m repro.launch.train --arch phi4_mini_3_8b \
        --smoke --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt --resume
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.data.tokens import make_batch
from repro.dist import checkpoint as ckpt_lib
from repro.dist.fault_tolerance import StepWatchdog
from repro.models import get_model
from repro.train import (AdamWConfig, TrainConfig, init_train_state,
                         make_train_step)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="phi4_mini_3_8b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--grad-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = get_model(cfg)
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=args.lr, warmup_steps=10),
                       accum_steps=args.accum, grad_dtype=args.grad_dtype)
    step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=0)

    state = init_train_state(model, jax.random.PRNGKey(args.seed))
    start = 0
    if args.resume and args.ckpt_dir and ckpt_lib.latest_step(args.ckpt_dir):
        state, start = ckpt_lib.restore(args.ckpt_dir, state)
        print(f"resumed from step {start}")

    watchdog = StepWatchdog()
    losses = []
    for step in range(start, args.steps):
        t0 = time.time()
        batch = make_batch(cfg, batch=args.batch, seq=args.seq, step=step,
                           seed=args.seed)
        state, metrics = step_fn(state, batch)
        dt = time.time() - t0
        status = watchdog.check(dt)
        loss = float(metrics["loss"])
        losses.append(loss)
        print(f"step {step:5d} loss {loss:.4f} "
              f"gnorm {float(metrics['grad_norm']):.3f} {dt * 1e3:.0f}ms"
              + (f" [{status}]" if status != "ok" else ""), flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            ckpt_lib.save(args.ckpt_dir, step + 1, state)
    if not losses:                  # resumed at or past --steps: no-op run
        print(f"nothing to do: resumed at step {start} >= --steps "
              f"{args.steps}")
        return losses
    if args.ckpt_dir:
        ckpt_lib.save(args.ckpt_dir, args.steps, state)
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()

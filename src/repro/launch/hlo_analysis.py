"""Post-SPMD HLO analysis: collective traffic + roofline terms.

``cost_analysis()`` has FLOPs/bytes but (a) no collective traffic and (b)
counts while-loop bodies ONCE (verified empirically: a 10-iteration scan of
matmuls reports 1 matmul of flops). Since every model here scans its layers,
we parse the optimized HLO text into computation blocks, build the call
graph (calls= / to_apply= / condition= / body= / branch_computations=),
extract while trip counts from loop-condition constants, and scale each
computation's collective bytes by its total trip multiplier. The same
multiplier machinery reports the aggregate loop correction factor so the raw
cost_analysis numbers can be sanity-checked against the analytic model
(launch/analytic.py) that feeds the compute/memory roofline terms.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Iterable

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([\d,]*)\]")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_LIST_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CALL_KEYS_RE = re.compile(
    r"(?:calls|to_apply|condition|body)=%([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_WHILE_RE = re.compile(
    r"while\(.*?condition=%([\w.\-]+),\s*body=%([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")
_COLL_RE = re.compile(
    r"=\s*(?:\()?[a-z0-9]+\[[^\]]*\][^=]*?\b"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    result_bytes: int
    group_size: int
    multiplier: float = 1.0       # loop trip-count product

    @property
    def per_chip_link_bytes(self) -> float:
        """Ring-algorithm bytes each participating chip moves over links."""
        n, b = self.group_size, self.result_bytes * self.multiplier
        if n <= 1:
            return 0.0
        if self.kind == "all-gather":          # result = full gathered tensor
            return b * (n - 1) / n
        if self.kind == "reduce-scatter":      # result = 1/n of the input
            return b * (n - 1)
        if self.kind == "all-reduce":          # RS + AG
            return 2.0 * b * (n - 1) / n
        if self.kind == "all-to-all":
            return b * (n - 1) / n
        return float(b)                         # collective-permute


def _shape_bytes(line: str) -> int:
    """Byte size of the result shape(s): everything between '=' and the op
    name (post-opt HLO shows only the result shape inline)."""
    if "=" not in line:
        return 0
    rhs = line.split("=", 1)[1]
    # cut at the op call parenthesis to avoid parsing attribute brackets
    m = re.search(r"\b[a-z][a-z0-9\-]*\(", rhs)
    head = rhs[:m.start()] if m else rhs
    total = 0
    for dt, dims in _SHAPE_RE.findall(head):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(2))                 # [G, N] → G groups of N
    m = _LIST_GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return 1


def split_computations(hlo_text: str) -> dict:
    """name → list of body lines (computation blocks)."""
    comps: dict = {}
    cur = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{", stripped)
        if m and not line.startswith("  "):
            cur = m.group(1)
            comps[cur] = []
            continue
        if stripped == "}":
            # only close top-level blocks
            if not line.startswith("  "):
                cur = None
            continue
        if cur is not None:
            comps[cur].append(stripped)
    return comps


def _trip_count(cond_lines: list) -> int:
    """Largest integer constant in the loop condition ≈ trip count."""
    best = 1
    for line in cond_lines:
        if "constant(" in line:
            for c in _CONST_RE.findall(line):
                best = max(best, int(c))
    return best


def computation_multipliers(comps: dict) -> dict:
    """name → total execution multiplier (product of enclosing loop trips)."""
    edges: dict = {name: [] for name in comps}
    for name, lines in comps.items():
        for line in lines:
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                trips = _trip_count(comps.get(cond, []))
                edges[name].append((body, trips))
                edges[name].append((cond, trips + 1))
                continue
            for callee in _CALL_KEYS_RE.findall(line):
                if callee in comps:
                    edges[name].append((callee, 1))
            bm = _BRANCHES_RE.search(line)
            if bm:
                for callee in re.findall(r"%([\w.\-]+)", bm.group(1)):
                    if callee in comps:
                        edges[name].append((callee, 1))

    roots = [n for n in comps
             if n.startswith("main") or ".main" in n or n == "main"]
    if not roots:
        roots = [next(iter(comps))] if comps else []
    mult = {n: 0.0 for n in comps}

    def visit(name, m, depth=0):
        if depth > 50:
            return
        mult[name] = mult.get(name, 0.0) + m
        for callee, trips in edges.get(name, []):
            visit(callee, m * trips, depth + 1)

    for r in roots:
        visit(r, 1.0)
    return mult


def parse_collectives(hlo_text: str) -> list:
    """Collectives with loop-trip multipliers applied."""
    comps = split_computations(hlo_text)
    if not comps:                         # fallback: flat scan, multiplier 1
        comps = {"main": hlo_text.splitlines()}
        mult = {"main": 1.0}
    else:
        mult = computation_multipliers(comps)

    ops = []
    for name, lines in comps.items():
        m = max(mult.get(name, 1.0), 0.0)
        if m == 0.0:
            m = 1.0                       # unreachable block: count once
        for line in lines:
            cm = _COLL_RE.search(line)
            if not cm:
                continue
            ops.append(CollectiveOp(
                kind=cm.group(1),
                result_bytes=_shape_bytes(line),
                group_size=_group_size(line),
                multiplier=m))
    return ops


def loop_correction_factor(hlo_text: str) -> float:
    """Rough aggregate trip-count correction: mean multiplier over
    computations that contain dots (for sanity-checking cost_analysis)."""
    comps = split_computations(hlo_text)
    mult = computation_multipliers(comps)
    weights = []
    for name, lines in comps.items():
        n_dots = sum(1 for l in lines if " dot(" in l or " dot." in l)
        if n_dots:
            weights.append((n_dots, max(mult.get(name, 1.0), 1.0)))
    if not weights:
        return 1.0
    tot = sum(w for w, _ in weights)
    return sum(w * m for w, m in weights) / tot


def collective_summary(ops: Iterable[CollectiveOp]) -> dict:
    out: dict = {}
    total = 0.0
    for op in ops:
        d = out.setdefault(op.kind, {"count": 0, "result_bytes": 0,
                                     "link_bytes_per_chip": 0.0})
        d["count"] += 1
        d["result_bytes"] += int(op.result_bytes * op.multiplier)
        d["link_bytes_per_chip"] += op.per_chip_link_bytes
        total += op.per_chip_link_bytes
    out["total_link_bytes_per_chip"] = total
    return out


# --- roofline -------------------------------------------------------------

TPU_V5E = {
    "peak_flops_bf16": 197e12,     # per chip
    "hbm_bw": 819e9,               # B/s per chip
    "link_bw": 50e9,               # B/s per ICI link
}


def roofline_terms(flops_per_chip: float, hbm_bytes_per_chip: float,
                   coll_link_bytes_per_chip: float, n_chips: int,
                   hw: dict = TPU_V5E) -> dict:
    """The three terms in seconds (whole step, per-chip quantities over
    per-chip rates — the task's chips×rate denominators cancel against
    chips×per-chip numerators)."""
    compute = flops_per_chip / hw["peak_flops_bf16"]
    memory = hbm_bytes_per_chip / hw["hbm_bw"]
    collective = coll_link_bytes_per_chip / hw["link_bw"]
    dominant = max(("compute", compute), ("memory", memory),
                   ("collective", collective), key=lambda t: t[1])[0]
    bound = max(compute, memory, collective)
    return {"compute_s": compute, "memory_s": memory,
            "collective_s": collective, "dominant": dominant,
            "bound_s": bound,
            "roofline_fraction": compute / bound if bound else 0.0}

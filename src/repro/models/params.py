"""Declarative parameter schemas.

A schema is a pytree whose leaves are ``Spec(shape, pspec, init, dtype)``.
The same schema serves three consumers:
  * ``init_params``     — materialise real arrays (smoke tests, examples)
  * ``abstract_params`` — ShapeDtypeStruct stand-ins (dry-run, no allocation)
  * ``shardings``       — NamedSharding tree for pjit in_shardings
Stacked layers: ``stack(schema, n)`` prepends a layer axis (never sharded)
to every leaf — the layout ``lax.scan`` consumes and FSDP overlaps on.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class Spec(NamedTuple):
    shape: tuple
    pspec: P
    init: str = "normal"     # "normal" | "zeros" | "ones" | "embed"
    dtype: Any = jnp.float32


def is_spec(x) -> bool:
    return isinstance(x, Spec)


def tree_map_specs(fn: Callable[[Spec], Any], schema):
    return jax.tree.map(fn, schema, is_leaf=is_spec)


def stack(schema, n: int):
    """Prepend a stacked-layer axis of size n to every leaf."""
    return tree_map_specs(
        lambda s: Spec((n,) + s.shape, P(None, *s.pspec), s.init, s.dtype),
        schema)


def init_params(schema, key: jax.Array):
    leaves, treedef = jax.tree.flatten(schema, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))

    def one(s: Spec, k):
        if s.init == "zeros":
            return jnp.zeros(s.shape, s.dtype)
        if s.init == "ones":
            return jnp.ones(s.shape, s.dtype)
        if s.init == "neg":
            return jnp.full(s.shape, -1, s.dtype)
        fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
        scale = 0.02 if s.init == "embed" else fan_in ** -0.5
        return (jax.random.normal(k, s.shape, jnp.float32) * scale
                ).astype(s.dtype)

    return treedef.unflatten([one(s, k) for s, k in zip(leaves, keys)])


def abstract_params(schema, mesh: Optional[Mesh] = None):
    """ShapeDtypeStruct tree; with a mesh, structs carry shardings so
    jit.lower() sees the intended layout without allocating anything."""
    def one(s: Spec):
        if mesh is None:
            return jax.ShapeDtypeStruct(s.shape, s.dtype)
        return jax.ShapeDtypeStruct(s.shape, s.dtype,
                                    sharding=NamedSharding(mesh, s.pspec))
    return tree_map_specs(one, schema)


def shardings(schema, mesh: Mesh):
    return tree_map_specs(lambda s: NamedSharding(mesh, s.pspec), schema)


def pspecs(schema):
    return tree_map_specs(lambda s: s.pspec, schema)


def cast_floats(tree, dtype):
    """Cast float leaves to the compute dtype (applied per scanned block so
    the cast happens after the FSDP gather, layer by layer)."""
    def one(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree.map(one, tree)


def n_params(schema) -> int:
    leaves = jax.tree.leaves(schema, is_leaf=is_spec)
    total = 0
    for s in leaves:
        n = 1
        for d in s.shape:
            n *= d
        total += n
    return total

"""Shared neural building blocks (pure JAX, functional).

Attention is implemented flash-style (double-blocked online-softmax) in pure
``lax.scan``/``lax.map`` so 32k-token prefill and 4k training lower with
O(chunk^2) live scores instead of O(S^2). bf16 compute, f32 softmax state.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------------
# norms / activations / rope
# --------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * weight.astype(dt)


def swiglu(x, w_gate, w_in, w_out):
    h = jax.nn.silu(x @ w_gate) * (x @ w_in)
    return h @ w_out


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 1e4):
    """x (..., T, H, hd), positions (..., T) int32 → same shape, rotated."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                         # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., T, hd/2)
    cos = jnp.cos(ang)[..., None, :]                    # (..., T, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention — double-blocked online softmax
# --------------------------------------------------------------------------

NEG_INF = -1e30


def _repeat_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    if n_rep == 1:
        return k
    b, t, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, t, h, n_rep, d)
                            ).reshape(b, t, h * n_rep, d)


def _block_mask(q_pos, k_pos, *, causal: bool, window: Optional[int]):
    """(B, Tq, Tk) bool validity mask from (B, Tq)/(B, Tk) positions."""
    m = jnp.ones(q_pos.shape + (k_pos.shape[-1],), bool)
    if causal:
        m &= k_pos[..., None, :] <= q_pos[..., :, None]
    if window is not None:
        m &= k_pos[..., None, :] > q_pos[..., :, None] - window
    return m


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
              causal: bool = True, window: Optional[int] = None,
              q_offset=0, kv_offset=0, kv_len: Optional[jnp.ndarray] = None,
              k_positions: Optional[jnp.ndarray] = None,
              chunk: int = 1024) -> jnp.ndarray:
    """q (B, Tq, H, hd); k/v (B, Tk, KVH, hd) → (B, Tq, H, hd).

    - GQA: KVH broadcast to H.
    - ``q_offset``/``kv_offset``: absolute positions (decode: q_offset=pos).
    - ``kv_len``: optional dynamic valid-length of k/v (decode against a
      preallocated cache).
    - ``k_positions``: explicit absolute position per KV slot (ring-buffer
      SWA caches); entries < 0 are masked out.
    - flash path engages when Tk > 2*chunk: sequential q-blocks (lax.map)
      over scanned kv-blocks with online max/denominator.
    """
    b, tq, h, hd = q.shape
    tk, kvh = k.shape[1], k.shape[2]
    hd_v = v.shape[-1]                 # MLA: v head dim ≠ qk head dim
    scale = 1.0 / (hd ** 0.5)

    if tq <= 4:
        # decode: grouped-GQA path — contract against K/V WITHOUT
        # materialising repeated heads. _repeat_kv's broadcast+reshape forces
        # the partitioner to all-gather the whole sequence-sharded cache
        # (measured 64 GB/step on phi4 decode_32k: §Perf decode iteration 1);
        # the grouped einsum leaves S sharded and reduces only the (B,H,hd)
        # output partial. MHA (g=1) takes the same path: it avoids the flash
        # scan whose chunked slicing also breaks the cache's S-sharding.
        g = h // kvh
        qg = q.reshape(b, tq, kvh, g, hd)
        q_pos_d = (jnp.asarray(q_offset)[..., None]
                   if jnp.asarray(q_offset).ndim else
                   jnp.asarray(q_offset)) + jnp.arange(tq)
        q_pos_d = jnp.broadcast_to(q_pos_d, (b, tq))
        if k_positions is not None:
            k_pos_d = jnp.where(k_positions < 0, 2 ** 30, k_positions)
            k_pos_d = jnp.broadcast_to(
                k_pos_d if k_pos_d.ndim == 2 else k_pos_d[None], (b, tk))
        else:
            k_pos_d = jnp.broadcast_to(kv_offset + jnp.arange(tk)[None],
                                       (b, tk))
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                       preferred_element_type=jnp.float32) * scale
        m = _block_mask(q_pos_d, k_pos_d, causal=causal, window=window)
        if kv_len is not None:
            kv_len_d = jnp.broadcast_to(jnp.asarray(kv_len), (b,))
            m &= (k_pos_d < kv_len_d[:, None])[:, None, :]
        s = jnp.where(m[:, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)
        return o.reshape(b, tq, h, hd_v)

    k = _repeat_kv(k, h // kvh)
    v = _repeat_kv(v, h // kvh)
    # positions normalised to (B, T): scalar or per-batch (B,) offsets both
    # supported (per-slot decode positions for continuous batching)
    q_off = jnp.asarray(q_offset)
    q_pos = (q_off[..., None] if q_off.ndim else q_off) + jnp.arange(tq)
    q_pos = jnp.broadcast_to(q_pos, (b, tq))
    if k_positions is not None:
        k_pos = jnp.where(k_positions < 0, 2 ** 30, k_positions)
        k_pos = jnp.broadcast_to(
            k_pos if k_pos.ndim == 2 else k_pos[None], (b, tk))
    else:
        k_pos = jnp.broadcast_to(kv_offset + jnp.arange(tk)[None], (b, tk))
    if kv_len is not None:
        kv_len = jnp.broadcast_to(jnp.asarray(kv_len), (b,))

    if tk <= 2 * chunk:   # direct path
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                       preferred_element_type=jnp.float32) * scale
        m = _block_mask(q_pos, k_pos, causal=causal, window=window)
        if kv_len is not None:
            m &= (k_pos < kv_len[:, None])[:, None, :]
        s = jnp.where(m[:, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)

    # ---- flash path ----
    n_kc = -(-tk // chunk)
    pad_k = n_kc * chunk - tk
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad_k)), constant_values=2 ** 30)
    kc = k.reshape(b, n_kc, chunk, h, hd).swapaxes(0, 1)      # (n_kc, B, c, H, hd)
    vc = v.reshape(b, n_kc, chunk, h, hd_v).swapaxes(0, 1)
    kp = k_pos.reshape(b, n_kc, chunk).swapaxes(0, 1)         # (n_kc, B, c)

    qc_size = min(chunk, tq)
    n_qc = -(-tq // qc_size)
    pad_q = n_qc * qc_size - tq
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad_q)), constant_values=2 ** 30)
    qs = q.reshape(b, n_qc, qc_size, h, hd).swapaxes(0, 1)
    qp = q_pos.reshape(b, n_qc, qc_size).swapaxes(0, 1)       # (n_qc, B, qc)

    def one_q_block(args):
        qb, qpb = args                                        # (B, qc, H, hd)

        def kv_step(carry, xs):
            m_run, l_run, acc = carry
            kb, vb, kpb = xs
            s = jnp.einsum("bqhd,bkhd->bhqk", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            msk = _block_mask(qpb, kpb, causal=causal, window=window)
            if kv_len is not None:
                msk &= (kpb < kv_len[:, None])[:, None, :]
            s = jnp.where(msk[:, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, jnp.max(s, -1))        # (B, H, qc)
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + jnp.sum(p, -1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vb.astype(jnp.float32))
            return (m_new, l_new, acc), None

        init = (jnp.full((b, h, qc_size), NEG_INF, jnp.float32),
                jnp.zeros((b, h, qc_size), jnp.float32),
                jnp.zeros((b, h, qc_size, hd_v), jnp.float32))
        (m_f, l_f, acc), _ = jax.lax.scan(kv_step, init, (kc, vc, kp))
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]        # (B, H, qc, hd)
        return out.swapaxes(1, 2)                             # (B, qc, H, hd)

    out = jax.lax.map(one_q_block, (qs, qp))                  # (n_qc, B, qc, H, hd_v)
    out = out.swapaxes(0, 1).reshape(b, n_qc * qc_size, h, hd_v)
    return out[:, :tq].astype(q.dtype)


# --------------------------------------------------------------------------
# standard projections
# --------------------------------------------------------------------------


def gqa_qkv(x, p, cfg, positions):
    """x (B, T, D) → q (B,T,H,hd), k/v (B,T,KVH,hd), rope applied."""
    from repro.dist.sharding import constrain_heads
    b, t, _ = x.shape
    q = (x @ p["wq"]).reshape(b, t, cfg.n_heads, cfg.head_dim)
    k = (x @ p["wk"]).reshape(b, t, cfg.n_kv_heads, cfg.head_dim)
    v = (x @ p["wv"]).reshape(b, t, cfg.n_kv_heads, cfg.head_dim)
    q = constrain_heads(apply_rope(q, positions, cfg.rope_theta))
    k = constrain_heads(apply_rope(k, positions, cfg.rope_theta))
    v = constrain_heads(v)
    return q, k, v


def attn_out(o, p):
    b, t, h, hd = o.shape
    return o.reshape(b, t, h * hd) @ p["wo"]

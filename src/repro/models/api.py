"""Family-dispatch API: one entry point per model kind.

``get_model(cfg)`` returns a ModelAPI whose five callables hide the family
differences (decoder-only / enc-dec / VLM) from the training loop, the
serving loop and the dry-run.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import transformer, whisper
from .config import ModelConfig
from .params import Spec


class ModelAPI(NamedTuple):
    cfg: ModelConfig
    schema: dict                       # param Spec tree
    cache_schema: Callable             # (batch, max_seq) -> Spec tree
    batch_schema: Callable             # (batch, seq) -> Spec tree (inputs)
    loss: Callable                     # (params, batch) -> scalar loss
    prefill: Callable                  # (params, batch, cache) -> (logits, cache)
    decode: Callable                   # (params, cache, token, pos) -> (logits, cache)


def _xent(logits: jnp.ndarray, targets: jnp.ndarray) -> jnp.ndarray:
    """Mean next-token cross-entropy. logits (B, T, V) f32, targets (B, T)."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


_BATCH_P = P(("pod", "data"))


def _token_batch_schema(cfg: ModelConfig):
    def make(batch: int, seq: int) -> dict:
        sch = {
            "tokens": Spec((batch, seq), P(("pod", "data"), None), "zeros",
                           jnp.int32),
            "targets": Spec((batch, seq), P(("pod", "data"), None), "zeros",
                            jnp.int32),
        }
        if cfg.encoder_decoder:
            sch["frames"] = Spec((batch, cfg.n_context_tokens, cfg.d_model),
                                 P(("pod", "data"), None, None), "normal",
                                 cfg.dtype)
        elif cfg.cross_attn_period:
            sch["context"] = Spec((batch, cfg.n_context_tokens, cfg.d_model),
                                  P(("pod", "data"), None, None), "normal",
                                  cfg.dtype)
        return sch
    return make


def get_model(cfg: ModelConfig) -> ModelAPI:
    if cfg.encoder_decoder:
        return _whisper_api(cfg)
    return _decoder_api(cfg)


def _decoder_api(cfg: ModelConfig) -> ModelAPI:
    schema = transformer.model_schema(cfg)

    def loss(params, batch):
        ctx = batch.get("context")
        x = transformer.forward(cfg, params, batch["tokens"], context=ctx)
        logits = transformer.lm_logits(cfg, params, x)
        return _xent(logits, batch["targets"])

    def prefill_fn(params, batch, cache):
        ctx = batch.get("context")
        return transformer.prefill(cfg, params, batch["tokens"], cache,
                                   context=ctx)

    def decode_fn(params, cache, token, pos):
        return transformer.decode(cfg, params, cache, token, pos)

    return ModelAPI(
        cfg=cfg, schema=schema,
        cache_schema=lambda b, s: transformer.init_cache_schema(cfg, b, s),
        batch_schema=_token_batch_schema(cfg),
        loss=loss, prefill=prefill_fn, decode=decode_fn)


def _whisper_api(cfg: ModelConfig) -> ModelAPI:
    schema = whisper.model_schema(cfg)

    def loss(params, batch):
        enc = whisper.encode(cfg, params, batch["frames"])
        x = whisper.decoder_forward(cfg, params, batch["tokens"], enc)
        logits = transformer.lm_logits(cfg, params, x)
        return _xent(logits, batch["targets"])

    def prefill_fn(params, batch, cache):
        return whisper.prefill(cfg, params, batch["frames"],
                               batch["tokens"], cache)

    def decode_fn(params, cache, token, pos):
        return whisper.decode(cfg, params, cache, token, pos)

    return ModelAPI(
        cfg=cfg, schema=schema,
        cache_schema=lambda b, s: whisper.init_cache_schema(
            cfg, b, s, cfg.n_context_tokens),
        batch_schema=_token_batch_schema(cfg),
        loss=loss, prefill=prefill_fn, decode=decode_fn)

"""Unified model configuration covering all 10 assigned architectures.

One declarative dataclass; each ``src/repro/configs/<arch>.py`` instantiates
it with the exact published numbers. The model code dispatches on the
``attn_kind`` / ``mixer_kind`` / ``moe`` / ``cross_attn_period`` /
``encoder_decoder`` fields, so every family (dense / MoE / MLA / SSM /
hybrid / enc-dec / VLM) is a configuration, not a fork.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int            # routed experts
    top_k: int
    d_ff_expert: int
    n_shared: int = 0         # always-on shared experts (DeepSeek style)
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 256          # SSD chunk length


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                       # 0 → d_model // n_heads

    # mixer selection
    attn_kind: str = "gqa"                  # "gqa" | "mla" | "none"
    mixer_kind: str = "attn"                # "attn" | "ssm" | "hybrid"
    sliding_window: Optional[int] = None    # SWA width (tokens) or None

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None

    # structure
    cross_attn_period: int = 0              # every Nth layer cross-attends
    n_context_tokens: int = 0               # cross-attn context length (stub frontend)
    encoder_decoder: bool = False
    n_encoder_layers: int = 0
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # numerics / memory policy
    dtype: str = "bfloat16"                 # activation/compute dtype
    param_dtype: str = "float32"
    remat: bool = True
    attn_chunk: int = 1024                  # flash-style KV block size

    # training
    max_seq_len: int = 8192
    accum_steps: int = 1

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_heads % max(self.n_kv_heads, 1) == 0 or \
            self.attn_kind != "gqa"

    @property
    def is_ssm_only(self) -> bool:
        return self.mixer_kind == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """True if serve_step memory is bounded independent of context length
        (SSM state, or sliding-window attention)."""
        return (self.mixer_kind == "ssm"
                or (self.sliding_window is not None)
                or (self.mixer_kind == "hybrid"
                    and self.sliding_window is not None))

    def n_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head), for the
        6·N·D roofline term. MoE counts all experts; n_active_params()
        counts the activated subset."""
        return self._count(active_only=False)

    def n_active_params(self) -> int:
        return self._count(active_only=True)

    def _count(self, active_only: bool) -> int:
        d, hd = self.d_model, self.head_dim
        nh, nkv = self.n_heads, self.n_kv_heads
        total = self.vocab_size * d                       # embed
        if not self.tie_embeddings:
            total += d * self.vocab_size                  # lm_head

        def attn_params():
            if self.attn_kind == "mla":
                m = self.mla or MLAConfig()
                qd = nh * (m.qk_nope_dim + m.qk_rope_dim)
                p = d * qd                                             # q
                p += d * (m.kv_lora_rank + m.qk_rope_dim)              # kv down
                p += m.kv_lora_rank * nh * (m.qk_nope_dim + m.v_head_dim)
                p += nh * m.v_head_dim * d                             # o
                return p
            return d * (nh * hd) + 2 * d * (nkv * hd) + (nh * hd) * d

        def mlp_params():
            if self.moe:
                e = (self.moe.top_k if active_only else self.moe.n_experts)
                p = 3 * d * self.moe.d_ff_expert * (e + self.moe.n_shared)
                p += d * self.moe.n_experts                            # router
                return p
            return 3 * d * self.d_ff                                   # swiglu

        def ssm_params():
            s = self.ssm or SSMConfig()
            d_in = s.expand * d
            p = d * (2 * d_in + 2 * s.n_groups * s.d_state + d_in // s.head_dim)
            p += d_in * d                                              # out
            return p

        per_layer = 2 * d                                              # norms
        if self.mixer_kind == "attn":
            per_layer += attn_params() + (mlp_params() if self.d_ff or self.moe else 0)
        elif self.mixer_kind == "ssm":
            per_layer = d + ssm_params()
        else:  # hybrid: both mixers in parallel + mlp
            per_layer += attn_params() + ssm_params() + mlp_params()

        n_blocks = self.n_layers
        if self.cross_attn_period:
            n_cross = self.n_layers // self.cross_attn_period
            n_blocks = self.n_layers - n_cross
            total += n_cross * (attn_params() + mlp_params() + 2 * d)
        total += n_blocks * per_layer
        if self.encoder_decoder:
            # encoder blocks (self-attn + mlp) + decoder cross-attn add-ons
            total += self.n_encoder_layers * (attn_params() + mlp_params()
                                              + 2 * d)
            total += self.n_layers * (attn_params() + d)   # cross per dec layer
        return total

"""Multi-head Latent Attention (DeepSeek-V2), with the compressed-latent KV
cache and the absorbed-projection decode path (scores computed in latent
space so the per-step cost is O(S·lora), not O(S·H·hd))."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from .layers import apply_rope, attention
from .params import Spec


def mla_schema(cfg: ModelConfig) -> dict:
    m = cfg.mla
    h = cfg.n_heads
    return {
        "wq":     Spec((cfg.d_model, h * (m.qk_nope_dim + m.qk_rope_dim)),
                       P("data", "model")),
        "w_dkv":  Spec((cfg.d_model, m.kv_lora_rank), P("data", None)),
        "w_krope": Spec((cfg.d_model, m.qk_rope_dim), P("data", None)),
        "w_uk":   Spec((m.kv_lora_rank, h, m.qk_nope_dim),
                       P(None, "model", None)),
        "w_uv":   Spec((m.kv_lora_rank, h, m.v_head_dim),
                       P(None, "model", None)),
        "wo":     Spec((h * m.v_head_dim, cfg.d_model), P("model", "data")),
    }


def _project_q(x, p, cfg, positions):
    m = cfg.mla
    b, t, _ = x.shape
    q = (x @ p["wq"]).reshape(b, t, cfg.n_heads, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _latent_kv(x, p, cfg, positions):
    m = cfg.mla
    ckv = x @ p["w_dkv"]                                     # (B, T, lora)
    kr = (x @ p["w_krope"])[:, :, None, :]                   # (B, T, 1, rope)
    kr = apply_rope(kr, positions, cfg.rope_theta)[:, :, 0]  # (B, T, rope)
    return ckv, kr


def mla_attention(x, p, cfg: ModelConfig, positions, *, causal=True):
    """Full (prefill/train) path: decompress per-token K/V, run attention."""
    m = cfg.mla
    b, t, _ = x.shape
    q_nope, q_rope = _project_q(x, p, cfg, positions)
    ckv, kr = _latent_kv(x, p, cfg, positions)
    k_nope = jnp.einsum("btl,lhn->bthn", ckv, p["w_uk"].astype(x.dtype))
    v = jnp.einsum("btl,lhv->bthv", ckv, p["w_uv"].astype(x.dtype))
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr[:, :, None], (b, t, cfg.n_heads,
                                                   m.qk_rope_dim))], -1)
    q = jnp.concatenate([q_nope, q_rope], -1)
    o = attention(q, k, v, causal=causal, chunk=cfg.attn_chunk)
    return o.reshape(b, t, -1) @ p["wo"]


def mla_decode(x, p, cfg: ModelConfig, ckv_cache, krope_cache, pos):
    """Absorbed decode: one new token against the latent cache.

    x (B, 1, D); ckv_cache (B, S, lora); krope_cache (B, S, rope); pos (B,)
    Returns (out (B, 1, D), new_ckv (B, 1, lora), new_krope (B, 1, rope)).
    """
    m = cfg.mla
    b = x.shape[0]
    positions = pos[:, None]                                 # (B, 1)
    q_nope, q_rope = _project_q(x, p, cfg, positions)        # (B,1,H,·)
    ckv_new, kr_new = _latent_kv(x, p, cfg, positions)

    s = ckv_cache.shape[1]

    def upd(c, u, pp):
        return jax.lax.dynamic_update_slice(c, u.astype(c.dtype), (pp, 0))

    ckv = jax.vmap(upd)(ckv_cache, ckv_new, pos)
    kr = jax.vmap(upd)(krope_cache, kr_new, pos)

    # absorb W_uk into q: score in latent space
    q_lat = jnp.einsum("bohn,lhn->bohl", q_nope, p["w_uk"].astype(x.dtype))
    scores = (jnp.einsum("bohl,bsl->bhs", q_lat, ckv) +
              jnp.einsum("bohr,bsr->bhs", q_rope, kr)
              ).astype(jnp.float32)
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    valid = jnp.arange(s)[None, :] <= pos[:, None]           # (B, S)
    scores = jnp.where(valid[:, None], scores * scale, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o_lat = jnp.einsum("bhs,bsl->bhl", w, ckv)
    o = jnp.einsum("bhl,lhv->bhv", o_lat, p["w_uv"].astype(x.dtype))
    out = o.reshape(b, 1, -1) @ p["wo"]
    return out, ckv, kr

"""Unified decoder-only transformer covering dense/GQA, SWA, MLA, MoE, SSM,
hybrid and interleaved-cross-attention (VLM) families — one scanned block
stack parameterised entirely by ModelConfig.

Layout invariants:
  * block weights are stacked on a leading layer axis and consumed by
    ``lax.scan`` (compile-time O(1) in depth; FSDP gathers overlap the scan)
  * activations are (B, T, D) with B sharded over the batch mesh axes
  * decode caches are per-layer pytrees stacked the same way
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import layers, mamba2, mla as mla_lib, moe as moe_lib
from .config import ModelConfig
from .params import Spec, cast_floats, stack
from repro.dist.sharding import (col_parallel_qkv, constrain_act,
                                 fused_mlp, row_parallel, seq_all_gather)

# --------------------------------------------------------------------------
# schemas
# --------------------------------------------------------------------------


def attn_schema(cfg: ModelConfig) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "wq": Spec((d, h * hd), P("data", "model")),
        "wk": Spec((d, kv * hd), P("data", "model")),
        "wv": Spec((d, kv * hd), P("data", "model")),
        "wo": Spec((h * hd, d), P("model", "data")),
    }


def mlp_schema(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_gate": Spec((d, f), P("data", "model")),
        "w_in":   Spec((d, f), P("data", "model")),
        "w_out":  Spec((f, d), P("model", "data")),
    }


def _mixer_schema(cfg: ModelConfig) -> dict:
    sch: dict = {"ln1": Spec((cfg.d_model,), P(None), "ones")}
    if cfg.mixer_kind in ("attn", "hybrid"):
        sch["attn"] = (mla_lib.mla_schema(cfg) if cfg.attn_kind == "mla"
                       else attn_schema(cfg))
    if cfg.mixer_kind in ("ssm", "hybrid"):
        sch["ssm"] = mamba2.mamba_schema(cfg)
    if cfg.mixer_kind == "hybrid":
        sch["attn_bn"] = Spec((cfg.d_model,), P(None), "ones")
        sch["ssm_bn"] = Spec((cfg.d_model,), P(None), "ones")
    return sch


def block_schema(cfg: ModelConfig) -> dict:
    sch = _mixer_schema(cfg)
    if cfg.mixer_kind != "ssm":                     # mamba2 blocks: mixer only
        sch["ln2"] = Spec((cfg.d_model,), P(None), "ones")
        sch["mlp"] = (moe_lib.moe_schema(cfg.d_model, cfg.moe) if cfg.moe
                      else mlp_schema(cfg))
    return sch


def cross_block_schema(cfg: ModelConfig) -> dict:
    sch = {"ln1": Spec((cfg.d_model,), P(None), "ones"),
           "lnc": Spec((cfg.d_model,), P(None), "ones"),
           "attn": attn_schema(cfg),
           "ln2": Spec((cfg.d_model,), P(None), "ones"),
           "mlp": (moe_lib.moe_schema(cfg.d_model, cfg.moe) if cfg.moe
                   else mlp_schema(cfg))}
    return sch


def model_schema(cfg: ModelConfig) -> dict:
    d, v = cfg.d_model, cfg.vocab_size
    sch: dict = {"embed": Spec((v, d), P("model", "data"), "embed")}
    if cfg.cross_attn_period:
        per = cfg.cross_attn_period
        n_groups = cfg.n_layers // per
        sch["blocks"] = stack(stack(block_schema(cfg), per - 1), n_groups)
        sch["cross_blocks"] = stack(cross_block_schema(cfg), n_groups)
    else:
        sch["blocks"] = stack(block_schema(cfg), cfg.n_layers)
    sch["final_norm"] = Spec((d,), P(None), "ones")
    if not cfg.tie_embeddings:
        sch["lm_head"] = Spec((d, v), P("data", "model"))
    return sch


# --------------------------------------------------------------------------
# block application (full-sequence: train / prefill)
# --------------------------------------------------------------------------


def _self_attn(x, p, cfg, positions):
    """x may be seq-sharded (SP): col_parallel_qkv gathers internally."""
    from repro.dist.sharding import constrain_heads
    b, t, _ = x.shape
    q2, k2, v2 = col_parallel_qkv(x, p["wq"], p["wk"], p["wv"])
    q = q2.reshape(b, t, cfg.n_heads, cfg.head_dim)
    k = k2.reshape(b, t, cfg.n_kv_heads, cfg.head_dim)
    v = v2.reshape(b, t, cfg.n_kv_heads, cfg.head_dim)
    q = constrain_heads(layers.apply_rope(q, positions, cfg.rope_theta))
    k = constrain_heads(layers.apply_rope(k, positions, cfg.rope_theta))
    v = constrain_heads(v)
    o = layers.attention(q, k, v, causal=True, window=cfg.sliding_window,
                         chunk=cfg.attn_chunk)
    # explicit row-parallel dot + psum_scatter (reduce-scatter semantics)
    b, t, h, hd = o.shape
    return row_parallel(o.reshape(b, t, h * hd), p["wo"])


def _mlp(x, p, cfg):
    if cfg.moe:
        return moe_lib.moe_ffn(x, p, cfg.moe)
    return fused_mlp(x, p["w_gate"], p["w_in"], p["w_out"])


def block_apply(cfg: ModelConfig, p: dict, x: jnp.ndarray, positions,
                ) -> jnp.ndarray:
    p = cast_floats(p, cfg.dtype)
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
    # SP (dense attn): h stays SEQ-SHARDED; the qkv shard_map gathers it
    # internally exactly once, so both fwd (AG) and bwd (psum_scatter of the
    # input cotangent) move 1× traffic — the Korthikanti schedule. Partial
    # history: constraints alone left 2.6 TB/step of bwd all-reduce
    # (EXPERIMENTS.md §Perf iterations 1-4).
    if cfg.mixer_kind == "attn" and cfg.attn_kind != "mla":
        x = x + _self_attn(h, p["attn"], cfg, positions)
    elif cfg.mixer_kind == "attn":
        x = x + mla_lib.mla_attention(seq_all_gather(h), p["attn"], cfg,
                                      positions)
    elif cfg.mixer_kind == "ssm":
        y, _ = mamba2.mamba_mixer(seq_all_gather(h), p["ssm"], cfg)
        return x + y                                 # mamba2: no MLP
    else:                                            # hybrid (hymba)
        hg = seq_all_gather(h)
        ya = _self_attn(hg, p["attn"], cfg, positions)
        ys, _ = mamba2.mamba_mixer(hg, p["ssm"], cfg)
        x = x + 0.5 * (layers.rms_norm(ya, p["attn_bn"], cfg.norm_eps)
                       + layers.rms_norm(ys, p["ssm_bn"], cfg.norm_eps))
    h2 = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
    return x + _mlp(h2, p["mlp"], cfg)


def cross_block_apply(cfg, p, x, context):
    """Cross-attention block (VLM): queries from x, K/V from context
    embeddings (no rope on cross-attn, matching Llama-3.2-Vision)."""
    p = cast_floats(p, cfg.dtype)
    b, t, _ = x.shape
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
    ctx = layers.rms_norm(context, p["lnc"], cfg.norm_eps)
    q = (h @ p["attn"]["wq"]).reshape(b, t, cfg.n_heads, cfg.head_dim)
    k = (ctx @ p["attn"]["wk"]).reshape(b, ctx.shape[1], cfg.n_kv_heads,
                                        cfg.head_dim)
    v = (ctx @ p["attn"]["wv"]).reshape(b, ctx.shape[1], cfg.n_kv_heads,
                                        cfg.head_dim)
    o = layers.attention(q, k, v, causal=False, chunk=cfg.attn_chunk)
    x = x + layers.attn_out(o, p["attn"])
    h2 = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
    return x + _mlp(h2, p["mlp"], cfg)


# --------------------------------------------------------------------------
# forward pass
# --------------------------------------------------------------------------


def embed_tokens(cfg, params, tokens):
    x = jnp.take(params["embed"], tokens, axis=0)
    return constrain_act(x.astype(cfg.dtype))


def forward(cfg: ModelConfig, params: dict, tokens: jnp.ndarray, *,
            context: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """tokens (B, T) int32 → final hidden states (B, T, D)."""
    x = embed_tokens(cfg, params, tokens)
    positions = jnp.arange(tokens.shape[1])

    def body(carry, p_block):
        y = block_apply(cfg, p_block, carry, positions)
        # plain constraint (not the custom_vjp pair): block outputs are
        # already seq-sharded by row_parallel/fused_mlp under SP; the
        # custom-vjp scatter here added a redundant bwd all-gather (§Perf).
        return constrain_act(y), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)

    if cfg.cross_attn_period:
        ctx = context.astype(cfg.dtype)

        def group(carry, xs):
            p_selfs, p_cross = xs

            def inner(c, pb):
                return body(c, pb)

            carry, _ = jax.lax.scan(inner, carry, p_selfs)
            carry = cross_block_apply(cfg, p_cross, carry, ctx)
            return constrain_act(carry), None

        if cfg.remat:
            group = jax.checkpoint(group, prevent_cse=False)
        x, _ = jax.lax.scan(group, x,
                            (params["blocks"], params["cross_blocks"]))
    else:
        x, _ = jax.lax.scan(body, x, params["blocks"])

    return layers.rms_norm(x, params["final_norm"], cfg.norm_eps)


def lm_logits(cfg: ModelConfig, params: dict, x: jnp.ndarray) -> jnp.ndarray:
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    return (x.astype(cfg.dtype) @ head.astype(cfg.dtype)).astype(jnp.float32)


# --------------------------------------------------------------------------
# decode (single new token against a cache)
# --------------------------------------------------------------------------


def init_cache_schema(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    """Schema (Spec tree) for the decode cache — same machinery as params so
    the dry-run can make abstract sharded caches. Sequence dim of full-attn
    caches is sharded over "model" (context parallelism: KV heads of the
    assigned archs don't divide the 16-way model axis — DESIGN.md §5)."""
    def layer_cache() -> dict:
        if cfg.attn_kind == "mla":
            m = cfg.mla
            return {
                "ckv": Spec((batch, max_seq, m.kv_lora_rank),
                            P(("pod", "data"), "model", None), "zeros",
                            cfg.dtype),
                "kr": Spec((batch, max_seq, m.qk_rope_dim),
                           P(("pod", "data"), "model", None), "zeros",
                           cfg.dtype),
            }
        c: dict = {}
        if cfg.mixer_kind in ("attn", "hybrid"):
            w = cfg.sliding_window
            s = min(w, max_seq) if w else max_seq
            seq_ax = "model" if not w else None
            kvshape = (batch, s, cfg.n_kv_heads, cfg.head_dim)
            c["k"] = Spec(kvshape, P(("pod", "data"), seq_ax, None, None),
                          "zeros", cfg.dtype)
            c["v"] = Spec(kvshape, P(("pod", "data"), seq_ax, None, None),
                          "zeros", cfg.dtype)
            if w:
                c["kpos"] = Spec((batch, s), P(("pod", "data"), None),
                                 "neg", jnp.int32)
        if cfg.mixer_kind in ("ssm", "hybrid"):
            s_cfg = cfg.ssm
            d_in, nh, conv_dim = mamba2.ssm_dims(cfg)
            c["conv"] = Spec((batch, s_cfg.conv_width - 1, conv_dim),
                             P(("pod", "data"), None, "model"), "zeros",
                             cfg.dtype)
            c["ssm"] = Spec((batch, nh, s_cfg.head_dim, s_cfg.d_state),
                            P(("pod", "data"), "model", None, None), "zeros",
                            jnp.float32)
        return c

    if cfg.cross_attn_period:
        per = cfg.cross_attn_period
        n_groups = cfg.n_layers // per
        ctx_kv = (batch, cfg.n_context_tokens, cfg.n_kv_heads, cfg.head_dim)
        return {
            "blocks": stack(stack(layer_cache(), per - 1), n_groups),
            "cross_k": Spec((n_groups,) + ctx_kv,
                            P(None, ("pod", "data"), None, None, None),
                            "zeros", cfg.dtype),
            "cross_v": Spec((n_groups,) + ctx_kv,
                            P(None, ("pod", "data"), None, None, None),
                            "zeros", cfg.dtype),
        }
    return {"blocks": stack(layer_cache(), cfg.n_layers)}


def _batched_update(cache_arr, new_vals, pos):
    """Write new_vals (B, 1, ...) into cache (B, S, ...) at per-batch pos."""
    def one(c, u, p):
        return jax.lax.dynamic_update_slice(
            c, u.astype(c.dtype), (p,) + (0,) * (c.ndim - 1))
    return jax.vmap(one)(cache_arr, new_vals, pos)


def _decode_self_attn(x, p, cfg, cache, pos):
    """One-token self-attention against the cache, per-slot positions.
    pos: (B,) int32. Returns (out, new_cache)."""
    b = x.shape[0]
    positions = pos[:, None]                               # (B, 1)
    q, k_new, v_new = layers.gqa_qkv(x, p, cfg, positions)

    if cfg.sliding_window:
        w = cache["k"].shape[1]
        slot = jnp.mod(pos, w)
        k = _batched_update(cache["k"], k_new, slot)
        v = _batched_update(cache["v"], v_new, slot)
        kpos = cache["kpos"].at[jnp.arange(b), slot].set(pos)
        o = layers.attention(q, k, v, causal=True, window=cfg.sliding_window,
                             q_offset=pos, k_positions=kpos,
                             chunk=cfg.attn_chunk)
        new_cache = dict(cache, k=k, v=v, kpos=kpos)
    else:
        k = _batched_update(cache["k"], k_new, pos)
        v = _batched_update(cache["v"], v_new, pos)
        o = layers.attention(q, k, v, causal=True, q_offset=pos,
                             kv_len=pos + 1, chunk=cfg.attn_chunk)
        new_cache = dict(cache, k=k, v=v)
    return layers.attn_out(o, p), new_cache


def block_decode(cfg: ModelConfig, p: dict, x, cache: dict, pos):
    p = cast_floats(p, cfg.dtype)
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.mixer_kind == "attn":
        if cfg.attn_kind == "mla":
            out, ckv, kr = mla_lib.mla_decode(h, p["attn"], cfg,
                                              cache["ckv"], cache["kr"], pos)
            x = x + out
            new_cache = dict(cache, ckv=ckv, kr=kr)
        else:
            out, new_cache = _decode_self_attn(h, p["attn"], cfg, cache, pos)
            x = x + out
    elif cfg.mixer_kind == "ssm":
        y, (conv, ssm) = mamba2.mamba_mixer(
            h, p["ssm"], cfg, conv_state=cache["conv"],
            ssm_state=cache["ssm"], single_step=True)
        return x + y, dict(cache, conv=conv, ssm=ssm)
    else:                                            # hybrid
        ya, new_cache = _decode_self_attn(h, p["attn"], cfg, cache, pos)
        ys, (conv, ssm) = mamba2.mamba_mixer(
            h, p["ssm"], cfg, conv_state=cache["conv"],
            ssm_state=cache["ssm"], single_step=True)
        x = x + 0.5 * (layers.rms_norm(ya, p["attn_bn"], cfg.norm_eps)
                       + layers.rms_norm(ys, p["ssm_bn"], cfg.norm_eps))
        new_cache = dict(new_cache, conv=conv, ssm=ssm)
    h2 = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
    return x + _mlp(h2, p["mlp"], cfg), new_cache


def _cross_decode(cfg, p, x, ck, cv):
    p = cast_floats(p, cfg.dtype)
    b = x.shape[0]
    h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
    q = (h @ p["attn"]["wq"]).reshape(b, 1, cfg.n_heads, cfg.head_dim)
    o = layers.attention(q, ck, cv, causal=False, chunk=cfg.attn_chunk)
    x = x + layers.attn_out(o, p["attn"])
    h2 = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
    return x + _mlp(h2, p["mlp"], cfg)


def decode(cfg: ModelConfig, params: dict, cache: dict, token: jnp.ndarray,
           pos) -> tuple[jnp.ndarray, dict]:
    """token (B, 1) int32, pos scalar or (B,) per-slot positions
    (continuous batching) → (logits (B, V) f32, new cache)."""
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (token.shape[0],))
    x = embed_tokens(cfg, params, token)

    if cfg.cross_attn_period:
        def group(carry, xs):
            p_selfs, p_cross, c_selfs, ck, cv = xs

            def inner(c2, xs2):
                pb, cb = xs2
                y, cb_new = block_decode(cfg, pb, c2, cb, pos)
                return y, cb_new

            carry, new_c = jax.lax.scan(inner, carry, (p_selfs, c_selfs))
            carry = _cross_decode(cfg, p_cross, carry, ck, cv)
            return carry, new_c

        x, new_blocks = jax.lax.scan(
            group, x, (params["blocks"], params["cross_blocks"],
                       cache["blocks"], cache["cross_k"], cache["cross_v"]))
        new_cache = dict(cache, blocks=new_blocks)
    else:
        def body(carry, xs):
            pb, cb = xs
            y, cb_new = block_decode(cfg, pb, carry, cb, pos)
            return y, cb_new

        x, new_blocks = jax.lax.scan(body, x,
                                     (params["blocks"], cache["blocks"]))
        new_cache = {"blocks": new_blocks}

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return lm_logits(cfg, params, x)[:, 0], new_cache


def prefill(cfg: ModelConfig, params: dict, tokens: jnp.ndarray, cache: dict,
            *, context: Optional[jnp.ndarray] = None):
    """Run the full prompt, fill the cache, return last-position logits.

    Implemented as forward() plus cache-filling projections per layer —
    lowered for the ``prefill_*`` dry-run shapes. For cross-attn models the
    context K/V are projected once here.
    """
    x = embed_tokens(cfg, params, tokens)
    positions = jnp.arange(tokens.shape[1])
    t = tokens.shape[1]

    def _write_kv(cache_block, new_cb, k, v):
        if cfg.sliding_window:
            w = cache_block["k"].shape[1]
            keep = min(w, t)
            new_cb["k"] = jax.lax.dynamic_update_slice(
                cache_block["k"], k[:, t - keep:].astype(
                    cache_block["k"].dtype), (0, 0, 0, 0))
            new_cb["v"] = jax.lax.dynamic_update_slice(
                cache_block["v"], v[:, t - keep:].astype(
                    cache_block["v"].dtype), (0, 0, 0, 0))
            new_cb["kpos"] = jax.lax.dynamic_update_slice(
                cache_block["kpos"],
                jnp.broadcast_to(jnp.arange(t - keep, t, dtype=jnp.int32),
                                 (k.shape[0], keep)), (0, 0))
        else:
            new_cb["k"] = jax.lax.dynamic_update_slice(
                cache_block["k"], k.astype(cache_block["k"].dtype),
                (0, 0, 0, 0))
            new_cb["v"] = jax.lax.dynamic_update_slice(
                cache_block["v"], v.astype(cache_block["v"].dtype),
                (0, 0, 0, 0))

    def fill_block(carry, p_block, cache_block):
        """Apply one block over the full prompt AND fill its cache — every
        mixer runs exactly once."""
        x_in = carry
        p_block = cast_floats(p_block, cfg.dtype)
        h = layers.rms_norm(x_in, p_block["ln1"], cfg.norm_eps)
        new_cb = dict(cache_block)

        if cfg.mixer_kind == "attn" and cfg.attn_kind == "mla":
            ckv, kr = mla_lib._latent_kv(h, p_block["attn"], cfg, positions)
            new_cb["ckv"] = jax.lax.dynamic_update_slice(
                cache_block["ckv"], ckv.astype(cache_block["ckv"].dtype),
                (0, 0, 0))
            new_cb["kr"] = jax.lax.dynamic_update_slice(
                cache_block["kr"], kr.astype(cache_block["kr"].dtype),
                (0, 0, 0))
            x = x_in + mla_lib.mla_attention(h, p_block["attn"], cfg,
                                             positions)
        elif cfg.mixer_kind == "attn":
            q, k, v = layers.gqa_qkv(h, p_block["attn"], cfg, positions)
            _write_kv(cache_block, new_cb, k, v)
            o = layers.attention(q, k, v, causal=True,
                                 window=cfg.sliding_window,
                                 chunk=cfg.attn_chunk)
            x = x_in + layers.attn_out(o, p_block["attn"])
        elif cfg.mixer_kind == "ssm":
            y, (conv, ssm) = mamba2.mamba_mixer(h, p_block["ssm"], cfg)
            new_cb["conv"] = conv.astype(cache_block["conv"].dtype)
            new_cb["ssm"] = ssm
            return x_in + y, new_cb                      # mamba2: no MLP
        else:                                            # hybrid
            q, k, v = layers.gqa_qkv(h, p_block["attn"], cfg, positions)
            _write_kv(cache_block, new_cb, k, v)
            o = layers.attention(q, k, v, causal=True,
                                 window=cfg.sliding_window,
                                 chunk=cfg.attn_chunk)
            ya = layers.attn_out(o, p_block["attn"])
            ys, (conv, ssm) = mamba2.mamba_mixer(h, p_block["ssm"], cfg)
            new_cb["conv"] = conv.astype(cache_block["conv"].dtype)
            new_cb["ssm"] = ssm
            x = x_in + 0.5 * (
                layers.rms_norm(ya, p_block["attn_bn"], cfg.norm_eps)
                + layers.rms_norm(ys, p_block["ssm_bn"], cfg.norm_eps))

        h2 = layers.rms_norm(x, p_block["ln2"], cfg.norm_eps)
        return x + _mlp(h2, p_block["mlp"], cfg), new_cb

    if cfg.cross_attn_period:
        ctx = context.astype(cfg.dtype)

        def group(carry, xs):
            p_selfs, p_cross, c_selfs = xs

            def inner(c2, xs2):
                pb, cb = xs2
                y, cb_new = fill_block(c2, pb, cb)
                return y, cb_new

            carry, new_c = jax.lax.scan(inner, carry, (p_selfs, c_selfs))
            b = ctx.shape[0]
            ck = (ctx @ p_cross["attn"]["wk"]).reshape(
                b, ctx.shape[1], cfg.n_kv_heads, cfg.head_dim)
            cv = (ctx @ p_cross["attn"]["wv"]).reshape(
                b, ctx.shape[1], cfg.n_kv_heads, cfg.head_dim)
            carry = cross_block_apply(cfg, p_cross, carry, ctx)
            return carry, (new_c, ck.astype(cfg.dtype), cv.astype(cfg.dtype))

        x, (new_blocks, cks, cvs) = jax.lax.scan(
            group, x, (params["blocks"], params["cross_blocks"],
                       cache["blocks"]))
        new_cache = dict(cache, blocks=new_blocks, cross_k=cks, cross_v=cvs)
    else:
        def body(carry, xs):
            pb, cb = xs
            return fill_block(carry, pb, cb)

        x, new_blocks = jax.lax.scan(body, x,
                                     (params["blocks"], cache["blocks"]))
        new_cache = {"blocks": new_blocks}

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return lm_logits(cfg, params, x[:, -1:])[:, 0], new_cache

"""Mamba-2 mixer via state-space duality (SSD, arXiv:2405.21060).

Training/prefill uses the chunked SSD algorithm (quadratic within chunks,
linear recurrence across chunks); decode is the O(1) per-token recurrence on
the (H, P, N) state. Heads are sharded over the "model" axis (head-parallel
SSM) and the depthwise conv keeps a (W-1)-deep state for decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from .layers import rms_norm
from .params import Spec


def ssm_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.head_dim
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    return d_in, n_heads, conv_dim


def mamba_schema(cfg: ModelConfig) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_in, nh, conv_dim = ssm_dims(cfg)
    gn = s.n_groups * s.d_state
    return {
        "w_z":   Spec((d, d_in), P("data", "model")),
        "w_x":   Spec((d, d_in), P("data", "model")),
        "w_B":   Spec((d, gn), P("data", None)),
        "w_C":   Spec((d, gn), P("data", None)),
        "w_dt":  Spec((d, nh), P("data", None)),
        "dt_bias": Spec((nh,), P(None), "zeros"),
        "A_log": Spec((nh,), P(None), "zeros"),
        "D":     Spec((nh,), P(None), "ones"),
        "conv_w": Spec((s.conv_width, conv_dim), P(None, "model")),
        "norm_w": Spec((d_in,), P("model"), "ones"),
        "w_out": Spec((d_in, d), P("model", "data")),
    }


def _segsum(x):
    """x (..., L) → (..., L, L): cumulative sums over segments (i >= j)."""
    l = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]       # sum over (j, i]
    mask = jnp.tril(jnp.ones((l, l), bool), 0)
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(x, dt, a, b, c, *, chunk: int, init_state=None):
    """SSD scan. x (B,T,H,Pd); dt (B,T,H); a (H,) negative; b,c (B,T,G,N).
    Returns (y (B,T,H,Pd), final_state (B,H,Pd,N))."""
    bsz, t, h, pd = x.shape
    g, n = b.shape[2], b.shape[3]
    rep = h // g
    pad = (-t) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (t + pad) // chunk
    xc = x.reshape(bsz, nc, chunk, h, pd)
    dtc = dt.reshape(bsz, nc, chunk, h)
    bc = b.reshape(bsz, nc, chunk, g, n)
    cc = c.reshape(bsz, nc, chunk, g, n)
    bh = jnp.repeat(bc, rep, axis=3)                 # (B,nc,L,H,N)
    ch = jnp.repeat(cc, rep, axis=3)

    da = (dtc * a[None, None, None, :]).astype(jnp.float32)   # (B,nc,L,H)
    da_cs = jnp.cumsum(da, axis=2)

    # --- intra-chunk (quadratic, attention-like with decay kernel) -------
    ll = jnp.exp(_segsum(da.swapaxes(2, 3)))         # (B,nc,H,L,L)
    scores = jnp.einsum("bclhn,bcshn->bchls", ch, bh).astype(jnp.float32)
    y_diag = jnp.einsum("bchls,bchls,bcsh,bcshp->bclhp",
                        scores, ll, dtc.astype(jnp.float32),
                        xc.astype(jnp.float32))

    # --- chunk boundary states -------------------------------------------
    decay_states = jnp.exp(da_cs[:, :, -1:, :] - da_cs)       # (B,nc,L,H)
    states = jnp.einsum("bclhn,bclh,bclh,bclhp->bchpn",
                        bh.astype(jnp.float32), decay_states,
                        dtc.astype(jnp.float32), xc.astype(jnp.float32))

    # --- inter-chunk recurrence ------------------------------------------
    chunk_decay = jnp.exp(da_cs[:, :, -1, :])                 # (B,nc,H)

    def rec(s_prev, xs):
        st, dec = xs                                          # (B,H,Pd,N),(B,H)
        s_new = s_prev * dec[:, :, None, None] + st
        return s_new, s_prev

    init = (jnp.zeros((bsz, h, pd, n), jnp.float32) if init_state is None
            else init_state.astype(jnp.float32))
    final_state, s_prevs = jax.lax.scan(
        rec, init, (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    s_prevs = s_prevs.swapaxes(0, 1)                          # (B,nc,H,Pd,N)

    # --- inter-chunk contribution ----------------------------------------
    out_decay = jnp.exp(da_cs)                                # (B,nc,L,H)
    y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp",
                       ch.astype(jnp.float32), s_prevs, out_decay)

    y = (y_diag + y_off).reshape(bsz, nc * chunk, h, pd)[:, :t]
    return y.astype(x.dtype), final_state


def ssd_decode_step(state, x, dt, a, b, c):
    """One-token recurrence. state (B,H,Pd,N); x (B,H,Pd); dt (B,H);
    b,c (B,G,N) → (y (B,H,Pd), new_state)."""
    h = x.shape[1]
    rep = h // b.shape[1]
    bh = jnp.repeat(b, rep, axis=1)                           # (B,H,N)
    ch = jnp.repeat(c, rep, axis=1)
    da = jnp.exp((dt * a[None, :]).astype(jnp.float32))       # (B,H)
    upd = jnp.einsum("bh,bhp,bhn->bhpn", dt.astype(jnp.float32),
                     x.astype(jnp.float32), bh.astype(jnp.float32))
    new_state = state * da[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_state, ch.astype(jnp.float32))
    return y.astype(x.dtype), new_state


def _causal_conv(xbc, conv_w, conv_state=None):
    """Depthwise causal conv, width W. xbc (B,T,C); conv_w (W,C).
    With conv_state (B,W-1,C) prepends history (decode/streaming)."""
    w = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], w - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    full = jnp.concatenate([pad, xbc], axis=1)                # (B,T+W-1,C)
    out = sum(full[:, i:i + xbc.shape[1]] * conv_w[i][None, None]
              for i in range(w))
    new_state = full[:, -(w - 1):] if w > 1 else pad
    return jax.nn.silu(out), new_state


def mamba_mixer(u, p, cfg: ModelConfig, *, conv_state=None, ssm_state=None,
                single_step=False):
    """u (B,T,D) → (y (B,T,D), (conv_state, ssm_state)).

    single_step=True runs the O(1) decode recurrence (T must be 1)."""
    s = cfg.ssm
    d_in, nh, conv_dim = ssm_dims(cfg)
    bsz, t, _ = u.shape
    z = u @ p["w_z"]
    xin = u @ p["w_x"]
    b = u @ p["w_B"]
    c = u @ p["w_C"]
    dt = jax.nn.softplus((u @ p["w_dt"]).astype(jnp.float32)
                         + p["dt_bias"][None, None])          # (B,T,H)

    xbc = jnp.concatenate([xin, b, c], axis=-1)               # (B,T,conv)
    xbc, new_conv = _causal_conv(xbc, p["conv_w"].astype(u.dtype), conv_state)
    xin, b, c = jnp.split(xbc, [d_in, d_in + s.n_groups * s.d_state], -1)

    xh = xin.reshape(bsz, t, nh, s.head_dim)
    bg = b.reshape(bsz, t, s.n_groups, s.d_state)
    cg = c.reshape(bsz, t, s.n_groups, s.d_state)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))              # (H,)

    if single_step:
        y1, new_ssm = ssd_decode_step(
            ssm_state, xh[:, 0], dt[:, 0], a, bg[:, 0], cg[:, 0])
        y = y1[:, None]
    else:
        y, new_ssm = ssd_chunked(xh, dt, a, bg, cg, chunk=s.chunk,
                                 init_state=ssm_state)
    y = y + xh * p["D"].astype(u.dtype)[None, None, :, None]
    y = y.reshape(bsz, t, d_in)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    return y @ p["w_out"], (new_conv, new_ssm)

"""Mixture-of-Experts FFN with token-choice top-k routing.

Dispatch avoids the GShard (T, E, C) one-hot cube: positions-in-expert come
from a cumsum over a (T·k, E) one-hot, tokens are *scattered* into per-expert
capacity buffers (E, C, D) and gathered back — O(T·E + E·C·D) memory. Expert
weight tensors are stacked (E, ...) and sharded over the "model" axis (EP);
the scatter/gather pair is what XLA lowers to the dispatch all-to-all.
Shared experts (DeepSeek-style) run as one fused dense SwiGLU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import MoEConfig
from .layers import swiglu
from .params import Spec
from jax.sharding import PartitionSpec as P


def moe_schema(d_model: int, moe: MoEConfig) -> dict:
    e, f = moe.n_experts, moe.d_ff_expert
    sch = {
        "router": Spec((d_model, e), P("data", None)),
        "w_gate": Spec((e, d_model, f), P("model", "data", None)),
        "w_in":   Spec((e, d_model, f), P("model", "data", None)),
        "w_out":  Spec((e, f, d_model), P("model", None, "data")),
    }
    if moe.n_shared:
        fs = f * moe.n_shared
        sch.update({
            "sh_gate": Spec((d_model, fs), P("data", "model")),
            "sh_in":   Spec((d_model, fs), P("data", "model")),
            "sh_out":  Spec((fs, d_model), P("model", "data")),
        })
    return sch


def moe_ffn(x: jnp.ndarray, p: dict, moe: MoEConfig) -> jnp.ndarray:
    """x (B, T, D) → (B, T, D). Token-choice top-k with capacity drop.

    Under mesh sharding (dry-run/production) dispatches to the explicit
    expert-parallel shard_map path — the partitioner's lowering of the
    scatter/gather dispatch all-reduces multi-GB expert buffers
    (EXPERIMENTS.md §Perf headroom note); the EP path reduces exactly one
    (B, T, D) partial sum per layer."""
    from repro.dist import sharding as shmod
    if shmod.mesh() is not None and shmod.batch_axes() is not None \
            and shmod.model_axis() > 1 \
            and moe.n_experts % shmod.model_axis() == 0:
        return _moe_ffn_ep(x, p, moe)
    return _moe_ffn_dense(x, p, moe)


def _moe_ffn_ep(x: jnp.ndarray, p: dict, moe: MoEConfig) -> jnp.ndarray:
    """Expert-parallel shard_map: tokens replicated over "model", each model
    shard dispatches ONLY to its E/16 local experts and contributes a
    partial combine; one psum over "model" finishes the layer."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.dist import sharding as shmod

    b, t, d = x.shape
    n_exp_local = moe.n_experts // shmod.model_axis()
    batch = shmod.batch_axes()

    def local(xl, router, wg, wi, wo):
        nl = xl.shape[0] * xl.shape[1]
        tokens = xl.reshape(nl, d)
        k = moe.top_k
        cap = max(8, int(moe.capacity_factor * nl * k / moe.n_experts))
        cap = -(-cap // 8) * 8

        logits = (tokens @ router.astype(xl.dtype)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, expert_idx = jax.lax.top_k(probs, k)              # global ids
        gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)

        my_lo = jax.lax.axis_index("model") * n_exp_local
        flat_e = expert_idx.reshape(-1) - my_lo                 # local ids
        mine = (flat_e >= 0) & (flat_e < n_exp_local)
        flat_e = jnp.clip(flat_e, 0, n_exp_local - 1)
        flat_g = gate.reshape(-1) * mine
        token_of_slot = jnp.repeat(jnp.arange(nl, dtype=jnp.int32), k)

        oh = jax.nn.one_hot(flat_e, n_exp_local, dtype=jnp.int32) \
            * mine[:, None]
        pos = jnp.sum((jnp.cumsum(oh, axis=0) - 1) * oh, axis=-1)
        keep = (pos < cap) & mine
        pos_c = jnp.minimum(pos, cap - 1)

        vals = tokens[token_of_slot] * keep[:, None].astype(xl.dtype)
        buf = jnp.zeros((n_exp_local, cap, d), xl.dtype
                        ).at[flat_e, pos_c].add(vals)

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg.astype(xl.dtype))
                        ) * jnp.einsum("ecd,edf->ecf", buf,
                                       wi.astype(xl.dtype))
        out = jnp.einsum("ecf,efd->ecd", h, wo.astype(xl.dtype))

        slot_out = out[flat_e, pos_c]
        w = (flat_g * keep).astype(xl.dtype)[:, None]
        y = jnp.zeros((nl, d), xl.dtype).at[token_of_slot].add(slot_out * w)
        y = jax.lax.psum(y, "model")
        return y.reshape(xl.shape)

    y = shard_map(
        local, mesh=shmod.mesh(),
        in_specs=(P(batch, None, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=P(batch, None, None), check_rep=False)(
        x, p["router"], p["w_gate"], p["w_in"], p["w_out"])

    if moe.n_shared:
        y = y + swiglu(x.reshape(b * t, d), p["sh_gate"].astype(x.dtype),
                       p["sh_in"].astype(x.dtype),
                       p["sh_out"].astype(x.dtype)).reshape(b, t, d)
    return y


def _moe_ffn_dense(x: jnp.ndarray, p: dict, moe: MoEConfig) -> jnp.ndarray:
    """Single-device / no-mesh path (semantics of record)."""
    b, t, d = x.shape
    n = b * t
    k = moe.top_k
    e = moe.n_experts
    cap = max(8, int(moe.capacity_factor * n * k / e))
    cap = -(-cap // 8) * 8

    tokens = x.reshape(n, d)
    logits = (tokens @ p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                   # (N, E)
    gate, expert_idx = jax.lax.top_k(probs, k)                # (N, k)
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)

    flat_e = expert_idx.reshape(-1)                           # (N·k,)
    flat_g = gate.reshape(-1)
    token_of_slot = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)

    oh = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)           # (N·k, E)
    pos = jnp.sum((jnp.cumsum(oh, axis=0) - 1) * oh, axis=-1)  # (N·k,)
    keep = pos < cap
    pos_c = jnp.minimum(pos, cap - 1)

    # scatter tokens into expert buffers (dropped tokens contribute zero)
    vals = tokens[token_of_slot] * keep[:, None].astype(x.dtype)
    buf = jnp.zeros((e, cap, d), x.dtype
                    ).at[flat_e, pos_c].add(vals)             # (E, C, D)

    # expert compute — einsum over stacked expert weights (EP-shardable).
    # Constrain weights to EP-only sharding here: with the d_model dim left
    # FSDP-sharded, the partitioner partial-sums the (E,C,F) ACTIVATIONS
    # over "data" (measured 2.7 GB f32 all-reduces/layer on deepseek-v2-lite
    # — §Perf headroom note); gathering the 0.4 GB/layer weights instead is
    # the right trade by ~7×.
    from repro.dist.sharding import batch_axes, model_axis
    if batch_axes() is not None and model_axis() > 1:
        from jax.sharding import PartitionSpec as _P
        ep = _P("model", None, None)
        p = dict(p, w_gate=jax.lax.with_sharding_constraint(p["w_gate"], ep),
                 w_in=jax.lax.with_sharding_constraint(p["w_in"], ep),
                 w_out=jax.lax.with_sharding_constraint(p["w_out"], ep))
    wg = p["w_gate"].astype(x.dtype)
    wi = p["w_in"].astype(x.dtype)
    wo = p["w_out"].astype(x.dtype)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) * \
        jnp.einsum("ecd,edf->ecf", buf, wi)
    out = jnp.einsum("ecf,efd->ecd", h, wo)                   # (E, C, D)

    # gather back + weighted combine
    slot_out = out[flat_e, pos_c]                             # (N·k, D)
    w = (flat_g * keep).astype(x.dtype)[:, None]
    y = jnp.zeros((n, d), x.dtype).at[token_of_slot].add(slot_out * w)

    if moe.n_shared:
        y = y + swiglu(tokens, p["sh_gate"].astype(x.dtype),
                       p["sh_in"].astype(x.dtype),
                       p["sh_out"].astype(x.dtype))
    return y.reshape(b, t, d)

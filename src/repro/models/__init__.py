from .api import ModelAPI, get_model  # noqa: F401
from .config import MLAConfig, ModelConfig, MoEConfig, SSMConfig  # noqa: F401

"""Encoder–decoder backbone (Whisper-large-v3 assignment).

The conv/mel frontend is a STUB per the task spec: ``input_specs`` feeds
precomputed frame embeddings (B, T_enc, D). Deviation note: positional
encoding is RoPE (repo-wide) instead of Whisper's learned embeddings — a
backbone-shape-preserving swap recorded in configs/whisper_large_v3.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import layers
from .config import ModelConfig
from .params import Spec, cast_floats, stack
from .transformer import attn_schema, mlp_schema, lm_logits
from repro.dist.sharding import constrain_act, constrain_batch


def enc_block_schema(cfg: ModelConfig) -> dict:
    return {"ln1": Spec((cfg.d_model,), P(None), "ones"),
            "attn": attn_schema(cfg),
            "ln2": Spec((cfg.d_model,), P(None), "ones"),
            "mlp": mlp_schema(cfg)}


def dec_block_schema(cfg: ModelConfig) -> dict:
    return {"ln1": Spec((cfg.d_model,), P(None), "ones"),
            "attn": attn_schema(cfg),
            "lnx": Spec((cfg.d_model,), P(None), "ones"),
            "xattn": attn_schema(cfg),
            "ln2": Spec((cfg.d_model,), P(None), "ones"),
            "mlp": mlp_schema(cfg)}


def model_schema(cfg: ModelConfig) -> dict:
    d, v = cfg.d_model, cfg.vocab_size
    return {
        "embed": Spec((v, d), P("model", "data"), "embed"),
        "enc_blocks": stack(enc_block_schema(cfg), cfg.n_encoder_layers),
        "enc_norm": Spec((d,), P(None), "ones"),
        "dec_blocks": stack(dec_block_schema(cfg), cfg.n_layers),
        "final_norm": Spec((d,), P(None), "ones"),
        "lm_head": Spec((d, v), P("data", "model")),
    }


def _proj_kv(ctx, p, cfg):
    b, tc, _ = ctx.shape
    k = (ctx @ p["wk"]).reshape(b, tc, cfg.n_kv_heads, cfg.head_dim)
    v = (ctx @ p["wv"]).reshape(b, tc, cfg.n_kv_heads, cfg.head_dim)
    return k, v


def encode(cfg: ModelConfig, params: dict, frames: jnp.ndarray):
    """frames (B, T_enc, D) stub embeddings → encoder states (B, T_enc, D)."""
    x = constrain_batch(frames.astype(cfg.dtype), None, None)
    positions = jnp.arange(frames.shape[1])

    def body(carry, p):
        p = cast_floats(p, cfg.dtype)
        h = layers.rms_norm(carry, p["ln1"], cfg.norm_eps)
        q, k, v = layers.gqa_qkv(h, p["attn"], cfg, positions)
        o = layers.attention(q, k, v, causal=False, chunk=cfg.attn_chunk)
        x2 = carry + layers.attn_out(o, p["attn"])
        h2 = layers.rms_norm(x2, p["ln2"], cfg.norm_eps)
        y = x2 + layers.swiglu(h2, p["mlp"]["w_gate"], p["mlp"]["w_in"],
                               p["mlp"]["w_out"])
        return constrain_act(y), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return layers.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def decoder_forward(cfg: ModelConfig, params: dict, tokens: jnp.ndarray,
                    enc_out: jnp.ndarray):
    """Teacher-forcing decoder pass → hidden (B, T, D)."""
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    x = constrain_batch(x, None, None)
    positions = jnp.arange(tokens.shape[1])

    def body(carry, p):
        p = cast_floats(p, cfg.dtype)
        h = layers.rms_norm(carry, p["ln1"], cfg.norm_eps)
        q, k, v = layers.gqa_qkv(h, p["attn"], cfg, positions)
        o = layers.attention(q, k, v, causal=True, chunk=cfg.attn_chunk)
        x2 = carry + layers.attn_out(o, p["attn"])
        hx = layers.rms_norm(x2, p["lnx"], cfg.norm_eps)
        qx = (hx @ p["xattn"]["wq"]).reshape(
            hx.shape[0], hx.shape[1], cfg.n_heads, cfg.head_dim)
        kx, vx = _proj_kv(enc_out, p["xattn"], cfg)
        ox = layers.attention(qx, kx, vx, causal=False, chunk=cfg.attn_chunk)
        x3 = x2 + layers.attn_out(ox, p["xattn"])
        h2 = layers.rms_norm(x3, p["ln2"], cfg.norm_eps)
        y = x3 + layers.swiglu(h2, p["mlp"]["w_gate"], p["mlp"]["w_in"],
                               p["mlp"]["w_out"])
        return constrain_act(y), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    return layers.rms_norm(x, params["final_norm"], cfg.norm_eps)


def init_cache_schema(cfg: ModelConfig, batch: int, max_seq: int,
                      enc_len: int) -> dict:
    kv = (batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    ckv = (batch, enc_len, cfg.n_kv_heads, cfg.head_dim)
    blk = {
        "k": Spec(kv, P(("pod", "data"), "model", None, None), "zeros",
                  cfg.dtype),
        "v": Spec(kv, P(("pod", "data"), "model", None, None), "zeros",
                  cfg.dtype),
        "xk": Spec(ckv, P(("pod", "data"), None, None, None), "zeros",
                   cfg.dtype),
        "xv": Spec(ckv, P(("pod", "data"), None, None, None), "zeros",
                   cfg.dtype),
    }
    return {"blocks": stack(blk, cfg.n_layers)}


def prefill(cfg: ModelConfig, params: dict, frames: jnp.ndarray,
            tokens: jnp.ndarray, cache: dict):
    """Encode audio, project per-layer cross K/V, run the prompt through the
    decoder filling the self cache. Returns (last logits (B, V), cache)."""
    enc_out = encode(cfg, params, frames)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    positions = jnp.arange(tokens.shape[1])

    def body(carry, xs):
        p, cb = xs
        p = cast_floats(p, cfg.dtype)
        new_cb = dict(cb)
        h = layers.rms_norm(carry, p["ln1"], cfg.norm_eps)
        q, k, v = layers.gqa_qkv(h, p["attn"], cfg, positions)
        new_cb["k"] = jax.lax.dynamic_update_slice(
            cb["k"], k.astype(cb["k"].dtype), (0, 0, 0, 0))
        new_cb["v"] = jax.lax.dynamic_update_slice(
            cb["v"], v.astype(cb["v"].dtype), (0, 0, 0, 0))
        o = layers.attention(q, k, v, causal=True, chunk=cfg.attn_chunk)
        x2 = carry + layers.attn_out(o, p["attn"])
        hx = layers.rms_norm(x2, p["lnx"], cfg.norm_eps)
        qx = (hx @ p["xattn"]["wq"]).reshape(
            hx.shape[0], hx.shape[1], cfg.n_heads, cfg.head_dim)
        kx, vx = _proj_kv(enc_out, p["xattn"], cfg)
        new_cb["xk"] = kx.astype(cb["xk"].dtype)
        new_cb["xv"] = vx.astype(cb["xv"].dtype)
        ox = layers.attention(qx, kx, vx, causal=False, chunk=cfg.attn_chunk)
        x3 = x2 + layers.attn_out(ox, p["xattn"])
        h2 = layers.rms_norm(x3, p["ln2"], cfg.norm_eps)
        y = x3 + layers.swiglu(h2, p["mlp"]["w_gate"], p["mlp"]["w_in"],
                               p["mlp"]["w_out"])
        return y, new_cb

    x, new_blocks = jax.lax.scan(body, x, (params["dec_blocks"],
                                           cache["blocks"]))
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return lm_logits(cfg, params, x[:, -1:])[:, 0], {"blocks": new_blocks}


def decode(cfg: ModelConfig, params: dict, cache: dict, token: jnp.ndarray,
           pos):
    """One decoder token against self cache + precomputed cross K/V."""
    x = jnp.take(params["embed"], token, axis=0).astype(cfg.dtype)
    b = token.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    positions = pos[:, None]

    def body(carry, xs):
        p, cb = xs
        p = cast_floats(p, cfg.dtype)
        new_cb = dict(cb)
        h = layers.rms_norm(carry, p["ln1"], cfg.norm_eps)
        q, k1, v1 = layers.gqa_qkv(h, p["attn"], cfg, positions)

        def upd(c, u, pp):
            return jax.lax.dynamic_update_slice(
                c, u.astype(c.dtype), (pp, 0, 0))

        k = jax.vmap(upd)(cb["k"], k1, pos)
        v = jax.vmap(upd)(cb["v"], v1, pos)
        new_cb["k"], new_cb["v"] = k, v
        o = layers.attention(q, k, v, causal=True, q_offset=pos,
                             kv_len=pos + 1, chunk=cfg.attn_chunk)
        x2 = carry + layers.attn_out(o, p["attn"])
        hx = layers.rms_norm(x2, p["lnx"], cfg.norm_eps)
        qx = (hx @ p["xattn"]["wq"]).reshape(b, 1, cfg.n_heads, cfg.head_dim)
        ox = layers.attention(qx, cb["xk"], cb["xv"], causal=False,
                              chunk=cfg.attn_chunk)
        x3 = x2 + layers.attn_out(ox, p["xattn"])
        h2 = layers.rms_norm(x3, p["ln2"], cfg.norm_eps)
        y = x3 + layers.swiglu(h2, p["mlp"]["w_gate"], p["mlp"]["w_in"],
                               p["mlp"]["w_out"])
        return y, new_cb

    x, new_blocks = jax.lax.scan(body, x, (params["dec_blocks"],
                                           cache["blocks"]))
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return lm_logits(cfg, params, x)[:, 0], {"blocks": new_blocks}

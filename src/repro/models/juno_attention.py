"""JUNO-attention: the paper's ANN machinery applied to decode-time
attention (beyond-paper; motivated by the paper's own §6.5 Llama experiment).

Attention IS maximum-inner-product search: query vectors search the cached
keys. We PQ-encode the keys per head (2-D subspaces, exactly the paper's
geometry), score all positions with the IP-LUT scan — reading S·(hd/2)
uint8 code bytes instead of S·hd·2 bf16 key bytes, a 4× cut of the
memory-bound decode traffic — then attend EXACTLY over the top-C positions.

This is the H2 two-stage idea transplanted into the KV cache: approximate
scan → static top-C → exact rerank. Quality knob: C (tokens attended).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.kmeans import kmeans
from repro.core.pq import split_subspaces


class KVIndex(NamedTuple):
    entries: jnp.ndarray    # (H, S_sub, E, 2) f32 — per-head codebooks
    codes: jnp.ndarray      # (B, H, S, S_sub) uint8 — encoded keys


@functools.partial(jax.jit, static_argnames=("n_entries",))
def build_kv_index(k_cache: jnp.ndarray, *, n_entries: int = 16,
                   key: jax.Array | None = None) -> KVIndex:
    """k_cache (B, S, KVH, hd) → per-head PQ index over the cached keys.
    Built once at prefill; decode appends via ``encode_step``."""
    if key is None:
        key = jax.random.PRNGKey(0)
    b, s, h, hd = k_cache.shape
    ks = k_cache.astype(jnp.float32).transpose(2, 0, 1, 3).reshape(h, b * s,
                                                                   hd)

    def per_head(pts, kk):
        sub = split_subspaces(pts, 2)                  # (N, S_sub, 2)
        sub = jnp.swapaxes(sub, 0, 1)                  # (S_sub, N, 2)
        cents = jax.vmap(lambda p, k2: kmeans(
            p, n_clusters=n_entries, n_iters=4, key=k2,
            chunk=min(4096, p.shape[0])).centroids)(
            sub, jax.random.split(kk, sub.shape[0]))
        return cents                                   # (S_sub, E, 2)

    entries = jax.vmap(per_head)(ks, jax.random.split(key, h))
    codes = _encode(k_cache, entries)
    return KVIndex(entries=entries, codes=codes)


def _encode(k_cache, entries):
    """k (B, S, H, hd), entries (H, S_sub, E, 2) → codes (B, H, S, S_sub)."""
    b, s, h, hd = k_cache.shape
    sub = k_cache.astype(jnp.float32).reshape(b, s, h, hd // 2, 2)
    sub = sub.transpose(0, 2, 1, 3, 4)                 # (B, H, S, S_sub, 2)
    d = jnp.sum((sub[:, :, :, :, None, :]
                 - entries[None, :, None]) ** 2, -1)   # (B,H,S,S_sub,E)
    return jnp.argmin(d, axis=-1).astype(jnp.uint8)


def encode_step(index: KVIndex, k_new: jnp.ndarray, pos) -> KVIndex:
    """Append one token's key codes at per-batch positions pos (B,)."""
    new_codes = _encode(k_new, index.entries)          # (B, H, 1, S_sub)

    def upd(c, u, p):
        return jax.lax.dynamic_update_slice(c, u, (0, p, 0))

    codes = jax.vmap(upd)(index.codes, new_codes, pos)
    return index._replace(codes=codes)


@functools.partial(jax.jit, static_argnames=("top_c",))
def juno_decode_attention(q: jnp.ndarray, index: KVIndex,
                          k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                          pos, *, top_c: int = 128) -> jnp.ndarray:
    """q (B, 1, H, hd) (post-rope), caches (B, S, KVH, hd), pos (B,).
    GQA: q heads grouped onto KVH codebooks. Returns (B, 1, H, hd)."""
    b, _, hq, hd = q.shape
    _, s, h, _ = k_cache.shape
    g = hq // h
    qg = q[:, 0].reshape(b, h, g, hd)

    # stage 1: approximate IP via LUT scan over codes (uint8 traffic only)
    qsub = qg.astype(jnp.float32).reshape(b, h, g, hd // 2, 2)
    lut = jnp.einsum("bhgsm,hsem->bhgse", qsub, index.entries)  # (B,H,G,S_sub,E)
    s_idx = jnp.arange(hd // 2)[None, None, None, :]
    codes = index.codes.astype(jnp.int32)                       # (B,H,S,S_sub)
    gathered = jnp.take_along_axis(
        lut[:, :, :, None],                                     # (B,H,G,1,S_sub,E)
        codes[:, :, None, :, :, None], axis=-1)[..., 0]         # (B,H,G,S,S_sub)
    approx = jnp.sum(gathered, -1)                              # (B,H,G,S)
    valid = jnp.arange(s)[None, :] <= pos[:, None]              # (B,S)
    approx = jnp.where(valid[:, None, None], approx, -jnp.inf)

    # stage 2: exact attention over the per-head top-C positions
    c = min(top_c, s)
    _, top_idx = jax.lax.top_k(approx, c)                       # (B,H,G,C)
    bi = jnp.arange(b)[:, None, None, None]
    hi = jnp.arange(h)[None, :, None, None]
    k_sel = k_cache.transpose(0, 2, 1, 3)[bi, hi, top_idx]      # (B,H,G,C,hd)
    v_sel = v_cache.transpose(0, 2, 1, 3)[bi, hi, top_idx]
    scores = jnp.einsum("bhgd,bhgcd->bhgc", qg, k_sel
                        ).astype(jnp.float32) / (hd ** 0.5)
    sel_valid = jnp.take_along_axis(
        jnp.broadcast_to(valid[:, None, None], approx.shape), top_idx, -1)
    scores = jnp.where(sel_valid, scores, -1e30)
    w = jax.nn.softmax(scores, -1).astype(v_sel.dtype)
    o = jnp.einsum("bhgc,bhgcd->bhgd", w, v_sel)
    return o.reshape(b, 1, hq, hd)


def traffic_model(s: int, hd: int, top_c: int) -> dict:
    """Decode-attention HBM bytes per (head, step): exact vs JUNO."""
    exact = s * hd * 2 * 2                      # K and V, bf16
    juno = s * (hd // 2) + top_c * hd * 2 * 2   # uint8 codes + exact top-C
    return {"exact_bytes": exact, "juno_bytes": juno,
            "reduction_x": exact / juno}

"""Product quantization: per-subspace codebook training + encoding.

Follows the paper's setup: the D-dim residual space is split into S = D/M
M-dim subspaces (M=2 in JUNO so each subspace is a 2-D plane — the property
the RT mapping exploits and that our grid/threshold machinery inherits).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kmeans import kmeans, assign


class PQCodebook(NamedTuple):
    entries: jnp.ndarray   # (S, E, M) f32 — codebook entry coordinates
    entry_sq: jnp.ndarray  # (S, E)    f32 — |e|^2, precomputed (MIPS + L2 expansion)

    @property
    def n_subspaces(self) -> int:
        return self.entries.shape[0]

    @property
    def n_entries(self) -> int:
        return self.entries.shape[1]

    @property
    def sub_dim(self) -> int:
        return self.entries.shape[2]


def split_subspaces(x: jnp.ndarray, m: int) -> jnp.ndarray:
    """(N, D) -> (N, S, M) with S = D // M. D must be divisible by M."""
    n, d = x.shape
    assert d % m == 0, f"D={d} not divisible by M={m}"
    return x.reshape(n, d // m, m)


@functools.partial(jax.jit, static_argnames=("n_entries", "n_iters", "m"))
def train_codebook(residuals: jnp.ndarray, *, n_entries: int, m: int = 2,
                   n_iters: int = 10, key: jax.Array | None = None) -> PQCodebook:
    """Train one k-means codebook per subspace (vmapped Lloyd)."""
    if key is None:
        key = jax.random.PRNGKey(1)
    sub = split_subspaces(residuals, m)                       # (N, S, M)
    sub = jnp.swapaxes(sub, 0, 1)                             # (S, N, M)
    keys = jax.random.split(key, sub.shape[0])

    def per_sub(pts, k):
        st = kmeans(pts, n_clusters=n_entries, n_iters=n_iters, key=k,
                    chunk=min(16384, pts.shape[0]))
        return st.centroids

    entries = jax.vmap(per_sub)(sub, keys)                    # (S, E, M)
    return PQCodebook(entries=entries, entry_sq=jnp.sum(entries * entries, -1))


@jax.jit
def encode(residuals: jnp.ndarray, codebook: PQCodebook) -> jnp.ndarray:
    """Encode residuals -> codes (N, S) uint8 (nearest entry per subspace)."""
    sub = split_subspaces(residuals, codebook.sub_dim)        # (N, S, M)
    sub = jnp.swapaxes(sub, 0, 1)                             # (S, N, M)

    def per_sub(pts, entries):
        return assign(pts, entries, chunk=min(16384, pts.shape[0]))

    codes = jax.vmap(per_sub)(sub, codebook.entries)          # (S, N)
    return jnp.swapaxes(codes, 0, 1).astype(jnp.uint8)


@jax.jit
def decode(codes: jnp.ndarray, codebook: PQCodebook) -> jnp.ndarray:
    """Reconstruct residuals from codes — used by tests/oracles. (N, S*M)."""
    gathered = jnp.take_along_axis(
        codebook.entries[None],                               # (1, S, E, M)
        codes.astype(jnp.int32)[:, :, None, None], axis=2)    # (N, S, 1, M)
    return gathered[:, :, 0, :].reshape(codes.shape[0], -1)

"""Distance-calculation stage (paper Fig. 1 stage D) — reference JAX path.

Two scan flavours over the PQ codes of the selected clusters:

* ``adc_scan``       — exact masked accumulation (JUNO-H): gathers LUT values
                       per (point, subspace) and sums over subspaces. The
                       Pallas twin (kernels/pq_scan) maps the gather to a
                       one-hot · LUT MXU matmul, the TPU analogue of the
                       paper's Tensor-core A×B(=1) accumulation trick.
* ``hit_count_scan`` — JUNO-L/M: int8 reward/penalty accumulation, no f32
                       LUT touch at all (the aggressive approximation §5.4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _gather(lut: jnp.ndarray, codes: jnp.ndarray) -> jnp.ndarray:
    """lut (S, E), codes (P, S) int -> (P, S): out[p, s] = lut[s, codes[p, s]]."""
    s_idx = jnp.arange(lut.shape[0])[None, :]                   # (1, S)
    return lut[s_idx, codes.astype(jnp.int32)]                  # (P, S)


def adc_scan(lut: jnp.ndarray, codes: jnp.ndarray, valid: jnp.ndarray,
             *, metric: str = "l2") -> jnp.ndarray:
    """lut (S, E) f32 (already mask-substituted), codes (P, S) uint8,
    valid (P,) bool. Returns (P,) scores; invalid slots get +inf / -inf."""
    vals = _gather(lut, codes)                                  # (P, S)
    total = jnp.sum(vals, axis=-1)
    bad = jnp.inf if metric == "l2" else -jnp.inf
    return jnp.where(valid, total, bad)


def hit_count_scan(table: jnp.ndarray, codes: jnp.ndarray, valid: jnp.ndarray
                   ) -> jnp.ndarray:
    """table (S, E) int8 hit table, codes (P, S) uint8 -> (P,) int32 score
    (higher = closer). Invalid slots get a large negative count."""
    vals = _gather(table.astype(jnp.int32), codes)
    total = jnp.sum(vals, axis=-1)
    return jnp.where(valid, total, jnp.int32(-(2 ** 30)))


def adc_scan_onehot(lut: jnp.ndarray, codes: jnp.ndarray, valid: jnp.ndarray,
                    *, metric: str = "l2") -> jnp.ndarray:
    """MXU-mapped variant: one_hot(codes) (P, S, E) contracted with lut (S, E).

    This is the accumulation the Pallas kernel implements blockwise; exposed
    here so tests can assert the two formulations agree bit-for-bit.
    """
    e = lut.shape[-1]
    oh = jax.nn.one_hot(codes.astype(jnp.int32), e, dtype=lut.dtype)  # (P,S,E)
    total = jnp.einsum("pse,se->p", oh, lut)
    bad = jnp.inf if metric == "l2" else -jnp.inf
    return jnp.where(valid, total, bad)

"""Exact brute-force nearest-neighbour oracle — ground truth for every test
and recall measurement. Chunked so the (Q, N) score matrix never exceeds
memory for benchmark-scale N."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("k", "metric", "chunk"))
def exact_topk(queries: jnp.ndarray, points: jnp.ndarray, *, k: int,
               metric: str = "l2", chunk: int = 65536):
    """Exact top-k ids+scores. queries (Q, D), points (N, D) -> (Q, k) each.

    Streaming top-k: scan over point chunks keeping the running best k, so
    memory is O(Q * (chunk + k)) regardless of N.
    """
    q = queries.astype(jnp.float32)
    n = points.shape[0]
    n_pad = ((n + chunk - 1) // chunk) * chunk
    pts = jnp.pad(points.astype(jnp.float32), ((0, n_pad - n), (0, 0)))
    pts = pts.reshape(-1, chunk, points.shape[-1])
    nq = q.shape[0]
    sign = -1.0 if metric == "l2" else 1.0  # internally higher-is-better

    def body(carry, xs):
        best_s, best_i = carry
        chunk_pts, base = xs
        dots = q @ chunk_pts.T                                   # (Q, chunk)
        if metric == "l2":
            p_sq = jnp.sum(chunk_pts * chunk_pts, axis=-1)
            scores = -(p_sq[None, :] - 2.0 * dots)               # -(|p|^2-2qp)
        else:
            scores = dots
        ids = base + jnp.arange(chunk, dtype=jnp.int32)[None, :]
        pad_mask = ids < n
        scores = jnp.where(pad_mask, scores, -jnp.inf)
        cat_s = jnp.concatenate([best_s, scores], axis=1)
        cat_i = jnp.concatenate([best_i, jnp.broadcast_to(ids, (nq, chunk))], 1)
        top_s, sel = jax.lax.top_k(cat_s, k)
        top_i = jnp.take_along_axis(cat_i, sel, axis=1)
        return (top_s, top_i), None

    init = (jnp.full((nq, k), -jnp.inf), jnp.full((nq, k), -1, jnp.int32))
    bases = jnp.arange(pts.shape[0], dtype=jnp.int32) * chunk
    (best_s, best_i), _ = jax.lax.scan(body, init, (pts, bases))
    return sign * best_s, best_i

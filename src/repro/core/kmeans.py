"""Batched Lloyd k-means in pure JAX — the training primitive for IVF and PQ.

Distances use the MXU-friendly expansion ``|x-c|^2 = |x|^2 - 2 x.c^T + |c|^2``
so assignment is a single matmul per chunk. Assignment is chunked with
``lax.map`` so the (N, C) distance matrix never materialises for large N.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


class KMeansState(NamedTuple):
    centroids: jnp.ndarray  # (C, D) f32
    counts: jnp.ndarray     # (C,)   f32 — points per cluster at last iter


def _pad_to(x: jnp.ndarray, n: int) -> jnp.ndarray:
    return jnp.pad(x, ((0, n - x.shape[0]),) + ((0, 0),) * (x.ndim - 1))


def _reseed_indices(i: jnp.ndarray | int, n: int, n_clusters: int) -> jnp.ndarray:
    """Deterministic reseed targets for dead clusters at Lloyd iteration ``i``.

    The map ``j -> (base_i + j) % n`` is injective over cluster positions
    ``j`` whenever ``n_clusters <= n``, so two dead clusters can never be
    reseeded to the same data point. (The previous scheme,
    ``(init_idx * (i + 2) + 7) % n``, collided whenever two init indices
    coincided mod ``n / gcd(i + 2, n)`` — e.g. ``init_idx`` 1 and 5 with
    ``n = 12`` at iteration 1 both reseeded to point 10.)

    Parameters
    ----------
    i : int or jnp.ndarray
        Lloyd iteration counter (traced inside ``fori_loop``).
    n : int
        Number of data points.
    n_clusters : int
        Number of clusters (one candidate index per cluster is returned).

    Returns
    -------
    jnp.ndarray
        (n_clusters,) int32 indices into the point set, pairwise distinct
        when ``n_clusters <= n``.
    """
    base = (7919 * (i + 2) + 7) % n
    return ((base + jnp.arange(n_clusters)) % n).astype(jnp.int32)


def assign(points: jnp.ndarray, centroids: jnp.ndarray, *, chunk: int = 16384) -> jnp.ndarray:
    """Nearest-centroid id per point, O(chunk*C) memory. Returns (N,) int32."""
    n = points.shape[0]
    n_pad = ((n + chunk - 1) // chunk) * chunk
    pts = _pad_to(points, n_pad).reshape(n_pad // chunk, chunk, -1)
    c_sq = jnp.sum(centroids * centroids, axis=-1)  # (C,)

    def one(chunk_pts):
        d = c_sq[None, :] - 2.0 * chunk_pts @ centroids.T  # |x|^2 constant per row
        return jnp.argmin(d, axis=-1).astype(jnp.int32)

    return jax.lax.map(one, pts).reshape(n_pad)[:n]


def _update(points, labels, n_clusters):
    one_hot = jax.nn.one_hot(labels, n_clusters, dtype=points.dtype)  # (N, C)
    sums = one_hot.T @ points                                          # (C, D)
    counts = jnp.sum(one_hot, axis=0)                                  # (C,)
    return sums, counts


def _update_chunked(points, labels, n_clusters, chunk):
    n = points.shape[0]
    n_pad = ((n + chunk - 1) // chunk) * chunk
    pts = _pad_to(points, n_pad).reshape(-1, chunk, points.shape[-1])
    # padded points get label == n_clusters (one_hot drops them)
    lbl = jnp.pad(labels, (0, n_pad - n), constant_values=n_clusters)
    lbl = lbl.reshape(-1, chunk)

    def body(carry, xs):
        sums, counts = carry
        p, l = xs
        s, c = _update(p, l, n_clusters)
        return (sums + s, counts + c), None

    init = (jnp.zeros((n_clusters, points.shape[-1]), points.dtype),
            jnp.zeros((n_clusters,), points.dtype))
    (sums, counts), _ = jax.lax.scan(body, init, (pts, lbl))
    return sums, counts


@functools.partial(jax.jit, static_argnames=("n_clusters", "n_iters", "chunk"))
def kmeans(points: jnp.ndarray, *, n_clusters: int, n_iters: int = 10,
           key: jax.Array | None = None, chunk: int = 16384) -> KMeansState:
    """Lloyd k-means with k-random init. Empty clusters re-seeded from data."""
    if key is None:
        key = jax.random.PRNGKey(0)
    n = points.shape[0]
    init_idx = jax.random.choice(key, n, shape=(n_clusters,), replace=n < n_clusters)
    centroids = points[init_idx].astype(jnp.float32)
    pts32 = points.astype(jnp.float32)

    def step(i, carry):
        centroids, _ = carry
        labels = assign(pts32, centroids, chunk=chunk)
        sums, counts = _update_chunked(pts32, labels, n_clusters, chunk)
        new = sums / jnp.maximum(counts, 1.0)[:, None]
        # dead clusters: re-seed deterministically from the data, with
        # pairwise-distinct targets (see _reseed_indices)
        reseed = pts32[_reseed_indices(i, n, n_clusters)]
        new = jnp.where((counts > 0)[:, None], new, reseed)
        return new, counts

    centroids, counts = jax.lax.fori_loop(
        0, n_iters, step, (centroids, jnp.zeros((n_clusters,), jnp.float32)))
    return KMeansState(centroids=centroids, counts=counts)


def kmeans_subsampled(points, *, n_clusters, n_iters=10, key=None,
                      max_train_points=200_000, chunk=16384) -> KMeansState:
    """FAISS-style: train centroids on a subsample, assign the full set later."""
    if key is None:
        key = jax.random.PRNGKey(0)
    n = points.shape[0]
    if n > max_train_points:
        idx = jax.random.choice(key, n, shape=(max_train_points,), replace=False)
        train = points[idx]
    else:
        train = points
    return kmeans(train, n_clusters=n_clusters, n_iters=n_iters, key=key, chunk=chunk)

"""Selective L2/IP-LUT construction (paper §4) — reference JAX path.

The Pallas kernel in ``repro.kernels.lut_build`` fuses the same computation;
this module is the semantics of record. For each selected cluster residual,
computes the (S, E) table of sub-distances plus the selection mask
``dist <= tau[s]`` — the TPU analogue of the RT-core in/out check, where the
dense E-wide MXU row replaces the BVH traversal and the dynamic threshold
vector replaces ``t_max`` (DESIGN.md §2).
"""
from __future__ import annotations

import jax.numpy as jnp

from .pq import PQCodebook

BIG = jnp.float32(1e9)


def build_lut(residual_sub: jnp.ndarray, codebook: PQCodebook, tau: jnp.ndarray,
              *, metric: str = "l2") -> tuple[jnp.ndarray, jnp.ndarray]:
    """residual_sub: (..., S, M) query-minus-centroid projections.
    tau: (..., S) per-subspace dynamic thresholds.

    Returns (lut, mask), each (..., S, E):
      l2: lut[s,e] = |r_s - e|^2,         mask = lut <= tau^2
      ip: lut[s,e] = <r_s, e>,            mask = (|e|^2 - 2<r_s,e>) <= tau^2
          (the paper's radius-folding trick: threshold on the transformed L2
          so selection still means "spatially close", while the LUT stores the
          similarity that will be accumulated — higher-is-better.)
    """
    r_dot_e = jnp.einsum("...sm,sem->...se", residual_sub,
                         codebook.entries)                     # (..., S, E)
    e_sq = codebook.entry_sq                                    # (S, E)
    tau_sq = (tau * tau)[..., None]
    if metric == "l2":
        r_sq = jnp.sum(residual_sub * residual_sub, -1)[..., None]
        lut = r_sq - 2.0 * r_dot_e + e_sq
        mask = lut <= tau_sq
        return lut, mask
    elif metric == "ip":
        lut = r_dot_e
        mask = (e_sq - 2.0 * r_dot_e) <= tau_sq                 # |e-r|^2 - |r|^2 <= tau^2
        return lut, mask
    raise ValueError(f"unknown metric {metric!r}")


def masked_lut(lut: jnp.ndarray, mask: jnp.ndarray, tau: jnp.ndarray,
               *, metric: str = "l2") -> jnp.ndarray:
    """Substitute pruned entries with their information-preserving bound.

    Paper Alg. 2 drops pruned entries entirely and gives never-hit points a
    large constant. We use the tighter per-subspace substitution: a pruned
    entry's sub-distance is *at least* tau[s] (L2) / at most the threshold
    bound (IP), so substituting the bound keeps ranking sound while exactly
    reproducing the paper's "large constant" behaviour for points pruned in
    every subspace (sum of bounds ≈ BIG ordering-wise).
    """
    if metric == "l2":
        fill = (tau * tau)[..., None]
        return jnp.where(mask, lut, fill)
    return ip_pruned_fill(lut, mask)


def ip_pruned_fill(lut: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """IP-metric pruned-entry substitution: each pruned entry contributes the
    worst KEPT similarity of its row (0.0 when a row keeps nothing).

    This is THE definition of ip pruning semantics — also applied by
    ``kernels.ops.build_selective_lut`` (post-pass over the kernel's
    placeholder) and mirrored by ``kernels.ref.selective_lut_ref``, so the
    ref/pallas/core paths cannot silently diverge again
    (tests/test_impl_parity.py)."""
    fill = jnp.min(jnp.where(mask, lut, jnp.inf), axis=-1, keepdims=True)
    fill = jnp.where(jnp.isfinite(fill), fill, 0.0)
    return jnp.where(mask, lut, fill)


def hit_tables(lut: jnp.ndarray, mask: jnp.ndarray, tau: jnp.ndarray,
               *, mode: str = "reward_penalty", metric: str = "l2") -> jnp.ndarray:
    """Hit-count tables (paper §5.4) as int8 (..., S, E).

    mode="count"          : JUNO-L — outer-sphere hit = +1, miss = 0
    mode="reward_penalty" : JUNO-M — inner sphere (tau/2) = +1, outer only = 0,
                            miss both = -1
    For IP the inner test uses the same transformed-L2 geometry as the mask.
    """
    if metric != "l2":
        raise ValueError("use hit_tables_ip for the IP metric")
    inner = lut <= (0.5 * tau[..., None]) ** 2
    if mode == "count":
        return mask.astype(jnp.int8)
    elif mode == "reward_penalty":
        return (inner.astype(jnp.int8) - (~mask).astype(jnp.int8))
    raise ValueError(f"unknown hit-count mode {mode!r}")


def hit_tables_ip(r_dot_e: jnp.ndarray, entry_sq: jnp.ndarray, tau: jnp.ndarray,
                  *, mode: str = "reward_penalty") -> jnp.ndarray:
    """IP-metric hit tables from raw dot products (transformed-L2 geometry)."""
    t = entry_sq - 2.0 * r_dot_e            # |e-r|^2 - |r|^2, monotone in L2
    tau_sq = (tau * tau)[..., None]
    outer = t <= tau_sq
    if mode == "count":
        return outer.astype(jnp.int8)
    inner = t <= 0.25 * tau_sq
    return inner.astype(jnp.int8) - (~outer).astype(jnp.int8)

"""Dynamic-threshold machinery (paper §4.1).

Offline: a G×G density grid per subspace over the residual projections, plus
a small polynomial regressor density → threshold-that-contains-the-top-100.
Online: grid lookup + polynomial eval + user scale factor. The regressor is
fit with a closed-form least-squares solve (no sklearn dependency).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class DensityModel(NamedTuple):
    grid: jnp.ndarray       # (S, G, G) f32 — log1p point density per cell
    lo: jnp.ndarray         # (S, M) f32 — bounding box per subspace
    hi: jnp.ndarray         # (S, M) f32
    coeffs: jnp.ndarray     # (deg+1,) f32 — poly coeffs, highest degree first
    tau_min: jnp.ndarray    # () f32 — clamp range for predicted thresholds
    tau_max: jnp.ndarray    # () f32

    @property
    def grid_size(self) -> int:
        return self.grid.shape[-1]


def build_density_grid(sub_points: jnp.ndarray, grid_size: int = 100
                       ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """sub_points: (S, N, M). Returns (grid (S,G,G), lo (S,M), hi (S,M)).

    Density per cell = count / cell_area, stored as log1p (the paper observes
    a power-law relation; log density linearises it for the polynomial fit).
    """
    s, n, m = sub_points.shape
    assert m == 2, "density grid assumes 2-D subspaces (M=2, as in JUNO)"
    lo = jnp.min(sub_points, axis=1)              # (S, 2)
    hi = jnp.max(sub_points, axis=1)
    span = jnp.maximum(hi - lo, 1e-6)

    def per_sub(pts, lo_s, span_s):
        ij = jnp.clip(((pts - lo_s) / span_s * grid_size).astype(jnp.int32),
                      0, grid_size - 1)
        flat = ij[:, 0] * grid_size + ij[:, 1]
        counts = jnp.zeros((grid_size * grid_size,), jnp.float32
                           ).at[flat].add(1.0)
        cell_area = (span_s[0] / grid_size) * (span_s[1] / grid_size)
        return jnp.log1p(counts / jnp.maximum(cell_area, 1e-12)
                         ).reshape(grid_size, grid_size)

    grid = jax.vmap(per_sub)(sub_points, lo, span)
    return grid, lo, hi


@jax.jit
def accumulate_density_counts(counts: jnp.ndarray, sub_points: jnp.ndarray,
                              lo: jnp.ndarray, hi: jnp.ndarray,
                              weights: jnp.ndarray | None = None
                              ) -> jnp.ndarray:
    """Add one chunk's binned counts to a running (S, G, G) histogram.

    The streaming counterpart of :func:`build_density_grid`: the bounding
    box is fixed up front (out-of-box points clip to edge cells, exactly
    as :func:`lookup_density` clips at query time) so chunks can be
    accumulated independently.

    Parameters
    ----------
    counts : jnp.ndarray
        (S, G, G) f32 running raw counts (start from zeros).
    sub_points : jnp.ndarray
        (S, B, M) f32 — one chunk's residual subspace projections.
    lo, hi : jnp.ndarray
        (S, M) f32 — fixed binning box per subspace.
    weights : jnp.ndarray, optional
        (B,) f32 per-row weight (0.0 excludes a padding row from the
        histogram; default all-ones).

    Returns
    -------
    jnp.ndarray
        (S, G, G) f32 updated counts.
    """
    g = counts.shape[-1]
    span = jnp.maximum(hi - lo, 1e-6)
    w = (jnp.ones((sub_points.shape[1],), jnp.float32)
         if weights is None else weights.astype(jnp.float32))

    def per_sub(cnt, pts, lo_s, span_s):
        ij = jnp.clip(((pts - lo_s) / span_s * g).astype(jnp.int32),
                      0, g - 1)
        flat = ij[:, 0] * g + ij[:, 1]
        return cnt.reshape(-1).at[flat].add(w).reshape(g, g)

    return jax.vmap(per_sub)(counts, sub_points, lo, span)


def density_grid_from_counts(counts: jnp.ndarray, lo: jnp.ndarray,
                             hi: jnp.ndarray) -> jnp.ndarray:
    """Finalize streamed raw counts into the log1p density grid.

    Parameters
    ----------
    counts : jnp.ndarray
        (S, G, G) f32 raw counts (:func:`accumulate_density_counts`).
    lo, hi : jnp.ndarray
        (S, M) f32 binning box used during accumulation.

    Returns
    -------
    jnp.ndarray
        (S, G, G) f32 — ``log1p(count / cell_area)``, the same quantity
        :func:`build_density_grid` produces in one shot.
    """
    g = counts.shape[-1]
    span = jnp.maximum(hi - lo, 1e-6)
    cell_area = (span[:, 0] / g) * (span[:, 1] / g)
    return jnp.log1p(counts / jnp.maximum(cell_area, 1e-12)[:, None, None])


def lookup_density(model: DensityModel, sub_queries: jnp.ndarray) -> jnp.ndarray:
    """sub_queries: (..., S, M) -> densities (..., S)."""
    g = model.grid_size
    span = jnp.maximum(model.hi - model.lo, 1e-6)
    ij = jnp.clip(((sub_queries - model.lo) / span * g).astype(jnp.int32), 0, g - 1)
    s_idx = jnp.arange(model.grid.shape[0])
    bshape = sub_queries.shape[:-2]
    s_idx = jnp.broadcast_to(s_idx, bshape + (model.grid.shape[0],))
    return model.grid[s_idx, ij[..., 0], ij[..., 1]]


def fit_threshold_regressor(densities: jnp.ndarray, thresholds: jnp.ndarray,
                            degree: int = 2) -> jnp.ndarray:
    """Least-squares polynomial fit threshold = poly(log-density). (deg+1,)."""
    x = densities.reshape(-1).astype(jnp.float32)
    y = thresholds.reshape(-1).astype(jnp.float32)
    powers = jnp.stack([x ** d for d in range(degree, -1, -1)], axis=-1)
    coeffs, *_ = jnp.linalg.lstsq(powers, y, rcond=None)
    return coeffs.astype(jnp.float32)


def predict_threshold(model: DensityModel, sub_queries: jnp.ndarray,
                      scale: jnp.ndarray | float = 1.0) -> jnp.ndarray:
    """(..., S, M) query projections -> per-subspace thresholds (..., S)."""
    dens = lookup_density(model, sub_queries)
    tau = jnp.polyval(model.coeffs, dens)
    tau = jnp.clip(tau, model.tau_min, model.tau_max)
    return tau * scale


def calibrate(sub_points: jnp.ndarray, codebook_entries: jnp.ndarray,
              sample_queries: jnp.ndarray, topk_entry_dists: jnp.ndarray,
              *, grid_size: int = 100, degree: int = 2) -> DensityModel:
    """Build the full DensityModel.

    sub_points:       (S, N, M) residual projections (grid source)
    sample_queries:   (Qs, S, M) training query projections
    topk_entry_dists: (Qs, S) distance that contains the top-100's entries in
                      each subspace for each training query (computed by the
                      caller from ground truth — see JunoIndex.build).
    """
    grid, lo, hi = build_density_grid(sub_points, grid_size)
    return calibrate_from_grid(grid, lo, hi, sample_queries,
                               topk_entry_dists, degree=degree)


def calibrate_from_grid(grid: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray,
                        sample_queries: jnp.ndarray,
                        topk_entry_dists: jnp.ndarray, *,
                        degree: int = 2) -> DensityModel:
    """Fit the threshold regressor onto an already-built density grid.

    The regression half of :func:`calibrate`, split out so the streaming
    build (``repro.build.pipeline``) can accumulate the grid chunk by
    chunk (:func:`accumulate_density_counts`) and still share the exact
    covering-fit logic of the in-memory path.

    Parameters
    ----------
    grid : jnp.ndarray
        (S, G, G) f32 log1p density grid.
    lo, hi : jnp.ndarray
        (S, M) f32 grid bounding box.
    sample_queries : jnp.ndarray
        (Qs, S, M) training query projections.
    topk_entry_dists : jnp.ndarray
        (Qs, S) covering distances from ground truth (see
        :func:`calibrate`).
    degree : int
        Polynomial degree of the regressor.

    Returns
    -------
    DensityModel
        The complete calibrated model.
    """
    stub = DensityModel(grid=grid, lo=lo, hi=hi,
                        coeffs=jnp.zeros((degree + 1,), jnp.float32),
                        tau_min=jnp.float32(0.0), tau_max=jnp.float32(1.0))
    dens = lookup_density(stub, sample_queries)               # (Qs, S)
    coeffs = fit_threshold_regressor(dens, topk_entry_dists, degree)
    # covering fit: shift the intercept so the predicted tau is an UPPER
    # bound for ~84% of calibration pairs (mean + 1σ of residuals) — a
    # threshold that undershoots drops true neighbours (paper Fig. 13b);
    # the user-facing thres_scale knob trades this margin for throughput.
    resid = topk_entry_dists.reshape(-1) - jnp.polyval(
        coeffs, dens.reshape(-1))
    margin = jnp.mean(resid) + jnp.std(resid)
    coeffs = coeffs.at[-1].add(margin.astype(jnp.float32))
    q_lo = jnp.quantile(topk_entry_dists, 0.01)
    q_hi = jnp.quantile(topk_entry_dists, 0.999) + margin
    return DensityModel(grid=grid, lo=lo, hi=hi, coeffs=coeffs,
                        tau_min=q_lo.astype(jnp.float32),
                        tau_max=q_hi.astype(jnp.float32))

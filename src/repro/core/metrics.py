"""Search-quality metrics exactly as defined in the paper §6.1."""
from __future__ import annotations

import jax.numpy as jnp


def recall_1_at_k(retrieved: jnp.ndarray, gt_top1: jnp.ndarray) -> jnp.ndarray:
    """R1@K: fraction of queries whose K retrieved ids include the true NN.
    retrieved (Q, K) int, gt_top1 (Q,) int."""
    hit = jnp.any(retrieved == gt_top1[:, None], axis=1)
    return jnp.mean(hit.astype(jnp.float32))


def recall_n_at_k(retrieved: jnp.ndarray, gt_topn: jnp.ndarray) -> jnp.ndarray:
    """R{N}@{K} (paper's R100@1000): mean fraction of the true top-N present
    among the K retrieved. retrieved (Q, K), gt_topn (Q, N)."""
    hits = (retrieved[:, None, :] == gt_topn[:, :, None]).any(axis=2)  # (Q, N)
    return jnp.mean(hits.astype(jnp.float32))

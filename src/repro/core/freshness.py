"""LSM-style freshness tiers for the mutable index (`repro.core.freshness`).

The PR 2 mutability story gave every mutable index exactly one delta
structure: a fixed-capacity :class:`~repro.core.juno.SideBuffer` (L0)
whose only escape hatch was ``compact()`` escalating to a stop-the-world
``rebuild_index``. This module generalizes that into a small LSM tree
("GPU-Accelerated ANNS: Quantized for Speed, Built for Change",
PAPERS.md):

* **L0** — the existing side buffer: inserts land here when their owning
  cluster's padded slots are full, PQ-encoded with the existing
  codebooks and scored exactly like in-cluster siblings.
* **Minor generations** — sealed, immutable snapshots of a full L0
  (:class:`MinorGeneration`), promoted by :func:`promote_l0`. Deletes
  tombstone their host-side valid mask; their codes may live on disk
  (artifact-backed, demand-paged — see ``repro.build.merge``).
* **Base** — the padded per-cluster storage. Minor points drain into
  freed base slots via the incremental per-cluster fold in
  ``repro.build.merge.fold_step`` — bounded work per call, instead of
  the full-rebuild escalation.

:func:`combined_delta` presents L0 ⊕ minors to the jitted search as ONE
:class:`~repro.core.juno.SideBuffer` of FIXED capacity
``B · (1 + max_minors)`` — promotions and folds change its contents,
never its shape, so every jitted search signature stays warm across
merge cycles (the same kept-capacity discipline as
``build/rebuild.py``). Delta points therefore inherit the probe-gated
scoring — including the ``prefilter="rt"`` sphere-test verdict — of
in-cluster points verbatim.

:class:`MergeScheduler` is the policy driver: ``maybe_step()`` runs one
bounded merge step between engine ticks (the same control-path hook
pattern as ``AnnServeEngine.swap_index``), and ``drain()`` runs steps to
quiescence for ``compact()``. On a sharded index it schedules per-shard
lanes (``DistributedMutableIndex.merge_lanes``) round-robin.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from .juno import SideBuffer, empty_side_buffer


@dataclasses.dataclass
class MinorGeneration:
    """One sealed, PQ-encoded delta generation (a promoted L0 tier).

    ``cluster``/``ids``/``valid`` are host arrays — ``valid`` is the only
    mutable field (deletes tombstone it, folds clear drained positions).
    ``codes`` may be None for an artifact-backed generation; ``loader``
    then faults them in on first search touch, verifying each row's
    sha256 against the minor's manifest (``repro.build.merge``) — the
    same fail-closed first-touch contract the paged base tier has.
    """

    gen: int                         #: monotone generation number
    cluster: np.ndarray              #: (B,) int32 owning clusters
    ids: np.ndarray                  #: (B,) int32 global point ids
    valid: np.ndarray                #: (B,) bool host-mutable tombstones
    codes: Optional[jnp.ndarray]     #: (B, S) uint8, or None until faulted
    loader: Optional[Callable[[], jnp.ndarray]] = None
    path: Optional[str] = None       #: artifact directory when disk-backed

    @property
    def capacity(self) -> int:
        """Fixed slot count B of this generation."""
        return int(self.ids.shape[0])

    @property
    def live(self) -> int:
        """Number of non-tombstoned points still in this generation."""
        return int(self.valid.sum())

    def materialize(self) -> jnp.ndarray:
        """Return the (B, S) code array, faulting it in when disk-backed.

        The first touch of an artifact-backed generation reads the codes
        from disk and verifies every row's sha256 digest — a corrupt
        minor raises :class:`~repro.build.store.ArtifactError` instead
        of serving garbage candidates.
        """
        if self.codes is None:
            self.codes = self.loader()
        return self.codes


def combined_delta(side: SideBuffer, minors: list[MinorGeneration],
                   max_minors: int) -> SideBuffer:
    """Present L0 ⊕ minor generations as one fixed-capacity SideBuffer.

    The result's capacity is ``side.capacity * (1 + max_minors)``
    regardless of how many minors currently exist (empty tail slots are
    padding), so the jitted search signature is a function of the
    *configuration*, not the merge state — promotions and folds never
    retrace.

    Parameters
    ----------
    side : SideBuffer
        The live L0 tier.
    minors : list of MinorGeneration
        Current sealed generations, oldest first.
    max_minors : int
        Configured generation cap (``enable_tiers``).

    Returns
    -------
    SideBuffer
        Concatenated view; invalid slots carry cluster/id −1.
    """
    total = side.capacity * (1 + len(minors))
    cap = side.capacity * (1 + max_minors)
    if total > cap:
        raise RuntimeError(
            f"{len(minors)} minor generations exceed max_minors="
            f"{max_minors} (bookkeeping bug)")
    codes = [side.codes]
    cluster = [side.cluster]
    ids = [side.ids]
    valid = [side.valid]
    for m in minors:
        codes.append(jnp.asarray(m.materialize()))
        cluster.append(jnp.asarray(np.where(m.valid, m.cluster, -1)))
        ids.append(jnp.asarray(m.ids))
        valid.append(jnp.asarray(m.valid))
    if cap > total:
        pad = empty_side_buffer(cap - total, int(side.codes.shape[1]))
        codes.append(pad.codes)
        cluster.append(pad.cluster)
        ids.append(pad.ids)
        valid.append(pad.valid)
    return SideBuffer(codes=jnp.concatenate(codes),
                      cluster=jnp.concatenate(cluster),
                      ids=jnp.concatenate(ids),
                      valid=jnp.concatenate(valid))


def promote_l0(mid) -> MinorGeneration:
    """Seal the current L0 side buffer into a new minor generation.

    The buffer's contents become an immutable :class:`MinorGeneration`
    (codes stay PQ-encoded — they were encoded with the existing
    codebooks at insert time), every promoted id's location is re-pointed
    at the generation, and L0 resets to empty so inserts keep landing in
    a small exact-scored tier. When the index has a minor sink attached
    (``enable_tiers(minor_store=...)``, the paged tier), the generation
    is committed through the :class:`~repro.build.store.ArtifactStore`
    FIRST — a failing commit mutates nothing — and its codes are dropped
    from memory, to be demand-paged back (sha256-row-verified) on first
    search touch.

    Parameters
    ----------
    mid : MutableIndexBase
        The index whose L0 to promote (``enable_tiers`` must have been
        called with ``max_minors > 0``).

    Returns
    -------
    MinorGeneration
        The sealed generation (also appended to the index's tier list).
    """
    if getattr(mid, "_max_minors", 0) <= 0:
        raise RuntimeError("delta tiers are disabled; call "
                           "enable_tiers(max_minors=...) first")
    if len(mid._minors) >= mid._max_minors:
        raise RuntimeError(
            f"minor tier full ({mid._max_minors} generations); fold "
            f"them into the base (build.merge.fold_step) or rebuild")
    if mid.side_fill == 0:
        raise RuntimeError("L0 is empty; nothing to promote")
    side = mid.side
    cluster = np.asarray(side.cluster).copy()
    ids = np.asarray(side.ids).copy()
    valid = np.asarray(side.valid).copy()
    gen = mid._minor_gen
    codes: Optional[jnp.ndarray] = side.codes
    loader = path = None
    sink = getattr(mid, "_minor_sink", None)
    if sink is not None:
        # fallible artifact commit FIRST: a failed write leaves the index
        # untouched (all-or-nothing, like every other mutation here)
        from repro.build import merge as merge_lib
        store, name = sink
        path = merge_lib.commit_minor(store, name, np.asarray(side.codes),
                                      cluster, ids, valid, gen=gen)
        loader = merge_lib.minor_codes_loader(path)
        codes = None                 # demand-paged + verified on first touch
    minor = MinorGeneration(gen=gen, cluster=cluster, ids=ids, valid=valid,
                            codes=codes, loader=loader, path=path)
    # infallible host commit
    for pos in np.where(valid)[0]:
        mid._loc[int(ids[pos])] = (-2 - gen, int(pos))
    mid._minors.append(minor)
    mid._minor_gen = gen + 1
    mid.side = empty_side_buffer(side.capacity, int(side.codes.shape[1]))
    mid._side_free = list(range(side.capacity))[::-1]
    mid._delta_epoch += 1
    return minor


class MergeScheduler:
    """Incremental background-merge policy over a tiered mutable index.

    One ``step()`` does bounded work: fold L0 points into already-free
    base slots (the vectorized ``compact()``), promote a full L0 into a
    minor generation when one is open, and fold up to
    ``clusters_per_step`` clusters of the oldest minor generations into
    the base (``repro.build.merge.fold_step``). ``AnnServeEngine`` calls
    :meth:`maybe_step` between ticks — the same control-path hook
    ``swap_index`` uses — so merges amortize across serving instead of
    stopping the world; ``compact()`` calls :meth:`drain`.

    On a sharded index (anything exposing ``merge_lanes()``, i.e.
    ``DistributedMutableIndex``) fold work is scheduled per shard: each
    step folds clusters of ONE shard's lane, round-robin, so a step's
    row scatter lands on a single shard.
    """

    def __init__(self, index, *, clusters_per_step: int = 32,
                 promote_fill: float = 1.0, registry=None):
        """Attach a scheduler to a tier-enabled mutable index.

        Parameters
        ----------
        index : MutableIndexBase
            The index to merge (``enable_tiers`` already called).
        clusters_per_step : int
            Fold budget: clusters merged per ``step()`` call.
        promote_fill : float
            L0 fill fraction that triggers promotion (1.0 = only when
            completely full; ``drain()`` also promotes partial L0s when
            nothing else makes progress).
        registry : repro.obs.MetricsRegistry, optional
            Destination for the ``juno_merge_*`` series: cycle-duration
            histograms, folded/promotion counters and L0/minor occupancy
            gauges, refreshed per step. None (default) keeps only the
            local ``stats`` dict.
        """
        self.index = index
        self.clusters_per_step = int(clusters_per_step)
        self.promote_fill = float(promote_fill)
        lanes = getattr(index, "merge_lanes", None)
        self._lanes: list = list(lanes()) if callable(lanes) else [None]
        self._lane_i = 0
        self.stats = {"steps": 0, "promotions": 0, "folded": 0,
                      "compacted": 0, "drains": 0}
        self.registry = registry

    @property
    def pending(self) -> int:
        """Delta points not yet folded into the base (L0 + minors)."""
        return self.index.delta_fill

    def _can_promote(self) -> bool:
        idx = self.index
        return (idx._max_minors > 0 and idx.side_fill > 0
                and len(idx._minors) < idx._max_minors)

    def maybe_step(self) -> int:
        """Between-ticks hook: one bounded step, only when work pends.

        Returns the number of points moved (0 when the delta tiers are
        disabled, empty, or below the promotion threshold with no minor
        generations to fold).
        """
        idx = self.index
        if getattr(idx, "_max_minors", 0) <= 0:
            return 0
        if (not idx._minors
                and idx.side_fill < self.promote_fill * idx.side.capacity):
            return 0
        return self.step()

    def step(self) -> int:
        """One bounded merge step; returns points moved between tiers."""
        from repro.build.merge import fold_step
        idx = self.index
        t0 = time.perf_counter()
        moved = idx.compact()            # L0 → free base slots (vectorized)
        self.stats["compacted"] += moved
        if (idx.side_fill >= self.promote_fill * idx.side.capacity
                and self._can_promote()):
            moved += idx.side_fill
            promote_l0(idx)
            self.stats["promotions"] += 1
        lane = self._lanes[self._lane_i]
        self._lane_i = (self._lane_i + 1) % len(self._lanes)
        folded = fold_step(idx, max_clusters=self.clusters_per_step,
                           lane=lane)
        self.stats["folded"] += folded
        self.stats["steps"] += 1
        if self.registry is not None:
            self._observe(time.perf_counter() - t0, moved, folded)
        return moved + folded

    def _observe(self, dt: float, moved: int, folded: int) -> None:
        """Refresh the ``juno_merge_*`` registry series after one step."""
        reg = self.registry
        reg.histogram("juno_merge_step_seconds").add(dt)
        reg.counter("juno_merge_steps_total").inc()
        reg.counter("juno_merge_folded_total").inc(folded)
        reg.counter("juno_merge_moved_total").inc(moved)
        idx = self.index
        cap = max(1, getattr(idx.side, "capacity", 1))
        reg.gauge("juno_merge_l0_fill").set(idx.side_fill / cap)
        reg.gauge("juno_merge_minors").set(len(getattr(idx, "_minors", ())))
        reg.gauge("juno_merge_delta_rows").set(self.pending)

    def drain(self, max_rounds: int = 10_000) -> int:
        """Run merge steps to quiescence (the ``compact()`` entry point).

        Rounds of one step per lane run until a full round moves
        nothing; a stuck non-empty L0 is then promoted even below the
        fill threshold when a minor slot is open (so ``compact()`` keeps
        its side-always-drains guarantee whenever the tier has room).

        Parameters
        ----------
        max_rounds : int
            Safety bound on merge rounds.

        Returns
        -------
        int
            Total points moved between tiers.
        """
        t0 = time.perf_counter()
        total = 0
        for _ in range(max_rounds):
            progress = sum(self.step() for _ in range(len(self._lanes)))
            if progress == 0:
                if self.index.side_fill and self._can_promote():
                    total += self.index.side_fill
                    promote_l0(self.index)
                    self.stats["promotions"] += 1
                    continue
                break
            total += progress
        self.stats["drains"] += 1
        if self.registry is not None:
            self.registry.histogram("juno_merge_drain_seconds").add(
                time.perf_counter() - t0)
            self.registry.counter("juno_merge_drains_total").inc()
        return total

"""JUNO core: sparsity- and locality-aware IVFPQ ANN search (the paper's
primary contribution), implemented as composable JAX modules.

Public API:
    JunoConfig, build, search          — end-to-end index (juno.py)
    exact_topk                         — brute-force oracle (ref.py)
    recall_1_at_k, recall_n_at_k       — paper metrics (metrics.py)
"""
from .juno import JunoConfig, JunoIndexData, build, search  # noqa: F401
from .ref import exact_topk  # noqa: F401
from .metrics import recall_1_at_k, recall_n_at_k  # noqa: F401

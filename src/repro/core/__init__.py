"""JUNO core: sparsity- and locality-aware IVFPQ ANN search (the paper's
primary contribution), implemented as composable JAX modules.

Public API:
    JunoConfig, build, search          — end-to-end index (juno.py)
    MutableJunoIndex, SideBuffer       — online insert/delete/compact (juno.py)
    exact_topk                         — brute-force oracle (ref.py)
    recall_1_at_k, recall_n_at_k       — paper metrics (metrics.py)
"""
from .juno import (JunoConfig, JunoIndexData, MutableJunoIndex,  # noqa: F401
                   SideBuffer, build, empty_side_buffer, search)
from .ref import exact_topk  # noqa: F401
from .metrics import recall_1_at_k, recall_n_at_k  # noqa: F401

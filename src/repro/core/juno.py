"""JunoIndex — the end-to-end JUNO system (paper Alg. 1 + Alg. 2).

Offline (``build``): IVF k-means → residual PQ codebooks → padded per-cluster
code storage (the TPU layout of the paper's entry→points inverted index) →
density grid + polynomial threshold regressor calibration.

Online (``search``): MXU filtering → selective LUT construction with dynamic
per-subspace thresholds (the RT-core stage, re-mapped per DESIGN.md §2) →
masked ADC scan (JUNO-H) or int8 hit-count scan (JUNO-L/M) → top-k.

Modes map 1:1 to the paper's operating points:
  "H" — exact selective distances            (high quality)
  "M" — reward/penalty hit count, r & r/2    (medium)
  "L" — plain hit count                      (low quality, max throughput)
plus one beyond-paper mode exploiting the same sparsity TPU-natively:
  "H2" — two-stage: int8 hit-count prefilter selects a static top-C
         candidate set, exact ADC reranks only those. The paper skips
         far points dynamically on the RT core; H2 gets the same skip as
         a static-shape top-k — ~(nprobe·P)/C less f32 gather work at
         JUNO-H-level recall (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import density as density_lib
from . import lut as lut_lib
from . import scan as scan_lib
from .ivf import IVFIndex, build_ivf, filter_clusters
from .pq import PQCodebook, encode, split_subspaces, train_codebook
from .ref import exact_topk


@dataclasses.dataclass(frozen=True)
class JunoConfig:
    n_clusters: int = 1024          # C
    n_entries: int = 256            # E
    sub_dim: int = 2                # M (JUNO uses 2-D subspaces)
    metric: str = "l2"              # "l2" | "ip"
    kmeans_iters: int = 10
    capacity_mult: float = 4.0
    grid_size: int = 64             # density grid G (paper: 100)
    calib_queries: int = 128        # queries used to fit the threshold poly
    calib_topk: int = 100           # "top-100" of the paper
    poly_degree: int = 2


class JunoIndexData(NamedTuple):
    ivf: IVFIndex
    codebook: PQCodebook
    codes: jnp.ndarray           # (N, S) uint8
    cluster_codes: jnp.ndarray   # (C, P, S) uint8 — padded per-cluster codes
    density: density_lib.DensityModel
    points_sq: jnp.ndarray       # (N,) f32 (kept for oracles/rerank)


def build(points: jnp.ndarray, config: JunoConfig,
          key: jax.Array | None = None) -> JunoIndexData:
    """Offline phase (paper Alg. 1 adapted to the TPU layout)."""
    if key is None:
        key = jax.random.PRNGKey(0)
    k_ivf, k_pq, k_cal = jax.random.split(key, 3)
    pts = jnp.asarray(points, jnp.float32)
    n, d = pts.shape
    s = d // config.sub_dim

    ivf = build_ivf(pts, n_clusters=config.n_clusters,
                    n_iters=config.kmeans_iters, key=k_ivf,
                    capacity_mult=config.capacity_mult)
    residuals = pts - ivf.centroids[ivf.labels]
    codebook = train_codebook(residuals, n_entries=config.n_entries,
                              m=config.sub_dim, n_iters=config.kmeans_iters,
                              key=k_pq)
    codes = encode(residuals, codebook)                          # (N, S)
    # Padded per-cluster codes: pad slots read code 0 but are masked by valid.
    safe_ids = jnp.maximum(ivf.point_ids, 0)
    cluster_codes = codes[safe_ids]                              # (C, P, S)

    dens_model = _calibrate_density(pts, residuals, codebook, codes, ivf,
                                    config, k_cal)
    return JunoIndexData(ivf=ivf, codebook=codebook, codes=codes,
                         cluster_codes=cluster_codes, density=dens_model,
                         points_sq=jnp.sum(pts * pts, axis=-1))


def _calibrate_density(pts, residuals, codebook, codes, ivf, config, key):
    """Fit density → threshold polynomial from ground-truth top-k (paper §4.1)."""
    n = pts.shape[0]
    nq = min(config.calib_queries, n)
    qidx = jax.random.choice(key, n, shape=(nq,), replace=False)
    # perturb so calibration queries are not exact database points
    noise = 0.01 * jax.random.normal(key, (nq, pts.shape[1])) * jnp.std(pts)
    queries = pts[qidx] + noise.astype(jnp.float32)

    _, gt_ids = exact_topk(queries, pts, k=config.calib_topk,
                           metric=config.metric, chunk=min(65536, n))
    # query-side projections in the geometry the mask uses (DESIGN.md §2)
    _, c1 = filter_clusters(queries, ivf, nprobe=1, metric=config.metric)
    if config.metric == "l2":
        qres = queries - ivf.centroids[c1[:, 0]]
        qsub = split_subspaces(qres, config.sub_dim)             # (Qs, S, M)
    else:
        qsub = split_subspaces(queries, config.sub_dim)

    # per-subspace transformed distance from query proj to each top-k entry
    gt_codes = codes[gt_ids].astype(jnp.int32)                   # (Qs, K, S)
    ent = codebook.entries                                       # (S, E, M)
    s_idx = jnp.arange(ent.shape[0])[None, None, :]
    gt_entries = ent[s_idx, gt_codes]                            # (Qs, K, S, M)
    diff = gt_entries - qsub[:, None, :, :]
    if config.metric == "l2":
        t = jnp.sum(diff * diff, axis=-1)                        # (Qs, K, S)
        tau_needed = jnp.sqrt(jnp.max(t, axis=1))                # (Qs, S)
    else:
        e_sq = jnp.sum(gt_entries * gt_entries, -1)
        dot = jnp.sum(gt_entries * qsub[:, None], -1)
        t = e_sq - 2.0 * dot
        tau_needed = jnp.sqrt(jnp.maximum(jnp.max(t, axis=1), 0.0))

    sub_pts = jnp.swapaxes(split_subspaces(residuals, config.sub_dim), 0, 1)
    return density_lib.calibrate(sub_pts, codebook.entries, qsub, tau_needed,
                                 grid_size=config.grid_size,
                                 degree=config.poly_degree)


@functools.partial(jax.jit,
                   static_argnames=("nprobe", "k", "mode", "metric", "impl"))
def _search_batch(index: JunoIndexData, queries: jnp.ndarray, *, nprobe: int,
                  k: int, mode: str, metric: str, thres_scale: float,
                  impl: str = "ref"):
    """One jitted query batch. Returns (scores (Q,k), ids (Q,k)).

    impl="ref"    — pure-jnp reference path (semantics of record)
    impl="pallas" — fused Pallas kernels (TPU path; interpret=True on CPU)
    """
    q = queries.astype(jnp.float32)
    nq = q.shape[0]
    m = index.codebook.sub_dim

    # --- stage A: filtering (MXU GEMM + top-k), paper Fig. 1 bottom-left ---
    base, cids = filter_clusters(q, index.ivf, nprobe=nprobe, metric=metric)

    # --- stage B: selective LUT construction (the RT-core stage) ---------
    if metric == "l2":
        res = q[:, None, :] - index.ivf.centroids[cids]          # (Q, np, D)
        qsub = res.reshape(nq, nprobe, -1, m)                    # (Q, np, S, M)
        probe_base = jnp.zeros((nq, nprobe), jnp.float32)
    else:
        qsub = jnp.broadcast_to(
            q.reshape(nq, 1, -1, m), (nq, nprobe, q.shape[1] // m, m))
        probe_base = base                                        # <q, c_probe>
    tau = density_lib.predict_threshold(index.density, qsub, thres_scale)

    # --- stage C: distance calculation over the selected clusters --------
    codes = index.cluster_codes[cids]                            # (Q, np, P, S)
    valid = index.ivf.valid[cids]                                # (Q, np, P)
    ids = index.ivf.point_ids[cids]                              # (Q, np, P)

    if impl == "pallas":
        from repro.kernels import ops as kops
        mlut, table = kops.build_selective_lut(
            qsub, index.codebook.entries, index.codebook.entry_sq, tau,
            metric=metric)
        if mode == "H":
            pt_scores = kops.masked_adc_scan(mlut, codes, valid,
                                             metric=metric)
            if metric == "ip":
                pt_scores = pt_scores + probe_base[..., None]
            higher_better = metric == "ip"
        else:
            if mode == "L":  # plain count: clip penalty/inner to {0, 1}
                table = (table >= 0).astype(jnp.int8)
            pt_scores = kops.hit_count_scan(table, codes, valid
                                            ).astype(jnp.float32)
            higher_better = True
    elif mode == "H":
        lut, mask = lut_lib.build_lut(qsub, index.codebook, tau, metric=metric)
        mlut = lut_lib.masked_lut(lut, mask, tau, metric=metric)
        scan = jax.vmap(jax.vmap(
            lambda l, c, v: scan_lib.adc_scan(l, c, v, metric=metric)))
        pt_scores = scan(mlut, codes, valid)                     # (Q, np, P)
        if metric == "ip":
            pt_scores = pt_scores + probe_base[..., None]
        higher_better = metric == "ip"
    else:
        lut, mask = lut_lib.build_lut(qsub, index.codebook, tau, metric=metric)
        hc_mode = "count" if mode == "L" else "reward_penalty"
        if metric == "l2":
            table = lut_lib.hit_tables(lut, mask, tau, mode=hc_mode,
                                       metric="l2")
        else:
            table = lut_lib.hit_tables_ip(lut, index.codebook.entry_sq, tau,
                                          mode=hc_mode)
        scan = jax.vmap(jax.vmap(scan_lib.hit_count_scan))
        pt_scores = scan(table, codes, valid).astype(jnp.float32)
        higher_better = True

    flat_scores = pt_scores.reshape(nq, -1)
    flat_ids = ids.reshape(nq, -1)
    sel_scores, sel = jax.lax.top_k(
        flat_scores if higher_better else -flat_scores, k)
    out_ids = jnp.take_along_axis(flat_ids, sel, axis=1)
    out_scores = sel_scores if higher_better else -sel_scores
    return out_scores, out_ids


@functools.partial(jax.jit, static_argnames=("nprobe", "k", "metric", "impl",
                                             "rerank"))
def _search_batch_two_stage(index: JunoIndexData, queries: jnp.ndarray, *,
                            nprobe: int, k: int, metric: str,
                            thres_scale: float, rerank: int = 0,
                            impl: str = "ref"):
    """Mode "H2": int8 hit-count prefilter → exact ADC on top-C survivors.

    Beyond-paper: converts JUNO's dynamic skip into a static-shape candidate
    set so the expensive f32 gather/accumulate runs on C = rerank points
    instead of nprobe·P (see module docstring)."""
    q = queries.astype(jnp.float32)
    nq = q.shape[0]
    m = index.codebook.sub_dim
    c_budget = rerank or 4 * k

    base, cids = filter_clusters(q, index.ivf, nprobe=nprobe, metric=metric)
    if metric == "l2":
        res = q[:, None, :] - index.ivf.centroids[cids]
        qsub = res.reshape(nq, nprobe, -1, m)
        probe_base = jnp.zeros((nq, nprobe), jnp.float32)
    else:
        qsub = jnp.broadcast_to(
            q.reshape(nq, 1, -1, m), (nq, nprobe, q.shape[1] // m, m))
        probe_base = base
    tau = density_lib.predict_threshold(index.density, qsub, thres_scale)

    codes = index.cluster_codes[cids]                            # (Q,np,P,S)
    valid = index.ivf.valid[cids]
    ids = index.ivf.point_ids[cids]

    if impl == "pallas":
        from repro.kernels import ops as kops
        mlut, table = kops.build_selective_lut(
            qsub, index.codebook.entries, index.codebook.entry_sq, tau,
            metric=metric)
        counts = kops.hit_count_scan(table, codes, valid)
    else:
        lut, mask = lut_lib.build_lut(qsub, index.codebook, tau,
                                      metric=metric)
        mlut = lut_lib.masked_lut(lut, mask, tau, metric=metric)
        if metric == "l2":
            table = lut_lib.hit_tables(lut, mask, tau, mode="reward_penalty",
                                       metric="l2")
        else:
            table = lut_lib.hit_tables_ip(lut, index.codebook.entry_sq, tau,
                                          mode="reward_penalty")
        counts = jax.vmap(jax.vmap(scan_lib.hit_count_scan))(table, codes,
                                                             valid)

    # stage 1: top-C candidates by hit count (int32, cheap)
    p = codes.shape[2]
    flat_counts = counts.reshape(nq, -1)
    _, cand = jax.lax.top_k(flat_counts, min(c_budget, nprobe * p))
    cand_probe = cand // p                                       # (Q, C)

    # stage 2: exact ADC only on survivors
    cand_codes = jnp.take_along_axis(
        codes.reshape(nq, -1, codes.shape[-1]), cand[..., None], axis=1)
    s_idx = jnp.arange(mlut.shape[2])[None, None, :]
    vals = mlut[jnp.arange(nq)[:, None, None], cand_probe[..., None],
                s_idx, cand_codes.astype(jnp.int32)]             # (Q, C, S)
    exact = jnp.sum(vals, axis=-1)
    cand_valid = jnp.take_along_axis(valid.reshape(nq, -1), cand, axis=1)
    if metric == "ip":
        exact = exact + jnp.take_along_axis(probe_base, cand_probe, axis=1)
        exact = jnp.where(cand_valid, exact, -jnp.inf)
        sel_s, sel = jax.lax.top_k(exact, k)
        out_scores = sel_s
    else:
        exact = jnp.where(cand_valid, exact, jnp.inf)
        sel_s, sel = jax.lax.top_k(-exact, k)
        out_scores = -sel_s
    cand_ids = jnp.take_along_axis(ids.reshape(nq, -1), cand, axis=1)
    out_ids = jnp.take_along_axis(cand_ids, sel, axis=1)
    return out_scores, out_ids


def search(index: JunoIndexData, queries: jnp.ndarray, *, nprobe: int = 16,
           k: int = 100, mode: str = "H", metric: str = "l2",
           thres_scale: float = 1.0, batch: int = 64, impl: str = "ref",
           rerank: int = 0):
    """Public search API — chunks queries through the jitted batch kernel."""
    nq = queries.shape[0]
    out_s, out_i = [], []
    for i in range(0, nq, batch):
        qb = queries[i:i + batch]
        pad = batch - qb.shape[0]
        if pad:
            qb = jnp.pad(qb, ((0, pad), (0, 0)))
        if mode == "H2":
            s, ids = _search_batch_two_stage(
                index, qb, nprobe=nprobe, k=k, metric=metric,
                thres_scale=thres_scale, rerank=rerank, impl=impl)
        else:
            s, ids = _search_batch(index, qb, nprobe=nprobe, k=k, mode=mode,
                                   metric=metric, thres_scale=thres_scale,
                                   impl=impl)
        out_s.append(s[:batch - pad])
        out_i.append(ids[:batch - pad])
    return jnp.concatenate(out_s), jnp.concatenate(out_i)

"""JunoIndex — the end-to-end JUNO system (paper Alg. 1 + Alg. 2).

Offline (``build``): IVF k-means → residual PQ codebooks → padded per-cluster
code storage (the TPU layout of the paper's entry→points inverted index) →
density grid + polynomial threshold regressor calibration.

Online (``search``): MXU filtering → selective LUT construction with dynamic
per-subspace thresholds (the RT-core stage, re-mapped per DESIGN.md §2) →
masked ADC scan (JUNO-H) or int8 hit-count scan (JUNO-L/M) → top-k.

Modes map 1:1 to the paper's operating points:
  "H" — exact selective distances            (high quality)
  "M" — reward/penalty hit count, r & r/2    (medium)
  "L" — plain hit count                      (low quality, max throughput)
plus one beyond-paper mode exploiting the same sparsity TPU-natively:
  "H2" — two-stage: int8 hit-count prefilter selects a static top-C
         candidate set, exact ADC reranks only those. The paper skips
         far points dynamically on the RT core; H2 gets the same skip as
         a static-shape top-k — ~(nprobe·P)/C less f32 gather work at
         JUNO-H-level recall (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import density as density_lib
from . import lut as lut_lib
from . import scan as scan_lib
from .ivf import IVFIndex, build_ivf, filter_clusters
from .pq import PQCodebook, encode, split_subspaces, train_codebook
from .ref import exact_topk


@dataclasses.dataclass(frozen=True)
class JunoConfig:
    """Build-time knobs of the JUNO index (paper defaults in comments)."""

    n_clusters: int = 1024          # C
    n_entries: int = 256            # E
    sub_dim: int = 2                # M (JUNO uses 2-D subspaces)
    metric: str = "l2"              # "l2" | "ip"
    kmeans_iters: int = 10
    capacity_mult: float = 4.0
    # Lloyd training (IVF and PQ) runs on at most this many points
    # (FAISS-style subsampled training); the full set is only streamed
    # through chunked assignment/encoding. <= 0 disables subsampling.
    max_train_points: int = 200_000
    grid_size: int = 64             # density grid G (paper: 100)
    calib_queries: int = 128        # queries used to fit the threshold poly
    calib_topk: int = 100           # "top-100" of the paper
    poly_degree: int = 2


class JunoIndexData(NamedTuple):
    """A built index: IVF + PQ codebooks + padded codes + density model."""

    ivf: IVFIndex
    codebook: PQCodebook
    codes: jnp.ndarray           # (N, S) uint8
    cluster_codes: jnp.ndarray   # (C, P, S) uint8 — padded per-cluster codes
    density: density_lib.DensityModel
    points_sq: jnp.ndarray       # (N,) f32 (kept for oracles/rerank)


class SideBuffer(NamedTuple):
    """Fixed-capacity exact-membership overflow store for online inserts.

    When an insert's owning cluster has no free padded slot left, the point
    spills here instead of forcing a rebuild. Side points are scored during
    search with the SAME masked-LUT / hit-table gather an in-cluster point
    would receive (and only when their owning cluster is probed), so
    ``compact()`` — which moves them back into freed cluster slots — is a
    search no-op.
    """
    codes: jnp.ndarray     # (B, S) uint8 — PQ codes of spilled points
    cluster: jnp.ndarray   # (B,) int32 — owning cluster (-1 = empty slot)
    ids: jnp.ndarray       # (B,) int32 — global point id
    valid: jnp.ndarray     # (B,) bool

    @property
    def capacity(self) -> int:
        """Fixed slot count B of the buffer."""
        return self.ids.shape[0]


def empty_side_buffer(capacity: int, n_subspaces: int) -> SideBuffer:
    """Allocate an all-empty :class:`SideBuffer`.

    Parameters
    ----------
    capacity : int
        Fixed slot count B (part of the jitted search signature).
    n_subspaces : int
        PQ subspace count S of the index the buffer will ride along with.

    Returns
    -------
    SideBuffer
        codes (B, S) uint8 zeros, cluster/ids (B,) int32 = -1,
        valid (B,) bool = False.
    """
    return SideBuffer(
        codes=jnp.zeros((capacity, n_subspaces), jnp.uint8),
        cluster=jnp.full((capacity,), -1, jnp.int32),
        ids=jnp.full((capacity,), -1, jnp.int32),
        valid=jnp.zeros((capacity,), bool))


def _side_gather(table: jnp.ndarray, cids: jnp.ndarray, side: SideBuffer
                 ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Score side-buffer points against a per-probe LUT/hit table.

    table (Q, np, S, E), cids (Q, np). A side point participates only when
    its owning cluster is among the probed clusters — exactly the condition
    under which it would have been scanned had it lived in its cluster's
    padded slots — and its score is the same gather+sum the in-cluster scan
    performs, so folding it back via ``compact()`` changes nothing.

    Returns (totals (Q, B), probe (Q, B), ok (Q, B)).
    """
    nq = cids.shape[0]
    match = cids[:, :, None] == side.cluster[None, None, :]      # (Q, np, B)
    ok = jnp.any(match, axis=1) & side.valid[None, :]            # (Q, B)
    probe = jnp.argmax(match, axis=1)                            # (Q, B)
    qi = jnp.arange(nq)[:, None, None]
    si = jnp.arange(table.shape[2])[None, None, :]
    codes = side.codes.astype(jnp.int32)[None, :, :]             # (1, B, S)
    vals = table[qi, probe[:, :, None], si, codes]               # (Q, B, S)
    return jnp.sum(vals, axis=-1), probe, ok


def build(points: jnp.ndarray, config: JunoConfig,
          key: jax.Array | None = None) -> JunoIndexData:
    """Offline phase (paper Alg. 1 adapted to the TPU layout)."""
    if key is None:
        key = jax.random.PRNGKey(0)
    k_ivf, k_pq, k_cal = jax.random.split(key, 3)
    pts = jnp.asarray(points, jnp.float32)
    n, d = pts.shape
    s = d // config.sub_dim

    t_max = config.max_train_points if config.max_train_points > 0 else n
    ivf = build_ivf(pts, n_clusters=config.n_clusters,
                    n_iters=config.kmeans_iters, key=k_ivf,
                    capacity_mult=config.capacity_mult,
                    max_train_points=t_max)
    residuals = pts - ivf.centroids[ivf.labels]
    if n > t_max:  # subsampled PQ training: full-set Lloyd is O(N·E) per iter
        sub_idx = jax.random.choice(jax.random.fold_in(k_pq, 1), n,
                                    shape=(t_max,), replace=False)
        train_res = residuals[sub_idx]
    else:
        train_res = residuals
    codebook = train_codebook(train_res, n_entries=config.n_entries,
                              m=config.sub_dim, n_iters=config.kmeans_iters,
                              key=k_pq)
    codes = encode(residuals, codebook)                          # (N, S)
    # Padded per-cluster codes: pad slots read code 0 but are masked by valid.
    safe_ids = jnp.maximum(ivf.point_ids, 0)
    cluster_codes = codes[safe_ids]                              # (C, P, S)

    dens_model = _calibrate_density(pts, residuals, codebook, codes, ivf,
                                    config, k_cal)
    return JunoIndexData(ivf=ivf, codebook=codebook, codes=codes,
                         cluster_codes=cluster_codes, density=dens_model,
                         points_sq=jnp.sum(pts * pts, axis=-1))


def _calib_query_subspaces(queries, ivf, config):
    """Query-side subspace projections in the geometry the mask uses.

    For l2 the projection is the probe-0 residual (DESIGN.md §2); for ip
    the raw query. Returns (Qs, S, M) f32. Shared by the in-memory
    calibration below and the streaming pipeline (``repro.build``).
    """
    _, c1 = filter_clusters(queries, ivf, nprobe=1, metric=config.metric)
    if config.metric == "l2":
        qres = queries - ivf.centroids[c1[:, 0]]
        return split_subspaces(qres, config.sub_dim)             # (Qs, S, M)
    return split_subspaces(queries, config.sub_dim)


def _calib_tau_needed(qsub, gt_codes, codebook, metric):
    """Per-subspace threshold containing every top-k entry (paper §4.1).

    qsub (Qs, S, M), gt_codes (Qs, K, S) int32 — the PQ codes of each
    calibration query's exact top-k. Returns (Qs, S) f32: the transformed
    distance from the query's subspace projection that covers all K
    ground-truth entries. Shared by :func:`build` and ``repro.build``.
    """
    ent = codebook.entries                                       # (S, E, M)
    s_idx = jnp.arange(ent.shape[0])[None, None, :]
    gt_entries = ent[s_idx, gt_codes]                            # (Qs, K, S, M)
    if metric == "l2":
        diff = gt_entries - qsub[:, None, :, :]
        t = jnp.sum(diff * diff, axis=-1)                        # (Qs, K, S)
        return jnp.sqrt(jnp.max(t, axis=1))                      # (Qs, S)
    e_sq = jnp.sum(gt_entries * gt_entries, -1)
    dot = jnp.sum(gt_entries * qsub[:, None], -1)
    t = e_sq - 2.0 * dot
    return jnp.sqrt(jnp.maximum(jnp.max(t, axis=1), 0.0))


def _calibrate_density(pts, residuals, codebook, codes, ivf, config, key):
    """Fit density → threshold polynomial from ground-truth top-k (paper §4.1)."""
    n = pts.shape[0]
    nq = min(config.calib_queries, n)
    k_choice, k_noise = jax.random.split(key)
    qidx = jax.random.choice(k_choice, n, shape=(nq,), replace=False)
    # perturb so calibration queries are not exact database points
    noise = 0.01 * jax.random.normal(k_noise, (nq, pts.shape[1])) * jnp.std(pts)
    queries = pts[qidx] + noise.astype(jnp.float32)

    _, gt_ids = exact_topk(queries, pts, k=config.calib_topk,
                           metric=config.metric, chunk=min(65536, n))
    qsub = _calib_query_subspaces(queries, ivf, config)
    gt_codes = codes[gt_ids].astype(jnp.int32)                   # (Qs, K, S)
    tau_needed = _calib_tau_needed(qsub, gt_codes, codebook, config.metric)

    sub_pts = jnp.swapaxes(split_subspaces(residuals, config.sub_dim), 0, 1)
    return density_lib.calibrate(sub_pts, codebook.entries, qsub, tau_needed,
                                 grid_size=config.grid_size,
                                 degree=config.poly_degree)


def _rt_probe_mask(rt_grid, q, tau, cids, rt_scale, rt_offset):
    """Stage-1 spatial pruning: which probed clusters survive the RT test.

    Runs the sphere-intersection filter (``repro.rt``) with the radius
    derived from the probe-0 row of the thresholds ``tau`` the caller
    already computed, and gathers the (Q, C) survivor mask at the probed
    cluster ids (offset by ``rt_offset`` on a shard — the grid is global).
    Probe 0 is always kept (nearest-probe backstop), so a query whose
    sphere misses everything still degrades to a nprobe=1 search instead
    of returning sentinels. Returns (Q, nprobe) bool.
    """
    from repro import rt as rt_lib
    radius = rt_lib.query_radius(rt_grid, tau[:, 0, :], rt_scale)
    hits = rt_lib.survivor_mask(rt_grid, q, radius)          # (Q, C_global)
    gcids = cids if rt_offset is None else cids + rt_offset
    probe_ok = jnp.take_along_axis(hits, gcids, axis=1) > 0
    return probe_ok.at[:, 0].set(True)


def _score_probed(index: JunoIndexData, q: jnp.ndarray, base: jnp.ndarray,
                  cids: jnp.ndarray, codes: jnp.ndarray, valid: jnp.ndarray,
                  ids: jnp.ndarray, *, k: int, mode: str, metric: str,
                  thres_scale: float, impl: str = "ref",
                  side: SideBuffer | None = None, prefilter: str = "scan",
                  rt_grid=None, rt_scale: float = 1.0, rt_offset=None):
    """Stages B+C over an explicitly gathered probe set.

    The tail of :func:`_search_batch` with the stage-A cluster filter and
    the per-probe gathers hoisted out: ``base``/``cids`` (Q, np) come from
    :func:`~repro.core.ivf.filter_clusters`, and ``codes`` (Q, np, P, S) /
    ``valid`` (Q, np, P) / ``ids`` (Q, np, P) are the probed rows of
    ``cluster_codes`` / ``ivf.valid`` / ``ivf.point_ids`` — however the
    caller obtained them. The resident path gathers them on device;
    the paged backend (``repro.serve.paged``) gathers codes on the host
    through its cluster cache and feeds them in, so both paths share this
    scoring math verbatim. Only ``index.codebook`` and ``index.density``
    are read from ``index``. Returns (scores (Q, k), ids (Q, k)).
    """
    nq = q.shape[0]
    nprobe = cids.shape[1]
    m = index.codebook.sub_dim

    # --- stage B: selective LUT construction (the RT-core stage) ---------
    if metric == "l2":
        res = q[:, None, :] - index.ivf.centroids[cids]          # (Q, np, D)
        qsub = res.reshape(nq, nprobe, -1, m)                    # (Q, np, S, M)
        probe_base = jnp.zeros((nq, nprobe), jnp.float32)
    else:
        qsub = jnp.broadcast_to(
            q.reshape(nq, 1, -1, m), (nq, nprobe, q.shape[1] // m, m))
        probe_base = base                                        # <q, c_probe>
    tau = density_lib.predict_threshold(index.density, qsub, thres_scale)

    # --- stage C: distance calculation over the selected clusters --------
    if prefilter == "rt":
        probe_ok = _rt_probe_mask(rt_grid, q, tau, cids, rt_scale, rt_offset)
        valid = valid & probe_ok[..., None]

    if impl == "pallas":
        from repro.kernels import ops as kops
        mlut, table = kops.build_selective_lut(
            qsub, index.codebook.entries, index.codebook.entry_sq, tau,
            metric=metric)
        if mode == "H":
            pt_scores = kops.masked_adc_scan(mlut, codes, valid,
                                             metric=metric)
            if metric == "ip":
                pt_scores = pt_scores + probe_base[..., None]
            higher_better = metric == "ip"
        else:
            if mode == "L":  # plain count: clip penalty/inner to {0, 1}
                table = (table >= 0).astype(jnp.int8)
            pt_scores = kops.hit_count_scan(table, codes, valid
                                            ).astype(jnp.float32)
            higher_better = True
    elif mode == "H":
        lut, mask = lut_lib.build_lut(qsub, index.codebook, tau, metric=metric)
        mlut = lut_lib.masked_lut(lut, mask, tau, metric=metric)
        scan = jax.vmap(jax.vmap(
            lambda l, c, v: scan_lib.adc_scan(l, c, v, metric=metric)))
        pt_scores = scan(mlut, codes, valid)                     # (Q, np, P)
        if metric == "ip":
            pt_scores = pt_scores + probe_base[..., None]
        higher_better = metric == "ip"
    else:
        lut, mask = lut_lib.build_lut(qsub, index.codebook, tau, metric=metric)
        hc_mode = "count" if mode == "L" else "reward_penalty"
        if metric == "l2":
            table = lut_lib.hit_tables(lut, mask, tau, mode=hc_mode,
                                       metric="l2")
        else:
            table = lut_lib.hit_tables_ip(lut, index.codebook.entry_sq, tau,
                                          mode=hc_mode)
        scan = jax.vmap(jax.vmap(scan_lib.hit_count_scan))
        pt_scores = scan(table, codes, valid).astype(jnp.float32)
        higher_better = True

    flat_scores = pt_scores.reshape(nq, -1)
    flat_ids = ids.reshape(nq, -1)
    if side is not None:
        # overflow inserts: same per-probe table, same gather+sum, same
        # invalid sentinel — only reachable when the owning cluster is probed
        # AND (under prefilter="rt") only when that probe survives the
        # sphere test, exactly like its in-cluster siblings
        if mode == "H":
            tot, probe, ok = _side_gather(mlut, cids, side)
            if prefilter == "rt":
                ok = ok & jnp.take_along_axis(probe_ok, probe, axis=1)
            if metric == "ip":
                tot = tot + jnp.take_along_axis(probe_base, probe, axis=1)
            side_scores = jnp.where(ok, tot,
                                    -jnp.inf if higher_better else jnp.inf)
        else:
            tot, probe, ok = _side_gather(table.astype(jnp.int32), cids, side)
            if prefilter == "rt":
                ok = ok & jnp.take_along_axis(probe_ok, probe, axis=1)
            side_scores = jnp.where(ok, tot, jnp.int32(-(2 ** 30))
                                    ).astype(jnp.float32)
        flat_scores = jnp.concatenate([flat_scores, side_scores], axis=1)
        flat_ids = jnp.concatenate(
            [flat_ids, jnp.broadcast_to(side.ids[None], (nq, side.capacity))],
            axis=1)
    sel_scores, sel = jax.lax.top_k(
        flat_scores if higher_better else -flat_scores, k)
    out_ids = jnp.take_along_axis(flat_ids, sel, axis=1)
    out_scores = sel_scores if higher_better else -sel_scores
    return out_scores, out_ids


@functools.partial(jax.jit,
                   static_argnames=("nprobe", "k", "mode", "metric", "impl",
                                    "prefilter"))
def _search_batch(index: JunoIndexData, queries: jnp.ndarray, *, nprobe: int,
                  k: int, mode: str, metric: str, thres_scale: float,
                  impl: str = "ref", side: SideBuffer | None = None,
                  prefilter: str = "scan", rt_grid=None,
                  rt_scale: float = 1.0, rt_offset=None):
    """One jitted query batch. Returns (scores (Q,k), ids (Q,k)).

    impl="ref"    — pure-jnp reference path (semantics of record)
    impl="pallas" — fused Pallas kernels (TPU path; interpret=True on CPU)
    side          — optional overflow buffer of online inserts, merged into
                    the final top-k with in-cluster-identical scoring.
    prefilter     — "scan" (dense, every probed cluster scanned) or "rt"
                    (RT-core-style sphere-intersection pruning: probes
                    whose cluster disc the query sphere misses are masked
                    out of the scans; needs ``rt_grid``, see ``repro.rt``).
    """
    q = queries.astype(jnp.float32)

    # --- stage A: filtering (MXU GEMM + top-k), paper Fig. 1 bottom-left ---
    base, cids = filter_clusters(q, index.ivf, nprobe=nprobe, metric=metric)
    codes = index.cluster_codes[cids]                            # (Q, np, P, S)
    valid = index.ivf.valid[cids]                                # (Q, np, P)
    ids = index.ivf.point_ids[cids]                              # (Q, np, P)
    return _score_probed(index, q, base, cids, codes, valid, ids, k=k,
                         mode=mode, metric=metric, thres_scale=thres_scale,
                         impl=impl, side=side, prefilter=prefilter,
                         rt_grid=rt_grid, rt_scale=rt_scale,
                         rt_offset=rt_offset)


def _score_probed_two_stage(index: JunoIndexData, q: jnp.ndarray,
                            base: jnp.ndarray, cids: jnp.ndarray,
                            codes: jnp.ndarray, valid: jnp.ndarray,
                            ids: jnp.ndarray, *, k: int, metric: str,
                            thres_scale: float, rerank: int = 0,
                            impl: str = "ref", fused: bool = False,
                            fused3: bool | None = None,
                            side: SideBuffer | None = None,
                            prefilter: str = "scan", rt_grid=None,
                            rt_scale: float = 1.0, rt_offset=None):
    """Mode "H2": int8 hit-count prefilter → exact ADC on top-C survivors.

    Beyond-paper: converts JUNO's dynamic skip into a static-shape candidate
    set so the expensive f32 gather/accumulate runs on C = rerank points
    instead of nprobe·P (see module docstring).

    ``fused=True`` routes both stages through
    ``kernels.ops.fused_two_stage_scan`` — one kernel computes the hit
    counts, applies the survivor threshold in-kernel and emits the compacted
    top-C candidates WITH their masked-ADC distances, so this function does
    no wide top-k and no separate rerank gather. Candidate selection is the
    same top-C-by-count rule, so fused and composed return identical ids
    (tests/test_impl_parity.py). Orthogonal to ``impl``, which picks who
    builds the LUT/hit tables.

    When ``fused=True`` meets ``prefilter="rt"``, the RT sphere test ALSO
    folds in — ``kernels.ops.fused_three_stage_scan`` runs the sphere
    walk, the hit-count prefilter and the masked ADC in one residency, and
    its ``probe_ok`` output replaces the separate :func:`_rt_probe_mask`
    round trip (bit-identical by construction; the kernel gathers the same
    ``slot_of`` verdicts in-register). ``fused3=False`` forces the
    composed rt+fused path (parity baseline); ``None``/``True`` take the
    three-stage kernel whenever it applies.

    Like :func:`_score_probed`, this is the post-gather tail of
    :func:`_search_batch_two_stage`: ``base``/``cids``/``codes``/``valid``/
    ``ids`` arrive pre-gathered so the resident and paged
    (``repro.serve.paged``) backends share the scoring math verbatim.
    """
    nq = q.shape[0]
    nprobe = cids.shape[1]
    m = index.codebook.sub_dim
    c_budget = rerank or 4 * k

    if metric == "l2":
        res = q[:, None, :] - index.ivf.centroids[cids]
        qsub = res.reshape(nq, nprobe, -1, m)
        probe_base = jnp.zeros((nq, nprobe), jnp.float32)
    else:
        qsub = jnp.broadcast_to(
            q.reshape(nq, 1, -1, m), (nq, nprobe, q.shape[1] // m, m))
        probe_base = base
    tau = density_lib.predict_threshold(index.density, qsub, thres_scale)

    use_fused3 = fused and prefilter == "rt" and fused3 is not False
    if prefilter == "rt" and not use_fused3:
        probe_ok = _rt_probe_mask(rt_grid, q, tau, cids, rt_scale, rt_offset)
        valid = valid & probe_ok[..., None]

    from repro.kernels import ops as kops
    if impl == "pallas":
        mlut, table = kops.build_selective_lut(
            qsub, index.codebook.entries, index.codebook.entry_sq, tau,
            metric=metric)
    else:
        lut, mask = lut_lib.build_lut(qsub, index.codebook, tau,
                                      metric=metric)
        mlut = lut_lib.masked_lut(lut, mask, tau, metric=metric)
        if metric == "l2":
            table = lut_lib.hit_tables(lut, mask, tau, mode="reward_penalty",
                                       metric="l2")
        else:
            table = lut_lib.hit_tables_ip(lut, index.codebook.entry_sq, tau,
                                          mode="reward_penalty")

    p = codes.shape[2]
    cap = min(c_budget, nprobe * p)
    if use_fused3:
        # all three stages in one residency: the kernel runs the sphere
        # walk over the grid cells, masks the probes in-register (same
        # slot_of verdicts _rt_probe_mask would gather, probe 0
        # backstopped), then counts, thresholds and compacts as the fused
        # two-stage scan does — no HBM hit table, no host mask round trip
        from repro import rt as rt_lib
        radius = rt_lib.query_radius(rt_grid, tau[:, 0, :], rt_scale)
        qp2 = q @ rt_grid.proj                                   # (Q, 2)
        gcids = cids if rt_offset is None else cids + rt_offset
        slot_idx = jnp.take(rt_grid.slot_of, gcids)              # (Q, np)
        _, _, cand, exact, probe_ok = kops.fused_three_stage_scan(
            mlut, table, codes, valid, qp2[:, 0], qp2[:, 1], radius,
            rt_grid.boxes, rt_grid.cell_reach, rt_grid.cell_c0,
            rt_grid.cell_c1, rt_grid.slot_reach, slot_idx,
            cap_c=cap, metric=metric)
        valid = valid & probe_ok[..., None]
        cand_probe = cand // p                                   # (Q, C)
        cand_valid = jnp.take_along_axis(valid.reshape(nq, -1), cand, axis=1)
        cand_ids = jnp.take_along_axis(ids.reshape(nq, -1), cand, axis=1)
    elif fused:
        # both stages in one fused scan: counts, in-kernel survivor
        # threshold, compacted top-C candidates + their ADC totals
        _, _, cand, exact = kops.fused_two_stage_scan(
            mlut, table, codes, valid, cap_c=cap, metric=metric)
        cand_probe = cand // p                                   # (Q, C)
        cand_valid = jnp.take_along_axis(valid.reshape(nq, -1), cand, axis=1)
        cand_ids = jnp.take_along_axis(ids.reshape(nq, -1), cand, axis=1)
    else:
        if impl == "pallas":
            counts = kops.hit_count_scan(table, codes, valid)
        else:
            counts = jax.vmap(jax.vmap(scan_lib.hit_count_scan))(table, codes,
                                                                 valid)

        # stage 1: top-C candidates by hit count (int32, cheap)
        flat_counts = counts.reshape(nq, -1)
        _, cand = jax.lax.top_k(flat_counts, cap)
        cand_probe = cand // p                                   # (Q, C)

        # stage 2: exact ADC only on survivors
        cand_codes = jnp.take_along_axis(
            codes.reshape(nq, -1, codes.shape[-1]), cand[..., None], axis=1)
        s_idx = jnp.arange(mlut.shape[2])[None, None, :]
        vals = mlut[jnp.arange(nq)[:, None, None], cand_probe[..., None],
                    s_idx, cand_codes.astype(jnp.int32)]         # (Q, C, S)
        exact = jnp.sum(vals, axis=-1)
        cand_valid = jnp.take_along_axis(valid.reshape(nq, -1), cand, axis=1)
        cand_ids = jnp.take_along_axis(ids.reshape(nq, -1), cand, axis=1)
    if metric == "ip":
        exact = exact + jnp.take_along_axis(probe_base, cand_probe, axis=1)
    if side is not None:
        # side points bypass stage 1 (the buffer is tiny) and join the exact
        # rerank pool directly, scored identically to in-cluster survivors —
        # including (under prefilter="rt") the probe's sphere-test verdict
        tot, probe, ok = _side_gather(mlut, cids, side)
        if prefilter == "rt":
            ok = ok & jnp.take_along_axis(probe_ok, probe, axis=1)
        if metric == "ip":
            tot = tot + jnp.take_along_axis(probe_base, probe, axis=1)
        exact = jnp.concatenate(
            [exact, jnp.where(ok, tot, -jnp.inf if metric == "ip" else jnp.inf)],
            axis=1)
        cand_valid = jnp.concatenate([cand_valid, ok], axis=1)
        cand_ids = jnp.concatenate(
            [cand_ids, jnp.broadcast_to(side.ids[None], (nq, side.capacity))],
            axis=1)
    if metric == "ip":
        exact = jnp.where(cand_valid, exact, -jnp.inf)
        sel_s, sel = jax.lax.top_k(exact, k)
        out_scores = sel_s
    else:
        exact = jnp.where(cand_valid, exact, jnp.inf)
        sel_s, sel = jax.lax.top_k(-exact, k)
        out_scores = -sel_s
    out_ids = jnp.take_along_axis(cand_ids, sel, axis=1)
    return out_scores, out_ids


@functools.partial(jax.jit, static_argnames=("nprobe", "k", "metric", "impl",
                                             "rerank", "fused", "fused3",
                                             "prefilter"))
def _search_batch_two_stage(index: JunoIndexData, queries: jnp.ndarray, *,
                            nprobe: int, k: int, metric: str,
                            thres_scale: float, rerank: int = 0,
                            impl: str = "ref", fused: bool = False,
                            fused3: bool | None = None,
                            side: SideBuffer | None = None,
                            prefilter: str = "scan", rt_grid=None,
                            rt_scale: float = 1.0, rt_offset=None):
    """Mode "H2" entry point: stage-A filter + gathers, then the shared
    two-stage scoring tail (:func:`_score_probed_two_stage`). Returns
    (scores (Q, k), ids (Q, k)); see the tail's docstring for the fused
    and composed candidate-selection semantics.
    """
    q = queries.astype(jnp.float32)
    base, cids = filter_clusters(q, index.ivf, nprobe=nprobe, metric=metric)
    codes = index.cluster_codes[cids]                            # (Q,np,P,S)
    valid = index.ivf.valid[cids]
    ids = index.ivf.point_ids[cids]
    return _score_probed_two_stage(
        index, q, base, cids, codes, valid, ids, k=k, metric=metric,
        thres_scale=thres_scale, rerank=rerank, impl=impl, fused=fused,
        fused3=fused3, side=side, prefilter=prefilter, rt_grid=rt_grid,
        rt_scale=rt_scale, rt_offset=rt_offset)


def search(index: JunoIndexData, queries: jnp.ndarray, *, nprobe: int = 16,
           k: int = 100, mode: str = "H", metric: str = "l2",
           thres_scale: float = 1.0, batch: int = 64, impl: str = "ref",
           rerank: int = 0, fused: bool = False, fused3: bool | None = None,
           side: SideBuffer | None = None, prefilter: str = "scan",
           rt_grid=None, rt_scale: float = 1.0):
    """Search the index — the public online API (paper Alg. 2).

    Chunks queries through the jitted batch kernels, padding the last
    chunk with edge-replicated rows (in-distribution work whose results
    are sliced off).

    Parameters
    ----------
    index : JunoIndexData
        A built index (:func:`build`).
    queries : jnp.ndarray
        (Q, D) f32 query vectors.
    nprobe : int
        Clusters probed per query (stage-A budget).
    k : int
        Results per query.
    mode : str
        Operating point — "H" (exact selective distances), "M"
        (reward/penalty hit count), "L" (plain hit count) or "H2"
        (two-stage hit-count prefilter → exact rerank).
    metric : str
        "l2" | "ip".
    thres_scale : float
        Multiplier on the calibrated selectivity thresholds τ.
    batch : int
        Queries per jitted call (one compiled program per distinct batch).
    impl : str
        "ref" (pure-jnp semantics of record) or "pallas" (TPU kernels;
        interpret mode off-TPU).
    rerank : int
        Mode "H2" stage-2 candidate budget C (0 → ``4 * k``).
    fused : bool
        Mode "H2" only: serve both stages through the fused
        hit-count→masked-ADC kernel path; top-k ids are identical to the
        composed path (see ``_search_batch_two_stage``). Combined with
        ``prefilter="rt"`` this dispatches the single-residency
        three-stage kernel (RT walk folded in as stage 0) unless
        ``fused3=False``.
    fused3 : bool, optional
        Three-stage dispatch override. ``None`` (default) auto-selects it
        whenever ``fused=True`` and ``prefilter="rt"``; ``False`` forces
        the composed rt-mask + two-stage path (bit-identical results —
        this is the parity baseline); ``True`` additionally validates
        that the combination actually applies.
    side : SideBuffer, optional
        Overflow buffer of online inserts, merged into the final top-k
        with in-cluster-identical scoring.
    prefilter : str
        "scan" (default — every probed cluster is scanned) or "rt"
        (RT-core-style sphere-intersection pruning, ``repro.rt``: probes
        whose cluster disc the query sphere misses are masked out ahead
        of the hit-count / masked-ADC scans; at full-coverage radii the
        results are identical to "scan").
    rt_grid : repro.rt.CentroidGrid, optional
        The spatial index required by ``prefilter="rt"``
        (``rt.build_grid``).
    rt_scale : float
        Query-sphere radius knob for "rt" (monotone: larger ⇒ more
        survivors; very large values reproduce "scan" exactly).

    Returns
    -------
    tuple of jnp.ndarray
        ``(scores (Q, k) f32, ids (Q, k) int32)``; scores are distances
        (lower better) for l2 H/H2, similarities/counts (higher better)
        otherwise.
    """
    if fused and mode != "H2":
        raise ValueError(f"fused=True requires mode='H2', got mode={mode!r}")
    if prefilter not in ("scan", "rt"):
        raise ValueError(f"unknown prefilter {prefilter!r}")
    if prefilter == "rt" and rt_grid is None:
        raise ValueError("prefilter='rt' requires rt_grid (rt.build_grid)")
    if fused3 and not (fused and prefilter == "rt"):
        raise ValueError("fused3=True requires fused=True and "
                         "prefilter='rt' (the three-stage kernel folds the "
                         "RT walk into the fused scan)")
    rt_kw = dict(prefilter=prefilter, rt_grid=rt_grid, rt_scale=rt_scale)
    nq = queries.shape[0]
    out_s, out_i = [], []
    for i in range(0, nq, batch):
        qb = queries[i:i + batch]
        pad = batch - qb.shape[0]
        if pad:
            # replicate the last real query instead of zero-padding: a zero
            # row is out-of-distribution garbage work and, under metric="ip",
            # degenerate (every score 0) — edge rows are real queries whose
            # results we slice off anyway.
            qb = jnp.pad(qb, ((0, pad), (0, 0)), mode="edge")
        if mode == "H2":
            s, ids = _search_batch_two_stage(
                index, qb, nprobe=nprobe, k=k, metric=metric,
                thres_scale=thres_scale, rerank=rerank, impl=impl,
                fused=fused, fused3=fused3, side=side, **rt_kw)
        else:
            s, ids = _search_batch(index, qb, nprobe=nprobe, k=k, mode=mode,
                                   metric=metric, thres_scale=thres_scale,
                                   impl=impl, side=side, **rt_kw)
        out_s.append(s[:batch - pad])
        out_i.append(ids[:batch - pad])
    return jnp.concatenate(out_s), jnp.concatenate(out_i)


@jax.jit
def _label_encode(pts: jnp.ndarray, centroids: jnp.ndarray,
                  codebook: PQCodebook) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Insert-time (labels, codes) for a small batch, fully under one jit.

    ``kmeans.assign`` is an eager ``lax.map`` pipeline tuned for N≫chunk
    offline builds; per-insert it would pay ~50ms of retracing for a
    microseconds-sized matmul. Insert batches are small, so the dense
    distance matrix is the right shape here.
    """
    d = (jnp.sum(centroids * centroids, -1)[None, :]
         - 2.0 * pts @ centroids.T)
    labels = jnp.argmin(d, axis=-1).astype(jnp.int32)
    return labels, encode(pts - centroids[labels], codebook)




class MutableIndexBase:
    """Host-side slot bookkeeping shared by the single-device and sharded
    mutable indices (`MutableJunoIndex`, `dist.DistributedMutableIndex`).

    The control plane is identical in both: per-cluster free-slot lists, an
    id → (cluster, slot) map (cluster −1 = side-buffer position), and a
    plan-then-commit discipline so a failing ``insert``/``delete`` raises
    BEFORE any state — host or device — has been touched. Subclasses supply
    the data plane via ``_labels_codes`` (insert-time encoding) and
    ``_apply_insert``/``_apply_delete`` (device scatters).
    """

    side: SideBuffer

    def _init_bookkeeping(self, ivf_valid, point_ids, *, side_capacity: int,
                          first_new_id: int, n_subspaces: int) -> None:
        valid = np.asarray(ivf_valid)
        pids = np.asarray(point_ids)
        n_clusters = valid.shape[0]
        self.side = empty_side_buffer(side_capacity, n_subspaces)
        self._free = [list(np.where(~valid[c])[0][::-1])
                      for c in range(n_clusters)]
        #: id -> (cluster, slot); cluster == -1 means side-buffer position
        self._loc: dict[int, tuple[int, int]] = {}
        for c in range(n_clusters):
            for slot in np.where(valid[c])[0]:
                self._loc[int(pids[c, slot])] = (c, int(slot))
        self._side_free = list(range(side_capacity))[::-1]
        self._next_id = first_new_id
        # LSM delta tiers (repro.core.freshness). A swap_data rederives the
        # base bookkeeping but keeps the tier CONFIGURATION (a rebuild
        # already folded the old tiers' points into the new base); the
        # generation counter and rt mutation counter are monotone across
        # swaps so stale cached views/budgets can never alias a new
        # generation's state.
        self._minors: list = []
        self._max_minors: int = getattr(self, "_max_minors", 0)
        self._minor_gen: int = getattr(self, "_minor_gen", 0)
        self._delta_epoch: int = getattr(self, "_delta_epoch", 0) + 1
        self._delta_cache: tuple[int, SideBuffer] | None = None
        self._rt_muts: int = getattr(self, "_rt_muts", -1) + 1

    # ---- data-plane hooks (subclass responsibility) ----------------------
    def _labels_codes(self, pts: jnp.ndarray):
        raise NotImplementedError

    def _rt_centroids(self) -> jnp.ndarray:
        """(C, D) replicated centroids for rt-grid maintenance."""
        raise NotImplementedError

    def _rt_on_insert(self, pts: jnp.ndarray, labels: np.ndarray) -> None:
        """Post-insert spatial-index maintenance (shared by subclasses).

        Called once per committed insert batch with the raw points and
        their owning clusters; when an ``repro.rt`` grid is attached
        (``self.rt_grid``), grows the touched clusters' projected reaches
        so the sphere filter never drops a cluster holding a fresh point.
        Always bumps :attr:`rt_mutations` (even gridless) so engine-side
        routing caches keyed on it can never serve a pre-insert probe
        budget to a post-insert index state.
        """
        self._rt_muts += 1
        if getattr(self, "rt_grid", None) is None:
            return
        from repro import rt as rt_lib
        res = (np.asarray(pts, np.float32)
               - np.asarray(self._rt_centroids())[labels])
        rp = res @ np.asarray(self.rt_grid.proj)
        self.rt_grid = rt_lib.update_radii(
            self.rt_grid, labels, np.sqrt(np.sum(rp * rp, axis=-1)))

    def _apply_insert(self, cl: list[int], sl: list[int], ids: np.ndarray,
                      codes: jnp.ndarray) -> None:
        raise NotImplementedError

    def _apply_delete(self, cl: list[int], sl: list[int]) -> None:
        raise NotImplementedError

    # ---- introspection ---------------------------------------------------
    @property
    def n_live(self) -> int:
        """Number of live (non-tombstoned) points in the index."""
        return len(self._loc)

    @property
    def side_fill(self) -> int:
        """Number of occupied side-buffer slots."""
        return self.side.capacity - len(self._side_free)

    def free_slots(self, cluster: int) -> int:
        """Free padded slots remaining in ``cluster``."""
        return len(self._free[cluster])

    @property
    def rt_mutations(self) -> int:
        """Monotone counter of rt-relevant mutations (insert batches and
        generation swaps); engine routing caches key on it to invalidate
        stale probe budgets."""
        return self._rt_muts

    # ---- LSM delta tiers (repro.core.freshness) --------------------------
    def enable_tiers(self, max_minors: int, *, minor_store=None,
                     minor_name: str = "minors") -> None:
        """Turn on the LSM freshness tiers (see ``repro.core.freshness``).

        With ``max_minors > 0`` a full L0 side buffer no longer makes
        ``insert`` raise: it is promoted into a sealed, PQ-encoded minor
        generation (up to ``max_minors`` of them) that a
        ``MergeScheduler`` folds back into the base incrementally.

        Parameters
        ----------
        max_minors : int
            Maximum concurrent minor generations (0 disables tiering —
            the legacy single-SideBuffer behavior).
        minor_store : repro.build.store.ArtifactStore, optional
            When given, promoted generations are committed through the
            store and demand-paged back on first search touch with
            per-row sha256 verification (the paged tier's contract).
        minor_name : str
            Artifact name minors are committed under.
        """
        self._max_minors = int(max_minors)
        if minor_store is not None:
            self._minor_sink = (minor_store, minor_name)
        self._delta_cache = None
        self._delta_epoch += 1

    @property
    def delta_fill(self) -> int:
        """Live points across all delta tiers (L0 + minor generations)."""
        return self.side_fill + sum(m.live for m in self._minors)

    def delta_view(self, *, elide_empty: bool = True):
        """The delta tiers as ONE fixed-capacity :class:`SideBuffer`.

        With tiering disabled this is exactly the legacy side buffer
        (None when empty, so the no-spill hot path keeps its
        side-elided jit signature). With tiering enabled, L0 and every
        minor generation are concatenated — padded to the constant
        capacity ``B * (1 + max_minors)`` so merge cycles never change
        the jitted search signature — and cached until the next tier
        mutation.

        Parameters
        ----------
        elide_empty : bool
            Return None when every tier is empty (default). The sharded
            serve path passes False: its compiled dispatch always takes
            a side argument.

        Returns
        -------
        SideBuffer or None
            The combined delta view.
        """
        if self._max_minors <= 0:
            if elide_empty and self.side_fill == 0:
                return None
            return self.side
        if elide_empty and self.side_fill == 0 and not self._minors:
            return None
        if (self._delta_cache is None
                or self._delta_cache[0] != self._delta_epoch):
            from .freshness import combined_delta
            self._delta_cache = (
                self._delta_epoch,
                combined_delta(self.side, self._minors, self._max_minors))
        return self._delta_cache[1]

    def delta_snapshot(self) -> tuple[np.ndarray, np.ndarray, np.ndarray,
                                      np.ndarray]:
        """Host-side ``(valid, cluster, ids, codes)`` over L0 + minors.

        The unpadded concatenation of every delta tier's slots, used by
        ``build.rebuild.live_points`` so an offline rebuild folds minor
        generations in exactly like side-buffer points.
        """
        valid = [np.asarray(self.side.valid)]
        cluster = [np.asarray(self.side.cluster)]
        ids = [np.asarray(self.side.ids)]
        codes = [np.asarray(self.side.codes)]
        for m in self._minors:
            valid.append(m.valid)
            cluster.append(m.cluster)
            ids.append(m.ids)
            codes.append(np.asarray(m.materialize()))
        return (np.concatenate(valid), np.concatenate(cluster),
                np.concatenate(ids), np.concatenate(codes))

    # ---- mutation --------------------------------------------------------
    def _placement_fits(self, labels: np.ndarray, side_slots: int) -> bool:
        """Whether a batch with these owning clusters is placeable, given
        ``side_slots`` free L0 positions. Pure feasibility check — reads
        the free lists, mutates nothing."""
        cs, counts = np.unique(labels, return_counts=True)
        spill = sum(max(0, int(n) - len(self._free[int(c)]))
                    for c, n in zip(cs, counts))
        return spill <= side_slots

    def insert(self, points) -> list[int]:
        """Insert a (B, D) batch; returns the assigned global ids.

        Raises RuntimeError (before mutating anything) if the batch cannot
        be placed — i.e. some owning cluster is full AND the delta tier
        cannot absorb the remainder; call ``compact()`` or build with a
        larger ``side_capacity``. With the LSM tiers enabled
        (:meth:`enable_tiers`) a full L0 is first promoted into a minor
        generation — but only when the batch provably fits afterwards, so
        a failing insert still mutates nothing. The commit itself is
        device-plane-first: every device update is a functional replace,
        so a failing subclass scatter (device OOM, a sealed paged shard)
        also leaves ALL state untouched; the infallible host bookkeeping
        runs last.
        """
        pts = jnp.atleast_2d(jnp.asarray(points, jnp.float32))
        labels, codes = self._labels_codes(pts)                  # (B,), (B, S)
        labels = np.asarray(labels)

        # feasibility first (no mutation). When the batch overflows, an
        # L0→minor promotion may free the whole side buffer — taken only
        # when the retry provably fits, keeping insert all-or-nothing.
        if not self._placement_fits(labels, len(self._side_free)):
            if (self._max_minors > 0 and self.side_fill > 0
                    and len(self._minors) < self._max_minors
                    and self._placement_fits(labels, self.side.capacity)):
                from .freshness import promote_l0
                promote_l0(self)
            else:
                raise RuntimeError(
                    "insert batch does not fit: cluster padding and side "
                    "buffer exhausted — call compact() or raise side_capacity")

        # plan (no mutation yet) — per-cluster free slots, then side buffer
        taken: dict[int, int] = {}
        side_need = 0
        placements: list[tuple[int, int]] = []   # (cluster, slot) | (-1, pos)
        for c in labels:
            c = int(c)
            used = taken.get(c, 0)
            if used < len(self._free[c]):
                # plan reads slots from the free lists' tails in order, so
                # the commit below can drop the tails in O(1)
                placements.append((c, self._free[c][-1 - used]))
                taken[c] = used + 1
            elif side_need < len(self._side_free):
                placements.append((-1, self._side_free[-1 - side_need]))
                side_need += 1
            else:                                # unreachable after _fits
                raise RuntimeError(
                    "insert batch does not fit: cluster padding and side "
                    "buffer exhausted — call compact() or raise side_capacity")

        new_ids = list(range(self._next_id, self._next_id + pts.shape[0]))
        ids_np = np.asarray(new_ids, np.int32)
        cl, sl, sel, s_pos, s_sel = [], [], [], [], []
        for i, (c, slot) in enumerate(placements):
            if c >= 0:
                cl.append(c)
                sl.append(slot)
                sel.append(i)
            else:
                s_pos.append(slot)
                s_sel.append(i)

        # commit: fallible device planes first, as functional replaces …
        new_side = None
        if s_pos:
            pos_j, sel_j = jnp.asarray(s_pos), jnp.asarray(s_sel)
            new_side = self.side._replace(
                codes=self.side.codes.at[pos_j].set(codes[sel_j]),
                cluster=self.side.cluster.at[pos_j].set(
                    jnp.asarray(labels[s_sel], jnp.int32)),
                ids=self.side.ids.at[pos_j].set(jnp.asarray(ids_np[s_sel])),
                valid=self.side.valid.at[pos_j].set(True))
        if cl:
            self._apply_insert(cl, sl, ids_np[sel], codes[jnp.asarray(sel)])
        # … then the infallible host bookkeeping
        if new_side is not None:
            self.side = new_side
        for c, cnt in taken.items():
            del self._free[c][-cnt:]
        if side_need:
            del self._side_free[-side_need:]
        for i, (c, slot) in enumerate(placements):
            self._loc[new_ids[i]] = (c, slot) if c >= 0 else (-1, slot)
        self._next_id += pts.shape[0]
        if s_pos:
            self._delta_epoch += 1
        self._rt_on_insert(pts, labels)
        return new_ids

    def delete(self, ids) -> int:
        """Tombstone points by global id. Freed cluster slots become insert
        targets; no data movement. An unknown/already-deleted/duplicated id
        raises KeyError BEFORE any state is touched (all-or-nothing).
        Points living in a minor generation (cluster code ≤ −2 in the
        location map) are tombstoned in that generation's host valid mask;
        a generation emptied this way is dropped."""
        pids = [int(p) for p in np.atleast_1d(np.asarray(ids, np.int64))]
        if len(set(pids)) != len(pids):
            raise KeyError(f"duplicate ids in delete batch: {pids}")
        locs = [self._loc[p] for p in pids]      # KeyError = unknown id
        cl, sl, s_pos = [], [], []
        m_pos: dict[int, list[int]] = {}         # minor gen -> positions
        for c, slot in locs:
            if c >= 0:
                cl.append(c)
                sl.append(slot)
            elif c == -1:
                s_pos.append(slot)
            else:
                m_pos.setdefault(-2 - c, []).append(slot)
        # fallible device planes first (functional replaces) …
        if cl:
            self._apply_delete(cl, sl)
        if s_pos:
            self.side = self.side._replace(
                valid=self.side.valid.at[jnp.asarray(s_pos)].set(False))
        # … then the infallible host bookkeeping
        if m_pos:
            by_gen = {m.gen: m for m in self._minors}
            for g, poss in m_pos.items():
                by_gen[g].valid[np.asarray(poss)] = False
            self._minors = [m for m in self._minors if m.live]
        for pid in pids:
            del self._loc[pid]
        for c, slot in locs:
            if c >= 0:
                self._free[c].append(slot)
            elif c == -1:
                self._side_free.append(slot)
        if s_pos or m_pos:
            self._delta_epoch += 1
        return len(pids)

    def compact(self) -> int:
        """Fold side-buffer points into freed slots of their owning cluster.
        Returns how many points moved; points whose cluster is still full
        stay in the buffer. Search results are unchanged (same scoring).

        The plan is built vectorized (one stable argsort groups side
        positions by owning cluster; each cluster donates its free-list
        tail) and validated BEFORE anything mutates: a duplicated free
        slot (double-free corruption) or a fold targeting a position
        already back on ``_side_free`` (reused-slot aliasing) raises
        RuntimeError with all state — host and device — untouched,
        instead of silently overwriting a live slot. Commit ordering is
        device-first / host-last, like ``insert``.
        """
        side_valid = np.asarray(self.side.valid)
        side_cluster = np.asarray(self.side.cluster)
        side_ids = np.asarray(self.side.ids)
        pos_all = np.where(side_valid)[0]
        if pos_all.size == 0:
            return 0
        order = np.argsort(side_cluster[pos_all], kind="stable")
        pos_sorted = pos_all[order]
        cs, starts, counts = np.unique(side_cluster[pos_sorted],
                                       return_index=True, return_counts=True)
        cl: list[int] = []
        sl: list[int] = []
        pos_l: list[int] = []
        plan: list[tuple[int, int]] = []         # (cluster, take)
        for c, st, n in zip(cs, starts, counts):
            c = int(c)
            take = min(int(n), len(self._free[c]))
            if not take:
                continue
            slots = self._free[c][-take:][::-1]
            cl += [c] * take
            sl += [int(s) for s in slots]
            pos_l += [int(p) for p in pos_sorted[st:st + take]]
            plan.append((c, take))
        if not pos_l:
            return 0
        # fail-closed plan validation (before ANY mutation)
        if len(set(zip(cl, sl))) != len(sl):
            raise RuntimeError(
                "compact plan references a cluster slot twice (corrupted "
                "free list / double-free); refusing to fold")
        if set(pos_l) & set(self._side_free):
            raise RuntimeError(
                "compact plan folds a side position already on the free "
                "list (reused-slot aliasing); refusing to fold")
        # fallible device planes first …
        pos_j = jnp.asarray(pos_l)
        self._apply_insert(cl, sl, side_ids[pos_l].astype(np.int32),
                           self.side.codes[pos_j])
        self.side = self.side._replace(
            valid=self.side.valid.at[pos_j].set(False))
        # … then the infallible host bookkeeping
        for c, take in plan:
            del self._free[c][-take:]
        for c, slot, pos in zip(cl, sl, pos_l):
            self._loc[int(side_ids[pos])] = (c, slot)
        self._side_free.extend(pos_l)
        self._delta_epoch += 1
        return len(pos_l)


class MutableJunoIndex(MutableIndexBase):
    """Online-mutable wrapper over a built :class:`JunoIndexData`.

    ``insert`` encodes new points with the EXISTING codebooks (no
    retraining) and appends them into free padded slots of their owning
    cluster; when a cluster's padding is exhausted the point spills into a
    fixed-capacity :class:`SideBuffer`. ``delete`` tombstones points via the
    ``valid`` mask. Neither touches the search hot path's shapes, so all
    jitted search signatures stay warm. ``compact()`` folds side-buffer
    points back into cluster slots freed by deletes — a search no-op by
    construction (side points are scored with the identical gather an
    in-cluster point gets).

    An optional :class:`repro.rt.CentroidGrid` rides along for
    ``search(prefilter="rt")`` (attach one, or let ``ensure_rt_grid``
    build it lazily); inserts keep it valid by growing the touched
    clusters' reaches — cell membership never changes because centroids
    never move — and deletes leave it alone (a stale larger reach only
    over-covers).
    """

    def __init__(self, data: JunoIndexData, *, side_capacity: int = 256,
                 rt_grid=None):
        self.data = data
        self.rt_grid = rt_grid
        self._init_bookkeeping(data.ivf.valid, data.ivf.point_ids,
                               side_capacity=side_capacity,
                               first_new_id=int(data.codes.shape[0]),
                               n_subspaces=int(data.codes.shape[1]))

    def _labels_codes(self, pts):
        return _label_encode(pts, self.data.ivf.centroids, self.data.codebook)

    # ---- hot swap --------------------------------------------------------
    def swap_data(self, new_data: JunoIndexData, *,
                  side_capacity: int | None = None) -> None:
        """Atomically install a rebuilt :class:`JunoIndexData`.

        The new index replaces the served one in a single assignment, the
        slot bookkeeping (free lists, id → location map) is rederived from
        its ``point_ids``/``valid`` arrays, the side buffer is reset to
        empty (a rebuild drains it into proper cluster slots — see
        ``repro.build.rebuild``), and the id counter is preserved so ids
        never repeat across generations. Any attached rt grid is dropped;
        it is rebuilt lazily on the next ``prefilter="rt"`` search
        (:meth:`ensure_rt_grid`).

        Parameters
        ----------
        new_data : JunoIndexData
            The replacement index. Point ids must already be global (a
            rebuild keeps them; see ``repro.build.rebuild.rebuild_index``).
        side_capacity : int, optional
            Capacity of the fresh side buffer (default: keep the current
            buffer's capacity).
        """
        first_new = max(
            self._next_id,
            int(np.asarray(new_data.ivf.point_ids).max(initial=-1)) + 1)
        self.data = new_data
        self.rt_grid = None
        self._init_bookkeeping(
            new_data.ivf.valid, new_data.ivf.point_ids,
            side_capacity=(self.side.capacity if side_capacity is None
                           else side_capacity),
            first_new_id=first_new,
            n_subspaces=int(new_data.codes.shape[1]))

    # ---- RT prefilter grid ----------------------------------------------
    def ensure_rt_grid(self, *, metric: str = "l2", **kw):
        """Build and attach the ``repro.rt`` centroid grid if absent.

        Parameters
        ----------
        metric : str
            "l2" | "ip" — forwarded to ``rt.build_grid`` calibration.
        **kw
            Remaining ``rt.build_grid`` keyword arguments.

        Returns
        -------
        repro.rt.CentroidGrid
            The attached grid.
        """
        if self.rt_grid is None:
            from repro import rt as rt_lib
            self.rt_grid = rt_lib.build_grid(self.data, metric=metric, **kw)
        return self.rt_grid

    def _rt_centroids(self):
        """Centroids for rt-grid maintenance (the index's own)."""
        return self.data.ivf.centroids

    def _apply_insert(self, cl, sl, ids, codes):
        cl_j, sl_j = jnp.asarray(cl), jnp.asarray(sl)
        ivf = self.data.ivf._replace(
            point_ids=self.data.ivf.point_ids.at[cl_j, sl_j].set(
                jnp.asarray(ids)),
            valid=self.data.ivf.valid.at[cl_j, sl_j].set(True))
        self.data = self.data._replace(
            ivf=ivf,
            cluster_codes=self.data.cluster_codes.at[cl_j, sl_j].set(codes))

    def _apply_delete(self, cl, sl):
        ivf = self.data.ivf._replace(
            valid=self.data.ivf.valid.at[jnp.asarray(cl),
                                         jnp.asarray(sl)].set(False))
        self.data = self.data._replace(ivf=ivf)

    # ---- query -----------------------------------------------------------
    def search(self, queries, *, prefilter: str = "scan", **kw):
        """Side-buffer-aware :func:`search` over the current index state.

        An empty side buffer is elided so the no-spill hot path compiles
        and runs exactly as the immutable index's. ``prefilter="rt"``
        routes stage 1 through the sphere-intersection filter, lazily
        building the grid on first use (``ensure_rt_grid``).

        Parameters
        ----------
        queries : jnp.ndarray
            (Q, D) f32 query vectors.
        prefilter : str
            "scan" | "rt" — see :func:`search`.
        **kw
            Remaining :func:`search` keyword arguments.

        Returns
        -------
        tuple of jnp.ndarray
            ``(scores (Q, k), ids (Q, k))`` as :func:`search`.
        """
        side = self.delta_view()
        if prefilter == "rt" and kw.get("rt_grid") is None:
            kw["rt_grid"] = self.ensure_rt_grid(metric=kw.get("metric", "l2"))
        return search(self.data, queries, side=side, prefilter=prefilter,
                      **kw)

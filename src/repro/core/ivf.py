"""Inverted file index: coarse k-means filtering + padded cluster storage.

Storage layout is TPU-native: instead of the CPU-style CSR inverted lists,
clusters are padded to a fixed capacity so that the online scan over the
``nprobs`` selected clusters is a static-shape gather — the structural
equivalent of the paper's per-cluster inverted indices (Alg. 1 line 12-14),
laid out for regular vector access instead of pointer chasing.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .kmeans import KMeansState, assign, kmeans_subsampled


class IVFIndex(NamedTuple):
    centroids: jnp.ndarray     # (C, D) f32
    centroid_sq: jnp.ndarray   # (C,)   f32
    point_ids: jnp.ndarray     # (C, P) int32 — padded per-cluster point ids; -1 = pad
    valid: jnp.ndarray         # (C, P) bool
    labels: jnp.ndarray        # (N,)   int32 — cluster of each point

    @property
    def n_clusters(self) -> int:
        return self.centroids.shape[0]

    @property
    def capacity(self) -> int:
        return self.point_ids.shape[1]


def cluster_capacity(n: int, n_clusters: int, capacity_mult: float) -> int:
    """Padded per-cluster slot count: ``capacity_mult * N/C``, min 8, mult of 8.

    Parameters
    ----------
    n : int
        Number of points.
    n_clusters : int
        Number of IVF clusters.
    capacity_mult : float
        Padding headroom over the perfectly balanced fill ``N / C``.

    Returns
    -------
    int
        The slot count P shared by every padded cluster row.
    """
    cap = int(max(8, capacity_mult * n / n_clusters))
    return ((cap + 7) // 8) * 8


def padded_layout(labels: np.ndarray, n_clusters: int, cap: int
                  ) -> tuple[np.ndarray, np.ndarray]:
    """Pack point ids into the padded (C, P) cluster layout, spilling overflow.

    Overflowing points (rare with reasonable k-means balance) spill to the
    emptiest non-full clusters via a host-side pass, and their ``labels``
    entry is rewritten to the adoptive cluster so storage and labels agree.

    Parameters
    ----------
    labels : np.ndarray
        (N,) int — owning cluster per point. Modified in place on spill.
    n_clusters : int
        Number of clusters C.
    cap : int
        Padded capacity P per cluster (:func:`cluster_capacity`).

    Returns
    -------
    tuple of np.ndarray
        ``(point_ids (C, P) int32 with -1 padding, labels (N,))``.
    """
    point_ids = np.full((n_clusters, cap), -1, dtype=np.int32)
    fill = np.zeros((n_clusters,), dtype=np.int64)
    overflow = []
    for pid, c in enumerate(labels):
        if fill[c] < cap:
            point_ids[c, fill[c]] = pid
            fill[c] += 1
        else:
            overflow.append(pid)
    if overflow:  # spill to emptiest clusters (keeps every point searchable)
        order = np.argsort(fill)
        oi = 0
        for c in order:
            while fill[c] < cap and oi < len(overflow):
                pid = overflow[oi]
                point_ids[c, fill[c]] = pid
                labels[pid] = c
                fill[c] += 1
                oi += 1
            if oi >= len(overflow):
                break
    return point_ids, labels


def build_ivf(points: jnp.ndarray, *, n_clusters: int, n_iters: int = 10,
              key: jax.Array | None = None, capacity_mult: float = 4.0,
              max_train_points: int = 200_000) -> IVFIndex:
    """Train IVF centroids and build the padded cluster layout.

    ``capacity_mult`` pads each cluster to ``capacity_mult * N/C`` slots;
    overflowing points (rare with reasonable k-means balance) spill to the
    emptiest non-full clusters via a host-side pass
    (:func:`padded_layout`). Lloyd training runs on a
    ``max_train_points``-row subsample (FAISS-style); the full set is
    only ever streamed through chunked assignment.
    """
    st: KMeansState = kmeans_subsampled(points, n_clusters=n_clusters,
                                        n_iters=n_iters, key=key,
                                        max_train_points=max_train_points)
    labels = np.array(assign(points.astype(jnp.float32), st.centroids))
    n = points.shape[0]
    cap = cluster_capacity(n, n_clusters, capacity_mult)
    point_ids, labels = padded_layout(labels, n_clusters, cap)
    point_ids = jnp.asarray(point_ids)
    return IVFIndex(
        centroids=st.centroids,
        centroid_sq=jnp.sum(st.centroids * st.centroids, axis=-1),
        point_ids=point_ids,
        valid=point_ids >= 0,
        labels=jnp.asarray(labels),
    )


@functools.partial(jax.jit, static_argnames=("nprobe", "metric"))
def filter_clusters(queries: jnp.ndarray, index: IVFIndex, *, nprobe: int,
                    metric: str = "l2") -> tuple[jnp.ndarray, jnp.ndarray]:
    """Stage A (paper Fig. 1): pick the nprobe closest/most-similar centroids.

    Mapped to the MXU exactly as the paper maps it to Tensor cores (§5.3):
    ``|x-q|^2 = x^2 - 2 x.q^T + q^2`` — a single GEMM plus rank-1 terms.
    Returns (scores, cluster_ids), each (Q, nprobe). Scores are
    lower-is-better for L2 and higher-is-better for IP.
    """
    qc = queries.astype(jnp.float32) @ index.centroids.T        # (Q, C)
    if metric == "l2":
        d = index.centroid_sq[None, :] - 2.0 * qc               # |q|^2 omitted (rank-only)
        neg_scores, ids = jax.lax.top_k(-d, nprobe)
        return -neg_scores, ids
    elif metric == "ip":
        scores, ids = jax.lax.top_k(qc, nprobe)
        return scores, ids
    raise ValueError(f"unknown metric {metric!r}")

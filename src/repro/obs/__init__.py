"""`repro.obs` — unified observability for the JUNO serving stack.

One package ties together the stack's previously fragmented telemetry
(`FleetRequest.trace()` segments, `LatencyHistogram`, paged-cache
counters, engine timestamps) behind three primitives:

* :class:`MetricsRegistry` of :class:`Counter` / :class:`Gauge` /
  :class:`Histogram` series under the ``juno_<subsystem>_<name>``
  naming scheme, mergeable fail-closed across fleet replicas
  (``repro.obs.registry``);
* a span :class:`Tracer` nesting enqueue → batch → rt-probe → kernel
  dispatch → paged fault-in → merge per request
  (``repro.obs.trace``);
* JSONL + Prometheus-text exporters with a fail-closed schema check
  (``repro.obs.export``, ``tools/obs_report.py``), and a sampled
  exact-rerank :class:`RecallProbe` feeding online ``recall@k`` gauges
  per recall tier (``repro.obs.recall``).

The package is numpy + stdlib only — importable without jax — and all
instrumentation is host-side: enabling it never adds jit arguments,
never widens the engine's signature lattice, and leaves served ids and
scores bit-identical (pinned by ``tests/test_obs.py``). Subsystems
accept an :class:`Observability` bundle (or a bare registry) and stay
fully functional with it absent.
"""
from .export import (SCHEMA, read_jsonl, registry_from_events, to_events,
                     validate_events, write_jsonl)
from .recall import RecallProbe, exact_topk_ids
from .registry import Counter, Gauge, Histogram, MetricsRegistry
from .trace import Span, Tracer

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "Span", "Tracer", "RecallProbe", "exact_topk_ids",
    "Observability", "SCHEMA", "to_events", "write_jsonl", "read_jsonl",
    "validate_events", "registry_from_events",
]


class Observability:
    """Bundle of registry + tracer (+ optional recall probe) for one scope.

    Engines, fleets and stores take one of these instead of three
    separate objects. ``registry`` and ``tracer`` default to fresh
    instances; ``recall`` stays None unless a shadow probe is wanted.
    The probe binds its gauges to the FIRST registry it meets
    (:meth:`RecallProbe.bind`), so a fleet can hand the same probe to
    every replica while the estimates land in the fleet-level registry.
    """

    def __init__(self, registry: MetricsRegistry = None,
                 tracer: Tracer = None, recall: RecallProbe = None):
        """Assemble a bundle, creating registry/tracer when not given."""
        self.registry = registry if registry is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else Tracer()
        self.recall = recall
        if recall is not None:
            recall.bind(self.registry)

    def child(self, registry: MetricsRegistry = None) -> "Observability":
        """Derive a per-replica bundle: own registry, shared tracer/probe.

        The fleet merges the child registries back into one view via
        :meth:`MetricsRegistry.merge`; the tracer is shared because span
        ids must be unique across the whole process for parent links to
        resolve in one dump.
        """
        return Observability(
            registry=registry if registry is not None else MetricsRegistry(),
            tracer=self.tracer, recall=self.recall)

    def events(self, extra_meta: dict = None) -> list:
        """Schema-stamped JSONL events for this bundle's registry + spans."""
        return to_events(self.registry, self.tracer, extra_meta=extra_meta)

"""Process-local, mergeable metrics registry (`repro.obs.registry`).

Three metric primitives — :class:`Counter` (monotone sum),
:class:`Gauge` (point-in-time value with a declared merge aggregation)
and :class:`Histogram` (the streaming log-bucketed distribution that
started life as ``repro.serve.fleet.LatencyHistogram``, relocated here
as the general primitive) — owned by a :class:`MetricsRegistry` keyed by
``(name, labels)``.

Naming scheme (documented in docs/observability.md): every metric an
instrumented subsystem registers is named ``juno_<subsystem>_<name>``,
with Prometheus conventions for units and suffixes — ``_total`` for
counters, ``_seconds`` / ``_bytes`` embedded units, label keys for the
low-cardinality dimensions (``mode``, ``reason``, ...). The registry
itself only enforces the character set (``[a-z0-9_]``); the scheme is a
repo convention checked by ``tests/test_obs.py``.

Merging is the cross-replica primitive (``AnnServeFleet`` folds every
replica's registry into one fleet view) and is FAIL-CLOSED: merging two
metrics of different kinds, two histograms with different bucket
*edges* (same shape is not enough — the PR-7 lesson), or two gauges
with different declared aggregations raises ``ValueError`` instead of
corrupting the merged numbers. Counter merge is commutative; gauge
merge follows the gauge's declared ``agg``.

Everything here is plain numpy + stdlib — importable without jax, so
``tools/obs_report.py`` stays light.
"""
from __future__ import annotations

import math
import re
from typing import Iterator

import numpy as np

#: metric / label-key character set (Prometheus-compatible subset)
_NAME_RE = re.compile(r"^[a-z_][a-z0-9_]*$")

#: gauge merge aggregations (see :class:`Gauge`)
GAUGE_AGGS = ("last", "sum", "max", "min")


def _check_name(name: str) -> str:
    """Validate a metric or label-key name against the character set."""
    if not _NAME_RE.match(name):
        raise ValueError(f"invalid metric/label name {name!r} "
                         f"(want [a-z_][a-z0-9_]*)")
    return name


class Counter:
    """Monotonically increasing sum. Merge (addition) is commutative."""

    kind = "counter"

    def __init__(self):
        """Start at zero."""
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        """Add ``n`` (must be >= 0: counters only go up)."""
        if n < 0:
            raise ValueError(f"counter increment must be >= 0, got {n}")
        self.value += n

    def merge(self, other: "Counter") -> None:
        """Fold another counter in (commutative: ``a+b == b+a``)."""
        self.value += other.value


class Gauge:
    """Point-in-time value with a declared cross-registry aggregation.

    ``agg`` decides what :meth:`merge` means when per-replica registries
    fold into one fleet view: ``"sum"`` for capacity-like gauges (total
    queued rows across replicas), ``"max"``/``"min"`` for envelope
    gauges, ``"last"`` (default) for sampled values where the most
    recently written side wins (NOT commutative — documented, and
    fail-closed against merging with a different ``agg``).
    """

    kind = "gauge"

    def __init__(self, agg: str = "last"):
        """Create an unset gauge with merge aggregation ``agg``."""
        if agg not in GAUGE_AGGS:
            raise ValueError(f"unknown gauge agg {agg!r} "
                             f"(want one of {GAUGE_AGGS})")
        self.agg = agg
        self.value = 0.0
        self.updates = 0

    def set(self, v: float) -> None:
        """Write the gauge's current value."""
        self.value = float(v)
        self.updates += 1

    def merge(self, other: "Gauge") -> None:
        """Fold another gauge in per this gauge's ``agg`` (fail-closed).

        Raises ValueError when the aggregations differ — the two sides
        disagree about what the merged number MEANS, so there is no
        correct answer to silently pick.
        """
        if other.agg != self.agg:
            raise ValueError(f"gauge agg mismatch: {self.agg!r} "
                             f"vs {other.agg!r}")
        if other.updates == 0:
            return
        if self.updates == 0 or self.agg == "last":
            self.value = other.value
        elif self.agg == "sum":
            self.value += other.value
        elif self.agg == "max":
            self.value = max(self.value, other.value)
        elif self.agg == "min":
            self.value = min(self.value, other.value)
        self.updates += other.updates


class Histogram:
    """Streaming log-bucketed histogram with percentile queries.

    Fixed memory (one int64 count per bucket), so it can absorb an
    unbounded observation stream: buckets are geometrically spaced
    between ``lo`` and ``hi`` at ``bins_per_decade`` buckets per decade
    (default 24 → ≤ ~10 % relative resolution). ``percentile`` returns
    the **upper edge** of the bucket holding the requested quantile
    (clamped to the exact observed max), i.e. a conservative tail
    estimate — an SLO gate on it can over-reject by at most one bucket
    width, never under-reject. Relocated from
    ``repro.serve.fleet.LatencyHistogram`` (which remains as a
    back-compat alias) and generalized: the unit is whatever the caller
    observes (seconds, bytes, ratios).
    """

    kind = "histogram"

    def __init__(self, lo: float = 1e-6, hi: float = 500.0,
                 bins_per_decade: int = 24):
        """Allocate the bucket table spanning [lo, hi].

        Parameters
        ----------
        lo, hi : float
            Smallest / largest value resolved exactly; values outside
            land in the under/overflow buckets.
        bins_per_decade : int
            Geometric bucket density (resolution ≈ ``10^(1/bins)``).
        """
        self.lo, self.hi = float(lo), float(hi)
        self.bins_per_decade = int(bins_per_decade)
        n_edges = int(math.ceil(math.log10(hi / lo) * bins_per_decade)) + 1
        #: upper edge of bucket b is _edges[b]; the final bucket (index
        #: len(_edges)) is the overflow bucket, bounded by the exact max
        self._edges = lo * 10.0 ** (np.arange(n_edges) / bins_per_decade)
        self._counts = np.zeros(n_edges + 1, np.int64)
        self.n = 0
        self.sum = 0.0
        self.max = 0.0

    def add(self, value: float) -> None:
        """Record one observation into its log-spaced bucket."""
        s = float(value)
        b = int(np.searchsorted(self._edges, s, side="left"))
        self._counts[b] += 1
        self.n += 1
        self.sum += s
        self.max = max(self.max, s)

    def merge(self, other: "Histogram") -> None:
        """Fold another histogram (same bucketing) into this one.

        The bucketings must be identical, which means the *edges* must
        match — two histograms with different ``lo``/``bins_per_decade``
        can land on the same bucket count (e.g. ``lo=1e-5, hi=5000`` vs
        the defaults), and folding those counts together would corrupt
        every percentile. Raises ValueError on any mismatch.
        """
        if not np.array_equal(other._edges, self._edges):
            raise ValueError("histogram bucketings differ")
        self._counts += other._counts
        self.n += other.n
        self.sum += other.sum
        self.max = max(self.max, other.max)

    def percentile(self, p: float) -> float:
        """Upper-edge estimate of the ``p`` quantile (0 < p <= 1)."""
        if self.n == 0:
            return 0.0
        target = max(1, int(math.ceil(p * self.n)))
        cum = np.cumsum(self._counts)
        b = int(np.searchsorted(cum, target))
        edge = self._edges[b] if b < len(self._edges) else self.max
        return float(min(edge, self.max))

    def summary(self) -> dict:
        """``{"n", "mean", "p50", "p95", "p99", "max"}`` in the observed unit."""
        if self.n == 0:
            return {"n": 0, "mean": 0.0, "p50": 0.0, "p95": 0.0,
                    "p99": 0.0, "max": 0.0}
        return {"n": self.n, "mean": self.sum / self.n,
                "p50": self.percentile(0.50), "p95": self.percentile(0.95),
                "p99": self.percentile(0.99), "max": self.max}

    # ---- serialization (JSONL export round-trip) -------------------------
    def state(self) -> dict:
        """Serializable constructor params + bucket state."""
        return {"lo": self.lo, "hi": self.hi,
                "bins_per_decade": self.bins_per_decade,
                "counts": [int(c) for c in self._counts],
                "n": int(self.n), "sum": float(self.sum),
                "max": float(self.max)}

    @classmethod
    def from_state(cls, state: dict) -> "Histogram":
        """Rebuild a histogram from :meth:`state` output (fail-closed)."""
        h = cls(lo=state["lo"], hi=state["hi"],
                bins_per_decade=state["bins_per_decade"])
        counts = np.asarray(state["counts"], np.int64)
        if counts.shape != h._counts.shape:
            raise ValueError(
                f"histogram state has {counts.shape[0]} buckets, "
                f"lo/hi/bins imply {h._counts.shape[0]}")
        if int(counts.sum()) != int(state["n"]):
            raise ValueError("histogram state n != sum(counts)")
        h._counts = counts
        h.n = int(state["n"])
        h.sum = float(state["sum"])
        h.max = float(state["max"])
        return h


MetricKey = tuple  # (name, ((label_key, label_value), ...))


class MetricsRegistry:
    """Get-or-create owner of named, labeled metrics.

    One registry per process-local scope (an engine, a replica, a
    store); :meth:`merge` folds registries together fail-closed for the
    fleet view. Accessors are get-or-create and type-checked: asking for
    ``counter(name)`` where ``name`` is already a gauge raises instead
    of shadowing.
    """

    def __init__(self):
        """Create an empty registry."""
        self._metrics: dict[MetricKey, object] = {}

    # ---- keying ----------------------------------------------------------
    @staticmethod
    def _key(name: str, labels: dict) -> MetricKey:
        _check_name(name)
        for k in labels:
            _check_name(k)
        return (name, tuple(sorted((k, str(v)) for k, v in labels.items())))

    def _get_or_create(self, name: str, labels: dict, kind: str, make):
        key = self._key(name, labels)
        m = self._metrics.get(key)
        if m is None:
            m = make()
            self._metrics[key] = m
        elif m.kind != kind:
            raise ValueError(f"metric {name!r} already registered as "
                             f"{m.kind}, not {kind}")
        return m

    # ---- accessors (get-or-create) ---------------------------------------
    def counter(self, name: str, **labels) -> Counter:
        """Get or create the counter ``name{labels}``."""
        return self._get_or_create(name, labels, "counter", Counter)

    def gauge(self, name: str, agg: str = "last", **labels) -> Gauge:
        """Get or create the gauge ``name{labels}`` with merge agg ``agg``."""
        g = self._get_or_create(name, labels, "gauge", lambda: Gauge(agg))
        if g.agg != agg:
            raise ValueError(f"gauge {name!r} already registered with "
                             f"agg={g.agg!r}, not {agg!r}")
        return g

    def histogram(self, name: str, lo: float = 1e-6, hi: float = 500.0,
                  bins_per_decade: int = 24, **labels) -> Histogram:
        """Get or create the histogram ``name{labels}``.

        Bucketing params apply on creation; a later call with different
        params against an existing histogram raises (fail-closed — the
        caller thought it was observing into different buckets).
        """
        h = self._get_or_create(
            name, labels, "histogram",
            lambda: Histogram(lo=lo, hi=hi, bins_per_decade=bins_per_decade))
        if (h.lo, h.hi, h.bins_per_decade) != (float(lo), float(hi),
                                               int(bins_per_decade)):
            raise ValueError(f"histogram {name!r} already registered with "
                             f"different bucketing")
        return h

    def get(self, name: str, **labels):
        """Return the metric ``name{labels}`` or None."""
        return self._metrics.get(self._key(name, labels))

    def metrics(self) -> Iterator[tuple[str, dict, object]]:
        """Iterate ``(name, labels_dict, metric)`` in sorted key order."""
        for (name, labels) in sorted(self._metrics):
            yield name, dict(labels), self._metrics[(name, labels)]

    def __len__(self) -> int:
        """Number of registered (name, labels) series."""
        return len(self._metrics)

    # ---- merge (the cross-replica primitive) -----------------------------
    def merge(self, other: "MetricsRegistry") -> "MetricsRegistry":
        """Fold another registry into this one, fail-closed.

        Series present in both are merged per their kind's semantics
        (kind mismatch, histogram edge mismatch and gauge agg mismatch
        all raise); series only in ``other`` are deep-copied in. Counter
        folds are commutative; see :meth:`Gauge.merge` for gauges.
        Returns ``self`` for chaining.
        """
        for key, om in other._metrics.items():
            mine = self._metrics.get(key)
            if mine is None:
                self._metrics[key] = _clone(om)
            elif mine.kind != om.kind:
                raise ValueError(f"merge kind mismatch on {key[0]!r}: "
                                 f"{mine.kind} vs {om.kind}")
            else:
                mine.merge(om)
        return self

    # ---- exposition ------------------------------------------------------
    def snapshot(self) -> dict:
        """Flat ``{"name{label=...}": value-or-summary}`` dict of all series."""
        out = {}
        for name, labels, m in self.metrics():
            lbl = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
            full = f"{name}{{{lbl}}}" if lbl else name
            out[full] = (m.summary() if m.kind == "histogram" else m.value)
        return out

    def render_text(self) -> str:
        """Prometheus-style text exposition of every series.

        Counters/gauges render one sample line; histograms render
        cumulative ``_bucket{le=...}`` lines (upper bucket edges plus
        ``+Inf``) and ``_sum`` / ``_count`` samples, per the Prometheus
        exposition format. ``# TYPE`` comments are emitted once per
        metric name.
        """
        lines: list[str] = []
        last_name = None
        for name, labels, m in self.metrics():
            if name != last_name:
                lines.append(f"# TYPE {name} {m.kind}")
                last_name = name
            base = sorted(labels.items())
            if m.kind == "histogram":
                cum = 0
                for edge, c in zip(m._edges, m._counts[:-1]):
                    cum += int(c)
                    if c:
                        lines.append(_sample(f"{name}_bucket",
                                             base + [("le", f"{edge:g}")],
                                             cum))
                lines.append(_sample(f"{name}_bucket",
                                     base + [("le", "+Inf")], m.n))
                lines.append(_sample(f"{name}_sum", base, m.sum))
                lines.append(_sample(f"{name}_count", base, m.n))
            else:
                lines.append(_sample(name, base, m.value))
        return "\n".join(lines) + ("\n" if lines else "")

    # ---- event (de)serialization -----------------------------------------
    def to_events(self) -> list[dict]:
        """One JSONL-able ``{"event": "metric", ...}`` dict per series."""
        out = []
        for name, labels, m in self.metrics():
            ev = {"event": "metric", "kind": m.kind, "name": name,
                  "labels": labels}
            if m.kind == "histogram":
                ev.update(m.state())
            elif m.kind == "gauge":
                ev.update({"value": m.value, "agg": m.agg,
                           "updates": m.updates})
            else:
                ev.update({"value": m.value})
            out.append(ev)
        return out

    @classmethod
    def from_events(cls, events) -> "MetricsRegistry":
        """Rebuild a registry from ``to_events`` output (round-trip)."""
        reg = cls()
        for ev in events:
            if ev.get("event") != "metric":
                continue
            name, labels, kind = ev["name"], ev.get("labels", {}), ev["kind"]
            if kind == "counter":
                reg.counter(name, **labels).value = float(ev["value"])
            elif kind == "gauge":
                g = reg.gauge(name, agg=ev.get("agg", "last"), **labels)
                g.value = float(ev["value"])
                g.updates = int(ev.get("updates", 1))
            elif kind == "histogram":
                key = cls._key(name, labels)
                reg._metrics[key] = Histogram.from_state(ev)
            else:
                raise ValueError(f"unknown metric kind {kind!r}")
        return reg


def _sample(name: str, labels: list, value) -> str:
    """One Prometheus sample line."""
    lbl = ",".join(f'{k}="{v}"' for k, v in labels)
    return (f"{name}{{{lbl}}} {value:g}" if lbl else f"{name} {value:g}")


def _clone(m):
    """Deep-copy one metric for merge-into-empty."""
    if m.kind == "counter":
        c = Counter()
        c.value = m.value
        return c
    if m.kind == "gauge":
        g = Gauge(m.agg)
        g.value, g.updates = m.value, m.updates
        return g
    return Histogram.from_state(m.state())

"""Span-based request tracing (`repro.obs.trace`).

Extends the flat per-request segment dict of
``repro.serve.fleet.FleetRequest.trace()`` into nested spans: every
engine tick opens an ``engine.tick`` span whose children cover the
pipeline — per-request ``engine.enqueue`` (submit → batch formation),
``engine.rt_probe`` (sphere-filter budget resolution), ``engine.dispatch``
(one jitted call per batch chunk; on the paged engine its children are
``paged.filter`` / ``paged.gather`` with one ``paged.fault`` span per
cluster cache miss / ``paged.score``) and ``engine.merge`` (results
sliced back onto requests). The fleet layer adds retroactive
``fleet.request`` spans with queue/compute/merge children per served
request.

The tracer is single-writer (the engine tick loop is single-threaded);
*concurrency* shows up as interleaved requests inside one tick, which is
exactly what ``trace_id`` disambiguates: spans belonging to one request
carry its request id, spans shared by the whole batch carry none.
Completed spans land in a bounded ring buffer (oldest dropped,
``dropped`` counts), exportable as JSONL events alongside the metrics
registry (``repro.obs.export``). Spans are appended on CLOSE, so buffer
order is end-time order; nesting is reconstructed from ``parent_id``.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import time
from typing import Iterator, Optional


@dataclasses.dataclass
class Span:
    """One timed, named, optionally-nested trace span.

    ``parent_id`` links to the enclosing span (None at the root),
    ``trace_id`` groups spans of one logical request across ticks, and
    ``attrs`` carries low-cardinality context (signature, cluster id,
    bucket size, ...). Timestamps are ``perf_counter`` seconds.
    """

    name: str
    span_id: int
    parent_id: Optional[int]
    trace_id: Optional[str]
    t_start: float
    t_end: float = 0.0
    attrs: dict = dataclasses.field(default_factory=dict)

    @property
    def duration(self) -> float:
        """``t_end - t_start`` in seconds."""
        return self.t_end - self.t_start


class Tracer:
    """Bounded collector of nested spans (single-writer).

    Live spans open via the :meth:`span` context manager and nest
    through an explicit stack (:attr:`current`); already-elapsed
    segments (a request's queue wait, a fleet request's lifetime) are
    stamped retroactively via :meth:`record`. The buffer holds the most
    recent ``max_spans`` completed spans; overflow increments
    :attr:`dropped` instead of growing without bound.
    """

    def __init__(self, max_spans: int = 8192):
        """Create an empty tracer keeping at most ``max_spans`` spans."""
        self.max_spans = int(max_spans)
        self._spans: collections.deque[Span] = collections.deque(
            maxlen=self.max_spans)
        self._stack: list[Span] = []
        self._next_id = 0
        self.dropped = 0

    @property
    def current(self) -> Optional[Span]:
        """The innermost open span, or None outside any span."""
        return self._stack[-1] if self._stack else None

    def _new(self, name: str, parent_id: Optional[int],
             trace_id: Optional[str], t_start: float, attrs: dict) -> Span:
        s = Span(name=name, span_id=self._next_id, parent_id=parent_id,
                 trace_id=trace_id, t_start=t_start, attrs=attrs)
        self._next_id += 1
        return s

    def _close(self, span: Span, t_end: float) -> None:
        span.t_end = t_end
        if len(self._spans) == self.max_spans:
            self.dropped += 1
        self._spans.append(span)

    @contextlib.contextmanager
    def span(self, name: str, trace_id: Optional[str] = None,
             **attrs) -> Iterator[Span]:
        """Open a live span nested under :attr:`current`; closes on exit.

        Parameters
        ----------
        name : str
            Span name (dotted taxonomy, e.g. ``"engine.dispatch"``).
        trace_id : str, optional
            Logical request the span belongs to; inherited from the
            enclosing span when omitted (None at the root = batch-shared).
        **attrs
            Attached attributes (stringified on export).
        """
        parent = self.current
        if trace_id is None and parent is not None:
            trace_id = parent.trace_id
        s = self._new(name, parent.span_id if parent else None, trace_id,
                      time.perf_counter(), attrs)
        self._stack.append(s)
        try:
            yield s
        finally:
            self._stack.pop()
            self._close(s, time.perf_counter())

    def record(self, name: str, t_start: float, t_end: float, *,
               trace_id: Optional[str] = None,
               parent: Optional[Span] = None, **attrs) -> Span:
        """Append an already-elapsed span with explicit timestamps.

        Used for segments whose boundaries were stamped before the
        tracer saw them — a request's submit→batch queue wait, a fleet
        request's arrival→done lifetime. ``parent`` defaults to
        :attr:`current` (the open span at call time), so retro-stamped
        spans still nest under the tick that completed them; like
        :meth:`span`, an omitted ``trace_id`` is inherited from the
        parent.
        """
        p = parent if parent is not None else self.current
        if trace_id is None and p is not None:
            trace_id = p.trace_id
        s = self._new(name, p.span_id if p else None, trace_id,
                      float(t_start), attrs)
        self._close(s, float(t_end))
        return s

    def spans(self) -> list[Span]:
        """Completed spans, oldest first (close-time order)."""
        return list(self._spans)

    def clear(self) -> None:
        """Drop all completed spans (open spans and ids are untouched)."""
        self._spans.clear()
        self.dropped = 0

    # ---- event (de)serialization -----------------------------------------
    def to_events(self) -> list[dict]:
        """One JSONL-able ``{"event": "span", ...}`` dict per span."""
        return [{"event": "span", "name": s.name, "span_id": s.span_id,
                 "parent_id": s.parent_id, "trace_id": s.trace_id,
                 "t_start": s.t_start, "t_end": s.t_end,
                 "attrs": {k: str(v) for k, v in s.attrs.items()}}
                for s in self._spans]

    @staticmethod
    def spans_from_events(events) -> list[Span]:
        """Rebuild :class:`Span` objects from ``to_events`` output."""
        out = []
        for ev in events:
            if ev.get("event") != "span":
                continue
            out.append(Span(name=ev["name"], span_id=int(ev["span_id"]),
                            parent_id=(None if ev.get("parent_id") is None
                                       else int(ev["parent_id"])),
                            trace_id=ev.get("trace_id"),
                            t_start=float(ev["t_start"]),
                            t_end=float(ev["t_end"]),
                            attrs=dict(ev.get("attrs", {}))))
        return out

"""Online recall telemetry via a sampled exact-rerank shadow path.

Offline recall gates (BENCH_*.json) measure quality against a frozen
ground truth; a serving system needs the same signal *online*, per
recall tier, so the future per-query strategy router (ROADMAP open
item) has something to route on. :class:`RecallProbe` shadows roughly
one in ``every`` served requests: it brute-force exact-scores the
request's queries against the raw row matrix the probe was built with
(numpy only — no jax, no index structures) and reports the fraction of
the engine's returned ids that land in the exact top-k as a
``juno_recall_online_at_k`` gauge per tier, alongside a sample
counter. The shadow pass runs on the host after results are already
returned, so it never sits on the serving path's critical section; its
cost is bounded by the sampling rate.

Snapshot caveat: the probe scores against the row matrix captured at
construction. Ids appended after that snapshot (inserts) fall outside
it and are counted as misses, biasing the estimate *down* — rebuild or
re-bind the probe after heavy ingest. Deletes are handled by the engine
never returning tombstoned ids.
"""
from __future__ import annotations

import numpy as np

from .registry import MetricsRegistry


def exact_topk_ids(queries: np.ndarray, vectors: np.ndarray, k: int,
                   metric: str = "l2",
                   v_sq: np.ndarray | None = None) -> np.ndarray:
    """Brute-force exact top-``k`` row ids per query (numpy, host-side).

    ``metric`` is ``"l2"`` (squared euclidean) or ``"ip"`` (maximum
    inner product). Returns ``(Q, k)`` int64 ids, best first — the same
    ordering contract as ``repro.core.exact_topk`` but dependency-free
    so the obs package stays importable without jax. ``v_sq`` optionally
    supplies precomputed per-row squared norms of ``vectors`` (an O(N*D)
    term otherwise recomputed per call — callers scoring against a fixed
    snapshot, like :class:`RecallProbe`, cache it once).
    """
    q = np.asarray(queries, dtype=np.float32)
    v = np.asarray(vectors, dtype=np.float32)
    if metric == "l2":
        # ||q - v||^2 = q.q - 2 q.v + v.v ; q.q is rank-constant per row.
        if v_sq is None:
            v_sq = np.sum(v * v, axis=1)
        d = -2.0 * (q @ v.T) + np.asarray(v_sq)[None, :]
    elif metric == "ip":
        d = -(q @ v.T)
    else:
        raise ValueError(f"unknown metric {metric!r}")
    k = min(int(k), v.shape[0])
    part = np.argpartition(d, k - 1, axis=1)[:, :k]
    order = np.argsort(np.take_along_axis(d, part, axis=1), axis=1)
    return np.take_along_axis(part, order, axis=1).astype(np.int64)


class RecallProbe:
    """Sampled online recall@k estimator feeding registry gauges.

    Parameters
    ----------
    vectors : np.ndarray
        ``(N, D)`` raw rows; id ``i`` is row ``i`` (the engine's id
        space for the base dataset).
    k : int
        Depth of the recall estimate (``recall@k``).
    every : int
        Shadow-rerank one request out of this many (per tier,
        deterministic round-robin — no RNG, so runs are reproducible).
    metric : str
        ``"l2"`` or ``"ip"``; must match the served index.
    """

    def __init__(self, vectors: np.ndarray, *, k: int = 10, every: int = 8,
                 metric: str = "l2"):
        """Snapshot the row matrix and sampling cadence for the probe."""
        self.vectors = np.asarray(vectors, dtype=np.float32)
        self.k = int(k)
        self.every = max(1, int(every))
        self.metric = metric
        # snapshot norms once; recomputing this O(N*D) term per sampled
        # request would dominate the probe's cost on large snapshots
        self._v_sq = (np.sum(self.vectors * self.vectors, axis=1)
                      if metric == "l2" else None)
        self._seen: dict[str, int] = {}
        # per-tier running sums: (matched ids, compared ids)
        self._hits: dict[str, int] = {}
        self._total: dict[str, int] = {}
        self._registry = None

    def bind(self, registry: MetricsRegistry) -> None:
        """Attach the registry that receives the gauges (first bind wins)."""
        if self._registry is None:
            self._registry = registry

    def observe(self, req, mode: str) -> None:
        """Maybe shadow-rerank one completed request for tier ``mode``.

        ``req`` needs ``queries``, ``ids`` and ``k`` (duck-typed so
        fleet-level and engine-level request objects both work). Only
        every ``self.every``-th call per tier actually reranks.
        """
        n = self._seen.get(mode, 0)
        self._seen[mode] = n + 1
        if n % self.every != 0 or req.ids is None:
            return
        k = min(self.k, int(req.k))
        exact = exact_topk_ids(req.queries, self.vectors, k, self.metric,
                               v_sq=self._v_sq)
        got = np.asarray(req.ids)[:, :k]
        # per-row intersection size: returned ids are unique within a row
        # (top-k of distinct points; only the -1 padding repeats, masked
        # out here), so counting membership equals the set intersection
        hits = int((((got[:, :, None] == exact[:, None, :]).any(-1))
                    & (got >= 0)).sum())
        self._hits[mode] = self._hits.get(mode, 0) + hits
        self._total[mode] = self._total.get(mode, 0) + got.shape[0] * k
        if self._registry is not None:
            self._registry.counter(
                "juno_recall_samples_total", mode=mode).inc(got.shape[0])
            self._registry.gauge(
                "juno_recall_online_at_k", mode=mode,
                k=str(k)).set(self.estimate(mode))

    def estimate(self, mode: str) -> float:
        """Current recall@k estimate for a tier (0.0 before any sample)."""
        total = self._total.get(mode, 0)
        if total == 0:
            return 0.0
        return self._hits.get(mode, 0) / total

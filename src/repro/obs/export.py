"""JSONL event export / import / validation (`repro.obs.export`).

One observability dump is a JSON-Lines file under the ``juno.obs.v1``
schema: a leading ``meta`` event naming the schema, then one ``metric``
event per registry metric (full state — counters carry ``value``,
gauges ``value``/``agg``/``updates``, histograms their bucket layout and
counts so dumps merge and round-trip losslessly) and one ``span`` event
per completed trace span. ``validate_events`` is the fail-closed schema
check behind ``tools/obs_report.py --validate`` and the CI smoke step:
it returns a list of human-readable problems (empty = valid) instead of
raising, so callers can surface every defect at once.
"""
from __future__ import annotations

import json
import math
import os
from typing import Optional

from .registry import GAUGE_AGGS, MetricsRegistry, _NAME_RE
from .trace import Tracer

SCHEMA = "juno.obs.v1"


def to_events(registry: Optional[MetricsRegistry] = None,
              tracer: Optional[Tracer] = None,
              extra_meta: Optional[dict] = None) -> list[dict]:
    """Flatten a registry and/or tracer into one schema-stamped event list."""
    meta = {"event": "meta", "schema": SCHEMA}
    if extra_meta:
        meta.update(extra_meta)
    events: list[dict] = [meta]
    if registry is not None:
        events.extend(registry.to_events())
    if tracer is not None:
        events.extend(tracer.to_events())
    return events


def write_jsonl(path: str, events: list[dict]) -> None:
    """Write events one-JSON-object-per-line, creating parent directories."""
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    with open(path, "w") as fh:
        for ev in events:
            fh.write(json.dumps(ev, sort_keys=True) + "\n")


def read_jsonl(path: str) -> list[dict]:
    """Parse a JSONL dump back into its event-dict list (blank lines skipped)."""
    events = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if line:
                events.append(json.loads(line))
    return events


def registry_from_events(events: list[dict]) -> MetricsRegistry:
    """Rebuild a :class:`MetricsRegistry` from a validated event list."""
    return MetricsRegistry.from_events(events)


def _check_metric(i: int, ev: dict, problems: list[str]) -> None:
    name = ev.get("name")
    if not isinstance(name, str) or not _NAME_RE.match(name):
        problems.append(f"line {i}: bad metric name {name!r}")
        return
    labels = ev.get("labels", {})
    if not isinstance(labels, dict):
        problems.append(f"line {i}: metric {name}: labels must be an object")
    kind = ev.get("kind")
    if kind == "counter":
        v = ev.get("value")
        if not isinstance(v, (int, float)) or v < 0:
            problems.append(f"line {i}: counter {name}: bad value {v!r}")
    elif kind == "gauge":
        if ev.get("agg") not in GAUGE_AGGS:
            problems.append(
                f"line {i}: gauge {name}: bad agg {ev.get('agg')!r}")
        if not isinstance(ev.get("value"), (int, float)):
            problems.append(f"line {i}: gauge {name}: non-numeric value")
    elif kind == "histogram":
        counts = ev.get("counts")
        lo, hi = ev.get("lo"), ev.get("hi")
        bpd = ev.get("bins_per_decade")
        if (not isinstance(counts, list)
                or not isinstance(lo, (int, float))
                or not isinstance(hi, (int, float))
                or not isinstance(bpd, int) or lo <= 0 or hi <= lo):
            problems.append(
                f"line {i}: histogram {name}: missing/bad bucketing state")
            return
        # bucket layout implied by (lo, hi, bins_per_decade): n_edges
        # resolved buckets plus one overflow bucket (see Histogram).
        want = int(math.ceil(math.log10(hi / lo) * bpd)) + 2
        if len(counts) != want:
            problems.append(
                f"line {i}: histogram {name}: {len(counts)} counts, "
                f"bucketing implies {want}")
        n = ev.get("n", 0)
        if sum(counts) != n:
            problems.append(
                f"line {i}: histogram {name}: n={n} != sum(counts)="
                f"{sum(counts)}")
        if any((not isinstance(c, int)) or c < 0 for c in counts):
            problems.append(
                f"line {i}: histogram {name}: negative or non-int count")
    else:
        problems.append(f"line {i}: metric {name}: unknown kind {kind!r}")


def validate_events(events: list[dict]) -> list[str]:
    """Fail-closed schema check; returns problems (empty list = valid).

    Checks: a leading ``meta`` event carrying ``schema == "juno.obs.v1"``;
    every metric event has a scheme-conforming name, a known kind, and
    internally consistent state (histogram ``counts`` length and total);
    every span event has ordered timestamps and a resolvable parent.
    """
    problems: list[str] = []
    if not events:
        return ["empty event list"]
    head = events[0]
    if head.get("event") != "meta":
        problems.append("line 0: first event must be 'meta'")
    elif head.get("schema") != SCHEMA:
        problems.append(
            f"line 0: schema {head.get('schema')!r} != {SCHEMA!r}")
    span_ids = set()
    for i, ev in enumerate(events):
        kind = ev.get("event")
        if kind == "span":
            span_ids.add(ev.get("span_id"))
    for i, ev in enumerate(events):
        kind = ev.get("event")
        if kind == "meta":
            if i != 0:
                problems.append(f"line {i}: duplicate meta event")
        elif kind == "metric":
            _check_metric(i, ev, problems)
        elif kind == "span":
            if not isinstance(ev.get("name"), str) or not ev.get("name"):
                problems.append(f"line {i}: span without a name")
            t0, t1 = ev.get("t_start"), ev.get("t_end")
            if not (isinstance(t0, (int, float)) and
                    isinstance(t1, (int, float)) and t0 <= t1):
                problems.append(
                    f"line {i}: span {ev.get('name')!r}: bad interval "
                    f"[{t0!r}, {t1!r}]")
            pid = ev.get("parent_id")
            if pid is not None and pid not in span_ids:
                problems.append(
                    f"line {i}: span {ev.get('name')!r}: parent_id {pid} "
                    "not in dump")
        else:
            problems.append(f"line {i}: unknown event kind {kind!r}")
    return problems

"""Straggler detection and crash-restart training.

``StepWatchdog`` flags steps that exceed ``slack``× a running baseline of
healthy step times — the signal a launcher uses to evict a sick host before
it stalls the whole mesh. ``run_with_restart`` is the driver loop around it:
deterministic data + atomic checkpoints (dist/checkpoint.py) make a restart
replay to the bitwise-identical state of an uninterrupted run.
"""
from __future__ import annotations

from typing import Any, Callable, Optional


class StepWatchdog:
    """Classify each step time as "ok" / "slow" / "sick".

    The first ``warmup`` steps only build the baseline (compile steps are
    slow and healthy). Afterwards a step slower than ``slack * baseline`` is
    "slow", a second consecutive one escalates to "sick", and a healthy step
    resets the strike count. Anomalous steps never pollute the baseline.
    """

    def __init__(self, slack: float = 2.0, warmup: int = 3):
        self.slack = float(slack)
        self.warmup = int(warmup)
        self._n = 0
        self._baseline: Optional[float] = None
        self._strikes = 0

    @property
    def baseline(self) -> Optional[float]:
        return self._baseline

    def check(self, step_time: float) -> str:
        self._n += 1
        if self._baseline is None:
            self._baseline = step_time
            return "ok"
        if self._n <= self.warmup:
            self._baseline = min(self._baseline, step_time)
            return "ok"
        if step_time > self.slack * self._baseline:
            self._strikes += 1
            return "slow" if self._strikes == 1 else "sick"
        self._strikes = 0
        self._baseline = 0.9 * self._baseline + 0.1 * step_time
        return "ok"


def run_with_restart(step_fn: Callable, init, n_steps: int, *,
                     save_fn: Optional[Callable] = None,
                     restore_fn: Optional[Callable] = None,
                     ckpt_every: int = 1,
                     fault_injector: Optional[Callable] = None,
                     max_restarts: int = 10) -> tuple[Any, int]:
    """Run ``step_fn(state, step) -> (state, ...)`` for ``n_steps`` steps,
    resuming from the latest checkpoint on any step failure.

    * ``save_fn(state, step)`` is called whenever ``step % ckpt_every == 0``
      (``step`` counts COMPLETED steps, so a checkpoint at step s resumes by
      re-running step s).
    * ``restore_fn() -> (state | None, step)`` supplies the recovery point;
      when it returns ``(None, _)`` (no checkpoint yet) the run restarts
      from ``init``.
    * ``fault_injector(step)`` is a test hook invoked before each step.

    Returns ``(final_state, completed_steps)``.
    """
    state, step = init, 0
    if restore_fn is not None:
        restored, s = restore_fn()
        if restored is not None:
            state, step = restored, s

    restarts = 0
    while step < n_steps:
        try:
            if fault_injector is not None:
                fault_injector(step)
            out = step_fn(state, step)
            state = out[0] if isinstance(out, tuple) else out
            step += 1
            if save_fn is not None and step % ckpt_every == 0:
                save_fn(state, step)
        except Exception:
            restarts += 1
            if restarts > max_restarts or restore_fn is None:
                raise
            restored, s = restore_fn()
            if restored is not None:
                state, step = restored, s
            else:
                state, step = init, 0
    return state, step

"""Process-global activation-sharding registry.

The model code never takes a mesh argument: ``enable()`` registers the mesh
plus the batch/SP policy once (dry-run, SP tests, production launch), and the
helpers below become real ``with_sharding_constraint`` calls. When disabled
(single-device tests, examples) every helper is an exact identity, so the
unsharded path is untouched.

Sequence parallelism (SP) follows the Korthikanti schedule: activations stay
SEQ-SHARDED over the "model" axis between blocks; ``col_parallel_qkv`` /
``fused_mlp`` gather the sequence internally exactly once (fwd all-gather,
bwd reduce-scatter via the ``sp_gather`` custom-vjp pair) and
``row_parallel`` / ``fused_mlp`` outputs return seq-sharded, so both
directions move 1× traffic.

All constraints are shape-aware: a mesh axis is silently dropped for a
dimension it does not divide (batch=1 cells, kv-heads < model axis), exactly
like launch/mesh.normalize_pspec — a constraint must never make a program
unpartitionable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Mesh | None = None
_BATCH_AXES: tuple | None = None
_SP: bool = False
_MODEL_AXIS: int = 1


def enable(batch_axes, *, sp: bool = False, model_axis: int | None = None,
           mesh: Mesh | None = None) -> None:
    """Register activation shardings for subsequent model traces.

    batch_axes: mesh axis names the batch dim is sharded over, e.g.
    ``("data",)`` or ``("pod", "data")``. ``sp=True`` additionally shards the
    sequence dim of (B, T, D) activations over "model" between blocks.
    ``model_axis`` defaults to the mesh's "model" axis size.
    """
    global _MESH, _BATCH_AXES, _SP, _MODEL_AXIS
    if mesh is None:
        raise ValueError("enable() requires a mesh")
    _MESH = mesh
    _BATCH_AXES = tuple(batch_axes)
    _SP = bool(sp)
    if model_axis is None:
        model_axis = dict(zip(mesh.axis_names, mesh.devices.shape)
                          ).get("model", 1)
    _MODEL_AXIS = int(model_axis)


def disable() -> None:
    global _MESH, _BATCH_AXES, _SP, _MODEL_AXIS
    _MESH, _BATCH_AXES, _SP, _MODEL_AXIS = None, None, False, 1


def batch_axes():
    """The registered batch axes, or None while disabled."""
    return _BATCH_AXES


def model_axis() -> int:
    """Size of the tensor/expert-parallel axis (1 while disabled or when
    the registered mesh has no "model" axis)."""
    return _MODEL_AXIS


def mesh() -> Mesh | None:
    """The registered mesh, or None while disabled."""
    return _MESH


# --------------------------------------------------------------------------
# shape-aware constraint core
# --------------------------------------------------------------------------


def _norm_entry(entry, dim: int, sizes: dict):
    """Drop axis names the mesh lacks or whose product doesn't divide dim."""
    names = entry if isinstance(entry, tuple) else (
        () if entry is None else (entry,))
    names = tuple(n for n in names if n in sizes)
    while names:
        total = 1
        for n in names:
            total *= sizes[n]
        if dim % total == 0:
            break
        names = names[:-1]
    if not names:
        return None
    return names if len(names) > 1 else names[0]


def constrain(x: jnp.ndarray, *entries) -> jnp.ndarray:
    """with_sharding_constraint(x, P(*entries)) on the registered mesh;
    identity when disabled or when x's rank doesn't match."""
    if _MESH is None or getattr(x, "ndim", None) != len(entries):
        return x
    sizes = dict(zip(_MESH.axis_names, _MESH.devices.shape))
    spec = P(*[_norm_entry(e, d, sizes) for e, d in zip(entries, x.shape)])
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, spec))


def _seq_axis():
    return "model" if _SP else None


# --------------------------------------------------------------------------
# activation constraints
# --------------------------------------------------------------------------


def constrain_act(x: jnp.ndarray) -> jnp.ndarray:
    """Canonical (B, T, D) activation layout: batch-sharded, and (under SP)
    seq-sharded over "model" between blocks."""
    return constrain(x, _BATCH_AXES, _seq_axis(), None)


def constrain_batch(x: jnp.ndarray, *rest) -> jnp.ndarray:
    """Shard dim 0 over the batch axes; trailing dims per ``rest``."""
    return constrain(x, _BATCH_AXES, *rest)


def constrain_heads(x: jnp.ndarray) -> jnp.ndarray:
    """(B, T, H, hd) with heads sharded over "model" (head parallelism)."""
    return constrain(x, _BATCH_AXES, None, "model", None)


def seq_all_gather(x: jnp.ndarray) -> jnp.ndarray:
    """Force a full (replicated-seq) view of a possibly seq-sharded (B, T, D)
    activation — used in front of mixers that need the whole sequence (SSM,
    MLA, hybrid)."""
    return constrain(x, _BATCH_AXES, None, None)


# --------------------------------------------------------------------------
# SP gather/scatter custom-vjp pair (layout-only: values are untouched)
# --------------------------------------------------------------------------


@jax.custom_vjp
def _sp_gather(x):
    return constrain(x, _BATCH_AXES, None, None)


def _sp_gather_fwd(x):
    return _sp_gather(x), None


def _sp_gather_bwd(_, ct):
    # cotangent of a layout change is the identity; constraining it back to
    # the seq-sharded layout lowers the bwd collective as reduce-scatter
    # instead of all-reduce + slice (1× traffic).
    return (constrain(ct, _BATCH_AXES, "model", None),)


_sp_gather.defvjp(_sp_gather_fwd, _sp_gather_bwd)


@jax.custom_vjp
def _sp_scatter(x):
    return constrain(x, _BATCH_AXES, "model", None)


def _sp_scatter_fwd(x):
    return _sp_scatter(x), None


def _sp_scatter_bwd(_, ct):
    return (constrain(ct, _BATCH_AXES, None, None),)


_sp_scatter.defvjp(_sp_scatter_fwd, _sp_scatter_bwd)


def sp_gather(x: jnp.ndarray) -> jnp.ndarray:
    """Seq-sharded → full sequence (fwd AG over "model", bwd reduce-scatter).
    Identity unless SP is enabled."""
    if _MESH is None or not _SP:
        return x
    return _sp_gather(x)


def sp_scatter(x: jnp.ndarray) -> jnp.ndarray:
    """Full sequence → seq-sharded (the transpose of sp_gather)."""
    if _MESH is None or not _SP:
        return x
    return _sp_scatter(x)


# --------------------------------------------------------------------------
# parallel projection helpers (column/row parallel + fused MLP)
# --------------------------------------------------------------------------


def col_parallel_qkv(x: jnp.ndarray, wq, wk, wv):
    """x (B, T, D) — possibly seq-sharded under SP — → (q2, k2, v2) each
    (B, T, heads·hd) column-sharded over "model". The internal sp_gather is
    the single fwd all-gather of the Korthikanti schedule."""
    if _MESH is None:
        return x @ wq, x @ wk, x @ wv
    xg = sp_gather(x)
    q2 = constrain(xg @ wq, _BATCH_AXES, None, "model")
    k2 = constrain(xg @ wk, _BATCH_AXES, None, "model")
    v2 = constrain(xg @ wv, _BATCH_AXES, None, "model")
    return q2, k2, v2


def row_parallel(o2: jnp.ndarray, wo) -> jnp.ndarray:
    """o2 (B, T, heads·hd) model-sharded on the contracting dim → (B, T, D)
    partial-sum reduction; the output constraint (seq-sharded under SP)
    lowers the reduction as reduce-scatter."""
    if _MESH is None:
        return o2 @ wo
    o2 = constrain(o2, _BATCH_AXES, None, "model")
    return constrain_act(o2 @ wo)


def fused_mlp(x: jnp.ndarray, w_gate, w_in, w_out) -> jnp.ndarray:
    """SwiGLU with column-parallel up projections and a row-parallel down
    projection; one sp_gather in, seq-sharded out (SP)."""
    if _MESH is None:
        h = jax.nn.silu(x @ w_gate) * (x @ w_in)
        return h @ w_out
    xg = sp_gather(x)
    g = constrain(xg @ w_gate, _BATCH_AXES, None, "model")
    u = constrain(xg @ w_in, _BATCH_AXES, None, "model")
    h = jax.nn.silu(g) * u
    return constrain_act(h @ w_out)

"""Step-numbered pytree checkpoints with atomic commit.

Layout: ``<dir>/step_00000123/`` holding one raw-bytes blob per leaf plus a
``manifest.json`` with dtypes/shapes (raw bytes rather than .npy because the
extended dtypes — bfloat16 et al. — don't round-trip through the npy header).
A checkpoint directory is written under a temp name and ``os.replace``d into
place, so readers never observe a partial checkpoint and a crash mid-save
leaves the previous latest intact.

``restore`` rebuilds arrays against a reference pytree (treedef + leaf order
come from ``like``) and can place them onto explicit shardings — the reshard
path used when the mesh changes between runs (elastic restore).
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np

_PREFIX = "step_"
_MANIFEST = "manifest.json"


def _step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"{_PREFIX}{step:08d}")


def _list_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        if name.startswith(_PREFIX) and os.path.isfile(
                os.path.join(directory, name, _MANIFEST)):
            try:
                steps.append(int(name[len(_PREFIX):]))
            except ValueError:
                continue
    return sorted(steps)


def latest_step(directory: str) -> int | None:
    """Highest committed step in ``directory``, or None."""
    steps = _list_steps(directory)
    return steps[-1] if steps else None


def save(directory: str, step: int, tree, *, keep: int | None = None) -> str:
    """Write ``tree`` as checkpoint ``step``; returns the committed path.

    ``keep=N`` prunes to the N newest checkpoints after the commit.
    """
    os.makedirs(directory, exist_ok=True)
    leaves = jax.tree.leaves(tree)
    tmp = os.path.join(directory,
                       f".tmp_{_PREFIX}{step:08d}.{os.getpid()}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = {"step": step, "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.bin"
        with open(os.path.join(tmp, fname), "wb") as f:
            f.write(arr.tobytes())
        manifest["leaves"].append({"file": fname, "dtype": str(arr.dtype),
                                   "shape": list(arr.shape)})
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    final = _step_dir(directory, step)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)

    if keep is not None:
        for old in _list_steps(directory)[:-keep]:
            shutil.rmtree(_step_dir(directory, old), ignore_errors=True)
    return final


def restore(directory: str, like, *, step: int | None = None,
            shardings=None):
    """Load checkpoint ``step`` (default: latest) shaped like ``like``.

    Returns ``(tree, step)``. ``shardings``: optional pytree (matching
    ``like``) of jax Shardings; restored leaves are ``device_put`` onto them.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory!r}")
    path = _step_dir(directory, step)
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)

    like_leaves, treedef = jax.tree.flatten(like)
    entries = manifest["leaves"]
    if len(entries) != len(like_leaves):
        raise ValueError(
            f"checkpoint has {len(entries)} leaves, reference tree has "
            f"{len(like_leaves)}")
    leaves = []
    for entry in entries:
        with open(os.path.join(path, entry["file"]), "rb") as f:
            raw = f.read()
        arr = np.frombuffer(raw, dtype=jnp.dtype(entry["dtype"])
                            ).reshape(entry["shape"])
        leaves.append(jnp.asarray(arr))
    tree = treedef.unflatten(leaves)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    return tree, manifest["step"]

"""Gradient compression for cross-pod all-reduce.

Two codecs over gradient pytrees:
  * bf16 cast-through — halves DCI traffic, unbiased enough for AdamW (the
    m/v accumulation absorbs the rounding noise).
  * int8 with error feedback — 4× compression; the per-leaf quantization
    residual is carried to the next step and added back before quantizing,
    so the ACCUMULATED decompressed signal tracks the accumulated true
    gradient (the EF-SGD guarantee).

Compressed leaves are ``Int8Leaf(q, scale)`` NamedTuples — still a valid jax
pytree, so the compressed tree can cross a ``jax.jit`` / collective boundary
unchanged.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


def _is_float(x) -> bool:
    return hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating)


# --------------------------------------------------------------------------
# bf16 cast-through
# --------------------------------------------------------------------------


def compress_bf16(tree):
    """Cast float leaves to bf16 (non-float leaves pass through)."""
    return jax.tree.map(
        lambda x: x.astype(jnp.bfloat16) if _is_float(x) else x, tree)


def decompress_bf16(tree):
    """Cast float leaves back to f32."""
    return jax.tree.map(
        lambda x: x.astype(jnp.float32) if _is_float(x) else x, tree)


# --------------------------------------------------------------------------
# int8 with error feedback
# --------------------------------------------------------------------------


class Int8Leaf(NamedTuple):
    q: jnp.ndarray       # int8 codes, same shape as the gradient leaf
    scale: jnp.ndarray   # () f32 — per-leaf max-abs / 127


def _is_int8_leaf(x) -> bool:
    return isinstance(x, Int8Leaf)


def compress_int8(tree, err: Optional[object] = None):
    """Quantize float leaves to ``Int8Leaf`` with error feedback.

    ``err`` is the residual pytree returned by the previous call (None on
    the first step). Returns ``(compressed_tree, new_err)``.
    """
    if err is None:
        err = jax.tree.map(
            lambda x: jnp.zeros(x.shape if _is_float(x) else (), jnp.float32),
            tree)

    def one(g, e):
        if not _is_float(g):
            return g, jnp.zeros((), jnp.float32)
        g_eff = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(g_eff)) / 127.0, 1e-12)
        q = jnp.clip(jnp.round(g_eff / scale), -127, 127).astype(jnp.int8)
        residual = g_eff - q.astype(jnp.float32) * scale
        return Int8Leaf(q, scale), residual

    flat_g, treedef = jax.tree.flatten(tree)
    flat_e = treedef.flatten_up_to(err)
    pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    comp = treedef.unflatten([p[0] for p in pairs])
    new_err = treedef.unflatten([p[1] for p in pairs])
    return comp, new_err


def decompress_int8(tree):
    """Invert ``compress_int8`` (up to the quantization residual)."""
    return jax.tree.map(
        lambda x: x.q.astype(jnp.float32) * x.scale if _is_int8_leaf(x) else x,
        tree, is_leaf=_is_int8_leaf)

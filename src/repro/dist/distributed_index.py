"""Cluster-partitioned distributed JUNO search.

Scale-out shape (FusionANNS-style): the IVF CLUSTER dimension — centroids,
padded point-id lists and per-cluster PQ codes — is sharded over every mesh
axis, while queries, the PQ codebook and the density model are replicated.
Each shard runs the existing single-device masked-ADC / hit-count scan
(core/juno.py) over its ``local_nprobe`` nearest LOCAL clusters, then the
per-shard top-k candidate lists are all-gathered and merged with one global
static-shape ``lax.top_k`` — global point ids travel with the candidates, so
the merge is exact.

On a 1-device mesh this degenerates to plain ``search`` bit-for-bit: the
local stage IS ``_search_batch`` and the merge is a stable top-k over an
already-sorted list.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.density import DensityModel
from repro.core.ivf import IVFIndex
from repro.core.juno import (JunoIndexData, MutableIndexBase, SideBuffer,
                             _label_encode, _search_batch,
                             _search_batch_two_stage)
from repro.core.pq import PQCodebook


def _cluster_entry(mesh: Mesh):
    """Shard the cluster dim over ALL mesh axes (pure scale-out)."""
    axes = tuple(mesh.axis_names)
    return axes if len(axes) > 1 else axes[0]


def index_pspecs(mesh: Mesh) -> JunoIndexData:
    """JunoIndexData-shaped tree of PartitionSpecs for the sharded index."""
    c = _cluster_entry(mesh)
    return JunoIndexData(
        ivf=IVFIndex(
            centroids=P(c, None),
            centroid_sq=P(c),
            point_ids=P(c, None),
            valid=P(c, None),
            labels=P(None)),
        codebook=PQCodebook(entries=P(None, None, None),
                            entry_sq=P(None, None)),
        codes=P(None, None),
        cluster_codes=P(c, None, None),
        density=DensityModel(grid=P(None, None, None), lo=P(None, None),
                             hi=P(None, None), coeffs=P(None),
                             tau_min=P(), tau_max=P()),
        points_sq=P(None))


def shard_index(idx: JunoIndexData, mesh: Mesh) -> JunoIndexData:
    """Place a built index on the mesh: cluster-partitioned arrays sharded,
    everything else replicated. Point ids stay GLOBAL, so shard-local results
    need no re-indexing at merge time."""
    specs = index_pspecs(mesh)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), idx, specs)


def side_pspecs() -> SideBuffer:
    """SideBuffer-shaped tree of PartitionSpecs: fully replicated (the buffer
    is tiny; every shard scores the slice owned by its probed clusters)."""
    return SideBuffer(codes=P(None, None), cluster=P(None), ids=P(None),
                      valid=P(None))


def grid_pspecs():
    """CentroidGrid-shaped tree of PartitionSpecs: fully replicated.

    The rt grid indexes GLOBAL cluster ids and is a few KB of cell
    tables, so every shard carries the whole thing and localizes its
    probe lookups with a cluster-id offset (see
    ``make_distributed_search(prefilter="rt")``).
    """
    from repro.rt import CentroidGrid
    return CentroidGrid(
        proj=P(None, None), lo=P(None), hi=P(None), boxes=P(None, None),
        cell_ids=P(None, None), cell_c0=P(None, None), cell_c1=P(None, None),
        slot_reach=P(None, None), cell_reach=P(None), slot_of=P(None),
        radius_scale=P(), radius_bias=P())


def make_distributed_search(mesh: Mesh, local_nprobe: int, k: int, *,
                            mode: str = "H", metric: str = "l2",
                            thres_scale: float = 1.0, impl: str = "ref",
                            rerank: int = 0, fused: bool = False,
                            fused3: bool | None = None,
                            with_side: bool = False,
                            prefilter: str = "scan", rt_scale: float = 1.0):
    """Build ``dsearch(sharded_index, queries[, side][, rt_grid])``.

    ``local_nprobe`` is the probe budget PER SHARD (global work scales with
    the mesh, matching the paper's fixed per-chip scan cost). The returned
    callable is jitted, so ``dsearch.lower(...)`` works for the dry-run.

    ``fused=True`` (mode "H2" only) runs each shard's two-stage scan
    through the fused hit-count→masked-ADC kernel path — per-shard results,
    and therefore the exact global merge, are id-identical to the composed
    path (core/juno.py). Combined with ``prefilter="rt"`` each shard
    serves the single-residency three-stage kernel (the shard's probes
    look up the replicated grid at ``local_cid + shard_offset`` inside
    the kernel, same offset rule as the composed path); ``fused3=False``
    forces the composed rt+fused baseline, bit-identically.

    With ``with_side=True`` the callable takes a replicated
    :class:`SideBuffer` of online-insert overflow as a third argument: each
    shard localizes the buffer's GLOBAL owning-cluster ids into its own
    cluster range (ids owned by other shards localize out of [0, C_local)
    and can never match a probed local cluster), so every side point is
    scored by exactly the shard that owns its cluster — the same routing
    rule inserts follow.

    With ``prefilter="rt"`` the callable takes a replicated
    :class:`repro.rt.CentroidGrid` as its LAST argument: the grid indexes
    global cluster ids, so each shard runs the identical
    sphere-intersection filter and looks its local probes up at
    ``local_cid + shard_offset`` — the pruning decision for any cluster
    is the same on every shard, and the exact global merge is unchanged
    up to which probes each shard masked out (at full-coverage radii the
    results match ``prefilter="scan"`` exactly).
    """
    if fused and mode != "H2":
        raise ValueError(f"fused=True requires mode='H2', got mode={mode!r}")
    if prefilter not in ("scan", "rt"):
        raise ValueError(f"unknown prefilter {prefilter!r}")
    axes = tuple(mesh.axis_names)
    gather_axes = axes if len(axes) > 1 else axes[0]
    specs = index_pspecs(mesh)
    # sign convention of core/juno.py: H/H2 report real distances (lower is
    # better for l2); hit-count modes report counts (higher is better).
    higher_better = metric == "ip" if mode in ("H", "H2") else True

    def local_search(idx: JunoIndexData, queries: jnp.ndarray, *rest):
        """Per-shard scan over local clusters + exact all-gather merge."""
        rest = list(rest)
        side = rest.pop(0) if with_side else None
        rt_grid = rest.pop(0) if prefilter == "rt" else None
        n_local = idx.ivf.centroids.shape[0]
        lin = jnp.int32(0)
        for ax in axes:
            lin = lin * mesh.shape[ax] + jax.lax.axis_index(ax)
        if side is not None:
            side = side._replace(cluster=side.cluster - lin * n_local)
        rt_kw = {}
        if prefilter == "rt":
            rt_kw = dict(prefilter="rt", rt_grid=rt_grid, rt_scale=rt_scale,
                         rt_offset=lin * n_local)
        if mode == "H2":
            s, ids = _search_batch_two_stage(
                idx, queries, nprobe=local_nprobe, k=k, metric=metric,
                thres_scale=thres_scale, rerank=rerank, impl=impl,
                fused=fused, fused3=fused3, side=side, **rt_kw)
        else:
            s, ids = _search_batch(
                idx, queries, nprobe=local_nprobe, k=k, mode=mode,
                metric=metric, thres_scale=thres_scale, impl=impl, side=side,
                **rt_kw)
        nq = queries.shape[0]
        key = s if higher_better else -s
        keys = jax.lax.all_gather(key, gather_axes)       # (shards, Q, k)
        gids = jax.lax.all_gather(ids, gather_axes)
        flat_key = jnp.swapaxes(keys, 0, 1).reshape(nq, -1)
        flat_ids = jnp.swapaxes(gids, 0, 1).reshape(nq, -1)
        sel_key, sel = jax.lax.top_k(flat_key, k)
        out_ids = jnp.take_along_axis(flat_ids, sel, axis=1)
        out_scores = sel_key if higher_better else -sel_key
        return out_scores, out_ids

    in_specs = (specs, P(None, None))
    if with_side:
        in_specs = in_specs + (side_pspecs(),)
    if prefilter == "rt":
        in_specs = in_specs + (grid_pspecs(),)
    fn = shard_map(local_search, mesh=mesh, in_specs=in_specs,
                   out_specs=(P(None, None), P(None, None)),
                   check_rep=False)
    return jax.jit(fn)


def make_distributed_insert(mesh: Mesh):
    """Jitted ``apply(idx, clusters, slots, ids, codes) -> idx`` scatter.

    The scatter targets rows of the cluster-sharded arrays, so XLA routes
    each update to the shard that owns the cluster — inserts are "routed by
    owning cluster" with no resharding and no shape change (hot jitted
    search signatures stay warm). Output shardings are pinned to the input
    layout.
    """
    specs = index_pspecs(mesh)
    out_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                          is_leaf=lambda x: isinstance(x, P))

    def apply(idx: JunoIndexData, clusters, slots, ids, codes):
        """Scatter new (id, code) cells into their owning clusters."""
        ivf = idx.ivf._replace(
            point_ids=idx.ivf.point_ids.at[clusters, slots].set(ids),
            valid=idx.ivf.valid.at[clusters, slots].set(True))
        return idx._replace(
            ivf=ivf,
            cluster_codes=idx.cluster_codes.at[clusters, slots].set(codes))

    return jax.jit(apply, out_shardings=out_sh)


def make_distributed_row_update(mesh: Mesh):
    """Jitted whole-row scatter ``apply(idx, clusters, ids, valid, codes)``.

    The rebuild counterpart of :func:`make_distributed_insert`: instead of
    touching single (cluster, slot) cells it replaces ENTIRE padded rows
    (point ids, valid mask and PQ codes) of the given clusters — XLA
    routes each row to the shard owning it, so a per-shard rebuild is one
    scatter with no resharding and no shape change.
    """
    specs = index_pspecs(mesh)
    out_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                          is_leaf=lambda x: isinstance(x, P))

    def apply(idx: JunoIndexData, clusters, row_ids, row_valid, row_codes):
        """Replace whole padded rows of the given clusters."""
        ivf = idx.ivf._replace(
            point_ids=idx.ivf.point_ids.at[clusters].set(row_ids),
            valid=idx.ivf.valid.at[clusters].set(row_valid))
        return idx._replace(
            ivf=ivf,
            cluster_codes=idx.cluster_codes.at[clusters].set(row_codes))

    return jax.jit(apply, out_shardings=out_sh)


def make_distributed_delete(mesh: Mesh):
    """Jitted ``apply(idx, clusters, slots) -> idx`` tombstone scatter."""
    specs = index_pspecs(mesh)
    out_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                          is_leaf=lambda x: isinstance(x, P))

    def apply(idx: JunoIndexData, clusters, slots):
        """Clear the valid bit of the given (cluster, slot) cells."""
        ivf = idx.ivf._replace(
            valid=idx.ivf.valid.at[clusters, slots].set(False))
        return idx._replace(ivf=ivf)

    return jax.jit(apply, out_shardings=out_sh)


class DistributedMutableIndex(MutableIndexBase):
    """Sharded, online-mutable JUNO index (the serving-scale counterpart of
    :class:`repro.core.MutableJunoIndex`).

    Data plane: cluster-sharded :class:`JunoIndexData` + replicated
    :class:`SideBuffer`; searches go through ``make_distributed_search(...,
    with_side=True)`` which merges per-shard top-k exactly. Control plane:
    the host-side slot bookkeeping inherited from
    :class:`~repro.core.juno.MutableIndexBase`, with device updates applied
    by the routed scatter updaters above — each insert/delete lands on the
    shard owning its cluster, and ``compact()`` (also inherited) folds the
    replicated side buffer back through the same routed scatter.

    Pass ``rt_grid`` (built from the UNSHARDED index via ``rt.build_grid``)
    to serve ``prefilter="rt"`` searches: inserts then grow the touched
    clusters' projected reaches exactly as :class:`MutableJunoIndex` does,
    and callers hand the CURRENT ``self.rt_grid`` to the callable returned
    by ``searcher(..., prefilter="rt")`` so mutated reaches take effect.
    """

    def __init__(self, idx: JunoIndexData, mesh: Mesh, *,
                 side_capacity: int = 256, rt_grid=None):
        """Shard a built global index onto ``mesh`` and wire its updaters."""
        n_clusters = idx.ivf.point_ids.shape[0]
        n_shards = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
        assert n_clusters % n_shards == 0, \
            f"clusters ({n_clusters}) must divide evenly over {n_shards} shards"
        self.mesh = mesh
        self.n_shards = n_shards
        self.data = shard_index(idx, mesh)
        self.rt_grid = rt_grid
        self._insert_fn = make_distributed_insert(mesh)
        self._delete_fn = make_distributed_delete(mesh)
        self._row_update_fn = make_distributed_row_update(mesh)
        # replicated small arrays for insert-time encoding
        self._centroids = idx.ivf.centroids
        self._codebook = idx.codebook
        self._init_bookkeeping(idx.ivf.valid, idx.ivf.point_ids,
                               side_capacity=side_capacity,
                               first_new_id=int(idx.codes.shape[0]),
                               n_subspaces=int(idx.codes.shape[1]))

    def _labels_codes(self, pts):
        return _label_encode(pts, self._centroids, self._codebook)

    def _rt_centroids(self):
        """Replicated centroids (the grid indexes GLOBAL cluster ids)."""
        return self._centroids

    def _apply_insert(self, cl, sl, ids, codes):
        self.data = self._insert_fn(self.data, jnp.asarray(cl),
                                    jnp.asarray(sl), jnp.asarray(ids), codes)

    def _apply_delete(self, cl, sl):
        self.data = self._delete_fn(self.data, jnp.asarray(cl),
                                    jnp.asarray(sl))

    def searcher(self, local_nprobe: int, k: int, **kw):
        """Side-aware distributed search callable for this index's mesh."""
        return make_distributed_search(self.mesh, local_nprobe, k,
                                       with_side=True, **kw)

    def merge_lanes(self) -> list[tuple[int, int]]:
        """Per-shard cluster ranges for the LSM merge scheduler.

        ``repro.core.freshness.MergeScheduler`` detects this hook and
        steps one lane per call, round-robin — each incremental fold's
        row scatter then lands on a single shard, giving the per-shard
        background-merge schedule without a scheduler object per shard.
        """
        n_clusters = self.data.ivf.point_ids.shape[0]
        cl = n_clusters // self.n_shards
        return [(s * cl, (s + 1) * cl) for s in range(self.n_shards)]

    # ---- rebuild / hot swap ---------------------------------------------
    def swap_data(self, new_data: JunoIndexData, *,
                  side_capacity: int | None = None) -> None:
        """Atomically install a rebuilt global index on this mesh.

        The distributed counterpart of
        :meth:`repro.core.MutableJunoIndex.swap_data`: the new index is
        cluster-sharded onto the mesh, slot bookkeeping is rederived
        from its ``point_ids``/``valid``, the side buffer resets to
        empty, the id watermark is preserved, and any attached rt grid
        is dropped (rebuild it from the new index when serving
        ``prefilter="rt"``). A capacity change retraces the jitted
        search/update programs on first use; an unchanged capacity
        keeps them warm.

        Parameters
        ----------
        new_data : JunoIndexData
            Replacement GLOBAL (unsharded) index; ``n_clusters`` must
            still divide over the mesh and point ids must be global.
        side_capacity : int, optional
            Capacity of the fresh side buffer (default: keep current).
        """
        first_new = max(
            self._next_id,
            int(np.asarray(new_data.ivf.point_ids).max(initial=-1)) + 1)
        self.data = shard_index(new_data, self.mesh)
        self.rt_grid = None
        self._centroids = new_data.ivf.centroids
        self._codebook = new_data.codebook
        self._init_bookkeeping(
            new_data.ivf.valid, new_data.ivf.point_ids,
            side_capacity=(self.side.capacity if side_capacity is None
                           else side_capacity),
            first_new_id=first_new,
            n_subspaces=int(new_data.codes.shape[1]))

    def rebuild_shard(self, shard: int) -> int:
        """Re-pack one cluster shard in place: drop tombstones, drain side.

        For every cluster owned by ``shard``, live in-cluster points are
        compacted to the front of their padded row (slot order preserved)
        and side-buffer points owned by those clusters are re-encoded
        into the freed slots (buffer order). The padded capacity is FIXED
        here — the (C, P) array shape is shared across shards — so
        spills that do not fit stay in the buffer; :meth:`rebuild`
        escalates those to a capacity-growing full swap. The whole shard
        lands on the device in ONE routed row scatter
        (:func:`make_distributed_row_update`), so the other shards — and
        every jitted search signature — are untouched while this shard
        rebuilds. Search results are unchanged by construction: a side
        point was already scored exactly like the in-cluster sibling it
        becomes.

        Parameters
        ----------
        shard : int
            Shard position in ``[0, n_shards)`` (clusters
            ``[shard*C/n, (shard+1)*C/n)``).

        Returns
        -------
        int
            Side-buffer points drained into this shard's clusters.
        """
        from repro.build.rebuild import live_points

        n_clusters = self.data.ivf.point_ids.shape[0]
        cl = n_clusters // self.n_shards
        lo, hi = shard * cl, (shard + 1) * cl
        point_ids = np.asarray(self.data.ivf.point_ids)
        valid = np.asarray(self.data.ivf.valid)
        cluster_codes = np.asarray(self.data.cluster_codes)
        cap = point_ids.shape[1]
        n_sub = cluster_codes.shape[-1]

        members = live_points(self, point_ids, valid, cluster_codes,
                              clusters=range(lo, hi))
        row_ids = np.full((cl, cap), -1, np.int32)
        row_codes = np.zeros((cl, cap, n_sub), np.uint8)
        for c in range(lo, hi):
            packed = members[c][:cap]      # overflow spills stay in side
            for slot, (pid, code) in enumerate(packed):
                row_ids[c - lo, slot] = pid
                row_codes[c - lo, slot] = code
                self._loc[pid] = (c, slot)
            self._free[c] = list(range(len(packed), cap))[::-1]
        # a side id that now has an in-cluster location frees its buffer slot
        side_ids = np.asarray(self.side.ids)
        side_valid = np.asarray(self.side.valid)
        freed_pos = [int(pos) for pos in np.where(side_valid)[0]
                     if self._loc.get(int(side_ids[pos]), (-1, -1))[0] >= 0]
        if freed_pos:
            pos_j = jnp.asarray(freed_pos)
            self.side = self.side._replace(
                valid=self.side.valid.at[pos_j].set(False))
            self._side_free.extend(freed_pos)
        # likewise, minor-generation points packed into this shard's base
        # rows are tombstoned in their generation (drained generations drop)
        freed_minor = 0
        for m in self._minors:
            mpos = [int(p) for p in np.where(m.valid)[0]
                    if self._loc.get(int(m.ids[p]), (-1, -1))[0] >= 0]
            if mpos:
                m.valid[np.asarray(mpos)] = False
                freed_minor += len(mpos)
        if freed_minor:
            self._minors = [m for m in self._minors if m.live]
        if freed_pos or freed_minor:
            self._delta_epoch += 1
        self.data = self._row_update_fn(
            self.data, np.arange(lo, hi, dtype=np.int32), row_ids,
            row_ids >= 0, row_codes)
        return len(freed_pos) + freed_minor

    def rebuild(self) -> int:
        """Drain the side buffer: per-shard repacks, then grow if stuck.

        Rebuilds every shard in sequence (:meth:`rebuild_shard` — cheap,
        fixed capacity, jit signatures stay warm). Spills whose owning
        cluster is still full afterwards cannot fit the fixed padded
        capacity, so they escalate to a full
        ``repro.build.rebuild.rebuild_index`` + :meth:`swap_data` —
        capacity grows and the buffer always ends empty, matching the
        single-device ``AnnServeEngine.compact()`` guarantee.

        Returns
        -------
        int
            Total side-buffer points drained (per-shard + escalation).
        """
        drained = sum(self.rebuild_shard(s) for s in range(self.n_shards))
        stuck = self.delta_fill      # L0 + minor points the repack left
        if stuck:
            from repro.build.rebuild import rebuild_index
            self.swap_data(rebuild_index(self))
            drained += stuck
        return drained

"""Cluster-partitioned distributed JUNO search.

Scale-out shape (FusionANNS-style): the IVF CLUSTER dimension — centroids,
padded point-id lists and per-cluster PQ codes — is sharded over every mesh
axis, while queries, the PQ codebook and the density model are replicated.
Each shard runs the existing single-device masked-ADC / hit-count scan
(core/juno.py) over its ``local_nprobe`` nearest LOCAL clusters, then the
per-shard top-k candidate lists are all-gathered and merged with one global
static-shape ``lax.top_k`` — global point ids travel with the candidates, so
the merge is exact.

On a 1-device mesh this degenerates to plain ``search`` bit-for-bit: the
local stage IS ``_search_batch`` and the merge is a stable top-k over an
already-sorted list.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.density import DensityModel
from repro.core.ivf import IVFIndex
from repro.core.juno import (JunoIndexData, _search_batch,
                             _search_batch_two_stage)
from repro.core.pq import PQCodebook


def _cluster_entry(mesh: Mesh):
    """Shard the cluster dim over ALL mesh axes (pure scale-out)."""
    axes = tuple(mesh.axis_names)
    return axes if len(axes) > 1 else axes[0]


def index_pspecs(mesh: Mesh) -> JunoIndexData:
    """JunoIndexData-shaped tree of PartitionSpecs for the sharded index."""
    c = _cluster_entry(mesh)
    return JunoIndexData(
        ivf=IVFIndex(
            centroids=P(c, None),
            centroid_sq=P(c),
            point_ids=P(c, None),
            valid=P(c, None),
            labels=P(None)),
        codebook=PQCodebook(entries=P(None, None, None),
                            entry_sq=P(None, None)),
        codes=P(None, None),
        cluster_codes=P(c, None, None),
        density=DensityModel(grid=P(None, None, None), lo=P(None, None),
                             hi=P(None, None), coeffs=P(None),
                             tau_min=P(), tau_max=P()),
        points_sq=P(None))


def shard_index(idx: JunoIndexData, mesh: Mesh) -> JunoIndexData:
    """Place a built index on the mesh: cluster-partitioned arrays sharded,
    everything else replicated. Point ids stay GLOBAL, so shard-local results
    need no re-indexing at merge time."""
    specs = index_pspecs(mesh)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), idx, specs)


def make_distributed_search(mesh: Mesh, local_nprobe: int, k: int, *,
                            mode: str = "H", metric: str = "l2",
                            thres_scale: float = 1.0, impl: str = "ref",
                            rerank: int = 0):
    """Build ``dsearch(sharded_index, queries) -> (scores, ids)``.

    ``local_nprobe`` is the probe budget PER SHARD (global work scales with
    the mesh, matching the paper's fixed per-chip scan cost). The returned
    callable is jitted, so ``dsearch.lower(...)`` works for the dry-run.
    """
    axes = tuple(mesh.axis_names)
    gather_axes = axes if len(axes) > 1 else axes[0]
    specs = index_pspecs(mesh)
    # sign convention of core/juno.py: H/H2 report real distances (lower is
    # better for l2); hit-count modes report counts (higher is better).
    higher_better = metric == "ip" if mode in ("H", "H2") else True

    def local_search(idx: JunoIndexData, queries: jnp.ndarray):
        if mode == "H2":
            s, ids = _search_batch_two_stage(
                idx, queries, nprobe=local_nprobe, k=k, metric=metric,
                thres_scale=thres_scale, rerank=rerank, impl=impl)
        else:
            s, ids = _search_batch(
                idx, queries, nprobe=local_nprobe, k=k, mode=mode,
                metric=metric, thres_scale=thres_scale, impl=impl)
        nq = queries.shape[0]
        key = s if higher_better else -s
        keys = jax.lax.all_gather(key, gather_axes)       # (shards, Q, k)
        gids = jax.lax.all_gather(ids, gather_axes)
        flat_key = jnp.swapaxes(keys, 0, 1).reshape(nq, -1)
        flat_ids = jnp.swapaxes(gids, 0, 1).reshape(nq, -1)
        sel_key, sel = jax.lax.top_k(flat_key, k)
        out_ids = jnp.take_along_axis(flat_ids, sel, axis=1)
        out_scores = sel_key if higher_better else -sel_key
        return out_scores, out_ids

    fn = shard_map(local_search, mesh=mesh,
                   in_specs=(specs, P(None, None)),
                   out_specs=(P(None, None), P(None, None)),
                   check_rep=False)
    return jax.jit(fn)

"""Distribution substrate: activation sharding, the cluster-partitioned
distributed JUNO index, checkpointing, fault tolerance and gradient
compression.

Mesh axes convention (shared with launch/mesh.py):
  * "pod"   — outermost data-parallel axis (multi-pod meshes only)
  * "data"  — data parallel / FSDP axis
  * "model" — tensor/expert/sequence parallel axis
The distributed ANN index shards its CLUSTER dimension over every mesh axis
(a pure scale-out partition: each chip owns C/n_chips inverted lists).

Every module degrades gracefully on a single device: ``sharding`` helpers
are identity until ``enable()`` is called, and the index/checkpoint paths
work on a trivial 1-device mesh.
"""
from . import checkpoint, compression, fault_tolerance, sharding  # noqa: F401
from .distributed_index import (index_pspecs, make_distributed_search,  # noqa: F401
                                shard_index)

"""End-to-end + unit tests for the JUNO core (paper Alg. 1/2 semantics)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (JunoConfig, build, search, exact_topk,
                        recall_1_at_k, recall_n_at_k)
from repro.core import lut as lut_lib
from repro.core import scan as scan_lib
from repro.core.ivf import build_ivf, filter_clusters
from repro.core.kmeans import kmeans, assign
from repro.core.pq import train_codebook, encode, decode
from repro.data import make_dataset, DEEP_LIKE, TTI_LIKE


@pytest.fixture(scope="module")
def small_l2():
    pts, q = make_dataset(DEEP_LIKE, 8000, 32, key=jax.random.PRNGKey(7))
    cfg = JunoConfig(n_clusters=32, n_entries=32, calib_queries=24,
                     kmeans_iters=5)
    idx = build(pts, cfg)
    gt_s, gt_i = exact_topk(q, pts, k=100, metric="l2")
    return pts, q, idx, gt_i


def test_kmeans_reduces_quantization_error():
    key = jax.random.PRNGKey(0)
    pts = jax.random.normal(key, (2000, 8))
    st1 = kmeans(pts, n_clusters=16, n_iters=1, key=key)
    st8 = kmeans(pts, n_clusters=16, n_iters=8, key=key)

    def qerr(c):
        lbl = assign(pts, c)
        return float(jnp.mean(jnp.sum((pts - c[lbl]) ** 2, -1)))

    assert qerr(st8.centroids) <= qerr(st1.centroids) + 1e-5
    assert jnp.all(jnp.isfinite(st8.centroids))


def test_dead_cluster_reseed_indices_are_distinct():
    """Regression: the old reseed map ``(init_idx * (i + 2) + 7) % n``
    sent DIFFERENT dead clusters to the SAME point whenever two init
    indices coincided mod ``n / gcd(i + 2, n)`` (e.g. init 1 and 5, n=12,
    iteration 1 both landed on point 10). The fixed map must be injective
    over cluster positions for every iteration whenever k <= n."""
    from repro.core.kmeans import _reseed_indices
    # the documented historical collision: old formula gave 1*3+7=10 and
    # 5*3+7=22%12=10 — same reseed point for two dead clusters
    assert (1 * 3 + 7) % 12 == (5 * 3 + 7) % 12
    for i in range(8):
        for n, k in [(12, 8), (10, 10), (100, 64), (9, 3)]:
            idx = np.asarray(_reseed_indices(i, n, k))
            assert len(set(idx.tolist())) == k, (i, n, k, idx)
            assert idx.min() >= 0 and idx.max() < n


def test_kmeans_many_dead_clusters_cover_data():
    """With heavily duplicated points (8 distinct coords tiled 8x) and
    k=16, duplicate init centroids leave ~half the clusters dead every
    iteration. Distinct reseed targets must still spread centroids over
    every distinct coordinate — a shared reseed point could not."""
    base = np.arange(8, dtype=np.float32)[:, None] * \
        np.array([100.0, -50.0], np.float32)[None, :]
    pts = jnp.asarray(np.tile(base, (8, 1)))          # tiled: any 16
    st = kmeans(pts, n_clusters=16, n_iters=6,        # consecutive rows
                key=jax.random.PRNGKey(0))            # cover all 8 coords
    cents = np.asarray(st.centroids)
    assert np.all(np.isfinite(cents))
    covered = [np.any(np.all(np.abs(cents - b[None]) < 1e-3, axis=1))
               for b in base]
    assert all(covered), covered


def test_assign_matches_bruteforce():
    key = jax.random.PRNGKey(1)
    pts = jax.random.normal(key, (500, 6))
    cents = jax.random.normal(jax.random.fold_in(key, 1), (37, 6))
    got = assign(pts, cents, chunk=128)
    want = jnp.argmin(jnp.sum((pts[:, None] - cents[None]) ** 2, -1), -1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_pq_roundtrip_reduces_error():
    key = jax.random.PRNGKey(2)
    res = jax.random.normal(key, (4000, 16))
    cb = train_codebook(res, n_entries=64, m=2, n_iters=8, key=key)
    codes = encode(res, cb)
    assert codes.shape == (4000, 8) and codes.dtype == jnp.uint8
    recon = decode(codes, cb)
    err = float(jnp.mean(jnp.sum((res - recon) ** 2, -1)))
    base = float(jnp.mean(jnp.sum(res ** 2, -1)))
    assert err < 0.5 * base  # quantization must explain >50% of energy


def test_ivf_every_point_stored_once():
    pts, _ = make_dataset(DEEP_LIKE, 3000, 4)
    ivf = build_ivf(pts, n_clusters=16, n_iters=4)
    ids = np.asarray(ivf.point_ids)
    stored = np.sort(ids[ids >= 0])
    np.testing.assert_array_equal(stored, np.arange(3000))


def test_filter_clusters_l2_matches_bruteforce():
    pts, q = make_dataset(DEEP_LIKE, 3000, 8)
    ivf = build_ivf(pts, n_clusters=16, n_iters=4)
    _, cids = filter_clusters(q, ivf, nprobe=4, metric="l2")
    d = jnp.sum((q[:, None] - ivf.centroids[None]) ** 2, -1)
    want = jnp.argsort(d, axis=1)[:, :4]
    assert set(np.asarray(cids)[0]) == set(np.asarray(want)[0])


def test_masked_lut_lower_bound_property():
    """Pruned entries must be substituted with a value >= any kept value's
    floor (tau^2): the substitution can only push pruned points further."""
    key = jax.random.PRNGKey(3)
    res = jax.random.normal(key, (4, 6, 2))  # (batch, S, M)
    cb = train_codebook(res.reshape(4, 12), n_entries=8, m=2, n_iters=4)
    tau = jnp.full((4, 6), 0.7)
    lutv, mask = lut_lib.build_lut(res, cb, tau, metric="l2")
    filled = lut_lib.masked_lut(lutv, mask, tau, metric="l2")
    assert bool(jnp.all(jnp.where(mask, filled == lutv, filled >= lutv * 0))), \
        "kept entries must be exact"
    assert bool(jnp.all(jnp.where(~mask, filled == (tau * tau)[..., None],
                                  True)))


def test_adc_scan_onehot_equivalence():
    key = jax.random.PRNGKey(4)
    lutv = jax.random.normal(key, (6, 16))
    codes = jax.random.randint(jax.random.fold_in(key, 1), (50, 6), 0, 16
                               ).astype(jnp.uint8)
    valid = jnp.arange(50) < 40
    a = scan_lib.adc_scan(lutv, codes, valid)
    b = scan_lib.adc_scan_onehot(lutv, codes, valid)
    np.testing.assert_allclose(np.asarray(a)[:40], np.asarray(b)[:40],
                               rtol=1e-5, atol=1e-5)
    assert np.all(np.isinf(np.asarray(a)[40:]))


def test_hit_count_modes():
    table_rp = jnp.array([[1, -1, 0], [0, 1, -1]], jnp.int8)
    codes = jnp.array([[0, 1], [1, 2], [2, 0]], jnp.uint8)
    valid = jnp.ones((3,), bool)
    got = scan_lib.hit_count_scan(table_rp, codes, valid)
    np.testing.assert_array_equal(np.asarray(got), [2, -2, 0])


def test_end_to_end_quality_ordering_l2(small_l2):
    pts, q, idx, gt_i = small_l2
    recalls = {}
    for mode in ["H", "M", "L"]:
        _, ids = search(idx, q, nprobe=8, k=100, mode=mode, metric="l2")
        recalls[mode] = float(recall_1_at_k(ids, gt_i[:, 0]))
    assert recalls["H"] >= 0.9, recalls
    assert recalls["H"] >= recalls["M"] >= recalls["L"] - 0.05, recalls


def test_threshold_scale_tradeoff(small_l2):
    """Paper Fig. 7(b)/13(b): smaller scale prunes more (recall can only
    drop), larger scale keeps more (recall can only rise)."""
    pts, q, idx, gt_i = small_l2
    r = {}
    for sc in [0.5, 1.0, 2.0]:
        _, ids = search(idx, q, nprobe=8, k=100, mode="H", thres_scale=sc)
        r[sc] = float(recall_n_at_k(ids, gt_i[:, :10]))
    assert r[2.0] >= r[1.0] >= r[0.5] - 0.02, r


def test_nprobe_monotonicity(small_l2):
    pts, q, idx, gt_i = small_l2
    r = {}
    for nprobe in [2, 8, 16]:
        _, ids = search(idx, q, nprobe=nprobe, k=100, mode="H")
        r[nprobe] = float(recall_1_at_k(ids, gt_i[:, 0]))
    assert r[16] >= r[8] >= r[2] - 0.02, r


def test_full_threshold_matches_plain_ivfpq(small_l2):
    """With an enormous threshold nothing is pruned: JUNO-H must equal the
    classic IVFPQ ADC result — the paper's baseline — exactly."""
    pts, q, idx, gt_i = small_l2
    _, ids_juno = search(idx, q, nprobe=16, k=50, mode="H", thres_scale=1e6)
    # classic IVFPQ reference: decode + exact residual ADC via the same LUT
    from repro.core.juno import _search_batch
    s2, ids2 = _search_batch(idx, q[:32], nprobe=16, k=50, mode="H",
                             metric="l2", thres_scale=1e6)
    np.testing.assert_array_equal(np.asarray(ids_juno)[:32], np.asarray(ids2))


def test_mips_end_to_end():
    pts, q = make_dataset(TTI_LIKE, 6000, 24, key=jax.random.PRNGKey(9))
    cfg = JunoConfig(n_clusters=32, n_entries=32, calib_queries=16,
                     kmeans_iters=5, metric="ip")
    idx = build(pts, cfg)
    _, gt_i = exact_topk(q, pts, k=100, metric="ip")
    _, ids = search(idx, q, nprobe=8, k=100, mode="H", metric="ip")
    assert float(recall_1_at_k(ids, gt_i[:, 0])) >= 0.5


def test_search_returns_sorted_and_valid(small_l2):
    pts, q, idx, gt_i = small_l2
    s, ids = search(idx, q, nprobe=8, k=20, mode="H")
    assert bool(jnp.all(ids >= 0)) and bool(jnp.all(ids < pts.shape[0]))
    assert bool(jnp.all(jnp.diff(s, axis=1) >= -1e-5))  # ascending L2

"""Autotune layer: cache round-trips, fail-closed loads, result parity.

The tuner (``kernels.autotune``) picks launch parameters, never results:
every knob it searches — (bQ, bP) tiling, top-C threshold implementation,
LUT accumulation dtype — is result-invariant by kernel contract, so a
tuned config must be bit-identical to the default one on both fused
kernels. The JSON cache is keyed on (schema, backend) and MUST fail
closed: a corrupt, stale, foreign-backend or schema-drifted file returns
``None`` (→ retune), never a silently misapplied config. The measured
``tune()`` search itself runs under the slow ``autotune`` marker (own CI
job); everything else here is deterministic tier 1.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.kernels import autotune
from repro.kernels.autotune import (KERNELS, KernelConfig, active_config,
                                    backend_name, candidates, ensure_tuned,
                                    load_cache, save_cache, set_config)


@pytest.fixture(autouse=True)
def _reset_active():
    autotune.reset()
    yield
    autotune.reset()


# ---------------------------------------------------------------------------
# config + candidate enumeration
# ---------------------------------------------------------------------------
def test_default_config_valid():
    cfg = KernelConfig()
    assert cfg.validate()
    assert active_config("fused_two_stage") == cfg
    assert active_config("fused_three_stage") == cfg


@pytest.mark.parametrize("bad", [
    dict(bq=0), dict(bq=-2), dict(bq=True), dict(bp=0), dict(bp=True),
    dict(topc_impl="quickselect"), dict(acc_dtype="f64"),
])
def test_config_validate_rejects(bad):
    assert not dataclasses.replace(KernelConfig(), **bad).validate()


def test_set_config_rejects_unknown_kernel():
    with pytest.raises(ValueError):
        set_config("fused_four_stage", KernelConfig())


def test_set_config_rejects_invalid_config():
    with pytest.raises(ValueError):
        set_config("fused_two_stage",
                   dataclasses.replace(KernelConfig(), acc_dtype="f64"))


def test_candidates_deduped_and_deterministic():
    """The search space collapses to the backend's effective knobs, keeps
    the first representative per effective key (deterministic tie-break),
    and always contains the default config."""
    for backend in ["cpu", "tpu"]:
        cs = candidates(backend)
        assert cs == candidates(backend)            # deterministic
        keys = [autotune._effective_key(c, backend) for c in cs]
        assert len(keys) == len(set(keys))          # deduped
        # the default path is always among the measured candidates
        assert autotune._effective_key(KernelConfig(), backend) in keys
    assert len(candidates("cpu")) == len(autotune.TOPC_IMPLS)


# ---------------------------------------------------------------------------
# cache round-trip: deterministic across runs
# ---------------------------------------------------------------------------
def test_cache_round_trip_deterministic(tmp_path):
    path = tmp_path / "autotune.json"
    configs = {"fused_two_stage": KernelConfig(bq=8, bp=128),
               "fused_three_stage": KernelConfig(topc_impl="topk",
                                                 acc_dtype="bf16")}
    save_cache(configs, path)
    blob1 = path.read_bytes()
    loaded = load_cache(path)
    assert loaded == configs
    save_cache(loaded, path)                         # save→load→save
    assert path.read_bytes() == blob1                # byte-identical
    assert blob1.endswith(b"\n")


def test_ensure_tuned_uses_cache_without_retuning(tmp_path, monkeypatch):
    """A valid cache short-circuits measurement entirely — ensure_tuned
    must install the cached configs and never call tune()."""
    path = tmp_path / "autotune.json"
    configs = {k: KernelConfig(bq=2, topc_impl="topk") for k in KERNELS}
    save_cache(configs, path)

    def boom(*a, **k):
        raise AssertionError("tune() ran despite a valid cache")
    monkeypatch.setattr(autotune, "tune", boom)
    got = ensure_tuned(path)
    assert got == configs
    for k in KERNELS:
        assert active_config(k) == configs[k]


# ---------------------------------------------------------------------------
# fail-closed loads: never misuse a stale/foreign/corrupt cache
# ---------------------------------------------------------------------------
def _valid_blob():
    return {"schema": autotune.SCHEMA_VERSION, "backend": backend_name(),
            "configs": {k: dataclasses.asdict(KernelConfig())
                        for k in KERNELS}}


def _corruptions():
    blob = _valid_blob()
    out = {"truncated-json": json.dumps(blob)[:-9],
           "not-a-dict": json.dumps([1, 2, 3]),
           "empty": ""}
    b = _valid_blob(); b["schema"] = autotune.SCHEMA_VERSION + 1
    out["schema-bump"] = json.dumps(b)
    b = _valid_blob(); b["backend"] = "definitely-not-" + backend_name()
    out["foreign-backend"] = json.dumps(b)
    b = _valid_blob(); b["configs"]["fused_four_stage"] = \
        dataclasses.asdict(KernelConfig())
    out["unknown-kernel"] = json.dumps(b)
    b = _valid_blob(); b["configs"][KERNELS[0]]["bq"] = -4
    out["invalid-field-value"] = json.dumps(b)
    b = _valid_blob(); b["configs"][KERNELS[0]]["block_q"] = \
        b["configs"][KERNELS[0]].pop("bq")
    out["field-set-drift"] = json.dumps(b)
    b = _valid_blob(); b["configs"][KERNELS[0]]["topc_impl"] = 7
    out["wrong-field-type"] = json.dumps(b)
    return out


@pytest.mark.parametrize("name", sorted(_corruptions()))
def test_load_fails_closed(tmp_path, name):
    path = tmp_path / "autotune.json"
    path.write_text(_corruptions()[name])
    assert load_cache(path) is None


def test_load_missing_file_is_none(tmp_path):
    assert load_cache(tmp_path / "nope.json") is None


def test_ensure_tuned_retunes_on_corrupt_cache(tmp_path, monkeypatch):
    """Corrupt cache → retune and REWRITE, never silently reuse."""
    path = tmp_path / "autotune.json"
    path.write_text("{not json")
    calls = []

    def fake_tune(kernel, **kw):
        calls.append(kernel)
        return KernelConfig()
    monkeypatch.setattr(autotune, "tune", fake_tune)
    got = ensure_tuned(path)
    assert sorted(calls) == sorted(KERNELS)
    assert load_cache(path) == got                   # rewritten, valid now


# ---------------------------------------------------------------------------
# tuned vs default: launch parameters must not change results
# ---------------------------------------------------------------------------
def _problem():
    rng = np.random.default_rng(0)
    q, n_probe, p, s, e, cap_c = 5, 3, 24, 6, 16, 12
    lut = rng.standard_normal((q, n_probe, s, e)).astype(np.float32)
    table = rng.integers(-1, 2, (q, n_probe, s, e)).astype(np.int8)
    codes = rng.integers(0, e, (q, n_probe, p, s)).astype(np.uint8)
    valid = rng.random((q, n_probe, p)) < 0.85
    return lut, table, codes, valid, cap_c


def test_tuned_configs_bit_identical_two_stage():
    """Every candidate config the tuner may pick returns bit-identical
    counts/cand and allclose distances from the two-stage kernel — on
    the host path (topc_impl) and the interpret-mode kernel (bq/bp/acc),
    i.e. the full effective-knob set of both backends."""
    from repro.kernels.fused_two_stage import (fused_two_stage,
                                               fused_two_stage_host)
    lut, table, codes, valid, cap_c = _problem()
    base_h = fused_two_stage_host(lut, table, codes, valid, cap_c=cap_c,
                                  metric="l2")
    base_k = fused_two_stage(lut, table, codes, valid, cap_c=cap_c,
                             metric="l2", interpret=True)
    for cfg in candidates("cpu") + candidates("tpu"):
        h = fused_two_stage_host(lut, table, codes, valid, cap_c=cap_c,
                                 metric="l2", topc_impl=cfg.topc_impl)
        k = fused_two_stage(lut, table, codes, valid, cap_c=cap_c,
                            metric="l2", bq=cfg.bq, bp=cfg.bp,
                            acc=cfg.acc_dtype, interpret=True)
        for base, got in [(base_h, h), (base_k, k)]:
            np.testing.assert_array_equal(np.asarray(base[0]),
                                          np.asarray(got[0]))
            np.testing.assert_array_equal(np.asarray(base[2]),
                                          np.asarray(got[2]))
            np.testing.assert_allclose(np.asarray(base[3]),
                                       np.asarray(got[3]), rtol=1e-5,
                                       atol=1e-5)


def test_tuned_configs_bit_identical_three_stage():
    """Same invariance for the three-stage kernel, probe verdicts
    included."""
    from repro.kernels.fused_three_stage import (fused_three_stage,
                                                 fused_three_stage_host)
    lut, table, codes, valid, cap_c = _problem()
    rng = np.random.default_rng(1)
    g, cap, q, n_probe = 3, 8, lut.shape[0], lut.shape[1]
    loxy = np.stack(np.meshgrid(np.arange(g), np.arange(g), indexing="ij"),
                    -1).reshape(-1, 2) / g
    boxes = np.concatenate([loxy, loxy + 1.0 / g], 1).astype(np.float32)
    c0 = rng.random((g * g, cap)).astype(np.float32)
    c1 = rng.random((g * g, cap)).astype(np.float32)
    reach = np.abs(rng.normal(0, 0.2, (g * g, cap))).astype(np.float32)
    reach[:, cap // 2:] = -np.inf
    args = (rng.random(q).astype(np.float32),
            rng.random(q).astype(np.float32),
            rng.random(q).astype(np.float32),
            boxes, reach.max(1), c0, c1, reach,
            rng.integers(0, g * g * cap, (q, n_probe)).astype(np.int32))
    base_h = fused_three_stage_host(
        lut, table, codes, valid, args[0], args[1], args[2], args[5],
        args[6], args[7], args[8], cap_c=cap_c, metric="l2")
    base_k = fused_three_stage(lut, table, codes, valid, *args,
                               cap_c=cap_c, metric="l2", interpret=True)
    for cfg in candidates("cpu") + candidates("tpu"):
        h = fused_three_stage_host(
            lut, table, codes, valid, args[0], args[1], args[2], args[5],
            args[6], args[7], args[8], cap_c=cap_c, metric="l2",
            topc_impl=cfg.topc_impl)
        k = fused_three_stage(lut, table, codes, valid, *args, cap_c=cap_c,
                              metric="l2", bq=cfg.bq, bp=cfg.bp,
                              acc=cfg.acc_dtype, interpret=True)
        for base, got in [(base_h, h), (base_k, k)]:
            np.testing.assert_array_equal(np.asarray(base[0]),
                                          np.asarray(got[0]))
            np.testing.assert_array_equal(np.asarray(base[2]),
                                          np.asarray(got[2]))
            np.testing.assert_array_equal(np.asarray(base[4]),
                                          np.asarray(got[4]))
            np.testing.assert_allclose(np.asarray(base[3]),
                                       np.asarray(got[3]), rtol=1e-5,
                                       atol=1e-5)


# ---------------------------------------------------------------------------
# the measured search itself (slow; own CI job)
# ---------------------------------------------------------------------------
@pytest.mark.autotune
def test_measured_tune_round_trips(tmp_path):
    """End-to-end: tune both kernels on the bundled micro-problems, cache,
    reload — the reloaded configs validate, match what was tuned, and a
    second ensure_tuned() run installs them without retuning."""
    path = tmp_path / "autotune.json"
    got = ensure_tuned(path, repeats=3)
    assert sorted(got) == sorted(KERNELS)
    for cfg in got.values():
        cfg.validate()
    assert load_cache(path) == got
    autotune.reset()
    again = ensure_tuned(path, repeats=3)
    assert again == got
    for k in KERNELS:
        assert active_config(k) == got[k]

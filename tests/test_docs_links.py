"""Docs-site integrity in tier 1: pages exist, intra-repo links resolve.

The docs-check CI job runs the same checker as a standalone gate
(``tools/check_links.py``); this test keeps "README links resolve and the
four docs pages exist" enforced wherever plain pytest runs.
"""
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "tools"))

import check_links  # noqa: E402


def test_docs_pages_exist():
    for page in ["index.md", "architecture.md", "kernels.md", "serving.md",
                 "building.md", "fleet.md", "benchmarks.md"]:
        assert os.path.exists(os.path.join(REPO, "docs", page)), page


def test_no_dead_intra_repo_links():
    files = check_links.default_files(REPO)
    assert any(f.endswith("README.md") for f in files)
    bad = check_links.dead_links(files)
    assert not bad, f"dead links: {bad}"


def test_no_orphan_docs_pages():
    """Every docs page is linked from README/DESIGN/another docs page —
    existence is not reachability (the docs-check CI job runs the same
    check standalone via --orphans)."""
    orphans = check_links.orphan_pages(REPO)
    assert not orphans, f"orphan docs pages: {orphans}"

"""AnnServeEngine: batching/routing correctness + mutability end-to-end.

The engine's contract: whatever batching, padding, coalescing, and knob
quantization happen inside, every request's rows are bit-equal to a direct
``search()`` call with the resolved signature (rows of ``_search_batch`` are
independent, so batch composition must not leak between requests).
"""
import jax
import numpy as np
import pytest

from repro.core import JunoConfig, MutableJunoIndex, build, search
from repro.data import DEEP_LIKE, make_dataset
from repro.serve.ann import AnnServeEngine


@pytest.fixture(scope="module")
def served():
    pts, q = make_dataset(DEEP_LIKE, 3000, 40, key=jax.random.PRNGKey(17))
    cfg = JunoConfig(n_clusters=16, n_entries=32, calib_queries=16,
                     kmeans_iters=4, capacity_mult=1.1)
    return np.asarray(pts), np.asarray(q), build(pts, cfg)


def test_engine_matches_direct_search(served):
    _, q, idx = served
    eng = AnnServeEngine(idx)
    reqs = [eng.submit(q[:5], k=10, mode="H", nprobe=8),
            eng.submit(q[5:9], k=10, mode="M", nprobe=8),
            eng.submit(q[9:10], k=50, mode="H2"),
            eng.submit(q[10:20], k=10, mode="L", nprobe=4)]
    assert eng.run() == 20
    for req in reqs:
        k, mode, nprobe = eng.route(req)
        s, ids = search(idx, req.queries, nprobe=nprobe, k=k, mode=mode,
                        batch=req.queries.shape[0])
        np.testing.assert_array_equal(np.asarray(ids)[:, :req.k], req.ids)
        np.testing.assert_array_equal(np.asarray(s)[:, :req.k], req.scores)


def test_engine_coalesces_same_signature(served):
    _, q, idx = served
    eng = AnnServeEngine(idx)
    for i in range(6):   # 6 requests, one signature → one tick
        eng.submit(q[i * 2:(i + 1) * 2], k=10, mode="H", nprobe=8)
    eng.run()
    assert eng.stats["ticks"] == 1
    assert eng.stats["requests"] == 6
    ((sig, count),) = eng.stats["signatures"].items()
    assert sig == (10, "H", 8, 32) and count == 1  # 12 rows → bucket 32


def test_fused_engine_routing_and_results(served):
    """fused=True: the H recall tier folds onto the H2 signature (one
    coalesced tick for a mixed H/H2 wave) and every request's rows stay
    bit-equal to a direct fused search with the engine's rerank budget."""
    _, q, idx = served
    eng = AnnServeEngine(idx, fused=True)
    r_h = eng.submit(q[:4], k=10, recall_target=0.95)    # H tier
    r_h2 = eng.submit(q[4:9], k=10, recall_target=0.85)  # H2 tier
    assert eng.route(r_h) == eng.route(r_h2) == (10, "H2", 16)
    eng.run()
    assert eng.stats["ticks"] == 1                       # coalesced
    for req in (r_h, r_h2):
        s, ids = search(idx, req.queries, nprobe=16, k=10, mode="H2",
                        fused=True, rerank=eng.FUSED_RERANK_MULT * 10,
                        batch=req.queries.shape[0])
        np.testing.assert_array_equal(np.asarray(ids), req.ids)
        np.testing.assert_array_equal(np.asarray(s), req.scores)
    # explicit-mode requests outside the high-recall tiers are untouched
    r_m = eng.submit(q[9:12], k=10, mode="M")
    assert eng.route(r_m)[1] == "M"


def test_router_recall_targets(served):
    _, q, idx = served
    eng = AnnServeEngine(idx)
    for target, want in [(0.99, "H"), (0.9, "H"), (0.85, "H2"),
                         (0.6, "M"), (0.2, "L")]:
        req = eng.submit(q[:1], recall_target=target)
        assert eng.route(req)[1] == want, (target, want)
    eng.queue.clear()


def test_knob_quantization(served):
    _, q, idx = served
    eng = AnnServeEngine(idx)
    req = eng.submit(q[:3], k=7, mode="H", nprobe=5)
    k, mode, nprobe = eng.route(req)
    assert (k, nprobe) == (10, 8)       # buckets, not raw knobs
    eng.run()
    assert req.ids.shape == (3, 7)      # sliced back to the requested k


def test_engine_insert_delete_visible(served):
    pts, q, idx = served
    eng = AnnServeEngine(idx)
    rng = np.random.default_rng(2)
    newpts = (q[:4] + 0.03 * rng.standard_normal(q[:4].shape)
              ).astype(np.float32)
    ids = eng.insert(newpts)
    req = eng.submit(newpts, k=10, mode="H", nprobe=16)
    eng.run()
    assert all(ids[j] in req.ids[j] for j in range(4))

    eng.delete(ids[:2])
    req2 = eng.submit(newpts[:2], k=10, mode="H", nprobe=16)
    eng.run()
    assert all(ids[j] not in req2.ids[j] for j in range(2))


def test_engine_spill_and_compact(served):
    """Overfill the tightest cluster through the engine: spilled points must
    be served from the side buffer, and compact() must fold them back."""
    pts, q, idx = served
    eng = AnnServeEngine(idx, side_capacity=32)
    mid = eng.index
    free = [mid.free_slots(c) for c in range(16)]
    c = int(np.argmin(free))
    cent = np.asarray(idx.ivf.centroids[c])
    rng = np.random.default_rng(4)
    newpts = (cent[None] + 0.02 * rng.standard_normal(
        (free[c] + 3, cent.shape[0]))).astype(np.float32)
    ids = eng.insert(newpts)
    assert mid.side_fill >= 3
    req = eng.submit(newpts, k=10, mode="H", nprobe=16)
    eng.run()
    assert all(ids[j] in req.ids[j] for j in range(len(ids)))

    # free slots, fold back, still retrievable (now from cluster storage)
    row_ids = np.asarray(mid.data.ivf.point_ids[c])
    row_valid = np.asarray(mid.data.ivf.valid[c])
    victims = [int(p) for p in row_ids[row_valid] if p < len(pts)][:3]
    eng.delete(victims)
    assert eng.compact() >= 3
    assert mid.side_fill == 0
    req2 = eng.submit(newpts, k=10, mode="H", nprobe=16)
    eng.run()
    assert all(ids[j] in req2.ids[j] for j in range(len(ids)))


def test_distributed_mutable_matches_single_device(served):
    """On a 1-device mesh the sharded mutable index must reproduce the
    single-device MutableJunoIndex bit-for-bit (insert + delete + side)."""
    from repro.dist.distributed_index import DistributedMutableIndex

    pts, q, idx = served
    mesh = jax.make_mesh((1,), ("data",))
    dmi = DistributedMutableIndex(idx, mesh, side_capacity=32)
    mid = MutableJunoIndex(idx, side_capacity=32)

    free = [mid.free_slots(c) for c in range(16)]
    c = int(np.argmin(free))
    cent = np.asarray(idx.ivf.centroids[c])
    rng = np.random.default_rng(9)
    newpts = (cent[None] + 0.02 * rng.standard_normal(
        (free[c] + 2, cent.shape[0]))).astype(np.float32)
    ids_d = dmi.insert(newpts)
    ids_s = mid.insert(newpts)
    assert ids_d == ids_s and dmi.side_fill == mid.side_fill >= 2
    dmi.delete(ids_d[:1])
    mid.delete(ids_s[:1])

    dsearch = dmi.searcher(local_nprobe=16, k=10, mode="H")
    s_d, i_d = dsearch(dmi.data, q[:16], dmi.side)
    s_s, i_s = mid.search(q[:16], nprobe=16, k=10, mode="H",
                          batch=16)
    np.testing.assert_array_equal(np.asarray(i_d), np.asarray(i_s))
    np.testing.assert_array_equal(np.asarray(s_d), np.asarray(s_s))

"""Hypothesis property tests on the system's invariants (core + substrate)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import density as density_lib
from repro.core import lut as lut_lib
from repro.core.metrics import recall_1_at_k, recall_n_at_k
from repro.core.pq import decode, encode, train_codebook
from repro.core.ref import exact_topk
from repro.models.mamba2 import ssd_chunked


@settings(max_examples=15, deadline=None)
@given(st.integers(10, 60), st.integers(2, 5), st.integers(0, 2 ** 31 - 1))
def test_exact_topk_is_exact(n, k, seed):
    """Streaming top-k == argsort of the full distance matrix."""
    key = jax.random.PRNGKey(seed)
    pts = jax.random.normal(key, (n, 6))
    q = jax.random.normal(jax.random.fold_in(key, 1), (3, 6))
    _, ids = exact_topk(q, pts, k=k, chunk=16)
    d = jnp.sum((q[:, None] - pts[None]) ** 2, -1)
    want = jnp.argsort(d, axis=1)[:, :k]
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 16), st.integers(0, 2 ** 31 - 1))
def test_pq_encode_decode_nearest(e, seed):
    """Each code must be the NEAREST entry: re-encoding a decoded vector is
    a fixed point (PQ idempotence)."""
    key = jax.random.PRNGKey(seed)
    res = jax.random.normal(key, (200, 8))
    cb = train_codebook(res, n_entries=e, m=2, n_iters=4, key=key)
    codes = encode(res, cb)
    recon = decode(codes, cb)
    codes2 = encode(recon, cb)
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(codes2))


@settings(max_examples=10, deadline=None)
@given(st.floats(0.1, 3.0), st.floats(1.05, 4.0),
       st.integers(0, 2 ** 31 - 1))
def test_mask_monotone_in_threshold(tau0, mult, seed):
    """Selection masks are monotone: a larger threshold keeps a superset."""
    key = jax.random.PRNGKey(seed)
    qsub = jax.random.normal(key, (3, 4, 2))
    cb_res = jax.random.normal(jax.random.fold_in(key, 1), (20, 8))
    cb = train_codebook(cb_res, n_entries=8, m=2, n_iters=3)
    t1 = jnp.full((3, 4), tau0)
    _, m1 = lut_lib.build_lut(qsub, cb, t1)
    _, m2 = lut_lib.build_lut(qsub, cb, t1 * mult)
    assert bool(jnp.all(m2 | ~m1)), "larger tau must keep a superset"


@settings(max_examples=10, deadline=None)
@given(st.integers(5, 40), st.integers(0, 2 ** 31 - 1))
def test_recall_metric_bounds_and_identity(k, seed):
    key = jax.random.PRNGKey(seed)
    gt = jax.random.permutation(key, jnp.arange(100))[None, :k]
    # retrieving exactly the ground truth → recall 1
    assert float(recall_n_at_k(gt, gt)) == 1.0
    assert float(recall_1_at_k(gt, gt[:, 0])) == 1.0
    # disjoint retrieval → recall 0
    other = gt + 1000
    assert float(recall_n_at_k(other, gt)) == 0.0


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 3), st.integers(8, 48), st.integers(0, 2 ** 31 - 1))
def test_ssd_chunk_invariance(b, t, seed):
    """SSD output must not depend on the chunk size (pure tiling)."""
    key = jax.random.PRNGKey(seed)
    h, p, g, n = 2, 4, 1, 3
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, t, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bb = jax.random.normal(ks[3], (b, t, g, n))
    cc = jax.random.normal(ks[4], (b, t, g, n))
    y8, s8 = ssd_chunked(x, dt, a, bb, cc, chunk=8)
    y16, s16 = ssd_chunked(x, dt, a, bb, cc, chunk=16)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y16),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s8), np.asarray(s16),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_density_grid_total_mass(seed):
    """Grid cell counts sum to N (no point lost/duplicated by binning)."""
    key = jax.random.PRNGKey(seed)
    pts = jax.random.normal(key, (4, 300, 2))
    grid, lo, hi = density_lib.build_density_grid(pts, grid_size=16)
    span = np.maximum(np.asarray(hi - lo), 1e-6)
    cell_area = (span[:, 0] / 16) * (span[:, 1] / 16)
    counts = (np.expm1(np.asarray(grid))
              * cell_area[:, None, None]).sum(axis=(1, 2))
    np.testing.assert_allclose(counts, 300.0, rtol=1e-3)


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 6), st.integers(0, 2 ** 31 - 1))
def test_hit_table_antisymmetry_bounds(s_dim, seed):
    """Reward/penalty tables: +1 ⊆ outer hits; -1 = complement of outer."""
    key = jax.random.PRNGKey(seed)
    qsub = jax.random.normal(key, (2, s_dim, 2))
    cb_res = jax.random.normal(jax.random.fold_in(key, 1), (40, 2 * s_dim))
    cb = train_codebook(cb_res, n_entries=8, m=2, n_iters=3)
    tau = jnp.abs(jax.random.normal(jax.random.fold_in(key, 2),
                                    (2, s_dim))) + 0.2
    lutv, mask = lut_lib.build_lut(qsub, cb, tau)
    table = lut_lib.hit_tables(lutv, mask, tau, mode="reward_penalty")
    t = np.asarray(table)
    m = np.asarray(mask)
    assert np.all((t == -1) == ~m)
    assert np.all((t == 1) <= m)


# ---------------------------------------------------------------------------
# Online mutability invariants (MutableJunoIndex)
# ---------------------------------------------------------------------------
import functools  # noqa: E402

from repro.core import JunoConfig, MutableJunoIndex, build  # noqa: E402
from repro.data import DEEP_LIKE, make_dataset  # noqa: E402


@functools.lru_cache(maxsize=1)
def _mutable_base():
    """One shared base index (hypothesis-wrapped tests can't take fixtures)."""
    pts, q = make_dataset(DEEP_LIKE, 2500, 8, key=jax.random.PRNGKey(21))
    cfg = JunoConfig(n_clusters=16, n_entries=16, calib_queries=12,
                     kmeans_iters=4, capacity_mult=1.05)
    return np.asarray(pts), np.asarray(q), build(pts, cfg)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_insert_then_search_finds_point(seed):
    """A freshly inserted point must be retrievable by its own vector."""
    pts, _, idx = _mutable_base()
    mid = MutableJunoIndex(idx, side_capacity=32)
    rng = np.random.default_rng(seed)
    base = pts[rng.integers(0, len(pts))]
    newpt = (base + 0.05 * rng.standard_normal(pts.shape[1])
             ).astype(np.float32)
    (pid,) = mid.insert(newpt[None])
    _, ids = mid.search(newpt[None], nprobe=16, k=10, mode="H")
    assert pid in np.asarray(ids)[0], (seed, pid)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from(["H", "M", "H2"]))
def test_delete_then_search_never_returns_id(seed, mode):
    """A tombstoned id must never appear again, in any scan mode."""
    pts, _, idx = _mutable_base()
    mid = MutableJunoIndex(idx)
    rng = np.random.default_rng(seed)
    pid = int(rng.integers(0, len(pts)))
    mid.delete([pid])
    _, ids = mid.search(pts[pid][None], nprobe=16, k=20, mode=mode)
    assert pid not in np.asarray(ids)[0], (seed, mode, pid)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from(["H", "M", "L"]))
def test_compact_is_search_noop(seed, mode):
    """compact() folds side-buffer points into freed cluster slots without
    changing any search result: the top-k is bit-identical up to the only
    freedom lax.top_k has — its index-order tie-break among EXACTLY equal
    scores (a moved point changes flat position, so equal-score runs may
    permute; e.g. two inserts that quantize to the same PQ codes tie
    bit-for-bit). Asserted: score vectors bit-identical, and the id set at
    every non-boundary score level identical."""
    pts, q, idx = _mutable_base()
    mid = MutableJunoIndex(idx, side_capacity=64)
    rng = np.random.default_rng(seed)
    free = [mid.free_slots(c) for c in range(16)]
    c = int(np.argmin(free))
    cent = np.asarray(idx.ivf.centroids[c])
    newpts = (cent[None] + 0.02 * rng.standard_normal(
        (free[c] + 2, cent.shape[0]))).astype(np.float32)
    mid.insert(newpts)
    assert mid.side_fill >= 2, "spill expected: tightest cluster overfilled"
    # tombstone two ORIGINAL members of that cluster → compact targets open up
    row_ids = np.asarray(mid.data.ivf.point_ids[c])
    row_valid = np.asarray(mid.data.ivf.valid[c])
    victims = [int(p) for p in row_ids[row_valid] if p < len(pts)][:2]
    mid.delete(victims)

    qq = np.concatenate([q, newpts[:2]], axis=0)
    s0, i0 = (np.asarray(x)
              for x in mid.search(qq, nprobe=8, k=20, mode=mode))
    moved = mid.compact()
    assert moved >= 2, "deletes freed slots, compact must use them"
    s1, i1 = (np.asarray(x)
              for x in mid.search(qq, nprobe=8, k=20, mode=mode))
    np.testing.assert_array_equal(s0, s1)
    for r in range(len(qq)):
        boundary = s0[r, -1]   # rank-k score: membership there is tie-broken
        for v in np.unique(s0[r][s0[r] != boundary]):
            assert (set(i0[r][s0[r] == v]) == set(i1[r][s1[r] == v])), \
                (seed, mode, r, float(v))

"""Hypothesis property tests on the system's invariants (core + substrate)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import density as density_lib
from repro.core import lut as lut_lib
from repro.core.metrics import recall_1_at_k, recall_n_at_k
from repro.core.pq import decode, encode, train_codebook
from repro.core.ref import exact_topk
from repro.models.mamba2 import ssd_chunked


@settings(max_examples=15, deadline=None)
@given(st.integers(10, 60), st.integers(2, 5), st.integers(0, 2 ** 31 - 1))
def test_exact_topk_is_exact(n, k, seed):
    """Streaming top-k == argsort of the full distance matrix."""
    key = jax.random.PRNGKey(seed)
    pts = jax.random.normal(key, (n, 6))
    q = jax.random.normal(jax.random.fold_in(key, 1), (3, 6))
    _, ids = exact_topk(q, pts, k=k, chunk=16)
    d = jnp.sum((q[:, None] - pts[None]) ** 2, -1)
    want = jnp.argsort(d, axis=1)[:, :k]
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 16), st.integers(0, 2 ** 31 - 1))
def test_pq_encode_decode_nearest(e, seed):
    """Each code must be the NEAREST entry: re-encoding a decoded vector is
    a fixed point (PQ idempotence)."""
    key = jax.random.PRNGKey(seed)
    res = jax.random.normal(key, (200, 8))
    cb = train_codebook(res, n_entries=e, m=2, n_iters=4, key=key)
    codes = encode(res, cb)
    recon = decode(codes, cb)
    codes2 = encode(recon, cb)
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(codes2))


@settings(max_examples=10, deadline=None)
@given(st.floats(0.1, 3.0), st.floats(1.05, 4.0),
       st.integers(0, 2 ** 31 - 1))
def test_mask_monotone_in_threshold(tau0, mult, seed):
    """Selection masks are monotone: a larger threshold keeps a superset."""
    key = jax.random.PRNGKey(seed)
    qsub = jax.random.normal(key, (3, 4, 2))
    cb_res = jax.random.normal(jax.random.fold_in(key, 1), (20, 8))
    cb = train_codebook(cb_res, n_entries=8, m=2, n_iters=3)
    t1 = jnp.full((3, 4), tau0)
    _, m1 = lut_lib.build_lut(qsub, cb, t1)
    _, m2 = lut_lib.build_lut(qsub, cb, t1 * mult)
    assert bool(jnp.all(m2 | ~m1)), "larger tau must keep a superset"


@settings(max_examples=10, deadline=None)
@given(st.integers(5, 40), st.integers(0, 2 ** 31 - 1))
def test_recall_metric_bounds_and_identity(k, seed):
    key = jax.random.PRNGKey(seed)
    gt = jax.random.permutation(key, jnp.arange(100))[None, :k]
    # retrieving exactly the ground truth → recall 1
    assert float(recall_n_at_k(gt, gt)) == 1.0
    assert float(recall_1_at_k(gt, gt[:, 0])) == 1.0
    # disjoint retrieval → recall 0
    other = gt + 1000
    assert float(recall_n_at_k(other, gt)) == 0.0


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 3), st.integers(8, 48), st.integers(0, 2 ** 31 - 1))
def test_ssd_chunk_invariance(b, t, seed):
    """SSD output must not depend on the chunk size (pure tiling)."""
    key = jax.random.PRNGKey(seed)
    h, p, g, n = 2, 4, 1, 3
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, t, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bb = jax.random.normal(ks[3], (b, t, g, n))
    cc = jax.random.normal(ks[4], (b, t, g, n))
    y8, s8 = ssd_chunked(x, dt, a, bb, cc, chunk=8)
    y16, s16 = ssd_chunked(x, dt, a, bb, cc, chunk=16)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y16),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s8), np.asarray(s16),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_density_grid_total_mass(seed):
    """Grid cell counts sum to N (no point lost/duplicated by binning)."""
    key = jax.random.PRNGKey(seed)
    pts = jax.random.normal(key, (4, 300, 2))
    grid, lo, hi = density_lib.build_density_grid(pts, grid_size=16)
    span = np.maximum(np.asarray(hi - lo), 1e-6)
    cell_area = (span[:, 0] / 16) * (span[:, 1] / 16)
    counts = (np.expm1(np.asarray(grid))
              * cell_area[:, None, None]).sum(axis=(1, 2))
    np.testing.assert_allclose(counts, 300.0, rtol=1e-3)


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 6), st.integers(0, 2 ** 31 - 1))
def test_hit_table_antisymmetry_bounds(s_dim, seed):
    """Reward/penalty tables: +1 ⊆ outer hits; -1 = complement of outer."""
    key = jax.random.PRNGKey(seed)
    qsub = jax.random.normal(key, (2, s_dim, 2))
    cb_res = jax.random.normal(jax.random.fold_in(key, 1), (40, 2 * s_dim))
    cb = train_codebook(cb_res, n_entries=8, m=2, n_iters=3)
    tau = jnp.abs(jax.random.normal(jax.random.fold_in(key, 2),
                                    (2, s_dim))) + 0.2
    lutv, mask = lut_lib.build_lut(qsub, cb, tau)
    table = lut_lib.hit_tables(lutv, mask, tau, mode="reward_penalty")
    t = np.asarray(table)
    m = np.asarray(mask)
    assert np.all((t == -1) == ~m)
    assert np.all((t == 1) <= m)

"""Per-kernel validation: shape/dtype sweeps + hypothesis property tests,
each asserting allclose against the pure-jnp oracle in repro.kernels.ref.
Kernels execute with interpret=True on CPU (real block iteration)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.pq_scan import pq_scan
from repro.kernels.hit_count import hit_count

pytestmark = pytest.mark.interpret


def _inputs(key, b, s, e, p, tau_scale=1.0):
    ks = jax.random.split(key, 6)
    qsub = jax.random.normal(ks[0], (b, s, 2))
    entries = jax.random.normal(ks[1], (s, e, 2))
    esq = jnp.sum(entries ** 2, -1)
    tau = jax.random.uniform(ks[2], (b, s), minval=0.3, maxval=2.0) * tau_scale
    codes = jax.random.randint(ks[3], (p, s), 0, e).astype(jnp.uint8)
    valid = jax.random.bernoulli(ks[4], 0.85, (p,))
    return qsub, entries, esq, tau, codes, valid


SHAPES = [  # (B, S, E, P) — covers non-divisible blocks, tiny/large E
    (8, 48, 256, 257),
    (16, 40, 128, 64),
    (3, 12, 64, 100),     # B not divisible by block
    (8, 100, 256, 130),   # S=100 (tti-like PQ100), odd P
    (1, 4, 16, 8),        # minimal
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_selective_lut_sweep(shape, metric):
    b, s, e, p = shape
    qsub, entries, esq, tau, *_ = _inputs(jax.random.PRNGKey(b * s), b, s, e, p)
    lut, hit = ops.build_selective_lut(qsub, entries, esq, tau, metric=metric)
    lut_r, hit_r = ref.selective_lut_ref(qsub[..., 0], qsub[..., 1],
                                         entries[..., 0], entries[..., 1],
                                         esq, tau, metric=metric)
    np.testing.assert_allclose(np.asarray(lut), np.asarray(lut_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(hit), np.asarray(hit_r))
    assert hit.dtype == jnp.int8


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_pq_scan_sweep(shape, metric):
    b, s, e, p = shape
    key = jax.random.PRNGKey(b + s + e)
    _, _, _, _, codes, valid = _inputs(key, b, s, e, p)
    lut = jax.random.normal(jax.random.fold_in(key, 5), (s, e))
    got = ops.masked_adc_scan(lut, codes, valid, metric=metric)
    want = ref.pq_scan_ref(lut, codes, valid, metric=metric)
    m = np.asarray(valid)
    np.testing.assert_allclose(np.asarray(got)[m], np.asarray(want)[m],
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(got)[~m], np.asarray(want)[~m])


@pytest.mark.parametrize("shape", SHAPES)
def test_hit_count_sweep(shape):
    b, s, e, p = shape
    key = jax.random.PRNGKey(7 * b + s)
    _, _, _, _, codes, valid = _inputs(key, b, s, e, p)
    table = jax.random.randint(jax.random.fold_in(key, 9), (s, e), -1, 2
                               ).astype(jnp.int8)
    got = ops.hit_count_scan(table, codes, valid)
    want = ref.hit_count_ref(table, codes, valid)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert got.dtype == jnp.int32


def test_pq_scan_batched_leading_dims():
    key = jax.random.PRNGKey(11)
    s, e, p = 12, 64, 50
    lut = jax.random.normal(key, (2, 3, s, e))
    codes = jax.random.randint(jax.random.fold_in(key, 1), (2, 3, p, s), 0, e
                               ).astype(jnp.uint8)
    valid = jnp.ones((2, 3, p), bool)
    got = ops.masked_adc_scan(lut, codes, valid)
    for i in range(2):
        for j in range(3):
            want = ref.pq_scan_ref(lut[i, j], codes[i, j], valid[i, j])
            np.testing.assert_allclose(np.asarray(got[i, j]),
                                       np.asarray(want), rtol=1e-5, atol=1e-4)


def test_block_size_invariance():
    """Result must not depend on the BlockSpec tiling — pure tiling property."""
    key = jax.random.PRNGKey(3)
    s, e, p = 16, 128, 192
    lut = jax.random.normal(key, (s, e))
    codes = jax.random.randint(jax.random.fold_in(key, 1), (p, s), 0, e
                               ).astype(jnp.uint8)
    valid = jnp.ones((p,), bool)
    outs = [pq_scan(lut, codes, valid, bp=bp, interpret=True)
            for bp in (32, 64, 192)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-6, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 6), st.integers(1, 8), st.integers(2, 5),
       st.integers(1, 70), st.integers(0, 2 ** 31 - 1))
def test_hit_count_property(b_blocks, s, log_e, p, seed):
    """Property: hit-count totals are bounded by ±S and exactly match the
    oracle for arbitrary shapes/seeds."""
    e = 2 ** log_e
    key = jax.random.PRNGKey(seed)
    codes = jax.random.randint(key, (p, s), 0, e).astype(jnp.uint8)
    table = jax.random.randint(jax.random.fold_in(key, 1), (s, e), -1, 2
                               ).astype(jnp.int8)
    valid = jnp.ones((p,), bool)
    got = hit_count(table, codes, valid, bp=min(32, p), interpret=True)
    want = ref.hit_count_ref(table, codes, valid)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert int(jnp.max(jnp.abs(got))) <= s


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 4), st.integers(1, 6), st.integers(1, 50),
       st.integers(0, 2 ** 31 - 1))
def test_selective_lut_mask_property(b, s, e, seed):
    """Property: every LUT value is <= tau^2 after masking (L2): kept values
    pass the threshold, pruned are substituted with exactly tau^2."""
    key = jax.random.PRNGKey(seed)
    qsub = jax.random.normal(key, (b, s, 2))
    entries = jax.random.normal(jax.random.fold_in(key, 1), (s, e, 2))
    esq = jnp.sum(entries ** 2, -1)
    tau = jax.random.uniform(jax.random.fold_in(key, 2), (b, s),
                             minval=0.1, maxval=3.0)
    lut, hit = ops.build_selective_lut(qsub, entries, esq, tau, metric="l2")
    assert bool(jnp.all(lut <= (tau * tau)[..., None] + 1e-5))
    # hit table values only in {-1, 0, 1}
    assert set(np.unique(np.asarray(hit))).issubset({-1, 0, 1})


@pytest.mark.parametrize("batched", [False, True])
def test_slab_onehot_dot_dtypes(batched):
    """Pin the MXU-path accumulation dtype of the shared SLAB one-hot
    helper: int32 for the hit-count path, f32 for the ADC path — and exact
    agreement with the plain-gather formulation in both."""
    key = jax.random.PRNGKey(21)
    s, e, p = 13, 32, 29                      # non-SLAB-multiple S
    lead = (3,) if batched else ()
    codes = jax.random.randint(key, (*lead, p, s), 0, e)
    tab_i = jax.random.randint(jax.random.fold_in(key, 1), (*lead, s, e),
                               -1, 2).astype(jnp.int8)
    tab_f = jax.random.normal(jax.random.fold_in(key, 2), (*lead, s, e))

    got_i = ops.slab_onehot_dot(codes, tab_i.astype(jnp.int32), n_entries=e,
                                out_dtype=jnp.int32)
    got_f = ops.slab_onehot_dot(codes, tab_f, n_entries=e,
                                out_dtype=jnp.float32)
    assert got_i.dtype == jnp.int32
    assert got_f.dtype == jnp.float32

    def gather_sum(tab):
        vals = jnp.take_along_axis(tab[..., None, :, :], codes[..., None],
                                   axis=-1)[..., 0]          # (..., P, S)
        return jnp.sum(vals, axis=-1)

    want_i = gather_sum(tab_i.astype(jnp.int32))
    want_f = gather_sum(tab_f)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    np.testing.assert_allclose(np.asarray(got_f), np.asarray(want_f),
                               rtol=1e-5, atol=1e-5)

    # f32 accumulation of small-int tables is still exact (the fused kernel
    # relies on this to share one one-hot between both stages)
    got_fi = ops.slab_onehot_dot(codes, tab_i.astype(jnp.float32),
                                 n_entries=e, out_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(got_fi).astype(np.int32),
                                  np.asarray(want_i))


@pytest.mark.parametrize("shape", [(64, 96, 128), (17, 40, 37),
                                   (128, 200, 300), (1, 8, 9)])
@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_ivf_filter_sweep(shape, metric):
    """4th kernel: fused filtering distances vs oracle (+ rank agreement
    with the exact L2 ordering, which is what stage A consumes)."""
    nq, d, c = shape
    key = jax.random.PRNGKey(nq + d + c)
    q = jax.random.normal(key, (nq, d))
    cents = jax.random.normal(jax.random.fold_in(key, 1), (c, d))
    csq = jnp.sum(cents ** 2, -1)
    got = ops.filter_scores(q, cents, csq, metric=metric)
    want = ref.ivf_filter_ref(q, cents, csq, metric=metric)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)
    if metric == "l2":  # rank-equivalence with true distances
        # tie-tolerant: the ordering induced by the kernel scores must be a
        # valid sort of the true distances (exact argsort equality is not
        # stable for centroid pairs closer than f32 resolution)
        true_d = np.asarray(jnp.sum((q[:, None] - cents[None]) ** 2, -1))
        true_at_rank = np.take_along_axis(
            true_d, np.argsort(np.asarray(got), axis=1), axis=1)
        np.testing.assert_allclose(true_at_rank, np.sort(true_d, axis=1),
                                   rtol=1e-5, atol=1e-4)

"""Paged (out-of-core) serving tier: `repro.serve.paged`.

The contract under test: an index committed to the artifact store can be
served memory-mapped — PQ code shards demand-paged through a bounded LRU
hot-cluster cache, centroid/grid metadata resident — with results
bit-identical to resident serving (the scoring tail is shared code, so
ids AND scores are equality-gated, even under eviction pressure). On top
of that sit the tier's own guarantees: per-cluster sha256 verification
on first touch (fail-closed — a flipped bit raises before it can serve),
an exact-rerank tier whose scores are true metric values from the raw
vectors, side-buffer-only mutability over the read-only shards, and
atomic generation swaps that retarget the cache without ever mixing
rows across generations.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.build import ArtifactError, ArtifactStore, save_index
from repro.core import (JunoConfig, build, exact_topk, recall_n_at_k,
                        search)
from repro.data import DEEP_LIKE, make_dataset
from repro.serve.ann import AnnServeEngine
from repro.serve.fleet import AnnServeFleet
from repro.serve.paged import (ClusterCache, PagedAnnServeEngine,
                               PagedIndexData, PagedJunoIndex)


@pytest.fixture(scope="module")
def paged_env(tmp_path_factory):
    pts, q = make_dataset(DEEP_LIKE, 6000, 32, key=jax.random.PRNGKey(5))
    pts, q = np.asarray(pts), np.asarray(q)
    cfg = JunoConfig(n_clusters=16, n_entries=16, calib_queries=12,
                     kmeans_iters=4, capacity_mult=1.2)
    idx = build(pts, cfg)
    root = tmp_path_factory.mktemp("paged")
    store = ArtifactStore(str(root / "store"))
    assert store.put("main", idx, cfg) == 1
    vec_path = str(root / "vectors.npy")
    np.save(vec_path, pts.astype(np.float32))
    return pts, q, cfg, idx, store, vec_path


def _quarter_cache(idx) -> int:
    """Cache capacity of 1/4 the PQ shard bytes: real eviction pressure."""
    return max(1, int(np.asarray(idx.cluster_codes).nbytes) // 4)


# ---------------------------------------------------------------------------
# cache unit behavior
# ---------------------------------------------------------------------------

def test_cluster_cache_lru_eviction_and_bypass():
    """LRU order (get refreshes recency), byte-bounded eviction, oversize
    bypass, and clear() keeping capacity + cumulative counters."""
    rows = {i: np.full((4, 4), i, np.uint8) for i in range(6)}   # 16 B each
    c = ClusterCache(capacity_bytes=48)                          # 3 rows
    for i in range(4):
        assert c.get(i) is None
        c.put(i, rows[i])
    assert len(c) == 3 and c.evictions == 1          # row 0 was LRU
    assert c.get(0) is None and c.get(1) is not None  # 1 is now MRU
    c.put(4, rows[4])
    c.put(5, rows[5])                                 # evict 2 then 3, not 1
    assert c.get(1) is not None
    assert c.get(2) is None and c.get(3) is None
    before = len(c)
    c.put(9, np.zeros(64, np.uint8))                  # larger than the cache
    assert len(c) == before and c.get(9) is None
    st = c.stats()
    c.clear()
    assert len(c) == 0 and c.bytes == 0
    assert c.stats()["hits"] == st["hits"]
    assert c.stats()["evictions"] == st["evictions"]
    assert c.stats()["capacity_bytes"] == 48


# ---------------------------------------------------------------------------
# paged == resident (the tentpole parity gate)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["H", "M", "L", "H2"])
def test_paged_search_matches_resident_bit_exact(paged_env, mode):
    """Every mode returns resident `search()`'s scores AND ids exactly,
    with a quarter-sized cache so eviction pressure is part of the run."""
    pts, q, cfg, idx, store, _ = paged_env
    paged = PagedIndexData(store.path("main", 1),
                           cache_bytes=_quarter_cache(idx))
    pidx = PagedJunoIndex(paged)
    s0, i0 = search(idx, q, nprobe=8, k=10, mode=mode, metric=cfg.metric)
    s1, i1 = pidx.search(q, nprobe=8, k=10, mode=mode, metric=cfg.metric)
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    assert paged.cache.stats()["evictions"] > 0


def test_paged_engine_matches_resident_engine(paged_env):
    """Full request plane over the paged tier: a PagedAnnServeEngine and
    a resident AnnServeEngine serve identical ids/scores per request."""
    pts, q, cfg, idx, store, _ = paged_env
    paged = PagedIndexData(store.path("main", 1),
                           cache_bytes=_quarter_cache(idx))
    peng = PagedAnnServeEngine(paged, metric=cfg.metric)
    reng = AnnServeEngine(idx, metric=cfg.metric)
    waves = [(q[:5], dict(k=10, mode="H", nprobe=8)),
             (q[5:9], dict(k=10, mode="M", nprobe=8)),
             (q[9:10], dict(k=10, mode="H2", nprobe=16)),
             (q[10:20], dict(k=10, mode="L", nprobe=4))]
    rp = [peng.submit(qs, **kw) for qs, kw in waves]
    rr = [reng.submit(qs, **kw) for qs, kw in waves]
    assert peng.run() == reng.run() == 20
    for a, b in zip(rp, rr):
        np.testing.assert_array_equal(a.ids, b.ids)
        np.testing.assert_array_equal(a.scores, b.scores)


def test_fleet_over_paged_generation(paged_env):
    """AnnServeFleet over a PagedIndexData: replicas share the one mmap +
    cache, results match a resident engine, inserts fan out; the
    shard-split topology is rejected (the paged tier is a storage split,
    not a device split)."""
    pts, q, cfg, idx, store, _ = paged_env
    paged = PagedIndexData(store.path("main", 1),
                           cache_bytes=_quarter_cache(idx))
    with pytest.raises(ValueError, match="n_replicas"):
        AnnServeFleet(paged, n_replicas=2, shards_per_replica=2)
    fleet = AnnServeFleet(paged, n_replicas=2, metric=cfg.metric)
    assert all(e.index.paged.cache is paged.cache for e in fleet.engines)
    reng = AnnServeEngine(idx, metric=cfg.metric)
    waves = [(q[i * 4:(i + 1) * 4], dict(k=10, mode="H", nprobe=8))
             for i in range(4)]
    rf = [fleet.submit(qs, **kw) for qs, kw in waves]
    rr = [reng.submit(qs, **kw) for qs, kw in waves]
    fleet.run()
    reng.run()
    for a, b in zip(rf, rr):
        assert a.done
        np.testing.assert_array_equal(a.ids, b.ids)
        np.testing.assert_array_equal(a.scores, b.scores)
    newpts = (pts[:4] + 0.01).astype(np.float32)
    ids = fleet.insert(newpts)
    req = fleet.submit(newpts, k=10, mode="H", nprobe=16)
    fleet.run()
    assert all(ids[j] in req.ids[j] for j in range(len(ids)))


# ---------------------------------------------------------------------------
# fail-closed first-touch verification
# ---------------------------------------------------------------------------

def test_first_touch_corruption_fails_closed(paged_env, tmp_path):
    """A flipped bit in one cluster row raises on that row's FIRST fetch;
    clean rows keep serving; opting out takes an explicit flag — and an
    old artifact without per-row digests demands the same explicit
    opt-out instead of silently serving unverifiable bytes."""
    pts, _, cfg, idx, store, _ = paged_env
    path = str(tmp_path / "art")
    save_index(path, idx, cfg)
    apath = os.path.join(path, "arrays.npz")
    with np.load(apath) as z:
        arrays = {k: z[k].copy() for k in z.files}
    arrays["cluster_codes"][3, 0, 0] ^= 1
    np.savez(apath, **arrays)

    paged = PagedIndexData(path, cache_bytes=1 << 20)
    clean = paged.fetch_cluster(2)
    assert clean.shape == arrays["cluster_codes"].shape[1:]
    with pytest.raises(ArtifactError, match="first touch"):
        paged.fetch_cluster(3)
    assert paged.verified_rows == 1                  # only the clean row

    loose = PagedIndexData(path, cache_bytes=1 << 20, verify_rows=False)
    loose.fetch_cluster(3)                           # explicit opt-out

    mpath = os.path.join(path, "manifest.json")
    m = json.load(open(mpath))
    del m["arrays"]["cluster_codes"]["sha256_rows"]
    json.dump(m, open(mpath, "w"))
    with pytest.raises(ArtifactError, match="per-row digests"):
        PagedIndexData(path, cache_bytes=1 << 20)
    PagedIndexData(path, cache_bytes=1 << 20, verify_rows=False)


def test_paged_stats_verify_once_and_gather_dedup(paged_env):
    """Each row is digest-verified exactly once; `gather` faults every
    distinct cluster once per call; the raw-vector tier reads addressed
    rows (negative sentinel ids clamp to row 0)."""
    pts, q, cfg, idx, store, vec_path = paged_env
    paged = PagedIndexData(store.path("main", 1), cache_bytes=1 << 22,
                           vectors=vec_path)
    a = paged.fetch_cluster(0)
    b = paged.fetch_cluster(0)
    np.testing.assert_array_equal(a, b)
    st = paged.stats()
    assert st["verified_rows"] == 1
    assert st["hits"] == 1 and st["misses"] == 1
    assert st["cluster_bytes"] == np.asarray(idx.cluster_codes).nbytes
    assert st["generation"] == store.path("main", 1)

    cids = np.array([[1, 2, 1], [2, 3, 3]])
    g = paged.gather(cids)
    assert g.shape == cids.shape + a.shape
    assert paged.stats()["misses"] == 4              # 1, 2, 3 once each
    np.testing.assert_array_equal(g[0, 0], g[0, 2])

    vv = paged.fetch_vectors(np.array([[0, 5, -1]]))
    assert vv.shape == (1, 3, pts.shape[1])
    np.testing.assert_array_equal(vv[0, 0], pts[0].astype(np.float32))
    np.testing.assert_array_equal(vv[0, 2], vv[0, 0])
    with pytest.raises(RuntimeError, match="vector"):
        PagedIndexData(store.path("main", 1),
                       cache_bytes=1 << 20).fetch_vectors(np.array([0]))


# ---------------------------------------------------------------------------
# exact-rerank tier
# ---------------------------------------------------------------------------

def test_exact_rerank_scores_are_exact_and_lift_recall(paged_env):
    """With exact_rerank=C the returned scores are true squared-l2
    distances recomputed from the raw vectors, and recall@10 does not
    drop (it rises well clear of the PQ-only engine on this set)."""
    pts, q, cfg, idx, store, vec_path = paged_env
    paged = PagedIndexData(store.path("main", 1), cache_bytes=1 << 22,
                           vectors=vec_path)
    with pytest.raises(ValueError, match="vector"):
        PagedAnnServeEngine(
            PagedIndexData(store.path("main", 1), cache_bytes=1 << 20),
            metric=cfg.metric, exact_rerank=40)
    plain = PagedAnnServeEngine(paged, metric=cfg.metric)
    rerank = PagedAnnServeEngine(paged, metric=cfg.metric, exact_rerank=40)
    _, gt = exact_topk(jnp.asarray(q), jnp.asarray(pts), k=10)
    recalls = {}
    for name, eng in [("plain", plain), ("rerank", rerank)]:
        req = eng.submit(q, k=10, mode="H2", nprobe=16)
        eng.run()
        recalls[name] = float(recall_n_at_k(jnp.asarray(req.ids), gt))
        if name == "rerank":
            d = np.sum((pts[req.ids].astype(np.float32)
                        - q[:, None, :]) ** 2, axis=-1)
            np.testing.assert_allclose(req.scores, d, rtol=1e-4)
            assert np.all(np.diff(req.scores, axis=1) >= 0)
    assert recalls["rerank"] >= recalls["plain"], recalls


# ---------------------------------------------------------------------------
# mutability over read-only shards
# ---------------------------------------------------------------------------

def test_paged_insert_delete_side_buffer_only(paged_env):
    """Inserts NEVER touch the mmap'd shards (all side-buffered),
    tombstones hide committed points via the resident valid mask, and
    in-process compaction/rebuild is structurally refused."""
    pts, _, cfg, idx, store, _ = paged_env
    paged = PagedIndexData(store.path("main", 1), cache_bytes=1 << 22)
    eng = PagedAnnServeEngine(paged, metric=cfg.metric, side_capacity=64)
    rng = np.random.default_rng(7)
    newpts = (pts[:4].mean(0)[None]
              + 0.01 * rng.standard_normal((4, pts.shape[1]))
              ).astype(np.float32)
    ids = eng.insert(newpts)
    assert min(ids) >= paged.first_new_id
    assert eng.index.side_fill == 4          # read-only shards: all spill
    req = eng.submit(newpts, k=10, mode="H", nprobe=16)
    eng.run()
    assert all(ids[j] in req.ids[j] for j in range(4))

    victim = int(np.asarray(idx.ivf.point_ids[0])[0])
    qv = pts[victim][None]
    r0 = eng.submit(qv, k=10, mode="H", nprobe=16)
    eng.run()
    assert victim in r0.ids[0]
    eng.delete([victim])
    r1 = eng.submit(qv, k=10, mode="H", nprobe=16)
    eng.run()
    assert victim not in r1.ids[0]

    assert eng.compact() == 0 and eng.index.side_fill == 4
    with pytest.raises(RuntimeError, match="offline"):
        eng.compact(rebuild=True)


def test_swap_generation_retargets_cache(paged_env):
    """swap_index requires an explicit next PagedIndexData generation;
    the new generation adopts the live cache with every row dropped
    (never mixing generations) while counters/capacity carry over, and
    post-swap results reproduce pre-swap ones."""
    pts, q, cfg, idx, store, _ = paged_env
    v2 = store.put("main", idx, cfg)
    paged1 = PagedIndexData(store.path("main", 1), cache_bytes=1 << 22)
    eng = PagedAnnServeEngine(paged1, metric=cfg.metric)
    r0 = eng.submit(q[:8], k=10, mode="H", nprobe=8)
    eng.run()
    cache = paged1.cache
    assert len(cache) > 0
    traffic0 = cache.hits + cache.misses

    with pytest.raises(RuntimeError, match="offline|generation"):
        eng.swap_index()                     # no in-process rebuild default
    with pytest.raises(TypeError):
        eng.swap_index(idx)                  # resident data isn't one

    paged2 = PagedIndexData(store.path("main", v2), cache_bytes=1 << 22)
    assert eng.swap_index(paged2) == 1
    assert paged2.cache is cache             # retargeted, not replaced
    assert len(cache) == 0                   # rows dropped at the swap
    assert cache.hits + cache.misses == traffic0
    r1 = eng.submit(q[:8], k=10, mode="H", nprobe=8)
    eng.run()
    np.testing.assert_array_equal(r0.ids, r1.ids)
    np.testing.assert_array_equal(r0.scores, r1.scores)
    ids = eng.insert((pts[:2] + 0.01).astype(np.float32))
    assert min(ids) >= paged2.first_new_id   # id space survives the swap


# ---------------------------------------------------------------------------
# rt prefilter over the paged tier
# ---------------------------------------------------------------------------

def test_paged_rt_needs_artifact_grid(paged_env, tmp_path):
    """The rt grid cannot be built lazily out-of-core (it needs every
    code): ensure_rt_grid refuses without an artifact-stored grid, and
    serves the folded grid when the artifact carries one."""
    from repro import rt as rt_lib

    pts, q, cfg, idx, store, _ = paged_env
    bare = PagedJunoIndex(PagedIndexData(store.path("main", 1),
                                         cache_bytes=1 << 22))
    with pytest.raises(RuntimeError, match="grid"):
        bare.ensure_rt_grid(metric=cfg.metric)

    grid = rt_lib.build_grid(idx, metric=cfg.metric, calib_queries=8,
                             points=pts)
    path = str(tmp_path / "with_grid")
    save_index(path, idx, cfg, rt_grid=grid)
    paged = PagedIndexData(path, cache_bytes=1 << 22)
    assert paged.rt_grid is not None
    eng = PagedAnnServeEngine(paged, metric=cfg.metric, prefilter="rt",
                              rt_scale=1e6)     # full coverage: parity
    assert eng.index.ensure_rt_grid(metric=cfg.metric) is eng.index.rt_grid
    req = eng.submit(q, k=10, mode="H", nprobe=16)
    eng.run()
    _, gt = exact_topk(jnp.asarray(q), jnp.asarray(pts), k=10)
    assert float(recall_n_at_k(jnp.asarray(req.ids), gt)) > 0.3

"""Differential harness for the RT-core-style sphere-intersection filter.

The rt prefilter (``repro.rt``) must be a *pruning overlay*, never a new
semantics: at full-coverage radii every path (H, H2, fused H2, the serving
engine, the 1-device distributed search) must return ids identical to the
dense-scan path, and at calibrated radii the recall floors pin the pruning
quality across {l2, ip}. The kernel itself is validated against the dense
oracle (``kernels.ref.rt_sphere_hits_ref``) on adversarial grids — ragged
last cells, ``-inf`` pad/empty sentinels, zero and huge radii — in
interpret mode (the ``interpret``-marked test, own CI job); the host path
shares the oracle's body by delegation (single source of truth), and the
dispatcher plumbing is pinned in tier 1.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import rt
from repro.core import (JunoConfig, MutableJunoIndex, build, exact_topk,
                        recall_n_at_k, search)
from repro.data import DEEP_LIKE, TTI_LIKE, make_dataset
from repro.kernels import ref
from repro.serve.ann import AnnServeEngine

NPROBE = 16
FULL = 1e6   # rt_scale at which every sphere covers every cell

# measured (2026-08, jax 0.4.37 CPU, this fixture): l2 H=0.988 H2=0.931,
# ip H=0.967 H2=0.723 — floors ~15-40% below, same style as
# test_recall_matrix.py (rt H2 on ip BEATS the dense-scan 0.435: pruning
# junk clusters out of stage 1 improves the candidate set)
RT_FLOORS_10_AT_100 = {
    ("l2", "H"): 0.85, ("l2", "H2"): 0.75,
    ("ip", "H"): 0.75, ("ip", "H2"): 0.40,
}


@pytest.fixture(scope="module")
def rt_data():
    out = {}
    for metric, spec in [("l2", DEEP_LIKE), ("ip", TTI_LIKE)]:
        pts, q = make_dataset(spec, 5000, 48, key=jax.random.PRNGKey(7))
        cfg = JunoConfig(n_clusters=32, n_entries=32, calib_queries=24,
                         kmeans_iters=5, metric=metric)
        idx = build(pts, cfg)
        grid = rt.build_grid(idx, metric=metric)
        _, gt10 = exact_topk(q, pts, k=10, metric=metric)
        out[metric] = (pts, q, idx, grid, gt10)
    return out


# ---------------------------------------------------------------------------
# full-coverage parity: rt must degenerate to the dense scan exactly
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("metric", ["l2", "ip"])
@pytest.mark.parametrize("mode,fused", [("H", False), ("H2", False),
                                        ("H2", True)])
def test_full_coverage_matches_scan(rt_data, metric, mode, fused):
    _, q, idx, grid, _ = rt_data[metric]
    kw = dict(nprobe=NPROBE, k=100, mode=mode, metric=metric, fused=fused)
    _, want = search(idx, q, **kw)
    _, got = search(idx, q, prefilter="rt", rt_grid=grid, rt_scale=FULL,
                    **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_engine_full_coverage_matches_scan(rt_data, metric):
    _, q, idx, _, _ = rt_data[metric]
    q = np.asarray(q)[:8]
    outs = {}
    for pf, kw in [("scan", {}), ("rt", dict(prefilter="rt",
                                             rt_scale=FULL))]:
        eng = AnnServeEngine(idx, metric=metric, batch_buckets=(8, 16), **kw)
        req = eng.submit(q, k=10, mode="H2")
        eng.run()
        outs[pf] = req.ids
    np.testing.assert_array_equal(outs["rt"], outs["scan"])


def test_dist_1device_full_coverage(rt_data):
    from jax.sharding import Mesh

    from repro.dist.distributed_index import (make_distributed_search,
                                              shard_index)
    _, q, idx, grid, _ = rt_data["l2"]
    q = jnp.asarray(q)[:16]
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    sidx = shard_index(idx, mesh)
    dsearch = make_distributed_search(mesh, NPROBE, 10, mode="H2",
                                      metric="l2", prefilter="rt",
                                      rt_scale=FULL)
    _, got = dsearch(sidx, q, grid)
    _, want = search(idx, q, nprobe=NPROBE, k=10, mode="H2", metric="l2",
                     batch=q.shape[0])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# calibrated radii: pruning quality floors
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cell", sorted(RT_FLOORS_10_AT_100))
def test_rt_recall_floor(rt_data, cell):
    metric, mode = cell
    _, q, idx, grid, gt10 = rt_data[metric]
    _, ids = search(idx, q, nprobe=NPROBE, k=100, mode=mode, metric=metric,
                    prefilter="rt", rt_grid=grid)
    r = float(recall_n_at_k(ids, gt10))
    floor = RT_FLOORS_10_AT_100[cell]
    assert r >= floor, (
        f"rt recall@10-in-100 regression: {metric}/{mode} = {r:.3f} < {floor}")


@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_survivors_monotone_in_scale(rt_data, metric):
    """Bigger rt_scale must only ever ADD survivors (monotone radius)."""
    _, q, idx, grid, _ = rt_data[metric]
    qj = jnp.asarray(q)
    tau = jnp.ones((qj.shape[0], idx.codes.shape[1]), jnp.float32)
    masks = [np.asarray(rt.survivor_mask(
        grid, qj, rt.query_radius(grid, tau, s))) for s in (1.0, 4.0, FULL)]
    assert np.all(masks[0] <= masks[1]) and np.all(masks[1] <= masks[2])
    assert masks[2].all()   # full coverage reaches every cluster


@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_probe_budget_covers_all_survivors(rt_data, metric):
    """No probe ranked beyond the routed budget may survive the rt test —
    the property that makes the engine's nprobe shrink lossless w.r.t.
    the masked search."""
    from repro.core import density as density_lib
    from repro.core.ivf import filter_clusters
    _, q, idx, grid, _ = rt_data[metric]
    qj = jnp.asarray(q)
    budget = rt.probe_budget(grid, idx, np.asarray(q), metric=metric,
                             max_probes=NPROBE)
    _, cids = filter_clusters(qj, idx.ivf, nprobe=NPROBE, metric=metric)
    if metric == "l2":
        res = qj - idx.ivf.centroids[cids[:, 0]]
    else:
        res = qj
    tau = density_lib.predict_threshold(
        idx.density, res.reshape(res.shape[0], -1, idx.codebook.sub_dim), 1.0)
    mask = np.asarray(rt.survivor_mask(
        grid, qj, rt.query_radius(grid, tau, 1.0)))
    probe_hits = mask[np.arange(len(q))[:, None], np.asarray(cids)] > 0
    for i in range(len(q)):
        assert not probe_hits[i, budget[i]:].any(), (
            f"query {i}: survivor beyond routed budget {budget[i]}")


def test_side_buffer_respects_rt_mask(rt_data):
    """Side-buffer points must get the SAME rt verdict as their in-cluster
    siblings: identical ids to the dense scan at full coverage, and
    ``compact()`` stays a search no-op under the calibrated radius (the
    spilled point scores the same whether it sits in the buffer or in a
    cluster slot — including the probe's sphere test)."""
    pts, q, idx, grid, _ = rt_data["l2"]
    q = jnp.asarray(q)[:16]
    mi = MutableJunoIndex(idx, side_capacity=64, rt_grid=grid)
    # force a spill: fill the fullest cluster's free slots + 1
    c = int(np.argmin([mi.free_slots(cc)
                       for cc in range(idx.ivf.point_ids.shape[0])]))
    cent = np.asarray(idx.ivf.centroids[c])
    spill = (cent[None] + 0.01 * np.random.default_rng(3).standard_normal(
        (mi.free_slots(c) + 1, cent.shape[0]))).astype(np.float32)
    mi.insert(spill)
    assert mi.side_fill >= 1
    for mode in ["H", "H2"]:
        _, want = mi.search(q, nprobe=NPROBE, k=10, mode=mode, metric="l2",
                            batch=q.shape[0])
        _, got = mi.search(q, nprobe=NPROBE, k=10, mode=mode, metric="l2",
                           prefilter="rt", rt_scale=FULL, batch=q.shape[0])
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # compact() no-op under rt: free a slot in the owner cluster, search
    # (side active), fold the spill back in, search again — same answers
    victim = int(idx.ivf.point_ids[c, 0])
    mi.delete([victim])
    s1, i1 = mi.search(q, nprobe=NPROBE, k=10, mode="H", metric="l2",
                       prefilter="rt", batch=q.shape[0])
    assert mi.compact() >= 1
    s2, i2 = mi.search(q, nprobe=NPROBE, k=10, mode="H", metric="l2",
                       prefilter="rt", batch=q.shape[0])
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=0, atol=0)
    for row1, row2 in zip(np.asarray(i1), np.asarray(i2)):
        assert set(row1) == set(row2)


def test_side_buffer_fused3_verdict_parity(rt_data):
    """Regression pin for the single-residency three-stage kernel: a
    side-buffer point must receive the SAME probe verdict as its
    in-cluster siblings — the kernel's in-register ``probe_ok`` is the
    one verdict both the cluster lanes and the side block consume, so
    fused3 stays bit-equal (ids AND scores) to the composed rt+fused
    path while a spill is live, and ``compact()`` stays a search no-op
    under the new kernel. Before the shared-verdict wiring this failed:
    a side point probed through a cell its cluster slot had pruned."""
    pts, q, idx, grid, _ = rt_data["l2"]
    q = jnp.asarray(q)[:16]
    mi = MutableJunoIndex(idx, side_capacity=64, rt_grid=grid)
    # force a spill: fill the fullest cluster's free slots + 1
    c = int(np.argmin([mi.free_slots(cc)
                       for cc in range(idx.ivf.point_ids.shape[0])]))
    cent = np.asarray(idx.ivf.centroids[c])
    spill = (cent[None] + 0.01 * np.random.default_rng(5).standard_normal(
        (mi.free_slots(c) + 1, cent.shape[0]))).astype(np.float32)
    mi.insert(spill)
    assert mi.side_fill >= 1
    # H2 tier raw and H-tier serving shape (fused + rerank), calibrated
    # and cover-all radii: three-stage vs composed, bit-equal both planes
    for rerank in [0, AnnServeEngine.FUSED_RERANK_MULT * 10]:
        for scale in [0.85, FULL]:
            s3, i3 = mi.search(q, nprobe=NPROBE, k=10, mode="H2",
                               metric="l2", prefilter="rt", fused=True,
                               rerank=rerank, rt_scale=scale,
                               batch=q.shape[0])
            s2, i2 = mi.search(q, nprobe=NPROBE, k=10, mode="H2",
                               metric="l2", prefilter="rt", fused=True,
                               fused3=False, rerank=rerank,
                               rt_scale=scale, batch=q.shape[0])
            np.testing.assert_array_equal(np.asarray(i3), np.asarray(i2))
            np.testing.assert_allclose(np.asarray(s3), np.asarray(s2),
                                       rtol=0, atol=0)
    # compact() no-op under the three-stage kernel: free a slot in the
    # owner cluster, search (side active), fold back in, search again
    victim = int(idx.ivf.point_ids[c, 0])
    mi.delete([victim])
    s1, i1 = mi.search(q, nprobe=NPROBE, k=10, mode="H2", metric="l2",
                       prefilter="rt", fused=True, batch=q.shape[0])
    assert mi.compact() >= 1
    s2, i2 = mi.search(q, nprobe=NPROBE, k=10, mode="H2", metric="l2",
                       prefilter="rt", fused=True, batch=q.shape[0])
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=0, atol=0)
    for row1, row2 in zip(np.asarray(i1), np.asarray(i2)):
        assert set(row1) == set(row2)


# ---------------------------------------------------------------------------
# grid structure: ragged padding, serialization, insert maintenance
# ---------------------------------------------------------------------------
def test_ragged_padding_and_slot_map(rt_data):
    _, q, idx, grid, _ = rt_data["l2"]
    c = idx.ivf.centroids.shape[0]
    slot_of = np.asarray(grid.slot_of)
    assert len(np.unique(slot_of)) == c            # a slot per cluster
    ids_flat = np.asarray(grid.cell_ids).reshape(-1)
    assert sorted(ids_flat[ids_flat >= 0]) == list(range(c))
    pad = ids_flat < 0
    assert pad.any(), "fixture should exercise ragged cells"
    assert np.all(np.isneginf(np.asarray(grid.slot_reach).reshape(-1)[pad]))
    # pad slots never hit, even at full coverage
    qj = jnp.asarray(q)
    tau = jnp.ones((qj.shape[0], idx.codes.shape[1]), jnp.float32)
    hits = np.asarray(rt.sphere_hits_host(
        (qj @ grid.proj)[:, 0], (qj @ grid.proj)[:, 1],
        rt.query_radius(grid, tau, FULL),
        grid.cell_c0, grid.cell_c1, grid.slot_reach))
    assert not hits[:, pad].any()
    assert hits[:, ~pad].all()


def test_grid_save_load_roundtrip(rt_data, tmp_path):
    _, q, idx, grid, _ = rt_data["l2"]
    path = str(tmp_path / "grid.npz")
    rt.save_grid(path, grid)
    loaded = rt.load_grid(path)
    for name in type(grid)._fields:
        np.testing.assert_array_equal(np.asarray(getattr(grid, name)),
                                      np.asarray(getattr(loaded, name)))
    _, a = search(idx, q[:8], nprobe=NPROBE, k=10, mode="H", metric="l2",
                  prefilter="rt", rt_grid=grid)
    _, b = search(idx, q[:8], nprobe=NPROBE, k=10, mode="H", metric="l2",
                  prefilter="rt", rt_grid=loaded)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_insert_grows_touched_reach_only(rt_data):
    pts, _, idx, _, _ = rt_data["l2"]
    mid = MutableJunoIndex(idx, side_capacity=64)
    grid0 = mid.ensure_rt_grid(metric="l2")
    before = np.asarray(grid0.slot_reach).copy()
    # an outlier far from its owning centroid
    outlier = np.asarray(pts)[0] + 40.0
    ids = mid.insert(outlier[None])
    assert len(ids) == 1
    after = np.asarray(mid.rt_grid.slot_reach)
    changed = np.flatnonzero(before.reshape(-1) != after.reshape(-1))
    assert len(changed) == 1                       # only the touched slot
    slot = changed[0]
    cluster = int(np.asarray(grid0.cell_ids).reshape(-1)[slot])
    res = outlier - np.asarray(idx.ivf.centroids)[cluster]
    rp = np.sqrt(np.sum((res @ np.asarray(grid0.proj)) ** 2))
    assert after.reshape(-1)[slot] >= rp - 1e-4
    # cell bound follows the slot bound
    cell = slot // grid0.capacity
    assert (np.asarray(mid.rt_grid.cell_reach)[cell]
            >= after.reshape(-1)[slot] - 1e-6)


def test_dist_mutable_rt_grid_maintenance(rt_data):
    """The sharded mutable index must maintain its rt grid on insert just
    like the single-device one, and the mutated grid must flow into the
    rt-prefiltered distributed search."""
    from jax.sharding import Mesh

    from repro.dist.distributed_index import DistributedMutableIndex
    pts, q, idx, grid, _ = rt_data["l2"]
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    dmi = DistributedMutableIndex(idx, mesh, side_capacity=64, rt_grid=grid)
    before = np.asarray(grid.slot_reach).copy()
    outlier = np.asarray(pts)[0] + 40.0
    ids = dmi.insert(outlier[None])
    assert len(ids) == 1
    after = np.asarray(dmi.rt_grid.slot_reach)
    changed = np.flatnonzero(before.reshape(-1) != after.reshape(-1))
    assert len(changed) == 1, "exactly the owner cluster's reach must grow"
    slot = changed[0]
    cluster = int(np.asarray(grid.cell_ids).reshape(-1)[slot])
    res = outlier - np.asarray(idx.ivf.centroids)[cluster]
    rp = np.sqrt(np.sum((res @ np.asarray(grid.proj)) ** 2))
    # the owner's disc now contains the fresh point's projection, so any
    # query sphere touching the point also touches the cluster
    assert after.reshape(-1)[slot] >= rp - 1e-3
    dsearch = dmi.searcher(NPROBE, 10, mode="H", metric="l2",
                           prefilter="rt")
    _, got = dsearch(dmi.data, jnp.asarray(outlier)[None], dmi.side,
                     dmi.rt_grid)
    assert np.asarray(got).shape == (1, 10)


# ---------------------------------------------------------------------------
# kernel differential validation
# ---------------------------------------------------------------------------
def _synth_grid(seed, n_cells_side, cap, q):
    """Random grid honoring the build invariants: slot coords inside their
    cell's AABB, cell_reach = max slot_reach, -inf pad/empty sentinels."""
    rng = np.random.default_rng(seed)
    g = n_cells_side
    n_cells = g * g
    lo = np.stack(np.meshgrid(np.arange(g), np.arange(g), indexing="ij"),
                  -1).reshape(-1, 2) / g
    boxes = np.concatenate([lo, lo + 1.0 / g], 1).astype(np.float32)
    counts = rng.integers(0, cap + 1, n_cells)
    c0 = np.zeros((n_cells, cap), np.float32)
    c1 = np.zeros((n_cells, cap), np.float32)
    reach = np.full((n_cells, cap), -np.inf, np.float32)
    for cell in range(n_cells):
        k = counts[cell]
        u = rng.random((k, 2)).astype(np.float32)
        c0[cell, :k] = boxes[cell, 0] + u[:, 0] / g
        c1[cell, :k] = boxes[cell, 1] + u[:, 1] / g
        reach[cell, :k] = np.abs(rng.normal(0, 0.2, k)).astype(np.float32)
    cell_reach = reach.max(1)
    q0 = rng.uniform(-0.3, 1.3, q).astype(np.float32)
    q1 = rng.uniform(-0.3, 1.3, q).astype(np.float32)
    radius = rng.uniform(0, 0.5, q).astype(np.float32)
    radius[: q // 4] = 0.0                       # degenerate: point queries
    radius[q // 4: 2 * (q // 4)] = 10.0          # degenerate: cover-all
    return tuple(map(jnp.asarray,
                     (q0, q1, radius, boxes, cell_reach, c0, c1, reach)))


def test_dispatcher_uses_oracle_semantics():
    """Off-TPU, ops.rt_sphere_hits must route to the host path, whose body
    IS the oracle (single source of truth — delegation, not duplication),
    so the dispatcher output equals ref by construction; this pins the
    dispatch plumbing (shapes, dtype, flattening) in tier 1."""
    from repro.kernels import ops
    for seed, g, cap, q in [(0, 3, 8, 16), (1, 4, 16, 7), (2, 2, 8, 1)]:
        q0, q1, r, boxes, creach, c0, c1, reach = _synth_grid(seed, g, cap, q)
        got = ops.rt_sphere_hits(q0, q1, r, boxes, creach, c0, c1, reach)
        want = ref.rt_sphere_hits_ref(q0, q1, r, c0, c1, reach)
        assert got.dtype == jnp.int8
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.interpret
@pytest.mark.parametrize("seed,g,cap,q", [(0, 3, 8, 16), (1, 4, 16, 7),
                                          (2, 2, 8, 1), (3, 5, 24, 33)])
def test_kernel_interpret_matches_ref(seed, g, cap, q):
    """The Pallas cell walk must be bit-identical to the dense oracle —
    the AABB skip is conservative, so it changes work, never results."""
    q0, q1, r, boxes, cell_reach, c0, c1, reach = _synth_grid(seed, g, cap, q)
    got = rt.sphere_hits(q0, q1, r, boxes, cell_reach, c0, c1, reach,
                         interpret=True)
    want = ref.rt_sphere_hits_ref(q0, q1, r, c0, c1, reach)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.interpret
def test_kernel_interpret_on_built_grid(rt_data):
    """Kernel parity on a REAL grid (build-produced layout and sentinels)."""
    _, q, idx, grid, _ = rt_data["l2"]
    qj = jnp.asarray(q)
    qp = qj @ grid.proj
    tau = jnp.ones((qj.shape[0], idx.codes.shape[1]), jnp.float32)
    for scale in (1.0, FULL):
        r = rt.query_radius(grid, tau, scale)
        got = rt.sphere_hits(qp[:, 0], qp[:, 1], r, grid.boxes,
                             grid.cell_reach, grid.cell_c0, grid.cell_c1,
                             grid.slot_reach, interpret=True)
        want = ref.rt_sphere_hits_ref(qp[:, 0], qp[:, 1], r, grid.cell_c0,
                                      grid.cell_c1, grid.slot_reach)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

"""Test-suite bootstrap: register markers and, when the real ``hypothesis``
package is missing (hermetic container), alias the deterministic fallback in
``tests/_hypothesis_fallback.py`` into ``sys.modules`` before test modules
import it. CI installs real hypothesis, so the fallback is exercised only
where pip installs are unavailable."""
import importlib.util
import os
import sys

if importlib.util.find_spec("hypothesis") is None:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_fallback
    sys.modules["hypothesis"] = _hypothesis_fallback


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: multi-device subprocess tests (minutes on CPU)")
    config.addinivalue_line(
        "markers", "interpret: interpret-mode Pallas kernel validation "
        "(split into its own CI job)")

"""Test-suite bootstrap: register markers and, when the real ``hypothesis``
package is missing (hermetic container), alias the deterministic fallback in
``tests/_hypothesis_fallback.py`` into ``sys.modules`` before test modules
import it. CI installs real hypothesis, so the fallback is exercised only
where pip installs are unavailable."""
import importlib.util
import os
import sys

if importlib.util.find_spec("hypothesis") is None:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_fallback
    sys.modules["hypothesis"] = _hypothesis_fallback


import gc

import pytest


@pytest.fixture(autouse=True, scope="module")
def _drop_jax_caches_per_module():
    """Clear jax's compilation caches after each test module.

    The full suite compiles thousands of distinct XLA programs in one
    process; on the CPU backend the accumulated LLVM JIT state eventually
    segfaults inside ``backend_compile`` (observed around ~450 modules'
    worth of executables). Module-scoped cache drops keep the resident
    executable count bounded without perturbing the warm-jit-signature
    assertions, which all live within a single module.
    """
    yield
    import jax

    jax.clear_caches()
    gc.collect()


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: multi-device subprocess tests (minutes on CPU)")
    config.addinivalue_line(
        "markers", "interpret: interpret-mode Pallas kernel validation "
        "(split into its own CI job)")
    config.addinivalue_line(
        "markers", "autotune: measured kernel-config search (wall-clock "
        "timing; own CI job)")

"""Distribution substrate tests: checkpoint/restart (exact recovery),
elastic restore, gradient compression, the serving engine, and the
distributed JUNO index (single-device mesh degenerate case + a subprocess
multi-device run)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data.tokens import make_batch
from repro.dist import checkpoint as ckpt
from repro.dist import compression
from repro.dist.fault_tolerance import StepWatchdog, run_with_restart
from repro.models import get_model
from repro.train import TrainConfig, init_train_state, make_train_step


def _tree_allclose(a, b):
    ok = jax.tree.map(lambda x, y: np.allclose(np.asarray(x), np.asarray(y),
                                               atol=1e-6), a, b)
    return all(jax.tree.leaves(ok))


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12).reshape(3, 4).astype(jnp.float32),
            "b": {"c": jnp.ones((5,), jnp.bfloat16),
                  "step": jnp.int32(7)}}
    ckpt.save(str(tmp_path), 3, tree)
    assert ckpt.latest_step(str(tmp_path)) == 3
    restored, step = ckpt.restore(str(tmp_path), tree)
    assert step == 3 and _tree_allclose(tree, restored)


def test_checkpoint_retention_and_latest(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    for s in [1, 2, 3, 4, 5]:
        ckpt.save(str(tmp_path), s, tree, keep=2)
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000004", "step_00000005"]
    assert ckpt.latest_step(str(tmp_path)) == 5


def test_train_restart_is_exact(tmp_path):
    """Crash at step 7, restore from the step-5 checkpoint, replay — the
    final state must be bitwise identical to an uninterrupted run
    (deterministic data pipeline + atomic checkpoints)."""
    cfg = get_smoke_config("phi4_mini_3_8b")
    model = get_model(cfg)
    step_jit = jax.jit(make_train_step(model, TrainConfig()))

    def make_step_fn():
        def fn(state, step):
            batch = make_batch(cfg, batch=2, seq=16, step=step, seed=3)
            return step_jit(state, batch)
        return fn

    init = init_train_state(model, jax.random.PRNGKey(0))

    # uninterrupted reference run
    ref = init
    for s in range(10):
        ref, _ = make_step_fn()(ref, s)

    # interrupted run with restart
    cdir = str(tmp_path)
    crashed = {"done": False}

    def injector(step):
        if step == 7 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("simulated node failure")

    def save_fn(state, step):
        ckpt.save(cdir, step, state)

    def restore_fn():
        if ckpt.latest_step(cdir) is None:
            return None, 0
        return ckpt.restore(cdir, init)

    final, step = run_with_restart(make_step_fn(), init, 10,
                                   save_fn=save_fn, restore_fn=restore_fn,
                                   ckpt_every=5, fault_injector=injector)
    assert crashed["done"] and step == 10
    assert _tree_allclose(final.params, ref.params), \
        "restart must replay to the identical state"


def test_elastic_restore_new_sharding(tmp_path):
    """Checkpoint saved unsharded restores onto explicit device placements
    (the reshard path used when the mesh changes)."""
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    ckpt.save(str(tmp_path), 1, tree)
    dev = jax.devices()[0]
    sh = {"w": jax.sharding.SingleDeviceSharding(dev)}
    restored, _ = ckpt.restore(str(tmp_path), tree, shardings=sh)
    assert restored["w"].sharding == sh["w"]
    assert _tree_allclose(tree, restored)


def test_compression_bf16_roundtrip():
    g = {"a": jnp.linspace(-3, 3, 100), "b": jnp.ones((4, 4)) * 1e-3}
    dec = compression.decompress_bf16(compression.compress_bf16(g))
    for k in g:
        np.testing.assert_allclose(np.asarray(dec[k]), np.asarray(g[k]),
                                   rtol=1e-2, atol=1e-4)


def test_compression_int8_error_feedback_unbiased():
    """With error feedback the accumulated decompressed signal converges to
    the accumulated true signal (the EF guarantee)."""
    key = jax.random.PRNGKey(0)
    g = {"w": jax.random.normal(key, (256,))}
    err = None
    acc_true = jnp.zeros((256,))
    acc_dec = jnp.zeros((256,))
    for i in range(20):
        gi = {"w": g["w"] * (1.0 + 0.1 * i)}
        comp, err = compression.compress_int8(gi, err)
        dec = compression.decompress_int8(comp)
        acc_true += gi["w"]
        acc_dec += dec["w"]
    rel = float(jnp.linalg.norm(acc_dec - acc_true)
                / jnp.linalg.norm(acc_true))
    assert rel < 0.01, rel


def test_watchdog_detects_stragglers():
    w = StepWatchdog(slack=1.5, warmup=2)
    for _ in range(6):
        assert w.check(1.0) == "ok"
    assert w.check(2.0) == "slow"
    assert w.check(2.0) == "sick"
    assert w.check(1.0) == "ok"


def test_serving_engine_continuous_batching():
    cfg = get_smoke_config("phi4_mini_3_8b")
    model = get_model(cfg)
    from repro.models.params import init_params
    from repro.serve.engine import Request, ServeEngine
    params = init_params(model.schema, jax.random.PRNGKey(1))
    eng = ServeEngine(model, params, n_slots=2, max_seq=64)
    reqs = [Request(rid=i, prompt=[1 + i, 2, 3], max_new=4)
            for i in range(5)]   # 5 requests > 2 slots → queueing
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done and len(r.out) == 4 for r in reqs)
    assert all(0 <= t < cfg.vocab_size for r in reqs for t in r.out)


def test_engine_matches_oneshot_decode():
    """Engine output for a single request == direct prefill+decode greedy."""
    cfg = get_smoke_config("phi4_mini_3_8b")
    model = get_model(cfg)
    from repro.models.params import init_params
    from repro.serve.engine import Request, ServeEngine
    params = init_params(model.schema, jax.random.PRNGKey(2))

    prompt = [5, 9, 2, 7]
    eng = ServeEngine(model, params, n_slots=1, max_seq=32)
    req = Request(rid=0, prompt=list(prompt), max_new=3)
    eng.submit(req)
    eng.run()

    cache = init_params(model.cache_schema(1, 32), jax.random.PRNGKey(0))
    batch = {"tokens": jnp.asarray([prompt], jnp.int32)}
    logits, cache = model.prefill(params, batch, cache)
    toks = []
    pos = len(prompt)
    tok = int(jnp.argmax(logits[0]))
    toks.append(tok)
    for _ in range(2):
        logits, cache = model.decode(params, cache,
                                     jnp.asarray([[tok]], jnp.int32), pos)
        tok = int(jnp.argmax(logits[0]))
        toks.append(tok)
        pos += 1
    assert req.out == toks, (req.out, toks)


def test_distributed_index_single_device_mesh():
    """shard_map JUNO on a trivial 1-device mesh == plain search."""
    from repro.core import JunoConfig, build, search
    from repro.data import make_dataset, DEEP_LIKE
    from repro.dist.distributed_index import (make_distributed_search,
                                              shard_index)
    pts, q = make_dataset(DEEP_LIKE, 4000, 16, key=jax.random.PRNGKey(5))
    cfg = JunoConfig(n_clusters=16, n_entries=32, calib_queries=16,
                     kmeans_iters=4)
    idx = build(pts, cfg)
    mesh = jax.make_mesh((1,), ("data",))
    sidx = shard_index(idx, mesh)
    dsearch = make_distributed_search(mesh, local_nprobe=8, k=50)
    s_d, i_d = dsearch(sidx, q)
    s_r, i_r = search(idx, q, nprobe=8, k=50, mode="H")
    np.testing.assert_array_equal(np.asarray(i_d), np.asarray(i_r))


@pytest.mark.slow
def test_distributed_index_multi_device_subprocess():
    """Real 8-way sharded search in a subprocess (own XLA device count):
    recall must match the single-shard search within 2 points."""
    code = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import JunoConfig, build, search, exact_topk, recall_1_at_k
from repro.data import make_dataset, DEEP_LIKE
from repro.dist.distributed_index import make_distributed_search, shard_index

pts, q = make_dataset(DEEP_LIKE, 8000, 32, key=jax.random.PRNGKey(5))
cfg = JunoConfig(n_clusters=32, n_entries=32, calib_queries=16, kmeans_iters=4)
idx = build(pts, cfg)
mesh = jax.make_mesh((8,), ("data",))
sidx = shard_index(idx, mesh)
dsearch = make_distributed_search(mesh, local_nprobe=2, k=100)
s_d, i_d = dsearch(sidx, q)
_, gt = exact_topk(q, pts, k=100)
r_dist = float(recall_1_at_k(i_d, gt[:, 0]))
_, i_s = search(idx, q, nprobe=16, k=100, mode="H")
r_single = float(recall_1_at_k(i_s, gt[:, 0]))
assert r_dist >= r_single - 0.07, (r_dist, r_single)
print("OK", r_dist, r_single)
'''
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], env=env, cwd=".",
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


@pytest.mark.slow
def test_distributed_mutable_side_buffer_multi_device_subprocess():
    """Real 8-way mutable sharded index: side-buffer cluster localization
    (`lin * C_local` offset per shard) must route every spilled point to
    exactly the shard owning its cluster — results bit-equal to the
    single-device MutableJunoIndex. A 1-device mesh cannot cover this (the
    offset is identically zero there)."""
    code = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro.core import JunoConfig, MutableJunoIndex, build
from repro.data import make_dataset, DEEP_LIKE
from repro.dist.distributed_index import DistributedMutableIndex

pts, q = make_dataset(DEEP_LIKE, 8000, 32, key=jax.random.PRNGKey(3))
cfg = JunoConfig(n_clusters=32, n_entries=32, calib_queries=16,
                 kmeans_iters=4, capacity_mult=1.1)
idx = build(pts, cfg)
mesh = jax.make_mesh((8,), ("data",))
dmi = DistributedMutableIndex(idx, mesh, side_capacity=64)
mid = MutableJunoIndex(idx, side_capacity=64)

# overfill the tightest cluster so at least 4 inserts spill to the side
c = int(np.argmin([dmi.free_slots(cc) for cc in range(32)]))
cent = np.asarray(idx.ivf.centroids[c])
rng = np.random.default_rng(1)
newpts = (cent[None] + 0.01 * rng.standard_normal(
    (dmi.free_slots(c) + 4, cent.shape[0]))).astype(np.float32)
ids_d, ids_s = dmi.insert(newpts), mid.insert(newpts)
assert ids_d == ids_s and dmi.side_fill == mid.side_fill >= 4
dmi.delete(ids_d[:2]); mid.delete(ids_s[:2])

dsearch = dmi.searcher(local_nprobe=4, k=10, mode="H")   # 4x8 = all clusters
s_d, i_d = dsearch(dmi.data, q[:16], dmi.side)
s_s, i_s = mid.search(q[:16], nprobe=32, k=10, mode="H", batch=16)
np.testing.assert_array_equal(np.asarray(i_d), np.asarray(i_s))
np.testing.assert_array_equal(np.asarray(s_d), np.asarray(s_s))
# spilled points must be found through the sharded path specifically
qs = newpts[2:]
_, got = dsearch(dmi.data, jax.numpy.asarray(qs), dmi.side)
assert all(ids_d[2 + j] in np.asarray(got)[j] for j in range(len(qs)))
print("OK")
'''
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], env=env, cwd=".",
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout

"""Extra coverage for the repro.dist subsystem beyond the seed tests:
degenerate-mesh equivalence as a property over search modes, and checkpoint
round-trips for mixed-dtype (bf16/int8/bool) pytrees, including the
``keep=``/overwrite and sharded-restore corners."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import JunoConfig, build, search
from repro.data import DEEP_LIKE, make_dataset
from repro.dist import checkpoint as ckpt
from repro.dist import compression
from repro.dist.distributed_index import (index_pspecs,
                                          make_distributed_search,
                                          shard_index)


@pytest.fixture(scope="module")
def small_index():
    pts, q = make_dataset(DEEP_LIKE, 3000, 12, key=jax.random.PRNGKey(9))
    cfg = JunoConfig(n_clusters=16, n_entries=32, calib_queries=16,
                     kmeans_iters=4)
    return build(pts, cfg), q


@pytest.mark.parametrize("mode", ["H", "H2", "M", "L"])
@pytest.mark.parametrize("nprobe,k", [(4, 10), (8, 50)])
def test_distributed_1mesh_matches_single_all_modes(small_index, mode,
                                                    nprobe, k):
    """Property: on a 1-device mesh the distributed search is the identity
    wrapper around plain ``search`` — exact same ids AND scores, for every
    operating mode of the paper."""
    idx, q = small_index
    mesh = jax.make_mesh((1,), ("data",))
    sidx = shard_index(idx, mesh)
    dsearch = make_distributed_search(mesh, local_nprobe=nprobe, k=k,
                                      mode=mode)
    s_d, i_d = dsearch(sidx, q)
    s_r, i_r = search(idx, q, nprobe=nprobe, k=k, mode=mode)
    np.testing.assert_array_equal(np.asarray(i_d), np.asarray(i_r))
    np.testing.assert_allclose(np.asarray(s_d), np.asarray(s_r), rtol=1e-6)


def test_distributed_fused_matches_single(small_index):
    """The fused two-stage path under shard_map: on a 1-device mesh it must
    be identity with local ``search(..., fused=True)`` — and with the
    composed distributed path (same candidate rule)."""
    idx, q = small_index
    mesh = jax.make_mesh((1,), ("data",))
    sidx = shard_index(idx, mesh)
    dfused = make_distributed_search(mesh, local_nprobe=4, k=10, mode="H2",
                                     fused=True)
    s_d, i_d = dfused(sidx, q)
    s_r, i_r = search(idx, q, nprobe=4, k=10, mode="H2", fused=True)
    np.testing.assert_array_equal(np.asarray(i_d), np.asarray(i_r))
    np.testing.assert_allclose(np.asarray(s_d), np.asarray(s_r),
                               rtol=1e-6, atol=1e-6)
    dcomp = make_distributed_search(mesh, local_nprobe=4, k=10, mode="H2")
    _, i_c = dcomp(sidx, q)
    np.testing.assert_array_equal(np.asarray(i_d), np.asarray(i_c))


def test_index_pspecs_matches_index_structure(small_index):
    """Every array leaf of the index has exactly one PartitionSpec whose
    rank matches — guards the shard_map in_specs against index refactors."""
    idx, _ = small_index
    mesh = jax.make_mesh((1,), ("data",))
    specs = index_pspecs(mesh)
    leaves, treedef = jax.tree.flatten(idx)
    spec_leaves = treedef.flatten_up_to(specs)
    assert len(leaves) == len(spec_leaves)
    for leaf, spec in zip(leaves, spec_leaves):
        assert len(spec) <= leaf.ndim, (spec, leaf.shape)


def test_checkpoint_roundtrip_mixed_dtypes(tmp_path):
    """bf16 / int8 / bool / f32 / int32-scalar leaves all survive the raw-
    bytes serialization with dtype and values intact."""
    tree = {
        "w32": jnp.linspace(-1, 1, 12).reshape(3, 4),
        "w16": jnp.linspace(-3, 3, 8).astype(jnp.bfloat16),
        "q": jnp.arange(-8, 8, dtype=jnp.int8).reshape(4, 4),
        "mask": jnp.asarray([True, False, True]),
        "nested": {"step": jnp.int32(41), "scale": jnp.float16(0.5)},
    }
    ckpt.save(str(tmp_path), 41, tree)
    restored, step = ckpt.restore(str(tmp_path), tree)
    assert step == 41
    for got, want in zip(jax.tree.leaves(restored), jax.tree.leaves(tree)):
        assert got.dtype == want.dtype
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_checkpoint_compressed_grads_roundtrip(tmp_path):
    """An int8-compressed gradient tree (Int8Leaf pytree) checkpoints and
    decompresses to the same values — the crash-during-all-reduce path."""
    g = {"w": jnp.linspace(-2, 2, 64).reshape(8, 8)}
    comp, _ = compression.compress_int8(g)
    ckpt.save(str(tmp_path), 1, comp)
    restored, _ = ckpt.restore(str(tmp_path), comp)
    dec_a = compression.decompress_int8(comp)
    dec_b = compression.decompress_int8(restored)
    np.testing.assert_array_equal(np.asarray(dec_a["w"]),
                                  np.asarray(dec_b["w"]))


def test_checkpoint_overwrite_same_step(tmp_path):
    """Re-saving a step replaces it atomically (restart writes step N again
    after replaying to it)."""
    ckpt.save(str(tmp_path), 2, {"x": jnp.zeros((3,))})
    ckpt.save(str(tmp_path), 2, {"x": jnp.ones((3,))})
    restored, step = ckpt.restore(str(tmp_path), {"x": jnp.zeros((3,))})
    assert step == 2
    np.testing.assert_array_equal(np.asarray(restored["x"]), np.ones((3,)))


def test_restore_missing_dir_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ckpt.restore(str(tmp_path / "nope"), {"x": jnp.zeros((1,))})
    assert ckpt.latest_step(str(tmp_path / "nope")) is None

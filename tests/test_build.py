"""`repro.build`: streaming construction, artifact store, rebuild/hot-swap.

Covers the subsystem's three contracts:

* **pipeline** — `build_streaming` matches the in-memory `core.build` on
  the same data/key (identical shapes/dtypes; H-tier recall within 0.01)
  while the raw point set is only ever resident one chunk at a time
  (asserted structurally via the `BuildProbe`, not RSS), and the sharded
  variant round-trips through `split_shards`/`merge_shards`.
* **store** — save/load round-trip preserves every array bit-for-bit
  (hypothesis over shapes/metrics), the rt grid folds into the same
  artifact, and schema-version / config-hash / integrity mismatches all
  raise `ArtifactError` before an index can reach serving.
* **rebuild** — after spills + tombstones, `rebuild_index` +
  `AnnServeEngine.swap_index` return the pre-swap (base ⊕ side ⊖
  tombstones) search results (scores bit-identical; ids identical at
  every non-boundary score level — the compact() invariant), the side
  buffer drains completely, serving continues across the swap under
  query/insert interleaving, and the distributed per-shard rebuild holds
  the same parity on a 1-device mesh.
"""
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.build import (ArtifactError, ArtifactStore, BuildProbe,
                         array_source, build_streaming,
                         build_streaming_sharded, config_hash, load_index,
                         merge_shards, rebuild_index, save_index,
                         split_shards, verify_artifact)
from repro.core import (JunoConfig, MutableJunoIndex, build, exact_topk,
                        recall_n_at_k, search)
from repro.data import DEEP_LIKE, TTI_LIKE, make_dataset
from repro.serve.ann import AnnServeEngine


@pytest.fixture(scope="module")
def base():
    pts, q = make_dataset(DEEP_LIKE, 6000, 32, key=jax.random.PRNGKey(3))
    cfg = JunoConfig(n_clusters=16, n_entries=16, calib_queries=12,
                     kmeans_iters=4, capacity_mult=1.1)
    return np.asarray(pts), np.asarray(q), cfg, build(pts, cfg)


# ---------------------------------------------------------------------------
# pipeline: streaming vs in-memory
# ---------------------------------------------------------------------------

def test_streaming_matches_inmemory_build(base):
    """Same data/key: identical shapes/dtypes everywhere, recall@10 within
    0.01 of the in-memory build, and the chunk probe proves the memory
    bound (every consumed chunk within budget, both passes chunked)."""
    pts, q, cfg, idx = base
    chunk = 1024
    probe = BuildProbe()
    sidx = build_streaming(array_source(pts, chunk), cfg, probe=probe)

    # memory bound, structurally: the pipeline consumed the set as chunks
    # within budget on EVERY pass (2, plus a 3rd targeted pass when the
    # tight capacity_mult forces overflow spill) and sampled at most
    # max_train_points rows
    n = pts.shape[0]
    assert probe.max_chunk_rows <= chunk
    assert probe.passes in (2, 3)
    assert probe.chunks == probe.passes * -(-n // chunk)
    assert probe.n_points == n
    assert probe.train_rows <= max(cfg.max_train_points, n)

    flat_m = jax.tree_util.tree_leaves(idx)
    flat_s = jax.tree_util.tree_leaves(sidx)
    assert len(flat_m) == len(flat_s)
    for a, b in zip(flat_m, flat_s):
        assert a.shape == b.shape and a.dtype == b.dtype

    _, gt = exact_topk(jnp.asarray(q), jnp.asarray(pts), k=10)
    recalls = {}
    for tag, ix in [("mem", idx), ("stream", sidx)]:
        _, ids = search(ix, q, nprobe=8, k=10, mode="H")
        recalls[tag] = float(recall_n_at_k(ids, gt))
    assert recalls["stream"] >= recalls["mem"] - 0.01, recalls


def test_streaming_subsampled_training_stays_bounded():
    """max_train_points < N: the reservoir (not the set) bounds training
    residency and the index still searches at a sane recall."""
    pts, q = make_dataset(DEEP_LIKE, 5000, 16, key=jax.random.PRNGKey(8))
    pts, q = np.asarray(pts), np.asarray(q)
    cfg = JunoConfig(n_clusters=16, n_entries=16, calib_queries=8,
                     kmeans_iters=3, max_train_points=2000)
    probe = BuildProbe()
    sidx = build_streaming(array_source(pts, 512), cfg, probe=probe)
    assert probe.train_rows == 2000
    assert probe.max_chunk_rows <= 512
    _, gt = exact_topk(jnp.asarray(q), jnp.asarray(pts), k=10)
    _, ids = search(sidx, q, nprobe=8, k=10, mode="H")
    assert float(recall_n_at_k(ids, gt)) > 0.3


def test_sharded_streaming_split_merge_roundtrip(base):
    """Per-shard parts carry exactly the rows dist would own; merging
    them reproduces the unsharded streaming build bit-for-bit."""
    pts, _, cfg, _ = base
    key = jax.random.PRNGKey(0)
    whole = build_streaming(array_source(pts, 2048), cfg, key=key)
    parts = build_streaming_sharded(array_source(pts, 2048), cfg, 4, key=key)
    assert len(parts) == 4
    cl = whole.ivf.centroids.shape[0] // 4
    for i, part in enumerate(parts):
        assert part.ivf.centroids.shape[0] == cl
        np.testing.assert_array_equal(
            np.asarray(part.ivf.point_ids),
            np.asarray(whole.ivf.point_ids[i * cl:(i + 1) * cl]))
    merged = merge_shards(parts)
    for a, b in zip(jax.tree_util.tree_leaves(whole),
                    jax.tree_util.tree_leaves(merged)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_split_shards_rejects_uneven(base):
    _, _, _, idx = base
    with pytest.raises(ValueError):
        split_shards(idx, 5)   # 16 clusters do not divide over 5 shards


def test_streaming_rejects_unstable_source(base):
    """A one-shot generator (exhausted on pass 2) must fail loudly, not
    silently build an empty index."""
    pts, _, cfg, _ = base

    one_shot = iter([pts[:2048], pts[2048:]])
    with pytest.raises(ValueError):
        build_streaming(one_shot, cfg)


# ---------------------------------------------------------------------------
# store: round-trip + fail-closed validation
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=8)
def _tiny_index(n_clusters: int, n_entries: int, metric: str, dim: int):
    spec = DEEP_LIKE if metric == "l2" else TTI_LIKE
    spec = type(spec)(spec.name, dim, metric, n_modes=8)
    pts, _ = make_dataset(spec, 400, 4, key=jax.random.PRNGKey(n_clusters))
    cfg = JunoConfig(n_clusters=n_clusters, n_entries=n_entries,
                     metric=metric, calib_queries=6, kmeans_iters=2,
                     grid_size=8)
    return build(pts, cfg), cfg


@settings(max_examples=5, deadline=None)
@given(st.sampled_from([4, 8]), st.sampled_from([8, 16]),
       st.sampled_from(["l2", "ip"]), st.sampled_from([8, 16]))
def test_store_roundtrip_bit_exact(n_clusters, n_entries, metric, dim):
    """save/load preserves every array bit-for-bit across shapes/metrics.

    (No pytest fixtures here: hypothesis-wrapped tests can't take them —
    tempfile stands in for tmp_path.)
    """
    import tempfile
    idx, cfg = _tiny_index(n_clusters, n_entries, metric, dim)
    with tempfile.TemporaryDirectory() as d:
        _roundtrip(os.path.join(d, "art"), idx, cfg)


def _roundtrip(path, idx, cfg):
    manifest = save_index(path, idx, cfg)
    assert manifest["shapes"]["c"] == cfg.n_clusters
    loaded = load_index(path, expect_config=cfg)
    assert loaded.rt_grid is None
    assert loaded.config == cfg
    for a, b in zip(jax.tree_util.tree_leaves(idx),
                    jax.tree_util.tree_leaves(loaded.data)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_store_folds_rt_grid(base, tmp_path):
    """An index and its calibrated rt grid travel as ONE artifact."""
    from repro import rt as rt_lib
    pts, _, cfg, idx = base
    grid = rt_lib.build_grid(idx, metric="l2", calib_queries=8,
                             points=pts)
    path = str(tmp_path / "with_grid")
    save_index(path, idx, cfg, rt_grid=grid, extra={"shard": 0})
    loaded = load_index(path)
    assert loaded.manifest["extra"] == {"shard": 0}
    assert loaded.rt_grid is not None
    for a, b in zip(jax.tree_util.tree_leaves(grid),
                    jax.tree_util.tree_leaves(loaded.rt_grid)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_store_version_and_hash_mismatch_raise(base, tmp_path):
    import json
    pts, _, cfg, idx = base
    path = str(tmp_path / "art")
    save_index(path, idx, cfg)

    # wrong expected config -> config-hash mismatch
    other = JunoConfig(n_clusters=8)
    assert config_hash(other) != config_hash(cfg)
    with pytest.raises(ArtifactError, match="config hash"):
        load_index(path, expect_config=other)

    # corrupted array bytes -> integrity failure
    import numpy as _np
    apath = os.path.join(path, "arrays.npz")
    with _np.load(apath) as z:
        arrays = {k: z[k].copy() for k in z.files}
    arrays["codes"][0, 0] ^= 1
    _np.savez(apath, **arrays)
    with pytest.raises(ArtifactError, match="checksum"):
        load_index(path)
    _np.savez(apath, **{k: v for k, v in arrays.items() if k != "codes"})
    with pytest.raises(ArtifactError, match="array set"):
        load_index(path)

    # future schema version -> fail closed (checked before anything else)
    mpath = os.path.join(path, "manifest.json")
    manifest = json.load(open(mpath))
    manifest["schema_version"] = 999
    json.dump(manifest, open(mpath, "w"))
    with pytest.raises(ArtifactError, match="schema version"):
        load_index(path)


def test_artifact_store_versions_and_latest(base, tmp_path):
    pts, _, cfg, idx = base
    store = ArtifactStore(str(tmp_path / "store"))
    assert store.latest("main") is None
    with pytest.raises(ArtifactError):
        store.get("main")
    v1 = store.put("main", idx, cfg)
    v2 = store.put("main", idx, cfg)
    assert (v1, v2) == (1, 2)
    assert store.versions("main") == [1, 2]
    loaded = store.get("main", expect_config=cfg)
    np.testing.assert_array_equal(np.asarray(loaded.data.codes),
                                  np.asarray(idx.codes))
    old = store.get("main", version=1)
    assert old.manifest["config_hash"] == config_hash(cfg)


def test_put_retries_past_concurrent_commit(base, tmp_path, monkeypatch):
    """A racing writer grabbing the computed generation number must not
    crash put or clobber either artifact: the rename's exclusive-create
    failure retries onto the next number (regression — the old put
    renamed once onto a precomputed path and leaked the OSError)."""
    import errno as _errno  # noqa: F401 — documents the contended errnos
    pts, _, cfg, idx = base
    store = ArtifactStore(str(tmp_path / "store"))
    assert store.put("main", idx, cfg) == 1

    real_rename = os.rename
    raced = {"n": 0}

    def racing_rename(src, dst):
        if os.path.basename(src).startswith(".tmp-") and raced["n"] == 0:
            raced["n"] += 1
            # a concurrent writer commits this generation just before us
            os.makedirs(dst)
            with open(os.path.join(dst, "manifest.json"), "w") as fh:
                fh.write("{}")
        return real_rename(src, dst)

    monkeypatch.setattr(os, "rename", racing_rename)
    v = store.put("main", idx, cfg)
    monkeypatch.undo()
    assert raced["n"] == 1
    assert v == 3 and store.versions("main") == [1, 2, 3]
    loaded = store.get("main", version=3, expect_config=cfg)
    np.testing.assert_array_equal(np.asarray(loaded.data.codes),
                                  np.asarray(idx.codes))


def test_put_crash_at_rename_leaves_no_partial_generation(base, tmp_path,
                                                          monkeypatch):
    """Dying between the artifact write and the publishing rename leaves
    the store exactly as it was: no new version, no temp debris visible
    to versions()/latest(), and the surviving generation still verifies."""
    import errno
    pts, _, cfg, idx = base
    store = ArtifactStore(str(tmp_path / "store"))
    assert store.put("main", idx, cfg) == 1

    def crash(src, dst):
        raise OSError(errno.EIO, "simulated crash at rename")

    monkeypatch.setattr(os, "rename", crash)
    with pytest.raises(OSError):
        store.put("main", idx, cfg)
    monkeypatch.undo()
    assert store.versions("main") == [1] and store.latest("main") == 1
    assert os.listdir(os.path.join(store.root, "main")) == ["v0001"]
    verify_artifact(store.path("main", 1))
    assert store.put("main", idx, cfg) == 2          # store still writable


def test_put_fsyncs_artifact_before_publishing(base, tmp_path, monkeypatch):
    """Durability ordering: every artifact byte (files AND directory
    entries) is fsynced before the rename makes the generation visible,
    and the parent directory is fsynced after it."""
    pts, _, cfg, idx = base
    store = ArtifactStore(str(tmp_path / "store"))
    events = []
    real_fsync, real_rename = os.fsync, os.rename
    monkeypatch.setattr(
        os, "fsync", lambda fd: (events.append("fsync"), real_fsync(fd))[1])
    monkeypatch.setattr(
        os, "rename",
        lambda s, d: (events.append("rename"), real_rename(s, d))[1])
    store.put("main", idx, cfg)
    monkeypatch.undo()
    r = events.index("rename")
    assert events[:r].count("fsync") >= 3    # arrays.npz, manifest.json, dir
    assert "fsync" in events[r + 1:]         # parent dir after publish


def test_load_verify_levels(base, tmp_path):
    """The three-level fail-closed contract: a flipped array bit trips
    only "full" (and bool True); shape/dtype/set stay checked at
    "manifest" (and bool False); "never" still refuses a foreign schema
    version; junk levels raise ValueError."""
    import json
    pts, _, cfg, idx = base
    path = str(tmp_path / "art")
    save_index(path, idx, cfg)
    apath = os.path.join(path, "arrays.npz")
    with np.load(apath) as z:
        arrays = {k: z[k].copy() for k in z.files}
    corrupt = {k: v.copy() for k, v in arrays.items()}
    corrupt["codes"][0, 0] ^= 1
    np.savez(apath, **corrupt)

    for v in ("full", True):
        with pytest.raises(ArtifactError, match="checksum"):
            load_index(path, verify=v)
    for v in ("manifest", "never", False):
        loaded = load_index(path, verify=v)          # no data digests read
        assert loaded.data.codes.shape == idx.codes.shape
    with pytest.raises(ValueError, match="verify"):
        load_index(path, verify="paranoid")

    # a missing array is a set mismatch: caught at "manifest" level
    np.savez(apath, **{k: v for k, v in corrupt.items() if k != "codes"})
    with pytest.raises(ArtifactError, match="array set"):
        load_index(path, verify="manifest")

    # schema version gates every level, including "never"
    np.savez(apath, **arrays)
    mpath = os.path.join(path, "manifest.json")
    manifest = json.load(open(mpath))
    manifest["schema_version"] = 999
    json.dump(manifest, open(mpath, "w"))
    with pytest.raises(ArtifactError, match="schema version"):
        load_index(path, verify="never")


def test_load_index_mmap_bit_parity(base, tmp_path):
    """mmap_mode="r" returns read-only memmap views bit-identical to the
    resident load, defaults to manifest-level verification (no data
    read), and still honors verify="full" by paging everything through
    the digest check."""
    pts, _, cfg, idx = base
    path = str(tmp_path / "art")
    save_index(path, idx, cfg)
    full = load_index(path)
    mm = load_index(path, mmap_mode="r")
    leaves = jax.tree_util.tree_leaves(mm.data)
    assert all(isinstance(b, np.memmap) for b in leaves)
    for a, b in zip(jax.tree_util.tree_leaves(full.data), leaves):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError, match="mmap_mode"):
        load_index(path, mmap_mode="w")

    apath = os.path.join(path, "arrays.npz")
    with np.load(apath) as z:
        arrays = {k: z[k].copy() for k in z.files}
    arrays["cluster_codes"][0, 0, 0] ^= 1
    np.savez(apath, **arrays)
    load_index(path, mmap_mode="r")                  # manifest default
    with pytest.raises(ArtifactError, match="checksum"):
        load_index(path, mmap_mode="r", verify="full")


def test_manifest_carries_per_cluster_digests(base, tmp_path):
    """save_index records one sha256 per cluster_codes row — the paged
    tier's first-touch verification source — and load-time checks reject
    a digest table whose length disagrees with the row count."""
    import json
    pts, _, cfg, idx = base
    path = str(tmp_path / "art")
    manifest = save_index(path, idx, cfg)
    rows = manifest["arrays"]["cluster_codes"]["sha256_rows"]
    assert len(rows) == cfg.n_clusters
    assert len(set(rows)) > 1                        # real per-row digests
    mpath = os.path.join(path, "manifest.json")
    on_disk = json.load(open(mpath))
    on_disk["arrays"]["cluster_codes"]["sha256_rows"] = rows[:-1]
    json.dump(on_disk, open(mpath, "w"))
    with pytest.raises(ArtifactError, match="per-row digests"):
        load_index(path, verify="manifest")


# ---------------------------------------------------------------------------
# rebuild + hot swap
# ---------------------------------------------------------------------------

def _spill_and_tombstone(eng, idx, pts, rng, n_extra=4):
    """Overfill the tightest cluster (forcing side spills) and tombstone
    two of its original members. Returns the inserted rows and ids."""
    mid = eng.index
    n_clusters = mid.data.ivf.point_ids.shape[0]
    free = [mid.free_slots(c) for c in range(n_clusters)]
    c = int(np.argmin(free))
    cent = np.asarray(idx.ivf.centroids[c])
    newpts = (cent[None] + 0.02 * rng.standard_normal(
        (free[c] + n_extra, cent.shape[0]))).astype(np.float32)
    ids = eng.insert(newpts)
    assert mid.side_fill >= n_extra
    row_ids = np.asarray(mid.data.ivf.point_ids[c])
    row_valid = np.asarray(mid.data.ivf.valid[c])
    victims = [int(p) for p in row_ids[row_valid] if p < len(pts)][:2]
    eng.delete(victims)
    return newpts, ids, victims


def _assert_same_results(s0, i0, s1, i1):
    """Scores bit-identical; id sets identical at every non-boundary score
    level (the only freedom is lax.top_k's index-order tie-break among
    exactly equal scores — rebuild changes flat positions)."""
    np.testing.assert_array_equal(s0, s1)
    for r in range(s0.shape[0]):
        boundary = s0[r, -1]
        for v in np.unique(s0[r][s0[r] != boundary]):
            assert set(i0[r][s0[r] == v]) == set(i1[r][s1[r] == v]), (r, v)


@pytest.mark.parametrize("mode", ["H", "H2", "M"])
def test_rebuild_swap_id_parity(base, mode):
    """Post-swap search == pre-swap (base ⊕ side ⊖ tombstones) search."""
    pts, q, cfg, idx = base
    eng = AnnServeEngine(idx, side_capacity=64)
    rng = np.random.default_rng(11)
    newpts, ids, _ = _spill_and_tombstone(eng, idx, pts, rng)

    qq = np.concatenate([q[:16], newpts[:2]], axis=0)
    r0 = eng.submit(qq, k=20, mode=mode)
    eng.run()
    gen = eng.swap_index()
    assert gen == 1 and eng.index.side_fill == 0
    r1 = eng.submit(qq, k=20, mode=mode)
    eng.run()
    _assert_same_results(r0.scores, r0.ids, r1.scores, r1.ids)


def test_rebuild_swap_under_query_insert_interleaving(base):
    """Serving continues across generations: query waves interleave with
    inserts and TWO hot swaps; every inserted point stays retrievable,
    every pre-swap result is reproduced post-swap, ids never repeat."""
    pts, q, cfg, idx = base
    eng = AnnServeEngine(idx, side_capacity=64)
    rng = np.random.default_rng(13)
    all_ids = []
    for wave in range(2):
        newpts, ids, _ = _spill_and_tombstone(eng, idx, pts, rng)
        all_ids.extend(ids)
        qq = np.concatenate([q[8 * wave:8 * wave + 8], newpts[:2]], axis=0)
        r0 = eng.submit(qq, k=20, mode="H")
        eng.run()
        assert eng.swap_index() == wave + 1
        assert eng.index.side_fill == 0
        # compact() is no longer a no-op: the buffer is empty, and a fresh
        # insert lands in a REAL cluster slot of the rebuilt index
        assert eng.compact() == 0
        r1 = eng.submit(qq, k=20, mode="H")
        eng.run()
        _assert_same_results(r0.scores, r0.ids, r1.scores, r1.ids)
        # inserted points remain retrievable in the new generation
        req = eng.submit(newpts, k=10, mode="H", nprobe=16)
        eng.run()
        assert all(ids[j] in req.ids[j] for j in range(len(ids)))
    assert len(set(all_ids)) == len(all_ids), "ids repeated across swaps"


def test_compact_rebuilds_stuck_spills(base):
    """compact() drains spills whose cluster has NO free slot (the case
    the old fold-only compact could never resolve) by rebuilding."""
    pts, q, cfg, idx = base
    eng = AnnServeEngine(idx, side_capacity=64)
    rng = np.random.default_rng(17)
    mid = eng.index
    free = [mid.free_slots(c) for c in range(16)]
    c = int(np.argmin(free))
    cent = np.asarray(idx.ivf.centroids[c])
    newpts = (cent[None] + 0.02 * rng.standard_normal(
        (free[c] + 5, cent.shape[0]))).astype(np.float32)
    ids = eng.insert(newpts)
    stuck = mid.side_fill
    assert stuck >= 5   # no deletes: these can never fold without rebuild
    assert eng.compact(rebuild=False) == 0 and mid.side_fill == stuck
    moved = eng.compact()
    assert moved == stuck and mid.side_fill == 0
    assert eng.generation == 1
    # capacity grew to absorb the drained spills; points still retrievable
    req = eng.submit(newpts, k=10, mode="H", nprobe=16)
    eng.run()
    assert all(ids[j] in req.ids[j] for j in range(len(ids)))
    # rebuild=True FORCES a repack even with an empty side buffer
    assert eng.compact(rebuild=True) == 0
    assert eng.generation == 2


def test_rebuild_index_standalone_matches_mutable_search(base):
    """rebuild_index on a bare MutableJunoIndex (no engine) preserves
    results and drops tombstoned ids from storage entirely."""
    pts, q, cfg, idx = base
    mid = MutableJunoIndex(idx, side_capacity=64)
    rng = np.random.default_rng(19)
    free = [mid.free_slots(c) for c in range(16)]
    c = int(np.argmin(free))
    cent = np.asarray(idx.ivf.centroids[c])
    newpts = (cent[None] + 0.02 * rng.standard_normal(
        (free[c] + 3, cent.shape[0]))).astype(np.float32)
    mid.insert(newpts)
    victims = [int(p) for p in np.asarray(idx.ivf.point_ids[c])[:2]]
    mid.delete(victims)

    s0, i0 = (np.asarray(x) for x in mid.search(q[:16], nprobe=8, k=20,
                                                mode="H"))
    new_data = rebuild_index(mid)
    stored = np.asarray(new_data.ivf.point_ids)
    for v in victims:
        assert v not in stored[stored >= 0]
    mid.swap_data(new_data)
    assert mid.side_fill == 0
    s1, i1 = (np.asarray(x) for x in mid.search(q[:16], nprobe=8, k=20,
                                                mode="H"))
    _assert_same_results(s0, i0, s1, i1)


def test_distributed_per_shard_rebuild_parity(base):
    """1-device mesh: per-shard rebuild drains the side buffer and the
    distributed search is unchanged (scores AND ids bit-equal here — the
    shard repack preserves in-cluster slot order)."""
    from repro.dist.distributed_index import DistributedMutableIndex

    pts, q, cfg, idx = base
    mesh = jax.make_mesh((1,), ("data",))
    dmi = DistributedMutableIndex(idx, mesh, side_capacity=64)
    rng = np.random.default_rng(23)
    free = [dmi.free_slots(c) for c in range(16)]
    c = int(np.argmin(free))
    cent = np.asarray(idx.ivf.centroids[c])
    newpts = (cent[None] + 0.02 * rng.standard_normal(
        (free[c] + 3, cent.shape[0]))).astype(np.float32)
    ids = dmi.insert(newpts)
    assert dmi.side_fill >= 3
    victims = [int(p) for p in np.asarray(idx.ivf.point_ids[c])[:3]]
    dmi.delete(victims)

    dsearch = dmi.searcher(local_nprobe=16, k=10, mode="H")
    s0, i0 = dsearch(dmi.data, jnp.asarray(q[:16]), dmi.side)
    drained = dmi.rebuild()
    assert drained >= 3 and dmi.side_fill == 0
    s1, i1 = dsearch(dmi.data, jnp.asarray(q[:16]), dmi.side)
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    # bookkeeping stays consistent: inserts still place correctly
    more = dmi.insert(newpts[:2])
    assert more[0] > max(ids)
    s2, i2 = dsearch(dmi.data, jnp.asarray(newpts[:2]), dmi.side)
    assert all(more[j] in np.asarray(i2)[j] for j in range(2))


def test_distributed_rebuild_escalates_stuck_spills(base):
    """Spills whose cluster is FULL (no tombstones) cannot fit the fixed
    per-shard capacity: rebuild() must escalate to a capacity-growing
    full swap and still drain the buffer — the same guarantee the
    single-device compact() gives."""
    from repro.dist.distributed_index import DistributedMutableIndex

    pts, q, cfg, idx = base
    mesh = jax.make_mesh((1,), ("data",))
    dmi = DistributedMutableIndex(idx, mesh, side_capacity=64)
    rng = np.random.default_rng(37)
    free = [dmi.free_slots(c) for c in range(16)]
    c = int(np.argmin(free))
    cent = np.asarray(idx.ivf.centroids[c])
    newpts = (cent[None] + 0.02 * rng.standard_normal(
        (free[c] + 4, cent.shape[0]))).astype(np.float32)
    ids = dmi.insert(newpts)
    assert dmi.side_fill >= 4          # cluster full, NO deletes
    old_cap = dmi.data.ivf.point_ids.shape[1]
    drained = dmi.rebuild()
    assert drained >= 4 and dmi.side_fill == 0
    assert dmi.data.ivf.point_ids.shape[1] > old_cap   # capacity grew
    dsearch = dmi.searcher(local_nprobe=16, k=10, mode="H")
    _, got = dsearch(dmi.data, jnp.asarray(newpts), dmi.side)
    assert all(ids[j] in np.asarray(got)[j] for j in range(len(ids)))
    # bookkeeping survived the swap: fresh inserts land in real slots
    more = dmi.insert(newpts[:1])
    assert more[0] > max(ids) and dmi.side_fill == 0


def test_swap_rebuilds_rt_routing_lazily(base):
    """prefilter="rt": swap_index drops the grid + routing snapshot; the
    next rt-routed request rebuilds both lazily and serves correctly."""
    pts, q, cfg, idx = base
    eng = AnnServeEngine(idx, side_capacity=64, prefilter="rt",
                         rt_scale=1e6)   # full coverage: parity regime
    rng = np.random.default_rng(31)
    newpts, ids, _ = _spill_and_tombstone(eng, idx, pts, rng)
    assert eng.index.rt_grid is not None
    eng.swap_index()
    assert eng.index.rt_grid is None and eng._rt_state is None
    req = eng.submit(newpts, k=10, mode="H", nprobe=16)
    eng.run()
    assert eng.index.rt_grid is not None   # rebuilt on demand
    assert all(ids[j] in req.ids[j] for j in range(len(ids)))


def test_streaming_to_store_to_serving_lifecycle(base, tmp_path):
    """End-to-end: stream-build → versioned store → load → serve → spill
    → rebuild → next store generation. The full offline/online loop."""
    pts, q, cfg, _ = base
    store = ArtifactStore(str(tmp_path / "lifecycle"))
    sidx = build_streaming(array_source(pts, 2048), cfg)
    store.put("prod", sidx, cfg)

    loaded = store.get("prod", expect_config=cfg)
    eng = AnnServeEngine(loaded.data, side_capacity=64)
    rng = np.random.default_rng(29)
    _spill_and_tombstone(eng, loaded.data, pts, rng)
    eng.swap_index()
    v2 = store.put("prod", eng.index.data, cfg)
    assert v2 == 2
    again = store.get("prod")
    np.testing.assert_array_equal(
        np.asarray(again.data.ivf.point_ids),
        np.asarray(eng.index.data.ivf.point_ids))

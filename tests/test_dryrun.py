"""Dry-run regression: one cheap cell per step-kind must lower+compile on
the 512-device multi-pod mesh (subprocess: device count is locked at jax
init, so the production mesh cannot be built inside the main test process)."""
import json
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_dryrun_cells_compile():
    code = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.dryrun import lower_cell, lower_juno_cell

# cheapest representative of each step kind + the paper cell
r1 = lower_cell("hymba_1_5b", "long_500k", multi_pod=True)
assert r1["status"] == "ok", r1.get("error")
assert r1["roofline"]["dominant"] in ("compute", "memory", "collective")
assert r1["n_chips"] == 512

r2 = lower_cell("mamba2_1_3b", "train_4k", multi_pod=False, sp=True)
assert r2["status"] == "ok", r2.get("error")
assert r2["analytic_flops_per_chip"] > 0
assert r2["useful_flop_ratio"] > 0.3

r3 = lower_cell("phi4_mini_3_8b", "long_500k", multi_pod=False)
assert r3["status"] == "skip" and "full-attention" in r3["reason"]

r4 = lower_juno_cell(multi_pod=False)
assert r4["status"] == "ok", r4.get("error")
print("OK")
'''
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], env=env, cwd=".",
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


def test_dryrun_artifacts_exist_and_complete():
    """The committed sweep artifacts cover every (arch × shape × mesh) cell
    with ok or a documented skip."""
    from repro.configs import ARCH_IDS
    from repro.launch.shapes import SHAPES
    missing, bad = [], []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            for mesh in ("single", "multi"):
                p = f"experiments/dryrun/{arch}_{shape}_{mesh}.json"
                if not os.path.exists(p):
                    missing.append(p)
                    continue
                r = json.load(open(p))
                if r["status"] not in ("ok", "skip"):
                    bad.append((p, r.get("error", "")[:80]))
    assert not missing, missing
    assert not bad, bad

"""AnnServeFleet: routing, admission control, failover, latency accounting.

The fleet contract under test: replicas are pure scale-out (results are
bit-identical to a single-replica run, and to a direct ``search()`` with
the resolved signature, no matter which replica served a request or how
many exist), admission failures are typed values (never data-plane
exceptions), deadline-expired requests cost zero compute, and the
mutation plane keeps every replica id-identical. Sharded-replica tests
need >= 4 emulated devices and run in the multidevice CI job
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
"""
import time

import jax
import numpy as np
import pytest

from repro.core import JunoConfig, build, search
from repro.data import DEEP_LIKE, make_dataset
from repro.serve.fleet import AnnServeFleet, LatencyHistogram, Rejection


@pytest.fixture(scope="module")
def served():
    pts, q = make_dataset(DEEP_LIKE, 3000, 40, key=jax.random.PRNGKey(17))
    cfg = JunoConfig(n_clusters=16, n_entries=32, calib_queries=16,
                     kmeans_iters=4, capacity_mult=1.1)
    return np.asarray(pts), np.asarray(q), build(pts, cfg)


def test_fleet_matches_single_replica_and_direct_search(served):
    """Replica scale-out must not change results: a 3-replica fleet, a
    1-replica fleet, and a direct search() agree bit-for-bit per request."""
    _, q, idx = served
    fleet = AnnServeFleet(idx, n_replicas=3)
    solo = AnnServeFleet(idx, n_replicas=1)
    waves = [(q[:5], dict(k=10, mode="H", nprobe=8)),
             (q[5:9], dict(k=10, mode="M", nprobe=8)),
             (q[9:10], dict(k=50, mode="H2")),
             (q[10:20], dict(k=10, mode="L", nprobe=4))]
    rf = [fleet.submit(qs, **kw) for qs, kw in waves]
    rs = [solo.submit(qs, **kw) for qs, kw in waves]
    assert fleet.run() == solo.run() == 20
    for req, ref in zip(rf, rs):
        assert req.done and ref.done
        np.testing.assert_array_equal(req.ids, ref.ids)
        np.testing.assert_array_equal(req.scores, ref.scores)
        eng = fleet.engines[req.replica]
        k, mode, nprobe = eng.route(req.inner)
        s, ids = search(idx, req.queries, nprobe=nprobe, k=k, mode=mode,
                        batch=req.queries.shape[0])
        np.testing.assert_array_equal(np.asarray(ids)[:, :req.k], req.ids)
        np.testing.assert_array_equal(np.asarray(s)[:, :req.k], req.scores)


def test_least_outstanding_routing(served):
    """Each submit lands on the emptiest replica: equal-sized requests
    round-robin across an idle fleet instead of piling onto one engine."""
    _, q, idx = served
    fleet = AnnServeFleet(idx, n_replicas=2)
    for i in range(4):
        fleet.submit(q[i * 2:(i + 1) * 2], k=10, mode="H", nprobe=8)
    assert [fleet.outstanding(r) for r in range(2)] == [4, 4]
    fleet.run()
    assert all(c["served"] == 2 for c in fleet.stats["per_replica"])


def test_queue_full_sheds_typed_rejection(served):
    """policy="shed" at capacity returns a typed Rejection on the request —
    no exception, and the shed request costs no compute."""
    _, q, idx = served
    fleet = AnnServeFleet(idx, n_replicas=2, max_queue=8, policy="shed")
    ok = [fleet.submit(q[:8]) for _ in range(2)]   # fills both replicas
    shed = fleet.submit(q[:8])
    assert all(r.status == "queued" for r in ok)
    assert shed.status == "shed" and not shed.done and shed.ids is None
    assert isinstance(shed.rejection, Rejection)
    assert shed.rejection.reason == "queue_full"
    assert fleet.run() == 16                       # only admitted rows ran
    assert fleet.latency_summary()["shed"] == 1


def test_queue_policy_backlogs_and_drains(served):
    """policy="queue" parks overflow in the fleet backlog instead of
    shedding, and drains it as replica capacity frees."""
    _, q, idx = served
    fleet = AnnServeFleet(idx, n_replicas=2, max_queue=8, policy="queue")
    reqs = [fleet.submit(q[:8]) for _ in range(4)]
    assert len(fleet.backlog) == 2
    fleet.run()
    assert all(r.done for r in reqs) and not fleet.backlog
    assert fleet.latency_summary()["shed"] == 0


def test_deadline_expires_before_compute(served):
    """A request whose deadline passes while queued is dropped BEFORE any
    jitted work: the engine's query counter must stay at zero."""
    _, q, idx = served
    fleet = AnnServeFleet(idx, n_replicas=1, default_deadline_s=0.0)
    req = fleet.submit(q[:4])
    live = fleet.submit(q[4:6], deadline_s=60.0)   # per-request override
    time.sleep(0.005)
    fleet.run()
    assert req.status == "expired" and req.rejection.reason == "deadline"
    assert live.done
    assert fleet.engines[0].stats["queries"] == 2  # only the live rows ran
    assert fleet.latency_summary()["expired"] == 1


def test_failover_preserves_results(served):
    """Failing a replica re-routes its queued work to survivors and the
    answers are exactly what a single-replica run produces."""
    _, q, idx = served
    fleet = AnnServeFleet(idx, n_replicas=2)
    solo = AnnServeFleet(idx, n_replicas=1)
    rf = [fleet.submit(q[i * 2:(i + 1) * 2], k=10, mode="H", nprobe=8)
          for i in range(6)]
    rs = [solo.submit(q[i * 2:(i + 1) * 2], k=10, mode="H", nprobe=8)
          for i in range(6)]
    assert fleet.fail_replica(0) == 3              # its queued half moves
    fleet.run()
    solo.run()
    assert all(r.done and r.replica == 1 for r in rf)
    for req, ref in zip(rf, rs):
        np.testing.assert_array_equal(req.ids, ref.ids)
    assert fleet.stats["rerouted"] == 3
    assert fleet.engines[0].stats["queries"] == 0  # failed replica idle
    fleet.restore_replica(0)
    back = fleet.submit(q[:2], k=10, mode="H", nprobe=8)
    fleet.run()
    assert back.done and back.replica == 0         # LOR prefers the idle one


def test_all_down_sheds_no_replica(served):
    _, q, idx = served
    fleet = AnnServeFleet(idx, n_replicas=1)
    fleet.fail_replica(0)
    req = fleet.submit(q[:2])
    assert req.status == "shed" and req.rejection.reason == "no_replica"


def test_mutations_fan_out_to_all_replicas(served):
    """insert/delete hit every replica with identical ids, so a query routed
    anywhere — including a replica that was 'down' during the write — sees
    the mutation."""
    _, q, idx = served
    fleet = AnnServeFleet(idx, n_replicas=2)
    rng = np.random.default_rng(2)
    newpts = (q[:4] + 0.03 * rng.standard_normal(q[:4].shape)
              ).astype(np.float32)
    fleet.fail_replica(1)                          # writes still land on it
    ids = fleet.insert(newpts)
    fleet.restore_replica(1)
    fleet.fail_replica(0)                          # force reads onto 1
    req = fleet.submit(newpts, k=10, mode="H", nprobe=16)
    fleet.run()
    assert req.replica == 1
    assert all(ids[j] in req.ids[j] for j in range(4))
    fleet.restore_replica(0)
    assert fleet.delete(ids[:2]) == 2
    req2 = fleet.submit(newpts[:2], k=10, mode="H", nprobe=16)
    fleet.run()
    assert all(ids[j] not in req2.ids[j] for j in range(2))


def test_trace_timestamps_ordered(served):
    """Served requests carry a monotone arrival→batch→compute→done chain and
    the histogram absorbs exactly the served count."""
    _, q, idx = served
    fleet = AnnServeFleet(idx, n_replicas=2)
    reqs = [fleet.submit(q[i:i + 1]) for i in range(6)]
    fleet.run()
    for req in reqs:
        tr = req.trace()
        assert set(tr) == {"queue", "compute", "merge", "total"}
        assert all(v >= 0 for v in tr.values())
        assert tr["total"] >= tr["compute"]
    summ = fleet.latency_summary()
    assert summ["n"] == summ["served"] == 6
    assert summ["p50"] <= summ["p95"] <= summ["p99"] <= summ["max"]
    fleet.reset_metrics()
    assert fleet.latency_summary()["n"] == 0


def test_latency_histogram_percentiles():
    """Log-bucketed percentile is a <=10% over-estimate (upper bucket edge),
    never an under-estimate, and merge is exact on the counts."""
    h = LatencyHistogram()
    vals = [10 ** (i / 250.0 - 4) for i in range(1000)]   # 100us..1s sweep
    for v in vals:
        h.add(v)
    exact = np.quantile(vals, [0.5, 0.95, 0.99])
    for p, e in zip([0.5, 0.95, 0.99], exact):
        got = h.percentile(p)
        assert e <= got <= e * 1.11, (p, e, got)
    assert h.percentile(1.0) == h.max == max(vals)
    h2 = LatencyHistogram()
    h2.add(5.0)                     # above hi=500? no — in range
    h2.merge(h)
    assert h2.n == 1001 and h2.max == 5.0
    assert LatencyHistogram().summary()["n"] == 0
    with pytest.raises(ValueError):
        h.merge(LatencyHistogram(bins_per_decade=10))


def test_histogram_overflow_clamps_to_max():
    h = LatencyHistogram(lo=1e-3, hi=1.0)
    h.add(50.0)                     # overflow bucket
    assert h.percentile(0.99) == 50.0


# ---- sharded replicas (>= 4 emulated devices; multidevice CI job) --------
needs4 = pytest.mark.skipif(jax.device_count() < 4,
                            reason="needs >=4 devices "
                                   "(xla_force_host_platform_device_count)")


@needs4
def test_sharded_fleet_replica_invariance(served):
    """2 replicas x 2 shards and 1 replica x 2 shards agree bit-for-bit:
    the replica dimension never changes results, only capacity."""
    _, q, idx = served
    f22 = AnnServeFleet(idx, n_replicas=2, shards_per_replica=2,
                        batch_buckets=(8, 16))
    f12 = AnnServeFleet(idx, n_replicas=1, shards_per_replica=2,
                        batch_buckets=(8, 16))
    for f in (f22, f12):
        assert f.engines[0].index.n_shards == 2
    r22 = [f22.submit(q[i * 4:(i + 1) * 4], k=10, mode="M", nprobe=8)
           for i in range(3)]
    r12 = [f12.submit(q[i * 4:(i + 1) * 4], k=10, mode="M", nprobe=8)
           for i in range(3)]
    assert f22.run() == f12.run() == 12
    for a, b in zip(r22, r12):
        np.testing.assert_array_equal(a.ids, b.ids)
        np.testing.assert_array_equal(a.scores, b.scores)


@needs4
def test_sharded_fleet_full_coverage_matches_unsharded(served):
    """At full probe coverage (nprobe = n_clusters) the per-shard budget
    scans every cluster, so the exact merge reproduces unsharded search
    bit-for-bit — sharding is pure partitioning, not approximation."""
    _, q, idx = served
    fleet = AnnServeFleet(idx, n_replicas=2, shards_per_replica=2,
                          batch_buckets=(8, 16))
    req = fleet.submit(q[:8], k=10, mode="H", nprobe=16)
    fleet.run()
    s, ids = search(idx, q[:8], nprobe=16, k=10, mode="H", batch=8)
    np.testing.assert_array_equal(np.asarray(ids), req.ids)
    np.testing.assert_array_equal(np.asarray(s), req.scores)


@needs4
def test_sharded_fleet_insert_visible(served):
    """Inserts fan out through the routed scatter on every replica's
    sub-mesh and are immediately servable (side-buffer path included)."""
    _, q, idx = served
    fleet = AnnServeFleet(idx, n_replicas=2, shards_per_replica=2,
                          batch_buckets=(8, 16))
    rng = np.random.default_rng(3)
    newpts = (q[:4] + 0.03 * rng.standard_normal(q[:4].shape)
              ).astype(np.float32)
    ids = fleet.insert(newpts)
    req = fleet.submit(newpts, k=10, mode="H", nprobe=16)
    fleet.run()
    assert all(ids[j] in req.ids[j] for j in range(4))


@needs4
def test_sharded_fleet_rejects_unwired_paths(served):
    _, _, idx = served
    with pytest.raises(ValueError, match="scan path only"):
        AnnServeFleet(idx, n_replicas=1, shards_per_replica=2, fused=True)


# ---------------------------------------------------------------------------
# latency histogram: bucketing identity + percentile edge cases
# ---------------------------------------------------------------------------

def test_histogram_merge_rejects_different_bucketings():
    """Same bucket COUNT is not same bucketing: lo=1e-5/hi=5000 spans the
    same ratio as the defaults, so the count tables have equal shape but
    shifted edges — merging must raise instead of silently corrupting
    every percentile (regression: the old check compared shapes only)."""
    a = LatencyHistogram()
    b = LatencyHistogram(lo=1e-5, hi=5000.0)
    assert a._counts.shape == b._counts.shape        # the trap the fix closes
    b.add(0.01)
    with pytest.raises(ValueError, match="bucketings differ"):
        a.merge(b)
    assert a.n == 0                                  # refused before mutating
    c = LatencyHistogram()
    c.add(0.02)
    a.merge(c)                                       # identical edges: folds
    assert a.n == 1 and a.summary()["max"] == pytest.approx(0.02)


def test_histogram_percentile_edge_cases():
    """Empty histogram reports 0.0 everywhere; a single observation comes
    back exactly (clamped to the observed max, not a bucket edge) at
    every quantile; out-of-range observations land in the overflow /
    underflow buckets and stay clamped to the true extremes."""
    h = LatencyHistogram()
    assert h.percentile(0.5) == 0.0
    assert h.summary() == {"n": 0, "mean": 0.0, "p50": 0.0, "p95": 0.0,
                           "p99": 0.0, "max": 0.0}
    h.add(0.0123)
    for p in (0.01, 0.5, 0.99, 1.0):
        assert h.percentile(p) == 0.0123             # exact, not an edge
    assert h.summary()["n"] == 1

    over = LatencyHistogram()
    over.add(1e9)                                    # past hi: overflow bucket
    assert over.percentile(0.99) == 1e9
    under = LatencyHistogram()
    under.add(0.0)                                   # below lo: bucket 0
    assert under.percentile(0.5) == 0.0

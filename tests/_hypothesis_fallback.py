"""Minimal deterministic stand-in for ``hypothesis`` (used only when the
real package is unavailable — e.g. hermetic containers; CI installs the real
thing).

Implements exactly the surface this repo's tests use: ``@settings``,
``@given`` with positional strategies, and ``st.integers`` / ``st.floats`` /
``st.sampled_from`` / ``st.booleans``. Examples are drawn from a fixed-seed
PRNG, always including the strategy's boundary values, so failures are
reproducible (no shrinking)."""
from __future__ import annotations

import functools
import inspect
import random


class _Strategy:
    def __init__(self, draw, boundaries=()):
        self._draw = draw
        self.boundaries = tuple(boundaries)

    def example(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value),
                     boundaries=(min_value, max_value))


def floats(min_value: float, max_value: float, **_kw) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value),
                     boundaries=(min_value, max_value))


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rng: rng.choice(elements),
                     boundaries=(elements[0],))


def booleans() -> _Strategy:
    return _Strategy(lambda rng: rng.random() < 0.5, boundaries=(False, True))


class strategies:
    integers = staticmethod(integers)
    floats = staticmethod(floats)
    sampled_from = staticmethod(sampled_from)
    booleans = staticmethod(booleans)


def settings(max_examples: int = 20, deadline=None, **_kw):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*strats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            # @settings is applied OUTSIDE @given, so it stamps the wrapper
            n = getattr(wrapper, "_fallback_max_examples", 20)
            rng = random.Random(0xC0FFEE)
            # boundary case first (min of every strategy), then random draws
            examples = [tuple(s.boundaries[0] for s in strats)]
            examples += [tuple(s.example(rng) for s in strats)
                         for _ in range(max(0, n - 1))]
            for ex in examples:
                fn(*args, *ex, **kwargs)
        # pytest must not see the strategy parameters as fixtures: drop the
        # signature forwarding functools.wraps sets up.
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper
    return deco

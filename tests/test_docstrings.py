"""Public-API docstring gate.

Every symbol a user reaches through the documented entry points —
``repro.core``'s index/search API, the serving engine, the ``ops.*``
kernel dispatchers and the ``repro.rt`` builders — must carry a
non-trivial docstring (shape/dtype contracts live there; docs/kernels.md
and docs/serving.md link to them instead of duplicating). CI additionally
runs ruff's pydocstyle D1xx subset over the same modules (the docs-check
job); this test keeps the guarantee in tier 1 where no ruff is installed.
"""
import inspect

import pytest

import repro.build as build
import repro.core as core
import repro.dist.distributed_index as dist_index
import repro.rt as rt
from repro.build.merge import fold_step, load_minor, save_minor
from repro.kernels import autotune
from repro.core.freshness import (MergeScheduler, MinorGeneration,
                                  combined_delta, promote_l0)
from repro.core.juno import MutableIndexBase, MutableJunoIndex
from repro.dist.distributed_index import DistributedMutableIndex
from repro.kernels import ops
from repro.obs import (Counter, Gauge, Histogram, MetricsRegistry,
                       Observability, RecallProbe, Span, Tracer,
                       exact_topk_ids, read_jsonl, registry_from_events,
                       to_events, validate_events, write_jsonl)
from repro.serve.ann import AnnRequest, AnnServeEngine
from repro.serve.fleet import (AnnServeFleet, FleetRequest, LatencyHistogram,
                               Rejection)
from repro.serve.paged import (ClusterCache, PagedAnnServeEngine,
                               PagedIndexData, PagedJunoIndex)

PUBLIC = [
    # repro.core index lifecycle
    core.JunoConfig, core.build, core.search, core.exact_topk,
    core.recall_1_at_k, core.recall_n_at_k, core.SideBuffer,
    core.empty_side_buffer,
    # mutable index
    MutableJunoIndex, MutableIndexBase.insert, MutableIndexBase.delete,
    MutableIndexBase.compact, MutableJunoIndex.search,
    MutableJunoIndex.ensure_rt_grid,
    # LSM freshness tiers + incremental merges
    MutableIndexBase.enable_tiers, MutableIndexBase.delta_view,
    MutableIndexBase.delta_snapshot, MinorGeneration, combined_delta,
    promote_l0, MergeScheduler, MergeScheduler.maybe_step,
    MergeScheduler.step, MergeScheduler.drain,
    fold_step, save_minor, load_minor,
    # serving engine
    AnnServeEngine, AnnRequest, AnnServeEngine.__init__,
    AnnServeEngine.submit, AnnServeEngine.route, AnnServeEngine.step,
    AnnServeEngine.run, AnnServeEngine.insert, AnnServeEngine.delete,
    AnnServeEngine.compact, AnnServeEngine.latency_stats,
    # kernel dispatchers
    ops.build_selective_lut, ops.masked_adc_scan, ops.hit_count_scan,
    ops.fused_two_stage_scan, ops.fused_three_stage_scan,
    ops.rt_sphere_hits, ops.filter_scores,
    ops.slab_onehot_dot,
    # autotune pass
    autotune.KernelConfig, autotune.KernelConfig.validate, autotune.tune,
    autotune.candidates, autotune.save_cache, autotune.load_cache,
    autotune.ensure_tuned, autotune.set_config, autotune.active_config,
    # rt builders
    rt.CentroidGrid, rt.build_grid, rt.query_radius, rt.survivor_mask,
    rt.routing_state, rt.probe_budget, rt.update_radii, rt.save_grid,
    rt.load_grid, rt.sphere_hits, rt.sphere_hits_host,
    # out-of-core build / artifact store / rebuild (repro.build)
    build.build_streaming, build.build_streaming_sharded, build.array_source,
    build.BuildProbe, build.split_shards, build.merge_shards,
    build.save_index, build.load_index, build.verify_artifact,
    build.config_hash, build.ArtifactStore, build.ArtifactStore.put,
    build.ArtifactStore.get, build.ArtifactStore.versions,
    build.ArtifactStore.latest, build.ArtifactError, build.rebuild_index,
    # rebuild/hot-swap wiring
    MutableJunoIndex.swap_data, AnnServeEngine.swap_index,
    DistributedMutableIndex.swap_data,
    DistributedMutableIndex.rebuild_shard, DistributedMutableIndex.rebuild,
    # distributed search/update factories
    dist_index.make_distributed_search, dist_index.make_distributed_insert,
    dist_index.make_distributed_delete,
    dist_index.make_distributed_row_update, dist_index.index_pspecs,
    dist_index.shard_index, DistributedMutableIndex,
    DistributedMutableIndex.searcher,
    # fleet layer
    AnnServeFleet, AnnServeFleet.__init__, AnnServeFleet.submit,
    AnnServeFleet.step, AnnServeFleet.run, AnnServeFleet.insert,
    AnnServeFleet.delete, AnnServeFleet.compact,
    AnnServeFleet.fail_replica, AnnServeFleet.restore_replica,
    AnnServeFleet.latency_summary, AnnServeFleet.reset_metrics,
    FleetRequest, FleetRequest.trace, Rejection,
    LatencyHistogram, LatencyHistogram.add, LatencyHistogram.merge,
    LatencyHistogram.percentile, LatencyHistogram.summary,
    # observability layer (repro.obs)
    Counter, Counter.inc, Counter.merge, Gauge, Gauge.set, Gauge.merge,
    Histogram, Histogram.add, Histogram.merge, Histogram.percentile,
    Histogram.summary, MetricsRegistry, MetricsRegistry.counter,
    MetricsRegistry.gauge, MetricsRegistry.histogram,
    MetricsRegistry.merge, MetricsRegistry.snapshot,
    MetricsRegistry.render_text, Span, Tracer, Tracer.span, Tracer.record,
    Observability, Observability.child, RecallProbe, RecallProbe.observe,
    RecallProbe.estimate, exact_topk_ids, to_events, write_jsonl,
    read_jsonl, validate_events, registry_from_events,
    AnnServeFleet.merged_registry, build.ArtifactStore.verify,
    # paged (out-of-core) serving tier
    ClusterCache, ClusterCache.get, ClusterCache.put, ClusterCache.stats,
    PagedIndexData, PagedIndexData.__init__, PagedIndexData.fetch_cluster,
    PagedIndexData.gather, PagedIndexData.fetch_vectors,
    PagedIndexData.adopt_cache, PagedIndexData.stats,
    PagedJunoIndex, PagedJunoIndex.swap_data, PagedJunoIndex.search,
    PagedJunoIndex.ensure_rt_grid,
    PagedAnnServeEngine, PagedAnnServeEngine.__init__,
    PagedAnnServeEngine.compact, PagedAnnServeEngine.swap_index,
    PagedAnnServeEngine.cache_stats,
]


def _name(obj):
    mod = getattr(obj, "__module__", "?")
    qual = getattr(obj, "__qualname__", getattr(obj, "__name__", repr(obj)))
    return f"{mod}.{qual}"


@pytest.mark.parametrize("obj", PUBLIC, ids=_name)
def test_public_symbol_has_docstring(obj):
    doc = inspect.getdoc(obj)
    assert doc and len(doc.split()) >= 5, (
        f"{_name(obj)} lacks a meaningful docstring")


def test_public_modules_have_docstrings():
    import repro.build.merge
    import repro.build.pipeline
    import repro.build.rebuild
    import repro.build.store
    import repro.core.freshness
    import repro.core.juno
    import repro.dist.distributed_index
    import repro.kernels.autotune
    import repro.kernels.fused_three_stage
    import repro.kernels.fused_two_stage
    import repro.kernels.ref
    import repro.obs
    import repro.obs.export
    import repro.obs.recall
    import repro.obs.registry
    import repro.obs.trace
    import repro.rt.grid
    import repro.rt.intersect
    import repro.serve.ann
    import repro.serve.fleet
    import repro.serve.paged
    for mod in [core, rt, ops, build, repro.core.juno, repro.core.freshness,
                repro.serve.ann,
                repro.serve.fleet, repro.serve.paged, repro.rt.grid,
                repro.rt.intersect,
                repro.kernels.ref, repro.kernels.fused_two_stage,
                repro.kernels.fused_three_stage, repro.kernels.autotune,
                repro.dist.distributed_index,
                repro.build.pipeline, repro.build.store, repro.build.rebuild,
                repro.build.merge, repro.obs, repro.obs.registry,
                repro.obs.trace, repro.obs.export, repro.obs.recall]:
        assert mod.__doc__ and len(mod.__doc__.split()) >= 10, mod.__name__

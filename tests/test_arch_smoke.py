"""Per-architecture smoke tests (task deliverable f): reduced config of each
family, one forward/train step + one prefill/decode step on CPU, asserting
output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import get_model
from repro.models.params import init_params
from repro.train import TrainConfig, init_train_state, make_train_step


def _make_batch(model, key, batch=2, seq=16):
    sch = model.batch_schema(batch, seq)
    out = {}
    for name, spec in sch.items():
        if spec.dtype == jnp.int32:
            out[name] = jax.random.randint(jax.random.fold_in(key, hash(name) % 97),
                                           spec.shape, 0,
                                           model.cfg.vocab_size
                                           ).astype(jnp.int32)
        else:
            out[name] = jax.random.normal(jax.random.fold_in(key, hash(name) % 89),
                                          spec.shape).astype(spec.dtype)
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    state = init_train_state(model, key)
    step = jax.jit(make_train_step(model, TrainConfig()))
    batch = _make_batch(model, key)
    state2, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), f"{arch}: loss={loss}"
    # loss must be near ln(V) at init (uniform predictions)
    assert abs(loss - np.log(cfg.vocab_size)) < 2.0, (arch, loss)
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.sum(jnp.abs(a - b))),
                     state.params, state2.params))
    assert delta > 0, f"{arch}: optimizer made no update"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_loss_decreases(arch):
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    key = jax.random.PRNGKey(1)
    state = init_train_state(model, key)
    step = jax.jit(make_train_step(model, TrainConfig()))
    batch = _make_batch(model, key)   # same batch → loss must drop
    losses = []
    for _ in range(5):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], (arch, losses)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_smoke(arch):
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    key = jax.random.PRNGKey(2)
    params = init_params(model.schema, key)
    batch = _make_batch(model, key, batch=2, seq=8)
    cache = init_params(model.cache_schema(2, 32), jax.random.PRNGKey(3))

    logits, cache = jax.jit(model.prefill)(params, batch, cache)
    assert logits.shape == (2, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: prefill NaN"

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache = jax.jit(model.decode)(params, cache, tok, 8)
    assert logits2.shape == (2, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2))), f"{arch}: decode NaN"


@pytest.mark.parametrize("arch", ["phi4_mini_3_8b", "h2o_danube_3_4b",
                                  "mamba2_1_3b", "hymba_1_5b"])
def test_decode_matches_forward(arch):
    """Prefill+decode must agree with a full forward pass on the same
    tokens — the KV-cache/SSM-state path is numerically consistent.
    Run in f32 so the check isn't dominated by bf16 rounding."""
    import dataclasses
    from repro.models import transformer
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    model = get_model(cfg)
    key = jax.random.PRNGKey(4)
    params = init_params(model.schema, key)
    tokens = jax.random.randint(key, (1, 9), 0, cfg.vocab_size
                                ).astype(jnp.int32)

    # full forward logits at the last position of tokens[:, :8]
    x = transformer.forward(cfg, params, tokens)
    full_logits = transformer.lm_logits(cfg, params, x)          # (1, 9, V)

    batch = {"tokens": tokens[:, :8], "targets": tokens[:, :8]}
    cache = init_params(model.cache_schema(1, 32), jax.random.PRNGKey(5))
    logits_pre, cache = model.prefill(params, batch, cache)
    np.testing.assert_allclose(np.asarray(logits_pre),
                               np.asarray(full_logits[:, 7]),
                               rtol=2e-2, atol=2e-2)

    # decode token 8 and compare against forward position 8
    logits_dec, _ = model.decode(params, cache, tokens[:, 8:9], 8)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(full_logits[:, 8]),
                               rtol=2e-2, atol=2e-2)

"""Ref ↔ Pallas parity: both implementations must return the SAME neighbours.

``impl="ref"`` (pure jnp, semantics of record) and ``impl="pallas"`` (fused
kernels, interpret mode on CPU — real block iteration) are compared on the
same batch over every mode × metric cell, including the H2 two-stage path:

* ids must be identical everywhere;
* hit-count scores (M/L, and H2's stage 1 internally) are integer totals and
  must be bit-identical;
* exact-distance scores (H/H2) may differ only by float accumulation order
  (gather-sum vs one-hot matmul), so they get a tight allclose.

This harness is what caught the ip masked-LUT substitution divergence (the
kernel's -tau^2/2 placeholder vs the reference's kept-row-min floor), now
reconciled in ops.build_selective_lut.
"""
import jax
import numpy as np
import pytest

from repro.core import JunoConfig, MutableJunoIndex, build, search
from repro.data import DEEP_LIKE, TTI_LIKE, make_dataset

MODES = ["H", "M", "L", "H2"]


@pytest.fixture(scope="module")
def parity_data():
    out = {}
    for metric, spec in [("l2", DEEP_LIKE), ("ip", TTI_LIKE)]:
        pts, q = make_dataset(spec, 2000, 6, key=jax.random.PRNGKey(5))
        cfg = JunoConfig(n_clusters=16, n_entries=16, calib_queries=12,
                         kmeans_iters=3, metric=metric)
        out[metric] = (pts, q, build(pts, cfg))
    return out


@pytest.mark.parametrize("metric", ["l2", "ip"])
@pytest.mark.parametrize("mode", MODES)
def test_ref_pallas_same_results(parity_data, metric, mode):
    _, q, idx = parity_data[metric]
    kw = dict(nprobe=4, k=10, mode=mode, metric=metric, batch=q.shape[0])
    s_ref, i_ref = (np.asarray(x) for x in search(idx, q, impl="ref", **kw))
    s_pal, i_pal = (np.asarray(x) for x in search(idx, q, impl="pallas", **kw))
    np.testing.assert_array_equal(i_ref, i_pal,
                                  err_msg=f"{metric}/{mode}: ids diverge")
    if mode in ("M", "L"):  # integer hit counts: no tolerance
        np.testing.assert_array_equal(s_ref, s_pal)
    else:
        np.testing.assert_allclose(s_ref, s_pal, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("metric", ["l2", "ip"])
@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_fused_two_stage_parity(parity_data, metric, impl):
    """The fused H2 path must return IDENTICAL top-k ids to the composed
    two-stage path (same top-C-by-count candidate rule, same exact-rerank
    semantics), for both LUT implementations and both rerank budgets."""
    _, q, idx = parity_data[metric]
    for rerank in (0, 33):
        kw = dict(nprobe=4, k=10, mode="H2", metric=metric,
                  batch=q.shape[0], impl=impl, rerank=rerank)
        s_c, i_c = (np.asarray(x) for x in search(idx, q, fused=False, **kw))
        s_f, i_f = (np.asarray(x) for x in search(idx, q, fused=True, **kw))
        np.testing.assert_array_equal(
            i_c, i_f, err_msg=f"{metric}/{impl}/C={rerank}: ids diverge")
        np.testing.assert_allclose(s_c, s_f, rtol=1e-5, atol=1e-4)


def test_fused_parity_with_side_buffer(parity_data):
    """Fused parity must survive online inserts: side-buffer points join
    the rerank pool identically in both paths."""
    pts, q, idx = parity_data["l2"]
    mid = MutableJunoIndex(idx, side_capacity=16)
    free = [mid.free_slots(c) for c in range(16)]
    c = int(np.argmin(free))
    cent = np.asarray(idx.ivf.centroids[c])
    rng = np.random.default_rng(7)
    newpts = (cent[None] + 0.02 * rng.standard_normal(
        (free[c] + 3, cent.shape[0]))).astype(np.float32)
    mid.insert(newpts)
    assert mid.side_fill >= 3

    kw = dict(nprobe=16, k=10, mode="H2", batch=q.shape[0])
    s_c, i_c = (np.asarray(x) for x in mid.search(q, fused=False, **kw))
    s_f, i_f = (np.asarray(x) for x in mid.search(q, fused=True, **kw))
    np.testing.assert_array_equal(i_c, i_f)
    np.testing.assert_allclose(s_c, s_f, rtol=1e-5, atol=1e-4)


def test_ref_pallas_parity_with_side_buffer(parity_data):
    """Parity must survive online inserts: spilled side-buffer points are
    scored by shared code, but the per-probe tables they gather from come
    from each impl's own LUT stage."""
    pts, q, idx = parity_data["l2"]
    mid = MutableJunoIndex(idx, side_capacity=16)
    # force spills: fill the tightest cluster beyond its padding
    free = [mid.free_slots(c) for c in range(16)]
    c = int(np.argmin(free))
    cent = np.asarray(idx.ivf.centroids[c])
    rng = np.random.default_rng(3)
    newpts = (cent[None] + 0.02 * rng.standard_normal(
        (free[c] + 3, cent.shape[0]))).astype(np.float32)
    mid.insert(newpts)
    assert mid.side_fill >= 3

    for mode in ["H", "H2"]:
        kw = dict(nprobe=16, k=10, mode=mode, batch=q.shape[0])
        s_ref, i_ref = (np.asarray(x) for x in mid.search(q, impl="ref", **kw))
        s_pal, i_pal = (np.asarray(x)
                        for x in mid.search(q, impl="pallas", **kw))
        np.testing.assert_array_equal(i_ref, i_pal)
        np.testing.assert_allclose(s_ref, s_pal, rtol=1e-5, atol=1e-4)

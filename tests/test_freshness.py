"""LSM freshness engine: tiered deltas, incremental merges, mutability fixes.

Tentpole coverage: L0 → minor-generation promotion, the fixed-capacity
combined delta view, MergeScheduler fold cycles (single-device and
per-shard lanes), engine wiring (``max_minors``), rt verdict parity for
tiered points, and artifact-backed minors on the paged tier.

Regression pins for the PR's three mutability bugfixes — each fails on the
pre-fix code:

* stale rt probe budgets surviving inserts (``AnnRequest.rt_epoch``),
* ``insert`` mutating state before a failing device scatter
  (device-first / host-last commit ordering),
* ``compact`` silently double-popping a corrupted free list
  (fail-closed plan validation).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.build import ArtifactError, ArtifactStore
from repro.build.merge import fold_step
from repro.build.rebuild import rebuild_index
from repro.core import JunoConfig, MutableJunoIndex, build, search
from repro.core.freshness import MergeScheduler, combined_delta, promote_l0
from repro.data import DEEP_LIKE, make_dataset
from repro.serve.ann import AnnServeEngine

FULL = 1e6   # rt_scale at which every sphere covers every cell


@pytest.fixture(scope="module")
def base():
    pts, q = make_dataset(DEEP_LIKE, 3000, 40, key=jax.random.PRNGKey(17))
    cfg = JunoConfig(n_clusters=16, n_entries=32, calib_queries=16,
                     kmeans_iters=4, capacity_mult=1.1)
    return np.asarray(pts), np.asarray(q), build(pts, cfg)


@functools.lru_cache(maxsize=1)
def _tiny_base():
    """Small shared base for hypothesis tests (no fixtures there)."""
    pts, q = make_dataset(DEEP_LIKE, 2500, 8, key=jax.random.PRNGKey(21))
    cfg = JunoConfig(n_clusters=16, n_entries=16, calib_queries=12,
                     kmeans_iters=4, capacity_mult=1.05)
    return np.asarray(pts), np.asarray(q), build(pts, cfg)


def _snapshot(mid):
    """Full host+device state of a mutable index, for all-or-nothing checks."""
    return dict(
        free=[list(f) for f in mid._free],
        loc=dict(mid._loc),
        side_free=list(mid._side_free),
        next_id=mid._next_id,
        minors=[(m.gen, m.valid.copy()) for m in mid._minors],
        valid=np.asarray(mid.data.ivf.valid).copy(),
        pids=np.asarray(mid.data.ivf.point_ids).copy(),
        codes=np.asarray(mid.data.cluster_codes).copy(),
        s_valid=np.asarray(mid.side.valid).copy(),
        s_cluster=np.asarray(mid.side.cluster).copy(),
        s_ids=np.asarray(mid.side.ids).copy(),
    )


def _diff(a, b):
    out = []
    for key in a:
        va, vb = a[key], b[key]
        if isinstance(va, np.ndarray):
            if not np.array_equal(va, vb):
                out.append(key)
        elif key == "minors":
            if len(va) != len(vb) or any(
                    ga != gb or not np.array_equal(xa, xb)
                    for (ga, xa), (gb, xb) in zip(va, vb)):
                out.append(key)
        elif va != vb:
            out.append(key)
    return out


def _assert_search_equiv(s0, i0, s1, i1):
    """Bit-identical scores; id sets equal at every non-boundary score level
    (lax.top_k may permute EXACTLY tied scores when flat positions move)."""
    s0, i0, s1, i1 = (np.asarray(x) for x in (s0, i0, s1, i1))
    np.testing.assert_array_equal(s0, s1)
    for r in range(s0.shape[0]):
        boundary = s0[r, -1]
        for v in np.unique(s0[r][s0[r] != boundary]):
            assert set(i0[r][s0[r] == v]) == set(i1[r][s1[r] == v]), (r, v)


def _overfill_points(mid, rng, extra, cluster=None):
    """Points near the tightest (or given) cluster's centroid: its free
    slots + ``extra`` of them, so ``extra`` land in the delta tier."""
    if cluster is None:
        cluster = int(np.argmin([mid.free_slots(c)
                                 for c in range(len(mid._free))]))
    cent = np.asarray(mid.data.ivf.centroids[cluster])
    n = mid.free_slots(cluster) + extra
    return cluster, (cent[None] + 0.02 * rng.standard_normal(
        (n, cent.shape[0]))).astype(np.float32)


def _check_bookkeeping(mid, tag=""):
    """Free-list / location-map consistency invariants across all tiers."""
    valid = np.asarray(mid.data.ivf.valid)
    pids = np.asarray(mid.data.ivf.point_ids)
    cap = valid.shape[1]
    for c, f in enumerate(mid._free):
        assert len(f) == len(set(f)), f"{tag}: dup in _free[{c}]"
        occ = set(np.where(valid[c])[0].tolist())
        assert not (set(f) & occ), f"{tag}: _free[{c}] overlaps occupied"
        assert len(f) + len(occ) == cap, f"{tag}: free+occ != P in {c}"
    sf = mid._side_free
    assert len(sf) == len(set(sf)), f"{tag}: dup in _side_free"
    socc = set(np.where(np.asarray(mid.side.valid))[0].tolist())
    assert not (set(sf) & socc), f"{tag}: _side_free overlaps side-valid"
    assert len(sf) + len(socc) == mid.side.capacity
    s_ids = np.asarray(mid.side.ids)
    n_cluster = n_side = n_minor = 0
    for pid, (c, slot) in mid._loc.items():
        if c >= 0:
            assert valid[c, slot] and pids[c, slot] == pid, (tag, pid)
            n_cluster += 1
        elif c == -1:
            assert s_ids[slot] == pid, (tag, pid)
            n_side += 1
        else:
            m = next(mm for mm in mid._minors if mm.gen == -2 - c)
            assert m.valid[slot] and m.ids[slot] == pid, (tag, pid)
            n_minor += 1
    assert n_cluster == int(valid.sum()), tag
    assert n_side == len(socc), tag
    assert n_minor == sum(m.live for m in mid._minors), tag


# ---------------------------------------------------------------------------
# tentpole: tiers, promotion, combined view, scheduler
# ---------------------------------------------------------------------------

def test_combined_delta_capacity_is_merge_state_invariant(base):
    """The combined delta view must keep ONE shape across every merge state
    (empty L0, L0+minor, post-fold) — the warm-jit-signature invariant."""
    _, _, idx = base
    mid = MutableJunoIndex(idx, side_capacity=16)
    mid.enable_tiers(2)
    cap = 16 * 3
    assert mid.delta_view(elide_empty=False).capacity == cap

    rng = np.random.default_rng(0)
    _, newpts = _overfill_points(mid, rng, 16)
    mid.insert(newpts)
    assert mid.side_fill == 16
    view = mid.delta_view()
    assert view.capacity == cap
    promote_l0(mid)
    assert mid.side_fill == 0 and len(mid._minors) == 1
    view = mid.delta_view()
    assert view.capacity == cap
    # tombstoned minor slots disappear from the view's cluster plane
    m = mid._minors[0]
    victim = int(m.ids[np.where(m.valid)[0][0]])
    mid.delete([victim])
    view = mid.delta_view()
    assert view.capacity == cap
    pos = int(np.where(np.asarray(view.ids) == victim)[0][0])
    assert int(np.asarray(view.cluster)[pos]) == -1
    # more minors than the configuration allows is a hard error
    with pytest.raises(RuntimeError, match="max_minors"):
        combined_delta(mid.side, mid._minors * 3, 2)


def test_insert_promotes_full_l0_and_search_matches_rebuild(base):
    """A full L0 no longer rejects inserts: it seals into a minor
    generation, and the tiered index's search equals a from-scratch
    rebuild of the same logical point set."""
    pts, q, idx = base
    mid = MutableJunoIndex(idx, side_capacity=8)
    mid.enable_tiers(2)
    rng = np.random.default_rng(1)
    c, newpts = _overfill_points(mid, rng, 8)     # fills base slots + L0
    cent = np.asarray(idx.ivf.centroids[c])
    more = (cent[None] + 0.02 * rng.standard_normal(
        (4, cent.shape[0]))).astype(np.float32)
    newpts = np.concatenate([newpts, more])
    ids = mid.insert(newpts[:-4])
    assert mid.side_fill == 8 and not mid._minors
    ids += mid.insert(more)            # full L0 seals into a minor first
    assert len(mid._minors) == 1 and mid.side_fill == 4
    assert mid.delta_fill == 12
    _check_bookkeeping(mid, "post-promote")

    # every tiered point is retrievable by its own vector
    _, got = mid.search(newpts[-12:], nprobe=16, k=10, mode="H")
    got = np.asarray(got)
    for j, pid in enumerate(ids[-12:]):
        assert pid in got[j]

    # end-state parity with a stop-the-world rebuild of the same set
    qq = np.concatenate([q[:16], newpts[:4]], axis=0)
    s0, i0 = mid.search(qq, nprobe=8, k=20, mode="H")
    rebuilt = rebuild_index(mid)
    s1, i1 = search(rebuilt, jnp.asarray(qq), nprobe=8, k=20, mode="H",
                    batch=qq.shape[0])
    _assert_search_equiv(s0, i0, s1, i1)


def test_insert_raises_when_tiers_exhausted(base):
    """With every minor slot taken AND L0 full, insert keeps the legacy
    all-or-nothing RuntimeError (nothing mutated)."""
    _, _, idx = base
    mid = MutableJunoIndex(idx, side_capacity=4)
    mid.enable_tiers(1)
    rng = np.random.default_rng(2)
    c, newpts = _overfill_points(mid, rng, 4)
    mid.insert(newpts)                 # fills base slots + L0
    cent = np.asarray(idx.ivf.centroids[c])
    mid.insert((cent[None] + 0.02 * rng.standard_normal(
        (4, cent.shape[0]))).astype(np.float32))   # promotes, refills L0
    assert len(mid._minors) == 1 and mid.side_fill == 4
    snap = _snapshot(mid)
    cent = np.asarray(idx.ivf.centroids[c])
    more = (cent[None] + 0.02 * rng.standard_normal(
        (2, cent.shape[0]))).astype(np.float32)
    with pytest.raises(RuntimeError, match="does not fit"):
        mid.insert(more)
    assert _diff(snap, _snapshot(mid)) == []


def test_scheduler_folds_minors_incrementally(base):
    """fold_step drains minor points into freed base slots in bounded
    per-cluster steps; a full drain empties every tier and is a search
    no-op (scores bit-identical)."""
    pts, q, idx = base
    mid = MutableJunoIndex(idx, side_capacity=8)
    mid.enable_tiers(2)
    rng = np.random.default_rng(3)
    c, newpts = _overfill_points(mid, rng, 8)
    cent = np.asarray(idx.ivf.centroids[c])
    more = (cent[None] + 0.02 * rng.standard_normal(
        (2, cent.shape[0]))).astype(np.float32)
    newpts = np.concatenate([newpts, more])
    ids = mid.insert(newpts[:-2])      # fills base + L0
    ids += mid.insert(more)            # promotes L0, lands in the fresh one
    assert len(mid._minors) == 1
    # tombstone enough ORIGINAL members of the overfilled cluster that the
    # whole delta tier has base slots to fold into
    row_ids = np.asarray(mid.data.ivf.point_ids[c])
    row_valid = np.asarray(mid.data.ivf.valid[c])
    victims = [int(p) for p in row_ids[row_valid] if p < len(pts)][:12]
    mid.delete(victims)

    qq = np.concatenate([q[:16], newpts[:4]], axis=0)
    s0, i0 = mid.search(qq, nprobe=8, k=20, mode="H")

    sched = MergeScheduler(mid, clusters_per_step=1)
    assert sched.pending == mid.delta_fill > 0
    moved = sched.drain()
    assert moved >= 10
    assert mid.delta_fill == 0 and not mid._minors
    assert sched.stats["drains"] == 1 and sched.stats["steps"] >= 1
    _check_bookkeeping(mid, "post-drain")

    s1, i1 = mid.search(qq, nprobe=8, k=20, mode="H")
    _assert_search_equiv(s0, i0, s1, i1)
    # drained points still retrievable, now from base slots
    _, got = mid.search(newpts, nprobe=16, k=10, mode="H")
    got = np.asarray(got)
    assert all(pid in got[j] for j, pid in enumerate(ids))


def test_fold_step_respects_lane_and_budget(base):
    """A lane-restricted fold touches only its cluster range, and the
    per-step cluster budget bounds the work."""
    pts, _, idx = base
    mid = MutableJunoIndex(idx, side_capacity=8)
    mid.enable_tiers(2)
    rng = np.random.default_rng(4)
    c, newpts = _overfill_points(mid, rng, 8)
    mid.insert(newpts)                 # fills base slots + L0
    promote_l0(mid)
    assert len(mid._minors) == 1
    row_ids = np.asarray(mid.data.ivf.point_ids[c])
    row_valid = np.asarray(mid.data.ivf.valid[c])
    victims = [int(p) for p in row_ids[row_valid] if p < len(pts)][:8]
    mid.delete(victims)

    before = mid._minors[0].live
    # a lane excluding the owning cluster folds nothing
    lane = (c + 1, c + 1 + 1)
    assert fold_step(mid, max_clusters=16, lane=lane) == 0
    assert mid._minors and mid._minors[0].live == before
    # the owning lane folds (bounded by freed slots)
    moved = fold_step(mid, max_clusters=16, lane=(c, c + 1))
    assert moved == min(before, 8)
    _check_bookkeeping(mid, "post-lane-fold")


def test_engine_merge_cycles_sustain_mixed_load(base):
    """AnnServeEngine(max_minors=...): sustained insert+delete+query churn
    across many promotion/fold cycles — every live inserted id stays
    retrievable, the scheduler makes progress between ticks, and the
    delta tier never exceeds its configured capacity."""
    pts, q, idx = base
    eng = AnnServeEngine(idx, max_minors=2, side_capacity=8,
                         merge_clusters_per_step=4)
    mid = eng.index
    cap = 8 * 3
    rng = np.random.default_rng(5)
    c = int(np.argmin([mid.free_slots(cc) for cc in range(16)]))
    cent = np.asarray(idx.ivf.centroids[c])
    # exhaust the target cluster's padding headroom so the delta tiers do
    # the absorbing, then keep inserting until TWO insert-path promotions
    # have happened (full L0 + full cluster seals a minor mid-insert)
    own: list[tuple[int, np.ndarray]] = []
    if mid.free_slots(c):
        prefill = (cent[None] + 0.02 * rng.standard_normal(
            (mid.free_slots(c), cent.shape[0]))).astype(np.float32)
        own += list(zip(eng.insert(prefill), prefill))
    for _ in range(10):
        if len(mid._minors) >= 2:
            break
        newpts = (cent[None] + 0.02 * rng.standard_normal(
            (4, cent.shape[0]))).astype(np.float32)
        own += list(zip(eng.insert(newpts), newpts))
        assert mid.delta_fill <= cap
    assert len(mid._minors) == 2

    # churn: deletes of ORIGINAL base members free fold targets, the
    # between-ticks scheduler folds the generations back into them while
    # queries keep finding every live point — across ≥ 8 merge cycles
    for cycle in range(8):
        row_ids = np.asarray(mid.data.ivf.point_ids[c])
        row_valid = np.asarray(mid.data.ivf.valid[c])
        victims = [int(p) for p in row_ids[row_valid]
                   if p < len(pts)][:6]
        eng.delete(victims)
        newpts = (cent[None] + 0.02 * rng.standard_normal(
            (4, cent.shape[0]))).astype(np.float32)
        own += list(zip(eng.insert(newpts), newpts))
        assert mid.delta_fill <= cap
        req = eng.submit(np.stack([p for _, p in own[-4:]]),
                         k=10, mode="H", nprobe=16)
        assert eng.run() >= 4
        got = np.asarray(req.ids)
        for j, (pid, _) in enumerate(own[-4:]):
            assert pid in got[j], (cycle, pid)
    assert mid._minor_gen >= 2         # generations sealed across the run
    assert eng.scheduler.stats["steps"] >= 1
    assert eng.scheduler.stats["folded"] + eng.scheduler.stats[
        "compacted"] >= 1
    # compact() now schedules merge work; whatever cannot fold escalates
    eng.compact()
    assert mid.side_fill == 0
    _check_bookkeeping(mid, "post-compact")


def test_rt_verdict_parity_for_minor_points(base):
    """Minor-generation points must get the SAME rt sphere verdict as
    in-cluster siblings: full-coverage rt == dense scan while tiered, and
    a drain is a search no-op under the calibrated radius."""
    pts, q, idx = base
    mid = MutableJunoIndex(idx, side_capacity=8)
    mid.enable_tiers(2)
    mid.ensure_rt_grid()
    rng = np.random.default_rng(6)
    c, newpts = _overfill_points(mid, rng, 8)
    mid.insert(newpts)                 # fills base slots + L0
    promote_l0(mid)
    assert len(mid._minors) == 1
    qq = np.concatenate([q[:8], newpts[:4]], axis=0)
    _, want = mid.search(qq, nprobe=16, k=10, mode="H", batch=qq.shape[0])
    _, got = mid.search(qq, nprobe=16, k=10, mode="H", prefilter="rt",
                        rt_scale=FULL, batch=qq.shape[0])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    # calibrated radius: drain must not change any answer
    row_ids = np.asarray(mid.data.ivf.point_ids[c])
    row_valid = np.asarray(mid.data.ivf.valid[c])
    victims = [int(p) for p in row_ids[row_valid] if p < len(pts)][:10]
    mid.delete(victims)
    s1, i1 = mid.search(qq, nprobe=16, k=10, mode="H", prefilter="rt",
                        batch=qq.shape[0])
    assert MergeScheduler(mid).drain() >= 8
    assert mid.delta_fill == 0
    s2, i2 = mid.search(qq, nprobe=16, k=10, mode="H", prefilter="rt",
                        batch=qq.shape[0])
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    for r1, r2 in zip(np.asarray(i1), np.asarray(i2)):
        assert set(r1) == set(r2)


def test_distributed_merge_lanes(base):
    """DistributedMutableIndex exposes per-shard merge lanes that
    partition the cluster range; a lane-scheduled drain empties the tiers
    and matches the single-device tiered index bit-for-bit."""
    from repro.dist.distributed_index import DistributedMutableIndex

    pts, q, idx = base
    mesh = jax.make_mesh((1,), ("data",))
    dmi = DistributedMutableIndex(idx, mesh, side_capacity=8)
    mid = MutableJunoIndex(idx, side_capacity=8)
    for m in (dmi, mid):
        m.enable_tiers(2)

    lanes = dmi.merge_lanes()
    assert len(lanes) == dmi.n_shards
    covered = sorted(c for lo, hi in lanes for c in range(lo, hi))
    assert covered == list(range(16))

    rng = np.random.default_rng(7)
    c, newpts = _overfill_points(mid, rng, 8)
    ids_d = dmi.insert(newpts)
    ids_s = mid.insert(newpts)
    assert ids_d == ids_s
    promote_l0(dmi)
    promote_l0(mid)
    assert len(dmi._minors) == len(mid._minors) == 1
    row_ids = np.asarray(mid.data.ivf.point_ids[c])
    row_valid = np.asarray(mid.data.ivf.valid[c])
    victims = [int(p) for p in row_ids[row_valid] if p < len(pts)][:10]
    dmi.delete(victims)
    mid.delete(victims)

    sch_d = MergeScheduler(dmi, clusters_per_step=4)
    assert sch_d._lanes == lanes     # the per-shard schedule was adopted
    moved_d = sch_d.drain()
    moved_s = MergeScheduler(mid, clusters_per_step=4).drain()
    assert moved_d == moved_s >= 8
    assert dmi.delta_fill == mid.delta_fill == 0

    dsearch = dmi.searcher(local_nprobe=16, k=10, mode="H")
    qq = np.concatenate([q[:8], newpts[:2]], axis=0)
    s_d, i_d = dsearch(dmi.data, qq, dmi.side)
    s_s, i_s = mid.search(qq, nprobe=16, k=10, mode="H", batch=qq.shape[0])
    np.testing.assert_array_equal(np.asarray(i_d), np.asarray(i_s))
    np.testing.assert_array_equal(np.asarray(s_d), np.asarray(s_s))


# ---------------------------------------------------------------------------
# bugfix 1: stale rt probe budgets must not survive inserts
# ---------------------------------------------------------------------------

def test_rt_probe_budget_refreshes_after_insert(base):
    """REGRESSION (pre-fix: ``route()`` kept any cached ``rt_probes``
    forever): an insert that grows a cluster's grid reach must invalidate
    budgets cached before it — a stale request re-routed after the insert
    gets the same (larger) probe budget as a fresh one, so the fresh
    point is probed, not silently skipped."""
    from repro import rt as rt_lib

    pts, q, idx = base
    # rt_scale < 1 shrinks the calibrated sphere radii so most budgets sit
    # at the bottom bucket — without headroom every query already routes at
    # the probe cap and a grown reach is invisible to the bucketing
    eng = AnnServeEngine(idx, prefilter="rt", rt_scale=0.25)
    mid = eng.index
    grid0 = mid.ensure_rt_grid()
    cent = np.asarray(idx.ivf.centroids, np.float32)
    proj = np.asarray(grid0.proj)
    cp = cent @ proj
    max_probes = eng.MODE_NPROBE["M"]

    def bucket(v):
        return next((b for b in eng.RT_NPROBE_BUCKETS if b >= max(v, 1)),
                    eng.RT_NPROBE_BUCKETS[-1])

    # find a (query, insert point) pair whose insert grows the query's
    # probe budget across a bucket boundary: a far-flung point grows its
    # owning cluster's projected reach until that cluster becomes a
    # sphere hit at a deeper stage-A rank
    found = None
    for qi in range(q.shape[0]):
        if found:
            break
        qq = q[qi:qi + 1].astype(np.float32)
        probe = eng.submit(qq, k=10, mode="M")
        eng.queue.clear()
        eng.route(probe)
        pre = probe.rt_probes
        if min(bucket(pre), max_probes) >= max_probes:
            continue                       # no headroom to grow into
        score = np.sum(cent * cent, -1) - 2.0 * (qq @ cent.T)[0]
        order = np.argsort(score)
        qp = (qq @ proj)[0]
        for rank in range(max(pre + 1, 3), max_probes + 1):
            if found:
                break
            target = int(order[rank - 1])
            d = float(np.linalg.norm(qp - cp[target]))
            for dirn in (proj[:, 0], -proj[:, 0], proj[:, 1], -proj[:, 1]):
                if found:
                    break
                for margin in (0.05, 4.0, 16.0, 64.0):
                    reach = d + abs(float(grid0.radius_bias)) + margin
                    p = (cent[target] + reach * dirn).astype(np.float32)
                    # simulate exactly what _rt_on_insert will do: the
                    # point lands in its nearest cluster (not necessarily
                    # `target`) and grows THAT cluster's projected reach
                    lab = int(np.argmin(np.sum((cent - p) ** 2, -1)))
                    rlab = float(np.linalg.norm((p - cent[lab]) @ proj))
                    g2 = rt_lib.update_radii(grid0, [lab], [rlab])
                    post = int(rt_lib.probe_budget(
                        g2, idx, qq, metric="l2", scale=eng.rt_scale,
                        thres_scale=eng.thres_scale,
                        max_probes=max_probes).max())
                    if min(bucket(post), max_probes) > min(bucket(pre),
                                                           max_probes):
                        found = (probe, qq, p, pre)
                        break
    assert found is not None, "no viable insert geometry in candidate pool"
    stale, qq, p, pre = found

    muts0 = mid.rt_mutations
    eng.insert(p[None])
    assert mid.rt_mutations == muts0 + 1   # the invalidation signal

    fresh = eng.submit(qq, k=10, mode="M")
    eng.queue.clear()
    sig_fresh = eng.route(fresh)
    assert fresh.rt_probes > pre           # the insert really grew the budget
    # THE regression: the pre-insert cached budget must be recomputed
    sig_stale = eng.route(stale)
    assert stale.rt_probes == fresh.rt_probes
    assert sig_stale == sig_fresh


# ---------------------------------------------------------------------------
# bugfix 2: insert is all-or-nothing, even against a failing device plane
# ---------------------------------------------------------------------------

def test_insert_untouched_when_device_scatter_fails(base):
    """REGRESSION (pre-fix: host bookkeeping committed before the device
    scatter, so a raising ``_apply_insert`` — exactly what a sealed paged
    shard does — left free lists/_loc/_next_id corrupted): a failing
    scatter must leave EVERY piece of state untouched."""
    _, _, idx = base
    mid = MutableJunoIndex(idx, side_capacity=8)
    rng = np.random.default_rng(8)
    c = int(np.argmax([mid.free_slots(cc) for cc in range(16)]))
    assert mid.free_slots(c) >= 2
    cent = np.asarray(idx.ivf.centroids[c])
    newpts = (cent[None] + 0.02 * rng.standard_normal(
        (2, cent.shape[0]))).astype(np.float32)

    snap = _snapshot(mid)

    def boom(cl, sl, ids, codes):
        raise RuntimeError("sealed shard: cluster rows are read-only")

    mid._apply_insert = boom
    with pytest.raises(RuntimeError, match="sealed shard"):
        mid.insert(newpts)
    assert _diff(snap, _snapshot(mid)) == []

    del mid._apply_insert              # restore the class method
    ids = mid.insert(newpts)           # and the same batch now lands cleanly
    assert [mid._loc[i][0] for i in ids] == [c, c]


def test_insert_overflow_mutates_nothing(base):
    """The docstring's promise, pinned: an unplaceable batch raises with
    host AND device state bit-identical to before the call."""
    _, _, idx = base
    mid = MutableJunoIndex(idx, side_capacity=2)
    rng = np.random.default_rng(9)
    _, newpts = _overfill_points(mid, rng, 2)
    mid.insert(newpts)                 # exactly fills cluster + side
    snap = _snapshot(mid)
    with pytest.raises(RuntimeError, match="does not fit"):
        mid.insert(newpts[-3:])
    assert _diff(snap, _snapshot(mid)) == []


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_interleaved_mutations_keep_bookkeeping_sound(seed):
    """Random insert/delete/compact interleavings (with tiers enabled)
    never corrupt the free lists, the location map, or the tier masks —
    and every failed op leaves state bit-identical."""
    pts, _, idx = _tiny_base()
    mid = MutableJunoIndex(idx, side_capacity=8)
    mid.enable_tiers(2)
    rng = np.random.default_rng(seed)
    live: list[int] = sorted(mid._loc)
    for step in range(40):
        op = rng.random()
        if op < 0.5:
            base_pt = pts[rng.integers(0, len(pts))]
            batch = (base_pt[None] + 0.05 * rng.standard_normal(
                (int(rng.integers(1, 4)), pts.shape[1]))).astype(np.float32)
            snap = _snapshot(mid)
            try:
                live += mid.insert(batch)
            except RuntimeError:
                assert _diff(snap, _snapshot(mid)) == [], step
        elif op < 0.9 and live:
            k = int(rng.integers(1, min(4, len(live)) + 1))
            pick = [live[int(j)] for j in
                    rng.choice(len(live), size=k, replace=False)]
            mid.delete(pick)
            live = [p for p in live if p not in set(pick)]
        else:
            mid.compact()
        _check_bookkeeping(mid, f"seed={seed} step={step}")
    assert sorted(mid._loc) == sorted(live)


# ---------------------------------------------------------------------------
# bugfix 3: compact fails closed on corrupted slot bookkeeping
# ---------------------------------------------------------------------------

def _spilled_index(idx, seed, extra=3):
    """A mutable index with ``extra`` side spills owned by one cluster and
    freed base slots for compact to fold into."""
    mid = MutableJunoIndex(idx, side_capacity=8)
    rng = np.random.default_rng(seed)
    c, newpts = _overfill_points(mid, rng, extra)
    mid.insert(newpts)
    assert mid.side_fill >= extra
    row_ids = np.asarray(mid.data.ivf.point_ids[c])
    row_valid = np.asarray(mid.data.ivf.valid[c])
    victims = [int(p) for p in row_ids[row_valid]][:extra]
    mid.delete(victims)
    return mid, c


def test_compact_rejects_double_freed_slot(base):
    """REGRESSION (pre-fix: the Python-loop LIFO pops silently scattered
    two side points into the SAME base slot when the free list held a
    duplicate — one point vanished): a duplicated free slot must raise
    with nothing mutated."""
    _, _, idx = base
    mid, c = _spilled_index(idx, seed=10)
    mid._free[c] = [mid._free[c][-1]] * 2    # simulated double-free
    snap = _snapshot(mid)
    with pytest.raises(RuntimeError, match="twice"):
        mid.compact()
    assert _diff(snap, _snapshot(mid)) == []


def test_compact_rejects_reused_side_slot(base):
    """REGRESSION: a side position that is simultaneously live and on the
    side free list (reused-slot aliasing) must be refused, not folded and
    re-freed into a duplicate free-list entry."""
    _, _, idx = base
    mid, c = _spilled_index(idx, seed=11)
    live_pos = int(np.where(np.asarray(mid.side.valid))[0][0])
    mid._side_free.append(live_pos)          # simulated aliasing
    snap = _snapshot(mid)
    with pytest.raises(RuntimeError, match="aliasing"):
        mid.compact()
    assert _diff(snap, _snapshot(mid)) == []


def test_compact_churn_is_bit_stable(base):
    """Vectorized compact across insert/delete churn cycles: every cycle's
    fold is a search no-op (scores bitwise, ids per tie level)."""
    pts, q, idx = base
    mid = MutableJunoIndex(idx, side_capacity=16)
    rng = np.random.default_rng(12)
    c = int(np.argmin([mid.free_slots(cc) for cc in range(16)]))
    cent = np.asarray(idx.ivf.centroids[c])
    inserted: list[int] = []
    for cycle in range(4):
        newpts = (cent[None] + 0.02 * rng.standard_normal(
            (mid.free_slots(c) + 2, cent.shape[0]))).astype(np.float32)
        inserted += mid.insert(newpts)
        row_ids = np.asarray(mid.data.ivf.point_ids[c])
        row_valid = np.asarray(mid.data.ivf.valid[c])
        victims = [int(p) for p in row_ids[row_valid]][:3]
        mid.delete(victims)
        inserted = [p for p in inserted if p not in set(victims)]
        qq = q[:12]
        s0, i0 = mid.search(qq, nprobe=8, k=20, mode="H")
        assert mid.compact() >= 2, cycle
        s1, i1 = mid.search(qq, nprobe=8, k=20, mode="H")
        _assert_search_equiv(s0, i0, s1, i1)
        _check_bookkeeping(mid, f"cycle={cycle}")


# ---------------------------------------------------------------------------
# paged tier: artifact-backed minors (satellite 4)
# ---------------------------------------------------------------------------

@pytest.fixture()
def paged_tiered(tmp_path):
    from repro.serve.paged import PagedAnnServeEngine, PagedIndexData

    pts, q = make_dataset(DEEP_LIKE, 2000, 8, key=jax.random.PRNGKey(23))
    pts, q = np.asarray(pts), np.asarray(q)
    cfg = JunoConfig(n_clusters=16, n_entries=16, calib_queries=12,
                     kmeans_iters=4, capacity_mult=1.1)
    idx = build(pts, cfg)
    store = ArtifactStore(str(tmp_path / "store"))
    assert store.put("main", idx, cfg) == 1
    paged = PagedIndexData(store.path("main", 1), cache_bytes=1 << 22)
    eng = PagedAnnServeEngine(paged, metric=cfg.metric, side_capacity=4,
                              minor_store=store, max_minors=2)
    rng = np.random.default_rng(13)
    newpts = (pts[:6].mean(0)[None] + 0.02 * rng.standard_normal(
        (6, pts.shape[1]))).astype(np.float32)
    ids = eng.insert(newpts[:4])       # read-only shards: all 4 fill L0
    ids += eng.insert(newpts[4:])      # full L0 commits a minor artifact
    assert len(eng.index._minors) == 1
    return eng, store, newpts, ids


def test_paged_minor_promotion_commits_artifact(paged_tiered):
    """On the paged tier a promoted L0 is committed through the
    ArtifactStore (codes dropped from memory) and demand-paged back on
    first search touch — inserted ids stay retrievable."""
    eng, store, newpts, ids = paged_tiered
    minor = eng.index._minors[0]
    assert minor.path is not None and minor.codes is None
    assert store.latest("minors") == 1

    req = eng.submit(newpts, k=10, mode="H", nprobe=16)
    eng.run()                          # faults the minor's codes in
    assert minor.codes is not None
    got = np.asarray(req.ids)
    assert all(pid in got[j] for j, pid in enumerate(ids))

    # a second promotion commits the next version
    rng = np.random.default_rng(14)
    more = (newpts[:1] + 0.02 * rng.standard_normal(
        (4, newpts.shape[1]))).astype(np.float32)
    eng.insert(more)
    assert len(eng.index._minors) == 2
    assert store.latest("minors") == 2


def test_paged_minor_corruption_fails_closed(paged_tiered):
    """A corrupted on-disk minor generation must raise ArtifactError on
    its first search touch — never serve garbage candidates."""
    import os

    eng, store, newpts, _ = paged_tiered
    minor = eng.index._minors[0]
    assert minor.codes is None         # not faulted in yet
    apath = os.path.join(minor.path, "minor.npz")
    with np.load(apath) as z:
        arrays = {k: z[k].copy() for k in z.files}
    arrays["codes"][0, 0] ^= 1
    np.savez(apath, **arrays)

    eng.submit(newpts, k=10, mode="H", nprobe=16)
    with pytest.raises(ArtifactError, match="minor code row"):
        eng.run()

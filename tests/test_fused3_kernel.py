"""Differential validation of the single-residency three-stage kernel.

The three-stage kernel (``kernels.fused_three_stage``) must be EQUIVALENT
to the composition it replaces — ``rt.sphere_hits`` (stage 0) → the
probe-mask gather of ``core.juno._rt_probe_mask`` (``slot_of`` lookup,
probe-0 backstop) → ``fused_two_stage`` over the masked ``valid``:

* ``counts``/``cand`` bit-identical to the composed path (including the
  value-desc/index-asc top-C tie order);
* ``probe_ok`` bit-identical to the host-side mask gather;
* ``dist``/``cand_dist`` equal at survivors, metric sentinel elsewhere;
* ids AND scores through the dense oracle
  (``kernels.ref.fused_three_stage_ref``) as semantics of record.

Grids come from the ``test_rt_filter`` synthesizer (build invariants:
slot coords inside their cell AABB, ``-inf`` pad/empty sentinels,
degenerate zero/cover-all radii in every batch). All Pallas executions
run in interpret mode; hypothesis drives the shape/seed sweep through
tests/_hypothesis_fallback.py when the real package is absent.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import rt
from repro.kernels import ref
from repro.kernels.fused_three_stage import (fused_three_stage,
                                             fused_three_stage_host)
from repro.kernels.fused_two_stage import fused_two_stage

pytestmark = pytest.mark.interpret


def _inputs(seed, q, n_probe, p, s, e, valid_p=0.85):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    lut = jax.random.normal(ks[0], (q, n_probe, s, e), jnp.float32)
    table = jax.random.randint(ks[1], (q, n_probe, s, e), -1, 2
                               ).astype(jnp.int8)
    codes = jax.random.randint(ks[2], (q, n_probe, p, s), 0, e
                               ).astype(jnp.uint8)
    if valid_p <= 0.0:
        valid = jnp.zeros((q, n_probe, p), bool)
    elif valid_p >= 1.0:
        valid = jnp.ones((q, n_probe, p), bool)
    else:
        valid = jax.random.bernoulli(ks[3], valid_p, (q, n_probe, p))
    return lut, table, codes, valid


def _synth_grid(seed, n_cells_side, cap, q, n_probe):
    """Random grid honoring the build invariants (slot coords inside their
    cell's AABB, cell_reach = max slot_reach, -inf pad/empty sentinels)
    plus a probed-cluster slot_idx table — the test_rt_filter synthesizer
    extended with the kernel's stage-0 probe plumbing. Every batch carries
    degenerate radii: the first quarter 0.0 (point queries), the second
    quarter 10.0 (cover-all)."""
    rng = np.random.default_rng(seed)
    g = n_cells_side
    n_cells = g * g
    lo = np.stack(np.meshgrid(np.arange(g), np.arange(g), indexing="ij"),
                  -1).reshape(-1, 2) / g
    boxes = np.concatenate([lo, lo + 1.0 / g], 1).astype(np.float32)
    counts = rng.integers(0, cap + 1, n_cells)
    c0 = np.zeros((n_cells, cap), np.float32)
    c1 = np.zeros((n_cells, cap), np.float32)
    reach = np.full((n_cells, cap), -np.inf, np.float32)
    for cell in range(n_cells):
        k = counts[cell]
        u = rng.random((k, 2)).astype(np.float32)
        c0[cell, :k] = boxes[cell, 0] + u[:, 0] / g
        c1[cell, :k] = boxes[cell, 1] + u[:, 1] / g
        reach[cell, :k] = np.abs(rng.normal(0, 0.2, k)).astype(np.float32)
    cell_reach = reach.max(1)
    q0 = rng.uniform(-0.3, 1.3, q).astype(np.float32)
    q1 = rng.uniform(-0.3, 1.3, q).astype(np.float32)
    radius = rng.uniform(0, 0.5, q).astype(np.float32)
    radius[: q // 4] = 0.0                       # degenerate: point queries
    radius[q // 4: 2 * (q // 4)] = 10.0         # degenerate: cover-all
    slot_idx = rng.integers(0, n_cells * cap, (q, n_probe)).astype(np.int32)
    return tuple(map(jnp.asarray, (q0, q1, radius, boxes, cell_reach,
                                   c0, c1, reach, slot_idx)))


def _composed(lut, table, codes, valid, grid_args, cap_c, metric):
    """The replaced pipeline: interpret-mode sphere walk → _rt_probe_mask
    gather (slot_of lookup + probe-0 backstop) → interpret-mode fused
    two-stage over the masked valid."""
    q0, q1, radius, boxes, cell_reach, c0, c1, reach, slot_idx = grid_args
    hits = rt.sphere_hits(q0, q1, radius, boxes, cell_reach, c0, c1, reach,
                          interpret=True)
    pok = jnp.take_along_axis(hits, slot_idx, axis=1) > 0
    pok = pok.at[:, 0].set(True)
    masked = valid & pok[:, :, None]
    counts, dist, cand, cdist = fused_two_stage(
        lut, table, codes, masked, cap_c=cap_c, metric=metric,
        interpret=True)
    return counts, dist, cand, cdist, pok


def _check_kernel(seed, q, n_probe, p, s, e, cap_c, metric, g=3, cap=8,
                  valid_p=0.85):
    lut, table, codes, valid = _inputs(seed, q, n_probe, p, s, e, valid_p)
    grid_args = _synth_grid(seed + 1, g, cap, q, n_probe)
    want = _composed(lut, table, codes, valid, grid_args, cap_c, metric)
    oracle = ref.fused_three_stage_ref(
        lut, table, codes, valid, grid_args[0], grid_args[1], grid_args[2],
        grid_args[5], grid_args[6], grid_args[7], grid_args[8],
        cap_c=cap_c, metric=metric)
    got = fused_three_stage(lut, table, codes, valid, *grid_args,
                            cap_c=cap_c, metric=metric, interpret=True)
    g_counts, g_dist, g_cand, g_cdist, g_pok = (np.asarray(x) for x in got)

    # vs composed rt → mask → fused two-stage: integer planes bit-equal
    np.testing.assert_array_equal(g_pok, np.asarray(want[4]))
    np.testing.assert_array_equal(g_counts, np.asarray(want[0]))
    np.testing.assert_array_equal(g_cand, np.asarray(want[2]))
    w_dist = np.asarray(want[1])
    np.testing.assert_array_equal(np.isinf(g_dist), np.isinf(w_dist))
    fin = np.isfinite(w_dist)
    np.testing.assert_allclose(g_dist[fin], w_dist[fin], rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(g_cdist, np.asarray(want[3]), rtol=1e-5,
                               atol=1e-5)
    # vs the dense oracle (semantics of record)
    np.testing.assert_array_equal(g_pok, np.asarray(oracle[4]))
    np.testing.assert_array_equal(g_counts, np.asarray(oracle[0]))
    np.testing.assert_array_equal(g_cand, np.asarray(oracle[2]))
    np.testing.assert_allclose(g_cdist, np.asarray(oracle[3]), rtol=1e-5,
                               atol=1e-4)


# (Q, np, P, S, E, cap_c, g, cap) — ragged Q (bQ padding), ragged cell
# grids (cells >/< point blocks exercise BOTH clamp directions on the
# shared grid axis), prime P above the tile size (point-padding path)
SHAPES = [
    (4, 2, 17, 6, 8, 9, 3, 8),
    (5, 3, 12, 5, 16, 7, 2, 16),
    (9, 2, 10, 12, 32, 20, 4, 8),   # Q=9 → bQ pad; 16 cells > point blocks
    (6, 2, 31, 7, 8, 15, 2, 8),     # P=31 prime → bP=31
    (2, 1, 8, 4, 8, 50, 3, 8),      # cap_c > W → clamped to W
    (1, 4, 13, 3, 4, 5, 2, 8),      # single query
    (4, 2, 131, 5, 8, 20, 3, 8),    # P=131 prime > 128 → padded tiles
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_fused3_matches_composed(shape, metric):
    _check_kernel(sum(shape), *shape[:6], metric, g=shape[6], cap=shape[7])


@pytest.mark.parametrize("metric", ["l2", "ip"])
@pytest.mark.parametrize("valid_p", [0.0, 1.0])
def test_fused3_edge_masks(metric, valid_p):
    """All-pruned (every point invalid) and all-valid masks — composed,
    oracle and kernel must still agree bit-for-bit."""
    _check_kernel(11, 4, 2, 16, 8, 8, 12, metric, valid_p=valid_p)


def test_fused3_probe0_backstop():
    """A query whose sphere misses EVERY cell still scans probe 0: its
    probe_ok row is the backstop pattern [True, False, ...] and its
    candidates come exclusively from probe 0 — never sentinels only."""
    lut, table, codes, valid = _inputs(5, 4, 3, 16, 4, 8, valid_p=1.0)
    grid_args = list(_synth_grid(9, 3, 8, 4, 3))
    grid_args[2] = jnp.full((4,), -1.0, jnp.float32)   # negative radius:
    # thr = r + reach < 0 for every slot (max reach < 1), so no hits
    got = fused_three_stage(lut, table, codes, valid, *grid_args,
                            cap_c=8, metric="l2", interpret=True)
    pok = np.asarray(got[4])
    np.testing.assert_array_equal(
        pok, np.broadcast_to(np.arange(3) == 0, (4, 3)))
    assert np.all(np.asarray(got[2]) < 16)   # all candidates in probe 0
    assert np.all(np.isfinite(np.asarray(got[3])))


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 6), st.integers(1, 3), st.integers(1, 24),
       st.integers(1, 10), st.integers(2, 5), st.integers(1, 30),
       st.integers(2, 4), st.sampled_from([8, 16]),
       st.sampled_from(["l2", "ip"]), st.integers(0, 2 ** 31 - 1))
def test_fused3_kernel_property(q, n_probe, p, s, log_e, cap_c, g, cap,
                                metric, seed):
    """Property sweep: arbitrary shapes/caps/grids/seeds, kernel ==
    composed == oracle."""
    _check_kernel(seed, q, n_probe, p, s, 2 ** log_e, cap_c, metric,
                  g=g, cap=cap)


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 5), st.integers(1, 3), st.integers(2, 20),
       st.integers(1, 8), st.integers(1, 25), st.sampled_from(["l2", "ip"]),
       st.floats(0.0, 1.0), st.integers(0, 2 ** 31 - 1))
def test_fused3_host_matches_oracle(q, n_probe, p, s, cap_c, metric,
                                    valid_p, seed):
    """Host fast path: same probe verdicts and counts, same candidate SET
    (index-ascending by contract), same distances at the candidates."""
    e = 16
    lut, table, codes, valid = _inputs(seed, q, n_probe, p, s, e, valid_p)
    ga = _synth_grid(seed + 1, 3, 8, q, n_probe)
    ro = ref.fused_three_stage_ref(lut, table, codes, valid, ga[0], ga[1],
                                   ga[2], ga[5], ga[6], ga[7], ga[8],
                                   cap_c=cap_c, metric=metric)
    rh = fused_three_stage_host(lut, table, codes, valid, ga[0], ga[1],
                                ga[2], ga[5], ga[6], ga[7], ga[8],
                                cap_c=cap_c, metric=metric)
    np.testing.assert_array_equal(np.asarray(rh[4]), np.asarray(ro[4]))
    np.testing.assert_array_equal(np.asarray(rh[0]), np.asarray(ro[0]))
    np.testing.assert_array_equal(np.sort(np.asarray(rh[2]), axis=1),
                                  np.sort(np.asarray(ro[2]), axis=1))
    assert np.all(np.diff(np.asarray(rh[2]), axis=1) > 0)
    want = np.take_along_axis(np.asarray(ro[1]).reshape(q, -1),
                              np.asarray(rh[2]), axis=1)
    np.testing.assert_allclose(np.asarray(rh[3]), want, rtol=1e-5,
                               atol=1e-4)


def test_fused3_acc_dtype_invariance():
    """The autotuner's hit-count accumulation knob must be invisible in
    results: every ACC_DTYPES option yields bit-equal counts/cand/probe_ok
    and allclose distances (same contraction, different operand dtype)."""
    from repro.kernels.fused_two_stage import ACC_DTYPES
    lut, table, codes, valid = _inputs(23, 5, 2, 16, 8, 16, 0.8)
    ga = _synth_grid(24, 3, 8, 5, 2)
    outs = [fused_three_stage(lut, table, codes, valid, *ga, cap_c=10,
                              metric="l2", acc=acc, interpret=True)
            for acc in ACC_DTYPES]
    c0, d0, i0, cd0, p0 = (np.asarray(x) for x in outs[0])
    for o in outs[1:]:
        c, d, i, cd, pk = (np.asarray(x) for x in o)
        np.testing.assert_array_equal(c0, c)
        np.testing.assert_array_equal(i0, i)
        np.testing.assert_array_equal(p0, pk)
        np.testing.assert_array_equal(np.isinf(d0), np.isinf(d))
        np.testing.assert_allclose(d0[np.isfinite(d0)], d[np.isfinite(d)],
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(cd0, cd, rtol=1e-5, atol=1e-5)


def test_fused3_block_size_invariance():
    """Results must not depend on the (bQ, bP) tiling — with the extra
    twist that bP changes how many point blocks share the grid axis with
    the cells (different clamp overlap every time)."""
    lut, table, codes, valid = _inputs(17, 6, 2, 24, 6, 16, 0.8)
    ga = _synth_grid(18, 3, 8, 6, 2)
    outs = [fused_three_stage(lut, table, codes, valid, *ga, cap_c=10,
                              metric="l2", bq=bq, bp=bp, interpret=True)
            for bq, bp in [(2, 8), (3, 24), (6, 12), (4, 4)]]
    c0, d0, i0, cd0, p0 = (np.asarray(x) for x in outs[0])
    for o in outs[1:]:
        c, d, i, cd, pk = (np.asarray(x) for x in o)
        np.testing.assert_array_equal(c0, c)
        np.testing.assert_array_equal(i0, i)
        np.testing.assert_array_equal(p0, pk)
        np.testing.assert_array_equal(np.isinf(d0), np.isinf(d))
        np.testing.assert_allclose(d0[np.isfinite(d0)], d[np.isfinite(d)],
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(cd0, cd, rtol=1e-5, atol=1e-5)

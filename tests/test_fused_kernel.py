"""Differential validation of the fused two-stage kernel.

The fused kernel (``kernels.fused_two_stage``) must be EQUIVALENT to the
composition it replaces — ``hit_count`` (stage 1) + ``pq_scan`` (stage 2) +
a wide ``lax.top_k`` between them:

* ``counts`` bit-identical to the composed ``hit_count`` kernel;
* ``cand`` bit-identical to ``lax.top_k(counts, cap_c)[1]`` (the composed
  stage-1 selection, including its value-desc/index-asc tie order);
* ``dist`` = the composed ``pq_scan`` totals at every survivor
  (count >= θ = cap_c-th largest), the metric sentinel elsewhere;
* ``cand_dist`` = ``dist`` gathered at ``cand``.

All Pallas executions run in interpret mode (real block iteration on CPU
CI). The host fast path (``fused_two_stage_host``) is held to the same
contract modulo its two documented deviations (index-ordered ``cand``,
``dist`` populated only at ``cand``). Hypothesis drives the shape/seed
sweep through tests/_hypothesis_fallback.py when the real package is
absent.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.fused_two_stage import (fused_two_stage,
                                           fused_two_stage_host)
from repro.kernels.hit_count import hit_count
from repro.kernels.pq_scan import pq_scan

pytestmark = pytest.mark.interpret


def _inputs(seed, q, n_probe, p, s, e, valid_p=0.85):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    lut = jax.random.normal(ks[0], (q, n_probe, s, e), jnp.float32)
    table = jax.random.randint(ks[1], (q, n_probe, s, e), -1, 2
                               ).astype(jnp.int8)
    codes = jax.random.randint(ks[2], (q, n_probe, p, s), 0, e
                               ).astype(jnp.uint8)
    if valid_p <= 0.0:
        valid = jnp.zeros((q, n_probe, p), bool)
    elif valid_p >= 1.0:
        valid = jnp.ones((q, n_probe, p), bool)
    else:
        valid = jax.random.bernoulli(ks[3], valid_p, (q, n_probe, p))
    return lut, table, codes, valid


def _composed(lut, table, codes, valid, cap_c, metric):
    """The replaced pipeline: per-(q, probe) kernels + wide top_k."""
    counts = jax.vmap(jax.vmap(
        lambda t, c, v: hit_count(t, c, v, interpret=True)))(
        table, codes, valid)
    totals = jax.vmap(jax.vmap(
        lambda l, c, v: pq_scan(l, c, v, metric=metric, interpret=True)))(
        lut, codes, valid)
    q = counts.shape[0]
    flat = counts.reshape(q, -1)
    cap_c = max(1, min(cap_c, flat.shape[1]))
    topv, cand = jax.lax.top_k(flat, cap_c)
    return counts, totals, topv, cand


def _check_kernel(seed, q, n_probe, p, s, e, cap_c, metric, valid_p=0.85):
    lut, table, codes, valid = _inputs(seed, q, n_probe, p, s, e, valid_p)
    counts, totals, topv, cand = _composed(lut, table, codes, valid, cap_c,
                                           metric)
    got = fused_two_stage(lut, table, codes, valid, cap_c=cap_c,
                          metric=metric, interpret=True)
    g_counts, g_dist, g_cand, g_cdist = (np.asarray(x) for x in got)
    bad = np.inf if metric == "l2" else -np.inf

    np.testing.assert_array_equal(g_counts, np.asarray(counts))
    np.testing.assert_array_equal(g_cand, np.asarray(cand))
    # dist: pq_scan totals at survivors (count >= θ), sentinel elsewhere
    theta = np.asarray(topv)[:, -1]
    keep = np.asarray(valid) & (np.asarray(counts)
                                >= theta[:, None, None])
    np.testing.assert_allclose(g_dist[keep], np.asarray(totals)[keep],
                               rtol=1e-5, atol=1e-4)
    assert np.all(g_dist[~keep] == bad)
    # compacted candidate distances == dist gathered at cand
    want_cdist = np.take_along_axis(g_dist.reshape(g_counts.shape[0], -1),
                                    g_cand, axis=1)
    np.testing.assert_array_equal(g_cdist, want_cdist)


# (Q, np, P, S, E, cap_c) — ragged Q (bQ padding), P not a multiple of the
# default block (divisor fallback), prime P below and above the tile size
# (the latter takes the point-padding path), S not a SLAB multiple
SHAPES = [
    (3, 2, 17, 6, 8, 9),
    (5, 3, 12, 5, 16, 7),
    (9, 2, 10, 12, 32, 20),    # Q=9 → bQ pad to 12
    (6, 2, 31, 7, 8, 15),      # P=31 prime → bP=31
    (2, 1, 8, 4, 8, 50),       # cap_c > W → clamped to W
    (1, 4, 13, 3, 4, 5),       # single query
    (4, 2, 131, 5, 8, 20),     # P=131 prime > 128 → padded to bP=128 tiles
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_fused_matches_composed_kernels(shape, metric):
    _check_kernel(sum(shape), *shape, metric)


@pytest.mark.parametrize("metric", ["l2", "ip"])
@pytest.mark.parametrize("valid_p", [0.0, 1.0])
def test_fused_edge_masks(metric, valid_p):
    """All-pruned (every point invalid) and all-valid survivor masks."""
    _check_kernel(11, 4, 2, 16, 8, 8, 12, metric, valid_p=valid_p)


def test_fused_all_pruned_sentinels():
    """With nothing valid, every dist is the sentinel and every count the
    NEG marker — and cand still lists cap_c well-formed indices."""
    lut, table, codes, valid = _inputs(3, 2, 2, 9, 4, 8, valid_p=0.0)
    counts, dist, cand, cdist = fused_two_stage(
        lut, table, codes, valid, cap_c=6, metric="l2", interpret=True)
    assert np.all(np.asarray(counts) == -(2 ** 30))
    assert np.all(np.isinf(np.asarray(dist)))
    assert np.all(np.isinf(np.asarray(cdist)))
    c = np.asarray(cand)
    assert c.shape == (2, 6) and np.all((c >= 0) & (c < 18))
    # ties at NEG break index-ascending, exactly like lax.top_k
    np.testing.assert_array_equal(c, np.broadcast_to(np.arange(6), (2, 6)))


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 6), st.integers(1, 3), st.integers(1, 24),
       st.integers(1, 10), st.integers(2, 5), st.integers(1, 30),
       st.sampled_from(["l2", "ip"]), st.integers(0, 2 ** 31 - 1))
def test_fused_kernel_property(q, n_probe, p, s, log_e, cap_c, metric, seed):
    """Property sweep: arbitrary shapes/caps/seeds, kernel == composed."""
    _check_kernel(seed, q, n_probe, p, s, 2 ** log_e, cap_c, metric)


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 5), st.integers(1, 3), st.integers(2, 20),
       st.integers(1, 8), st.integers(1, 25),
       st.sampled_from(["l2", "ip"]), st.floats(0.0, 1.0),
       st.integers(0, 2 ** 31 - 1))
def test_host_path_matches_oracle(q, n_probe, p, s, cap_c, metric, valid_p,
                                  seed):
    """Host fast path: same counts, same candidate SET (order is
    index-ascending by contract), same distances at the candidates."""
    e = 16
    lut, table, codes, valid = _inputs(seed, q, n_probe, p, s, e, valid_p)
    ro = ref.fused_two_stage_ref(lut, table, codes, valid, cap_c=cap_c,
                                 metric=metric)
    rh = fused_two_stage_host(lut, table, codes, valid, cap_c=cap_c,
                              metric=metric)
    np.testing.assert_array_equal(np.asarray(rh[0]), np.asarray(ro[0]))
    np.testing.assert_array_equal(np.sort(np.asarray(rh[2]), axis=1),
                                  np.sort(np.asarray(ro[2]), axis=1))
    # host cand is index-sorted by construction
    assert np.all(np.diff(np.asarray(rh[2]), axis=1) > 0)
    want = np.take_along_axis(np.asarray(ro[1]).reshape(q, -1),
                              np.asarray(rh[2]), axis=1)
    np.testing.assert_allclose(np.asarray(rh[3]), want, rtol=1e-5, atol=1e-4)


def test_kernel_matches_dense_oracle():
    """The interpret-mode kernel reproduces the dense oracle EXACTLY —
    including the survivor-masked dist plane and tie handling."""
    for seed, metric in [(0, "l2"), (1, "ip")]:
        lut, table, codes, valid = _inputs(seed, 5, 2, 19, 7, 8, 0.7)
        ro = ref.fused_two_stage_ref(lut, table, codes, valid, cap_c=13,
                                     metric=metric)
        rk = fused_two_stage(lut, table, codes, valid, cap_c=13,
                             metric=metric, interpret=True)
        np.testing.assert_array_equal(np.asarray(rk[0]), np.asarray(ro[0]))
        np.testing.assert_array_equal(np.asarray(rk[2]), np.asarray(ro[2]))
        dk, do = np.asarray(rk[1]), np.asarray(ro[1])
        np.testing.assert_array_equal(np.isinf(dk), np.isinf(do))
        np.testing.assert_allclose(dk[np.isfinite(dk)], do[np.isfinite(do)],
                                   rtol=1e-5, atol=1e-4)
        np.testing.assert_allclose(np.asarray(rk[3]), np.asarray(ro[3]),
                                   rtol=1e-5, atol=1e-4)


def test_fused_block_size_invariance():
    """Results must not depend on the (bQ, bP) tiling — pure BlockSpec
    property, mirroring test_kernels.test_block_size_invariance. Integer
    outputs (counts, cand) are bit-equal; f32 ADC totals may differ by
    accumulation order across tile shapes, so they get a tight allclose."""
    lut, table, codes, valid = _inputs(17, 6, 2, 24, 6, 16, 0.8)
    outs = [fused_two_stage(lut, table, codes, valid, cap_c=10, metric="l2",
                            bq=bq, bp=bp, interpret=True)
            for bq, bp in [(2, 8), (3, 24), (6, 12), (4, 4)]]
    c0, d0, i0, cd0 = (np.asarray(x) for x in outs[0])
    for o in outs[1:]:
        c, d, i, cd = (np.asarray(x) for x in o)
        np.testing.assert_array_equal(c0, c)
        np.testing.assert_array_equal(i0, i)
        np.testing.assert_array_equal(np.isinf(d0), np.isinf(d))
        np.testing.assert_allclose(d0[np.isfinite(d0)], d[np.isfinite(d)],
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(cd0, cd, rtol=1e-5, atol=1e-5)

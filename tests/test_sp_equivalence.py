"""Sequence-parallel (SP) correctness: the shard_map SP schedule must be
numerically equivalent to the unsharded model — run in a subprocess with 8
host devices on a (2, 4) data×model mesh."""
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_sp_train_step_matches_unsharded():
    code = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_smoke_config
from repro.data.tokens import make_batch
from repro.dist import sharding as shmod
from repro.models import get_model
from repro.models.params import init_params, tree_map_specs
from repro.launch.mesh import normalize_pspec
from repro.train import TrainConfig, TrainState, make_train_step
from repro.train.optimizer import init_opt_state

# seq must divide model axis (4); heads (4) divide model axis (4)
cfg = dataclasses.replace(get_smoke_config("phi4_mini_3_8b"),
                          dtype="float32")
model = get_model(cfg)
params = init_params(model.schema, jax.random.PRNGKey(0))
state = TrainState(params=params, opt=init_opt_state(params))
batch = make_batch(cfg, batch=4, seq=32, step=0)

# reference: no sharding machinery at all
ref_step = jax.jit(make_train_step(model, TrainConfig()))
_, ref_metrics = ref_step(state, batch)

# SP on a (2, 4) mesh
mesh = jax.make_mesh((2, 4), ("data", "model"))
shmod.enable(("data",), sp=True, model_axis=4, mesh=mesh)
grad_pspecs = tree_map_specs(
    lambda s: normalize_pspec(s.pspec, mesh, s.shape), model.schema)
with mesh:
    sp_step = jax.jit(make_train_step(model, TrainConfig(),
                                      grad_pspecs=grad_pspecs))
    state_sh = jax.tree.map(
        lambda x: jax.device_put(x, NamedSharding(mesh, P())), state)
    _, sp_metrics = sp_step(state_sh, batch)
shmod.disable()

l_ref, l_sp = float(ref_metrics["loss"]), float(sp_metrics["loss"])
g_ref, g_sp = float(ref_metrics["grad_norm"]), float(sp_metrics["grad_norm"])
assert abs(l_ref - l_sp) < 1e-4 * max(1, abs(l_ref)), (l_ref, l_sp)
assert abs(g_ref - g_sp) < 1e-3 * max(1, abs(g_ref)), (g_ref, g_sp)
print("OK", l_ref, l_sp, g_ref, g_sp)
'''
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], env=env, cwd=".",
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout, out.stdout


@pytest.mark.slow
def test_ep_moe_matches_dense():
    """Expert-parallel shard_map MoE == dense-path MoE (generous capacity
    so neither path drops tokens), on a real (2,4) mesh."""
    code = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_smoke_config
from repro.data.tokens import make_batch
from repro.dist import sharding as shmod
from repro.models import get_model
from repro.models.config import MoEConfig
from repro.models.params import init_params
from repro.train import TrainConfig, TrainState, make_train_step
from repro.train.optimizer import init_opt_state

base = get_smoke_config("deepseek_v2_lite_16b")
cfg = dataclasses.replace(
    base, dtype="float32",
    moe=dataclasses.replace(base.moe, capacity_factor=8.0))
model = get_model(cfg)
params = init_params(model.schema, jax.random.PRNGKey(0))
state = TrainState(params=params, opt=init_opt_state(params))
batch = make_batch(cfg, batch=4, seq=16, step=0)

ref_step = jax.jit(make_train_step(model, TrainConfig()))
_, ref_metrics = ref_step(state, batch)

mesh = jax.make_mesh((2, 4), ("data", "model"))
shmod.enable(("data",), sp=False, model_axis=4, mesh=mesh)
with mesh:
    ep_step = jax.jit(make_train_step(model, TrainConfig()))
    state_sh = jax.tree.map(
        lambda x: jax.device_put(x, NamedSharding(mesh, P())), state)
    _, ep_metrics = ep_step(state_sh, batch)
shmod.disable()

l_ref, l_ep = float(ref_metrics["loss"]), float(ep_metrics["loss"])
assert abs(l_ref - l_ep) < 1e-4 * max(1, abs(l_ref)), (l_ref, l_ep)
print("OK", l_ref, l_ep)
'''
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], env=env, cwd=".",
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout, out.stdout

"""Observability layer: ``repro.obs`` primitives + serving integration.

Two halves. The primitives half pins the registry's merge algebra —
counter folds commute, gauge merges respect the declared aggregation and
fail closed on disagreement, histogram merges fail closed on bucket-edge
mismatch, kind conflicts raise — plus the JSONL export round-trip (a
dump rebuilds into an identical registry and validates clean) and the
tracer's nesting/ordering guarantees. The integration half pins the
property the whole layer is built around: instrumentation is host-side
bookkeeping ONLY, so an engine with a live registry/tracer/recall-probe
returns bit-identical ids and scores and compiles the identical jit
signature lattice as an uninstrumented one, across the resident and
paged tiers — and the deprecated dict-shaped stats remain consistent
views of the registry series that replaced them.
"""
import jax
import numpy as np
import pytest

from repro.build import ArtifactStore
from repro.core import JunoConfig, build
from repro.data import DEEP_LIKE, make_dataset
from repro.obs import (Counter, Gauge, Histogram, MetricsRegistry,
                       Observability, RecallProbe, Tracer, exact_topk_ids,
                       read_jsonl, registry_from_events, to_events,
                       validate_events, write_jsonl)
from repro.serve.ann import AnnServeEngine
from repro.serve.fleet import AnnServeFleet, LatencyHistogram
from repro.serve.paged import PagedAnnServeEngine, PagedIndexData


# ---------------------------------------------------------------------------
# registry primitives: merge algebra, fail-closed everywhere
# ---------------------------------------------------------------------------

def test_counter_merge_commutative():
    a, b = Counter(), Counter()
    a.inc(3)
    a.inc(4.5)
    b.inc(10)
    ab, ba = Counter(), Counter()
    ab.merge(a)
    ab.merge(b)
    ba.merge(b)
    ba.merge(a)
    assert ab.value == ba.value == 17.5


def test_gauge_agg_semantics_and_mismatch():
    last, mx = Gauge(agg="last"), Gauge(agg="max")
    last.set(3.0)
    other = Gauge(agg="last")
    other.set(7.0)
    last.merge(other)
    assert last.value == 7.0            # other wins: it has updates
    fresh = Gauge(agg="last")           # no updates → no new information
    last.merge(fresh)
    assert last.value == 7.0
    with pytest.raises(ValueError):
        last.merge(mx)                  # agg disagreement: no right answer


def test_histogram_merge_requires_identical_edges():
    a = Histogram()
    b = Histogram()
    for v in (0.001, 0.01, 0.1):
        a.add(v)
        b.add(v * 2)
    n_before = a.n
    a.merge(b)
    assert a.n == n_before + b.n
    # same bucket COUNT is not enough — the edges themselves must match
    skewed = Histogram(lo=1e-5, hi=5000.0)
    assert len(skewed._counts) == len(Histogram()._counts)
    with pytest.raises(ValueError):
        Histogram().merge(skewed)


def test_registry_kind_and_bucketing_conflicts_raise():
    reg = MetricsRegistry()
    reg.counter("juno_test_total")
    with pytest.raises(ValueError):
        reg.gauge("juno_test_total")    # same series, different kind
    reg.histogram("juno_test_seconds")
    with pytest.raises(ValueError):
        reg.histogram("juno_test_seconds", lo=1e-5, hi=5000.0)
    other = MetricsRegistry()
    other.histogram("juno_test_seconds", lo=1e-5, hi=5000.0)
    with pytest.raises(ValueError):
        reg.merge(other)                # fail-closed across registries too


def test_registry_merge_sums_and_copies():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("juno_x_total", mode="H").inc(2)
    b.counter("juno_x_total", mode="H").inc(3)
    b.counter("juno_only_b_total").inc(1)
    a.merge(b)
    assert a.snapshot()['juno_x_total{mode="H"}'] == 5
    assert a.snapshot()["juno_only_b_total"] == 1
    b.counter("juno_only_b_total").inc(1)   # deep copy: no aliasing back
    assert a.snapshot()["juno_only_b_total"] == 1


def test_metric_name_scheme_enforced():
    reg = MetricsRegistry()
    for bad in ("Juno_x", "juno x", "9juno", "juno-x"):
        with pytest.raises(ValueError):
            reg.counter(bad)


# ---------------------------------------------------------------------------
# tracer: nesting, ordering, bounded buffer
# ---------------------------------------------------------------------------

def test_tracer_nesting_and_order():
    tr = Tracer()
    with tr.span("tick", trace_id="t1"):
        with tr.span("dispatch", rows=8):
            pass
        with tr.span("merge"):
            pass
    spans = {s.name: s for s in tr.spans()}
    assert spans["dispatch"].parent_id == spans["tick"].span_id
    assert spans["merge"].parent_id == spans["tick"].span_id
    assert spans["dispatch"].trace_id == "t1"       # inherited from parent
    assert spans["tick"].parent_id is None
    # spans are appended on CLOSE: children precede their parent
    names = [s.name for s in tr.spans()]
    assert names.index("dispatch") < names.index("merge") < names.index("tick")
    assert all(s.t_end >= s.t_start for s in tr.spans())


def test_tracer_retro_record_and_bounded_buffer():
    tr = Tracer(max_spans=3)
    with tr.span("serve") as root:
        tr.record("queue", 1.0, 2.0, parent=root)
    assert [s.name for s in tr.spans()] == ["queue", "serve"]
    for i in range(5):
        tr.record(f"extra_{i}", 0.0, 1.0)
    assert len(tr.spans()) == 3         # deque bounded
    assert tr.dropped == 4              # 2 + 5 recorded, 3 kept


# ---------------------------------------------------------------------------
# export: JSONL round-trip + fail-closed validation
# ---------------------------------------------------------------------------

def _sample_bundle():
    obs = Observability()
    obs.registry.counter("juno_engine_requests_total", mode="H").inc(4)
    obs.registry.gauge("juno_engine_queue_rows", agg="sum").set(3)
    h = obs.registry.histogram("juno_engine_request_seconds")
    for v in (0.001, 0.02, 0.5):
        h.add(v)
    with obs.tracer.span("engine.tick", trace_id="r1"):
        with obs.tracer.span("engine.dispatch"):
            pass
    return obs


def test_jsonl_round_trip(tmp_path):
    obs = _sample_bundle()
    events = obs.events(extra_meta={"who": "test"})
    assert validate_events(events) == []
    path = str(tmp_path / "dump.jsonl")
    write_jsonl(path, events)
    back = read_jsonl(path)
    assert back == events
    rebuilt = registry_from_events(back)
    assert rebuilt.snapshot() == obs.registry.snapshot()
    assert rebuilt.render_text() == obs.registry.render_text()


def test_validate_flags_corruption(tmp_path):
    obs = _sample_bundle()
    events = obs.events()
    no_meta = [ev for ev in events if ev.get("event") != "meta"]
    assert validate_events(no_meta)
    bad_hist = [dict(ev) for ev in events]
    for ev in bad_hist:
        if ev.get("kind") == "histogram":
            ev["counts"] = ev["counts"][:-1]        # truncated state
    assert validate_events(bad_hist)
    bad_span = [dict(ev) for ev in events]
    for ev in bad_span:
        if ev.get("event") == "span" and ev["parent_id"] is not None:
            ev["parent_id"] = "no-such-span"
    assert validate_events(bad_span)


# ---------------------------------------------------------------------------
# recall probe: exactness at every=1
# ---------------------------------------------------------------------------

class _FakeReq:
    """Duck-typed request shell: just what RecallProbe.observe reads."""

    def __init__(self, queries, ids, k):
        """Hold queries, returned ids and the requested depth."""
        self.queries, self.ids, self.k = queries, ids, k


def test_recall_probe_every1_exact():
    rng = np.random.default_rng(0)
    vecs = rng.standard_normal((200, 8)).astype(np.float32)
    q = rng.standard_normal((6, 8)).astype(np.float32)
    exact = exact_topk_ids(q, vecs, 10)
    probe = RecallProbe(vecs, k=10, every=1)
    reg = MetricsRegistry()
    probe.bind(reg)
    probe.observe(_FakeReq(q, exact, 10), "H")
    assert probe.estimate("H") == 1.0
    half = exact.copy()
    half[:, 5:] = -1                    # blow away half the hits
    probe.observe(_FakeReq(q, half, 10), "H")
    assert probe.estimate("H") == pytest.approx(0.75)
    snap = reg.snapshot()
    assert snap['juno_recall_samples_total{mode="H"}'] == 12
    assert snap['juno_recall_online_at_k{k="10",mode="H"}'] == (
        pytest.approx(0.75))


def test_latency_histogram_is_obs_histogram():
    lh = LatencyHistogram()
    assert isinstance(lh, Histogram)
    oh = Histogram()
    lh.add(0.01)
    oh.merge(lh)                        # identical bucketing by definition
    assert oh.n == 1


# ---------------------------------------------------------------------------
# serving integration: zero result impact, identical lattice, live series
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def obs_env(tmp_path_factory):
    pts, q = make_dataset(DEEP_LIKE, 4000, 32, key=jax.random.PRNGKey(9))
    pts, q = np.asarray(pts), np.asarray(q)
    cfg = JunoConfig(n_clusters=16, n_entries=16, calib_queries=12,
                     kmeans_iters=4, capacity_mult=1.2)
    idx = build(pts, cfg)
    root = tmp_path_factory.mktemp("obs_store")
    store = ArtifactStore(str(root))
    assert store.put("main", idx, cfg) == 1
    return pts, q, cfg, idx, store


def _mixed_wave(eng, q):
    reqs = [eng.submit(q[:5], k=10, mode="H", nprobe=8),
            eng.submit(q[5:9], k=10, mode="H2", nprobe=8),
            eng.submit(q[9:12], k=10, mode="H"),
            eng.submit(q[12:16], k=10, mode="H2")]
    eng.run()
    return reqs


@pytest.mark.parametrize("tier", ["resident", "paged"])
def test_obs_on_off_bit_parity(obs_env, tier):
    pts, q, cfg, idx, store = obs_env

    def make(obs):
        if tier == "resident":
            return AnnServeEngine(idx, obs=obs)
        paged = PagedIndexData(store.path("main", 1), expect_config=cfg)
        return PagedAnnServeEngine(paged, obs=obs)

    plain, inst = make(None), make(Observability(
        recall=RecallProbe(pts, k=10, every=1)))
    r_plain, r_inst = _mixed_wave(plain, q), _mixed_wave(inst, q)
    for a, b in zip(r_plain, r_inst):
        np.testing.assert_array_equal(a.ids, b.ids)
        np.testing.assert_array_equal(a.scores, b.scores)
    # the jit signature lattice must be untouched by instrumentation
    assert plain.stats["signatures"] == inst.stats["signatures"]
    snap = inst.obs.registry.snapshot()
    assert snap['juno_engine_requests_total{mode="H"}'] == 2
    assert snap['juno_engine_requests_total{mode="H2"}'] == 2
    assert snap['juno_recall_online_at_k{k="10",mode="H"}'] > 0.0
    if tier == "paged":
        assert snap["juno_paged_faults_total"] > 0


def test_engine_spans_nest_under_ticks(obs_env):
    _, q, cfg, idx, _ = obs_env
    obs = Observability()
    eng = AnnServeEngine(idx, obs=obs)
    _mixed_wave(eng, q)
    spans = obs.tracer.spans()
    by_id = {s.span_id: s for s in spans}
    names = {s.name for s in spans}
    assert {"engine.tick", "engine.dispatch", "engine.merge",
            "engine.enqueue"} <= names
    for s in spans:
        if s.name in ("engine.dispatch", "engine.merge", "engine.enqueue"):
            assert by_id[s.parent_id].name == "engine.tick"
    # every request's trace_id shows up on its enqueue span
    enq = {s.trace_id for s in spans if s.name == "engine.enqueue"}
    assert len(enq) == 4


def test_latency_stats_is_registry_alias(obs_env):
    _, q, cfg, idx, _ = obs_env
    obs = Observability()
    eng = AnnServeEngine(idx, obs=obs)
    _mixed_wave(eng, q)
    lat = eng.latency_stats()
    hist = obs.registry.histogram("juno_engine_request_seconds", mode="H")
    hist2 = obs.registry.histogram("juno_engine_request_seconds", mode="H2")
    assert hist.n + hist2.n == lat["n"] == 4
    # same observations on both sides: counts and the exact max agree;
    # percentiles are upper-edge estimates in the registry form, so they
    # may over-report the legacy exact-sorted quantile by at most one
    # log-spaced bucket (and never under-report it)
    merged = Histogram()
    merged.merge(hist)
    merged.merge(hist2)
    assert merged.max == lat["max"]
    assert lat["p50"] <= merged.percentile(0.75) <= lat["max"]


def test_fleet_merged_registry_sums_replicas(obs_env):
    _, q, cfg, idx, _ = obs_env
    fleet = AnnServeFleet(idx, n_replicas=2, shards_per_replica=1, obs=True)
    for i in range(6):
        fleet.submit(q[i * 2:i * 2 + 2], k=10, mode="M", nprobe=8)
    fleet.run()
    merged = fleet.merged_registry()
    snap = merged.snapshot()
    assert snap["juno_fleet_submitted_total"] == 6
    served = sum(v for k, v in snap.items()
                 if k.startswith("juno_fleet_served_total"))
    assert served == 6
    # replica child registries fold in: engine query totals sum to the
    # fleet-wide query count
    assert snap["juno_engine_queries_total"] == 12
    # per-request fleet spans carry the queue/compute/merge children
    roots = [s for s in fleet.obs.tracer.spans() if s.name == "fleet.request"]
    assert len(roots) == 6
    kids = [s for s in fleet.obs.tracer.spans()
            if s.parent_id in {r.span_id for r in roots}]
    assert len(kids) == 3 * len(roots)


def test_cache_stats_alias_matches_registry(obs_env):
    pts, q, cfg, idx, store = obs_env
    paged = PagedIndexData(store.path("main", 1), expect_config=cfg)
    obs = Observability()
    eng = PagedAnnServeEngine(paged, obs=obs)
    _mixed_wave(eng, q)
    stats = eng.cache_stats()           # deprecated dict-shaped alias
    snap = obs.registry.snapshot()
    assert snap["juno_cache_hits_total"] == stats["hits"]
    assert snap["juno_cache_misses_total"] == stats["misses"]
    assert snap["juno_cache_evictions_total"] == stats["evictions"]
    assert snap["juno_cache_bytes"] == stats["bytes"]


def test_observability_child_shares_tracer_and_probe():
    probe = RecallProbe(np.zeros((4, 2), np.float32), k=1)
    parent = Observability(recall=probe)
    child = parent.child()
    assert child.tracer is parent.tracer
    assert child.recall is parent.recall
    assert child.registry is not parent.registry
    child.registry.counter("juno_x_total").inc()
    assert "juno_x_total" not in parent.registry.snapshot()

"""Recall regression harness: pinned recall@10 lower bounds over the full
mode × metric matrix on fixed-seed synthetic data.

Retrieval quality previously had only coarse spot checks (R1@100 for two
modes); an algorithmic regression in the LUT/threshold/scan pipeline could
pass tier-1 silently. Here every operating point in {H, M, L, H2} × {l2, ip}
must clear a floor set ~30-40% below the measured seed value — loose enough
for cross-machine BLAS jitter, tight enough that any real regression
(masking bug, threshold miscalibration, scan sign flip) fails loudly.

Metric: recall of the exact top-10 within a k=100 candidate list (the
paper's R@k style), plus strict recall@10-of-10 floors for the H modes.
"""
import jax
import numpy as np
import pytest

from repro.core import (JunoConfig, build, exact_topk, recall_n_at_k,
                        search)
from repro.data import DEEP_LIKE, TTI_LIKE, make_dataset
from repro.serve.ann import AnnServeEngine

NPROBE = 16

# (metric, mode) -> recall@10-in-100 floor.  Measured seed values (2026-08,
# jax 0.4.37 CPU): l2: H=1.000 M=0.669 L=0.354 H2=0.923
#                  ip: H=0.981 M=0.202 L=0.215 H2=0.435
FLOORS_10_AT_100 = {
    ("l2", "H"): 0.95, ("l2", "M"): 0.45, ("l2", "L"): 0.20,
    ("l2", "H2"): 0.80,
    ("ip", "H"): 0.90, ("ip", "M"): 0.10, ("ip", "L"): 0.10,
    ("ip", "H2"): 0.30,
}
# strict k=10 retrieval for the exact-distance modes (seed: l2 H=0.665,
# l2 H2=0.469, ip H=0.642)
FLOORS_10_AT_10 = {
    ("l2", "H"): 0.50, ("l2", "H2"): 0.30, ("ip", "H"): 0.45,
}

# fused-path floors at the two candidate budgets that exist in the system:
# "H" = the serving engine's fused signature (rerank = FUSED_RERANK_MULT·k —
# BOTH the H and H2 recall tiers are served at this budget), "H2" = the core
# API's default fused budget (rerank=0 → 4k; what direct search(fused=True),
# fig12 and the distributed path use).
# Measured (2026-08, jax 0.4.37 CPU): l2: H=1.000 H2=0.923
#                                     ip: H=0.965 H2=0.435
FLOORS_FUSED_10_AT_100 = {
    ("l2", "H"): 0.95, ("l2", "H2"): 0.80,
    ("ip", "H"): 0.85, ("ip", "H2"): 0.30,
}

# three-stage (fused + rt) floors over the full serving matrix, resident
# AND paged (the paged tier must additionally be bit-equal to resident).
# Measured (2026-08, jax 0.4.37 CPU): l2: H=0.969 H2=0.902
#                                     ip: H=0.940 H2=0.688
FLOORS_FUSED3_10_AT_100 = {
    ("l2", "H"): 0.82, ("l2", "H2"): 0.75,
    ("ip", "H"): 0.78, ("ip", "H2"): 0.45,
}


@pytest.fixture(scope="module")
def matrix_data():
    out = {}
    for metric, spec in [("l2", DEEP_LIKE), ("ip", TTI_LIKE)]:
        pts, q = make_dataset(spec, 8000, 48, key=jax.random.PRNGKey(13))
        cfg = JunoConfig(n_clusters=32, n_entries=32, calib_queries=24,
                         kmeans_iters=5, metric=metric)
        idx = build(pts, cfg)
        _, gt10 = exact_topk(q, pts, k=10, metric=metric)
        out[metric] = (pts, q, idx, gt10)
    return out


@pytest.mark.parametrize("metric", ["l2", "ip"])
@pytest.mark.parametrize("mode", ["H", "M", "L", "H2"])
def test_recall_floor_10_at_100(matrix_data, metric, mode):
    _, q, idx, gt10 = matrix_data[metric]
    _, ids = search(idx, q, nprobe=NPROBE, k=100, mode=mode, metric=metric)
    r = float(recall_n_at_k(ids, gt10))
    floor = FLOORS_10_AT_100[(metric, mode)]
    assert r >= floor, (
        f"recall@10-in-100 regression: {metric}/{mode} = {r:.3f} < {floor}")


@pytest.mark.parametrize("cell", sorted(FLOORS_10_AT_10))
def test_recall_floor_10_at_10(matrix_data, cell):
    metric, mode = cell
    _, q, idx, gt10 = matrix_data[metric]
    _, ids = search(idx, q, nprobe=NPROBE, k=10, mode=mode, metric=metric)
    r = float(recall_n_at_k(ids, gt10))
    floor = FLOORS_10_AT_10[cell]
    assert r >= floor, (
        f"recall@10 regression: {metric}/{mode} = {r:.3f} < {floor}")


@pytest.mark.parametrize("metric", ["l2", "ip"])
@pytest.mark.parametrize("tier", ["H", "H2"])
def test_recall_floor_fused(matrix_data, metric, tier):
    """Fused-path recall floors at both candidate budgets: "H" = the
    engine's widened serving budget (32·k, serves the H and H2 recall
    tiers), "H2" = the core default budget (4·k, identical candidates to
    composed H2 — what direct fused search/fig12/dist use)."""
    _, q, idx, gt10 = matrix_data[metric]
    rerank = AnnServeEngine.FUSED_RERANK_MULT * 100 if tier == "H" else 0
    _, ids = search(idx, q, nprobe=NPROBE, k=100, mode="H2", metric=metric,
                    fused=True, rerank=rerank)
    r = float(recall_n_at_k(ids, gt10))
    floor = FLOORS_FUSED_10_AT_100[(metric, tier)]
    assert r >= floor, (
        f"fused recall@10-in-100 regression: {metric}/{tier} = {r:.3f} "
        f"< {floor}")


@pytest.fixture(scope="module")
def fused3_data(matrix_data, tmp_path_factory):
    """matrix_data plus, per metric, the rt grid and a paged index whose
    artifact carries that grid (the out-of-core three-stage serving
    shape)."""
    from repro import rt
    from repro.build import save_index
    from repro.core import JunoConfig
    from repro.serve.paged import PagedIndexData, PagedJunoIndex

    out = {}
    for metric in ["l2", "ip"]:
        pts, q, idx, gt10 = matrix_data[metric]
        grid = rt.build_grid(idx, metric=metric)
        cfg = JunoConfig(n_clusters=32, n_entries=32, calib_queries=24,
                         kmeans_iters=5, metric=metric)
        path = str(tmp_path_factory.mktemp(f"fused3_{metric}") / "idx")
        save_index(path, idx, cfg, rt_grid=grid)
        pidx = PagedJunoIndex(PagedIndexData(path, cache_bytes=1 << 22))
        out[metric] = (q, idx, grid, pidx, gt10)
    return out


@pytest.mark.parametrize("residency", ["resident", "paged"])
@pytest.mark.parametrize("metric", ["l2", "ip"])
@pytest.mark.parametrize("tier", ["H", "H2"])
def test_recall_floor_fused3(fused3_data, metric, tier, residency):
    """Three-stage-path recall floors over {tier} × {metric} ×
    {resident, paged} — the same two candidate budgets as the fused
    floors, now with the RT sphere test folded into the kernel. The paged
    run must ALSO be bit-identical to the resident one (same artifact
    grid, same verdicts — residency is an implementation detail)."""
    q, idx, grid, pidx, gt10 = fused3_data[metric]
    rerank = AnnServeEngine.FUSED_RERANK_MULT * 100 if tier == "H" else 0
    _, res_ids = search(idx, q, nprobe=NPROBE, k=100, mode="H2",
                        metric=metric, fused=True, prefilter="rt",
                        rt_grid=grid, rerank=rerank)
    if residency == "paged":
        _, ids = pidx.search(q, nprobe=NPROBE, k=100, mode="H2",
                             metric=metric, fused=True, prefilter="rt",
                             rerank=rerank)
        np.testing.assert_array_equal(np.asarray(ids), np.asarray(res_ids))
    else:
        ids = res_ids
    r = float(recall_n_at_k(ids, gt10))
    floor = FLOORS_FUSED3_10_AT_100[(metric, tier)]
    assert r >= floor, (
        f"fused3 recall@10-in-100 regression: {metric}/{tier}/{residency}"
        f" = {r:.3f} < {floor}")


def test_autotune_preserves_signature_lattice(fused3_data):
    """Engine-level pin: installing autotune configs must not widen the
    jit signature lattice — configs are applied at trace time inside the
    kernel dispatchers, never as new dispatch keys. The same request mix
    served under default and under non-default configs must produce an
    IDENTICAL signature Counter and identical results (every autotune
    knob is result-invariant)."""
    from repro.kernels import autotune

    q, idx, grid, _, _ = fused3_data["l2"]
    waves = [(q[:8], dict(k=10, mode="H2", nprobe=NPROBE)),
             (q[8:24], dict(k=10, mode="H", nprobe=NPROBE)),
             (q[24:28], dict(k=10, mode="H2", nprobe=8))]

    def serve(configs):
        autotune.reset()
        try:
            for kernel, cfg in configs.items():
                autotune.set_config(kernel, cfg)
            eng = AnnServeEngine(idx, metric="l2", fused=True,
                                 prefilter="rt", batch_buckets=(8, 16, 32))
            reqs = [eng.submit(qs, **kw) for qs, kw in waves]
            eng.run()
            sigs = dict(eng.stats["signatures"])
            return sigs, [np.asarray(r.ids) for r in reqs]
        finally:
            autotune.reset()

    base_sigs, base_ids = serve({})
    tuned_sigs, tuned_ids = serve({
        "fused_two_stage": autotune.KernelConfig(bq=2, topc_impl="topk",
                                                 acc_dtype="bf16"),
        "fused_three_stage": autotune.KernelConfig(bq=8, bp=64,
                                                   topc_impl="topk"),
    })
    assert tuned_sigs == base_sigs
    # keys stay exactly (k, mode, nprobe, bucket) — no knob leaked into
    # the dispatch key (the fused engine folds the H tier into the H2
    # signature, so the count is the engine's own lattice, not widened)
    assert base_sigs
    assert all(len(key) == 4 for key in base_sigs)
    assert {kw["k"] for _, kw in waves} == {key[0] for key in base_sigs}
    for a, b in zip(base_ids, tuned_ids):
        np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_unfused_bit_equal_to_seed_composition(matrix_data, metric):
    """fused=False must remain BIT-IDENTICAL to the seed's composed
    two-stage semantics. The expected result is rebuilt here from the
    seed-era building blocks (reference LUT/hit-table construction, gather
    scans, wide top-k) so a silent behaviour change in the default path —
    not just a disagreement between fused and unfused — fails loudly."""
    import jax.numpy as jnp

    from repro.core import density as density_lib
    from repro.core import lut as lut_lib
    from repro.core import scan as scan_lib
    from repro.core.ivf import filter_clusters

    _, q, idx, _ = matrix_data[metric]
    q = jnp.asarray(q)[:16]
    nprobe, k = NPROBE, 10
    got_s, got_i = search(idx, q, nprobe=nprobe, k=k, mode="H2",
                          metric=metric, fused=False, batch=q.shape[0])

    # seed-composed reference (mirrors the pre-fused _search_batch_two_stage
    # op for op); jitted so both sides run compiled programs of the same
    # structure — bit-equality is the whole point here
    @jax.jit
    def seed_two_stage(idx, q):
        nq, m = q.shape[0], idx.codebook.sub_dim
        base, cids = filter_clusters(q, idx.ivf, nprobe=nprobe,
                                     metric=metric)
        if metric == "l2":
            res = q[:, None, :] - idx.ivf.centroids[cids]
            qsub = res.reshape(nq, nprobe, -1, m)
            probe_base = jnp.zeros((nq, nprobe), jnp.float32)
        else:
            qsub = jnp.broadcast_to(
                q.reshape(nq, 1, -1, m), (nq, nprobe, q.shape[1] // m, m))
            probe_base = base
        tau = density_lib.predict_threshold(idx.density, qsub, 1.0)
        codes = idx.cluster_codes[cids]
        valid = idx.ivf.valid[cids]
        ids = idx.ivf.point_ids[cids]
        lut, mask = lut_lib.build_lut(qsub, idx.codebook, tau, metric=metric)
        mlut = lut_lib.masked_lut(lut, mask, tau, metric=metric)
        if metric == "l2":
            table = lut_lib.hit_tables(lut, mask, tau, mode="reward_penalty",
                                       metric="l2")
        else:
            table = lut_lib.hit_tables_ip(lut, idx.codebook.entry_sq, tau,
                                          mode="reward_penalty")
        counts = jax.vmap(jax.vmap(scan_lib.hit_count_scan))(table, codes,
                                                             valid)
        p = codes.shape[2]
        _, cand = jax.lax.top_k(counts.reshape(nq, -1),
                                min(4 * k, nprobe * p))
        cand_probe = cand // p
        cand_codes = jnp.take_along_axis(
            codes.reshape(nq, -1, codes.shape[-1]), cand[..., None], axis=1)
        s_idx = jnp.arange(mlut.shape[2])[None, None, :]
        vals = mlut[jnp.arange(nq)[:, None, None], cand_probe[..., None],
                    s_idx, cand_codes.astype(jnp.int32)]
        exact = jnp.sum(vals, axis=-1)
        cand_valid = jnp.take_along_axis(valid.reshape(nq, -1), cand, axis=1)
        cand_ids = jnp.take_along_axis(ids.reshape(nq, -1), cand, axis=1)
        if metric == "ip":
            exact = exact + jnp.take_along_axis(probe_base, cand_probe,
                                                axis=1)
            exact = jnp.where(cand_valid, exact, -jnp.inf)
            sel_s, sel = jax.lax.top_k(exact, k)
            out_s = sel_s
        else:
            exact = jnp.where(cand_valid, exact, jnp.inf)
            sel_s, sel = jax.lax.top_k(-exact, k)
            out_s = -sel_s
        return out_s, jnp.take_along_axis(cand_ids, sel, axis=1)

    want_s, want_i = seed_two_stage(idx, q)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want_s))


@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_mode_quality_ordering(matrix_data, metric):
    """The paper's quality ladder must hold: H >= H2 >= M (hit-count modes
    may tie each other but never beat the exact modes)."""
    _, q, idx, gt10 = matrix_data[metric]
    r = {}
    for mode in ["H", "H2", "M"]:
        _, ids = search(idx, q, nprobe=NPROBE, k=100, mode=mode,
                        metric=metric)
        r[mode] = float(recall_n_at_k(ids, gt10))
    assert r["H"] >= r["H2"] - 0.02 >= r["M"] - 0.04, r

"""Recall regression harness: pinned recall@10 lower bounds over the full
mode × metric matrix on fixed-seed synthetic data.

Retrieval quality previously had only coarse spot checks (R1@100 for two
modes); an algorithmic regression in the LUT/threshold/scan pipeline could
pass tier-1 silently. Here every operating point in {H, M, L, H2} × {l2, ip}
must clear a floor set ~30-40% below the measured seed value — loose enough
for cross-machine BLAS jitter, tight enough that any real regression
(masking bug, threshold miscalibration, scan sign flip) fails loudly.

Metric: recall of the exact top-10 within a k=100 candidate list (the
paper's R@k style), plus strict recall@10-of-10 floors for the H modes.
"""
import jax
import pytest

from repro.core import (JunoConfig, build, exact_topk, recall_n_at_k,
                        search)
from repro.data import DEEP_LIKE, TTI_LIKE, make_dataset

NPROBE = 16

# (metric, mode) -> recall@10-in-100 floor.  Measured seed values (2026-08,
# jax 0.4.37 CPU): l2: H=1.000 M=0.669 L=0.354 H2=0.923
#                  ip: H=0.981 M=0.202 L=0.215 H2=0.435
FLOORS_10_AT_100 = {
    ("l2", "H"): 0.95, ("l2", "M"): 0.45, ("l2", "L"): 0.20,
    ("l2", "H2"): 0.80,
    ("ip", "H"): 0.90, ("ip", "M"): 0.10, ("ip", "L"): 0.10,
    ("ip", "H2"): 0.30,
}
# strict k=10 retrieval for the exact-distance modes (seed: l2 H=0.665,
# l2 H2=0.469, ip H=0.642)
FLOORS_10_AT_10 = {
    ("l2", "H"): 0.50, ("l2", "H2"): 0.30, ("ip", "H"): 0.45,
}


@pytest.fixture(scope="module")
def matrix_data():
    out = {}
    for metric, spec in [("l2", DEEP_LIKE), ("ip", TTI_LIKE)]:
        pts, q = make_dataset(spec, 8000, 48, key=jax.random.PRNGKey(13))
        cfg = JunoConfig(n_clusters=32, n_entries=32, calib_queries=24,
                         kmeans_iters=5, metric=metric)
        idx = build(pts, cfg)
        _, gt10 = exact_topk(q, pts, k=10, metric=metric)
        out[metric] = (pts, q, idx, gt10)
    return out


@pytest.mark.parametrize("metric", ["l2", "ip"])
@pytest.mark.parametrize("mode", ["H", "M", "L", "H2"])
def test_recall_floor_10_at_100(matrix_data, metric, mode):
    _, q, idx, gt10 = matrix_data[metric]
    _, ids = search(idx, q, nprobe=NPROBE, k=100, mode=mode, metric=metric)
    r = float(recall_n_at_k(ids, gt10))
    floor = FLOORS_10_AT_100[(metric, mode)]
    assert r >= floor, (
        f"recall@10-in-100 regression: {metric}/{mode} = {r:.3f} < {floor}")


@pytest.mark.parametrize("cell", sorted(FLOORS_10_AT_10))
def test_recall_floor_10_at_10(matrix_data, cell):
    metric, mode = cell
    _, q, idx, gt10 = matrix_data[metric]
    _, ids = search(idx, q, nprobe=NPROBE, k=10, mode=mode, metric=metric)
    r = float(recall_n_at_k(ids, gt10))
    floor = FLOORS_10_AT_10[cell]
    assert r >= floor, (
        f"recall@10 regression: {metric}/{mode} = {r:.3f} < {floor}")


@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_mode_quality_ordering(matrix_data, metric):
    """The paper's quality ladder must hold: H >= H2 >= M (hit-count modes
    may tie each other but never beat the exact modes)."""
    _, q, idx, gt10 = matrix_data[metric]
    r = {}
    for mode in ["H", "H2", "M"]:
        _, ids = search(idx, q, nprobe=NPROBE, k=100, mode=mode,
                        metric=metric)
        r[mode] = float(recall_n_at_k(ids, gt10))
    assert r["H"] >= r["H2"] - 0.02 >= r["M"] - 0.04, r
